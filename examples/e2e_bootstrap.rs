//! End-to-end driver: a full-stack statistical workload proving all three
//! layers compose (EXPERIMENTS.md §E2E).
//!
//! Run: `cargo run --release --example e2e_bootstrap` (needs `make artifacts`)
//!
//! Workload: a weighted (random-weighting) bootstrap of a least-squares
//! regression on a synthetic dataset of 4096 (x, y) points.
//!
//! * L3 (this binary): `plan(multisession, 4)`; `future_lapply` fans 200
//!   replicates out to worker processes with parallel RNG streams
//!   (`seed = TRUE`) and live progress via `immediateCondition`s.
//! * L2: each replicate executes the AOT-compiled `bootstrap_stat` JAX
//!   graph (weighted least-squares from weighted moments) through PJRT.
//! * L1: the weighted-moment reduction inside that graph is the Pallas
//!   kernel `weighted_moments`, validated against ref.py at build time.
//!
//! Output: slope/intercept point estimates, 95% bootstrap CI, wall time —
//! and a reproducibility assertion (same seed ⇒ identical CI).

use std::time::Instant;

use rustures::prelude::*;

const N: usize = 4096;
const REPLICATES: usize = 200;
const WORKERS: usize = 4;
const TRUE_SLOPE: f32 = 2.5;
const TRUE_INTERCEPT: f32 = -1.0;
const NOISE: f32 = 0.5;

fn synth_data(seed: u64) -> Tensor {
    let mut rng = RngStream::from_seed(seed);
    let mut data = Vec::with_capacity(N * 2);
    for _ in 0..N {
        let x = rng.next_unif() as f32 * 4.0 - 2.0;
        let eps = rng.next_norm() as f32 * NOISE;
        data.push(x);
        data.push(TRUE_SLOPE * x + TRUE_INTERCEPT + eps);
    }
    Tensor::new(vec![N, 2], data).unwrap()
}

fn percentile(sorted: &[f64], p: f64) -> f64 {
    let idx = ((sorted.len() - 1) as f64 * p).round() as usize;
    sorted[idx]
}

fn run_bootstrap(session: &Session, seed: u64) -> (Vec<f64>, Vec<f64>) {
    // Per-session counters: a fresh counter per run, no global reset.
    session.reset_counter();
    let mut env = Env::new();
    env.insert("xy", synth_data(7));

    // One replicate: draw random weights, fit, report [slope, intercept],
    // signalling progress every 50th replicate.
    let body = Expr::seq(vec![
        Expr::if_else(
            Expr::prim(
                PrimOp::Eq,
                vec![Expr::var("i"), Expr::lit(0i64)],
            ),
            Expr::progress(Expr::prim(
                PrimOp::Concat,
                vec![Expr::lit("replicate batch starting")],
            )),
            Expr::lit(Value::Unit),
        ),
        Expr::call("bootstrap_stat", vec![Expr::var("xy"), Expr::runif(N)]),
    ]);

    let is: Vec<Value> = (0..REPLICATES as i64).map(Value::I64).collect();
    let fits = session
        .lapply(
            &is,
            "i",
            &body,
            &env,
            &LapplyOpts::new().seed(seed).chunking(Chunking::PerWorker),
        )
        .unwrap();

    let mut slopes: Vec<f64> = Vec::with_capacity(REPLICATES);
    let mut intercepts: Vec<f64> = Vec::with_capacity(REPLICATES);
    for fit in &fits {
        let parts = fit.as_list().expect("bootstrap_stat returns [slope, intercept]");
        slopes.push(parts[0].as_f64().unwrap());
        intercepts.push(parts[1].as_f64().unwrap());
    }
    slopes.sort_by(|a, b| a.partial_cmp(b).unwrap());
    intercepts.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (slopes, intercepts)
}

fn main() {
    if rustures::runtime::global().is_none() {
        eprintln!("e2e_bootstrap requires AOT artifacts: run `make artifacts` first");
        std::process::exit(1);
    }

    println!("== End-to-end: weighted bootstrap of a regression fit ==");
    println!(
        "data: N={N}, true slope {TRUE_SLOPE}, intercept {TRUE_INTERCEPT}, noise sd {NOISE}"
    );
    println!("replicates: {REPLICATES} on plan(multisession, workers = {WORKERS})\n");

    let session = Session::with_plan(PlanSpec::multiprocess(WORKERS));

    let t0 = Instant::now();
    let (slopes, intercepts) = run_bootstrap(&session, 20240710);
    let wall = t0.elapsed();

    let mid = |v: &[f64]| percentile(v, 0.5);
    println!("slope:     {:.4}  95% CI [{:.4}, {:.4}]", mid(&slopes),
        percentile(&slopes, 0.025), percentile(&slopes, 0.975));
    println!("intercept: {:.4}  95% CI [{:.4}, {:.4}]", mid(&intercepts),
        percentile(&intercepts, 0.025), percentile(&intercepts, 0.975));
    println!("wall time: {wall:?}  ({:.1} replicates/s)\n",
        REPLICATES as f64 / wall.as_secs_f64());

    // Sanity: the CI must cover the truth.
    assert!(
        percentile(&slopes, 0.025) < TRUE_SLOPE as f64
            && (TRUE_SLOPE as f64) < percentile(&slopes, 0.975),
        "slope CI missed the truth"
    );

    // Reproducibility: same seed, another session with another worker
    // count — identical bootstrap distribution.
    session.plan(PlanSpec::multiprocess(2));
    let (slopes2, _) = run_bootstrap(&session, 20240710);
    assert_eq!(slopes, slopes2, "bootstrap not reproducible across worker counts");
    println!("reproducibility: identical CI with 2 workers and seed fixed ✓");

    session.close();
    println!("\ne2e_bootstrap OK");
}
