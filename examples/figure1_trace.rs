//! Figure 1 reproduction: `lapply()` over ten `slow_fcn(x)` calls via
//! futures on four multisession workers, rendered as a schedule chart.
//!
//! Run: `cargo run --release --example figure1_trace`
//!
//! The paper's Figure 1 shows ten futures distributed over four background
//! R processes: each future launches when a worker is free, the 5th+ wait,
//! and results (plus relayed output) are collected at the end.  This driver
//! records the same lifecycle (create → launch → exec span → collect) from
//! the metrics layer and prints an ASCII Gantt chart plus a CSV
//! (`figure1_trace.csv`) with the raw timestamps.

use std::fmt::Write as _;

use rustures::prelude::*;

const WORKERS: usize = 4;
const TASKS: usize = 10;

fn main() {
    // A dedicated session owns the plan; its counter starts at 0, so no
    // global reset is needed.
    let session = Session::with_plan(PlanSpec::multiprocess(WORKERS));

    let have_kernels = rustures::runtime::global().is_some();
    let mut env = Env::new();
    let payload = if have_kernels {
        // The real slow_fcn: an AOT-compiled JAX/Pallas matmul chain,
        // called repeatedly so one future ≈ tens of milliseconds.
        let mut rng = RngStream::from_seed(1);
        let x = Tensor::new(vec![128, 128], rng.unif_f32(128 * 128)).unwrap();
        env.insert("x", x);
        Expr::seq(vec![
            Expr::call("slow_fcn_heavy", vec![Expr::var("x")]),
            Expr::call("slow_fcn_heavy", vec![Expr::var("x")]),
            Expr::call("slow_fcn_heavy", vec![Expr::var("x")]),
            Expr::lit(0i64),
        ])
    } else {
        eprintln!("(artifacts missing: using Spin payload — run `make artifacts`)");
        Expr::Spin { millis: 60 }
    };

    // Warm the workers: the first kernel call per worker pays the one-time
    // PJRT runtime load + artifact compile; Figure 1 traces steady state.
    if have_kernels {
        let warm: Vec<Future> =
            (0..WORKERS).map(|_| session.future(payload.clone(), &env).unwrap()).collect();
        for f in &warm {
            let _ = f.value();
        }
        session.reset_counter();
    }

    println!("Figure 1: {TASKS} slow_fcn futures on {WORKERS} multisession workers\n");

    let t0 = std::time::Instant::now();
    let epoch = now_ns();

    // lapply(xs, function(x) future(slow_fcn(x))): create all futures...
    let futures: Vec<Future> = (0..TASKS)
        .map(|i| {
            session
                .future_with(
                    payload.clone(),
                    &env,
                    FutureOpts::new().label(&format!("slow_fcn(xs[{i}])")),
                )
                .unwrap()
        })
        .collect();
    // ...then collect the values (relaying output) at the end.
    let mut rows = Vec::new();
    for (i, f) in futures.iter().enumerate() {
        let result = f.result().unwrap();
        let create = f.trace.created_ns.saturating_sub(epoch);
        let launch =
            f.trace.event_ns("launch").unwrap_or(f.trace.created_ns).saturating_sub(epoch);
        let exec_start = result.metrics.started_ns.saturating_sub(epoch);
        let exec_end = result.metrics.finished_ns.saturating_sub(epoch);
        rows.push((i, create, launch, exec_start, exec_end));
    }
    let wall = t0.elapsed();

    // ASCII Gantt: '.' queued, '#' executing.
    let total_ns = rows.iter().map(|r| r.4).max().unwrap_or(1).max(1);
    let width = 64usize;
    let scale = |ns: u64| ((ns as f64 / total_ns as f64) * width as f64) as usize;
    println!("{:>3} {:<10} {}", "f#", "exec(ms)", "timeline (. queued, # executing)");
    for (i, create, _launch, es, ee) in &rows {
        let (a, b, c) = (scale(*create), scale(*es), scale(*ee));
        let mut line = String::new();
        for _ in 0..a {
            line.push(' ');
        }
        for _ in a..b {
            line.push('.');
        }
        for _ in b..c.max(b + 1) {
            line.push('#');
        }
        println!("{i:>3} {:<10.2} {line}", (*ee - *es) as f64 / 1e6);
    }
    println!("\nwall clock: {wall:?} ({TASKS} tasks, {WORKERS} workers)");

    // The Figure-1 shape: with 4 workers, at most 4 tasks execute
    // concurrently, later tasks queue until a worker frees.
    let mut events: Vec<(u64, i32)> = Vec::new();
    for (_, _, _, es, ee) in &rows {
        events.push((*es, 1));
        events.push((*ee, -1));
    }
    events.sort();
    let mut now = 0;
    let mut peak = 0;
    for (_, d) in events {
        now += d;
        peak = peak.max(now);
    }
    println!("peak concurrent executions: {peak} (≤ {WORKERS} expected)");

    // CSV for plotting.
    let mut csv = String::from("future,create_ns,launch_ns,exec_start_ns,exec_end_ns\n");
    for (i, c, l, es, ee) in &rows {
        writeln!(csv, "{i},{c},{l},{es},{ee}").unwrap();
    }
    std::fs::write("figure1_trace.csv", csv).unwrap();
    println!("wrote figure1_trace.csv");

    // Supervision metrics, keyed per session (JSON schema v1).
    println!("supervision: {}", rustures::metrics::supervision_json());
    // Capacity ledger + result-cache counters for the same run — queried
    // before close() so this session's rows are still resident.
    println!("capacity: {}", rustures::metrics::capacity_json());
    println!("cache: {}", rustures::metrics::cache_json());
    // Transport reactor: one poll thread drove all four worker channels —
    // wakeups/frames/outbox gauges for the run (queried before close()
    // while the channels are still registered).
    println!("transport: {}", rustures::metrics::transport_json());

    session.close();
}

fn now_ns() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .unwrap_or_default()
        .as_nanos() as u64
}
