//! Monte-Carlo π on the simulated HPC scheduler — the
//! `plan(batchtools_slurm)` workflow from the paper.
//!
//! Run: `cargo run --release --example mc_pi_hpc` (needs `make artifacts`)
//!
//! Each future is submitted as a *job* to the scheduler substrate: spooled
//! to disk, queued behind a submission latency, admitted to a node slot,
//! executed by an isolated worker process (`rustures worker --batch-job`)
//! that runs the `mc_pi_block` PJRT kernel, and harvested by polling —
//! exactly the batchtools job model.  The same code then reruns on
//! multisession to demonstrate the paper's headline property: *change
//! plan(), change nothing else, get the identical answer*.

use std::time::Instant;

use rustures::prelude::*;

const BLOCK: usize = 8192; // samples per job (the AOT-compiled shape)
const JOBS: usize = 24;

/// One estimation run inside its own `Session` — the plan is the only
/// thing that changes between runs (the paper's headline property), and a
/// fresh session means a fresh future-creation counter (no reset needed).
fn estimate_pi(spec: PlanSpec) -> (f64, std::time::Duration) {
    let session = Session::with_plan(spec);
    // One job: draw u ~ f32[8192, 2] from the job's own RNG stream and
    // count in-circle hits on the device.
    let body = Expr::call("mc_pi_block", vec![Expr::runif_shaped(vec![BLOCK, 2])]);

    let is: Vec<Value> = (0..JOBS as i64).map(Value::I64).collect();
    let t0 = Instant::now();
    let estimates = session
        .lapply(&is, "i", &body, &Env::new(), &LapplyOpts::new().seed(3141592))
        .unwrap();
    let wall = t0.elapsed();
    session.close();

    let mean: f64 =
        estimates.iter().map(|v| v.as_f64().unwrap()).sum::<f64>() / estimates.len() as f64;
    (mean, wall)
}

fn main() {
    if rustures::runtime::global().is_none() {
        eprintln!("mc_pi_hpc requires AOT artifacts: run `make artifacts` first");
        std::process::exit(1);
    }

    println!(
        "== Monte-Carlo π: {JOBS} jobs × {BLOCK} samples = {} draws ==\n",
        JOBS * BLOCK
    );

    // 1. The HPC way: every future is a scheduler job.
    let (pi_batch, wall_batch) = estimate_pi(PlanSpec::Batch {
        workers: 4,
        submit_latency_ms: 10,
        poll_interval_ms: 2,
    });
    println!("batchtools (4 nodes, 10ms submit latency):");
    println!(
        "  π ≈ {pi_batch:.5}  (err {:+.5})  wall {wall_batch:?}",
        pi_batch - std::f64::consts::PI
    );

    // 2. Same code, local multisession — only the session's plan changed.
    let (pi_ms, wall_ms) = estimate_pi(PlanSpec::multiprocess(4));
    println!("multisession (4 workers):");
    println!(
        "  π ≈ {pi_ms:.5}  (err {:+.5})  wall {wall_ms:?}",
        pi_ms - std::f64::consts::PI
    );

    // Identical digits: RNG streams are backend-independent.
    assert_eq!(pi_batch, pi_ms, "π must be identical across backends");
    println!("\nplan-independent result ✓ (batchtools ≡ multisession, bit-for-bit)");
    println!(
        "latency profile: batch {}ms vs multisession {}ms — the paper's \
         \"batchtools is for throughput, not latency\"",
        wall_batch.as_millis(),
        wall_ms.as_millis()
    );

    assert!((pi_batch - std::f64::consts::PI).abs() < 0.02, "π estimate off: {pi_batch}");

    println!("\nmc_pi_hpc OK");
}
