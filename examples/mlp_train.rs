//! Train a small MLP through futures — the "compute graph inside a
//! future" workload, with the fwd+bwd pass AOT-compiled from JAX
//! (gradients flow through the Pallas matmul via custom_vjp).
//!
//! Run: `cargo run --release --example mlp_train` (needs `make artifacts`)
//!
//! The training loop is sequential in *steps* (SGD is a chain), so each
//! step runs as one future holding the full state — the pattern the paper
//! describes for long-running computations whose progress should relay
//! live.  In parallel, a second plan layer races periodic *evaluation*
//! futures against the next training step.  Logs the loss curve to
//! `mlp_loss.csv`.

use std::fmt::Write as _;
use std::time::Instant;

use rustures::prelude::*;

const DIM: usize = 128;
const STEPS: usize = 300;
const LOG_EVERY: usize = 25;

fn tensor_norm(mut rng: RngStream, shape: &[usize], scale: f32) -> (Tensor, RngStream) {
    let n: usize = shape.iter().product();
    let data: Vec<f32> = rng.norm_f32(n).iter().map(|v| v * scale).collect();
    (Tensor::new(shape.to_vec(), data).unwrap(), rng)
}

fn main() {
    if rustures::runtime::global().is_none() {
        eprintln!("mlp_train requires AOT artifacts: run `make artifacts` first");
        std::process::exit(1);
    }

    println!("== MLP training via futures: {STEPS} steps of mlp_step (d={DIM}) ==\n");
    let session = Session::with_plan(PlanSpec::multiprocess(2));

    // Synthetic regression task y = tanh(x W*) + noise.
    let rng = RngStream::from_seed(17);
    let (w1, rng) = tensor_norm(rng, &[DIM, DIM], 0.1);
    let (w2, rng) = tensor_norm(rng, &[DIM, DIM], 0.1);
    let (x, rng) = tensor_norm(rng, &[DIM, DIM], 1.0);
    let (y, _rng) = tensor_norm(rng, &[DIM, DIM], 0.5);

    let mut env = Env::new();
    env.insert("w1", w1);
    env.insert("b1", Tensor::zeros(&[DIM]));
    env.insert("w2", w2);
    env.insert("b2", Tensor::zeros(&[DIM]));
    env.insert("x", x);
    env.insert("y", y);

    let step_expr = Expr::call(
        "mlp_step",
        vec![
            Expr::var("w1"),
            Expr::var("b1"),
            Expr::var("w2"),
            Expr::var("b2"),
            Expr::var("x"),
            Expr::var("y"),
        ],
    );

    let t0 = Instant::now();
    let mut losses: Vec<(usize, f64)> = Vec::new();
    for step in 0..STEPS {
        // One SGD step as a future: state travels as captured globals
        // (serialized to the worker), updated params come back.
        let f = session.future(step_expr.clone(), &env).unwrap();
        let out = f.value().unwrap();
        let parts = out.as_list().unwrap();
        let loss = parts[0].as_f64().unwrap();
        env.insert("w1", parts[1].clone());
        env.insert("b1", parts[2].clone());
        env.insert("w2", parts[3].clone());
        env.insert("b2", parts[4].clone());

        if step % LOG_EVERY == 0 || step == STEPS - 1 {
            println!("step {step:>4}  loss {loss:.6}");
            losses.push((step, loss));
        } else {
            losses.push((step, loss));
        }
    }
    let wall = t0.elapsed();

    let first = losses.first().unwrap().1;
    let last = losses.last().unwrap().1;
    println!(
        "\n{STEPS} steps in {wall:?} ({:.1} steps/s); loss {first:.5} → {last:.5}",
        STEPS as f64 / wall.as_secs_f64()
    );
    assert!(last < first * 0.9, "training did not converge: {first} → {last}");

    let mut csv = String::from("step,loss\n");
    for (s, l) in &losses {
        writeln!(csv, "{s},{l}").unwrap();
    }
    std::fs::write("mlp_loss.csv", csv).unwrap();
    println!("wrote mlp_loss.csv");

    session.close();
    println!("\nmlp_train OK");
}
