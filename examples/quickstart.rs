//! Quickstart — the paper's introductory examples, end to end.
//!
//! Run: `cargo run --release --example quickstart`
//!
//! Covers: the three atomic constructs, creation-time globals capture,
//! plan() switching (the end-user's knob) via first-class `Session`
//! contexts, future assignments + listenv, error relay, a parallel map
//! with load balancing, and two concurrent sessions in one process.

use rustures::api::future::values;
use rustures::api::promise::FuturePromise;
use rustures::prelude::*;

fn main() {
    // ----------------------------------------------------------------
    // 1. The assignment decoupled:  f <- future(expr);  v <- value(f)
    //    (a Session owns the plan; free functions target the current one)
    // ----------------------------------------------------------------
    let session = Session::with_plan(PlanSpec::sequential());
    let mut env = Env::new();
    env.insert("x", 1.0);

    let f = session.future(Expr::mul(Expr::var("x"), Expr::lit(100.0)), &env).unwrap();
    env.insert("x", 2.0); // reassigned after creation...
    let v = f.value().unwrap();
    println!("1. future(x * 100) with x=1 at creation, x=2 at collect → {v}");
    assert_eq!(v, Value::F64(100.0)); // ...the future saw x = 1

    // ----------------------------------------------------------------
    // 2. The end-user picks the backend: session.plan(multisession)
    // ----------------------------------------------------------------
    session.plan(PlanSpec::multiprocess(2));
    println!("2. session.plan(multisession, workers = 2)");

    // Three futures, two workers: the third create blocks until a worker
    // frees (the paper's blocking example).  session.scope(...) makes this
    // session the target of the free functions inside.
    let env2 = Env::new();
    let vs = session.scope(|_| {
        let futures: Vec<Future> = (1..=3)
            .map(|i| {
                future(
                    Expr::seq(vec![Expr::Spin { millis: 50 }, Expr::lit(i as i64)]),
                    &env2,
                )
                .unwrap()
            })
            .collect();
        values(&futures).unwrap()
    });
    println!("   three futures on two workers → {vs:?}");

    // ----------------------------------------------------------------
    // 3. v %<-% expr  (future assignment) and listenv
    // ----------------------------------------------------------------
    session.scope(|_| {
        let p =
            FuturePromise::assign(Expr::add(Expr::lit(40.0), Expr::lit(2.0)), &env2).unwrap();
        println!("3. v %<-% (40 + 2) → {}", p.get().unwrap());

        let mut lv = ListEnv::new();
        for i in 0..4usize {
            lv.assign(i, Expr::mul(Expr::lit(i as i64), Expr::lit(i as i64)), &env2).unwrap();
        }
        println!("   listenv squares → {:?}", lv.as_list().unwrap());
    });

    // ----------------------------------------------------------------
    // 4. Errors relay as-is; tryCatch-style handling
    // ----------------------------------------------------------------
    let bad = session.future(Expr::stop(Expr::lit("non-numeric argument")), &env2).unwrap();
    match bad.value() {
        Err(FutureError::Eval(e)) => println!("4. relayed error: \"{e}\""),
        other => panic!("unexpected: {other:?}"),
    }

    // ----------------------------------------------------------------
    // 5. Parallel map-reduce with load balancing + parallel RNG
    // ----------------------------------------------------------------
    let xs: Vec<Value> = (0..10i64).map(Value::I64).collect();
    let body = Expr::add(Expr::var("x"), Expr::runif(1));
    let out = session.lapply(&xs, "x", &body, &env2, &LapplyOpts::new().seed(42)).unwrap();
    println!("5. future_lapply(xs, x + runif(1)), seeded → {} results", out.len());
    // Rerun: identical (reproducible regardless of backend/workers).
    let out2 = session.lapply(&xs, "x", &body, &env2, &LapplyOpts::new().seed(42)).unwrap();
    assert_eq!(out, out2);
    println!("   rerun is bit-identical ✓");

    // ----------------------------------------------------------------
    // 6. future_either — first resolved wins
    // ----------------------------------------------------------------
    session.plan(PlanSpec::multicore(3));
    let winner = session.scope(|_| {
        future_either(
            vec![
                Expr::seq(vec![Expr::Spin { millis: 300 }, Expr::lit("shell sort")]),
                Expr::seq(vec![Expr::Spin { millis: 10 }, Expr::lit("quick sort")]),
                Expr::seq(vec![Expr::Spin { millis: 300 }, Expr::lit("radix sort")]),
            ],
            &env2,
        )
        .unwrap()
    });
    println!("6. future_either(3 sorts) → winner: {winner}");

    // ----------------------------------------------------------------
    // 7. Two tenants, one process: independent sessions, independent plans
    // ----------------------------------------------------------------
    let tenant_a = Session::with_plan(PlanSpec::multicore(2));
    let tenant_b = Session::with_plan(PlanSpec::multiprocess(2));
    let wa = tenant_a.lapply(&xs, "x", &body, &env2, &LapplyOpts::new().seed(42)).unwrap();
    let wb = tenant_b.lapply(&xs, "x", &body, &env2, &LapplyOpts::new().seed(42)).unwrap();
    assert_eq!(wa, wb, "same seed, different backends, bit-identical");
    println!("7. two concurrent sessions (multicore vs multisession) agree bit-identically ✓");
    tenant_a.close();
    tenant_b.close();

    session.close();
    println!("\nquickstart OK");
}
