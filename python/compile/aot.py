"""AOT compile path: lower every L2 entry to HLO *text* + a manifest.

HLO text (NOT ``lowered.compiler_ir("hlo").as_hlo_text()`` via serialized
protos) is the interchange format: jax >= 0.5 emits HloModuleProto with
64-bit instruction ids which xla_extension 0.5.1 (behind the Rust ``xla``
crate) rejects; the text parser reassigns ids and round-trips cleanly.

Run once by ``make artifacts``; the Rust binary is self-contained afterwards.

Usage: python -m compile.aot --out-dir ../artifacts
"""

import argparse
import hashlib
import json
import os

import jax
from jax._src.lib import xla_client as xc

from .model import ENTRIES


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple for the loader)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec_json(s):
    return {"shape": list(s.shape), "dtype": str(s.dtype)}


def lower_entry(name, fn, example_args):
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    out_avals = lowered.out_info
    outputs = [
        {"shape": list(o.shape), "dtype": str(o.dtype)}
        for o in jax.tree_util.tree_leaves(out_avals)
    ]
    return text, outputs


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts")
    p.add_argument("--only", default=None, help="comma-separated entry names")
    args = p.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    only = set(args.only.split(",")) if args.only else None
    manifest = {"format": 1, "entries": []}
    for name, (fn, example_args) in ENTRIES.items():
        if only and name not in only:
            continue
        text, outputs = lower_entry(name, fn, example_args)
        fname = f"{name}.hlo.txt"
        path = os.path.join(args.out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        manifest["entries"].append(
            {
                "name": name,
                "file": fname,
                "args": [_spec_json(s) for s in example_args],
                "outputs": outputs,
                "sha256": hashlib.sha256(text.encode()).hexdigest(),
            }
        )
        print(f"lowered {name}: {len(text)} chars, {len(outputs)} outputs")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest with {len(manifest['entries'])} entries")


if __name__ == "__main__":
    main()
