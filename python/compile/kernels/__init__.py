# L1: Pallas kernels for the compute hot-spots; ref.py is the jnp oracle.
from . import matmul, ref, resample  # noqa: F401
