"""L1 Pallas kernel: tiled matmul shaped for the TPU MXU.

The paper's ``slow_fcn(x)`` payloads and the MLP train step bottom out in
dense matmuls.  This kernel expresses the classic HBM->VMEM tiling schedule
with ``BlockSpec``: a 3-D grid over (M/bm, N/bn, K/bk), f32 accumulation in
the output tile across the K dimension (``preferred_element_type``), blocks
sized as multiples of (8, 128) for the MXU systolic array.

``interpret=True`` is mandatory on this image: real TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute.  Interpret mode lowers
to plain HLO so the same artifact runs on the Rust CPU client.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes.  128x128x128 is the MXU-native shape; tests shrink the
# tiles to force multi-step grids on small operands.
DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128


def _matmul_kernel(x_ref, y_ref, o_ref):
    """One (bm, bn) output tile; accumulates over the K grid dimension.

    o_ref doubles as the accumulator: zeroed on the first K step, flushed
    implicitly on the last.  This is the standard Pallas accumulation idiom
    and keeps the kernel scratch-free (interpret-mode friendly).
    """

    @pl.when(pl.program_id(2) == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(x, y, *, bm=DEFAULT_BM, bn=DEFAULT_BN, bk=DEFAULT_BK):
    """Tiled matmul ``x @ y`` via a Pallas kernel.

    Args:
      x: f32[M, K]; M % bm == 0 and K % bk == 0.
      y: f32[K, N]; N % bn == 0.
      bm/bn/bk: tile sizes (multiples of 8 and 128 on real TPU).

    Returns:
      f32[M, N].
    """
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"contraction mismatch: {x.shape} @ {y.shape}"
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"shapes {(m, k, n)} not divisible by tiles {(bm, bk, bn)}"
    )
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, y)


@jax.custom_vjp
def mm(x, y):
    """Differentiable wrapper around the Pallas matmul.

    ``pallas_call`` has no autodiff rule, so the MLP train step (which takes
    ``jax.grad`` through its matmuls) routes both the forward and the two
    backward products through the same kernel via ``custom_vjp``.
    """
    return matmul(x, y)


def _mm_fwd(x, y):
    return matmul(x, y), (x, y)


def _mm_bwd(res, g):
    x, y = res
    # dX = g @ Y^T ; dY = X^T @ g — both through the Pallas kernel.
    return matmul(g, y.T), matmul(x.T, g)


mm.defvjp(_mm_fwd, _mm_bwd)
