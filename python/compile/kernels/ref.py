"""Pure-jnp oracles for the Pallas kernels — the correctness ground truth.

Every Pallas kernel in this package has an exact (up to float tolerance)
reference here, written with nothing but jnp ops.  pytest + hypothesis sweep
shapes and values and assert_allclose kernel vs oracle.
"""

import jax.numpy as jnp


def matmul_ref(x, y):
    """Oracle for kernels.matmul.matmul."""
    return jnp.matmul(x, y, preferred_element_type=jnp.float32)


def weighted_moments_ref(xy, w):
    """Oracle for kernels.resample.weighted_moments (8-lane moment vector)."""
    x = xy[:, 0]
    y = xy[:, 1]
    z = jnp.zeros((), jnp.float32)
    return jnp.stack(
        [
            jnp.sum(w),
            jnp.sum(w * x),
            jnp.sum(w * y),
            jnp.sum(w * x * x),
            jnp.sum(w * x * y),
            jnp.sum(w * y * y),
            z,
            z,
        ]
    )


def count_in_circle_ref(u):
    """Oracle for kernels.resample.count_in_circle."""
    inside = (u[:, 0] ** 2 + u[:, 1] ** 2) <= 1.0
    return jnp.sum(inside.astype(jnp.float32))[None]


def wls_fit_ref(xy, w):
    """Weighted least-squares (slope, intercept) directly from the data."""
    x = xy[:, 0]
    y = xy[:, 1]
    sw = jnp.sum(w)
    swx = jnp.sum(w * x)
    swy = jnp.sum(w * y)
    swxx = jnp.sum(w * x * x)
    swxy = jnp.sum(w * x * y)
    denom = sw * swxx - swx * swx
    slope = (sw * swxy - swx * swy) / denom
    intercept = (swy - slope * swx) / sw
    return slope, intercept
