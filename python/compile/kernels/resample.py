"""L1 Pallas kernels: blocked streaming reductions.

Two reduction kernels back the paper-style workloads:

* ``weighted_moments`` — the bootstrap hot-spot.  Given (x, y) pairs and a
  bootstrap weight vector, it streams blocks of rows through VMEM and
  accumulates the five weighted moments a weighted least-squares fit needs
  (sum w, sum w*x, sum w*y, sum w*x^2, sum w*x*y) plus sum w*y^2 for R^2.

* ``count_in_circle`` — the Monte-Carlo-pi hot-spot: counts uniform points
  falling inside the unit quarter-circle, block by block.

Both use the grid-accumulation idiom (output tile is the accumulator, zeroed
at grid step 0) and (8, 128)-aligned blocks.  interpret=True throughout: the
CPU PJRT plugin cannot run Mosaic custom-calls.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Number of accumulated moments, padded to 8 lanes for layout friendliness.
N_MOMENTS = 8
DEFAULT_BLOCK = 512


def _moments_kernel(xy_ref, w_ref, o_ref):
    @pl.when(pl.program_id(0) == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    xy = xy_ref[...]  # (bn, 2)
    w = w_ref[...]  # (bn,)
    x = xy[:, 0]
    y = xy[:, 1]
    o_ref[...] += jnp.stack(
        [
            jnp.sum(w),
            jnp.sum(w * x),
            jnp.sum(w * y),
            jnp.sum(w * x * x),
            jnp.sum(w * x * y),
            jnp.sum(w * y * y),
            jnp.array(0.0, jnp.float32),
            jnp.array(0.0, jnp.float32),
        ]
    )


@functools.partial(jax.jit, static_argnames=("block",))
def weighted_moments(xy, w, *, block=DEFAULT_BLOCK):
    """Weighted moment vector of (x, y) rows under bootstrap weights ``w``.

    Args:
      xy: f32[N, 2] data rows; N % block == 0.
      w: f32[N] bootstrap weights (multinomial counts or continuous).
      block: rows streamed through VMEM per grid step.

    Returns:
      f32[8]: [Sw, Swx, Swy, Swxx, Swxy, Swyy, 0, 0].
    """
    n = xy.shape[0]
    block = min(block, n)
    assert xy.shape == (n, 2) and w.shape == (n,)
    assert n % block == 0, f"N={n} not divisible by block={block}"
    grid = (n // block,)
    return pl.pallas_call(
        _moments_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block, 2), lambda i: (i, 0)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((N_MOMENTS,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((N_MOMENTS,), jnp.float32),
        interpret=True,
    )(xy, w)


def _circle_kernel(u_ref, o_ref):
    @pl.when(pl.program_id(0) == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    u = u_ref[...]  # (bn, 2)
    inside = (u[:, 0] * u[:, 0] + u[:, 1] * u[:, 1]) <= 1.0
    o_ref[0] += jnp.sum(inside.astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("block",))
def count_in_circle(u, *, block=DEFAULT_BLOCK):
    """Number of rows of ``u`` (f32[N, 2] uniforms) inside the unit circle.

    Returns f32[1] so the accumulator keeps an array layout.
    """
    n = u.shape[0]
    block = min(block, n)
    assert u.shape == (n, 2)
    assert n % block == 0, f"N={n} not divisible by block={block}"
    return pl.pallas_call(
        _circle_kernel,
        grid=(n // block,),
        in_specs=[pl.BlockSpec((block, 2), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((1,), jnp.float32),
        interpret=True,
    )(u)
