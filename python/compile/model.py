"""L2: the JAX compute graphs behind the paper's ``slow_fcn(x)`` payloads.

The futures paper keeps its workload abstract ("a slow function").  Here each
payload is a real compute graph, calling the L1 Pallas kernels, AOT-lowered
once by aot.py and executed by Rust workers through PJRT.  Nothing in this
file runs on the request path.

Payloads (all static shapes — required for AOT):

* ``slow_fcn``       — the paper's generic expensive function: an iterated,
                       normalized matmul chain over f32[128,128].
* ``slow_fcn_heavy`` — same, 4x the iterations (for future_either races and
                       overhead/throughput benches).
* ``bootstrap_stat`` — one bootstrap replicate: weighted least-squares fit
                       of y~x under a bootstrap weight vector (the e2e
                       example's per-future payload).
* ``mc_pi_block``    — Monte-Carlo pi from a block of uniforms.
* ``mlp_step``       — one SGD step of a 2-layer MLP (fwd+bwd through the
                       Pallas matmul via custom_vjp): the "train a model
                       inside a future" workload.
"""

import jax
import jax.numpy as jnp

from .kernels.matmul import mm
from .kernels.resample import count_in_circle, weighted_moments

# Static workload shapes (the AOT contract; mirrored in artifacts/manifest.json).
SLOW_DIM = 128
BOOT_N = 4096
PI_N = 8192
MLP_DIM = 128
SLOW_ITERS = 8
HEAVY_ITERS = 32
LEARNING_RATE = 0.01


def _slow_chain(x, iters):
    """Iterated normalized matmul: y <- tanh(y @ x / dim), ``iters`` times."""
    scale = 1.0 / x.shape[0]
    y = x
    for _ in range(iters):
        y = jnp.tanh(mm(y, x) * scale)
    return (y,)


def slow_fcn(x):
    """f32[128,128] -> (f32[128,128],): the paper's generic slow payload."""
    return _slow_chain(x, SLOW_ITERS)


def slow_fcn_heavy(x):
    """As slow_fcn but 4x the matmul chain — a deliberately slower racer."""
    return _slow_chain(x, HEAVY_ITERS)


def bootstrap_stat(xy, w):
    """One bootstrap replicate of a weighted least-squares fit.

    Args:
      xy: f32[4096, 2] (x, y) rows.
      w: f32[4096] bootstrap weights for this replicate.

    Returns:
      (slope f32[], intercept f32[]).
    """
    s = weighted_moments(xy, w)
    sw, swx, swy, swxx, swxy = s[0], s[1], s[2], s[3], s[4]
    denom = sw * swxx - swx * swx
    slope = (sw * swxy - swx * swy) / denom
    intercept = (swy - slope * swx) / sw
    return (slope, intercept)


def mc_pi_block(u):
    """Monte-Carlo pi estimate from f32[8192, 2] uniforms in [0,1)^2."""
    count = count_in_circle(u)[0]
    return (4.0 * count / u.shape[0],)


def _mlp_loss(w1, b1, w2, b2, x, y):
    h = jnp.tanh(mm(x, w1) + b1)
    pred = mm(h, w2) + b2
    return jnp.mean((pred - y) ** 2)


def mlp_step(w1, b1, w2, b2, x, y):
    """One SGD step of a 2-layer MLP; fwd+bwd run through the Pallas matmul.

    Returns (loss, w1', b1', w2', b2').
    """
    loss, grads = jax.value_and_grad(_mlp_loss, argnums=(0, 1, 2, 3))(
        w1, b1, w2, b2, x, y
    )
    g1, gb1, g2, gb2 = grads
    return (
        loss,
        w1 - LEARNING_RATE * g1,
        b1 - LEARNING_RATE * gb1,
        w2 - LEARNING_RATE * g2,
        b2 - LEARNING_RATE * gb2,
    )


def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


# AOT entry registry: name -> (fn, example_args).  aot.py lowers each entry
# to artifacts/<name>.hlo.txt; the Rust runtime loads them by name via
# artifacts/manifest.json.
ENTRIES = {
    "slow_fcn": (slow_fcn, (_f32(SLOW_DIM, SLOW_DIM),)),
    "slow_fcn_heavy": (slow_fcn_heavy, (_f32(SLOW_DIM, SLOW_DIM),)),
    "bootstrap_stat": (bootstrap_stat, (_f32(BOOT_N, 2), _f32(BOOT_N))),
    "mc_pi_block": (mc_pi_block, (_f32(PI_N, 2),)),
    "mlp_step": (
        mlp_step,
        (
            _f32(MLP_DIM, MLP_DIM),
            _f32(MLP_DIM),
            _f32(MLP_DIM, MLP_DIM),
            _f32(MLP_DIM),
            _f32(MLP_DIM, MLP_DIM),
            _f32(MLP_DIM, MLP_DIM),
        ),
    ),
}
