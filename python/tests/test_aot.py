"""AOT path: every entry lowers to parseable HLO text with a sound manifest."""

import json
import os
import subprocess
import sys

import pytest

from compile import aot, model


@pytest.mark.parametrize("name", list(model.ENTRIES))
def test_entry_lowers_to_hlo_text(name):
    fn, example = model.ENTRIES[name]
    text, outputs = aot.lower_entry(name, fn, example)
    assert "HloModule" in text
    assert "ENTRY" in text
    assert len(outputs) >= 1
    # interpret=True must have erased all Mosaic custom-calls.
    assert "mosaic" not in text.lower()


def test_manifest_roundtrip(tmp_path):
    out = tmp_path / "artifacts"
    pkg_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out),
         "--only", "mc_pi_block"],
        check=True,
        cwd=pkg_root,
    )
    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["format"] == 1
    [entry] = manifest["entries"]
    assert entry["name"] == "mc_pi_block"
    assert entry["args"][0]["shape"] == [model.PI_N, 2]
    hlo = (out / entry["file"]).read_text()
    assert "HloModule" in hlo
    import hashlib

    assert hashlib.sha256(hlo.encode()).hexdigest() == entry["sha256"]


def test_output_specs_match_model():
    fn, example = model.ENTRIES["bootstrap_stat"]
    _, outputs = aot.lower_entry("bootstrap_stat", fn, example)
    assert len(outputs) == 2  # slope, intercept
    assert all(o["shape"] == [] for o in outputs)
