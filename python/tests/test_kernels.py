"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes (multiples of the tile sizes) and values; fixed
seeds keep the suite deterministic.  This is the CORE correctness signal for
the compiled artifacts the Rust runtime executes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.matmul import matmul, mm
from compile.kernels.resample import count_in_circle, weighted_moments

jax.config.update("jax_platform_name", "cpu")


def _rand(key, *shape, lo=-1.0, hi=1.0):
    return jax.random.uniform(key, shape, jnp.float32, lo, hi)


class TestMatmul:
    @pytest.mark.parametrize(
        "m,k,n,bm,bn,bk",
        [
            (64, 64, 64, 64, 64, 64),  # single tile
            (128, 128, 128, 64, 64, 64),  # 2x2x2 grid — exercises accumulation
            (128, 256, 64, 64, 64, 64),  # rectangular, deep K
            (64, 64, 64, 128, 128, 128),  # tiles clamped to operand
            (256, 128, 128, 128, 128, 128),  # MXU-native tiles
        ],
    )
    def test_matches_ref(self, m, k, n, bm, bn, bk):
        kx, ky = jax.random.split(jax.random.PRNGKey(m * k + n))
        x, y = _rand(kx, m, k), _rand(ky, k, n)
        got = matmul(x, y, bm=bm, bn=bn, bk=bk)
        np.testing.assert_allclose(got, ref.matmul_ref(x, y), rtol=1e-5, atol=1e-5)

    def test_rejects_mismatched_contraction(self):
        with pytest.raises(AssertionError):
            matmul(jnp.zeros((64, 64)), jnp.zeros((128, 64)))

    def test_rejects_untileable_shape(self):
        with pytest.raises(AssertionError):
            matmul(jnp.zeros((96, 64)), jnp.zeros((64, 64)), bm=64)

    def test_identity(self):
        x = _rand(jax.random.PRNGKey(7), 64, 64)
        np.testing.assert_allclose(
            matmul(x, jnp.eye(64, dtype=jnp.float32)), x, rtol=1e-6, atol=1e-6
        )

    @settings(max_examples=10, deadline=None)
    @given(
        mi=st.integers(1, 3),
        ki=st.integers(1, 3),
        ni=st.integers(1, 3),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_shape_sweep(self, mi, ki, ni, seed):
        m, k, n = 64 * mi, 64 * ki, 64 * ni
        kx, ky = jax.random.split(jax.random.PRNGKey(seed))
        x, y = _rand(kx, m, k, lo=-2, hi=2), _rand(ky, k, n, lo=-2, hi=2)
        got = matmul(x, y, bm=64, bn=64, bk=64)
        np.testing.assert_allclose(got, ref.matmul_ref(x, y), rtol=1e-4, atol=1e-4)

    def test_mm_gradient_matches_jnp(self):
        """custom_vjp backward (both products via Pallas) vs jnp autodiff."""
        kx, ky = jax.random.split(jax.random.PRNGKey(3))
        x, y = _rand(kx, 64, 64), _rand(ky, 64, 64)

        gx_pallas, gy_pallas = jax.grad(lambda a, b: jnp.sum(mm(a, b) ** 2), (0, 1))(x, y)
        gx_ref, gy_ref = jax.grad(lambda a, b: jnp.sum((a @ b) ** 2), (0, 1))(x, y)
        np.testing.assert_allclose(gx_pallas, gx_ref, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(gy_pallas, gy_ref, rtol=1e-4, atol=1e-4)


class TestWeightedMoments:
    @pytest.mark.parametrize("n,block", [(512, 512), (1024, 256), (4096, 512)])
    def test_matches_ref(self, n, block):
        kx, kw = jax.random.split(jax.random.PRNGKey(n))
        xy = _rand(kx, n, 2, lo=-3, hi=3)
        w = _rand(kw, n, lo=0, hi=2)
        got = weighted_moments(xy, w, block=block)
        np.testing.assert_allclose(
            got, ref.weighted_moments_ref(xy, w), rtol=1e-4, atol=1e-3
        )

    def test_zero_weights_give_zero_moments(self):
        xy = _rand(jax.random.PRNGKey(0), 512, 2)
        got = weighted_moments(xy, jnp.zeros(512, jnp.float32))
        np.testing.assert_allclose(got, jnp.zeros(8), atol=1e-7)

    def test_uniform_weights_recover_unweighted_sums(self):
        xy = _rand(jax.random.PRNGKey(1), 512, 2)
        got = weighted_moments(xy, jnp.ones(512, jnp.float32))
        assert abs(float(got[0]) - 512.0) < 1e-3
        np.testing.assert_allclose(
            float(got[1]), float(jnp.sum(xy[:, 0])), rtol=1e-4, atol=1e-3
        )

    @settings(max_examples=10, deadline=None)
    @given(blocks=st.integers(1, 8), seed=st.integers(0, 2**31 - 1))
    def test_hypothesis_block_sweep(self, blocks, seed):
        n = 128 * blocks
        kx, kw = jax.random.split(jax.random.PRNGKey(seed))
        xy = _rand(kx, n, 2, lo=-1, hi=1)
        w = _rand(kw, n, lo=0, hi=1)
        got = weighted_moments(xy, w, block=128)
        np.testing.assert_allclose(
            got, ref.weighted_moments_ref(xy, w), rtol=1e-4, atol=1e-3
        )

    def test_block_size_invariance(self):
        """Same data, different VMEM block schedule -> same moments."""
        kx, kw = jax.random.split(jax.random.PRNGKey(5))
        xy = _rand(kx, 1024, 2)
        w = _rand(kw, 1024, lo=0, hi=1)
        a = weighted_moments(xy, w, block=128)
        b = weighted_moments(xy, w, block=1024)
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-4)


class TestCountInCircle:
    @pytest.mark.parametrize("n,block", [(512, 512), (8192, 512), (1024, 128)])
    def test_matches_ref(self, n, block):
        u = jax.random.uniform(jax.random.PRNGKey(n), (n, 2), jnp.float32)
        got = count_in_circle(u, block=block)
        np.testing.assert_allclose(got, ref.count_in_circle_ref(u), atol=0.5)

    def test_all_inside(self):
        u = jnp.full((512, 2), 0.1, jnp.float32)
        assert float(count_in_circle(u)[0]) == 512.0

    def test_all_outside(self):
        u = jnp.full((512, 2), 1.0, jnp.float32)
        assert float(count_in_circle(u)[0]) == 0.0

    @settings(max_examples=8, deadline=None)
    @given(blocks=st.integers(1, 6), seed=st.integers(0, 2**31 - 1))
    def test_hypothesis_sweep(self, blocks, seed):
        n = 256 * blocks
        u = jax.random.uniform(jax.random.PRNGKey(seed), (n, 2), jnp.float32)
        got = count_in_circle(u, block=256)
        np.testing.assert_allclose(got, ref.count_in_circle_ref(u), atol=0.5)
