"""L2 correctness: the payload graphs vs direct jnp computation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def test_slow_fcn_shape_and_determinism():
    x = jax.random.uniform(jax.random.PRNGKey(0), (128, 128), jnp.float32)
    (y1,) = model.slow_fcn(x)
    (y2,) = model.slow_fcn(x)
    assert y1.shape == (128, 128)
    np.testing.assert_array_equal(y1, y2)
    assert float(jnp.max(jnp.abs(y1))) <= 1.0  # tanh-bounded


def test_slow_fcn_heavy_differs_from_slow_fcn():
    x = jax.random.uniform(jax.random.PRNGKey(1), (128, 128), jnp.float32)
    (a,) = model.slow_fcn(x)
    (b,) = model.slow_fcn_heavy(x)
    assert not np.allclose(a, b)


def test_bootstrap_stat_recovers_known_slope():
    """y = 2x + 1 exactly -> WLS fit must return (2, 1) for any weights."""
    key = jax.random.PRNGKey(2)
    x = jax.random.uniform(key, (model.BOOT_N,), jnp.float32, -2, 2)
    xy = jnp.stack([x, 2.0 * x + 1.0], axis=1)
    w = jax.random.uniform(jax.random.PRNGKey(3), (model.BOOT_N,), jnp.float32, 0.1, 2.0)
    slope, intercept = model.bootstrap_stat(xy, w)
    assert abs(float(slope) - 2.0) < 1e-3
    assert abs(float(intercept) - 1.0) < 1e-3


def test_bootstrap_stat_matches_wls_oracle():
    kx, ky, kw = jax.random.split(jax.random.PRNGKey(4), 3)
    x = jax.random.uniform(kx, (model.BOOT_N,), jnp.float32, -1, 1)
    y = 0.5 * x + 0.1 * jax.random.normal(ky, (model.BOOT_N,), jnp.float32)
    xy = jnp.stack([x, y], axis=1)
    w = jax.random.uniform(kw, (model.BOOT_N,), jnp.float32, 0.0, 2.0)
    slope, intercept = model.bootstrap_stat(xy, w)
    rs, ri = ref.wls_fit_ref(xy, w)
    np.testing.assert_allclose(float(slope), float(rs), rtol=1e-3)
    np.testing.assert_allclose(float(intercept), float(ri), atol=1e-3)


def test_mc_pi_block_estimates_pi():
    u = jax.random.uniform(jax.random.PRNGKey(5), (model.PI_N, 2), jnp.float32)
    (pi_hat,) = model.mc_pi_block(u)
    assert abs(float(pi_hat) - np.pi) < 0.1  # 8192 samples: ~0.02 stderr


def test_mlp_step_reduces_loss():
    keys = jax.random.split(jax.random.PRNGKey(6), 6)
    d = model.MLP_DIM
    w1 = jax.random.normal(keys[0], (d, d), jnp.float32) * 0.1
    b1 = jnp.zeros(d, jnp.float32)
    w2 = jax.random.normal(keys[1], (d, d), jnp.float32) * 0.1
    b2 = jnp.zeros(d, jnp.float32)
    x = jax.random.normal(keys[2], (d, d), jnp.float32)
    y = jax.random.normal(keys[3], (d, d), jnp.float32) * 0.5

    losses = []
    for _ in range(5):
        loss, w1, b1, w2, b2 = model.mlp_step(w1, b1, w2, b2, x, y)
        losses.append(float(loss))
    assert losses[-1] < losses[0], f"loss did not decrease: {losses}"


def test_mlp_step_grads_match_pure_jnp():
    """One step through Pallas mm vs the identical graph through jnp matmul."""
    keys = jax.random.split(jax.random.PRNGKey(7), 4)
    d = model.MLP_DIM
    w1 = jax.random.normal(keys[0], (d, d), jnp.float32) * 0.1
    b1 = jnp.zeros(d, jnp.float32)
    w2 = jax.random.normal(keys[1], (d, d), jnp.float32) * 0.1
    b2 = jnp.zeros(d, jnp.float32)
    x = jax.random.normal(keys[2], (d, d), jnp.float32)
    y = jax.random.normal(keys[3], (d, d), jnp.float32)

    def jnp_loss(w1, b1, w2, b2):
        h = jnp.tanh(x @ w1 + b1)
        return jnp.mean((h @ w2 + b2 - y) ** 2)

    loss, nw1, nb1, nw2, nb2 = model.mlp_step(w1, b1, w2, b2, x, y)
    rloss, rgrads = jax.value_and_grad(jnp_loss, argnums=(0, 1, 2, 3))(w1, b1, w2, b2)
    np.testing.assert_allclose(float(loss), float(rloss), rtol=1e-4)
    np.testing.assert_allclose(nw1, w1 - model.LEARNING_RATE * rgrads[0], rtol=1e-3, atol=1e-5)
    np.testing.assert_allclose(nw2, w2 - model.LEARNING_RATE * rgrads[2], rtol=1e-3, atol=1e-5)


@pytest.mark.parametrize("name", list(model.ENTRIES))
def test_entries_are_callable_with_example_shapes(name):
    fn, example = model.ENTRIES[name]
    args = [
        jax.random.uniform(jax.random.PRNGKey(i), s.shape, s.dtype, 0.0, 1.0)
        for i, s in enumerate(example)
    ]
    out = fn(*args)
    assert isinstance(out, tuple) and len(out) >= 1
    for o in out:
        assert jnp.all(jnp.isfinite(o)), f"{name} produced non-finite output"
