//! E15 — per-create static-analysis overhead.
//!
//! The analyzer runs on every `future_with` call, so its cost must be a
//! small fraction of the create path itself.  Target: `analysis-on` vs
//! `analysis-off` delta under 5% of the BENCH_overhead sequential create
//! round trip.  The `lint-only` mode isolates the analyzer passes from
//! the rest of creation (globals identification, launch, value collect).
//!
//! Emits `BENCH_analysis.json` (schema in BENCH.md); `scripts/bench.sh`
//! runs this in smoke mode.

mod common;

use common::{fmt_dur, header, json_row, measure, row, scale_iters, write_bench_json, Json};
use rustures::prelude::*;

fn workload() -> (Env, Expr) {
    let mut env = Env::new();
    env.insert("t", Tensor::new(vec![256], vec![1.0f32; 256]).unwrap());
    // A realistic small expression: touch the captured global, draw
    // nothing (the RNG pass still scans the tree).
    let expr = Expr::add(Expr::prim(PrimOp::Sum, vec![Expr::var("t")]), Expr::lit(1.0));
    (env, expr)
}

fn main() {
    let iters = scale_iters(2000);
    let (env, expr) = workload();

    header(
        "E15: per-create static-analysis overhead (sequential)",
        &["mode         ", "mean      ", "p50       ", "p95       "],
    );

    let mut json_rows = Vec::new();
    let configs = [
        ("analysis-off", AnalysisConfig::disabled()),
        ("analysis-on", AnalysisConfig::new()),
    ];
    for (mode, config) in configs {
        let session = Session::with_plan(PlanSpec::sequential());
        session.set_analysis_config(config);
        let stats = session.scope(|_| {
            measure(3, iters, || {
                let f = future_with(expr.clone(), &env, FutureOpts::new().no_capture()).unwrap();
                let _ = f.value().unwrap();
            })
        });
        session.close();
        row(&[
            format!("{mode:<13}"),
            format!("{:>10}", fmt_dur(stats.mean)),
            format!("{:>10}", fmt_dur(stats.p50)),
            format!("{:>10}", fmt_dur(stats.p95)),
        ]);
        json_rows.push(json_row(&[
            ("mode", Json::Str(mode.to_string())),
            ("mean_ns", Json::Int(stats.mean.as_nanos() as i64)),
            ("p50_ns", Json::Int(stats.p50.as_nanos() as i64)),
            ("p95_ns", Json::Int(stats.p95.as_nanos() as i64)),
            ("iters", Json::Int(stats.n as i64)),
        ]));
    }

    // The analyzer alone (all passes, Allow findings included), no future.
    let session = Session::with_plan(PlanSpec::sequential());
    let opts = FutureOpts::new();
    let stats = measure(3, iters, || {
        let _ = session.lint(&expr, &env, &opts);
    });
    session.close();
    row(&[
        format!("{:<13}", "lint-only"),
        format!("{:>10}", fmt_dur(stats.mean)),
        format!("{:>10}", fmt_dur(stats.p50)),
        format!("{:>10}", fmt_dur(stats.p95)),
    ]);
    json_rows.push(json_row(&[
        ("mode", Json::Str("lint-only".to_string())),
        ("mean_ns", Json::Int(stats.mean.as_nanos() as i64)),
        ("p50_ns", Json::Int(stats.p50.as_nanos() as i64)),
        ("p95_ns", Json::Int(stats.p95.as_nanos() as i64)),
        ("iters", Json::Int(stats.n as i64)),
    ]));

    write_bench_json("analysis", json_rows);
    println!(
        "\nshape check: (analysis-on − analysis-off) must stay under 5% of the \
         analysis-off create round trip; lint-only bounds the analyzer's own cost"
    );
}
