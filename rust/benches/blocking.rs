//! E2 — blocking semantics: `future()` creation latency when workers are
//! free vs all-busy.
//!
//! Paper: "the first two futures are created in a non-blocking way ...
//! however, when we attempt to create a third future ... future() blocks
//! until one of the workers is available."

mod common;

use common::{fmt_dur, header, row, Stats};
use rustures::api::plan::{with_plan, PlanSpec};
use rustures::prelude::*;
use std::time::Instant;

fn main() {
    header(
        "E2: future() creation latency (2 workers, 60ms payloads)",
        &["backend     ", "create #", "state      ", "p50       "],
    );

    for spec in [PlanSpec::multicore(2), PlanSpec::multiprocess(2)] {
        let mut free_samples = Vec::new();
        let mut busy_samples = Vec::new();
        with_plan(spec.clone(), || {
            for _ in 0..15 {
                let env = Env::new();
                let t0 = Instant::now();
                let f1 = future(Expr::Sleep { millis: 60 }, &env).unwrap();
                let d1 = t0.elapsed();
                let t1 = Instant::now();
                let f2 = future(Expr::Sleep { millis: 60 }, &env).unwrap();
                let d2 = t1.elapsed();
                free_samples.push(d1);
                free_samples.push(d2);

                let t2 = Instant::now();
                let f3 = future(Expr::lit(0i64), &env).unwrap();
                busy_samples.push(t2.elapsed());
                let _ = (f1.value(), f2.value(), f3.value());
            }
        });
        let free = Stats::from(free_samples);
        let busy = Stats::from(busy_samples);
        row(&[
            format!("{:<12}", spec.name()),
            format!("{:<8}", "1st/2nd"),
            format!("{:<11}", "worker free"),
            format!("{:>10}", fmt_dur(free.p50)),
        ]);
        row(&[
            format!("{:<12}", spec.name()),
            format!("{:<8}", "3rd"),
            format!("{:<11}", "all busy"),
            format!("{:>10}", fmt_dur(busy.p50)),
        ]);
    }
    println!("\nshape check: 3rd create blocks ≈ the remaining payload time; 1st/2nd are ~instant");
}
