//! E17 — result-cache hit/miss economics.
//!
//! Four modes per plan, same seeded lapply workload: `disabled` (the
//! baseline — cache config off, every run evaluates), `cold` (cached, but
//! a fresh session per run so every element misses and publishes — the
//! price of cache bookkeeping), `warm-mem` (one session, repeated runs —
//! pure in-memory hits), and `warm-disk` (fresh session per run over a
//! shared store root — hits through the disk tier).  Plus the headline
//! number: per-hit `future_with` round-trip latency, which is the
//! admission-free fast path (no permit, no lease, no backend).
//!
//! Shape: warm-mem ≪ disabled (that is the point of the cache), cold stays
//! within a small factor of disabled (bookkeeping must be cheap), and the
//! per-hit round trip is microseconds, not milliseconds.
//!
//! Emits `BENCH_cache.json` (schema in BENCH.md); `scripts/bench.sh` runs
//! this in smoke mode.

mod common;

use common::{fmt_dur, header, json_row, measure, row, scale_iters, write_bench_json, Json};
use rustures::prelude::*;
use rustures::util::uuid_v4;

const ELEMENTS: i64 = 16;
const SPIN_MS: u64 = 1;

fn workload() -> (Vec<Value>, Expr, Env) {
    // Spin makes the evaluation cost real (so hits have something to
    // save); the seeded draw makes bit-identity meaningful.
    let body = Expr::seq(vec![
        Expr::Spin { millis: SPIN_MS },
        Expr::add(Expr::var("x"), Expr::runif(1)),
    ]);
    ((0..ELEMENTS).map(Value::I64).collect(), body, Env::new())
}

fn opts() -> LapplyOpts {
    LapplyOpts::new().seed(5).chunking(Chunking::ChunkSize(4)).cached()
}

fn emit(rows: &mut Vec<Json>, plan: &str, mode: &str, stats: &common::Stats) {
    row(&[
        format!("{plan:<12}"),
        format!("{mode:<10}"),
        format!("{:>10}", fmt_dur(stats.mean)),
        format!("{:>10}", fmt_dur(stats.p50)),
        format!("{:>10}", fmt_dur(stats.p95)),
    ]);
    rows.push(json_row(&[
        ("plan", Json::Str(plan.to_string())),
        ("mode", Json::Str(mode.to_string())),
        ("mean_ns", Json::Int(stats.mean.as_nanos() as i64)),
        ("p50_ns", Json::Int(stats.p50.as_nanos() as i64)),
        ("p95_ns", Json::Int(stats.p95.as_nanos() as i64)),
        ("iters", Json::Int(stats.n as i64)),
    ]));
}

fn bench_plan(plan: &str, spec: PlanSpec, json_rows: &mut Vec<Json>) {
    let iters = scale_iters(30);
    let (xs, body, env) = workload();

    // disabled: the no-cache baseline — every run pays full evaluation.
    let s = Session::with_plan(spec.clone());
    s.set_cache_config(CacheConfig::disabled());
    let stats = measure(1, iters, || {
        let _ = s.lapply(&xs, "x", &body, &env, &opts()).unwrap();
    });
    s.close();
    emit(json_rows, plan, "disabled", &stats);

    // cold: fresh memory-only session per run — all misses, all publishes.
    let stats = measure(1, iters, || {
        let s = Session::with_plan(spec.clone());
        s.set_cache_config(CacheConfig::new());
        let _ = s.lapply(&xs, "x", &body, &env, &opts()).unwrap();
        s.close();
    });
    emit(json_rows, plan, "cold", &stats);

    // warm-mem: one session, repeated runs — in-memory hits after run one.
    let s = Session::with_plan(spec.clone());
    s.set_cache_config(CacheConfig::new());
    let stats = measure(1, iters, || {
        let _ = s.lapply(&xs, "x", &body, &env, &opts()).unwrap();
    });
    s.close();
    emit(json_rows, plan, "warm-mem", &stats);

    // warm-disk: fresh session per run over a shared root — disk hits.
    let root = std::env::temp_dir().join(format!("rustures-bench-cache-{}", uuid_v4()));
    let cfg = CacheConfig::new().disk(&root);
    let populate = Session::with_plan(spec.clone());
    populate.set_cache_config(cfg.clone());
    let _ = populate.lapply(&xs, "x", &body, &env, &opts()).unwrap();
    populate.close();
    let stats = measure(1, iters, || {
        let s = Session::with_plan(spec.clone());
        s.set_cache_config(cfg.clone());
        let _ = s.lapply(&xs, "x", &body, &env, &opts()).unwrap();
        s.close();
    });
    let _ = std::fs::remove_dir_all(&root);
    emit(json_rows, plan, "warm-disk", &stats);
}

fn main() {
    header(
        "E17: result-cache hit/miss economics",
        &["plan        ", "mode      ", "mean      ", "p50       ", "p95       "],
    );

    let mut json_rows = Vec::new();
    bench_plan("sequential", PlanSpec::sequential(), &mut json_rows);
    bench_plan("multicore-2", PlanSpec::multicore(2), &mut json_rows);

    // Headline: the per-hit future_with round trip — create consults the
    // cache and resolves Done before admission, so this is the full
    // admission-free fast path, backend not involved.
    let s = Session::with_plan(PlanSpec::sequential());
    s.set_cache_config(CacheConfig::new());
    let expr = Expr::add(Expr::lit(40i64), Expr::lit(2i64));
    let env = Env::new();
    let _ = s.future_with(expr.clone(), &env, FutureOpts::new().cached()).unwrap().value();
    let stats = measure(10, scale_iters(5000), || {
        let f = s.future_with(expr.clone(), &env, FutureOpts::new().cached()).unwrap();
        let _ = f.value().unwrap();
    });
    s.close();
    emit(&mut json_rows, "sequential", "per-hit", &stats);

    write_bench_json("cache", json_rows);
    println!(
        "\nshape check: warm-mem ≪ disabled; cold within a small factor of \
         disabled; per-hit round trip is the microsecond admission-free path"
    );
}
