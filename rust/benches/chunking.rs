//! E3 — load balancing: one future per element vs chunked futures.
//!
//! Paper (footnote 6 + Future work): per-element futures are "suboptimal
//! if the overhead of creating a future is relatively large compared to the
//! evaluation time", mitigated by processing elements in chunks — one
//! future per worker.  This bench regenerates that table: N cheap elements
//! under each chunking policy, per backend.  Since the `Expr::MapChunk`
//! hot path, a chunk ships ONE body plus packed elements, so the per-chunk
//! cost is O(elements), never O(elements·|body|).
//!
//! Emits `BENCH_chunking.json` (schema in BENCH.md); `scripts/bench.sh`
//! runs this in smoke mode.

mod common;

use common::{fmt_dur, header, json_row, row, smoke, time_once, write_bench_json, Json};
use rustures::api::plan::{with_plan, PlanSpec};
use rustures::prelude::*;

fn run(n: usize, chunking: Chunking, spec: PlanSpec) -> std::time::Duration {
    with_plan(spec, || {
        let env = Env::new();
        let xs: Vec<Value> = (0..n as i64).map(Value::I64).collect();
        let body = Expr::mul(Expr::var("x"), Expr::var("x"));
        // Warm the backend (worker spawn is one-time setup, not per-map).
        let _ = future(Expr::lit(0i64), &env).unwrap().value();
        time_once(|| {
            let out = future_lapply(
                &xs,
                "x",
                &body,
                &env,
                &LapplyOpts::new().no_capture().chunking(chunking),
            )
            .unwrap();
            assert_eq!(out.len(), n);
        })
    })
}

fn main() {
    header(
        "E3: chunking ablation (N cheap elements, 2 workers)",
        &["backend     ", "N    ", "policy          ", "wall      ", "per-elem  "],
    );

    let sizes: &[usize] = if smoke() { &[64, 256] } else { &[64, 256, 1024] };
    let mut json_rows = Vec::new();
    for spec in [PlanSpec::multicore(2), PlanSpec::multiprocess(2)] {
        for &n in sizes {
            for (label, chunking) in [
                ("per-element", Chunking::PerElement),
                ("per-worker", Chunking::PerWorker),
                ("scheduling=4", Chunking::Scheduling(4.0)),
                ("chunk=32", Chunking::ChunkSize(32)),
            ] {
                let wall = run(n, chunking, spec.clone());
                row(&[
                    format!("{:<12}", spec.name()),
                    format!("{n:<5}"),
                    format!("{label:<16}"),
                    format!("{:>10}", fmt_dur(wall)),
                    format!("{:>10}", fmt_dur(wall / n as u32)),
                ]);
                json_rows.push(json_row(&[
                    ("backend", Json::Str(spec.name().to_string())),
                    ("n", Json::Int(n as i64)),
                    ("policy", Json::Str(label.to_string())),
                    ("wall_ns", Json::Int(wall.as_nanos() as i64)),
                    ("per_elem_ns", Json::Int((wall.as_nanos() / n as u128) as i64)),
                ]));
            }
        }
    }
    write_bench_json("chunking", json_rows);
    println!("\nshape check: per-worker chunking beats per-element by ~N/workers on overhead-dominated maps");
}
