//! Shared bench harness: criterion is unavailable offline, so each bench is
//! a `harness = false` binary using this minimal measured-loop helper.
//! Output is a fixed-width table (one row per configuration) — the format
//! EXPERIMENTS.md records.

use std::time::{Duration, Instant};

/// Run `f` `iters` times after `warmup` unmeasured runs; returns per-iter
/// stats (mean, p50, p95) over individually timed iterations.
pub fn measure(warmup: usize, iters: usize, mut f: impl FnMut()) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    Stats::from(samples)
}

/// Time a single run of `f`.
pub fn time_once(f: impl FnOnce()) -> Duration {
    let t0 = Instant::now();
    f();
    t0.elapsed()
}

#[derive(Debug, Clone)]
pub struct Stats {
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub n: usize,
}

impl Stats {
    pub fn from(mut samples: Vec<Duration>) -> Self {
        assert!(!samples.is_empty());
        samples.sort();
        let n = samples.len();
        let total: Duration = samples.iter().sum();
        Stats {
            mean: total / n as u32,
            p50: samples[n / 2],
            p95: samples[(n as f64 * 0.95) as usize - if n >= 20 { 0 } else { usize::from(n > 1) }],
            n,
        }
    }
}

pub fn fmt_dur(d: Duration) -> String {
    if d.as_secs_f64() >= 1.0 {
        format!("{:.2}s", d.as_secs_f64())
    } else if d.as_millis() >= 1 {
        format!("{:.2}ms", d.as_secs_f64() * 1e3)
    } else {
        format!("{:.1}µs", d.as_secs_f64() * 1e6)
    }
}

pub fn header(title: &str, cols: &[&str]) {
    println!("\n== {title} ==");
    println!("{}", cols.join(" | "));
    println!("{}", "-".repeat(cols.iter().map(|c| c.len() + 3).sum::<usize>()));
}

pub fn row(cells: &[String]) {
    println!("{}", cells.join(" | "));
}
