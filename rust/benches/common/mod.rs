//! Shared bench harness: criterion is unavailable offline, so each bench is
//! a `harness = false` binary using this minimal measured-loop helper.
//! Output is a fixed-width table (one row per configuration) — plus, for
//! the benches that track the perf trajectory across PRs, a
//! machine-readable `BENCH_<name>.json` (see BENCH.md at the repo root).
//!
//! Env knobs:
//! * `BENCH_SMOKE=1` — reduced iteration counts (CI / scripts/bench.sh).
//! * `BENCH_OUT=dir` — where `BENCH_*.json` files are written (default `.`).

#![allow(dead_code)] // each bench binary uses a subset of these helpers

use std::time::{Duration, Instant};

pub use rustures::util::json::Json;
use rustures::util::json;

/// Smoke mode: fewer iterations, same schema.
pub fn smoke() -> bool {
    std::env::var("BENCH_SMOKE").map(|v| v != "0").unwrap_or(false)
}

/// Scale an iteration count down in smoke mode (min 3 so stats exist).
pub fn scale_iters(full: usize) -> usize {
    if smoke() {
        (full / 10).max(3)
    } else {
        full
    }
}

/// One row of a `BENCH_*.json` file (serialized via the crate's own
/// [`rustures::util::json`] — one escaping implementation, not two).
pub fn json_row(fields: &[(&str, Json)]) -> Json {
    Json::Obj(fields.iter().map(|(k, v)| (k.to_string(), v.clone())).collect())
}

/// Write `BENCH_<name>.json` into `$BENCH_OUT` (default `.`).  Schema is
/// documented in BENCH.md; `rows` are [`json_row`] objects.
pub fn write_bench_json(name: &str, rows: Vec<Json>) {
    let dir = std::env::var("BENCH_OUT").unwrap_or_else(|_| ".".to_string());
    let path = std::path::Path::new(&dir).join(format!("BENCH_{name}.json"));
    let doc = Json::Obj(
        [
            ("bench".to_string(), Json::Str(name.to_string())),
            ("schema".to_string(), Json::Int(1)),
            ("smoke".to_string(), Json::Bool(smoke())),
            ("rows".to_string(), Json::Arr(rows)),
        ]
        .into_iter()
        .collect(),
    );
    match std::fs::write(&path, json::to_string(&doc) + "\n") {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("bench: could not write {}: {e}", path.display()),
    }
}

/// Run `f` `iters` times after `warmup` unmeasured runs; returns per-iter
/// stats (mean, p50, p95) over individually timed iterations.
pub fn measure(warmup: usize, iters: usize, mut f: impl FnMut()) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    Stats::from(samples)
}

/// Time a single run of `f`.
pub fn time_once(f: impl FnOnce()) -> Duration {
    let t0 = Instant::now();
    f();
    t0.elapsed()
}

#[derive(Debug, Clone)]
pub struct Stats {
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub n: usize,
}

impl Stats {
    pub fn from(mut samples: Vec<Duration>) -> Self {
        assert!(!samples.is_empty());
        samples.sort();
        let n = samples.len();
        let total: Duration = samples.iter().sum();
        Stats {
            mean: total / n as u32,
            p50: samples[n / 2],
            p95: samples[(n as f64 * 0.95) as usize - if n >= 20 { 0 } else { usize::from(n > 1) }],
            n,
        }
    }
}

pub fn fmt_dur(d: Duration) -> String {
    if d.as_secs_f64() >= 1.0 {
        format!("{:.2}s", d.as_secs_f64())
    } else if d.as_millis() >= 1 {
        format!("{:.2}ms", d.as_secs_f64() * 1e3)
    } else {
        format!("{:.1}µs", d.as_secs_f64() * 1e6)
    }
}

pub fn header(title: &str, cols: &[&str]) {
    println!("\n== {title} ==");
    println!("{}", cols.join(" | "));
    println!("{}", "-".repeat(cols.iter().map(|c| c.len() + 3).sum::<usize>()));
}

pub fn row(cells: &[String]) {
    println!("{}", cells.join(" | "));
}
