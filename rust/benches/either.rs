//! E7 — `future_either`: first-resolved-wins latency.
//!
//! Paper ("Other uses of futures"): EITHER "evaluates the expressions in
//! parallel and returns the value of the first one that finishes" — e.g.
//! racing sort algorithms.  The win: latency equals the *fastest* racer
//! (plus overhead), not the chosen-wrong-algorithm worst case.

mod common;

use common::{fmt_dur, header, measure, row};
use rustures::api::plan::{with_plan, PlanSpec};
use rustures::prelude::*;

fn main() {
    header(
        "E7: future_either latency vs racer spread",
        &["backend     ", "racers (ms)     ", "either    ", "worst-case"],
    );

    let configs: Vec<(&str, Vec<u64>)> = vec![
        ("5/50/100", vec![5, 50, 100]),
        ("20/20/20", vec![20, 20, 20]),
        ("1/200", vec![1, 200]),
    ];

    for spec in [PlanSpec::multicore(3), PlanSpec::multiprocess(3)] {
        for (label, delays) in &configs {
            let exprs = |ds: &[u64]| {
                ds.iter()
                    .map(|ms| {
                        Expr::seq(vec![Expr::Sleep { millis: *ms }, Expr::lit(*ms as i64)])
                    })
                    .collect::<Vec<_>>()
            };
            let stats = with_plan(spec.clone(), || {
                measure(1, 10, || {
                    let v = future_either(exprs(delays), &Env::new()).unwrap();
                    std::hint::black_box(v);
                })
            });
            let worst = *delays.iter().max().unwrap();
            row(&[
                format!("{:<12}", spec.name()),
                format!("{label:<16}"),
                format!("{:>10}", fmt_dur(stats.p50)),
                format!("{:>9}ms", worst),
            ]);
        }
    }
    println!("\nshape check: either latency tracks the fastest racer, not the slowest");
}
