//! E6 — nested parallelism: protection (N, not N²) and configured
//! topologies (A×B workers).
//!
//! Paper: "if PkgA and PkgB parallelize using the future framework, the
//! nested parallelism will run with a total of N cores, not N²", and
//! `plan(list(tweak(multisession, 2), tweak(multisession, 3)))` runs "at
//! most 2 × 3 = 6 tasks in parallel".

mod common;

use common::{fmt_dur, header, row, time_once};
use rustures::api::plan::{at_depth, backend_for_current_depth, with_plan_topology, PlanSpec};
use rustures::prelude::*;

fn main() {
    // (a) effective worker counts by depth under various topologies.
    header(
        "E6a: backend selected per nesting depth",
        &["topology                    ", "depth", "backend     ", "workers"],
    );
    let topologies: Vec<(&str, Vec<PlanSpec>)> = vec![
        ("multicore(4)", vec![PlanSpec::multicore(4)]),
        (
            "multicore(2), multicore(3)",
            vec![PlanSpec::multicore(2), PlanSpec::multicore(3)],
        ),
        (
            "batch(2), multicore(2)",
            vec![PlanSpec::batch(2), PlanSpec::multicore(2)],
        ),
    ];
    for (label, topo) in &topologies {
        with_plan_topology(topo.clone(), || {
            for depth in 0..3u32 {
                at_depth(depth, || {
                    let (b, _) = backend_for_current_depth().unwrap();
                    row(&[
                        format!("{label:<28}"),
                        format!("{depth:>5}"),
                        format!("{:<12}", b.name()),
                        format!("{:>7}", b.workers()),
                    ]);
                });
            }
        });
    }
    println!("protection: depths beyond the topology run sequential (workers=1) — N, not N²");

    // (b) wall time of an outer map under flat vs nested topology: the
    // protected nested level must not oversubscribe (latency-bound load).
    header(
        "E6b: outer map of 4 × Sleep(40ms), nested level protected",
        &["topology                    ", "wall      "],
    );
    for (label, topo) in [
        ("multicore(4)", vec![PlanSpec::multicore(4)]),
        ("multicore(4), sequential", vec![PlanSpec::multicore(4), PlanSpec::Sequential]),
    ] {
        let wall = with_plan_topology(topo, || {
            let xs: Vec<Value> = (0..4i64).map(Value::I64).collect();
            time_once(|| {
                let _ = future_lapply(
                    &xs,
                    "x",
                    &Expr::Sleep { millis: 40 },
                    &Env::new(),
                    &LapplyOpts::new().no_capture(),
                )
                .unwrap();
            })
        });
        row(&[format!("{label:<28}"), format!("{:>10}", fmt_dur(wall))]);
    }
    println!("\nshape check: explicit and implicit sequential inner layers perform identically");
}
