//! E1 — per-future overhead by backend and payload size.
//!
//! Paper: "overhead differs between parallel backends.  Certain parallel
//! backends, such as forked processing ('multicore'), are better suited for
//! low-latency requirements, whereas others, such as distributed processing
//! ('cluster' and 'batchtools'), are better suited for large-throughput
//! requirements."  The expected *shape*: sequential < multicore <
//! multisession ≈ cluster < batchtools, growing with payload size on the
//! serializing backends.

mod common;

use common::{fmt_dur, header, measure, row};
use rustures::api::plan::{with_plan, PlanSpec};
use rustures::prelude::*;

fn payload_env(bytes: usize) -> (Env, Expr) {
    let mut env = Env::new();
    if bytes == 0 {
        (env, Expr::lit(1i64))
    } else {
        let n = bytes / 4;
        env.insert("t", Tensor::new(vec![n], vec![1.0f32; n]).unwrap());
        // Touch the payload so transfer is not dead code.
        (env, Expr::prim(PrimOp::Sum, vec![Expr::var("t")]))
    }
}

fn main() {
    let backends = vec![
        (PlanSpec::sequential(), 200usize),
        (PlanSpec::multicore(2), 200),
        (PlanSpec::multiprocess(2), 100),
        (PlanSpec::cluster(&["n1.local", "n2.local"]), 100),
        (PlanSpec::batch(2), 20),
    ];
    let payloads = [0usize, 1 << 10, 64 << 10, 1 << 20];

    header(
        "E1: per-future round-trip overhead (create → value)",
        &["backend     ", "payload ", "mean      ", "p50       ", "p95       "],
    );

    for (spec, iters) in backends {
        for bytes in payloads {
            let (env, expr) = payload_env(bytes);
            let name = spec.name();
            let stats = with_plan(spec.clone(), || {
                measure(3, iters, || {
                    let f = future_with(expr.clone(), &env, FutureOpts::new().no_capture())
                        .unwrap();
                    let _ = f.value().unwrap();
                })
            });
            row(&[
                format!("{name:<12}"),
                format!("{:>7}B", bytes),
                format!("{:>10}", fmt_dur(stats.mean)),
                format!("{:>10}", fmt_dur(stats.p50)),
                format!("{:>10}", fmt_dur(stats.p95)),
            ]);
        }
    }
    println!("\nshape check: multicore ≪ multisession/cluster ≪ batchtools; cost grows with payload on serializing backends");
}
