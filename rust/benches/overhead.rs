//! E1 — per-future overhead by backend and payload size.
//!
//! Paper: "overhead differs between parallel backends.  Certain parallel
//! backends, such as forked processing ('multicore'), are better suited for
//! low-latency requirements, whereas others, such as distributed processing
//! ('cluster' and 'batchtools'), are better suited for large-throughput
//! requirements."  The expected *shape*: sequential < multicore <
//! multisession ≈ cluster < batchtools, growing with payload size on the
//! serializing backends — and, since the zero-copy hot path, multicore must
//! be ~flat in payload size (globals capture and thread hand-off are Arc
//! bumps, not buffer copies).
//!
//! Emits `BENCH_overhead.json` (schema in BENCH.md) so the perf trajectory
//! is diffable across PRs; `scripts/bench.sh` runs this in smoke mode.

mod common;

use common::{fmt_dur, header, json_row, measure, row, scale_iters, write_bench_json, Json};
use rustures::api::plan::{with_plan, PlanSpec};
use rustures::prelude::*;

fn payload_env(bytes: usize) -> (Env, Expr) {
    let mut env = Env::new();
    if bytes == 0 {
        (env, Expr::lit(1i64))
    } else {
        let n = bytes / 4;
        env.insert("t", Tensor::new(vec![n], vec![1.0f32; n]).unwrap());
        // Touch the payload so transfer is not dead code.
        (env, Expr::prim(PrimOp::Sum, vec![Expr::var("t")]))
    }
}

fn main() {
    let backends = vec![
        (PlanSpec::sequential(), 200usize),
        (PlanSpec::multicore(2), 200),
        (PlanSpec::multiprocess(2), 100),
        (PlanSpec::cluster(&["n1.local", "n2.local"]), 100),
        (PlanSpec::batch(2), 20),
    ];
    let payloads = [0usize, 1 << 10, 64 << 10, 1 << 20];

    header(
        "E1: per-future round-trip overhead (create → value)",
        &["backend     ", "payload ", "mean      ", "p50       ", "p95       "],
    );

    let mut json_rows = Vec::new();
    for (spec, iters) in backends {
        let iters = scale_iters(iters);
        for bytes in payloads {
            let (env, expr) = payload_env(bytes);
            let name = spec.name();
            let stats = with_plan(spec.clone(), || {
                measure(3, iters, || {
                    let f = future_with(expr.clone(), &env, FutureOpts::new().no_capture())
                        .unwrap();
                    let _ = f.value().unwrap();
                })
            });
            row(&[
                format!("{name:<12}"),
                format!("{:>7}B", bytes),
                format!("{:>10}", fmt_dur(stats.mean)),
                format!("{:>10}", fmt_dur(stats.p50)),
                format!("{:>10}", fmt_dur(stats.p95)),
            ]);
            json_rows.push(json_row(&[
                ("backend", Json::Str(name.to_string())),
                ("payload_bytes", Json::Int(bytes as i64)),
                ("mean_ns", Json::Int(stats.mean.as_nanos() as i64)),
                ("p50_ns", Json::Int(stats.p50.as_nanos() as i64)),
                ("p95_ns", Json::Int(stats.p95.as_nanos() as i64)),
                ("iters", Json::Int(stats.n as i64)),
            ]));
        }
    }
    write_bench_json("overhead", json_rows);
    println!("\nshape check: multicore ≪ multisession/cluster ≪ batchtools; cost grows with payload on serializing backends (multicore stays ~flat: zero-copy hand-off)");
}
