//! E12 — fault-tolerant elastic execution: `future_lapply` throughput with
//! 0 / 1 / 2 injected worker kills under supervised retry.
//!
//! Each killed worker takes one in-flight chunk down with it; the
//! supervisor respawns the seat and the retry policy resubmits the chunk.
//! `kills = 0` is the baseline; the deltas are the price of recovery
//! (respawn latency + one chunk re-executed).  Values are asserted equal
//! to the clean run every time — a recovery that corrupts results would
//! fail the bench, not just skew it.
//!
//! E14 — liveness plane: the same map with 0 / 1 injected worker *hangs*
//! (silent, no heartbeats) under an armed stall detector.  The detector
//! kills the hung worker after `stall_after` of silence, the seat returns
//! through the capacity ledger, and the retry policy resubmits the chunk —
//! so the hang premium should be roughly `stall_after` + respawn + one
//! re-run chunk, never the hang's own (60 s) duration.
//!
//! Emits `BENCH_recovery.json` and `BENCH_liveness.json` (schemas in
//! BENCH.md); `scripts/bench.sh` runs this in smoke mode.

mod common;

use common::{fmt_dur, header, json_row, row, smoke, time_once, write_bench_json, Json};
use rustures::api::plan::{with_plan, PlanSpec};
use rustures::liveness::{reset_liveness_config, set_liveness_config, LivenessConfig};
use rustures::prelude::*;
use std::time::Duration;

fn marker(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("rustures-bench-rec-{tag}-{}", rustures::util::uuid_v4()))
        .to_string_lossy()
        .into_owned()
}

/// Body: elements in `kills` murder their worker once (marker-gated), then
/// every element does a fixed slab of CPU work and squares itself.
fn body_with_kills(kill_markers: &[(i64, String)], work_iters: u64) -> Expr {
    let mut probe = Expr::lit(0i64);
    for (k, m) in kill_markers {
        probe = Expr::if_else(
            Expr::prim(PrimOp::Eq, vec![Expr::var("x"), Expr::lit(*k)]),
            Expr::chaos_kill_once(m),
            probe,
        );
    }
    Expr::seq(vec![
        probe,
        Expr::Work { iters: work_iters },
        Expr::mul(Expr::var("x"), Expr::var("x")),
    ])
}

fn run_one(spec: PlanSpec, n: usize, kills: usize, work_iters: u64) -> Duration {
    let kill_elems: Vec<i64> = (0..kills as i64).map(|i| (i + 1) * n as i64 / 4).collect();
    let kill_markers: Vec<(i64, String)> =
        kill_elems.iter().map(|k| (*k, marker(&format!("k{k}")))).collect();
    let wall = with_plan(spec, || {
        let env = Env::new();
        let xs: Vec<Value> = (0..n as i64).map(Value::I64).collect();
        let body = body_with_kills(&kill_markers, work_iters);
        let opts = LapplyOpts::new()
            .no_capture()
            .chunking(Chunking::ChunkSize(4))
            .retry(RetryPolicy::idempotent(4).with_backoff(Duration::from_millis(1), 2.0));
        // Warm the backend (worker spawn is one-time setup, not per-map).
        let _ = future(Expr::lit(0i64), &env).unwrap().value();
        let want: Vec<Value> = (0..n as i64).map(|i| Value::I64(i * i)).collect();
        time_once(|| {
            let out = future_lapply(&xs, "x", &body, &env, &opts).unwrap();
            assert_eq!(out, want, "recovery must not change values");
        })
    });
    for (_, m) in &kill_markers {
        let _ = std::fs::remove_file(m);
    }
    wall
}

/// Body: elements in `hangs` hang their worker once (marker-gated, silent —
/// no heartbeats, so only the stall detector can reclaim the seat), then
/// every element does a fixed slab of CPU work and squares itself.
fn body_with_hangs(hang_markers: &[(i64, String)], work_iters: u64) -> Expr {
    let mut probe = Expr::lit(0i64);
    for (h, m) in hang_markers {
        probe = Expr::if_else(
            Expr::prim(PrimOp::Eq, vec![Expr::var("x"), Expr::lit(*h)]),
            Expr::chaos_hang_once(60_000, m),
            probe,
        );
    }
    Expr::seq(vec![
        probe,
        Expr::Work { iters: work_iters },
        Expr::mul(Expr::var("x"), Expr::var("x")),
    ])
}

fn run_one_hang(
    spec: PlanSpec,
    n: usize,
    hangs: usize,
    work_iters: u64,
    stall_after: Duration,
) -> Duration {
    let hang_elems: Vec<i64> = (0..hangs as i64).map(|i| (i + 1) * n as i64 / 4).collect();
    let hang_markers: Vec<(i64, String)> =
        hang_elems.iter().map(|h| (*h, marker(&format!("h{h}")))).collect();
    set_liveness_config(LivenessConfig::with_stall_after(stall_after));
    let wall = with_plan(spec, || {
        let env = Env::new();
        let xs: Vec<Value> = (0..n as i64).map(Value::I64).collect();
        let body = body_with_hangs(&hang_markers, work_iters);
        let opts = LapplyOpts::new()
            .no_capture()
            .chunking(Chunking::ChunkSize(4))
            .retry(RetryPolicy::idempotent(4).with_backoff(Duration::from_millis(1), 2.0));
        let _ = future(Expr::lit(0i64), &env).unwrap().value();
        let want: Vec<Value> = (0..n as i64).map(|i| Value::I64(i * i)).collect();
        time_once(|| {
            let out = future_lapply(&xs, "x", &body, &env, &opts).unwrap();
            assert_eq!(out, want, "hang recovery must not change values");
        })
    });
    reset_liveness_config();
    for (_, m) in &hang_markers {
        let _ = std::fs::remove_file(m);
    }
    wall
}

fn main() {
    header(
        "E12: lapply throughput under injected worker kills (supervised retry, 2 workers)",
        &["backend     ", "N    ", "kills ", "wall      "],
    );

    let (n, work_iters) = if smoke() { (32, 20_000) } else { (128, 200_000) };
    let mut json_rows = Vec::new();
    for spec in [PlanSpec::multicore(2), PlanSpec::multiprocess(2)] {
        for kills in [0usize, 1, 2] {
            let wall = run_one(spec.clone(), n, kills, work_iters);
            row(&[
                format!("{:<12}", spec.name()),
                format!("{n:<5}"),
                format!("{kills:<6}"),
                format!("{:>10}", fmt_dur(wall)),
            ]);
            json_rows.push(json_row(&[
                ("backend", Json::Str(spec.name().to_string())),
                ("n", Json::Int(n as i64)),
                ("kills", Json::Int(kills as i64)),
                ("work_iters", Json::Int(work_iters as i64)),
                ("wall_ns", Json::Int(wall.as_nanos() as i64)),
            ]));
        }
    }
    write_bench_json("recovery", json_rows);
    println!("\nshape check: wall grows modestly per kill (respawn + one re-run chunk)");

    header(
        "E14: lapply throughput under injected worker hangs (stall detector + retry, 2 workers)",
        &["backend     ", "N    ", "hangs ", "stall  ", "wall      "],
    );

    // Hung workers never reply on their own, so only process-seat backends
    // (the stall detector can SIGKILL the worker) are measured.
    let stall_after = Duration::from_millis(250);
    let mut liveness_rows = Vec::new();
    for spec in [PlanSpec::multiprocess(2), PlanSpec::cluster(&["n1.local", "n2.local"])] {
        for hangs in [0usize, 1] {
            let wall = run_one_hang(spec.clone(), n, hangs, work_iters, stall_after);
            row(&[
                format!("{:<12}", spec.name()),
                format!("{n:<5}"),
                format!("{hangs:<6}"),
                format!("{:<7}", format!("{}ms", stall_after.as_millis())),
                format!("{:>10}", fmt_dur(wall)),
            ]);
            liveness_rows.push(json_row(&[
                ("backend", Json::Str(spec.name().to_string())),
                ("n", Json::Int(n as i64)),
                ("hangs", Json::Int(hangs as i64)),
                ("stall_after_ms", Json::Int(stall_after.as_millis() as i64)),
                ("work_iters", Json::Int(work_iters as i64)),
                ("wall_ns", Json::Int(wall.as_nanos() as i64)),
            ]));
        }
    }
    write_bench_json("liveness", liveness_rows);
    println!(
        "\nshape check: each hang adds ~stall_after + respawn + one re-run chunk, never the 60s hang"
    );
}
