//! E4 — output/condition capture & relay overhead.
//!
//! Paper: "there is a small overhead ... from capturing and relaying
//! standard output and conditions.  Except for the error-handling overhead,
//! these can all be avoided via certain future() arguments."  This bench
//! measures futures that emit output/conditions with capture on vs off.

mod common;

use common::{fmt_dur, header, measure, row};
use rustures::api::conditions::set_sink;
use rustures::api::plan::{with_plan, PlanSpec};
use rustures::prelude::*;

struct NullSink;
impl rustures::api::conditions::ConditionSink for NullSink {
    fn stdout(&mut self, _: &str) {}
    fn condition(&mut self, _: &rustures::api::conditions::Condition) {}
}

fn chatty_expr(lines: usize) -> Expr {
    let mut items = Vec::new();
    for i in 0..lines {
        items.push(Expr::cat(Expr::lit(format!("line {i}\n").as_str())));
        items.push(Expr::message(Expr::lit("msg")));
        items.push(Expr::warning(Expr::lit("warn")));
    }
    items.push(Expr::lit(0i64));
    Expr::seq(items)
}

fn main() {
    set_sink(Some(Box::new(NullSink))); // don't spam the terminal

    header(
        "E4: stdout/condition capture + relay overhead",
        &["backend     ", "emits", "capture", "mean      ", "p50       "],
    );

    for (spec, iters) in
        [(PlanSpec::multicore(2), 150usize), (PlanSpec::multiprocess(2), 80)]
    {
        for lines in [0usize, 10, 100] {
            for capture in [true, false] {
                let expr = chatty_expr(lines);
                let stats = with_plan(spec.clone(), || {
                    measure(3, iters, || {
                        let mut opts = FutureOpts::new();
                        opts.stdout = capture;
                        opts.conditions = capture;
                        let f = future_with(expr.clone(), &Env::new(), opts).unwrap();
                        let _ = f.value().unwrap();
                    })
                });
                row(&[
                    format!("{:<12}", spec.name()),
                    format!("{lines:>5}"),
                    format!("{:>7}", capture),
                    format!("{:>10}", fmt_dur(stats.mean)),
                    format!("{:>10}", fmt_dur(stats.p50)),
                ]);
            }
        }
    }
    set_sink(None);
    println!("\nshape check: capture=false flattens the cost of emit-heavy futures");
}
