//! E11 — as-completed resolution: the dispatcher/resolve() path.
//!
//! Three measurements on a **skewed-chunk** workload (element 0 spins,
//! every other element is cheap, so one chunk dominates the wall clock):
//!
//! * `in-order`      — `future_lapply` with the historical strictly-ordered
//!                     harvest (`LapplyOpts::in_order`),
//! * `as-completed`  — the default streaming harvest (must be **no slower**:
//!                     the acceptance gate for the dispatcher subsystem),
//! * `map-reduce`    — `future_map_reduce` folding in completion order,
//!
//! plus `resolve-any`: latency of `resolve_any([slow, fast])`, which must
//! track the FAST future (shared completion channel), not the slow one.
//!
//! Emits `BENCH_resolve.json` (schema in BENCH.md); `scripts/bench.sh`
//! runs this in smoke mode.

mod common;

use common::{fmt_dur, header, json_row, row, smoke, time_once, write_bench_json, Json};
use rustures::api::plan::{with_plan, PlanSpec};
use rustures::prelude::*;

/// Skewed body: element 0 spins `skew_ms`, the rest just square.
fn skewed_body(skew_ms: u64) -> Expr {
    let square = Expr::mul(Expr::var("x"), Expr::var("x"));
    Expr::if_else(
        Expr::prim(PrimOp::Eq, vec![Expr::var("x"), Expr::lit(0i64)]),
        Expr::seq(vec![Expr::Spin { millis: skew_ms }, square.clone()]),
        square,
    )
}

fn run_lapply(
    spec: PlanSpec,
    n: usize,
    skew_ms: u64,
    in_order: bool,
) -> std::time::Duration {
    with_plan(spec, || {
        let env = Env::new();
        let xs: Vec<Value> = (0..n as i64).map(Value::I64).collect();
        let body = skewed_body(skew_ms);
        let mut opts = LapplyOpts::new().no_capture().chunking(Chunking::ChunkSize(4));
        if in_order {
            opts = opts.in_order();
        }
        // Warm the backend (worker spawn is one-time setup, not per-map).
        let _ = future(Expr::lit(0i64), &env).unwrap().value();
        time_once(|| {
            let out = future_lapply(&xs, "x", &body, &env, &opts).unwrap();
            assert_eq!(out.len(), n);
        })
    })
}

fn run_map_reduce(spec: PlanSpec, n: usize, skew_ms: u64) -> std::time::Duration {
    with_plan(spec, || {
        let env = Env::new();
        let xs: Vec<Value> = (0..n as i64).map(Value::I64).collect();
        let body = skewed_body(skew_ms);
        let opts = LapplyOpts::new().no_capture().chunking(Chunking::ChunkSize(4));
        let _ = future(Expr::lit(0i64), &env).unwrap().value();
        let want: i64 = (0..n as i64).map(|i| i * i).sum();
        time_once(|| {
            let total = future_map_reduce(
                &xs,
                "x",
                &body,
                &env,
                &opts,
                Value::I64(0),
                |acc, v| match (acc, v) {
                    (Value::I64(a), Value::I64(b)) => Ok(Value::I64(a + b)),
                    _ => unreachable!("integer fold"),
                },
            )
            .unwrap();
            assert_eq!(total, Value::I64(want));
        })
    })
}

fn run_resolve_any(spec: PlanSpec, slow_ms: u64) -> std::time::Duration {
    with_plan(spec, || {
        let env = Env::new();
        let _ = future(Expr::lit(0i64), &env).unwrap().value();
        let fs = vec![
            future(Expr::seq(vec![Expr::Spin { millis: slow_ms }, Expr::lit(0i64)]), &env)
                .unwrap(),
            future(Expr::seq(vec![Expr::Spin { millis: 1 }, Expr::lit(1i64)]), &env).unwrap(),
        ];
        let wall = time_once(|| {
            let i = resolve_any(&fs).unwrap();
            assert_eq!(i, 1, "fast future must win the race");
        });
        // Drain the slow future so the plan tears down cleanly.
        let _ = fs[0].value();
        wall
    })
}

fn main() {
    header(
        "E11: as-completed resolution (skewed chunk workload, 2 workers)",
        &["backend     ", "N    ", "mode          ", "wall      "],
    );

    let (n, skew_ms, slow_ms) = if smoke() { (32, 40, 60) } else { (128, 100, 150) };
    let mut json_rows = Vec::new();
    for spec in [PlanSpec::multicore(2), PlanSpec::multiprocess(2)] {
        let modes: [(&str, Box<dyn Fn() -> std::time::Duration>); 4] = [
            ("in-order", {
                let s = spec.clone();
                Box::new(move || run_lapply(s.clone(), n, skew_ms, true))
            }),
            ("as-completed", {
                let s = spec.clone();
                Box::new(move || run_lapply(s.clone(), n, skew_ms, false))
            }),
            ("map-reduce", {
                let s = spec.clone();
                Box::new(move || run_map_reduce(s.clone(), n, skew_ms))
            }),
            ("resolve-any", {
                let s = spec.clone();
                Box::new(move || run_resolve_any(s.clone(), slow_ms))
            }),
        ];
        for (label, run) in modes {
            let wall = run();
            row(&[
                format!("{:<12}", spec.name()),
                format!("{n:<5}"),
                format!("{label:<14}"),
                format!("{:>10}", fmt_dur(wall)),
            ]);
            json_rows.push(json_row(&[
                ("backend", Json::Str(spec.name().to_string())),
                ("n", Json::Int(n as i64)),
                ("mode", Json::Str(label.to_string())),
                ("skew_ms", Json::Int(skew_ms as i64)),
                ("wall_ns", Json::Int(wall.as_nanos() as i64)),
            ]));
        }
    }
    write_bench_json("resolve", json_rows);
    println!("\nshape check: as-completed ≤ in-order; resolve-any tracks the FAST racer (≪ slow_ms)");
}
