//! E5 — parallel RNG: the cost of `seed = TRUE` and stream machinery.
//!
//! Paper: "because seed = TRUE can introduce significant overhead, the
//! default is seed = FALSE."  Measures: (a) per-future cost with/without a
//! seed, (b) the raw 2^127 stream-jump cost vs stream index, (c) draw
//! throughput, and asserts reproducibility across two runs as a guard.

mod common;

use common::{fmt_dur, header, measure, row, time_once};
use rustures::api::future::reset_session_counter;
use rustures::api::plan::{with_plan, PlanSpec};
use rustures::prelude::*;

fn main() {
    // (a) per-future overhead with and without parallel RNG streams.
    header(
        "E5a: future overhead, seed = TRUE vs FALSE (rnorm(100) payload)",
        &["backend     ", "seed ", "mean      ", "p50       "],
    );
    for (spec, iters) in
        [(PlanSpec::multicore(2), 200usize), (PlanSpec::multiprocess(2), 80)]
    {
        for seed in [false, true] {
            let stats = with_plan(spec.clone(), || {
                measure(3, iters, || {
                    let mut opts = FutureOpts::new().no_capture();
                    if seed {
                        opts = opts.seed(42);
                    }
                    let f = future_with(Expr::rnorm(100), &Env::new(), opts).unwrap();
                    let _ = f.value().unwrap();
                })
            });
            row(&[
                format!("{:<12}", spec.name()),
                format!("{seed:<5}"),
                format!("{:>10}", fmt_dur(stats.mean)),
                format!("{:>10}", fmt_dur(stats.p50)),
            ]);
        }
    }

    // (b) stream-jump cost: nth_stream(seed, k) is O(log k) matrix work.
    header("E5b: RNG stream-jump cost (nth_stream)", &["stream index", "time      "]);
    for k in [0u64, 1, 100, 10_000, 1_000_000, u64::MAX / 2] {
        let stats = measure(2, 50, || {
            let _ = RngStream::nth_stream(12345, k);
        });
        row(&[format!("{k:>12}"), format!("{:>10}", fmt_dur(stats.mean))]);
    }

    // (c) draw throughput.
    header("E5c: draw throughput", &["dist", "draws/s       "]);
    for (label, norm) in [("unif", false), ("norm", true)] {
        let n = 2_000_000usize;
        let mut stream = RngStream::from_seed(9);
        let wall = time_once(|| {
            let mut acc = 0.0;
            for _ in 0..n {
                acc += if norm { stream.next_norm() } else { stream.next_unif() };
            }
            std::hint::black_box(acc);
        });
        row(&[
            format!("{label:<4}"),
            format!("{:>14.1}M", n as f64 / wall.as_secs_f64() / 1e6),
        ]);
    }

    // (d) reproducibility guard across a full parallel map.
    let run = || {
        with_plan(PlanSpec::multicore(2), || {
            reset_session_counter();
            let xs: Vec<Value> = (0..8i64).map(Value::I64).collect();
            future_lapply(&xs, "x", &Expr::rnorm(4), &Env::new(), &LapplyOpts::new().seed(7))
                .unwrap()
        })
    };
    assert_eq!(run(), run());
    println!("\nreproducibility guard: two seeded parallel maps identical ✓");
}
