//! E8 — wall-clock scaling of a parallel map with worker count, plus E13:
//! the capacity ledger's acquire/release overhead (`BENCH_capacity.json`).
//!
//! The framework's raison d'être: `future_lapply` over latency-bound
//! payloads (Sleep models I/O / remote-service waits, the honest choice on
//! this 1-core container — see DESIGN.md §3 caveat) should scale ~linearly
//! with workers; CPU-bound payloads (Spin) cannot on one core, and the
//! bench shows both so the distinction is explicit.
//!
//! E13 answers "what did centralizing seat admission cost?": one ledger
//! acquire+release cycle is compared against the seed's per-pool
//! mutex+condvar slot path (re-created here as a baseline), with quota'd
//! and contended variants.  Schema in BENCH.md.

mod common;

use common::{
    fmt_dur, header, json_row, measure, row, scale_iters, time_once, write_bench_json, Json,
};
use rustures::api::plan::{with_plan, PlanSpec};
use rustures::capacity::{
    set_session_limits, BreakerConfig, PoolRegistration, RevivePolicy, SessionLimits,
};
use rustures::prelude::*;

const ELEMENTS: usize = 16;
const MS: u64 = 30;

fn run_map(payload: &Expr, spec: PlanSpec) -> std::time::Duration {
    with_plan(spec, || {
        let env = Env::new();
        let xs: Vec<Value> = (0..ELEMENTS as i64).map(Value::I64).collect();
        // Warm the backend (worker spawn is one-time setup, not per-map).
        let _ = future(Expr::lit(0i64), &env).unwrap().value();
        time_once(|| {
            let _ = future_lapply(&xs, "x", payload, &env, &LapplyOpts::new().no_capture())
                .unwrap();
        })
    })
}

/// Calibrate Expr::Work iterations to ≈ MS milliseconds of CPU on this box.
fn calibrated_work() -> Expr {
    let probe = 2_000_000u64;
    let t0 = std::time::Instant::now();
    let mut acc = 0u64;
    for i in 0..probe {
        acc = acc.wrapping_add(rustures::util::uuid::splitmix64(i ^ acc));
    }
    std::hint::black_box(acc);
    let per_iter = t0.elapsed().as_secs_f64() / probe as f64;
    let iters = ((MS as f64 / 1e3) / per_iter) as u64;
    Expr::Work { iters }
}

/// E13: ledger acquire/release overhead vs the seed slot path.
fn bench_capacity() {
    let iters = scale_iters(20_000);

    // The seed's admission shape: one pool-private Mutex<usize> + Condvar
    // (ProcPool `slot_cv`, ThreadPool `free_slots`) — re-created here as
    // the baseline the ledger replaced.
    let seed = {
        use std::sync::{Condvar, Mutex};
        let slots = Mutex::new(4usize);
        let cv = Condvar::new();
        measure(1_000, iters, || {
            let mut free = slots.lock().unwrap();
            while *free == 0 {
                free = cv.wait(free).unwrap();
            }
            *free -= 1;
            drop(free);
            *slots.lock().unwrap() += 1;
            cv.notify_one();
        })
    };

    let reg = PoolRegistration::register(
        "bench",
        &[("local".to_string(), 4)],
        RevivePolicy::Never,
        BreakerConfig::default(),
    );
    for _ in 0..4 {
        reg.activate("local");
    }

    // Uncontended acquire+release through the ledger's single waiter queue.
    let ledger = measure(1_000, iters, || {
        let lease = reg.acquire(0).unwrap();
        drop(lease);
    });

    // The same cycle with a session quota consulted on every admission.
    let quota_session = 9_900_001u64;
    set_session_limits(quota_session, SessionLimits::new().max_workers(4));
    let quota = measure(1_000, iters, || {
        let lease = reg.acquire(quota_session).unwrap();
        drop(lease);
    });
    set_session_limits(quota_session, SessionLimits::new());

    header(
        "E13: capacity ledger acquire/release overhead",
        &["mode              ", "mean      ", "p50       ", "p95       "],
    );
    let mut rows = Vec::new();
    for (mode, stats) in [
        ("seed-mutex-condvar", &seed),
        ("ledger", &ledger),
        ("ledger-quota", &quota),
    ] {
        row(&[
            format!("{mode:<18}"),
            format!("{:>10}", fmt_dur(stats.mean)),
            format!("{:>10}", fmt_dur(stats.p50)),
            format!("{:>10}", fmt_dur(stats.p95)),
        ]);
        rows.push(json_row(&[
            ("mode", Json::Str(mode.to_string())),
            ("iters", Json::Int(stats.n as i64)),
            ("mean_ns", Json::Int(stats.mean.as_nanos() as i64)),
            ("p50_ns", Json::Int(stats.p50.as_nanos() as i64)),
            ("p95_ns", Json::Int(stats.p95.as_nanos() as i64)),
        ]));
    }
    write_bench_json("capacity", rows);
}

fn main() {
    bench_capacity();

    let sleep = Expr::Sleep { millis: MS };
    let work = calibrated_work();

    header(
        &format!("E8: future_lapply scaling ({ELEMENTS} × {MS}ms payload)"),
        &["payload", "backend     ", "workers", "wall      ", "speedup"],
    );

    // Smoke mode (scripts/bench.sh default) keeps the wall-clock table
    // short; the E13 JSON above is the per-PR perf-trajectory artifact.
    let worker_counts: &[usize] = if common::smoke() { &[1, 2] } else { &[1, 2, 4, 8] };
    for (label, payload) in [("sleep", &sleep), ("cpu", &work)] {
        let base = run_map(payload, PlanSpec::sequential());
        row(&[
            format!("{label:<7}"),
            format!("{:<12}", "sequential"),
            format!("{:>7}", 1),
            format!("{:>10}", fmt_dur(base)),
            format!("{:>7.2}x", 1.0),
        ]);
        for workers in worker_counts.iter().copied() {
            for spec in
                [PlanSpec::multicore(workers), PlanSpec::multiprocess(workers)]
            {
                let name = spec.name();
                let wall = run_map(payload, spec);
                row(&[
                    format!("{label:<7}"),
                    format!("{name:<12}"),
                    format!("{workers:>7}"),
                    format!("{:>10}", fmt_dur(wall)),
                    format!("{:>7.2}x", base.as_secs_f64() / wall.as_secs_f64()),
                ]);
            }
        }
    }
    println!("\nshape check: sleep payloads scale ≈ linearly in workers; cpu payloads cannot exceed the core count (1 here)");
}
