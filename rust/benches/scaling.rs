//! E8 — wall-clock scaling of a parallel map with worker count.
//!
//! The framework's raison d'être: `future_lapply` over latency-bound
//! payloads (Sleep models I/O / remote-service waits, the honest choice on
//! this 1-core container — see DESIGN.md §3 caveat) should scale ~linearly
//! with workers; CPU-bound payloads (Spin) cannot on one core, and the
//! bench shows both so the distinction is explicit.

mod common;

use common::{fmt_dur, header, row, time_once};
use rustures::api::plan::{with_plan, PlanSpec};
use rustures::prelude::*;

const ELEMENTS: usize = 16;
const MS: u64 = 30;

fn run_map(payload: &Expr, spec: PlanSpec) -> std::time::Duration {
    with_plan(spec, || {
        let env = Env::new();
        let xs: Vec<Value> = (0..ELEMENTS as i64).map(Value::I64).collect();
        // Warm the backend (worker spawn is one-time setup, not per-map).
        let _ = future(Expr::lit(0i64), &env).unwrap().value();
        time_once(|| {
            let _ = future_lapply(&xs, "x", payload, &env, &LapplyOpts::new().no_capture())
                .unwrap();
        })
    })
}

/// Calibrate Expr::Work iterations to ≈ MS milliseconds of CPU on this box.
fn calibrated_work() -> Expr {
    let probe = 2_000_000u64;
    let t0 = std::time::Instant::now();
    let mut acc = 0u64;
    for i in 0..probe {
        acc = acc.wrapping_add(rustures::util::uuid::splitmix64(i ^ acc));
    }
    std::hint::black_box(acc);
    let per_iter = t0.elapsed().as_secs_f64() / probe as f64;
    let iters = ((MS as f64 / 1e3) / per_iter) as u64;
    Expr::Work { iters }
}

fn main() {
    let sleep = Expr::Sleep { millis: MS };
    let work = calibrated_work();

    header(
        &format!("E8: future_lapply scaling ({ELEMENTS} × {MS}ms payload)"),
        &["payload", "backend     ", "workers", "wall      ", "speedup"],
    );

    for (label, payload) in [("sleep", &sleep), ("cpu", &work)] {
        let base = run_map(payload, PlanSpec::sequential());
        row(&[
            format!("{label:<7}"),
            format!("{:<12}", "sequential"),
            format!("{:>7}", 1),
            format!("{:>10}", fmt_dur(base)),
            format!("{:>7.2}x", 1.0),
        ]);
        for workers in [1usize, 2, 4, 8] {
            for spec in
                [PlanSpec::multicore(workers), PlanSpec::multiprocess(workers)]
            {
                let name = spec.name();
                let wall = run_map(payload, spec);
                row(&[
                    format!("{label:<7}"),
                    format!("{name:<12}"),
                    format!("{workers:>7}"),
                    format!("{:>10}", fmt_dur(wall)),
                    format!("{:>7.2}x", base.as_secs_f64() / wall.as_secs_f64()),
                ]);
            }
        }
    }
    println!("\nshape check: sleep payloads scale ≈ linearly in workers; cpu payloads cannot exceed the core count (1 here)");
}
