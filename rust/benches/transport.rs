//! E18 — async multiplexed transport core.
//!
//! Three measurements, one story: what the poll-driven reactor buys over
//! the legacy thread-per-connection shape, and what promise pipelining
//! buys over collect-then-reship.
//!
//! * `lapply` — the same seeded map on a multiprocess pool with channels
//!   on the reactor (default) vs forced onto blocking pump threads (the
//!   legacy per-seat reader/writer shape).  Results are bit-identical
//!   (the conformance suite asserts it); this measures the time.
//! * `chain` — a dependency chain `f1 → f2 → … → fK`: `pipelined` ships
//!   each dependency's outcome straight to the consumer's seat as a
//!   wire-v7 Forward frame (one hop); `round-trip` collects each value at
//!   the coordinator and re-ships it inside the next future's globals
//!   (two hops).
//! * `fanout-256` — register 256 simulated worker channels (socketpairs),
//!   deliver one frame from each, tear down: the reactor does it on ONE
//!   poll thread; pump mode pays 256 thread spawns + stack churn.
//!
//! Shape: reactor ≤ pump on `lapply` (same work, fewer threads), pipelined
//! < round-trip on `chain` (one hop beats two), and reactor ≪ pump on
//! `fanout-256` (thread churn dominates at scale).
//!
//! Emits `BENCH_transport.json` (schema in BENCH.md); `scripts/bench.sh`
//! runs this in smoke mode.

mod common;

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use common::{fmt_dur, header, json_row, measure, row, scale_iters, write_bench_json, Json};
use rustures::prelude::*;

const CHAIN_DEPTH: usize = 4;
const FANOUT: usize = 256;

fn emit(rows: &mut Vec<Json>, plan: &str, mode: &str, stats: &common::Stats) {
    row(&[
        format!("{plan:<12}"),
        format!("{mode:<10}"),
        format!("{:>10}", fmt_dur(stats.mean)),
        format!("{:>10}", fmt_dur(stats.p50)),
        format!("{:>10}", fmt_dur(stats.p95)),
    ]);
    rows.push(json_row(&[
        ("plan", Json::Str(plan.to_string())),
        ("mode", Json::Str(mode.to_string())),
        ("mean_ns", Json::Int(stats.mean.as_nanos() as i64)),
        ("p50_ns", Json::Int(stats.p50.as_nanos() as i64)),
        ("p95_ns", Json::Int(stats.p95.as_nanos() as i64)),
        ("iters", Json::Int(stats.n as i64)),
    ]));
}

/// The same seeded lapply, channels on the reactor vs on pump threads.
/// Fresh session per run: `force_pump_scope` only affects registrations
/// made while the guard lives, so the pool must be built inside it.
fn bench_lapply(json_rows: &mut Vec<Json>) {
    let iters = scale_iters(20);
    let env = Env::new();
    let xs: Vec<Value> = (0..12i64).map(Value::I64).collect();
    let body = Expr::add(Expr::var("x"), Expr::runif(1));
    let opts = || LapplyOpts::new().seed(11).chunking(Chunking::ChunkSize(3));

    let stats = measure(1, iters, || {
        let s = Session::with_plan(PlanSpec::multiprocess(2));
        let _ = s.lapply(&xs, "x", &body, &env, &opts()).unwrap();
        s.close();
    });
    emit(json_rows, "mp-2 lapply", "reactor", &stats);

    let stats = measure(1, iters, || {
        let _pump = rustures::transport::force_pump_scope();
        let s = Session::with_plan(PlanSpec::multiprocess(2));
        let _ = s.lapply(&xs, "x", &body, &env, &opts()).unwrap();
        s.close();
    });
    emit(json_rows, "mp-2 lapply", "pump", &stats);
}

/// A K-deep dependency chain: pipelined (Forward frames, one hop per
/// link) vs classic round-trip (collect at the coordinator, re-ship in
/// the next future's globals).
fn bench_chain(json_rows: &mut Vec<Json>) {
    let iters = scale_iters(20);
    let s = Session::with_plan(PlanSpec::multiprocess(2));
    let env = Env::new();

    let stats = measure(1, iters, || {
        let mut prev = s.future(Expr::lit(0i64), &env).unwrap();
        for _ in 0..CHAIN_DEPTH {
            let dep_id = prev.id().to_string();
            let link = Expr::seq(vec![
                Expr::Spin { millis: 1 },
                Expr::add(Expr::await_future(&dep_id), Expr::lit(1i64)),
            ]);
            prev = s
                .future_pipelined(link, &env, FutureOpts::new(), vec![prev])
                .unwrap();
        }
        assert_eq!(prev.value().unwrap(), Value::I64(CHAIN_DEPTH as i64));
    });
    emit(json_rows, "chain-4", "pipelined", &stats);

    let stats = measure(1, iters, || {
        let mut v = s.future(Expr::lit(0i64), &env).unwrap().value().unwrap();
        for _ in 0..CHAIN_DEPTH {
            let mut link_env = Env::new();
            link_env.insert("prev", v);
            let link = Expr::seq(vec![
                Expr::Spin { millis: 1 },
                Expr::add(Expr::var("prev"), Expr::lit(1i64)),
            ]);
            v = s.future(link, &link_env).unwrap().value().unwrap();
        }
        assert_eq!(v, Value::I64(CHAIN_DEPTH as i64));
    });
    emit(json_rows, "chain-4", "round-trip", &stats);
    s.close();
}

/// Register `FANOUT` simulated worker channels, deliver one frame from
/// each, tear down.  One reactor thread vs one pump thread per channel.
#[cfg(unix)]
fn fanout_once(force_pump: bool) {
    use std::os::unix::io::AsRawFd;
    use std::os::unix::net::UnixStream;

    use rustures::ipc::frame::write_message;
    use rustures::ipc::Message;
    use rustures::transport::{self, ChannelEvent, Endpoint};

    let _pump = force_pump.then(transport::force_pump_scope);
    let frames = Arc::new(AtomicUsize::new(0));
    let closed = Arc::new(AtomicUsize::new(0));
    let mut peers = Vec::with_capacity(FANOUT);
    let mut channels = Vec::with_capacity(FANOUT);
    for i in 0..FANOUT {
        let (ours, theirs) = UnixStream::pair().expect("socketpair");
        let reader = ours.try_clone().expect("dup");
        let (rfd, wfd) = (reader.as_raw_fd(), ours.as_raw_fd());
        let frames = Arc::clone(&frames);
        let closed = Arc::clone(&closed);
        channels.push(transport::register(
            &format!("bench-fanout-{i}"),
            Endpoint::with_fds(Box::new(reader), Box::new(ours), rfd, wfd),
            Arc::new(move |ev| match ev {
                ChannelEvent::Message(_) => {
                    frames.fetch_add(1, Ordering::SeqCst);
                }
                ChannelEvent::Closed | ChannelEvent::Error(_) => {
                    closed.fetch_add(1, Ordering::SeqCst);
                }
                ChannelEvent::Stalled { .. } => {}
            }),
        ));
        peers.push(theirs);
    }
    for peer in &mut peers {
        write_message(peer, &Message::Ping).expect("peer write");
    }
    let give_up = Instant::now() + Duration::from_secs(60);
    while frames.load(Ordering::SeqCst) < FANOUT {
        assert!(Instant::now() < give_up, "fan-out frames never all arrived");
        std::thread::sleep(Duration::from_millis(1));
    }
    drop(peers);
    while closed.load(Ordering::SeqCst) < FANOUT {
        assert!(Instant::now() < give_up, "fan-out channels never all closed");
        std::thread::sleep(Duration::from_millis(1));
    }
    for ch in &channels {
        ch.close();
    }
}

#[cfg(unix)]
fn bench_fanout(json_rows: &mut Vec<Json>) {
    let iters = scale_iters(10);
    let stats = measure(1, iters, || fanout_once(false));
    emit(json_rows, "fanout-256", "reactor", &stats);
    let stats = measure(1, iters, || fanout_once(true));
    emit(json_rows, "fanout-256", "pump", &stats);
}

#[cfg(not(unix))]
fn bench_fanout(_json_rows: &mut Vec<Json>) {
    println!("fanout-256: skipped (no socketpair on this platform)");
}

fn main() {
    header(
        "E18: async multiplexed transport core",
        &["plan        ", "mode      ", "mean      ", "p50       ", "p95       "],
    );

    let mut json_rows = Vec::new();
    bench_lapply(&mut json_rows);
    bench_chain(&mut json_rows);
    bench_fanout(&mut json_rows);

    write_bench_json("transport", json_rows);
    println!(
        "\nshape check: reactor ≤ pump on lapply; pipelined < round-trip on \
         the chain (one hop per link beats two); reactor ≪ pump on the \
         256-channel fan-out (thread churn dominates at scale)"
    );
}
