//! E16 — wire protocol v6: bytes on the wire and codec cost.
//!
//! Measures the serialization substrate directly (no backend in the loop):
//! encode/decode nanoseconds and bytes-on-wire for a task carrying one
//! large tensor global, across four modes —
//!
//! * `raw-resend`     — uncompressed, uninterned: the v5-equivalent
//!                      baseline every other mode is judged against.
//! * `compressed`     — v6 per-frame codec, no interning (fresh ledger per
//!                      send).
//! * `interned-first` — interning on, first send to a seat (pays the
//!                      provide: digest + blob + compression).
//! * `interned-ref`   — interning on, steady state (the global collapses
//!                      to a 17-byte reference).
//!
//! The PR 8 acceptance bar: at the 1 MB payload point, `compressed` and
//! `interned-ref` bytes-on-wire MUST be strictly below `raw-resend`.
//! Emits `BENCH_wire.json` (schema in BENCH.md); `scripts/bench.sh` runs
//! this in smoke mode.

mod common;

use common::{fmt_dur, header, json_row, measure, row, scale_iters, write_bench_json, Json};
use rustures::api::env::Env;
use rustures::api::expr::{Expr, PrimOp};
use rustures::api::value::{Tensor, Value};
use rustures::ipc::intern::SeatLedger;
use rustures::ipc::wire::{decode_message, encode_message_opts, encode_task_message_interned};
use rustures::ipc::{Message, TaskOpts, TaskSpec};

/// A task shipping one `payload_bytes`-sized f32 tensor global plus a
/// small expression that uses it — the shape the paper's repeated-`lapply`
/// workloads send per chunk.
fn payload_task(payload_bytes: usize) -> TaskSpec {
    let n = payload_bytes / 4;
    // Slowly varying values: realistic enough that RLE has runs to find
    // but the win comes from the lag-4 delta, not an all-zeros fluke.
    let data: Vec<f32> = (0..n).map(|i| (i / 64) as f32).collect();
    let mut globals = Env::new();
    globals
        .insert("weights", Value::Tensor(Tensor::new(vec![n], data).unwrap()));
    TaskSpec {
        id: "f-0-1".to_string(),
        expr: Expr::prim(PrimOp::Sum, vec![Expr::var("weights")]),
        globals,
        opts: TaskOpts::default(),
    }
}

struct Mode {
    name: &'static str,
    encode: fn(&TaskSpec) -> Vec<u8>,
}

fn enc_raw(t: &TaskSpec) -> Vec<u8> {
    encode_message_opts(&Message::Task(t.clone()), false)
}

fn enc_compressed(t: &TaskSpec) -> Vec<u8> {
    encode_message_opts(&Message::Task(t.clone()), true)
}

fn enc_interned_first(t: &TaskSpec) -> Vec<u8> {
    // Fresh ledger: every send pays the provide.
    let mut ledger = SeatLedger::new();
    encode_task_message_interned(t, &mut ledger)
}

fn main() {
    let iters = scale_iters(200);
    let payloads: &[usize] = &[1 << 14, 1 << 17, 1 << 20]; // 16 KiB .. 1 MiB

    header(
        "E16: wire v6 bytes-on-wire + codec cost",
        &["payload ", "mode          ", "bytes     ", "encode p50", "decode p50"],
    );

    let modes: &[Mode] = &[
        Mode { name: "raw-resend", encode: enc_raw },
        Mode { name: "compressed", encode: enc_compressed },
        Mode { name: "interned-first", encode: enc_interned_first },
    ];

    let mut json_rows = Vec::new();
    let mut emit = |payload: usize,
                    mode: &str,
                    bytes: usize,
                    enc: common::Stats,
                    dec: common::Stats,
                    json_rows: &mut Vec<Json>| {
        row(&[
            format!("{:<8}", payload),
            format!("{mode:<14}"),
            format!("{bytes:>10}"),
            format!("{:>10}", fmt_dur(enc.p50)),
            format!("{:>10}", fmt_dur(dec.p50)),
        ]);
        json_rows.push(json_row(&[
            ("payload_bytes", Json::Int(payload as i64)),
            ("mode", Json::Str(mode.to_string())),
            ("bytes_on_wire", Json::Int(bytes as i64)),
            ("encode_ns_p50", Json::Int(enc.p50.as_nanos() as i64)),
            ("encode_ns_mean", Json::Int(enc.mean.as_nanos() as i64)),
            ("decode_ns_p50", Json::Int(dec.p50.as_nanos() as i64)),
            ("decode_ns_mean", Json::Int(dec.mean.as_nanos() as i64)),
            ("iters", Json::Int(enc.n as i64)),
        ]));
    };

    for &payload in payloads {
        let task = payload_task(payload);
        for m in modes {
            let frame = (m.encode)(&task);
            let bytes = frame.len();
            let enc = measure(2, iters, || {
                std::hint::black_box((m.encode)(std::hint::black_box(&task)));
            });
            let dec = measure(2, iters, || {
                // Decoded without a cache: these three modes never emit
                // references (a fresh ledger's first send is all provides,
                // which install into the decoder's own scratch cache).
                std::hint::black_box(decode_message(std::hint::black_box(&frame)).unwrap());
            });
            emit(payload, m.name, bytes, enc, dec, &mut json_rows);
        }

        // Steady-state interning: one warm ledger, measure the Nth send.
        let mut ledger = SeatLedger::new();
        let first = encode_task_message_interned(&task, &mut ledger);
        drop(first);
        let frame = encode_task_message_interned(&task, &mut ledger);
        let bytes = frame.len();
        let enc = measure(2, iters, || {
            std::hint::black_box(encode_task_message_interned(
                std::hint::black_box(&task),
                &mut ledger,
            ));
        });
        // A reference-only frame needs the worker-side cache primed with
        // the blob, exactly as a real worker's would be after the first
        // frame: decode the provide frame into a cache, then measure.
        let cache = rustures::ipc::intern::InternCache::new();
        let provide_frame = {
            let mut fresh = SeatLedger::new();
            encode_task_message_interned(&task, &mut fresh)
        };
        rustures::ipc::wire::decode_message_cached(&provide_frame, Some(&cache)).unwrap();
        let dec = measure(2, iters, || {
            std::hint::black_box(
                rustures::ipc::wire::decode_message_cached(
                    std::hint::black_box(&frame),
                    Some(&cache),
                )
                .unwrap(),
            );
        });
        emit(payload, "interned-ref", bytes, enc, dec, &mut json_rows);
    }

    write_bench_json("wire", json_rows);
    println!(
        "\nshape check: at every payload point, compressed and interned-ref \
         bytes_on_wire must sit strictly below raw-resend (interned-ref by \
         orders of magnitude); encode/decode p50 for interned-ref must be \
         payload-independent"
    );
}
