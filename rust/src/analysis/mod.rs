//! Plan-time static analysis — a multi-pass linter over `(Expr, globals,
//! FutureOpts, session plan/limits)` that runs *before* a future costs
//! anything: no capacity lease, no serialization, no worker round trip.
//!
//! This is the reproduction of the paper's guard rails around automatic
//! globals identification: `future.globals.maxSize` (the export-size
//! budget), `future.rng.onMisuse` (RNG hygiene), and the `get("k")`
//! opacity trap — plus plan-level cross-checks the R package surfaces as
//! runtime errors (nested-blocking deadlock shapes, deadlines shorter
//! than a heartbeat, exhausted topology tails).
//!
//! Design rules:
//!
//! * **Stable lint codes.** [`LintCode`] is the public contract; messages
//!   and help text may be reworded, codes never change meaning.
//! * **Configurable severity.** [`AnalysisConfig`] maps every code to
//!   [`Severity::Deny`] / [`Severity::Warn`] / [`Severity::Allow`] with
//!   documented defaults; sessions carry their own config.
//! * **Diagnostics never perturb execution.** An `Allow`ed (or disabled)
//!   analysis run is bit-identical to no analysis at all; a `Warn` run
//!   only relays conditions and bumps counters — values and RNG streams
//!   are untouched. Only `Deny` changes behavior, by refusing creation
//!   with [`crate::api::error::FutureError::Rejected`].
//! * **The export estimator may over-count but never under-counts.** See
//!   [`estimate_export_size`]; the property test in `tests/proptests.rs`
//!   machine-checks domination over the actual wire encoding.

use std::collections::BTreeMap;
use std::fmt;
use std::time::Duration;

use crate::api::env::Env;
use crate::api::expr::Expr;
use crate::api::future::FutureOpts;
use crate::api::globals::{free_variables, GlobalsSpec};
use crate::api::value::Value;

/// Stable identifiers for everything the analyzer can flag.
///
/// The string form ([`LintCode::as_str`]) is what appears in diagnostics,
/// metrics JSON (`rustures.analysis.v1`), and config files — treat it as
/// a wire format.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum LintCode {
    /// Estimated export (globals + literal payloads) exceeds
    /// `max_globals_size` — the `future.globals.maxSize` analog.
    ExportSize,
    /// The expression draws random numbers but no seed was supplied —
    /// the creation-time promotion of `future.rng.onMisuse`.
    UnseededRng,
    /// A seed was supplied but the expression never draws — a wasted
    /// (and probably misplaced) RNG stream.
    UnusedSeed,
    /// Two `WithRngStream` scopes in one expression reuse the same
    /// substream index, so their draws are correlated.
    DuplicateRngStream,
    /// `DynLookup` (the paper's `get("k")` trap) is reachable under
    /// `GlobalsSpec::Auto`, where static capture cannot see the name.
    DynLookup,
    /// `ChaosKill` / `ChaosHang` fault injection outside a chaos-armed
    /// session.
    ChaosInjection,
    /// A blocking (non-queued, non-lazy) create from a worker-side
    /// derived session while `SessionLimits::max_workers` caps the very
    /// pool the parent occupies — the classic nested-blocking deadlock
    /// shape.
    DeadlockHazard,
    /// Effective deadline shorter than the liveness heartbeat interval:
    /// the future can time out before the worker's first sign of life.
    DeadlineHeartbeat,
    /// Create at a nesting depth past the last topology level — the
    /// plan silently degrades to sequential (the paper's nested-
    /// protection tail).
    TopologyTail,
    /// An explicit/`AutoPlus` capture name that the expression never
    /// references (probable typo), or a free variable missing from an
    /// `Explicit` list (guaranteed eval-time failure).
    UselessCapture,
    /// `FutureOpts::cached` on a future whose result is not a pure
    /// function of its cache key: unseeded RNG draws, or `DynLookup`
    /// under `GlobalsSpec::Auto` (the captured globals — hence the key —
    /// cannot see the dynamically-named input).  A cached
    /// nondeterministic future silently freezes one sample.
    CacheNondeterministic,
}

impl LintCode {
    /// Every code, in catalog order (DESIGN.md §Static Analysis).
    pub const ALL: [LintCode; 11] = [
        LintCode::ExportSize,
        LintCode::UnseededRng,
        LintCode::UnusedSeed,
        LintCode::DuplicateRngStream,
        LintCode::DynLookup,
        LintCode::ChaosInjection,
        LintCode::DeadlockHazard,
        LintCode::DeadlineHeartbeat,
        LintCode::TopologyTail,
        LintCode::UselessCapture,
        LintCode::CacheNondeterministic,
    ];

    pub fn as_str(&self) -> &'static str {
        match self {
            LintCode::ExportSize => "export-size",
            LintCode::UnseededRng => "unseeded-rng",
            LintCode::UnusedSeed => "unused-seed",
            LintCode::DuplicateRngStream => "duplicate-rng-stream",
            LintCode::DynLookup => "dyn-lookup",
            LintCode::ChaosInjection => "chaos-injection",
            LintCode::DeadlockHazard => "deadlock-hazard",
            LintCode::DeadlineHeartbeat => "deadline-heartbeat",
            LintCode::TopologyTail => "topology-tail",
            LintCode::UselessCapture => "useless-capture",
            LintCode::CacheNondeterministic => "cache-nondeterministic",
        }
    }
}

impl fmt::Display for LintCode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// What happens when a lint fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Severity {
    /// Recorded by `Session::lint` only; creation proceeds untouched.
    Allow,
    /// Creation proceeds; the diagnostic is relayed through the
    /// conditions plane and counted per session in metrics.
    Warn,
    /// Creation fails with `FutureError::Rejected` before any capacity
    /// lease or worker round trip.
    Deny,
}

impl fmt::Display for Severity {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Severity::Allow => "allow",
            Severity::Warn => "warn",
            Severity::Deny => "deny",
        })
    }
}

/// One finding: a stable code, the severity it resolved to under the
/// active config, a coarse path locating the finding, a human message,
/// and actionable help.
#[derive(Debug, Clone, PartialEq)]
pub struct Diagnostic {
    pub code: LintCode,
    pub severity: Severity,
    /// Coarse locator: `"globals"`, `"expr"`, `"plan"`, or a refinement
    /// like `"globals['weights']"` / `"expr.with_rng_stream[7]"`.
    pub path: String,
    pub message: String,
    pub help: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "lint {} [{}] at {}: {} (help: {})",
            self.code, self.severity, self.path, self.message, self.help
        )
    }
}

/// Default export budget: 500 MiB, matching `future.globals.maxSize`'s
/// R default of 500 MB in spirit (we use binary units throughout).
pub const DEFAULT_MAX_GLOBALS_SIZE: usize = 500 * 1024 * 1024;

/// Per-session analyzer policy: an on/off switch, the export budget,
/// chaos arming, and per-code severity overrides on top of the
/// documented defaults.
#[derive(Debug, Clone, PartialEq)]
pub struct AnalysisConfig {
    /// Master switch consulted by `future_with`; `Session::lint` runs
    /// the passes regardless so a disabled session can still be probed.
    pub enabled: bool,
    /// Export budget in estimated bytes (see [`estimate_export_size`]).
    pub max_globals_size: usize,
    /// Chaos-armed sessions (the default — ambient sessions double as
    /// the test harness) treat `ChaosKill`/`ChaosHang` as `Allow`;
    /// disarmed sessions deny them. [`AnalysisConfig::hardened`] disarms.
    pub chaos_armed: bool,
    overrides: BTreeMap<LintCode, Severity>,
}

impl Default for AnalysisConfig {
    fn default() -> Self {
        AnalysisConfig {
            enabled: true,
            max_globals_size: DEFAULT_MAX_GLOBALS_SIZE,
            chaos_armed: true,
            overrides: BTreeMap::new(),
        }
    }
}

impl AnalysisConfig {
    pub fn new() -> Self {
        Self::default()
    }

    /// Analysis fully off: `future_with` skips the passes entirely.
    pub fn disabled() -> Self {
        AnalysisConfig { enabled: false, ..Self::default() }
    }

    /// Production preset for multi-tenant sessions: chaos injection is
    /// disarmed (→ `Deny`) and the softer hygiene lints are promoted to
    /// `Warn` so misconfiguration is at least visible.
    pub fn hardened() -> Self {
        AnalysisConfig { chaos_armed: false, ..Self::default() }
            .warn(LintCode::UnseededRng)
            .warn(LintCode::UnusedSeed)
            .warn(LintCode::TopologyTail)
            .deny(LintCode::CacheNondeterministic)
    }

    /// Override one code's severity.
    pub fn set(mut self, code: LintCode, severity: Severity) -> Self {
        self.overrides.insert(code, severity);
        self
    }

    pub fn deny(self, code: LintCode) -> Self {
        self.set(code, Severity::Deny)
    }

    pub fn warn(self, code: LintCode) -> Self {
        self.set(code, Severity::Warn)
    }

    pub fn allow(self, code: LintCode) -> Self {
        self.set(code, Severity::Allow)
    }

    /// Set the export budget (estimated bytes).
    pub fn max_globals_size(mut self, bytes: usize) -> Self {
        self.max_globals_size = bytes;
        self
    }

    /// The severity `code` resolves to under this config: an explicit
    /// override wins; otherwise the documented default (which for
    /// `ChaosInjection` depends on [`AnalysisConfig::chaos_armed`]).
    pub fn action(&self, code: LintCode) -> Severity {
        if let Some(s) = self.overrides.get(&code) {
            return *s;
        }
        match code {
            LintCode::ExportSize => Severity::Deny,
            // The eval-time warning remains the default surface for
            // unseeded draws; promoting this to Warn/Deny is the
            // fail-fast opt-in.
            LintCode::UnseededRng => Severity::Allow,
            LintCode::UnusedSeed => Severity::Allow,
            LintCode::DuplicateRngStream => Severity::Warn,
            LintCode::DynLookup => Severity::Warn,
            LintCode::ChaosInjection => {
                if self.chaos_armed {
                    Severity::Allow
                } else {
                    Severity::Deny
                }
            }
            LintCode::DeadlockHazard => Severity::Warn,
            LintCode::DeadlineHeartbeat => Severity::Warn,
            // Nested tails are ubiquitous and intentional in topology
            // tests; surfacing them is opt-in (hardened() warns).
            LintCode::TopologyTail => Severity::Allow,
            LintCode::UselessCapture => Severity::Warn,
            // The cache layer already refuses to KEY such futures
            // (they evaluate normally, uncached) — the lint makes the
            // silent downgrade visible; hardened() denies.
            LintCode::CacheNondeterministic => Severity::Warn,
        }
    }
}

/// The session-side facts the plan cross-check pass needs, assembled by
/// `Session::analysis_facts` without instantiating any backend.
#[derive(Debug, Clone, Default)]
pub struct SessionFacts {
    /// True for worker-side derived sessions (`id != origin_id`).
    pub derived: bool,
    /// Current nesting depth (0 = top level).
    pub depth: u32,
    /// Number of plan levels in the session topology.
    pub topology_levels: usize,
    /// The origin session's `SessionLimits::max_workers`, if capped.
    pub max_workers: Option<usize>,
    /// Session default deadline (applied when `FutureOpts::deadline`
    /// is unset).
    pub default_deadline: Option<Duration>,
}

/// Conservative upper bound for one value's wire footprint: the
/// in-memory [`Value::byte_size`] accounting plus a fixed 16-byte margin
/// per node for tags/lengths/dims. Lists are summed recursively so every
/// nested element gets its own margin.
fn value_upper(v: &Value) -> usize {
    match v {
        Value::List(items) => 16 + items.iter().map(value_upper).sum::<usize>(),
        other => other.byte_size() + 16,
    }
}

/// Static upper bound (bytes) for what shipping this future would
/// serialize: captured globals plus the expression tree with its literal
/// payloads (`Lit` values, `MapChunk` elements).
///
/// The estimate intentionally **over**-counts — every node carries a
/// fixed margin dominating its wire tag/length fields — and never
/// under-counts, so an export-size `Deny` can trust it: if the estimate
/// is within budget, the encoded task is too. Machine-checked against
/// `ipc::wire::enc_expr` by `prop_export_estimate_dominates_encoding`.
pub fn estimate_export_size(expr: &Expr, globals: &Env) -> usize {
    // Base margin for the task frame: v6 frame header (magic, version,
    // kind, codec, varint length), provide-section count, id, opts,
    // session context header.
    let mut est = 256usize;
    for (name, value) in globals.iter() {
        // 56 dominates both wire shapes of a captured global: the plain
        // encoding (name varint + value tag/length fields) and the v6
        // interned shape (a 16-byte digest + varint blob length in the
        // provide section PLUS a 17-byte reference slot in the record).
        est += name.len() + 56 + value_upper(value);
    }
    expr.walk(&mut |e| {
        // Per-node margin dominating the wire tag plus any fixed-width
        // operands (counts, indices, millis).
        est += 24;
        match e {
            Expr::Lit(v) => est += value_upper(v),
            Expr::Var(name) => est += name.len(),
            Expr::Let { name, .. } => est += name.len(),
            Expr::Call { kernel, .. } => est += kernel.len(),
            Expr::Rng { shape, .. } => est += 8 * shape.len(),
            Expr::MapChunk { param, elements, .. } => {
                est += param.len() + 8 * elements.len();
                est += elements.iter().map(value_upper).sum::<usize>();
            }
            Expr::ChaosKill { marker } => {
                est += marker.as_deref().map_or(0, str::len);
            }
            Expr::ChaosHang { marker, .. } => {
                est += marker.as_deref().map_or(0, str::len);
            }
            Expr::Await { future_id } => est += future_id.len(),
            _ => {}
        }
    });
    est
}

struct Collector<'c> {
    config: &'c AnalysisConfig,
    include_allowed: bool,
    out: Vec<Diagnostic>,
}

impl Collector<'_> {
    /// Whether a pass should bother computing findings for `code`.
    fn wants(&self, code: LintCode) -> bool {
        self.include_allowed || self.config.action(code) != Severity::Allow
    }

    fn emit(&mut self, code: LintCode, path: impl Into<String>, message: String, help: &str) {
        let severity = self.config.action(code);
        if severity == Severity::Allow && !self.include_allowed {
            return;
        }
        self.out.push(Diagnostic {
            code,
            severity,
            path: path.into(),
            message,
            help: help.to_string(),
        });
    }
}

/// Enforcement entry point used by `future_with`: runs all passes and
/// returns only findings whose configured severity is `Warn` or `Deny`
/// (an `Allow`ed finding costs nothing, preserving bit-identity with a
/// disabled analyzer).
pub fn analyze(
    expr: &Expr,
    globals: &Env,
    spec: &GlobalsSpec,
    opts: &FutureOpts,
    facts: &SessionFacts,
    config: &AnalysisConfig,
) -> Vec<Diagnostic> {
    run_passes(expr, globals, spec, opts, facts, config, false)
}

/// Introspection entry point used by `Session::lint`: like [`analyze`]
/// but includes `Allow`-severity findings, so callers can see everything
/// the analyzer knows regardless of the enforcement policy.
pub fn lint(
    expr: &Expr,
    globals: &Env,
    spec: &GlobalsSpec,
    opts: &FutureOpts,
    facts: &SessionFacts,
    config: &AnalysisConfig,
) -> Vec<Diagnostic> {
    run_passes(expr, globals, spec, opts, facts, config, true)
}

#[allow(clippy::too_many_arguments)]
fn run_passes(
    expr: &Expr,
    globals: &Env,
    spec: &GlobalsSpec,
    opts: &FutureOpts,
    facts: &SessionFacts,
    config: &AnalysisConfig,
    include_allowed: bool,
) -> Vec<Diagnostic> {
    let mut c = Collector { config, include_allowed, out: Vec::new() };
    pass_export_audit(expr, globals, config, &mut c);
    pass_rng_hygiene(expr, opts, &mut c);
    pass_opacity(expr, spec, &mut c);
    pass_plan_cross_check(opts, facts, &mut c);
    pass_capture_typos(expr, spec, &mut c);
    pass_cache_determinism(expr, spec, opts, &mut c);
    c.out
}

/// Pass 1 — export audit (`future.globals.maxSize`).
fn pass_export_audit(expr: &Expr, globals: &Env, config: &AnalysisConfig, c: &mut Collector<'_>) {
    if !c.wants(LintCode::ExportSize) {
        return;
    }
    let est = estimate_export_size(expr, globals);
    if est > config.max_globals_size {
        c.emit(
            LintCode::ExportSize,
            "globals",
            format!(
                "estimated export is {est} bytes, exceeding the \
                 max_globals_size budget of {} bytes",
                config.max_globals_size
            ),
            "shrink the captured globals (capture a slice, not the whole \
             tensor), or raise AnalysisConfig::max_globals_size if the \
             transfer is intentional",
        );
    }
}

/// Pass 2 — RNG hygiene (`future.rng.onMisuse`).
fn pass_rng_hygiene(expr: &Expr, opts: &FutureOpts, c: &mut Collector<'_>) {
    let uses_rng = expr.uses_rng();
    if opts.seed.is_none() && uses_rng && c.wants(LintCode::UnseededRng) {
        c.emit(
            LintCode::UnseededRng,
            "expr",
            "expression draws random numbers but no seed was supplied; \
             results are not reproducible"
                .to_string(),
            "pass FutureOpts::new().seed(s) to derive a parallel-safe \
             per-future stream",
        );
    }
    if opts.seed.is_some() && !uses_rng && c.wants(LintCode::UnusedSeed) {
        c.emit(
            LintCode::UnusedSeed,
            "expr",
            "a seed was supplied but the expression never draws random \
             numbers; the dedicated RNG stream is wasted"
                .to_string(),
            "drop the seed, or move it to the future that actually draws",
        );
    }
    if c.wants(LintCode::DuplicateRngStream) {
        let mut seen: BTreeMap<u64, usize> = BTreeMap::new();
        expr.walk(&mut |e| {
            if let Expr::WithRngStream { index, .. } = e {
                *seen.entry(*index).or_insert(0) += 1;
            }
        });
        for (index, count) in seen {
            if count > 1 {
                c.emit(
                    LintCode::DuplicateRngStream,
                    format!("expr.with_rng_stream[{index}]"),
                    format!(
                        "RNG substream index {index} is opened by {count} \
                         sibling scopes; their draws are identical, not \
                         independent"
                    ),
                    "give every WithRngStream scope in one expression a \
                     distinct index (the map-reduce layer derives them \
                     from element positions)",
                );
            }
        }
    }
}

/// Pass 3 — opacity / exportability (`get("k")`, chaos injection).
fn pass_opacity(expr: &Expr, spec: &GlobalsSpec, c: &mut Collector<'_>) {
    let mut has_dyn = false;
    let mut chaos: Option<&'static str> = None;
    expr.walk(&mut |e| match e {
        Expr::DynLookup(_) => has_dyn = true,
        Expr::ChaosKill { .. } => chaos = chaos.or(Some("ChaosKill")),
        Expr::ChaosHang { .. } => chaos = chaos.or(Some("ChaosHang")),
        _ => {}
    });
    if has_dyn && *spec == GlobalsSpec::Auto && c.wants(LintCode::DynLookup) {
        c.emit(
            LintCode::DynLookup,
            "expr",
            "expression looks up a global by computed name (the paper's \
             get(\"k\") trap); automatic capture cannot see which \
             variable it needs"
                .to_string(),
            "name the dynamic globals with \
             GlobalsSpec::AutoPlus([\"k\", ...]) — the paper's fix — or \
             capture everything explicitly with GlobalsSpec::Explicit",
        );
    }
    if let Some(kind) = chaos {
        if c.wants(LintCode::ChaosInjection) {
            c.emit(
                LintCode::ChaosInjection,
                "expr",
                format!("expression contains {kind} fault injection"),
                "chaos expressions are for arming tests; run them in a \
                 chaos-armed session (the default config) or strip them \
                 before production",
            );
        }
    }
}

/// Pass 4 — plan cross-check (deadlocks, deadlines, topology tails).
fn pass_plan_cross_check(opts: &FutureOpts, facts: &SessionFacts, c: &mut Collector<'_>) {
    if facts.derived
        && !opts.queued
        && !opts.lazy
        && facts.max_workers.is_some()
        && c.wants(LintCode::DeadlockHazard)
    {
        c.emit(
            LintCode::DeadlockHazard,
            "plan",
            format!(
                "blocking create from a worker-side derived session while \
                 SessionLimits::max_workers = {:?} caps the pool the \
                 parent already occupies; if all capped slots hold \
                 blocked parents, no child can ever run",
                facts.max_workers
            ),
            "use FutureOpts::new().queued() (non-blocking admission), \
             make the future lazy, or raise max_workers",
        );
    }
    if c.wants(LintCode::DeadlineHeartbeat) {
        let effective = opts.deadline.or(facts.default_deadline);
        if let Some(d) = effective {
            let hb = crate::liveness::liveness_config().heartbeat_interval;
            if d < hb {
                c.emit(
                    LintCode::DeadlineHeartbeat,
                    "plan",
                    format!(
                        "deadline {}ms is shorter than the liveness \
                         heartbeat interval {}ms; the future can time out \
                         before the worker's first sign of life",
                        d.as_millis(),
                        hb.as_millis()
                    ),
                    "raise the deadline above \
                     LivenessConfig::heartbeat_interval, or lower the \
                     heartbeat interval for latency-critical sessions",
                );
            }
        }
    }
    if facts.depth > 0
        && facts.depth as usize >= facts.topology_levels
        && c.wants(LintCode::TopologyTail)
    {
        c.emit(
            LintCode::TopologyTail,
            "plan",
            format!(
                "create at nesting depth {} but the topology declares \
                 only {} level(s); execution silently falls back to \
                 sequential (nested protection)",
                facts.depth, facts.topology_levels
            ),
            "declare one plan level per intended nesting depth with \
             Session::with_topology, or keep the fallback and silence \
             this lint",
        );
    }
}

/// Satellite pass — explicit/`AutoPlus` capture-list cross-check.
fn pass_capture_typos(expr: &Expr, spec: &GlobalsSpec, c: &mut Collector<'_>) {
    if !c.wants(LintCode::UselessCapture) {
        return;
    }
    let (names, explicit) = match spec {
        GlobalsSpec::Explicit(names) => (names, true),
        GlobalsSpec::AutoPlus(names) => (names, false),
        _ => return,
    };
    let free = free_variables(expr);
    let mut has_dyn = false;
    expr.walk(&mut |e| {
        if matches!(e, Expr::DynLookup(_)) {
            has_dyn = true;
        }
    });
    // A listed name the expression never references statically: with no
    // DynLookup in sight it cannot be reached at all — probable typo.
    if !has_dyn {
        for name in names {
            if !free.contains(name) {
                c.emit(
                    LintCode::UselessCapture,
                    format!("globals['{name}']"),
                    format!(
                        "'{name}' is captured explicitly but the \
                         expression never references it — useless capture \
                         or probable typo"
                    ),
                    "drop the name from the capture list, or fix the \
                     variable reference in the expression",
                );
            }
        }
    }
    // The converse only bites Explicit (AutoPlus still auto-captures):
    // a free variable missing from the list fails at eval time with
    // "object not found" — surface it at creation instead.
    if explicit {
        for name in &free {
            if !names.contains(name) {
                c.emit(
                    LintCode::UselessCapture,
                    format!("globals['{name}']"),
                    format!(
                        "free variable '{name}' is not in the Explicit \
                         capture list; evaluation is guaranteed to fail \
                         with \"object '{name}' not found\""
                    ),
                    "add the name to GlobalsSpec::Explicit, or switch to \
                     GlobalsSpec::Auto",
                );
            }
        }
    }
}

/// Satellite pass — result-cache determinism (`FutureOpts::cached`).
///
/// The cache layer itself refuses to key chaos-marked and unseeded-RNG
/// expressions (they simply evaluate uncached, every time), so nothing
/// here is needed for soundness — the lint exists to make that silent
/// downgrade, and the subtler `get("k")` key-blindness, visible at
/// creation: a key derived from statically-captured globals cannot see a
/// dynamically-named input, so two semantically different futures could
/// collide on one entry.
fn pass_cache_determinism(
    expr: &Expr,
    spec: &GlobalsSpec,
    opts: &FutureOpts,
    c: &mut Collector<'_>,
) {
    if !opts.cached || !c.wants(LintCode::CacheNondeterministic) {
        return;
    }
    if opts.seed.is_none() && expr.uses_rng() {
        c.emit(
            LintCode::CacheNondeterministic,
            "expr",
            "cached future draws random numbers without a seed; its result \
             is not a function of its cache key, so the cache layer will \
             refuse to memoize it (it evaluates uncached every time)"
                .to_string(),
            "pass FutureOpts::new().seed(s) so draws come from a keyed \
             substream, or drop cached() for genuinely random futures",
        );
    }
    let mut has_dyn = false;
    expr.walk(&mut |e| {
        if matches!(e, Expr::DynLookup(_)) {
            has_dyn = true;
        }
    });
    if has_dyn && *spec == GlobalsSpec::Auto {
        c.emit(
            LintCode::CacheNondeterministic,
            "expr",
            "cached future looks up a global by computed name under \
             automatic capture; the cache key is derived from the \
             statically-captured globals and cannot see the dynamic \
             input, so distinct computations may share one cache entry"
                .to_string(),
            "name the dynamic globals with GlobalsSpec::AutoPlus so they \
             enter the captured set (and the key), or drop cached()",
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::value::Tensor;
    use crate::ipc::wire::{enc_expr, Encoder};

    fn facts() -> SessionFacts {
        SessionFacts { topology_levels: 1, ..SessionFacts::default() }
    }

    fn run(
        expr: &Expr,
        spec: &GlobalsSpec,
        opts: &FutureOpts,
        config: &AnalysisConfig,
    ) -> Vec<Diagnostic> {
        let globals = crate::api::globals::identify_globals(expr, &Env::new(), &GlobalsSpec::None)
            .expect("no globals needed");
        lint(expr, &globals, spec, opts, &facts(), config)
    }

    fn codes(diags: &[Diagnostic]) -> Vec<LintCode> {
        diags.iter().map(|d| d.code).collect()
    }

    #[test]
    fn catalog_is_stable_and_distinct() {
        let strs: std::collections::BTreeSet<&str> =
            LintCode::ALL.iter().map(|c| c.as_str()).collect();
        assert_eq!(strs.len(), LintCode::ALL.len());
        assert!(strs.contains("export-size"));
        assert!(strs.contains("useless-capture"));
        assert!(strs.contains("cache-nondeterministic"));
    }

    #[test]
    fn default_severities_match_design_doc() {
        let c = AnalysisConfig::default();
        assert_eq!(c.action(LintCode::ExportSize), Severity::Deny);
        assert_eq!(c.action(LintCode::UnseededRng), Severity::Allow);
        assert_eq!(c.action(LintCode::DuplicateRngStream), Severity::Warn);
        assert_eq!(c.action(LintCode::ChaosInjection), Severity::Allow);
        assert_eq!(c.action(LintCode::TopologyTail), Severity::Allow);
        assert_eq!(c.action(LintCode::CacheNondeterministic), Severity::Warn);
        let hardened = AnalysisConfig::hardened();
        assert_eq!(hardened.action(LintCode::ChaosInjection), Severity::Deny);
        assert_eq!(hardened.action(LintCode::UnseededRng), Severity::Warn);
        assert_eq!(hardened.action(LintCode::CacheNondeterministic), Severity::Deny);
        let overridden = AnalysisConfig::new().deny(LintCode::DynLookup);
        assert_eq!(overridden.action(LintCode::DynLookup), Severity::Deny);
    }

    #[test]
    fn export_audit_fires_over_budget_only() {
        let mut env = Env::new();
        env.insert("t", Tensor::new(vec![256], vec![1.0f32; 256]).unwrap());
        let expr = Expr::prim(crate::api::expr::PrimOp::Sum, vec![Expr::var("t")]);
        let config = AnalysisConfig::new().max_globals_size(64);
        let diags = lint(
            &expr,
            &env,
            &GlobalsSpec::Auto,
            &FutureOpts::new(),
            &facts(),
            &config,
        );
        assert!(codes(&diags).contains(&LintCode::ExportSize), "{diags:?}");
        let roomy = AnalysisConfig::new().max_globals_size(1 << 20);
        let diags = lint(&expr, &env, &GlobalsSpec::Auto, &FutureOpts::new(), &facts(), &roomy);
        assert!(!codes(&diags).contains(&LintCode::ExportSize), "{diags:?}");
    }

    #[test]
    fn estimate_dominates_wire_encoding_for_a_nasty_expr() {
        let expr = Expr::let_in(
            "x",
            Expr::lit(Value::List(vec![
                Value::Str("abc".into()),
                Value::Tensor(Tensor::new(vec![2, 3], vec![0.0; 6]).unwrap()),
            ])),
            Expr::seq(vec![
                Expr::with_rng_stream(3, Expr::runif_shaped(vec![2, 2, 2])),
                Expr::chaos_hang_once(5, "m"),
                Expr::var("x"),
            ]),
        );
        let mut enc = Encoder::new();
        enc_expr(&mut enc, &expr);
        let bytes = enc.into_bytes().len();
        let est = estimate_export_size(&expr, &Env::new());
        assert!(est >= bytes, "estimate {est} under-counts wire {bytes}");
    }

    #[test]
    fn rng_hygiene_unseeded_unused_and_duplicates() {
        let draws = Expr::runif(4);
        let diags = run(&draws, &GlobalsSpec::Auto, &FutureOpts::new(), &AnalysisConfig::new());
        assert!(codes(&diags).contains(&LintCode::UnseededRng));
        let diags = run(
            &Expr::lit(1i64),
            &GlobalsSpec::Auto,
            &FutureOpts::new().seed(7),
            &AnalysisConfig::new(),
        );
        assert!(codes(&diags).contains(&LintCode::UnusedSeed));
        let dup = Expr::list(vec![
            Expr::with_rng_stream(7, Expr::runif(2)),
            Expr::with_rng_stream(7, Expr::runif(2)),
        ]);
        let diags =
            run(&dup, &GlobalsSpec::Auto, &FutureOpts::new().seed(1), &AnalysisConfig::new());
        let dup_diag = diags.iter().find(|d| d.code == LintCode::DuplicateRngStream);
        assert!(dup_diag.is_some(), "{diags:?}");
        assert_eq!(dup_diag.unwrap().path, "expr.with_rng_stream[7]");
        let distinct = Expr::list(vec![
            Expr::with_rng_stream(1, Expr::runif(2)),
            Expr::with_rng_stream(2, Expr::runif(2)),
        ]);
        let diags =
            run(&distinct, &GlobalsSpec::Auto, &FutureOpts::new().seed(1), &AnalysisConfig::new());
        assert!(!codes(&diags).contains(&LintCode::DuplicateRngStream));
    }

    #[test]
    fn dyn_lookup_flagged_only_under_auto() {
        let expr = Expr::dyn_lookup(Expr::lit("k"));
        let diags = run(&expr, &GlobalsSpec::Auto, &FutureOpts::new(), &AnalysisConfig::new());
        let d = diags.iter().find(|d| d.code == LintCode::DynLookup).expect("flagged");
        assert!(d.help.contains("AutoPlus"), "help must name the paper's fix: {}", d.help);
        let fixed = GlobalsSpec::AutoPlus(vec!["k".to_string()]);
        let diags = run(&expr, &fixed, &FutureOpts::new(), &AnalysisConfig::new());
        assert!(!codes(&diags).contains(&LintCode::DynLookup), "{diags:?}");
    }

    #[test]
    fn chaos_denied_only_when_disarmed() {
        let expr = Expr::chaos_kill();
        let armed = run(&expr, &GlobalsSpec::Auto, &FutureOpts::new(), &AnalysisConfig::new());
        let d = armed.iter().find(|d| d.code == LintCode::ChaosInjection).expect("visible in lint");
        assert_eq!(d.severity, Severity::Allow);
        let disarmed =
            run(&expr, &GlobalsSpec::Auto, &FutureOpts::new(), &AnalysisConfig::hardened());
        let d = disarmed.iter().find(|d| d.code == LintCode::ChaosInjection).expect("flagged");
        assert_eq!(d.severity, Severity::Deny);
        // Enforcement path: armed config emits nothing for chaos.
        let enforced = analyze(
            &expr,
            &Env::new(),
            &GlobalsSpec::Auto,
            &FutureOpts::new(),
            &facts(),
            &AnalysisConfig::new(),
        );
        assert!(!codes(&enforced).contains(&LintCode::ChaosInjection));
    }

    #[test]
    fn plan_cross_check_shapes() {
        let expr = Expr::lit(1i64);
        let hazard = SessionFacts {
            derived: true,
            max_workers: Some(2),
            topology_levels: 1,
            ..SessionFacts::default()
        };
        let diags = lint(
            &expr,
            &Env::new(),
            &GlobalsSpec::Auto,
            &FutureOpts::new(),
            &hazard,
            &AnalysisConfig::new(),
        );
        assert!(codes(&diags).contains(&LintCode::DeadlockHazard), "{diags:?}");
        // queued() admission defuses the hazard.
        let diags = lint(
            &expr,
            &Env::new(),
            &GlobalsSpec::Auto,
            &FutureOpts::new().queued(),
            &hazard,
            &AnalysisConfig::new(),
        );
        assert!(!codes(&diags).contains(&LintCode::DeadlockHazard), "{diags:?}");

        let opts = FutureOpts::new().deadline(Duration::from_millis(1));
        let diags =
            lint(&expr, &Env::new(), &GlobalsSpec::Auto, &opts, &facts(), &AnalysisConfig::new());
        assert!(codes(&diags).contains(&LintCode::DeadlineHeartbeat), "{diags:?}");

        let tail = SessionFacts { depth: 2, topology_levels: 1, ..SessionFacts::default() };
        let diags = lint(
            &expr,
            &Env::new(),
            &GlobalsSpec::Auto,
            &FutureOpts::new(),
            &tail,
            &AnalysisConfig::new(),
        );
        let d = diags.iter().find(|d| d.code == LintCode::TopologyTail).expect("flagged");
        assert_eq!(d.severity, Severity::Allow);
    }

    #[test]
    fn capture_typos_both_directions() {
        let expr = Expr::add(Expr::var("weights"), Expr::lit(1.0));
        // Misspelled explicit name: useless capture AND missing free var.
        let spec = GlobalsSpec::Explicit(vec!["wieghts".to_string()]);
        let diags = run(&expr, &spec, &FutureOpts::new(), &AnalysisConfig::new());
        let hits: Vec<&Diagnostic> =
            diags.iter().filter(|d| d.code == LintCode::UselessCapture).collect();
        assert_eq!(hits.len(), 2, "{diags:?}");
        assert!(hits.iter().any(|d| d.path == "globals['wieghts']"));
        assert!(hits.iter().any(|d| d.path == "globals['weights']"));
        // AutoPlus extra with a DynLookup present is the documented fix,
        // not a typo.
        let dyn_expr = Expr::dyn_lookup(Expr::lit("k"));
        let spec = GlobalsSpec::AutoPlus(vec!["k".to_string()]);
        let diags = run(&dyn_expr, &spec, &FutureOpts::new(), &AnalysisConfig::new());
        assert!(!codes(&diags).contains(&LintCode::UselessCapture), "{diags:?}");
        // Correct explicit list is clean.
        let spec = GlobalsSpec::Explicit(vec!["weights".to_string()]);
        let diags = run(&expr, &spec, &FutureOpts::new(), &AnalysisConfig::new());
        assert!(!codes(&diags).contains(&LintCode::UselessCapture), "{diags:?}");
    }

    #[test]
    fn analyze_filters_allowed_lint_keeps_them() {
        let expr = Expr::runif(2); // unseeded → Allow by default
        let all = lint(
            &expr,
            &Env::new(),
            &GlobalsSpec::Auto,
            &FutureOpts::new(),
            &facts(),
            &AnalysisConfig::new(),
        );
        assert!(codes(&all).contains(&LintCode::UnseededRng));
        let enforced = analyze(
            &expr,
            &Env::new(),
            &GlobalsSpec::Auto,
            &FutureOpts::new(),
            &facts(),
            &AnalysisConfig::new(),
        );
        assert!(enforced.is_empty(), "{enforced:?}");
    }

    #[test]
    fn cache_nondeterminism_fires_only_for_cached_futures() {
        let mut cached = FutureOpts::new();
        cached.cached = true;
        // Unseeded draws under cached(): flagged.
        let rng = Expr::runif(2);
        let diags = run(&rng, &GlobalsSpec::Auto, &cached, &AnalysisConfig::new());
        let d = diags
            .iter()
            .find(|d| d.code == LintCode::CacheNondeterministic)
            .expect("unseeded cached RNG must be flagged");
        assert_eq!(d.severity, Severity::Warn);
        // Seeding fixes it.
        let mut seeded = cached.clone();
        seeded.seed = Some(7);
        let diags = run(&rng, &GlobalsSpec::Auto, &seeded, &AnalysisConfig::new());
        assert!(!codes(&diags).contains(&LintCode::CacheNondeterministic), "{diags:?}");
        // Same expression without cached(): not this lint's business.
        let diags = run(&rng, &GlobalsSpec::Auto, &FutureOpts::new(), &AnalysisConfig::new());
        assert!(!codes(&diags).contains(&LintCode::CacheNondeterministic), "{diags:?}");
        // DynLookup under Auto: key-blind input → flagged; AutoPlus fixes.
        let dyn_expr = Expr::dyn_lookup(Expr::lit("k"));
        let diags = run(&dyn_expr, &GlobalsSpec::Auto, &cached, &AnalysisConfig::new());
        assert!(codes(&diags).contains(&LintCode::CacheNondeterministic), "{diags:?}");
        let fixed = GlobalsSpec::AutoPlus(vec!["k".to_string()]);
        let diags = run(&dyn_expr, &fixed, &cached, &AnalysisConfig::new());
        assert!(!codes(&diags).contains(&LintCode::CacheNondeterministic), "{diags:?}");
        // hardened() denies.
        let diags = run(&rng, &GlobalsSpec::Auto, &cached, &AnalysisConfig::hardened());
        let d = diags
            .iter()
            .find(|d| d.code == LintCode::CacheNondeterministic)
            .expect("flagged under hardened");
        assert_eq!(d.severity, Severity::Deny);
    }

    #[test]
    fn diagnostic_display_is_greppable() {
        let d = Diagnostic {
            code: LintCode::ExportSize,
            severity: Severity::Deny,
            path: "globals".to_string(),
            message: "too big".to_string(),
            help: "shrink it".to_string(),
        };
        let s = d.to_string();
        assert!(s.contains("export-size") && s.contains("deny") && s.contains("shrink it"), "{s}");
    }
}
