//! Capture and relay of standard output and conditions.
//!
//! Futures capture stdout and all conditions (messages, warnings) on the
//! worker and relay them in the main process when `value()` is called,
//! preserving the paper's ordering contract:
//!
//! 1. all captured **stdout** is relayed first, then
//! 2. conditions are relayed **in the order they were signaled**;
//! 3. `immediateCondition`s (progress updates) are exempt — they may be
//!    relayed as soon as the backend can transport them, out of order with
//!    everything else.

use std::fmt;
use std::sync::Mutex;

/// Kinds of captured conditions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ConditionKind {
    /// `message()` — diagnostic message (R sends to stderr; the condition
    /// object is captured, not the stream).
    Message,
    /// `warning()`.
    Warning,
    /// An `immediateCondition` — relayed ASAP when the backend supports it.
    Immediate,
}

/// A captured condition, tagged with its signal order.
#[derive(Debug, Clone, PartialEq)]
pub struct Condition {
    pub kind: ConditionKind,
    pub message: String,
    /// Monotone per-future sequence number assigned at capture.
    pub seq: u64,
}

impl fmt::Display for Condition {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.kind {
            ConditionKind::Message => write!(f, "{}", self.message),
            ConditionKind::Warning => write!(f, "Warning message:\n{}", self.message),
            ConditionKind::Immediate => write!(f, "[progress] {}", self.message),
        }
    }
}

/// Worker-side capture buffer: accumulates stdout and conditions during
/// evaluation of one future.
#[derive(Debug, Default)]
pub struct CaptureBuffer {
    stdout: String,
    conditions: Vec<Condition>,
    seq: u64,
    /// Immediate conditions ready to be drained out-of-band by backends
    /// that support live relay.
    immediate_pending: Vec<Condition>,
    /// Whether the expression drew from the RNG (for the misuse warning).
    pub rng_used: bool,
}

impl CaptureBuffer {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn capture_stdout(&mut self, text: &str) {
        self.stdout.push_str(text);
    }

    pub fn signal(&mut self, kind: ConditionKind, message: impl Into<String>) {
        let c = Condition { kind, message: message.into(), seq: self.seq };
        self.seq += 1;
        if kind == ConditionKind::Immediate {
            self.immediate_pending.push(c.clone());
        }
        self.conditions.push(c);
    }

    /// Drain immediates signaled since the last drain (for live relay).
    pub fn drain_immediate(&mut self) -> Vec<Condition> {
        std::mem::take(&mut self.immediate_pending)
    }

    /// Finish capture, producing the relay payload.
    pub fn finish(self) -> Captured {
        Captured { stdout: self.stdout, conditions: self.conditions, rng_used: self.rng_used }
    }
}

/// Everything captured while resolving one future.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Captured {
    pub stdout: String,
    pub conditions: Vec<Condition>,
    pub rng_used: bool,
}

impl Captured {
    /// Relay order per the paper: stdout first, then conditions by `seq`.
    /// Immediates already relayed live are excluded when
    /// `skip_immediate` is set (supporting backends).
    pub fn relay_order(&self, skip_immediate: bool) -> Vec<&Condition> {
        let mut out: Vec<&Condition> = self
            .conditions
            .iter()
            .filter(|c| !(skip_immediate && c.kind == ConditionKind::Immediate))
            .collect();
        out.sort_by_key(|c| c.seq);
        out
    }
}

/// Where relayed output/conditions go in the main process.  The default
/// sink prints like R does; tests install a recording sink.
pub trait ConditionSink: Send {
    fn stdout(&mut self, text: &str);
    fn condition(&mut self, c: &Condition);
}

/// Prints stdout to stdout and conditions to stderr (R-like).
pub struct StdSink;

impl ConditionSink for StdSink {
    fn stdout(&mut self, text: &str) {
        print!("{text}");
    }

    fn condition(&mut self, c: &Condition) {
        eprintln!("{c}");
    }
}

/// Records everything (used by tests and by `capture.output()`-style APIs).
/// Clone it before installing to keep a handle on the shared buffers.
#[derive(Default, Clone)]
pub struct RecordingSink {
    inner: std::sync::Arc<Mutex<RecordingInner>>,
}

#[derive(Default)]
struct RecordingInner {
    stdout: String,
    conditions: Vec<Condition>,
}

impl RecordingSink {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn stdout_text(&self) -> String {
        self.inner.lock().unwrap().stdout.clone()
    }

    pub fn conditions(&self) -> Vec<Condition> {
        self.inner.lock().unwrap().conditions.clone()
    }
}

impl ConditionSink for RecordingSink {
    fn stdout(&mut self, text: &str) {
        self.inner.lock().unwrap().stdout.push_str(text);
    }

    fn condition(&mut self, c: &Condition) {
        self.inner.lock().unwrap().conditions.push(c.clone());
    }
}

/// Process-global relay sink (what `value()` writes to).
static SINK: Mutex<Option<Box<dyn ConditionSink>>> = Mutex::new(None);

/// Install a custom sink; returns the previous one.  Passing `None`
/// restores the default [`StdSink`].
pub fn set_sink(sink: Option<Box<dyn ConditionSink>>) -> Option<Box<dyn ConditionSink>> {
    let mut guard = SINK.lock().unwrap();
    std::mem::replace(&mut *guard, sink)
}

/// Relay one captured payload through the installed sink (or StdSink),
/// honoring the ordering contract.
pub fn relay(captured: &Captured, skip_immediate: bool) {
    let mut guard = SINK.lock().unwrap();
    match guard.as_mut() {
        Some(sink) => do_relay(sink.as_mut(), captured, skip_immediate),
        None => do_relay(&mut StdSink, captured, skip_immediate),
    }
}

/// Relay a single immediate condition (live path).
pub fn relay_immediate(c: &Condition) {
    let mut guard = SINK.lock().unwrap();
    match guard.as_mut() {
        Some(sink) => sink.condition(c),
        None => StdSink.condition(c),
    }
}

fn do_relay(sink: &mut dyn ConditionSink, captured: &Captured, skip_immediate: bool) {
    if !captured.stdout.is_empty() {
        sink.stdout(&captured.stdout);
    }
    for c in captured.relay_order(skip_immediate) {
        sink.condition(c);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capture_preserves_signal_order() {
        let mut buf = CaptureBuffer::new();
        buf.capture_stdout("Hello world\n");
        buf.signal(ConditionKind::Message, "The sum of 'x' is 55");
        buf.signal(ConditionKind::Warning, "Missing values were omitted");
        buf.capture_stdout("Bye bye\n");
        let captured = buf.finish();

        // stdout is concatenated regardless of interleaving...
        assert_eq!(captured.stdout, "Hello world\nBye bye\n");
        // ...and conditions keep signal order.
        let order = captured.relay_order(false);
        assert_eq!(order.len(), 2);
        assert_eq!(order[0].kind, ConditionKind::Message);
        assert_eq!(order[1].kind, ConditionKind::Warning);
    }

    #[test]
    fn immediates_drain_out_of_band() {
        let mut buf = CaptureBuffer::new();
        buf.signal(ConditionKind::Immediate, "10%");
        buf.signal(ConditionKind::Message, "working");
        buf.signal(ConditionKind::Immediate, "20%");
        let drained = buf.drain_immediate();
        assert_eq!(drained.len(), 2);
        assert_eq!(drained[0].message, "10%");
        // Draining again yields nothing new.
        assert!(buf.drain_immediate().is_empty());
        // With skip_immediate, the final relay excludes them.
        let captured = buf.finish();
        assert_eq!(captured.relay_order(true).len(), 1);
        // Non-supporting backends relay them at the end, in order.
        assert_eq!(captured.relay_order(false).len(), 3);
    }

    #[test]
    fn relay_goes_through_installed_sink() {
        let mut buf = CaptureBuffer::new();
        buf.capture_stdout("out");
        buf.signal(ConditionKind::Warning, "w1");
        let captured = buf.finish();

        let rec = RecordingSink::new();
        set_sink(Some(Box::new(rec.clone())));
        relay(&captured, false);
        set_sink(None);
        assert_eq!(rec.stdout_text(), "out");
        assert_eq!(rec.conditions().len(), 1);
        assert_eq!(rec.conditions()[0].message, "w1");
    }
}
