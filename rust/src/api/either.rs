//! `future_either(...)` — Hewitt & Baker's `(EITHER ...)` construct.
//!
//! "Evaluates the expressions in parallel and returns the value of the first
//! one that finishes", ignoring (and best-effort cancelling) the others.
//! The paper sketches `future_either(sort shell, sort quick, sort radix)`;
//! here any set of expressions races on the current plan.

use crate::api::env::Env;
use crate::api::error::FutureError;
use crate::api::expr::Expr;
use crate::api::future::{future_with, Future, FutureOpts, FutureSet};
use crate::api::value::Value;

/// Race `exprs`; return the value of the first to resolve.
///
/// Losers are cancelled best-effort (the paper's "suspend" future-work item;
/// supported natively by the process backends, a no-op on thread backends).
pub fn future_either(exprs: Vec<Expr>, env: &Env) -> Result<Value, FutureError> {
    future_either_with(exprs, env, FutureOpts::new())
}

/// [`future_either`] with shared options (e.g. a seed).
pub fn future_either_with(
    exprs: Vec<Expr>,
    env: &Env,
    opts: FutureOpts,
) -> Result<Value, FutureError> {
    if exprs.is_empty() {
        return Err(FutureError::Launch("future_either: no expressions".into()));
    }
    let futures: Vec<Future> = exprs
        .into_iter()
        .map(|e| future_with(e, env, opts.clone()))
        .collect::<Result<_, _>>()?;

    // Wait for the first resolution on the shared completion channel — no
    // polling.  Sequential plans resolve eagerly, so index 0 wins
    // immediately there (same as R: already-resolved futures report first,
    // in input order).
    let winner = FutureSet::new(&futures).wait_any().expect("non-empty race");
    // Cancel the rest before collecting.
    for (j, g) in futures.iter().enumerate() {
        if j != winner {
            g.cancel();
        }
    }
    futures[winner].value()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::plan::{with_plan, PlanSpec};

    #[test]
    fn returns_a_winner_sequential() {
        with_plan(PlanSpec::sequential(), || {
            let env = Env::new();
            let v = future_either(
                vec![Expr::lit(1i64), Expr::lit(2i64)],
                &env,
            )
            .unwrap();
            assert_eq!(v, Value::I64(1)); // sequential: first expression wins
        });
    }

    #[test]
    fn fast_racer_beats_slow_on_threads() {
        with_plan(PlanSpec::multicore(2), || {
            let env = Env::new();
            let v = future_either(
                vec![
                    Expr::seq(vec![Expr::Spin { millis: 300 }, Expr::lit("slow")]),
                    Expr::lit("fast"),
                ],
                &env,
            )
            .unwrap();
            assert_eq!(v, Value::Str("fast".into()));
        });
    }

    #[test]
    fn empty_race_is_an_error() {
        with_plan(PlanSpec::sequential(), || {
            let env = Env::new();
            assert!(future_either(vec![], &env).is_err());
        });
    }

    #[test]
    fn race_runs_on_the_scoped_session() {
        // The session-first contract: inside session.scope, the race uses
        // that session's plan — no global plan mutation required.
        let s = crate::api::session::Session::with_plan(PlanSpec::multicore(2));
        let env = Env::new();
        let v = s.scope(|_| {
            future_either(
                vec![
                    Expr::seq(vec![Expr::Spin { millis: 200 }, Expr::lit("slow")]),
                    Expr::lit("fast"),
                ],
                &env,
            )
            .unwrap()
        });
        assert_eq!(v, Value::Str("fast".into()));
        s.close();
    }
}
