//! [`Env`] — the caller's workspace, from which globals are captured.
//!
//! A future records its required globals *at creation time* (the paper's
//! `x <- 1; f <- future(slow_fcn(x)); x <- 2` example: the future sees 1).
//! `Env` models the R workspace: a mutable name→[`Value`] map the user
//! assigns into, from which [`crate::api::globals::identify_globals`] snapshots
//! exactly the bindings a future expression needs.

use std::collections::BTreeMap;

use crate::api::value::Value;

/// A mutable variable workspace.  BTreeMap keeps iteration deterministic
/// (serialization, digests, tests).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Env {
    bindings: BTreeMap<String, Value>,
}

impl Env {
    pub fn new() -> Self {
        Env::default()
    }

    /// Assign a variable (R's `name <- value`).
    pub fn insert(&mut self, name: &str, value: impl Into<Value>) {
        self.bindings.insert(name.to_string(), value.into());
    }

    pub fn get(&self, name: &str) -> Option<&Value> {
        self.bindings.get(name)
    }

    pub fn contains(&self, name: &str) -> bool {
        self.bindings.contains_key(name)
    }

    pub fn remove(&mut self, name: &str) -> Option<Value> {
        self.bindings.remove(name)
    }

    pub fn len(&self) -> usize {
        self.bindings.len()
    }

    pub fn is_empty(&self) -> bool {
        self.bindings.is_empty()
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.bindings.keys().map(String::as_str)
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, &Value)> {
        self.bindings.iter().map(|(k, v)| (k.as_str(), v))
    }

    /// Snapshot a subset of bindings (the captured globals of a future).
    /// Names absent from the env are skipped — the globals analysis reports
    /// them separately so the caller can decide (optimistic strategy).
    pub fn subset(&self, names: &[String]) -> Env {
        let mut out = Env::new();
        for n in names {
            if let Some(v) = self.bindings.get(n) {
                out.bindings.insert(n.clone(), v.clone());
            }
        }
        out
    }

    /// Merge `other` into `self`, `other` winning on conflicts.
    pub fn extend(&mut self, other: &Env) {
        for (k, v) in other.iter() {
            self.bindings.insert(k.to_string(), v.clone());
        }
    }

    /// Total payload size of all bindings (transfer accounting).
    pub fn byte_size(&self) -> usize {
        self.bindings.iter().map(|(k, v)| k.len() + v.byte_size()).sum()
    }
}

impl FromIterator<(String, Value)> for Env {
    fn from_iter<T: IntoIterator<Item = (String, Value)>>(iter: T) -> Self {
        Env { bindings: iter.into_iter().collect() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_get_roundtrip() {
        let mut env = Env::new();
        env.insert("x", 1.5);
        env.insert("s", "hello");
        assert_eq!(env.get("x"), Some(&Value::F64(1.5)));
        assert_eq!(env.get("s").and_then(Value::as_str), Some("hello"));
        assert!(env.get("missing").is_none());
        assert_eq!(env.len(), 2);
    }

    #[test]
    fn subset_skips_missing_names() {
        let mut env = Env::new();
        env.insert("a", 1i64);
        env.insert("b", 2i64);
        let sub = env.subset(&["a".to_string(), "zzz".to_string()]);
        assert_eq!(sub.len(), 1);
        assert!(sub.contains("a"));
    }

    #[test]
    fn snapshot_is_independent_of_later_mutation() {
        // The core creation-time capture invariant from the paper.
        let mut env = Env::new();
        env.insert("x", 1i64);
        let snap = env.subset(&["x".to_string()]);
        env.insert("x", 2i64);
        assert_eq!(snap.get("x"), Some(&Value::I64(1)));
        assert_eq!(env.get("x"), Some(&Value::I64(2)));
    }

    #[test]
    fn extend_overwrites() {
        let mut a = Env::new();
        a.insert("x", 1i64);
        let mut b = Env::new();
        b.insert("x", 9i64);
        b.insert("y", 2i64);
        a.extend(&b);
        assert_eq!(a.get("x"), Some(&Value::I64(9)));
        assert_eq!(a.len(), 2);
    }
}
