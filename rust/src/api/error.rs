//! The exception taxonomy.
//!
//! The paper distinguishes two kinds of failures:
//!
//! * **Evaluation errors** ([`EvalError`]) — produced by the future's own
//!   expression (R's `stop()`, type errors...).  They are captured on the
//!   worker and *relayed as-is* in the main process when `value()` is
//!   called, so `tryCatch`-style handling works unchanged.
//! * **[`FutureError`]s** — "errors due to extraordinary circumstances,
//!   such as terminated R workers and failed communication", plus
//!   creation-time failures (missing globals).  These are signaled as a
//!   distinct class so callers can restart workers or relaunch futures.

use thiserror::Error;

/// An error produced while *evaluating* a future's expression — relayed
/// verbatim to the caller of `value()`, mimicking non-future behaviour.
#[derive(Debug, Clone, PartialEq, Error)]
#[error("{message}")]
pub struct EvalError {
    /// The error message, exactly as signaled on the worker.
    pub message: String,
    /// Rendered call/expression context, when available.
    pub call: Option<String>,
}

impl EvalError {
    pub fn new(message: impl Into<String>) -> Self {
        EvalError { message: message.into(), call: None }
    }

    pub fn with_call(message: impl Into<String>, call: impl Into<String>) -> Self {
        EvalError { message: message.into(), call: Some(call.into()) }
    }
}

/// Infrastructure-level failures of the future framework itself —
/// the paper's *FutureError* class.
#[derive(Debug, Error)]
pub enum FutureError {
    /// Static analysis (or explicit spec) referenced a variable absent from
    /// the calling environment at creation time.
    #[error("object '{name}' not found (missing global at future creation)")]
    MissingGlobal { name: String },

    /// The worker process/thread died before resolving the future.
    #[error("FutureError: worker terminated unexpectedly{}", detail_fmt(.detail))]
    WorkerDied { detail: String },

    /// Communication with a worker failed (broken pipe/socket, bad frame).
    #[error("FutureError: communication with worker failed: {0}")]
    Channel(String),

    /// Backend could not launch the future (pool shut down, scheduler
    /// rejected the job, ...).
    #[error("FutureError: could not launch future: {0}")]
    Launch(String),

    /// The requested plan/backend is invalid or unavailable.
    #[error("FutureError: invalid plan: {0}")]
    InvalidPlan(String),

    /// PJRT runtime failure (artifact missing, compile error, bad shapes).
    #[error("FutureError: runtime: {0}")]
    Runtime(String),

    /// The future was cancelled before it resolved (extension feature;
    /// `suspend()`/cancellation is "Future work" in the paper).
    #[error("FutureError: future was cancelled")]
    Cancelled,

    /// An evaluation error relayed through `value()`.  Kept in this enum so
    /// `value()` has a single error type; pattern-match to distinguish —
    /// everything else is an infrastructure failure.
    #[error("{0}")]
    Eval(#[from] EvalError),
}

fn detail_fmt(detail: &str) -> String {
    if detail.is_empty() {
        String::new()
    } else {
        format!(": {detail}")
    }
}

impl FutureError {
    /// True when this is a relayed *evaluation* error (the user's code
    /// failed), false for framework/infrastructure failures.
    pub fn is_eval(&self) -> bool {
        matches!(self, FutureError::Eval(_))
    }

    /// True for failures where relaunching the future elsewhere could
    /// succeed (the paper's motivation for the distinct FutureError class).
    pub fn is_recoverable(&self) -> bool {
        matches!(
            self,
            FutureError::WorkerDied { .. }
                | FutureError::Channel(_)
                | FutureError::Launch(_)
                | FutureError::Cancelled
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_error_displays_message_as_is() {
        let e = EvalError::new("non-numeric argument to mathematical function");
        assert_eq!(e.to_string(), "non-numeric argument to mathematical function");
    }

    #[test]
    fn taxonomy_separates_eval_from_infrastructure() {
        let eval: FutureError = EvalError::new("boom").into();
        assert!(eval.is_eval());
        assert!(!eval.is_recoverable());

        let died = FutureError::WorkerDied { detail: "signal 9".into() };
        assert!(!died.is_eval());
        assert!(died.is_recoverable());

        let plan = FutureError::InvalidPlan("no such backend".into());
        assert!(!plan.is_eval());
        assert!(!plan.is_recoverable());
    }

    #[test]
    fn worker_died_formats_detail() {
        let e = FutureError::WorkerDied { detail: String::new() };
        assert_eq!(e.to_string(), "FutureError: worker terminated unexpectedly");
        let e = FutureError::WorkerDied { detail: "exit 137".into() };
        assert!(e.to_string().ends_with(": exit 137"));
    }
}
