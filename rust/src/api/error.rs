//! The exception taxonomy.
//!
//! The paper distinguishes two kinds of failures:
//!
//! * **Evaluation errors** ([`EvalError`]) — produced by the future's own
//!   expression (R's `stop()`, type errors...).  They are captured on the
//!   worker and *relayed as-is* in the main process when `value()` is
//!   called, so `tryCatch`-style handling works unchanged.
//! * **[`FutureError`]s** — "errors due to extraordinary circumstances,
//!   such as terminated R workers and failed communication", plus
//!   creation-time failures (missing globals).  These are signaled as a
//!   distinct class so callers can restart workers or relaunch futures.
//!
//! (`thiserror` is unavailable in this offline image, so the `Display` and
//! `Error` impls are written by hand.)

use std::fmt;

/// An error produced while *evaluating* a future's expression — relayed
/// verbatim to the caller of `value()`, mimicking non-future behaviour.
#[derive(Debug, Clone, PartialEq)]
pub struct EvalError {
    /// The error message, exactly as signaled on the worker.
    pub message: String,
    /// Rendered call/expression context, when available.
    pub call: Option<String>,
}

impl EvalError {
    pub fn new(message: impl Into<String>) -> Self {
        EvalError { message: message.into(), call: None }
    }

    pub fn with_call(message: impl Into<String>, call: impl Into<String>) -> Self {
        EvalError { message: message.into(), call: Some(call.into()) }
    }
}

impl fmt::Display for EvalError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.message)
    }
}

impl std::error::Error for EvalError {}

/// Infrastructure-level failures of the future framework itself —
/// the paper's *FutureError* class.
///
/// `Clone` so a [`crate::api::future::Future`] can store a terminal failure
/// and replay the *same* error (kind included) on every later
/// `resolved()`/`value()` call.
#[derive(Debug, Clone)]
pub enum FutureError {
    /// Static analysis (or explicit spec) referenced a variable absent from
    /// the calling environment at creation time.
    MissingGlobal { name: String },

    /// The worker process/thread died before resolving the future.
    WorkerDied { detail: String },

    /// Communication with a worker failed (broken pipe/socket, bad frame).
    Channel(String),

    /// Backend could not launch the future (pool shut down, scheduler
    /// rejected the job, ...).
    Launch(String),

    /// The requested plan/backend is invalid or unavailable.
    InvalidPlan(String),

    /// PJRT runtime failure (artifact missing, compile error, bad shapes).
    Runtime(String),

    /// The future was cancelled before it resolved (extension feature;
    /// `suspend()`/cancellation is "Future work" in the paper).
    Cancelled,

    /// The future's deadline expired before it resolved.  `elapsed` is how
    /// long the caller actually waited; `attempts` is how many launches the
    /// supervisor made before the clock ran out.  The in-flight attempt is
    /// *cancelled* on expiry (seat freed), not abandoned — and latched
    /// terminally: every later `resolved()`/`value()` replays this error.
    TimedOut { elapsed: std::time::Duration, attempts: u32 },

    /// The future's owning [`crate::api::session::Session`] was closed
    /// before the future resolved.  Latched terminally: every later
    /// `resolved()`/`value()` replays the same error — a closed session's
    /// backends are gone, so the future can never complete.
    SessionClosed { session: u64 },

    /// A supervised future was resubmitted after infrastructure loss and
    /// still failed: `attempts` total attempts were made (including the
    /// original submission); `last` is the final attempt's failure.
    /// Produced by [`crate::backend::supervisor::SupervisedHandle`] when a
    /// [`crate::backend::supervisor::RetryPolicy`] budget is exhausted —
    /// structured provenance, so callers can tell "failed once" from
    /// "failed N times on N different workers".
    Retried { attempts: u32, last: Box<FutureError> },

    /// Plan-time static analysis refused to create the future: at least
    /// one lint resolved to `Deny` under the session's
    /// [`crate::analysis::AnalysisConfig`].  Raised *before* any capacity
    /// lease is taken or any worker is contacted, so a rejected future
    /// costs nothing but the analysis itself.  Carries every denied
    /// diagnostic (code, path, message, help).
    Rejected { diagnostics: Vec<crate::analysis::Diagnostic> },

    /// An evaluation error relayed through `value()`.  Kept in this enum so
    /// `value()` has a single error type; pattern-match to distinguish —
    /// everything else is an infrastructure failure.
    Eval(EvalError),
}

impl fmt::Display for FutureError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FutureError::MissingGlobal { name } => {
                write!(f, "object '{name}' not found (missing global at future creation)")
            }
            FutureError::WorkerDied { detail } => {
                write!(f, "FutureError: worker terminated unexpectedly{}", detail_fmt(detail))
            }
            FutureError::Channel(m) => {
                write!(f, "FutureError: communication with worker failed: {m}")
            }
            FutureError::Launch(m) => write!(f, "FutureError: could not launch future: {m}"),
            FutureError::InvalidPlan(m) => write!(f, "FutureError: invalid plan: {m}"),
            FutureError::Runtime(m) => write!(f, "FutureError: runtime: {m}"),
            FutureError::Cancelled => write!(f, "FutureError: future was cancelled"),
            FutureError::TimedOut { elapsed, attempts } => {
                write!(
                    f,
                    "FutureError: future timed out after {:.3}s ({attempts} attempt{})",
                    elapsed.as_secs_f64(),
                    if *attempts == 1 { "" } else { "s" }
                )
            }
            FutureError::SessionClosed { session } => {
                write!(
                    f,
                    "FutureError: session {session} was closed before the future resolved"
                )
            }
            FutureError::Retried { attempts, last } => {
                write!(f, "FutureError: failed after {attempts} attempts (retry exhausted): {last}")
            }
            FutureError::Rejected { diagnostics } => {
                let codes: Vec<&str> =
                    diagnostics.iter().map(|d| d.code.as_str()).collect();
                write!(
                    f,
                    "FutureError: rejected by static analysis [{}]",
                    codes.join(", ")
                )?;
                if let Some(first) = diagnostics.first() {
                    write!(f, ": {} (help: {})", first.message, first.help)?;
                }
                Ok(())
            }
            FutureError::Eval(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for FutureError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            FutureError::Eval(e) => Some(e),
            FutureError::Retried { last, .. } => Some(&**last),
            _ => None,
        }
    }
}

impl From<EvalError> for FutureError {
    fn from(e: EvalError) -> Self {
        FutureError::Eval(e)
    }
}

fn detail_fmt(detail: &str) -> String {
    if detail.is_empty() {
        String::new()
    } else {
        format!(": {detail}")
    }
}

impl FutureError {
    /// True when this is a relayed *evaluation* error (the user's code
    /// failed), false for framework/infrastructure failures.
    pub fn is_eval(&self) -> bool {
        matches!(self, FutureError::Eval(_))
    }

    /// True for failures where relaunching the future elsewhere could
    /// succeed (the paper's motivation for the distinct FutureError class).
    pub fn is_recoverable(&self) -> bool {
        match self {
            FutureError::WorkerDied { .. }
            | FutureError::Channel(_)
            | FutureError::Launch(_)
            | FutureError::Cancelled => true,
            // Exhausted-retry provenance: recoverability follows the final
            // attempt's failure (another relaunch *could* still work).
            FutureError::Retried { last, .. } => last.is_recoverable(),
            _ => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_error_displays_message_as_is() {
        let e = EvalError::new("non-numeric argument to mathematical function");
        assert_eq!(e.to_string(), "non-numeric argument to mathematical function");
    }

    #[test]
    fn taxonomy_separates_eval_from_infrastructure() {
        let eval: FutureError = EvalError::new("boom").into();
        assert!(eval.is_eval());
        assert!(!eval.is_recoverable());

        let died = FutureError::WorkerDied { detail: "signal 9".into() };
        assert!(!died.is_eval());
        assert!(died.is_recoverable());

        let plan = FutureError::InvalidPlan("no such backend".into());
        assert!(!plan.is_eval());
        assert!(!plan.is_recoverable());
    }

    #[test]
    fn worker_died_formats_detail() {
        let e = FutureError::WorkerDied { detail: String::new() };
        assert_eq!(e.to_string(), "FutureError: worker terminated unexpectedly");
        let e = FutureError::WorkerDied { detail: "exit 137".into() };
        assert!(e.to_string().ends_with(": exit 137"));
    }

    #[test]
    fn retried_carries_provenance_and_inherits_recoverability() {
        let e = FutureError::Retried {
            attempts: 3,
            last: Box::new(FutureError::WorkerDied { detail: "kill -9".into() }),
        };
        assert!(!e.is_eval());
        assert!(e.is_recoverable(), "last attempt was recoverable");
        let msg = e.to_string();
        assert!(msg.contains("3 attempts"), "{msg}");
        assert!(msg.contains("kill -9"), "{msg}");
        // source() chains to the final failure.
        let src = std::error::Error::source(&e).expect("source");
        assert!(src.to_string().contains("kill -9"));

        let dead_end = FutureError::Retried {
            attempts: 2,
            last: Box::new(FutureError::InvalidPlan("gone".into())),
        };
        assert!(!dead_end.is_recoverable());
    }

    #[test]
    fn session_closed_is_terminal_infrastructure() {
        let e = FutureError::SessionClosed { session: 3 };
        assert!(!e.is_eval());
        assert!(!e.is_recoverable(), "a closed session cannot host a relaunch");
        assert!(e.to_string().contains("session 3"));
    }

    #[test]
    fn timed_out_is_terminal_and_structured() {
        let e = FutureError::TimedOut {
            elapsed: std::time::Duration::from_millis(1500),
            attempts: 2,
        };
        assert!(!e.is_eval());
        assert!(!e.is_recoverable(), "deadline expiry must not feed the retry path");
        let msg = e.to_string();
        assert!(msg.contains("timed out"), "{msg}");
        assert!(msg.contains("2 attempts"), "{msg}");
        let one = FutureError::TimedOut {
            elapsed: std::time::Duration::from_millis(10),
            attempts: 1,
        };
        assert!(one.to_string().contains("1 attempt)"), "{one}");
    }

    #[test]
    fn rejected_lists_codes_and_first_help() {
        use crate::analysis::{Diagnostic, LintCode, Severity};
        let e = FutureError::Rejected {
            diagnostics: vec![Diagnostic {
                code: LintCode::ExportSize,
                severity: Severity::Deny,
                path: "globals".into(),
                message: "estimated export is 9001 bytes".into(),
                help: "shrink the capture".into(),
            }],
        };
        assert!(!e.is_eval(), "a rejection is framework policy, not user code");
        assert!(!e.is_recoverable(), "relaunching the same future is rejected again");
        let msg = e.to_string();
        assert!(msg.contains("export-size"), "{msg}");
        assert!(msg.contains("shrink the capture"), "{msg}");
    }

    #[test]
    fn clone_preserves_error_kind() {
        // Future stores terminal failures and replays them; the clone must
        // keep the taxonomy (WorkerDied stays recoverable, etc).
        let e = FutureError::WorkerDied { detail: "gone".into() };
        let c = e.clone();
        assert!(c.is_recoverable());
        assert_eq!(c.to_string(), e.to_string());
    }
}
