//! [`Expr`] — the serializable task-expression DSL.
//!
//! The R framework ships *quoted R expressions* to workers and walks their
//! AST to identify globals.  Rust has no runtime-inspectable closures, so the
//! same contract is reproduced with an explicit expression tree: futures
//! evaluate `Expr`s, [`crate::api::globals`] walks them to auto-identify free
//! variables, and [`crate::ipc::wire`] serializes them to any backend.
//!
//! The DSL is intentionally small but covers everything the paper's examples
//! need: variables and literals, `let` bindings, sequencing, lists and
//! indexing, arithmetic/comparison glue, branches, compiled-kernel calls
//! (`slow_fcn(x)` et al. via PJRT), RNG draws, output/condition emission —
//! and [`Expr::DynLookup`], the analog of R's `get("k")` that defeats static
//! globals analysis (a behaviour the paper documents explicitly).

use std::sync::Arc;

use crate::api::value::Value;

/// Scalar/element-wise primitive operations (the "glue" between kernels).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PrimOp {
    Add,
    Sub,
    Mul,
    Div,
    /// Numeric negation (1 arg).
    Neg,
    /// `<`, `<=`, `==` on numbers; Eq also on strings.
    Lt,
    Le,
    Eq,
    /// Logical not (1 arg).
    Not,
    /// Length of a list, string, or tensor (1 arg).
    Len,
    /// Sum of a list of numbers or a tensor (1 arg).
    Sum,
    /// Mean of a list of numbers or a tensor (1 arg).
    Mean,
    /// Square root (1 arg).
    Sqrt,
    /// String concatenation of all args (rendered via Display).
    Concat,
}

/// Condition-emission kinds usable inside a future expression.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EmitKind {
    /// `cat(...)` — captured standard output.
    Stdout,
    /// `message(...)` — a message condition.
    Message,
    /// `warning(...)` — a warning condition.
    Warning,
    /// An `immediateCondition`: relayed as soon as the backend can
    /// (progress updates in the paper).
    Progress,
}

/// Distributions for [`Expr::Rng`] draws.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RngDist {
    /// Uniform on [0, 1).
    Unif,
    /// Standard normal (inversion method).
    Norm,
}

/// A future's task expression.
#[derive(Debug, Clone, PartialEq)]
pub enum Expr {
    /// A literal value.
    Lit(Value),
    /// A variable reference — a *global* unless locally bound by `Let`.
    Var(String),
    /// `let name = value in body` — introduces a local (non-global) binding.
    Let { name: String, value: Box<Expr>, body: Box<Expr> },
    /// Evaluate in order; the value is the last expression's
    /// (R's `{ ...; ... }` braces).
    Seq(Vec<Expr>),
    /// Construct a list from element expressions.
    List(Vec<Expr>),
    /// Zero-based list/tensor-row indexing: `xs[[i]]`.
    Index { list: Box<Expr>, index: Box<Expr> },
    /// Call an AOT-compiled kernel (PJRT executable) by manifest name.
    Call { kernel: String, args: Vec<Expr> },
    /// Primitive glue op.
    Prim { op: PrimOp, args: Vec<Expr> },
    /// Conditional.
    If { cond: Box<Expr>, then: Box<Expr>, otherwise: Box<Expr> },
    /// Runtime environment lookup by *computed* name — R's `get("k")`.
    /// Static analysis cannot see through this; the paper's documented fix
    /// (mention the variable, or pass `globals=`) applies here identically.
    DynLookup(Box<Expr>),
    /// Emit output or a condition, then continue with `Value::Unit`.
    Emit { kind: EmitKind, message: Box<Expr> },
    /// Signal an evaluation error (R's `stop(...)`).
    Stop(Box<Expr>),
    /// Draw a tensor of the given shape from the future's RNG stream
    /// (row-major fill).  Using this without `seed = TRUE` triggers the
    /// paper's "unexpected RNG use" warning.
    Rng { dist: RngDist, shape: Vec<usize> },
    /// Run `body` under the per-element RNG substream `index` — the
    /// map-reduce layer wraps chunk elements in this so results are
    /// invariant to chunking (future.apply's per-element streams).
    WithRngStream { index: u64, body: Box<Expr> },
    /// A whole map-reduce chunk as one first-class task: bind `param` to
    /// each element of `elements` in turn, evaluate the **shared** `body`,
    /// and yield the list of per-element results.
    ///
    /// §Perf: `body` is `Arc`-shared, so building/cloning/shipping a chunk
    /// costs O(1) in body size instead of the O(n·|body|) that n `let`-bound
    /// body clones used to cost, and elements are packed `Value`s (tensor
    /// payloads Arc-shared in process, bulk-encoded on the wire).
    ///
    /// RNG contract: when the task is seeded, element `i` evaluates under
    /// substream `base_index + i` (its *global* element index), so results
    /// are invariant to chunk boundaries, backends, and worker counts —
    /// exactly the [`Expr::WithRngStream`] semantics, amortized.
    MapChunk {
        param: String,
        body: Arc<Expr>,
        elements: Vec<Value>,
        /// Global element index of `elements[0]`.
        base_index: u64,
    },
    /// Busy-wait for approximately this many milliseconds (deterministic
    /// CPU-bound load generator for scheduling benches — not a real
    /// workload).
    Spin { millis: u64 },
    /// Sleep for this many milliseconds (latency-bound load: models I/O or
    /// remote-service waits, where parallelism helps even on one core).
    Sleep { millis: u64 },
    /// A fixed amount of CPU work (`iters` rounds of a mixing function).
    /// Unlike `Spin` (wall-deadline), total CPU demand is constant, so
    /// this is the honest CPU-bound payload for scaling studies.
    Work { iters: u64 },
    /// Chaos probe: kill the executing *worker* mid-task — a real crash,
    /// not an eval error.  In a disposable worker process (multisession /
    /// cluster / batch job) this exits the process; on the thread pool the
    /// worker thread dies without replying; under `plan(sequential)`
    /// (nothing disposable to kill) it degrades to an evaluation error.
    ///
    /// With `marker: Some(path)` the kill fires only while `path` does not
    /// exist, and the marker file is created *before* dying — so a retried
    /// run of the same task survives: deterministic fail-exactly-once
    /// injection for the supervisor/retry tests.  `marker: None` kills on
    /// every execution (retry-exhaustion tests).
    ChaosKill { marker: Option<String> },

    /// Chaos probe: the executing worker *hangs* for `millis` — it stays
    /// alive, holds its seat, and emits nothing (heartbeats included), then
    /// evaluates to `0`.  The liveness plane's stall detector should declare
    /// it hung, kill the seat, and retry; without a detector the task merely
    /// runs long.
    ///
    /// With `marker: Some(path)` the hang fires only while `path` does not
    /// exist, and the marker file is created *before* hanging — so a retried
    /// run of the same task proceeds immediately: deterministic
    /// hang-exactly-once injection, mirroring [`Expr::ChaosKill`]'s
    /// fail-exactly-once contract.  `marker: None` hangs on every execution.
    ChaosHang { millis: u64, marker: Option<String> },

    /// The value of a *pipelined* future dependency (protocol v7).  When
    /// `future(g(f1))` is created with `f1` still unresolved, the consumer
    /// task ships with `Await(f1.id)` in place of the value and lists the
    /// id in [`crate::ipc::TaskOpts::pending`]; the coordinator forwards
    /// `f1`'s outcome straight to the consumer's seat as a
    /// [`crate::ipc::Message::Forward`] frame, and the worker binds it
    /// before evaluation — one hop instead of a resolve-and-resubmit round
    /// trip through the caller.  A dependency that *failed* re-raises its
    /// error here.  Never a free variable for globals analysis (the
    /// binding arrives out-of-band), and never an RNG consumer.
    Await {
        /// The pipelined dependency's future id.
        future_id: String,
    },
}

impl Expr {
    // -- ergonomic constructors ------------------------------------------

    pub fn lit(v: impl Into<Value>) -> Expr {
        Expr::Lit(v.into())
    }

    pub fn var(name: &str) -> Expr {
        Expr::Var(name.to_string())
    }

    pub fn let_in(name: &str, value: Expr, body: Expr) -> Expr {
        Expr::Let { name: name.to_string(), value: Box::new(value), body: Box::new(body) }
    }

    pub fn seq(exprs: Vec<Expr>) -> Expr {
        Expr::Seq(exprs)
    }

    pub fn list(items: Vec<Expr>) -> Expr {
        Expr::List(items)
    }

    pub fn index(list: Expr, index: Expr) -> Expr {
        Expr::Index { list: Box::new(list), index: Box::new(index) }
    }

    pub fn call(kernel: &str, args: Vec<Expr>) -> Expr {
        Expr::Call { kernel: kernel.to_string(), args }
    }

    pub fn prim(op: PrimOp, args: Vec<Expr>) -> Expr {
        Expr::Prim { op, args }
    }

    pub fn add(a: Expr, b: Expr) -> Expr {
        Expr::prim(PrimOp::Add, vec![a, b])
    }

    pub fn sub(a: Expr, b: Expr) -> Expr {
        Expr::prim(PrimOp::Sub, vec![a, b])
    }

    pub fn mul(a: Expr, b: Expr) -> Expr {
        Expr::prim(PrimOp::Mul, vec![a, b])
    }

    pub fn div(a: Expr, b: Expr) -> Expr {
        Expr::prim(PrimOp::Div, vec![a, b])
    }

    pub fn if_else(cond: Expr, then: Expr, otherwise: Expr) -> Expr {
        Expr::If { cond: Box::new(cond), then: Box::new(then), otherwise: Box::new(otherwise) }
    }

    pub fn dyn_lookup(name: Expr) -> Expr {
        Expr::DynLookup(Box::new(name))
    }

    pub fn cat(message: Expr) -> Expr {
        Expr::Emit { kind: EmitKind::Stdout, message: Box::new(message) }
    }

    pub fn message(message: Expr) -> Expr {
        Expr::Emit { kind: EmitKind::Message, message: Box::new(message) }
    }

    pub fn warning(message: Expr) -> Expr {
        Expr::Emit { kind: EmitKind::Warning, message: Box::new(message) }
    }

    pub fn progress(message: Expr) -> Expr {
        Expr::Emit { kind: EmitKind::Progress, message: Box::new(message) }
    }

    pub fn stop(message: Expr) -> Expr {
        Expr::Stop(Box::new(message))
    }

    pub fn runif(n: usize) -> Expr {
        Expr::Rng { dist: RngDist::Unif, shape: vec![n] }
    }

    pub fn rnorm(n: usize) -> Expr {
        Expr::Rng { dist: RngDist::Norm, shape: vec![n] }
    }

    /// Uniform draws shaped as a matrix/tensor (kernel-input layouts).
    pub fn runif_shaped(shape: Vec<usize>) -> Expr {
        Expr::Rng { dist: RngDist::Unif, shape }
    }

    pub fn rnorm_shaped(shape: Vec<usize>) -> Expr {
        Expr::Rng { dist: RngDist::Norm, shape }
    }

    pub fn with_rng_stream(index: u64, body: Expr) -> Expr {
        Expr::WithRngStream { index, body: Box::new(body) }
    }

    /// One map-reduce chunk: evaluate `body` with `param` bound to each
    /// element (see [`Expr::MapChunk`] for the sharing and RNG contract).
    pub fn map_chunk(
        param: &str,
        body: Arc<Expr>,
        elements: Vec<Value>,
        base_index: u64,
    ) -> Expr {
        Expr::MapChunk { param: param.to_string(), body, elements, base_index }
    }

    /// Kill the executing worker every time this evaluates (chaos probe;
    /// see [`Expr::ChaosKill`]).
    pub fn chaos_kill() -> Expr {
        Expr::ChaosKill { marker: None }
    }

    /// Kill the executing worker exactly once: the first evaluation
    /// creates `marker` and dies; later evaluations (e.g. a supervised
    /// retry) see the marker and survive, evaluating to `0`.
    pub fn chaos_kill_once(marker: &str) -> Expr {
        Expr::ChaosKill { marker: Some(marker.to_string()) }
    }

    /// Hang the executing worker for `millis` every time this evaluates
    /// (chaos probe; see [`Expr::ChaosHang`]).
    pub fn chaos_hang(millis: u64) -> Expr {
        Expr::ChaosHang { millis, marker: None }
    }

    /// Hang the executing worker exactly once: the first evaluation creates
    /// `marker` and hangs for `millis`; later evaluations (e.g. a retry
    /// after a stall kill) see the marker and evaluate to `0` immediately.
    pub fn chaos_hang_once(millis: u64, marker: &str) -> Expr {
        Expr::ChaosHang { millis, marker: Some(marker.to_string()) }
    }

    /// Reference a pipelined future dependency by id (see [`Expr::Await`];
    /// [`crate::api::future::future_pipelined`] builds these for you).
    pub fn await_future(future_id: &str) -> Expr {
        Expr::Await { future_id: future_id.to_string() }
    }

    /// Whether this expression (statically) may draw random numbers —
    /// used for the `seed = FALSE` misuse warning.
    pub fn uses_rng(&self) -> bool {
        let mut found = false;
        self.walk(&mut |e| {
            if matches!(e, Expr::Rng { .. }) {
                found = true;
            }
        });
        found
    }

    /// Pre-order traversal over all sub-expressions (including `self`).
    pub fn walk(&self, f: &mut dyn FnMut(&Expr)) {
        f(self);
        match self {
            Expr::Lit(_)
            | Expr::Var(_)
            | Expr::Rng { .. }
            | Expr::Spin { .. }
            | Expr::Sleep { .. }
            | Expr::Work { .. }
            | Expr::ChaosKill { .. }
            | Expr::ChaosHang { .. }
            | Expr::Await { .. } => {}
            Expr::Let { value, body, .. } => {
                value.walk(f);
                body.walk(f);
            }
            Expr::Seq(items) | Expr::List(items) => {
                for e in items {
                    e.walk(f);
                }
            }
            Expr::Index { list, index } => {
                list.walk(f);
                index.walk(f);
            }
            Expr::Call { args, .. } | Expr::Prim { args, .. } => {
                for e in args {
                    e.walk(f);
                }
            }
            Expr::If { cond, then, otherwise } => {
                cond.walk(f);
                then.walk(f);
                otherwise.walk(f);
            }
            Expr::DynLookup(inner) | Expr::Stop(inner) => inner.walk(f),
            Expr::Emit { message, .. } => message.walk(f),
            Expr::WithRngStream { body, .. } => body.walk(f),
            // The shared body is walked once — elements are plain values.
            Expr::MapChunk { body, .. } => body.walk(f),
        }
    }

    /// Number of nodes (diagnostics / metrics).
    pub fn node_count(&self) -> usize {
        let mut n = 0;
        self.walk(&mut |_| n += 1);
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_build_expected_shapes() {
        let e = Expr::add(Expr::var("x"), Expr::lit(1.0));
        match &e {
            Expr::Prim { op: PrimOp::Add, args } => {
                assert_eq!(args.len(), 2);
                assert_eq!(args[0], Expr::Var("x".into()));
            }
            _ => panic!("wrong shape"),
        }
    }

    #[test]
    fn walk_visits_every_node() {
        let e = Expr::let_in(
            "a",
            Expr::add(Expr::var("x"), Expr::lit(1.0)),
            Expr::seq(vec![Expr::cat(Expr::lit("hi")), Expr::var("a")]),
        );
        // Let, Prim, Var(x), Lit, Seq, Emit, Lit, Var(a) = 8 nodes
        assert_eq!(e.node_count(), 8);
    }

    #[test]
    fn map_chunk_shares_one_body() {
        let body = Arc::new(Expr::add(Expr::var("x"), Expr::runif(1)));
        let chunk =
            Expr::map_chunk("x", Arc::clone(&body), vec![Value::I64(1), Value::I64(2)], 5);
        assert!(chunk.uses_rng(), "RNG in the shared body must be visible");
        // walk visits the chunk node plus the shared body exactly once.
        assert_eq!(chunk.node_count(), 1 + body.node_count());
        match &chunk {
            Expr::MapChunk { body: b, base_index, .. } => {
                assert!(Arc::ptr_eq(b, &body), "body must be shared, not cloned");
                assert_eq!(*base_index, 5);
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn uses_rng_detects_nested_draws() {
        let plain = Expr::add(Expr::var("x"), Expr::lit(1.0));
        assert!(!plain.uses_rng());
        let rng = Expr::seq(vec![Expr::lit(0.0), Expr::rnorm(3)]);
        assert!(rng.uses_rng());
        let wrapped = Expr::with_rng_stream(7, Expr::runif(1));
        assert!(wrapped.uses_rng());
    }
}
