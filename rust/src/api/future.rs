//! The three atomic constructs: `future()`, `value()`, `resolved()`.
//!
//! ```text
//! f <- future(expr)   →  let f = future(expr, &env)?;
//! v <- value(f)       →  let v = f.value()?;
//! r <- resolved(f)    →  let r = f.resolved();
//! ```
//!
//! `future()` captures globals at creation (static analysis over the
//! expression), assigns an RNG stream index by creation order, picks the
//! backend from the current `plan()` at the current nesting depth, and
//! launches — blocking only if every worker is busy.  `value()` blocks until
//! resolution, relays captured stdout + conditions in order, and re-raises
//! evaluation errors as-is.
//!
//! Beyond the three constructs, this module hosts the paper's
//! `resolve()` — "wait until one or more futures are resolved":
//! [`FutureSet`] watches N futures through ONE shared completion channel
//! ([`crate::backend::dispatch::CompletionWaker`]) that every backend
//! notifies on resolution, so [`resolve_any`]/[`resolve_all`] block on a
//! single condvar instead of polling N handles.  [`FutureOpts::queued`]
//! additionally decouples creation from seat acquisition (the dispatcher
//! subsystem): `future()` then enqueues and returns immediately, and the
//! paper's block-on-create behaviour remains the default.

use std::sync::{Arc, Mutex};
use std::time::{Duration, SystemTime, UNIX_EPOCH};

use crate::api::conditions::{relay, relay_immediate, Condition, ConditionKind};
use crate::api::env::Env;
use crate::api::error::{EvalError, FutureError};
use crate::api::expr::Expr;
use crate::api::globals::{identify_globals, GlobalsSpec};
use crate::api::plan::current_depth;
use crate::api::session::{self, Session};
use crate::api::value::Value;
use crate::backend::dispatch::CompletionWaker;
use crate::backend::supervisor::{supervise, RetryPolicy};
use crate::backend::{Backend, TaskHandle};
use crate::ipc::{TaskOpts, TaskOutcome, TaskResult, TaskSpec};
use crate::metrics::{record_event, CounterScope, FutureTrace};

/// Restart the *current session's* future-creation counter (new "session
/// run"; benches/tests).  The counter drives deterministic RNG stream
/// index assignment ("fully reproducible regardless of backend and number
/// of workers") and is per-[`Session`] — two concurrent sessions assign
/// streams independently.
pub fn reset_session_counter() {
    session::current().reset_counter();
}

fn now_ns() -> u64 {
    SystemTime::now().duration_since(UNIX_EPOCH).unwrap_or_default().as_nanos() as u64
}

/// Options for [`future_with`] — the `future(...)` arguments.
#[derive(Debug, Clone, Default)]
pub struct FutureOpts {
    /// `seed = TRUE` analog: base seed for this future's RNG stream.
    pub seed: Option<u64>,
    /// Override the automatically assigned stream index (map-reduce layers
    /// use this for per-element streams).
    pub stream_index: Option<u64>,
    /// Globals determination (`globals=` argument).
    pub globals: GlobalsSpec,
    /// Capture stdout on the worker (default true).
    pub stdout: bool,
    /// Capture conditions on the worker (default true).
    pub conditions: bool,
    /// `lazy = TRUE`: defer launch until `resolved()`/`value()`.
    pub lazy: bool,
    /// Queued dispatch: enqueue on the backend's bounded backlog instead of
    /// blocking until a worker seat frees (the paper's block-on-create
    /// default).  Launch failures then surface at `resolved()`/`value()`
    /// rather than at creation.  Ignored when `lazy` is set (a lazy future
    /// already defers its launch).
    pub queued: bool,
    /// Keep the task spec so the future can be [`Future::restart`]ed after
    /// an infrastructure failure (paper's `restart(f)` future-work item).
    /// Off by default.  (Retention is cheap since tensor payloads are
    /// Arc-shared — the clone is O(1) in payload bytes.)
    pub restartable: bool,
    /// Supervised retry: transparently resubmit this future to a healthy
    /// worker when the infrastructure fails (worker death, broken channel,
    /// lost launch), per the policy's budget/backoff.  Requires the
    /// policy's `idempotent` gate; eval errors and cancellations are never
    /// retried.  `None` falls back to the plan-wide default
    /// ([`crate::api::plan::plan_with_retry`]); both absent keeps the
    /// paper's at-most-once submission.
    pub retry: Option<RetryPolicy>,
    /// Per-future deadline, measured from creation: once it expires, the
    /// future latches [`FutureError::TimedOut`] terminally and the
    /// in-flight attempt is *cancelled* (seat freed), not abandoned.  The
    /// clock includes queue wait and retry backoff — it bounds the
    /// caller's wait, not the worker's compute.  `None` falls back to the
    /// session default ([`Session::set_default_deadline`]); both absent
    /// means no deadline (the paper's semantics).
    pub deadline: Option<Duration>,
    /// Human-readable label.
    pub label: Option<String>,
    /// Opt into the content-addressed result cache ([`crate::cache`]):
    /// before any capacity admission, the future's key — `digest(expr ‖
    /// resolved globals ‖ seed+stream ‖ protocol version)` — is looked up,
    /// and a hit resolves the future immediately **without acquiring a
    /// capacity lease or backend at all**.  A miss evaluates normally and
    /// publishes on clean resolution only (eval errors, `TimedOut`,
    /// `Cancelled`, and chaos-marked expressions are never cached; unseeded
    /// RNG expressions are never keyed).  Subject to the session's
    /// [`crate::cache::CacheConfig`].
    pub cached: bool,
}

impl FutureOpts {
    pub fn new() -> Self {
        FutureOpts { stdout: true, conditions: true, ..Default::default() }
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = Some(seed);
        self
    }

    pub fn globals(mut self, spec: GlobalsSpec) -> Self {
        self.globals = spec;
        self
    }

    pub fn lazy(mut self) -> Self {
        self.lazy = true;
        self
    }

    pub fn queued(mut self) -> Self {
        self.queued = true;
        self
    }

    pub fn restartable(mut self) -> Self {
        self.restartable = true;
        self
    }

    pub fn retry(mut self, policy: RetryPolicy) -> Self {
        self.retry = Some(policy);
        self
    }

    pub fn deadline(mut self, deadline: Duration) -> Self {
        self.deadline = Some(deadline);
        self
    }

    pub fn label(mut self, label: &str) -> Self {
        self.label = Some(label.to_string());
        self
    }

    /// Opt into the content-addressed result cache (see
    /// [`FutureOpts::cached`]).
    pub fn cached(mut self) -> Self {
        self.cached = true;
        self
    }

    pub fn no_capture(mut self) -> Self {
        self.stdout = false;
        self.conditions = false;
        self
    }
}

enum State {
    /// `lazy = TRUE` and not yet launched.
    Lazy(Box<TaskSpec>),
    /// Launched on a backend.
    Running { handle: Box<dyn TaskHandle>, supports_immediate: bool },
    /// Result collected from the handle (value() may be called repeatedly).
    Done(Box<TaskResult>),
    /// Infrastructure failure captured for replay on later calls — the
    /// original [`FutureError`] is kept (not stringified) so its kind
    /// survives: a `WorkerDied` future stays recoverable however often it
    /// is probed or collected.
    Failed(FutureError),
}

/// A future: a placeholder for the value of `expr` evaluated with the
/// globals captured at creation.
pub struct Future {
    id: String,
    label: Option<String>,
    state: Mutex<State>,
    /// Whether the expression may draw RNG without `seed` (misuse warning).
    warn_unseeded_rng: bool,
    relayed: Mutex<bool>,
    /// Retained spec for [`Future::restart`] (opt-in via
    /// [`FutureOpts::restartable`]).
    restart_spec: Mutex<Option<TaskSpec>>,
    /// Effective retry policy (opts override, else the plan default at
    /// creation) — applied on every launch path, including lazy launch
    /// and [`Future::restart`].
    retry: Option<RetryPolicy>,
    /// Effective deadline (opts override, else the session default at
    /// creation), measured from `created_at`.  `None` = never expires.
    deadline: Option<Duration>,
    /// Creation instant — the deadline clock's zero.
    created_at: std::time::Instant,
    /// The owning session: lazy launches and restarts go back to it, and a
    /// closed session latches unresolved futures into `SessionClosed`.
    session: Session,
    /// `max_in_flight` quota charge, taken (blocking) at creation and
    /// returned on the first terminal transition — or, as the backstop,
    /// when the future is dropped.
    permit: Mutex<Option<crate::capacity::InFlightPermit>>,
    /// Result-cache publication plan for a `cached` future that MISSED at
    /// creation (hits carry `None` — nothing re-publishes).  Snapshotted at
    /// creation so publication never reads session state.
    cache_plan: Option<crate::cache::CachePlan>,
    pub trace: Arc<FutureTrace>,
}

/// Launch `task` on `backend`, supervised when an armed retry policy is in
/// effect — THE single launch choke point shared by eager creation, lazy
/// launch, and restart, so no path can silently lose supervision.
/// Retries record against the owning session's counter `scope`.
fn launch_on(
    backend: &Arc<dyn Backend>,
    task: TaskSpec,
    retry: Option<&RetryPolicy>,
    queued: bool,
    scope: &CounterScope,
) -> Result<Box<dyn TaskHandle>, FutureError> {
    match retry {
        Some(p) if p.armed() => supervise(backend, task, p.clone(), queued, scope.clone()),
        _ if queued => backend.launch_queued(task),
        _ => backend.launch(task),
    }
}

/// Create a future with default options (eager, auto globals, no seed).
pub fn future(expr: Expr, env: &Env) -> Result<Future, FutureError> {
    future_with(expr, env, FutureOpts::new())
}

/// Create a future with explicit options, under the current
/// [`Session`] (the innermost [`Session::scope`], else the default).
pub fn future_with(expr: Expr, env: &Env, opts: FutureOpts) -> Result<Future, FutureError> {
    future_inner(expr, env, opts, Env::new(), Vec::new())
}

/// `f2 <- future(g(f1))` — promise pipelining.  `expr` may reference each
/// dependency through [`Expr::await_future`]`(dep.id())`; the dependency's
/// resolved outcome reaches the consumer's worker either **prebound** into
/// its globals (dependency already resolved at creation, or the backend
/// cannot pipeline) or as a wire-v7 `Forward` frame sent straight from the
/// coordinator to the consumer's seat the moment the dependency resolves —
/// one hop, instead of collect-here-then-reship.  A failed dependency
/// surfaces on the consumer as an evaluation error (never a hang);
/// supervised retries of the consumer re-deliver every forward to the
/// fresh seat.  Pipelined futures are never cached: their inputs arrive
/// out-of-band, invisible to the content-addressed cache key.
pub fn future_pipelined(
    expr: Expr,
    env: &Env,
    mut opts: FutureOpts,
    deps: Vec<Future>,
) -> Result<Future, FutureError> {
    opts.cached = false;
    let session = session::current();
    session.ensure_open()?;
    let backend = session.backend_for_depth(current_depth())?;
    // Lazy consumers have no seat to forward to until poked — resolve
    // dependencies at creation instead (still correct, just eager on the
    // dependency side).
    let pipelining = backend.supports_pipelining() && !opts.lazy;

    let mut prebound = Env::new();
    let mut pending: Vec<String> = Vec::new();
    let mut live: Vec<Future> = Vec::new();
    for dep in deps {
        if pipelining && !dep.resolved() {
            pending.push(dep.id().to_string());
            live.push(dep);
        } else {
            // Already resolved — or resolving it here is the fallback:
            // bind the outcome into the consumer's globals at creation.
            prebind_dep(&mut prebound, &dep);
        }
    }

    let fut = future_inner(expr, env, opts, prebound, pending)?;
    for dep in live {
        let dep = Arc::new(dep);
        let fwd_backend = Arc::clone(&backend);
        let fwd_dep = Arc::clone(&dep);
        let consumer = fut.id().to_string();
        let spawned = std::thread::Builder::new()
            .name("rustures-pipeline-fwd".into())
            .spawn(move || forward_dep(&fwd_backend, &consumer, &fwd_dep));
        if spawned.is_err() {
            // Could not detach a forwarder (thread exhaustion): deliver
            // synchronously — slower, never lost.
            forward_dep(&backend, fut.id(), &dep);
        }
    }
    Ok(fut)
}

/// Resolve `dep` (blocking if needed) and bind its outcome under the
/// reserved pipeline sentinel key in `prebound` — the creation-time
/// delivery path ([`Expr::Await`] reads these on the worker).
fn prebind_dep(prebound: &mut Env, dep: &Future) {
    match dep.result() {
        Ok(r) => match r.outcome {
            TaskOutcome::Ok(v) => {
                prebound.insert(&crate::ipc::pipeline_ok_key(dep.id()), v);
            }
            TaskOutcome::Err(e) => {
                prebound.insert(
                    &crate::ipc::pipeline_err_key(dep.id()),
                    Value::Str(e.message),
                );
            }
        },
        Err(e) => {
            prebound.insert(
                &crate::ipc::pipeline_err_key(dep.id()),
                Value::Str(format!("pipelined dependency failed: {e}")),
            );
        }
    }
    crate::transport::note_prebind();
}

/// Block on `dep`, then hand its outcome to the backend for direct
/// seat-to-seat delivery (the forwarder-thread body).  An infrastructure
/// failure of the dependency forwards as an evaluation error so the
/// consumer fails fast instead of hanging.
fn forward_dep(backend: &Arc<dyn Backend>, consumer_id: &str, dep: &Future) {
    let outcome = match dep.result() {
        Ok(r) => r.outcome,
        Err(e) => TaskOutcome::Err(EvalError::new(format!(
            "pipelined dependency '{}' failed: {e}",
            dep.id()
        ))),
    };
    let _ = backend.pipeline_forward(consumer_id, dep.id(), &outcome);
}

/// Shared creation path behind [`future_with`] (no extras) and
/// [`future_pipelined`] (prebound sentinels and/or pending dependency
/// ids).  `extra_globals` are merged into the captured globals *after*
/// free-variable analysis — sentinel keys are not user bindings and must
/// never shadow one; `pending` rides to the worker in
/// [`TaskOpts::pending`], telling it how many `Forward` frames to await
/// before evaluation.
fn future_inner(
    expr: Expr,
    env: &Env,
    opts: FutureOpts,
    extra_globals: Env,
    pending: Vec<String>,
) -> Result<Future, FutureError> {
    let session = session::current();
    session.ensure_open()?;

    // 1. Identify and snapshot globals (creation-time capture).
    let mut globals = identify_globals(&expr, env, &opts.globals)?;
    for (k, v) in extra_globals.iter() {
        globals.insert(k, v.clone());
    }

    // 2. Plan-time static analysis — BEFORE the capacity ledger is
    //    touched, so a denied future costs no in-flight permit, no slot
    //    lease, and no worker round trip.  Deny → structured rejection;
    //    Warn → relayed through the conditions plane and counted per
    //    session (`rustures.analysis.v1`).  Allow findings are skipped
    //    inside `analyze`, so a clean run is bit-identical to a disabled
    //    analyzer.
    let depth = current_depth();
    let config = session.analysis_config();
    if config.enabled {
        let facts = session.analysis_facts(depth);
        let diagnostics =
            crate::analysis::analyze(&expr, &globals, &opts.globals, &opts, &facts, &config);
        if !diagnostics.is_empty() {
            let origin = session.origin_id();
            let denied: Vec<crate::analysis::Diagnostic> = diagnostics
                .iter()
                .filter(|d| d.severity == crate::analysis::Severity::Deny)
                .cloned()
                .collect();
            if !denied.is_empty() {
                for d in &denied {
                    crate::metrics::record_analysis(origin, d.code.as_str(), true);
                }
                return Err(FutureError::Rejected { diagnostics: denied });
            }
            // All remaining findings are Warn severity.
            for d in &diagnostics {
                crate::metrics::record_analysis(origin, d.code.as_str(), false);
                relay_immediate(&Condition {
                    kind: ConditionKind::Warning,
                    message: d.to_string(),
                    seq: 0,
                });
            }
        }
    }

    // 3. Deterministic RNG stream index by creation order — per session,
    //    so concurrent sessions assign streams independently.  Computed
    //    BEFORE capacity admission so a cache hit can key without touching
    //    the ledger; a hit still consumes this ordinal, so every later
    //    future's stream index matches an uncached run bit-identically.
    let id = session.next_future_id();
    let created_ns = now_ns();
    let ordinal = session.next_ordinal();
    let stream_index = opts.stream_index.unwrap_or(ordinal);

    // 4. Content-addressed result cache (opt-in): a hit constructs a
    //    born-resolved future with NO in-flight permit, NO slot lease, and
    //    NO backend — the session never appears in `capacity_json()` for
    //    it.  `plan_for_task` refuses uncacheable tasks (config disabled,
    //    chaos markers, unseeded RNG), which then evaluate normally.
    let cache_plan = if opts.cached {
        crate::cache::plan_for_task(
            session.origin_id(),
            &expr,
            &globals,
            opts.seed,
            stream_index,
            &session.cache_config(),
        )
    } else {
        None
    };
    if let Some(plan) = &cache_plan {
        if let Some(mut result) = crate::cache::lookup(plan) {
            result.id = id.clone();
            let trace = Arc::new(FutureTrace::new(
                &id,
                opts.label.as_deref(),
                "cache",
                session.origin_id(),
                created_ns,
            ));
            record_event(&trace, "cache-hit");
            record_event(&trace, "resolved");
            return Ok(Future {
                id,
                label: opts.label,
                state: Mutex::new(State::Done(Box::new(result))),
                // Cacheable futures are seeded whenever they draw RNG, so
                // the cold run's flag was false too — relay stays
                // bit-identical.
                warn_unseeded_rng: false,
                relayed: Mutex::new(false),
                restart_spec: Mutex::new(None),
                retry: None,
                deadline: None,
                created_at: std::time::Instant::now(),
                session,
                permit: Mutex::new(None),
                // A hit never re-publishes what it just read.
                cache_plan: None,
                trace,
            });
        }
    }

    // 5. Per-session in-flight quota (SessionLimits::max_in_flight):
    //    blocks — never drops — while the session has that many
    //    unresolved futures outstanding.  The permit frees on the
    //    future's first terminal transition, or when it is dropped.
    let permit = crate::capacity::admit_in_flight(session.origin_id());

    // 6. Backend + serialized session context for the current depth.
    let backend = session.backend_for_depth(depth)?;
    let context = session.context_for_depth(depth);

    let warn_unseeded_rng = opts.seed.is_none() && expr.uses_rng();

    // Per-future retry wins; otherwise inherit the session's plan-wide
    // default (the same default the context ships to nested workers).
    let retry = opts.retry.clone().or_else(|| context.retry.clone());
    // Same precedence for the deadline: per-future, else session default.
    let deadline = opts.deadline.or_else(|| session.default_deadline());

    let task = TaskSpec {
        id: id.clone(),
        expr,
        globals,
        opts: TaskOpts {
            seed: opts.seed,
            stream_index,
            capture_stdout: opts.stdout,
            capture_conditions: opts.conditions,
            label: opts.label.clone(),
            depth,
            context,
            // First launch; the supervisor restamps this on every retry.
            attempt: 0,
            pending,
        },
    };

    let trace = Arc::new(FutureTrace::new(
        &id,
        opts.label.as_deref(),
        backend.name(),
        // Attribute to the origin session (== id except on worker-side
        // derived sessions, where the originating session owns the rows).
        session.origin_id(),
        created_ns,
    ));

    let restart_spec = if opts.restartable { Some(task.clone()) } else { None };
    let state = if opts.lazy {
        State::Lazy(Box::new(task))
    } else {
        let supports_immediate = backend.supports_immediate();
        record_event(&trace, "launch");
        let handle =
            launch_on(&backend, task, retry.as_ref(), opts.queued, &session.metrics_scope())?;
        State::Running { handle, supports_immediate }
    };

    Ok(Future {
        id,
        label: opts.label,
        state: Mutex::new(state),
        warn_unseeded_rng,
        relayed: Mutex::new(false),
        restart_spec: Mutex::new(restart_spec),
        retry,
        deadline,
        created_at: std::time::Instant::now(),
        session,
        permit: Mutex::new(Some(permit)),
        cache_plan,
        trace,
    })
}

impl std::fmt::Debug for Future {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let state = match &*self.state.lock().unwrap() {
            State::Lazy(_) => "lazy",
            State::Running { .. } => "running",
            State::Done(_) => "done",
            State::Failed(_) => "failed",
        };
        f.debug_struct("Future")
            .field("id", &self.id)
            .field("label", &self.label)
            .field("state", &state)
            .finish()
    }
}

impl Future {
    pub fn id(&self) -> &str {
        &self.id
    }

    pub fn label(&self) -> Option<&str> {
        self.label.as_deref()
    }

    /// Id of the [`Session`] this future attributes to (the originating
    /// session for futures created on worker-side derived sessions).
    pub fn session_id(&self) -> u64 {
        self.session.origin_id()
    }

    /// Return the `max_in_flight` quota charge: the future reached a
    /// terminal state, so it no longer counts against the session's
    /// in-flight window.  Idempotent; the `Drop` of the permit inside
    /// `Future` is the backstop for futures abandoned mid-flight.
    fn release_permit(&self) {
        self.permit.lock().unwrap().take();
    }

    /// Publish a cleanly-collected result to the result cache — miss-path
    /// `cached` futures only (hits carry no plan, so a hit never re-writes
    /// what it just read).  Runs at the two Running→Done promotions in
    /// [`Self::resolved`] and [`Self::result`]; eval errors are filtered
    /// inside [`crate::cache::publish`], and `TimedOut`/`Cancelled`/infra
    /// failures latch `State::Failed`, which never reaches here.  The
    /// promotion inside [`Self::latch_if_session_closed`] deliberately does
    /// NOT publish: a closing session is tearing down — it should salvage
    /// its own value, not grow shared state.
    fn publish_to_cache(&self, result: &TaskResult) {
        if let Some(plan) = &self.cache_plan {
            crate::cache::publish(plan, result);
        }
    }

    /// Latch `SessionClosed` into an unresolvable future of a closed
    /// session.  Returns the error to surface, or `None` when the future
    /// already reached — or can still reach — a terminal state: a result
    /// the worker finished before the close is promoted and survives
    /// (close() never discards computed values), only futures that can no
    /// longer complete latch the error.
    fn latch_if_session_closed(&self, state: &mut State) -> Option<FutureError> {
        if !self.session.is_closed() {
            return None;
        }
        let closed_err = || FutureError::SessionClosed { session: self.session.origin_id() };
        match state {
            State::Done(_) | State::Failed(_) => None,
            State::Running { handle, .. } => {
                if handle.is_resolved() {
                    // An outcome the backend parked before teardown is
                    // collected and survives — a VALUE as a value, a
                    // parked infrastructure failure (worker crashed
                    // pre-close, torn frame, or the seat close() itself
                    // killed) with its real provenance intact.  Such an
                    // error may read as recoverable (WorkerDied/Channel),
                    // but any relaunch attempt in this session surfaces
                    // SessionClosed at creation, so nothing misleads.
                    match handle.wait() {
                        Ok(r) => {
                            record_event(&self.trace, "resolved");
                            *state = State::Done(Box::new(r));
                            None
                        }
                        Err(e) => {
                            *state = State::Failed(e.clone());
                            Some(e)
                        }
                    }
                } else {
                    let e = closed_err();
                    *state = State::Failed(e.clone());
                    Some(e)
                }
            }
            State::Lazy(_) => {
                let e = closed_err();
                *state = State::Failed(e.clone());
                Some(e)
            }
        }
    }

    /// Launch a lazy future now (no-op otherwise).
    pub fn launch(&self) -> Result<(), FutureError> {
        let mut state = self.state.lock().unwrap();
        if let Some(e) = self.latch_if_session_closed(&mut state) {
            return Err(e);
        }
        if let State::Lazy(task) = &*state {
            // A failed launch attempt is TERMINAL for this future: the real
            // error (kind intact) is latched into State::Failed, so
            // resolved(), value(), and result() all replay the same failure
            // no matter which is called first — mirroring eager futures,
            // which error at creation.  Retry is the restart() /
            // FutureOpts::restartable path, not silent relaunching.
            //
            // The launch goes back to the OWNING session at the depth the
            // spec recorded — a lazy future poked from another thread or
            // scope still resolves on its own session's plan.
            let depth = task.opts.depth;
            let backend = match self.session.backend_for_depth(depth) {
                Ok(b) => b,
                Err(e) => {
                    *state = State::Failed(e.clone());
                    return Err(e);
                }
            };
            let placeholder = State::Failed(FutureError::Launch("launch in progress".into()));
            let task = match std::mem::replace(&mut *state, placeholder) {
                State::Lazy(t) => t,
                _ => unreachable!(),
            };
            let supports_immediate = backend.supports_immediate();
            record_event(&self.trace, "launch");
            match launch_on(
                &backend,
                *task,
                self.retry.as_ref(),
                false,
                &self.session.metrics_scope(),
            ) {
                Ok(handle) => *state = State::Running { handle, supports_immediate },
                Err(e) => {
                    *state = State::Failed(e.clone());
                    return Err(e);
                }
            }
        }
        Ok(())
    }

    /// Non-blocking resolution probe.  A lazy future is launched by the
    /// first `resolved()` call ("a lazy future defers evaluation until we
    /// use resolved() ... or value()").
    pub fn resolved(&self) -> bool {
        {
            let mut state = self.state.lock().unwrap();
            if self.latch_if_session_closed(&mut state).is_some() {
                self.release_permit();
                return true; // resolved, to a SessionClosed failure
            }
            match &*state {
                State::Done(_) | State::Failed(_) => {
                    self.release_permit();
                    return true;
                }
                State::Lazy(_) => {}
                State::Running { .. } => {}
            }
        }
        // Lazy: launch first (outside the match to avoid double-lock).
        // A launch error latches State::Failed inside launch(), so the
        // match below reports it as resolved — pollers never spin forever.
        if matches!(&*self.state.lock().unwrap(), State::Lazy(_)) {
            let _ = self.launch();
        }
        let mut state = self.state.lock().unwrap();
        let is_terminal = match &mut *state {
            State::Running { handle, .. } => {
                if handle.is_resolved() {
                    // Promote to Done so value() won't block.
                    match handle.wait() {
                        Ok(result) => {
                            record_event(&self.trace, "resolved");
                            self.publish_to_cache(&result);
                            *state = State::Done(Box::new(result));
                        }
                        Err(e) => *state = State::Failed(e),
                    }
                    true
                } else if self.deadline.is_some_and(|d| self.created_at.elapsed() >= d) {
                    // Deadline expired with the attempt still in flight:
                    // cancel it (frees the seat) and latch TimedOut — the
                    // non-blocking probe reaches the same terminal state a
                    // blocking result() would.
                    handle.cancel();
                    let e = FutureError::TimedOut {
                        elapsed: self.created_at.elapsed(),
                        attempts: handle.attempts(),
                    };
                    self.session.metrics_scope().timeout();
                    *state = State::Failed(e);
                    true
                } else {
                    false
                }
            }
            State::Done(_) | State::Failed(_) => true,
            // Not reachable in practice: launch() above either converted the
            // state or latched its error.  Defensive false, not a panic.
            State::Lazy(_) => false,
        };
        if is_terminal {
            self.release_permit();
        }
        is_terminal
    }

    /// Block until resolved; relay captured output/conditions; return the
    /// value or re-raise the evaluation error as-is.
    pub fn value(&self) -> Result<Value, FutureError> {
        let result = self.result()?;
        self.relay_once(&result);
        match result.outcome {
            TaskOutcome::Ok(v) => Ok(v),
            TaskOutcome::Err(e) => Err(FutureError::Eval(e)),
        }
    }

    /// Like [`Self::value`] but returns the full result (value + captured
    /// output + metrics) without relaying — programmatic access.
    pub fn result(&self) -> Result<TaskResult, FutureError> {
        // Lazy futures launch on first value()/result().
        if matches!(&*self.state.lock().unwrap(), State::Lazy(_)) {
            self.launch()?;
        }
        let mut state = self.state.lock().unwrap();
        if let Some(e) = self.latch_if_session_closed(&mut state) {
            self.release_permit();
            return Err(e);
        }
        let out = match &mut *state {
            State::Done(r) => Ok((**r).clone()),
            State::Failed(e) => Err(e.clone()),
            State::Running { handle, .. } => {
                record_event(&self.trace, "collect-wait");
                let outcome = if let Some(d) = self.deadline {
                    // Deadline-aware collection: subscribe to the handle's
                    // completion push and sleep at most until the deadline,
                    // so expiry interrupts the wait.  The clock runs from
                    // creation — queue wait and retry backoff count.
                    let waker = CompletionWaker::new();
                    let push = handle.subscribe(&waker, 0);
                    loop {
                        if handle.is_resolved() {
                            // A result at the boundary beats the deadline:
                            // never discard a value that already arrived.
                            break handle.wait();
                        }
                        let elapsed = self.created_at.elapsed();
                        if elapsed >= d {
                            // Expired: cancel the in-flight attempt (seat
                            // freed — cancelled, not abandoned) and latch.
                            handle.cancel();
                            self.session.metrics_scope().timeout();
                            break Err(FutureError::TimedOut {
                                elapsed,
                                attempts: handle.attempts(),
                            });
                        }
                        let remaining = d - elapsed;
                        // Bounded slices even with push support: a
                        // supervised handle in its retry-backoff window is
                        // only driven forward by is_resolved() probes, so
                        // sleeping clear to the deadline would starve the
                        // relaunch the deadline still has budget for.
                        let cap = if push {
                            remaining.min(Duration::from_millis(20))
                        } else {
                            remaining.min(Duration::from_millis(5))
                        };
                        let _ = waker.wait_next(Some(cap));
                    }
                } else {
                    handle.wait()
                };
                match outcome {
                    Ok(result) => {
                        record_event(&self.trace, "resolved");
                        self.publish_to_cache(&result);
                        *state = State::Done(Box::new(result.clone()));
                        Ok(result)
                    }
                    Err(e) => {
                        *state = State::Failed(e.clone());
                        Err(e)
                    }
                }
            }
            State::Lazy(_) => Err(FutureError::Launch("lazy future failed to launch".into())),
        };
        // Every arm above is terminal (Done, Failed, or a latched launch
        // failure): the in-flight charge returns now.
        self.release_permit();
        out
    }

    /// Relay captured output + conditions exactly once across repeated
    /// `value()` calls.
    fn relay_once(&self, result: &TaskResult) {
        let mut relayed = self.relayed.lock().unwrap();
        if *relayed {
            return;
        }
        *relayed = true;

        let skip_immediate = {
            let state = self.state.lock().unwrap();
            match &*state {
                State::Running { supports_immediate, .. } => *supports_immediate,
                // Done: the handle is gone; infer from captured data — the
                // live-relaying backends already emitted immediates.
                _ => self.backend_relayed_immediates(),
            }
        };

        let mut captured = result.captured.clone();
        // The paper's RNG-misuse warning: "the future framework will
        // generate an informative warning" when RNG is used without seed.
        if (self.warn_unseeded_rng || captured.rng_used) && result.captured.rng_used {
            captured.conditions.push(Condition {
                kind: ConditionKind::Warning,
                message: format!(
                    "UnexpectedRandomNumbers: future ('{}') drew random numbers without seed = TRUE; \
                     results may be statistically unsound",
                    self.label.as_deref().unwrap_or(&self.id)
                ),
                seq: u64::MAX, // after all captured conditions
            });
        }
        relay(&captured, skip_immediate);
    }

    fn backend_relayed_immediates(&self) -> bool {
        // Conservative: only in-process backends relay live, and they mark
        // supports_immediate at launch; after Done we keep relaying
        // immediates unless we know better. False = relay them here too.
        false
    }

    /// `restart(f)` — the paper's future-work item: relaunch this future
    /// (e.g. after a crashed worker / cancelled job), reusing the captured
    /// globals and options.  Requires [`FutureOpts::restartable`].
    ///
    /// Any previous run is cancelled; relay state resets so output relays
    /// again from the fresh run.
    pub fn restart(&self) -> Result<(), FutureError> {
        let spec = self
            .restart_spec
            .lock()
            .unwrap()
            .clone()
            .ok_or_else(|| FutureError::Launch(
                "future was not created with restartable()".into(),
            ))?;
        // Stop whatever is in flight.
        {
            let mut state = self.state.lock().unwrap();
            if let State::Running { handle, .. } = &mut *state {
                handle.cancel();
            }
        }
        // Relaunch on the OWNING session at the recorded depth.
        let backend = self.session.backend_for_depth(spec.opts.depth)?;
        let supports_immediate = backend.supports_immediate();
        record_event(&self.trace, "restart");
        let handle =
            launch_on(&backend, spec, self.retry.as_ref(), false, &self.session.metrics_scope())?;
        *self.state.lock().unwrap() = State::Running { handle, supports_immediate };
        *self.relayed.lock().unwrap() = false;
        Ok(())
    }

    /// Best-effort cancellation (extension feature).
    pub fn cancel(&self) -> bool {
        let mut state = self.state.lock().unwrap();
        match &mut *state {
            State::Running { handle, .. } => handle.cancel(),
            State::Lazy(_) => {
                *state = State::Failed(FutureError::Cancelled);
                true
            }
            _ => false,
        }
    }

    /// Register a resolution subscription with the backend.  Lazy futures
    /// launch first (`resolve()` semantics: "a lazy future defers
    /// evaluation until we use resolved() ... or value()").
    fn subscribe_completion(&self, waker: &Arc<CompletionWaker>, token: u64) -> Subscribed {
        if matches!(&*self.state.lock().unwrap(), State::Lazy(_)) {
            // A launch failure latches State::Failed — reported as already
            // resolved below, exactly like resolved().
            let _ = self.launch();
        }
        let mut state = self.state.lock().unwrap();
        if self.latch_if_session_closed(&mut state).is_some() {
            return Subscribed::AlreadyResolved;
        }
        match &mut *state {
            State::Done(_) | State::Failed(_) => Subscribed::AlreadyResolved,
            State::Running { handle, .. } => {
                if handle.subscribe(waker, token) {
                    Subscribed::Push
                } else {
                    Subscribed::Poll
                }
            }
            // Unreachable in practice (launch() above either converted the
            // state or latched its failure); poll is the safe fallback.
            State::Lazy(_) => Subscribed::Poll,
        }
    }
}

/// How a future's resolution will reach a [`FutureSet`].
enum Subscribed {
    /// Already resolved at subscription time.
    AlreadyResolved,
    /// The backend push-notifies the shared waker (every built-in backend).
    Push,
    /// No push support (third-party handle): the set polls this future on a
    /// short timeout.
    Poll,
}

/// The paper's `resolve()` machinery: wait on *any* or *all* of N futures
/// through one shared completion channel — a single mutex + condvar that
/// every watched backend notifies — instead of polling each handle.
///
/// Each future's index is reported by [`FutureSet::wait_any`] exactly once,
/// in completion order; already-resolved futures (and sequential plans,
/// which resolve at creation) report immediately in input order.
///
/// ```no_run
/// use rustures::prelude::*;
/// use rustures::api::future::FutureSet;
/// # let futures: Vec<Future> = vec![];
/// let mut set = FutureSet::new(&futures);
/// while let Some(i) = set.wait_any() {
///     println!("future {i} resolved: {:?}", futures[i].value());
/// }
/// ```
pub struct FutureSet<'a> {
    futures: Vec<&'a Future>,
    waker: Arc<CompletionWaker>,
    /// Index already returned by `wait_any`.
    reported: Vec<bool>,
    /// Index downgraded to the timed-poll fallback (no push support).
    needs_poll: Vec<bool>,
    /// Indices known resolved but not yet reported.
    ready: std::collections::VecDeque<usize>,
    remaining: usize,
}

impl<'a> FutureSet<'a> {
    /// Watch `futures` (any iterable of `&Future`; a `&[Future]` slice
    /// works directly).  Lazy futures are launched.
    pub fn new<I>(futures: I) -> Self
    where
        I: IntoIterator<Item = &'a Future>,
    {
        let futures: Vec<&Future> = futures.into_iter().collect();
        let n = futures.len();
        let waker = CompletionWaker::new();
        let mut set = FutureSet {
            futures,
            waker,
            reported: vec![false; n],
            needs_poll: vec![false; n],
            ready: std::collections::VecDeque::new(),
            remaining: n,
        };
        for i in 0..n {
            match set.futures[i].subscribe_completion(&set.waker, i as u64) {
                Subscribed::AlreadyResolved => set.ready.push_back(i),
                Subscribed::Push => {}
                Subscribed::Poll => set.needs_poll[i] = true,
            }
        }
        set
    }

    /// Futures not yet reported by [`FutureSet::wait_any`].
    pub fn pending(&self) -> usize {
        self.remaining
    }

    /// Has future `i` already been reported resolved by this set?
    pub fn is_reported(&self, i: usize) -> bool {
        self.reported.get(i).copied().unwrap_or(false)
    }

    /// Record a waker token: verify the future really resolved (promoting
    /// it to Done so a later `value()` cannot block) or downgrade it to the
    /// poll fallback on a spurious wake.
    fn admit_token(&mut self, token: u64) {
        let i = token as usize;
        if i >= self.futures.len() || self.reported[i] {
            return;
        }
        if self.futures[i].resolved() {
            if !self.ready.contains(&i) {
                self.ready.push_back(i);
            }
        } else {
            self.needs_poll[i] = true;
        }
    }

    /// Block until one more future resolves and return its index
    /// (completion order); `None` once every future has been reported.
    pub fn wait_any(&mut self) -> Option<usize> {
        loop {
            if self.remaining == 0 {
                return None;
            }
            if let Some(i) = self.ready.pop_front() {
                if self.reported[i] {
                    continue;
                }
                self.reported[i] = true;
                self.remaining -= 1;
                return Some(i);
            }
            // Drain whatever notifications already arrived.
            while let Some(token) = self.waker.try_next() {
                self.admit_token(token);
            }
            if !self.ready.is_empty() {
                continue;
            }
            // Poll-fallback futures (handles without push notification).
            let mut any_poll = false;
            for i in 0..self.futures.len() {
                if self.needs_poll[i] && !self.reported[i] {
                    any_poll = true;
                    if self.futures[i].resolved() {
                        self.needs_poll[i] = false;
                        self.ready.push_back(i);
                    }
                }
            }
            if !self.ready.is_empty() {
                continue;
            }
            // Nothing resolved yet: sleep on the shared channel.  The short
            // timeout re-polls non-push handles; the long one is a safety
            // net — backends keep ONE subscription per handle (last wins),
            // so overlapping FutureSets (or a future listed twice) can have
            // a wakeup displaced.  The sweep below recovers it; the push
            // path never waits for it.
            let timeout = if any_poll {
                Duration::from_millis(1)
            } else {
                Duration::from_millis(100)
            };
            match self.waker.wait_next(Some(timeout)) {
                Some(token) => self.admit_token(token),
                None => {
                    // Timed out without a token: sweep every unreported
                    // future so a displaced subscription cannot hang us.
                    for i in 0..self.futures.len() {
                        if !self.reported[i]
                            && !self.ready.contains(&i)
                            && self.futures[i].resolved()
                        {
                            self.ready.push_back(i);
                        }
                    }
                }
            }
        }
    }

    /// Block until every watched future is resolved.
    pub fn wait_all(&mut self) {
        while self.wait_any().is_some() {}
    }
}

/// The paper's `resolve(F)`: block until **all** futures are resolved,
/// without collecting values (collection stays `value()`/[`values`]).
/// After this returns, `value()` on any of them cannot block.
pub fn resolve(futures: &[Future]) {
    FutureSet::new(futures).wait_all();
}

/// Alias for [`resolve`] mirroring the `resolve(..., idxs)` family.
pub fn resolve_all(futures: &[Future]) {
    resolve(futures);
}

/// Block until **any** future resolves; returns its index (`None` for an
/// empty slice).  Wakes via the shared completion channel — no per-future
/// polling.
pub fn resolve_any(futures: &[Future]) -> Option<usize> {
    FutureSet::new(futures).wait_any()
}

/// `value()` for a collection: resolve all, in order (S3 `value()` on
/// lists in the paper's future-work section).
pub fn values(futures: &[Future]) -> Result<Vec<Value>, FutureError> {
    futures.iter().map(|f| f.value()).collect()
}

/// `resolved()` across a collection.
pub fn all_resolved(futures: &[Future]) -> bool {
    futures.iter().all(|f| f.resolved())
}

/// Helper: evaluate `expr` via a transient future and return its value
/// (used by tests and the conformance suite).
pub fn value_of(expr: Expr, env: &Env) -> Result<Value, FutureError> {
    future(expr, env)?.value()
}

/// Re-raise helper mirroring R's `tryCatch(value(f), error = ...)`:
/// maps a relayed evaluation error through `handler`, passes
/// infrastructure errors through.
pub fn try_value(
    f: &Future,
    handler: impl FnOnce(&EvalError) -> Value,
) -> Result<Value, FutureError> {
    match f.value() {
        Ok(v) => Ok(v),
        Err(FutureError::Eval(e)) => Ok(handler(&e)),
        Err(other) => Err(other),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::plan::{with_plan, PlanSpec};

    #[test]
    fn future_value_resolved_roundtrip() {
        with_plan(PlanSpec::sequential(), || {
            let mut env = Env::new();
            env.insert("x", 1i64);
            let f = future(Expr::add(Expr::var("x"), Expr::lit(1i64)), &env).unwrap();
            assert!(f.resolved());
            assert_eq!(f.value().unwrap(), Value::I64(2));
            // value() is repeatable.
            assert_eq!(f.value().unwrap(), Value::I64(2));
        });
    }

    #[test]
    fn creation_time_capture_paper_example() {
        // x <- 1; f <- future(slow_fcn(x)); x <- 2; value(f) uses x == 1.
        with_plan(PlanSpec::sequential(), || {
            let mut env = Env::new();
            env.insert("x", 1i64);
            let f = future(Expr::mul(Expr::var("x"), Expr::lit(100i64)), &env).unwrap();
            env.insert("x", 2i64);
            assert_eq!(f.value().unwrap(), Value::I64(100));
        });
    }

    #[test]
    fn missing_global_fails_at_creation() {
        with_plan(PlanSpec::sequential(), || {
            let env = Env::new();
            let err = future(Expr::var("nope"), &env).unwrap_err();
            assert!(matches!(err, FutureError::MissingGlobal { .. }));
        });
    }

    #[test]
    fn eval_error_relayed_as_is() {
        with_plan(PlanSpec::sequential(), || {
            let env = Env::new();
            let f = future(Expr::stop(Expr::lit("boom")), &env).unwrap();
            match f.value() {
                Err(FutureError::Eval(e)) => assert_eq!(e.message, "boom"),
                other => panic!("expected eval error, got {other:?}"),
            }
        });
    }

    #[test]
    fn try_value_maps_eval_errors_like_trycatch() {
        with_plan(PlanSpec::sequential(), || {
            let env = Env::new();
            let f = future(Expr::stop(Expr::lit("x")), &env).unwrap();
            let v = try_value(&f, |_| Value::F64(f64::NAN)).unwrap();
            assert!(v.as_f64().unwrap().is_nan());
        });
    }

    #[test]
    fn lazy_future_defers_launch() {
        with_plan(PlanSpec::sequential(), || {
            let env = Env::new();
            let f = future_with(Expr::lit(9i64), &env, FutureOpts::new().lazy()).unwrap();
            // Not resolved until poked...
            assert!(f.resolved()); // resolved() launches it (sequential: instant)
            assert_eq!(f.value().unwrap(), Value::I64(9));
        });
    }

    #[test]
    fn values_collects_in_order() {
        with_plan(PlanSpec::multicore(2), || {
            let env = Env::new();
            let fs: Vec<Future> = (0..5)
                .map(|i| future(Expr::lit(i as i64), &env).unwrap())
                .collect();
            let vs = values(&fs).unwrap();
            assert_eq!(vs, (0..5).map(Value::I64).collect::<Vec<_>>());
        });
    }

    #[test]
    fn resolve_all_makes_every_value_nonblocking() {
        with_plan(PlanSpec::multicore(2), || {
            let env = Env::new();
            let fs: Vec<Future> = (0..5)
                .map(|i| {
                    future(
                        Expr::seq(vec![Expr::Spin { millis: 5 }, Expr::lit(i as i64)]),
                        &env,
                    )
                    .unwrap()
                })
                .collect();
            resolve(&fs);
            for (i, f) in fs.iter().enumerate() {
                assert!(f.resolved(), "future {i} unresolved after resolve()");
                assert_eq!(f.value().unwrap(), Value::I64(i as i64));
            }
        });
    }

    #[test]
    fn resolve_any_returns_a_resolved_index() {
        with_plan(PlanSpec::multicore(2), || {
            let env = Env::new();
            let fs: Vec<Future> = (0..3)
                .map(|i| future(Expr::lit(i as i64), &env).unwrap())
                .collect();
            let i = resolve_any(&fs).expect("non-empty set");
            assert!(fs[i].resolved());
            assert_eq!(fs[i].value().unwrap(), Value::I64(i as i64));
        });
        assert_eq!(resolve_any(&[]), None);
    }

    #[test]
    fn future_set_reports_each_index_exactly_once() {
        with_plan(PlanSpec::multicore(2), || {
            let env = Env::new();
            let fs: Vec<Future> = (0..6)
                .map(|i| future(Expr::lit(i as i64), &env).unwrap())
                .collect();
            let mut set = FutureSet::new(&fs);
            let mut seen = Vec::new();
            while let Some(i) = set.wait_any() {
                seen.push(i);
            }
            seen.sort_unstable();
            assert_eq!(seen, (0..6).collect::<Vec<_>>());
            assert_eq!(set.pending(), 0);
            assert_eq!(set.wait_any(), None, "exhausted set stays exhausted");
        });
    }

    #[test]
    fn future_set_launches_lazy_futures() {
        with_plan(PlanSpec::sequential(), || {
            let env = Env::new();
            let f = future_with(Expr::lit(3i64), &env, FutureOpts::new().lazy()).unwrap();
            let mut set = FutureSet::new(std::iter::once(&f));
            assert_eq!(set.wait_any(), Some(0));
            assert_eq!(f.value().unwrap(), Value::I64(3));
        });
    }

    #[test]
    fn duplicated_future_in_a_set_does_not_hang() {
        // Backends keep one subscription per handle (last wins), so the
        // first token for a duplicated future is displaced — the sweep
        // fallback must still report both indices.
        with_plan(PlanSpec::multicore(1), || {
            let env = Env::new();
            let f = future(Expr::Spin { millis: 30 }, &env).unwrap();
            let mut set = FutureSet::new([&f, &f]);
            let a = set.wait_any().expect("first index");
            let b = set.wait_any().expect("second index");
            let mut got = vec![a, b];
            got.sort_unstable();
            assert_eq!(got, vec![0, 1]);
            assert_eq!(set.wait_any(), None);
        });
    }

    #[test]
    fn failed_futures_count_as_resolved_in_sets() {
        with_plan(PlanSpec::multicore(1), || {
            let env = Env::new();
            let fs = vec![
                future(Expr::stop(Expr::lit("boom")), &env).unwrap(),
                future(Expr::lit(1i64), &env).unwrap(),
            ];
            resolve(&fs); // must terminate despite the eval error
            assert!(fs[0].value().is_err());
            assert_eq!(fs[1].value().unwrap(), Value::I64(1));
        });
    }

    #[test]
    fn queued_future_resolves_with_correct_value() {
        with_plan(PlanSpec::multicore(1), || {
            let env = Env::new();
            // Occupy the single worker, then enqueue without blocking.
            let slow = future(Expr::Spin { millis: 60 }, &env).unwrap();
            let f = future_with(
                Expr::add(Expr::lit(20i64), Expr::lit(22i64)),
                &env,
                FutureOpts::new().queued(),
            )
            .unwrap();
            assert_eq!(f.value().unwrap(), Value::I64(42));
            slow.value().unwrap();
        });
    }

    #[test]
    fn deadline_expiry_latches_timed_out_terminally() {
        with_plan(PlanSpec::multicore(1), || {
            let env = Env::new();
            // Many small elements so the post-expiry cancel interrupts the
            // chunk at the next yield point (the pool tears down fast).
            let body = Arc::new(Expr::Spin { millis: 10 });
            let elements: Vec<Value> = (0..500).map(Value::I64).collect();
            let f = future_with(
                Expr::map_chunk("x", body, elements, 0),
                &env,
                FutureOpts::new().deadline(Duration::from_millis(60)),
            )
            .unwrap();
            match f.value() {
                Err(FutureError::TimedOut { elapsed, attempts }) => {
                    assert_eq!(attempts, 1);
                    assert!(elapsed >= Duration::from_millis(60));
                }
                other => panic!("expected TimedOut, got {other:?}"),
            }
            // Latched terminally: later probes and collections replay it.
            assert!(f.resolved());
            assert!(matches!(f.value(), Err(FutureError::TimedOut { .. })));
        });
    }

    #[test]
    fn resolved_probe_latches_deadline_expiry() {
        with_plan(PlanSpec::multicore(1), || {
            let env = Env::new();
            let body = Arc::new(Expr::Spin { millis: 10 });
            let elements: Vec<Value> = (0..500).map(Value::I64).collect();
            let f = future_with(
                Expr::map_chunk("x", body, elements, 0),
                &env,
                FutureOpts::new().deadline(Duration::from_millis(40)),
            )
            .unwrap();
            assert!(!f.resolved(), "deadline not expired yet");
            std::thread::sleep(Duration::from_millis(60));
            assert!(f.resolved(), "expired future must probe as resolved");
            assert!(matches!(f.value(), Err(FutureError::TimedOut { .. })));
        });
    }

    #[test]
    fn deadline_does_not_fire_on_a_fast_future() {
        with_plan(PlanSpec::multicore(1), || {
            let env = Env::new();
            let f = future_with(
                Expr::lit(5i64),
                &env,
                FutureOpts::new().deadline(Duration::from_secs(30)),
            )
            .unwrap();
            assert_eq!(f.value().unwrap(), Value::I64(5));
        });
    }

    #[test]
    fn session_default_deadline_applies_with_opts_override() {
        use crate::api::session::Session;
        let s = Session::new();
        s.plan(PlanSpec::multicore(1));
        s.set_default_deadline(Some(Duration::from_millis(50)));
        s.scope(|_| {
            let env = Env::new();
            let body = Arc::new(Expr::Spin { millis: 10 });
            let elements: Vec<Value> = (0..500).map(Value::I64).collect();
            // Inherits the session default: times out.
            let f = future(Expr::map_chunk("x", body, elements, 0), &env).unwrap();
            assert!(matches!(f.value(), Err(FutureError::TimedOut { .. })));
            // Per-future override wins over the (tiny) session default.
            let g = future_with(
                Expr::Sleep { millis: 80 },
                &env,
                FutureOpts::new().deadline(Duration::from_secs(30)),
            )
            .unwrap();
            assert!(g.value().is_ok(), "explicit deadline must override the default");
        });
        s.close();
    }

    #[test]
    fn cached_future_hits_in_memory_and_skips_capacity() {
        use crate::api::session::Session;
        let s = Session::new();
        s.plan(PlanSpec::sequential());
        s.scope(|_| {
            let mut env = Env::new();
            env.insert("x", 20i64);
            let expr = || Expr::add(Expr::var("x"), Expr::lit(22i64));
            let cold = future_with(expr(), &env, FutureOpts::new().cached()).unwrap();
            assert_eq!(cold.value().unwrap(), Value::I64(42));
            let warm = future_with(expr(), &env, FutureOpts::new().cached()).unwrap();
            assert!(warm.resolved());
            assert_eq!(warm.value().unwrap(), Value::I64(42));
        });
        let c = crate::cache::session_counters(s.id());
        assert_eq!(c.memory.hits, 1, "second creation must be served by the cache");
        assert!(c.memory.publishes >= 1, "cold resolution must publish");
        s.close();
    }

    #[test]
    fn stream_indices_assigned_by_creation_order() {
        with_plan(PlanSpec::sequential(), || {
            reset_session_counter();
            let env = Env::new();
            let f1 = future_with(Expr::rnorm(2), &env, FutureOpts::new().seed(42)).unwrap();
            let f2 = future_with(Expr::rnorm(2), &env, FutureOpts::new().seed(42)).unwrap();
            let v1 = f1.value().unwrap();
            let v2 = f2.value().unwrap();
            // Different streams → different draws.
            assert_ne!(v1, v2);

            // Re-run the "session": identical results.
            reset_session_counter();
            let g1 = future_with(Expr::rnorm(2), &env, FutureOpts::new().seed(42)).unwrap();
            let g2 = future_with(Expr::rnorm(2), &env, FutureOpts::new().seed(42)).unwrap();
            assert_eq!(v1, g1.value().unwrap());
            assert_eq!(v2, g2.value().unwrap());
        });
    }
}
