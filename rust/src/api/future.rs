//! The three atomic constructs: `future()`, `value()`, `resolved()`.
//!
//! ```text
//! f <- future(expr)   →  let f = future(expr, &env)?;
//! v <- value(f)       →  let v = f.value()?;
//! r <- resolved(f)    →  let r = f.resolved();
//! ```
//!
//! `future()` captures globals at creation (static analysis over the
//! expression), assigns an RNG stream index by creation order, picks the
//! backend from the current `plan()` at the current nesting depth, and
//! launches — blocking only if every worker is busy.  `value()` blocks until
//! resolution, relays captured stdout + conditions in order, and re-raises
//! evaluation errors as-is.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{SystemTime, UNIX_EPOCH};

use crate::api::conditions::{relay, Condition, ConditionKind};
use crate::api::env::Env;
use crate::api::error::{EvalError, FutureError};
use crate::api::expr::Expr;
use crate::api::globals::{identify_globals, GlobalsSpec};
use crate::api::plan::{backend_for_current_depth, current_depth};
use crate::api::value::Value;
use crate::backend::TaskHandle;
use crate::ipc::{TaskOpts, TaskOutcome, TaskResult, TaskSpec};
use crate::metrics::{record_event, FutureTrace};
use crate::util::uuid_v4;

/// Session-global future-creation counter: the deterministic RNG stream
/// index assignment ("fully reproducible regardless of backend and number
/// of workers").
static CREATION_COUNTER: AtomicU64 = AtomicU64::new(0);

/// Restart the creation counter (new "session"; benches/tests).
pub fn reset_session_counter() {
    CREATION_COUNTER.store(0, Ordering::SeqCst);
}

fn now_ns() -> u64 {
    SystemTime::now().duration_since(UNIX_EPOCH).unwrap_or_default().as_nanos() as u64
}

/// Options for [`future_with`] — the `future(...)` arguments.
#[derive(Debug, Clone, Default)]
pub struct FutureOpts {
    /// `seed = TRUE` analog: base seed for this future's RNG stream.
    pub seed: Option<u64>,
    /// Override the automatically assigned stream index (map-reduce layers
    /// use this for per-element streams).
    pub stream_index: Option<u64>,
    /// Globals determination (`globals=` argument).
    pub globals: GlobalsSpec,
    /// Capture stdout on the worker (default true).
    pub stdout: bool,
    /// Capture conditions on the worker (default true).
    pub conditions: bool,
    /// `lazy = TRUE`: defer launch until `resolved()`/`value()`.
    pub lazy: bool,
    /// Keep the task spec so the future can be [`Future::restart`]ed after
    /// an infrastructure failure (paper's `restart(f)` future-work item).
    /// Off by default.  (Retention is cheap since tensor payloads are
    /// Arc-shared — the clone is O(1) in payload bytes.)
    pub restartable: bool,
    /// Human-readable label.
    pub label: Option<String>,
}

impl FutureOpts {
    pub fn new() -> Self {
        FutureOpts { stdout: true, conditions: true, ..Default::default() }
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = Some(seed);
        self
    }

    pub fn globals(mut self, spec: GlobalsSpec) -> Self {
        self.globals = spec;
        self
    }

    pub fn lazy(mut self) -> Self {
        self.lazy = true;
        self
    }

    pub fn restartable(mut self) -> Self {
        self.restartable = true;
        self
    }

    pub fn label(mut self, label: &str) -> Self {
        self.label = Some(label.to_string());
        self
    }

    pub fn no_capture(mut self) -> Self {
        self.stdout = false;
        self.conditions = false;
        self
    }
}

enum State {
    /// `lazy = TRUE` and not yet launched.
    Lazy(Box<TaskSpec>),
    /// Launched on a backend.
    Running { handle: Box<dyn TaskHandle>, supports_immediate: bool },
    /// Result collected from the handle (value() may be called repeatedly).
    Done(Box<TaskResult>),
    /// Infrastructure failure captured for replay on later calls — the
    /// original [`FutureError`] is kept (not stringified) so its kind
    /// survives: a `WorkerDied` future stays recoverable however often it
    /// is probed or collected.
    Failed(FutureError),
}

/// A future: a placeholder for the value of `expr` evaluated with the
/// globals captured at creation.
pub struct Future {
    id: String,
    label: Option<String>,
    state: Mutex<State>,
    /// Whether the expression may draw RNG without `seed` (misuse warning).
    warn_unseeded_rng: bool,
    relayed: Mutex<bool>,
    /// Retained spec for [`Future::restart`] (opt-in via
    /// [`FutureOpts::restartable`]).
    restart_spec: Mutex<Option<TaskSpec>>,
    pub trace: Arc<FutureTrace>,
}

/// Create a future with default options (eager, auto globals, no seed).
pub fn future(expr: Expr, env: &Env) -> Result<Future, FutureError> {
    future_with(expr, env, FutureOpts::new())
}

/// Create a future with explicit options.
pub fn future_with(expr: Expr, env: &Env, opts: FutureOpts) -> Result<Future, FutureError> {
    let id = uuid_v4();
    let created_ns = now_ns();

    // 1. Identify and snapshot globals (creation-time capture).
    let globals = identify_globals(&expr, env, &opts.globals)?;

    // 2. Deterministic RNG stream index by creation order.
    let ordinal = CREATION_COUNTER.fetch_add(1, Ordering::SeqCst);
    let stream_index = opts.stream_index.unwrap_or(ordinal);

    // 3. Backend + nested topology for the current nesting depth.
    let depth = current_depth();
    let (backend, nested_plan) = backend_for_current_depth()?;

    let warn_unseeded_rng = opts.seed.is_none() && expr.uses_rng();

    let task = TaskSpec {
        id: id.clone(),
        expr,
        globals,
        opts: TaskOpts {
            seed: opts.seed,
            stream_index,
            capture_stdout: opts.stdout,
            capture_conditions: opts.conditions,
            label: opts.label.clone(),
            depth,
            nested_plan,
        },
    };

    let trace = Arc::new(FutureTrace::new(&id, opts.label.as_deref(), backend.name(), created_ns));

    let restart_spec = if opts.restartable { Some(task.clone()) } else { None };
    let state = if opts.lazy {
        State::Lazy(Box::new(task))
    } else {
        let supports_immediate = backend.supports_immediate();
        record_event(&trace, "launch");
        let handle = backend.launch(task)?;
        State::Running { handle, supports_immediate }
    };

    Ok(Future {
        id,
        label: opts.label,
        state: Mutex::new(state),
        warn_unseeded_rng,
        relayed: Mutex::new(false),
        restart_spec: Mutex::new(restart_spec),
        trace,
    })
}

impl std::fmt::Debug for Future {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let state = match &*self.state.lock().unwrap() {
            State::Lazy(_) => "lazy",
            State::Running { .. } => "running",
            State::Done(_) => "done",
            State::Failed(_) => "failed",
        };
        f.debug_struct("Future")
            .field("id", &self.id)
            .field("label", &self.label)
            .field("state", &state)
            .finish()
    }
}

impl Future {
    pub fn id(&self) -> &str {
        &self.id
    }

    pub fn label(&self) -> Option<&str> {
        self.label.as_deref()
    }

    /// Launch a lazy future now (no-op otherwise).
    pub fn launch(&self) -> Result<(), FutureError> {
        let mut state = self.state.lock().unwrap();
        if let State::Lazy(_) = &*state {
            // A failed launch attempt is TERMINAL for this future: the real
            // error (kind intact) is latched into State::Failed, so
            // resolved(), value(), and result() all replay the same failure
            // no matter which is called first — mirroring eager futures,
            // which error at creation.  Retry is the restart() /
            // FutureOpts::restartable path, not silent relaunching.
            let (backend, _) = match backend_for_current_depth() {
                Ok(b) => b,
                Err(e) => {
                    *state = State::Failed(e.clone());
                    return Err(e);
                }
            };
            let placeholder = State::Failed(FutureError::Launch("launch in progress".into()));
            let task = match std::mem::replace(&mut *state, placeholder) {
                State::Lazy(t) => t,
                _ => unreachable!(),
            };
            let supports_immediate = backend.supports_immediate();
            record_event(&self.trace, "launch");
            match backend.launch(*task) {
                Ok(handle) => *state = State::Running { handle, supports_immediate },
                Err(e) => {
                    *state = State::Failed(e.clone());
                    return Err(e);
                }
            }
        }
        Ok(())
    }

    /// Non-blocking resolution probe.  A lazy future is launched by the
    /// first `resolved()` call ("a lazy future defers evaluation until we
    /// use resolved() ... or value()").
    pub fn resolved(&self) -> bool {
        {
            let state = self.state.lock().unwrap();
            match &*state {
                State::Done(_) | State::Failed(_) => return true,
                State::Lazy(_) => {}
                State::Running { .. } => {}
            }
        }
        // Lazy: launch first (outside the match to avoid double-lock).
        // A launch error latches State::Failed inside launch(), so the
        // match below reports it as resolved — pollers never spin forever.
        if matches!(&*self.state.lock().unwrap(), State::Lazy(_)) {
            let _ = self.launch();
        }
        let mut state = self.state.lock().unwrap();
        match &mut *state {
            State::Running { handle, .. } => {
                if handle.is_resolved() {
                    // Promote to Done so value() won't block.
                    match handle.wait() {
                        Ok(result) => {
                            record_event(&self.trace, "resolved");
                            *state = State::Done(Box::new(result));
                        }
                        Err(e) => *state = State::Failed(e),
                    }
                    true
                } else {
                    false
                }
            }
            State::Done(_) | State::Failed(_) => true,
            // Not reachable in practice: launch() above either converted the
            // state or latched its error.  Defensive false, not a panic.
            State::Lazy(_) => false,
        }
    }

    /// Block until resolved; relay captured output/conditions; return the
    /// value or re-raise the evaluation error as-is.
    pub fn value(&self) -> Result<Value, FutureError> {
        let result = self.result()?;
        self.relay_once(&result);
        match result.outcome {
            TaskOutcome::Ok(v) => Ok(v),
            TaskOutcome::Err(e) => Err(FutureError::Eval(e)),
        }
    }

    /// Like [`Self::value`] but returns the full result (value + captured
    /// output + metrics) without relaying — programmatic access.
    pub fn result(&self) -> Result<TaskResult, FutureError> {
        // Lazy futures launch on first value()/result().
        if matches!(&*self.state.lock().unwrap(), State::Lazy(_)) {
            self.launch()?;
        }
        let mut state = self.state.lock().unwrap();
        match &mut *state {
            State::Done(r) => Ok((**r).clone()),
            State::Failed(e) => Err(e.clone()),
            State::Running { handle, .. } => {
                record_event(&self.trace, "collect-wait");
                match handle.wait() {
                    Ok(result) => {
                        record_event(&self.trace, "resolved");
                        *state = State::Done(Box::new(result.clone()));
                        Ok(result)
                    }
                    Err(e) => {
                        *state = State::Failed(e.clone());
                        Err(e)
                    }
                }
            }
            State::Lazy(_) => Err(FutureError::Launch("lazy future failed to launch".into())),
        }
    }

    /// Relay captured output + conditions exactly once across repeated
    /// `value()` calls.
    fn relay_once(&self, result: &TaskResult) {
        let mut relayed = self.relayed.lock().unwrap();
        if *relayed {
            return;
        }
        *relayed = true;

        let skip_immediate = {
            let state = self.state.lock().unwrap();
            match &*state {
                State::Running { supports_immediate, .. } => *supports_immediate,
                // Done: the handle is gone; infer from captured data — the
                // live-relaying backends already emitted immediates.
                _ => self.backend_relayed_immediates(),
            }
        };

        let mut captured = result.captured.clone();
        // The paper's RNG-misuse warning: "the future framework will
        // generate an informative warning" when RNG is used without seed.
        if (self.warn_unseeded_rng || captured.rng_used) && result.captured.rng_used {
            captured.conditions.push(Condition {
                kind: ConditionKind::Warning,
                message: format!(
                    "UnexpectedRandomNumbers: future ('{}') drew random numbers without seed = TRUE; \
                     results may be statistically unsound",
                    self.label.as_deref().unwrap_or(&self.id)
                ),
                seq: u64::MAX, // after all captured conditions
            });
        }
        relay(&captured, skip_immediate);
    }

    fn backend_relayed_immediates(&self) -> bool {
        // Conservative: only in-process backends relay live, and they mark
        // supports_immediate at launch; after Done we keep relaying
        // immediates unless we know better. False = relay them here too.
        false
    }

    /// `restart(f)` — the paper's future-work item: relaunch this future
    /// (e.g. after a crashed worker / cancelled job), reusing the captured
    /// globals and options.  Requires [`FutureOpts::restartable`].
    ///
    /// Any previous run is cancelled; relay state resets so output relays
    /// again from the fresh run.
    pub fn restart(&self) -> Result<(), FutureError> {
        let spec = self
            .restart_spec
            .lock()
            .unwrap()
            .clone()
            .ok_or_else(|| FutureError::Launch(
                "future was not created with restartable()".into(),
            ))?;
        // Stop whatever is in flight.
        {
            let mut state = self.state.lock().unwrap();
            if let State::Running { handle, .. } = &mut *state {
                handle.cancel();
            }
        }
        let (backend, _) = backend_for_current_depth()?;
        let supports_immediate = backend.supports_immediate();
        record_event(&self.trace, "restart");
        let handle = backend.launch(spec)?;
        *self.state.lock().unwrap() = State::Running { handle, supports_immediate };
        *self.relayed.lock().unwrap() = false;
        Ok(())
    }

    /// Best-effort cancellation (extension feature).
    pub fn cancel(&self) -> bool {
        let mut state = self.state.lock().unwrap();
        match &mut *state {
            State::Running { handle, .. } => handle.cancel(),
            State::Lazy(_) => {
                *state = State::Failed(FutureError::Cancelled);
                true
            }
            _ => false,
        }
    }
}

/// `value()` for a collection: resolve all, in order (S3 `value()` on
/// lists in the paper's future-work section).
pub fn values(futures: &[Future]) -> Result<Vec<Value>, FutureError> {
    futures.iter().map(|f| f.value()).collect()
}

/// `resolved()` across a collection.
pub fn all_resolved(futures: &[Future]) -> bool {
    futures.iter().all(|f| f.resolved())
}

/// Helper: evaluate `expr` via a transient future and return its value
/// (used by tests and the conformance suite).
pub fn value_of(expr: Expr, env: &Env) -> Result<Value, FutureError> {
    future(expr, env)?.value()
}

/// Re-raise helper mirroring R's `tryCatch(value(f), error = ...)`:
/// maps a relayed evaluation error through `handler`, passes
/// infrastructure errors through.
pub fn try_value(
    f: &Future,
    handler: impl FnOnce(&EvalError) -> Value,
) -> Result<Value, FutureError> {
    match f.value() {
        Ok(v) => Ok(v),
        Err(FutureError::Eval(e)) => Ok(handler(&e)),
        Err(other) => Err(other),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::plan::{with_plan, PlanSpec};

    #[test]
    fn future_value_resolved_roundtrip() {
        with_plan(PlanSpec::sequential(), || {
            let mut env = Env::new();
            env.insert("x", 1i64);
            let f = future(Expr::add(Expr::var("x"), Expr::lit(1i64)), &env).unwrap();
            assert!(f.resolved());
            assert_eq!(f.value().unwrap(), Value::I64(2));
            // value() is repeatable.
            assert_eq!(f.value().unwrap(), Value::I64(2));
        });
    }

    #[test]
    fn creation_time_capture_paper_example() {
        // x <- 1; f <- future(slow_fcn(x)); x <- 2; value(f) uses x == 1.
        with_plan(PlanSpec::sequential(), || {
            let mut env = Env::new();
            env.insert("x", 1i64);
            let f = future(Expr::mul(Expr::var("x"), Expr::lit(100i64)), &env).unwrap();
            env.insert("x", 2i64);
            assert_eq!(f.value().unwrap(), Value::I64(100));
        });
    }

    #[test]
    fn missing_global_fails_at_creation() {
        with_plan(PlanSpec::sequential(), || {
            let env = Env::new();
            let err = future(Expr::var("nope"), &env).unwrap_err();
            assert!(matches!(err, FutureError::MissingGlobal { .. }));
        });
    }

    #[test]
    fn eval_error_relayed_as_is() {
        with_plan(PlanSpec::sequential(), || {
            let env = Env::new();
            let f = future(Expr::stop(Expr::lit("boom")), &env).unwrap();
            match f.value() {
                Err(FutureError::Eval(e)) => assert_eq!(e.message, "boom"),
                other => panic!("expected eval error, got {other:?}"),
            }
        });
    }

    #[test]
    fn try_value_maps_eval_errors_like_trycatch() {
        with_plan(PlanSpec::sequential(), || {
            let env = Env::new();
            let f = future(Expr::stop(Expr::lit("x")), &env).unwrap();
            let v = try_value(&f, |_| Value::F64(f64::NAN)).unwrap();
            assert!(v.as_f64().unwrap().is_nan());
        });
    }

    #[test]
    fn lazy_future_defers_launch() {
        with_plan(PlanSpec::sequential(), || {
            let env = Env::new();
            let f = future_with(Expr::lit(9i64), &env, FutureOpts::new().lazy()).unwrap();
            // Not resolved until poked...
            assert!(f.resolved()); // resolved() launches it (sequential: instant)
            assert_eq!(f.value().unwrap(), Value::I64(9));
        });
    }

    #[test]
    fn values_collects_in_order() {
        with_plan(PlanSpec::multicore(2), || {
            let env = Env::new();
            let fs: Vec<Future> = (0..5)
                .map(|i| future(Expr::lit(i as i64), &env).unwrap())
                .collect();
            let vs = values(&fs).unwrap();
            assert_eq!(vs, (0..5).map(Value::I64).collect::<Vec<_>>());
        });
    }

    #[test]
    fn stream_indices_assigned_by_creation_order() {
        with_plan(PlanSpec::sequential(), || {
            reset_session_counter();
            let env = Env::new();
            let f1 = future_with(Expr::rnorm(2), &env, FutureOpts::new().seed(42)).unwrap();
            let f2 = future_with(Expr::rnorm(2), &env, FutureOpts::new().seed(42)).unwrap();
            let v1 = f1.value().unwrap();
            let v2 = f2.value().unwrap();
            // Different streams → different draws.
            assert_ne!(v1, v2);

            // Re-run the "session": identical results.
            reset_session_counter();
            let g1 = future_with(Expr::rnorm(2), &env, FutureOpts::new().seed(42)).unwrap();
            let g2 = future_with(Expr::rnorm(2), &env, FutureOpts::new().seed(42)).unwrap();
            assert_eq!(v1, g1.value().unwrap());
            assert_eq!(v2, g2.value().unwrap());
        });
    }
}
