//! Automatic identification of globals — the paper's `globals`/`codetools`
//! machinery.
//!
//! "By default, `future()` will attempt to identify, locate, and record
//! these globals internally via static code inspection."  Here the static
//! inspection is a free-variable analysis over the [`Expr`] AST: walk the
//! tree in order, track `Let`-bound locals, and record every `Var` not bound
//! at its use site.  The strategy is *optimistic* (false positives allowed —
//! an unused captured variable costs only transfer bytes; false negatives
//! produce runtime errors, exactly as in the paper's `get("k")` example).

use std::collections::BTreeSet;

use crate::api::env::Env;
use crate::api::error::FutureError;
use crate::api::expr::Expr;

/// How globals are determined for a future (the `globals=` argument).
#[derive(Debug, Clone, PartialEq, Default)]
pub enum GlobalsSpec {
    /// Automatic static identification (the default).
    #[default]
    Auto,
    /// Automatic + these extra names (the paper's fix for `get("k")`).
    AutoPlus(Vec<String>),
    /// Exactly these names; static analysis skipped
    /// (the "manually specifying globals" overhead opt-out).
    Explicit(Vec<String>),
    /// Capture nothing (expression must be closed).
    None,
}

/// Free variables of `expr`, in first-use order, deduplicated.
pub fn free_variables(expr: &Expr) -> Vec<String> {
    let mut bound: Vec<String> = Vec::new();
    let mut seen: BTreeSet<String> = BTreeSet::new();
    let mut out: Vec<String> = Vec::new();
    collect(expr, &mut bound, &mut seen, &mut out);
    out
}

fn collect(
    expr: &Expr,
    bound: &mut Vec<String>,
    seen: &mut BTreeSet<String>,
    out: &mut Vec<String>,
) {
    match expr {
        Expr::Var(name) => {
            if !bound.iter().any(|b| b == name) && seen.insert(name.clone()) {
                out.push(name.clone());
            }
        }
        Expr::Let { name, value, body } => {
            // `value` is evaluated before the binding is in scope.
            collect(value, bound, seen, out);
            bound.push(name.clone());
            collect(body, bound, seen, out);
            bound.pop();
        }
        Expr::Seq(items) | Expr::List(items) => {
            for e in items {
                collect(e, bound, seen, out);
            }
        }
        Expr::Index { list, index } => {
            collect(list, bound, seen, out);
            collect(index, bound, seen, out);
        }
        Expr::Call { args, .. } | Expr::Prim { args, .. } => {
            for e in args {
                collect(e, bound, seen, out);
            }
        }
        Expr::If { cond, then, otherwise } => {
            collect(cond, bound, seen, out);
            collect(then, bound, seen, out);
            collect(otherwise, bound, seen, out);
        }
        // The point of DynLookup: its *name expression* is analyzed (it may
        // reference variables) but the looked-up name itself is invisible
        // to static analysis — the paper's get("k") trap.
        Expr::DynLookup(inner) => collect(inner, bound, seen, out),
        Expr::Emit { message, .. } => collect(message, bound, seen, out),
        Expr::Stop(inner) => collect(inner, bound, seen, out),
        Expr::WithRngStream { body, .. } => collect(body, bound, seen, out),
        // The chunk parameter is locally bound inside the shared body;
        // elements are literal values and contribute no names.
        Expr::MapChunk { param, body, .. } => {
            bound.push(param.clone());
            collect(body, bound, seen, out);
            bound.pop();
        }
        // `Await` is not a free variable: its binding arrives out-of-band
        // as a Forward frame (or a creation-time prebind), never from the
        // caller's environment.
        Expr::Lit(_)
        | Expr::Rng { .. }
        | Expr::Spin { .. }
        | Expr::Sleep { .. }
        | Expr::Work { .. }
        | Expr::ChaosKill { .. }
        | Expr::ChaosHang { .. }
        | Expr::Await { .. } => {}
    }
}

/// Resolve the globals of `expr` against `env` per `spec`.
///
/// Returns the captured snapshot.  Unresolvable names found by static
/// analysis produce [`FutureError::MissingGlobal`] at *creation* time —
/// mirroring the framework's early failure — while names hidden behind
/// `DynLookup` surface only at evaluation time (as in R).
pub fn identify_globals(
    expr: &Expr,
    env: &Env,
    spec: &GlobalsSpec,
) -> Result<Env, FutureError> {
    let names: Vec<String> = match spec {
        GlobalsSpec::Auto => free_variables(expr),
        GlobalsSpec::AutoPlus(extra) => {
            let mut names = free_variables(expr);
            for e in extra {
                if !names.contains(e) {
                    names.push(e.clone());
                }
            }
            names
        }
        GlobalsSpec::Explicit(names) => names.clone(),
        GlobalsSpec::None => Vec::new(),
    };

    let mut captured = Env::new();
    for name in &names {
        match env.get(name) {
            Some(v) => captured.insert(name, v.clone()),
            None => {
                return Err(FutureError::MissingGlobal { name: name.clone() });
            }
        }
    }
    Ok(captured)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::value::Value;

    #[test]
    fn finds_simple_free_vars_in_order() {
        let e = Expr::add(Expr::var("b"), Expr::mul(Expr::var("a"), Expr::var("b")));
        assert_eq!(free_variables(&e), vec!["b", "a"]);
    }

    #[test]
    fn let_binds_locally() {
        // let a = x in a + y  →  free: x, y (not a)
        let e = Expr::let_in("a", Expr::var("x"), Expr::add(Expr::var("a"), Expr::var("y")));
        assert_eq!(free_variables(&e), vec!["x", "y"]);
    }

    #[test]
    fn let_value_evaluated_outside_binding_scope() {
        // let a = a in a  →  the RHS `a` is free (R: value looked up in the
        // enclosing env), the body `a` is bound.
        let e = Expr::let_in("a", Expr::var("a"), Expr::var("a"));
        assert_eq!(free_variables(&e), vec!["a"]);
    }

    #[test]
    fn shadowing_pops_correctly() {
        // (let x = 1 in x) + x  →  the second x is free.
        let e = Expr::add(
            Expr::let_in("x", Expr::lit(1.0), Expr::var("x")),
            Expr::var("x"),
        );
        assert_eq!(free_variables(&e), vec!["x"]);
    }

    #[test]
    fn dyn_lookup_is_invisible() {
        // get("k") — static analysis sees nothing.
        let e = Expr::dyn_lookup(Expr::lit("k"));
        assert!(free_variables(&e).is_empty());
    }

    #[test]
    fn paper_fix_mention_variable_at_top() {
        // { k; get("k") } — mentioning k makes it a detected global.
        let e = Expr::seq(vec![Expr::var("k"), Expr::dyn_lookup(Expr::lit("k"))]);
        assert_eq!(free_variables(&e), vec!["k"]);
    }

    #[test]
    fn map_chunk_binds_param_like_let() {
        use crate::api::value::Value;
        use std::sync::Arc;
        // MapChunk{param: x, body: x + offset} → only `offset` is free,
        // matching the per-element `let x = <el> in body` desugaring.
        let body = Arc::new(Expr::add(Expr::var("x"), Expr::var("offset")));
        let chunk = Expr::map_chunk("x", body, vec![Value::I64(1)], 0);
        assert_eq!(free_variables(&chunk), vec!["offset"]);
    }

    #[test]
    fn identify_auto_captures_values() {
        let mut env = Env::new();
        env.insert("x", 5i64);
        let e = Expr::add(Expr::var("x"), Expr::lit(1i64));
        let captured = identify_globals(&e, &env, &GlobalsSpec::Auto).unwrap();
        assert_eq!(captured.get("x"), Some(&Value::I64(5)));
        assert_eq!(captured.len(), 1);
    }

    #[test]
    fn identify_missing_global_fails_at_creation() {
        let env = Env::new();
        let e = Expr::var("ghost");
        let err = identify_globals(&e, &env, &GlobalsSpec::Auto).unwrap_err();
        assert!(matches!(err, FutureError::MissingGlobal { ref name } if name == "ghost"));
    }

    #[test]
    fn identify_explicit_skips_analysis() {
        let mut env = Env::new();
        env.insert("k", 42i64);
        // get("k") with globals = "k" — the paper's second fix.
        let e = Expr::dyn_lookup(Expr::lit("k"));
        let captured =
            identify_globals(&e, &env, &GlobalsSpec::Explicit(vec!["k".into()])).unwrap();
        assert_eq!(captured.get("k"), Some(&Value::I64(42)));
    }

    #[test]
    fn identify_auto_plus_adds_extras() {
        let mut env = Env::new();
        env.insert("k", 1i64);
        env.insert("x", 2i64);
        let e = Expr::seq(vec![Expr::var("x"), Expr::dyn_lookup(Expr::lit("k"))]);
        let captured =
            identify_globals(&e, &env, &GlobalsSpec::AutoPlus(vec!["k".into()])).unwrap();
        assert_eq!(captured.len(), 2);
    }
}
