//! Lazy futures and `merge()` — the paper's Future-work sketch, implemented.
//!
//! "Imagine that we have a function merge() to merge futures.  This would
//! allow us to partition ten futures into only two futures, one per worker":
//! [`merge_futures`] combines the task specs of unlaunched lazy futures into
//! one chunk future whose value is the list of the originals' values —
//! exactly the load-balancing trick the high-level map-reduce APIs perform,
//! available at the core level.

use crate::api::env::Env;
use crate::api::error::FutureError;
use crate::api::expr::Expr;
use crate::api::future::{future_with, Future, FutureOpts};

/// A not-yet-launched future description (expression + creation env).
/// Building blocks for [`merge_futures`]; cheaper than full lazy [`Future`]s
/// because no backend interaction happens until the merged chunk launches.
#[derive(Debug, Clone)]
pub struct LazySpec {
    pub expr: Expr,
    pub stream_index: Option<u64>,
}

impl LazySpec {
    pub fn new(expr: Expr) -> Self {
        LazySpec { expr, stream_index: None }
    }

    /// Pin this element to an RNG substream (chunk-invariant randomness).
    pub fn with_stream(expr: Expr, index: u64) -> Self {
        LazySpec { expr, stream_index: Some(index) }
    }
}

/// Merge lazy specs into one future whose value is the list of their
/// values, evaluated left to right on a single worker.
pub fn merge_futures(
    specs: &[LazySpec],
    env: &Env,
    opts: FutureOpts,
) -> Result<Future, FutureError> {
    let elements: Vec<Expr> = specs
        .iter()
        .map(|s| match s.stream_index {
            Some(idx) => Expr::with_rng_stream(idx, s.expr.clone()),
            None => s.expr.clone(),
        })
        .collect();
    future_with(Expr::list(elements), env, opts)
}

/// Partition `specs` into `chunks` merged futures of near-equal size
/// (the "one future per worker" pattern).
pub fn merge_into_chunks(
    specs: &[LazySpec],
    chunks: usize,
    env: &Env,
    opts: FutureOpts,
) -> Result<Vec<Future>, FutureError> {
    let chunks = chunks.max(1).min(specs.len().max(1));
    let mut out = Vec::with_capacity(chunks);
    for range in crate::mapreduce::partition(specs.len(), chunks) {
        out.push(merge_futures(&specs[range], env, opts.clone())?);
    }
    Ok(out)
}

/// Flatten the values of merged chunk futures back into element order.
pub fn collect_merged(futures: &[Future]) -> Result<Vec<crate::api::value::Value>, FutureError> {
    let mut out = Vec::new();
    for f in futures {
        match f.value()? {
            crate::api::value::Value::List(items) => out.extend(items),
            other => out.push(other),
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::plan::{with_plan, PlanSpec};
    use crate::api::value::Value;

    #[test]
    fn merge_preserves_element_order_and_values() {
        with_plan(PlanSpec::sequential(), || {
            let env = Env::new();
            let specs: Vec<LazySpec> =
                (0..10).map(|i| LazySpec::new(Expr::lit(i as i64))).collect();
            let futures = merge_into_chunks(&specs, 2, &env, FutureOpts::new()).unwrap();
            assert_eq!(futures.len(), 2);
            let vs = collect_merged(&futures).unwrap();
            assert_eq!(vs, (0..10).map(Value::I64).collect::<Vec<_>>());
        });
    }

    #[test]
    fn merged_chunk_count_never_exceeds_elements() {
        with_plan(PlanSpec::sequential(), || {
            let env = Env::new();
            let specs = vec![LazySpec::new(Expr::lit(1i64))];
            let futures = merge_into_chunks(&specs, 8, &env, FutureOpts::new()).unwrap();
            assert_eq!(futures.len(), 1);
        });
    }

    #[test]
    fn merged_lazy_futures_launch_on_their_owning_session() {
        // A lazy merged future created under session S must resolve on S's
        // plan even when poked outside the scope (the Future carries its
        // session handle).
        let s = crate::api::session::Session::with_plan(PlanSpec::multicore(2));
        let env = Env::new();
        let specs: Vec<LazySpec> = (0..4).map(|i| LazySpec::new(Expr::lit(i as i64))).collect();
        let merged = s
            .scope(|_| merge_futures(&specs, &env, FutureOpts::new().lazy()))
            .unwrap();
        // Outside the scope now: launch + collect still target session S.
        assert_eq!(
            merged.value().unwrap(),
            Value::List((0..4).map(Value::I64).collect())
        );
        assert_eq!(merged.session_id(), s.id());
        s.close();
    }

    #[test]
    fn per_element_streams_survive_merging() {
        with_plan(PlanSpec::sequential(), || {
            let env = Env::new();
            let specs: Vec<LazySpec> =
                (0..4).map(|i| LazySpec::with_stream(Expr::runif(1), i as u64)).collect();
            let one = merge_into_chunks(&specs, 1, &env, FutureOpts::new().seed(11)).unwrap();
            let four = merge_into_chunks(&specs, 4, &env, FutureOpts::new().seed(11)).unwrap();
            assert_eq!(collect_merged(&one).unwrap(), collect_merged(&four).unwrap());
        });
    }
}
