//! The *Future API* and its cross-cutting services.
//!
//! Layout mirrors the paper's structure: the three atomic constructs live in
//! [`future`], backend selection in [`plan`], and the services every backend
//! inherits — globals identification, parallel RNG, condition relaying,
//! exception taxonomy — in their own modules.

pub mod conditions;
pub mod either;
pub mod env;
pub mod error;
pub mod expr;
pub mod future;
pub mod globals;
pub mod lazy;
pub mod plan;
pub mod promise;
pub mod rng;
pub mod session;
pub mod value;
