//! `plan()` — how and where futures are resolved.
//!
//! The defining design of the framework: *the end-user decides the backend*
//! via `plan()`, the developer never hard-codes one.  Supports single
//! backends (`plan(multisession)`) and nested topologies
//! (`plan(list(batchtools_sge, multisession))`), with the paper's built-in
//! protection against nested parallelism: any nesting level not explicitly
//! configured runs **sequentially**, so two future-using layers use N cores,
//! not N².
//!
//! Since the session-first redesign the plan state lives on a first-class
//! [`crate::api::session::Session`]; every free function here is a thin
//! wrapper over the *current* session (the innermost
//! [`crate::api::session::Session::scope`] on this thread, else the process
//! default) — existing call sites compile and behave unchanged, while
//! multiple sessions with different plans can coexist in one process.

use std::cell::Cell;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::api::error::FutureError;
use crate::api::session;
use crate::backend::supervisor::RetryPolicy;
use crate::backend::Backend;
use crate::util::available_cores;

/// A declarative backend specification — serializable, so nested topologies
/// travel to worker processes.
#[derive(Debug, Clone, PartialEq)]
pub enum PlanSpec {
    /// Resolve futures sequentially in the calling process (the default).
    Sequential,
    /// Shared-memory worker threads — the `multicore` (forked processing)
    /// analog: globals are inherited by reference, lowest latency.
    ThreadPool { workers: usize },
    /// Background worker OS processes over pipes — the `multisession`
    /// (SOCK cluster on localhost) analog.
    Multiprocess { workers: usize },
    /// TCP-socket workers, one per host — the `cluster`/PSOCK analog.
    /// Hosts are simulated locally (see DESIGN.md §Substitutions).
    Cluster { hosts: Vec<String> },
    /// Futures submitted as jobs to the (simulated) HPC scheduler — the
    /// `future.batchtools` analog: high latency, high throughput.
    Batch { workers: usize, submit_latency_ms: u64, poll_interval_ms: u64 },
    /// A third-party backend registered via [`register_backend`].
    Custom { name: String, workers: usize },
}

impl PlanSpec {
    /// `plan(sequential)`.
    pub fn sequential() -> Self {
        PlanSpec::Sequential
    }

    /// `plan(multicore, workers = n)`; `0` ⇒ `availableCores()`.
    pub fn multicore(workers: usize) -> Self {
        PlanSpec::ThreadPool { workers }
    }

    /// `plan(multisession, workers = n)`; `0` ⇒ `availableCores()`.
    pub fn multiprocess(workers: usize) -> Self {
        PlanSpec::Multiprocess { workers }
    }

    /// `plan(cluster, workers = c("n1", "n2", ...))`.
    pub fn cluster(hosts: &[&str]) -> Self {
        PlanSpec::Cluster { hosts: hosts.iter().map(|s| s.to_string()).collect() }
    }

    /// `plan(future.batchtools::batchtools_slurm)` with defaults.
    pub fn batch(workers: usize) -> Self {
        PlanSpec::Batch { workers, submit_latency_ms: 5, poll_interval_ms: 2 }
    }

    /// `tweak(spec, workers = n)` — adjust the worker count.
    pub fn tweak_workers(mut self, n: usize) -> Self {
        match &mut self {
            PlanSpec::Sequential => {}
            PlanSpec::ThreadPool { workers }
            | PlanSpec::Multiprocess { workers }
            | PlanSpec::Batch { workers, .. }
            | PlanSpec::Custom { workers, .. } => *workers = n,
            PlanSpec::Cluster { hosts } => {
                // Clamp to ≥ 1: an empty host list is rejected by
                // ClusterBackend::new even though effective_workers()
                // reports 1.  Growing past the current list appends
                // generated simulated-host labels (truncate alone silently
                // no-ops when n > len).
                let n = n.max(1);
                if n <= hosts.len() {
                    hosts.truncate(n);
                } else {
                    for i in hosts.len()..n {
                        hosts.push(format!("sim{}.local", i + 1));
                    }
                }
            }
        }
        self
    }

    /// Effective worker count (`0` placeholders resolved via
    /// `availableCores()`).
    pub fn effective_workers(&self) -> usize {
        match self {
            PlanSpec::Sequential => 1,
            PlanSpec::ThreadPool { workers }
            | PlanSpec::Multiprocess { workers }
            | PlanSpec::Batch { workers, .. }
            | PlanSpec::Custom { workers, .. } => {
                if *workers == 0 {
                    available_cores()
                } else {
                    *workers
                }
            }
            PlanSpec::Cluster { hosts } => hosts.len().max(1),
        }
    }

    /// Backend display name (paper naming).
    pub fn name(&self) -> &'static str {
        match self {
            PlanSpec::Sequential => "sequential",
            PlanSpec::ThreadPool { .. } => "multicore",
            PlanSpec::Multiprocess { .. } => "multisession",
            PlanSpec::Cluster { .. } => "cluster",
            PlanSpec::Batch { .. } => "batchtools",
            PlanSpec::Custom { .. } => "custom",
        }
    }
}

/// Third-party backend factory (the paper's "third-party future backends"
/// contract — anything conforming to the Backend trait plugs in).
pub type BackendFactory = Arc<dyn Fn(usize) -> Arc<dyn Backend> + Send + Sync>;

static REGISTRY: Mutex<Option<HashMap<String, BackendFactory>>> = Mutex::new(None);
/// Serializes `with_plan` sections (tests run concurrently but the
/// *default* session is process-shared, exactly like R's `plan()`; explicit
/// [`crate::api::session::Session`]s don't need this lock).
static PLAN_USER_LOCK: Mutex<()> = Mutex::new(());

thread_local! {
    /// Nesting depth of futures created on this thread.
    static DEPTH: Cell<u32> = const { Cell::new(0) };
}

/// Register a custom backend under `name` for `PlanSpec::Custom`.
pub fn register_backend(name: &str, factory: BackendFactory) {
    let mut guard = REGISTRY.lock().unwrap();
    guard.get_or_insert_with(HashMap::new).insert(name.to_string(), factory);
}

pub(crate) fn lookup_backend_factory(name: &str) -> Option<BackendFactory> {
    REGISTRY.lock().unwrap().as_ref().and_then(|m| m.get(name).cloned())
}

/// Set the current session's plan: a single backend for all futures
/// (`plan(multisession)`).
pub fn plan(spec: PlanSpec) {
    session::current().plan(spec);
}

/// `plan(spec)` with a plan-wide [`RetryPolicy`]: every future created
/// under this plan is supervised (resubmitted to a healthy worker on
/// infrastructure loss) unless its own `FutureOpts::retry` overrides it.
pub fn plan_with_retry(spec: PlanSpec, retry: RetryPolicy) {
    session::current().plan_with_retry(spec, retry);
}

/// Set a nested topology (`plan(list(tweak(multisession, 2), ...))`) on the
/// current session.  Shuts down the previous plan's backends.
pub fn plan_topology(topology: Vec<PlanSpec>) {
    session::current().plan_topology(topology);
}

/// [`plan_topology`] with an optional plan-wide retry default.
pub fn plan_topology_with_retry(topology: Vec<PlanSpec>, retry: Option<RetryPolicy>) {
    session::current().plan_topology_with_retry(topology, retry);
}

/// The current session's plan-wide retry default, if any.
pub fn current_plan_retry() -> Option<RetryPolicy> {
    session::current().retry()
}

/// The current session's topology (defaults to `[sequential]`).
pub fn current_topology() -> Vec<PlanSpec> {
    session::current().topology()
}

/// Run `f` under `spec`, restoring `plan(sequential)` afterwards.  Takes a
/// process-wide user lock so concurrent tests don't fight over the shared
/// default session.  (Prefer an explicit [`crate::api::session::Session`]
/// for new code — sessions don't need the lock.)
pub fn with_plan<R>(spec: PlanSpec, f: impl FnOnce() -> R) -> R {
    with_plan_topology(vec![spec], f)
}

/// [`with_plan`] for nested topologies.
pub fn with_plan_topology<R>(topology: Vec<PlanSpec>, f: impl FnOnce() -> R) -> R {
    let _guard = PLAN_USER_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    plan_topology(topology);
    let out = f();
    plan_topology(vec![PlanSpec::Sequential]);
    out
}

/// [`with_plan`] with a plan-wide retry default (tests/benches).
pub fn with_plan_retry<R>(spec: PlanSpec, retry: RetryPolicy, f: impl FnOnce() -> R) -> R {
    let _guard = PLAN_USER_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    plan_topology_with_retry(vec![spec], Some(retry));
    let out = f();
    plan_topology(vec![PlanSpec::Sequential]);
    out
}

/// Depth of future nesting on the current thread (0 = top level).
pub fn current_depth() -> u32 {
    DEPTH.with(|d| d.get())
}

/// Run `f` at nesting depth `d` (in-process backends evaluate nested
/// expressions under this so `plan()` protection applies).
pub fn at_depth<R>(d: u32, f: impl FnOnce() -> R) -> R {
    DEPTH.with(|cell| {
        let old = cell.get();
        cell.set(d);
        let out = f();
        cell.set(old);
        out
    })
}

/// Resolve the backend for the current nesting depth, plus the remaining
/// topology to ship to that backend's workers for *their* nested futures.
///
/// Depths beyond the configured topology get the implicit
/// `plan(sequential)` — the nested-parallelism protection.
pub fn backend_for_current_depth() -> Result<(Arc<dyn Backend>, Vec<PlanSpec>), FutureError> {
    let s = session::current();
    let depth = current_depth();
    let backend = s.backend_for_depth(depth)?;
    Ok((backend, s.nested_plan_for_depth(depth)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_plan_is_sequential() {
        let _guard = PLAN_USER_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        plan_topology(vec![PlanSpec::Sequential]);
        assert_eq!(current_topology(), vec![PlanSpec::Sequential]);
    }

    #[test]
    fn tweak_adjusts_workers() {
        let spec = PlanSpec::multicore(8).tweak_workers(2);
        assert_eq!(spec.effective_workers(), 2);
        let c = PlanSpec::cluster(&["a", "b", "c"]).tweak_workers(2);
        assert_eq!(c.effective_workers(), 2);
    }

    #[test]
    fn tweak_cluster_grows_with_generated_hosts() {
        // Regression: truncate(n) silently no-oped when n > len.
        let c = PlanSpec::cluster(&["a", "b"]).tweak_workers(4);
        assert_eq!(c.effective_workers(), 4);
        match &c {
            PlanSpec::Cluster { hosts } => {
                assert_eq!(hosts.len(), 4);
                assert_eq!(hosts[0], "a");
                assert_eq!(hosts[1], "b");
                // Generated labels are distinct and non-empty.
                assert_ne!(hosts[2], hosts[3]);
                assert!(!hosts[2].is_empty());
            }
            other => panic!("tweak changed the variant: {other:?}"),
        }
    }

    #[test]
    fn tweak_cluster_to_zero_keeps_one_host() {
        // Regression: n = 0 used to yield an empty host list, which
        // ClusterBackend::new rejects while effective_workers() said 1.
        let c = PlanSpec::cluster(&["a", "b"]).tweak_workers(0);
        match &c {
            PlanSpec::Cluster { hosts } => assert_eq!(hosts, &vec!["a".to_string()]),
            other => panic!("tweak changed the variant: {other:?}"),
        }
        assert_eq!(c.effective_workers(), 1);
    }

    #[test]
    fn plan_retry_default_is_scoped_to_the_plan() {
        with_plan_retry(PlanSpec::sequential(), RetryPolicy::idempotent(3), || {
            assert_eq!(current_plan_retry(), Some(RetryPolicy::idempotent(3)));
        });
        with_plan(PlanSpec::sequential(), || {
            assert_eq!(current_plan_retry(), None, "retry must not leak across plans");
        });
    }

    #[test]
    fn plan_free_functions_target_the_scoped_session() {
        // The session-first contract: plan() inside a scope mutates that
        // session, not the process default.
        let s = crate::api::session::Session::new();
        s.scope(|_| {
            plan(PlanSpec::multicore(3));
            assert_eq!(current_topology(), vec![PlanSpec::multicore(3)]);
        });
        assert_eq!(s.topology(), vec![PlanSpec::multicore(3)]);
        s.close();
    }

    #[test]
    fn effective_workers_zero_uses_available_cores() {
        let spec = PlanSpec::multicore(0);
        assert!(spec.effective_workers() >= 1);
    }

    #[test]
    fn depth_tracking_is_scoped() {
        assert_eq!(current_depth(), 0);
        at_depth(2, || {
            assert_eq!(current_depth(), 2);
            at_depth(3, || assert_eq!(current_depth(), 3));
            assert_eq!(current_depth(), 2);
        });
        assert_eq!(current_depth(), 0);
    }

    #[test]
    fn names_follow_paper() {
        assert_eq!(PlanSpec::sequential().name(), "sequential");
        assert_eq!(PlanSpec::multicore(2).name(), "multicore");
        assert_eq!(PlanSpec::multiprocess(2).name(), "multisession");
        assert_eq!(PlanSpec::cluster(&["h"]).name(), "cluster");
        assert_eq!(PlanSpec::batch(2).name(), "batchtools");
    }
}
