//! Future assignments (`%<-%`) and list environments (`listenv`).
//!
//! R's `v %<-% expr` binds a *promise* that forces the future on first use.
//! Rust has no implicit promises, so [`FuturePromise`] makes the force
//! explicit (`.get()`), and [`ListEnv`] reproduces the `listenv` package:
//! an indexable container of future assignments, collected with
//! `as_list()` — the paper's workaround for "promises can only be assigned
//! to environments, not lists".

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::api::env::Env;
use crate::api::error::FutureError;
use crate::api::expr::Expr;
use crate::api::future::{future_with, Future, FutureOpts};
use crate::api::value::Value;

/// `v %<-% expr`: a deferred assignment backed by a future.
/// The first `get()` forces (blocks on) the future and caches the value.
pub struct FuturePromise {
    future: Future,
    cached: Mutex<Option<Result<Value, String>>>,
}

impl FuturePromise {
    /// Create the promise (launches the future per the current plan —
    /// same as `%<-%`).
    pub fn assign(expr: Expr, env: &Env) -> Result<Self, FutureError> {
        Self::assign_with(expr, env, FutureOpts::new())
    }

    /// `%<-% ... %seed% TRUE` and friends: assignment with options.
    pub fn assign_with(expr: Expr, env: &Env, opts: FutureOpts) -> Result<Self, FutureError> {
        Ok(FuturePromise { future: future_with(expr, env, opts)?, cached: Mutex::new(None) })
    }

    /// Force the promise: blocks until resolved, relays output/conditions,
    /// then behaves like a plain value on every later call.
    pub fn get(&self) -> Result<Value, FutureError> {
        let mut cached = self.cached.lock().unwrap();
        if let Some(prev) = &*cached {
            return prev.clone().map_err(FutureError::Launch);
        }
        match self.future.value() {
            Ok(v) => {
                *cached = Some(Ok(v.clone()));
                Ok(v)
            }
            Err(e) => {
                // Cache infrastructure failures; eval errors re-raise as-is
                // each time (matching R, where the error re-signals).
                if !e.is_eval() {
                    *cached = Some(Err(e.to_string()));
                }
                Err(e)
            }
        }
    }

    /// Non-blocking: has the underlying future resolved?
    pub fn resolved(&self) -> bool {
        self.cached.lock().unwrap().is_some() || self.future.resolved()
    }
}

/// The `listenv` analog: an integer-indexed container of future promises,
/// usable where plain lists can't hold promises.
#[derive(Default)]
pub struct ListEnv {
    slots: BTreeMap<usize, FuturePromise>,
}

impl ListEnv {
    pub fn new() -> Self {
        ListEnv::default()
    }

    /// `vs[[i]] %<-% expr`.
    pub fn assign(&mut self, index: usize, expr: Expr, env: &Env) -> Result<(), FutureError> {
        self.assign_with(index, expr, env, FutureOpts::new())
    }

    pub fn assign_with(
        &mut self,
        index: usize,
        expr: Expr,
        env: &Env,
        opts: FutureOpts,
    ) -> Result<(), FutureError> {
        self.slots.insert(index, FuturePromise::assign_with(expr, env, opts)?);
        Ok(())
    }

    /// Force one slot.
    pub fn get(&self, index: usize) -> Result<Value, FutureError> {
        self.slots
            .get(&index)
            .ok_or_else(|| FutureError::Launch(format!("listenv: no element {index}")))?
            .get()
    }

    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// `as.list(vs)`: force everything, in index order.
    pub fn as_list(&self) -> Result<Vec<Value>, FutureError> {
        self.slots.values().map(FuturePromise::get).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::plan::{with_plan, PlanSpec};

    #[test]
    fn promise_forces_once_and_caches() {
        with_plan(PlanSpec::sequential(), || {
            let mut env = Env::new();
            env.insert("x", 4i64);
            let p = FuturePromise::assign(Expr::mul(Expr::var("x"), Expr::lit(10i64)), &env)
                .unwrap();
            // Reassigning x after the promise does not affect it.
            env.insert("x", 9i64);
            assert_eq!(p.get().unwrap(), Value::I64(40));
            assert_eq!(p.get().unwrap(), Value::I64(40));
            assert!(p.resolved());
        });
    }

    #[test]
    fn eval_errors_re_raise_on_each_get() {
        with_plan(PlanSpec::sequential(), || {
            let env = Env::new();
            let p = FuturePromise::assign(Expr::stop(Expr::lit("bad")), &env).unwrap();
            assert!(p.get().is_err());
            assert!(p.get().is_err());
        });
    }

    #[test]
    fn promise_from_closed_session_reports_session_closed() {
        use crate::api::error::FutureError;
        let s = crate::api::session::Session::with_plan(PlanSpec::multicore(1));
        let env = Env::new();
        // Lazy: never launched, so the close makes it unresolvable (an
        // eagerly-launched promise whose worker finished would instead
        // keep its computed value — close() never discards results).
        let p = s
            .scope(|_| {
                FuturePromise::assign_with(Expr::lit(4i64), &env, FutureOpts::new().lazy())
            })
            .unwrap();
        s.close();
        match p.get() {
            Err(FutureError::SessionClosed { .. }) => {}
            other => panic!("expected SessionClosed, got {other:?}"),
        }
    }

    #[test]
    fn listenv_collects_in_index_order() {
        with_plan(PlanSpec::multicore(2), || {
            let env = Env::new();
            let mut vs = ListEnv::new();
            for i in 0..6usize {
                vs.assign(i, Expr::lit((i * i) as i64), &env).unwrap();
            }
            let list = vs.as_list().unwrap();
            assert_eq!(list, (0..6).map(|i| Value::I64((i * i) as i64)).collect::<Vec<_>>());
            assert_eq!(vs.get(3).unwrap(), Value::I64(9));
            assert!(vs.get(99).is_err());
        });
    }
}
