//! Parallel random number generation — L'Ecuyer-CMRG (MRG32k3a).
//!
//! "The ability to produce high-quality random numbers is essential for the
//! validity of many statistical analyses" — and the default Mersenne-Twister
//! is not designed for concurrent use.  The future framework builds
//! L'Ecuyer's (1999) combined multiple-recursive generator in at its core:
//! with `seed = TRUE`, every future gets its **own RNG stream**, assigned
//! deterministically by future-creation order, so results are *fully
//! reproducible regardless of backend and number of workers*.
//!
//! This is a from-scratch MRG32k3a: two order-3 recurrences modulo
//! m1 = 2^32 − 209 and m2 = 2^32 − 22853, combined.  Streams are spaced
//! 2^127 states apart; the jump matrices are **computed** (not pasted) by
//! 127 modular squarings of the one-step transition matrices, then cached.
//!
//! Divergence from R noted for reviewers: `next_norm` uses Box–Muller over
//! stream draws rather than R's inversion method — deterministic and
//! stream-stable, but numerically different normals than R would produce.

use std::sync::OnceLock;

const M1: u64 = 4294967087; // 2^32 - 209
const M2: u64 = 4294944443; // 2^32 - 22853
const A12: u64 = 1403580;
const A13N: u64 = 810728;
const A21: u64 = 527612;
const A23N: u64 = 1370589;
/// 1 / (m1 + 1): maps the combined state into (0, 1).
const NORM: f64 = 2.328306549295727688e-10;

type Mat = [[u64; 3]; 3];

/// One-step transition matrix of the first component, acting on the state
/// column vector (x_{n-3}, x_{n-2}, x_{n-1}).
const A1_STEP: Mat = [[0, 1, 0], [0, 0, 1], [M1 - A13N, A12, 0]];
/// One-step transition matrix of the second component.
const A2_STEP: Mat = [[0, 1, 0], [0, 0, 1], [M2 - A23N, 0, A21]];

fn mat_mul(a: &Mat, b: &Mat, m: u64) -> Mat {
    let mut out = [[0u64; 3]; 3];
    for i in 0..3 {
        for j in 0..3 {
            let mut acc: u128 = 0;
            for (k, bk) in b.iter().enumerate() {
                acc += a[i][k] as u128 * bk[j] as u128;
            }
            out[i][j] = (acc % m as u128) as u64;
        }
    }
    out
}

fn mat_vec(a: &Mat, v: &[u64; 3], m: u64) -> [u64; 3] {
    let mut out = [0u64; 3];
    for i in 0..3 {
        let mut acc: u128 = 0;
        for k in 0..3 {
            acc += a[i][k] as u128 * v[k] as u128;
        }
        out[i] = (acc % m as u128) as u64;
    }
    out
}

fn mat_pow2k(a: &Mat, k: u32, m: u64) -> Mat {
    // a^(2^k) by k modular squarings.
    let mut acc = *a;
    for _ in 0..k {
        acc = mat_mul(&acc, &acc, m);
    }
    acc
}

/// The 2^127 jump matrices (stream spacing), computed once.
static JUMP: OnceLock<(Mat, Mat)> = OnceLock::new();

fn jump() -> &'static (Mat, Mat) {
    JUMP.get_or_init(|| (mat_pow2k(&A1_STEP, 127, M1), mat_pow2k(&A2_STEP, 127, M2)))
}

fn mat_pow(a: &Mat, mut e: u64, m: u64) -> Mat {
    // a^e by square-and-multiply.
    let mut result: Mat = [[1, 0, 0], [0, 1, 0], [0, 0, 1]];
    let mut base = *a;
    while e > 0 {
        if e & 1 == 1 {
            result = mat_mul(&result, &base, m);
        }
        base = mat_mul(&base, &base, m);
        e >>= 1;
    }
    result
}

/// An MRG32k3a stream.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RngStream {
    s1: [u64; 3],
    s2: [u64; 3],
}

impl RngStream {
    /// Base stream from a user seed, expanded via splitmix64 into six
    /// in-range, not-all-zero state words (R's `set.seed()` analog).
    pub fn from_seed(seed: u64) -> Self {
        let mut x = seed;
        let mut next = |m: u64| {
            x = crate::util::uuid::splitmix64(x);
            // Map into [1, m-1]: nonzero guarantees a valid state vector.
            1 + x % (m - 1)
        };
        RngStream {
            s1: [next(M1), next(M1), next(M1)],
            s2: [next(M2), next(M2), next(M2)],
        }
    }

    /// Stream `index` for this seed: the base state advanced `index` jumps
    /// of 2^127 states (R's `nextRNGStream()` applied `index` times, in
    /// O(log index) matrix work).
    pub fn nth_stream(seed: u64, index: u64) -> Self {
        let base = Self::from_seed(seed);
        if index == 0 {
            return base;
        }
        let (j1, j2) = jump();
        let p1 = mat_pow(j1, index, M1);
        let p2 = mat_pow(j2, index, M2);
        RngStream { s1: mat_vec(&p1, &base.s1, M1), s2: mat_vec(&p2, &base.s2, M2) }
    }

    /// Advance this stream to the next one (exactly R's `nextRNGStream`).
    pub fn next_stream(&self) -> Self {
        let (j1, j2) = jump();
        RngStream { s1: mat_vec(j1, &self.s1, M1), s2: mat_vec(j2, &self.s2, M2) }
    }

    /// One uniform draw on (0, 1).
    pub fn next_unif(&mut self) -> f64 {
        // Component 1: x_n = (a12*x_{n-2} - a13n*x_{n-3}) mod m1
        let p1 = ((A12 as u128 * self.s1[1] as u128 + (M1 - A13N) as u128 * self.s1[0] as u128)
            % M1 as u128) as u64;
        self.s1 = [self.s1[1], self.s1[2], p1];
        // Component 2: x_n = (a21*x_{n-1} - a23n*x_{n-3}) mod m2
        let p2 = ((A21 as u128 * self.s2[2] as u128 + (M2 - A23N) as u128 * self.s2[0] as u128)
            % M2 as u128) as u64;
        self.s2 = [self.s2[1], self.s2[2], p2];

        let d = (p1 + M1 - p2) % M1;
        if d == 0 {
            M1 as f64 * NORM // boundary case: map to just under 1
        } else {
            d as f64 * NORM
        }
    }

    /// One standard-normal draw (Box–Muller; consumes two uniforms).
    pub fn next_norm(&mut self) -> f64 {
        let u1 = self.next_unif();
        let u2 = self.next_unif();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// `n` uniforms as f32 (tensor fill).
    pub fn unif_f32(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.next_unif() as f32).collect()
    }

    /// `n` normals as f32 (tensor fill).
    pub fn norm_f32(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.next_norm() as f32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn draws_are_in_unit_interval() {
        let mut s = RngStream::from_seed(42);
        for _ in 0..10_000 {
            let u = s.next_unif();
            assert!(u > 0.0 && u < 1.0, "u = {u}");
        }
    }

    #[test]
    fn same_seed_same_sequence() {
        let mut a = RngStream::from_seed(7);
        let mut b = RngStream::from_seed(7);
        for _ in 0..100 {
            assert_eq!(a.next_unif().to_bits(), b.next_unif().to_bits());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = RngStream::from_seed(1);
        let mut b = RngStream::from_seed(2);
        let same = (0..100).filter(|_| a.next_unif() == b.next_unif()).count();
        assert!(same < 3);
    }

    #[test]
    fn nth_stream_matches_repeated_next_stream() {
        // Jump composition: nth_stream(seed, k) == next_stream^k(base).
        let mut iter = RngStream::from_seed(123);
        for k in 0..5u64 {
            let direct = RngStream::nth_stream(123, k);
            assert_eq!(direct, iter, "stream index {k}");
            iter = iter.next_stream();
        }
    }

    #[test]
    fn streams_produce_disjoint_output_prefixes() {
        // 2^127 spacing: the first draws of neighboring streams must differ
        // (probability of collision is negligible unless the jump is wrong).
        let mut firsts = Vec::new();
        for k in 0..50 {
            let mut s = RngStream::nth_stream(42, k);
            firsts.push(s.next_unif().to_bits());
        }
        let unique: std::collections::HashSet<_> = firsts.iter().collect();
        assert_eq!(unique.len(), firsts.len());
    }

    #[test]
    fn jump_commutes_with_stepping() {
        // A^(2^127) ∘ step == step ∘ A^(2^127): both orders land on the same
        // state, a strong algebraic check that the jump matrix is a true
        // power of the one-step transition.
        let base = RngStream::from_seed(9);

        // Path A: step once, then jump.
        let mut stepped = base.clone();
        stepped.next_unif();
        let a = stepped.next_stream();

        // Path B: jump, then step once.
        let mut b = base.next_stream();
        b.next_unif();

        assert_eq!(a, b);
    }

    #[test]
    fn mean_and_variance_are_sane() {
        let mut s = RngStream::from_seed(2024);
        let n = 50_000;
        let draws: Vec<f64> = (0..n).map(|_| s.next_unif()).collect();
        let mean = draws.iter().sum::<f64>() / n as f64;
        let var = draws.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
        assert!((var - 1.0 / 12.0).abs() < 0.01, "var {var}");
    }

    #[test]
    fn normals_are_standard() {
        let mut s = RngStream::from_seed(7);
        let n = 50_000;
        let draws: Vec<f64> = (0..n).map(|_| s.next_norm()).collect();
        let mean = draws.iter().sum::<f64>() / n as f64;
        let var = draws.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn serial_correlation_is_low() {
        let mut s = RngStream::from_seed(3);
        let n = 20_000;
        let draws: Vec<f64> = (0..n).map(|_| s.next_unif()).collect();
        let mean = 0.5;
        let mut cov = 0.0;
        for i in 1..n {
            cov += (draws[i] - mean) * (draws[i - 1] - mean);
        }
        cov /= (n - 1) as f64;
        assert!(cov.abs() < 0.005, "lag-1 covariance {cov}");
    }
}
