//! First-class `Session` contexts — the session-first Future API.
//!
//! The paper's contract is "the end-user chooses the parallel backend while
//! the developer focuses on what to parallelize".  Historically that choice
//! lived in process-global state (`plan()`), which cannot express two
//! tenants with different backends in one process and silently dropped
//! plan-level retry defaults on nested workers.  A [`Session`] makes the
//! execution context an explicit, cheaply-clonable value:
//!
//! * the **plan topology** and its lazily-instantiated backend cache,
//! * the plan-wide [`RetryPolicy`] default,
//! * the future-creation **counter** (deterministic RNG stream assignment —
//!   now per session, so two concurrent sessions draw reproducible,
//!   independent streams),
//! * a per-session **supervision metrics scope**
//!   ([`crate::metrics::CounterScope`]), and
//! * a unique **session id** prefixed into every future id.
//!
//! The historical free functions (`plan`, `future`, `future_lapply`, ...)
//! are thin wrappers over the *current* session — the innermost
//! [`Session::scope`] on this thread, else the process-default session —
//! so existing callers keep working unchanged:
//!
//! ```no_run
//! use rustures::prelude::*;
//!
//! // Two tenants, one process, different backends:
//! let a = Session::with_plan(PlanSpec::multicore(2));
//! let b = Session::with_plan(PlanSpec::multiprocess(2));
//! let env = Env::new();
//! let fa = a.future(Expr::lit(1i64), &env).unwrap();
//! let fb = b.future(Expr::lit(2i64), &env).unwrap();
//! assert_eq!(fa.value().unwrap(), Value::I64(1));
//! assert_eq!(fb.value().unwrap(), Value::I64(2));
//! a.close();
//! b.close();
//! ```
//!
//! ## Context propagation to workers
//!
//! Every task ships a serialized [`SessionContext`] (wire protocol v4):
//! the topology *tail* for nested futures, the session's retry default,
//! and a counter base.  Workers — remote processes and in-process worker
//! threads alike — evaluate under a **derived session** built from that
//! context ([`scope_task_context`]), so a nested `plan()` on a worker
//! inherits the parent session's retry posture and topology instead of
//! falling back to process-local defaults (the PR 3 supervision gap).
//! Derived sessions are cached per (origin session, context), so repeated
//! tasks reuse nested backends instead of rebuilding them per task; the
//! cache is LRU-bounded (default 64 entries, `RUSTURES_CONTEXT_CACHE_CAP`
//! overrides), so a long-lived worker serving many tenants does not grow
//! without bound.

use std::cell::RefCell;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock, RwLock};

use crate::api::env::Env;
use crate::api::error::FutureError;
use crate::api::expr::Expr;
use crate::api::plan::{at_depth, PlanSpec};
use crate::api::value::Value;
use crate::backend::supervisor::RetryPolicy;
use crate::backend::{make_backend, Backend};
use crate::ipc::SessionContext;
use crate::metrics::{self, CounterScope, SupervisionCounters};
use crate::util::uuid_v4;

/// Session ids: 0 is the process-default session; explicit sessions and
/// worker-derived sessions take fresh ids from here.
static NEXT_SESSION_ID: AtomicU64 = AtomicU64::new(1);

struct Core {
    topology: Vec<PlanSpec>,
    /// Plan-wide retry default: every future created under this session is
    /// supervised with this policy unless its own
    /// [`crate::api::future::FutureOpts::retry`] overrides it.  Shipped to
    /// nested workers inside the [`SessionContext`].
    retry: Option<RetryPolicy>,
    /// Session-wide deadline default: every future created under this
    /// session gets this deadline unless its own
    /// [`crate::api::future::FutureOpts::deadline`] overrides it.  A
    /// collection-side concern (the deadline clock runs on the caller), so
    /// it is NOT shipped inside the [`SessionContext`].
    default_deadline: Option<std::time::Duration>,
    /// Plan-time static-analysis policy for futures created under this
    /// session (see [`crate::analysis`]).  A creation-side concern — the
    /// analyzer runs where `future_with` runs — so, like the deadline
    /// default, it is NOT shipped inside the [`SessionContext`].
    analysis: crate::analysis::AnalysisConfig,
    /// Result-cache policy for `cached` futures created under this session
    /// (see [`crate::cache`]).  A creation-side concern — lookup and
    /// publication both happen where `future_with` runs — so it is NOT
    /// shipped inside the [`SessionContext`]; keys are content-addressed,
    /// so nested workers sharing a disk root interoperate regardless.
    cache: crate::cache::CacheConfig,
    /// Per-session liveness settings (heartbeat cadence + stall deadline),
    /// shipped inside every [`SessionContext`] so workers heartbeat at this
    /// session's cadence and the transport reactor arms this session's
    /// stall deadline — no process-global state on the hot path.  `None` =
    /// fall back to the process-global
    /// [`crate::liveness::liveness_config`] (kept for the historical free
    /// functions) at context-build time.
    liveness: Option<crate::liveness::LivenessConfig>,
}

struct Inner {
    id: u64,
    /// The session id used for *attribution*: equal to `id` for ordinary
    /// sessions; for worker-side derived sessions it is the ORIGINATING
    /// session's id, so nested contexts at any depth keep pointing at the
    /// real owner (metrics, purge keying, shipped `SessionContext`).
    origin: u64,
    core: RwLock<Core>,
    /// Lazily-instantiated backend per nesting depth.
    backends: Mutex<HashMap<u32, Arc<dyn Backend>>>,
    /// Future-creation counter (deterministic RNG stream index assignment).
    counter: AtomicU64,
    closed: AtomicBool,
    /// Supervision metrics sink; pools built by this session capture it.
    scope: CounterScope,
}

/// A first-class execution context for futures.  Cheap to clone (an `Arc`
/// handle); safe to share across threads.  See the module docs.
#[derive(Clone)]
pub struct Session {
    inner: Arc<Inner>,
}

impl std::fmt::Debug for Session {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Session")
            .field("id", &self.inner.id)
            .field("topology", &self.topology())
            .field("closed", &self.is_closed())
            .finish()
    }
}

thread_local! {
    /// Scope stack: [`Session::scope`] pushes; `current()` reads the top.
    static STACK: RefCell<Vec<Session>> = const { RefCell::new(Vec::new()) };
}

struct ScopeGuard;

impl Drop for ScopeGuard {
    fn drop(&mut self) {
        STACK.with(|s| {
            s.borrow_mut().pop();
        });
    }
}

fn push_current(session: Session) -> ScopeGuard {
    STACK.with(|s| s.borrow_mut().push(session));
    ScopeGuard
}

static DEFAULT: OnceLock<Session> = OnceLock::new();

/// Origin-session registry: id → weak handle, so worker-side context
/// installs can tell a *retired* origin (closed, or every handle dropped)
/// from one that merely lives in another process.  Entries are weak; dead
/// ones are swept opportunistically on insert.
static REGISTRY: Mutex<Option<HashMap<u64, std::sync::Weak<Inner>>>> = Mutex::new(None);

fn register_origin(inner: &Arc<Inner>) {
    let mut guard = REGISTRY.lock().unwrap();
    let map = guard.get_or_insert_with(HashMap::new);
    if map.len() > 32 {
        map.retain(|_, w| w.strong_count() > 0);
    }
    map.insert(inner.id, Arc::downgrade(inner));
}

/// What this process knows about origin session `id`.
enum Origin {
    /// Never seen here: a context arriving in a worker process from a
    /// remote coordinator.
    Unknown,
    /// Closed, or its last handle was dropped.
    Retired,
    /// Alive in this process.
    Live(Session),
}

fn origin_lookup(id: u64) -> Origin {
    let guard = REGISTRY.lock().unwrap();
    match guard.as_ref().and_then(|m| m.get(&id)) {
        Some(w) => match w.upgrade() {
            Some(inner) if inner.closed.load(Ordering::SeqCst) => Origin::Retired,
            Some(inner) => Origin::Live(Session { inner }),
            None => Origin::Retired,
        },
        None => Origin::Unknown,
    }
}

/// The process-default session (id 0) — what the historical free functions
/// operate on outside any [`Session::scope`].
pub fn default_session() -> &'static Session {
    DEFAULT.get_or_init(|| Session::with_id(0, vec![PlanSpec::Sequential], None, 0))
}

/// The session governing future creation on this thread: the innermost
/// [`Session::scope`], else the process default.
pub fn current() -> Session {
    STACK
        .with(|s| s.borrow().last().cloned())
        .unwrap_or_else(|| default_session().clone())
}

impl Session {
    fn with_id(
        id: u64,
        topology: Vec<PlanSpec>,
        retry: Option<RetryPolicy>,
        counter_base: u64,
    ) -> Session {
        let session = Session {
            inner: Arc::new(Inner {
                id,
                origin: id,
                core: RwLock::new(Core {
                    topology,
                    retry,
                    default_deadline: None,
                    analysis: crate::analysis::AnalysisConfig::default(),
                    cache: crate::cache::CacheConfig::default(),
                    liveness: None,
                }),
                backends: Mutex::new(HashMap::new()),
                counter: AtomicU64::new(counter_base),
                closed: AtomicBool::new(false),
                scope: metrics::scope_for_session(id),
            }),
        };
        register_origin(&session.inner);
        session
    }

    /// A fresh session with `plan(sequential)` and a unique id.
    pub fn new() -> Session {
        // Opportunistically retire derived state of RAII-dropped sessions
        // (multi-tenant loops create sessions continually, so leaks from
        // close()-less drops are reclaimed here and on context lookups).
        drain_pending_retirements();
        let id = NEXT_SESSION_ID.fetch_add(1, Ordering::SeqCst);
        Session::with_id(id, vec![PlanSpec::Sequential], None, 0)
    }

    /// A fresh session under a single-backend plan.
    pub fn with_plan(spec: PlanSpec) -> Session {
        let s = Session::new();
        s.plan(spec);
        s
    }

    /// A fresh session under a nested topology.
    pub fn with_topology(topology: Vec<PlanSpec>) -> Session {
        let s = Session::new();
        s.plan_topology(topology);
        s
    }

    /// A fresh session with a plan-wide retry default.
    pub fn with_plan_retry(spec: PlanSpec, retry: RetryPolicy) -> Session {
        let s = Session::new();
        s.plan_topology_with_retry(vec![spec], Some(retry));
        s
    }

    /// Worker-side derived session for a shipped [`SessionContext`]: the
    /// topology tail becomes this session's full topology (depth restarts
    /// at 0 on the worker), the retry default carries over, the counter
    /// starts at the shipped base, and supervision metrics attribute to
    /// the *originating* session id.
    fn for_context(ctx: &SessionContext, detached_metrics: bool) -> Session {
        let id = NEXT_SESSION_ID.fetch_add(1, Ordering::SeqCst);
        Session {
            inner: Arc::new(Inner {
                id,
                // Attribution stays with the ORIGIN session: metrics,
                // nested SessionContexts, and purge keying all use it, so
                // a second nesting level still belongs to the real owner.
                origin: ctx.session,
                core: RwLock::new(Core {
                    topology: ctx.nested_plan.clone(),
                    retry: ctx.retry.clone(),
                    default_deadline: None,
                    analysis: crate::analysis::AnalysisConfig::default(),
                    cache: crate::cache::CacheConfig::default(),
                    liveness: None,
                }),
                backends: Mutex::new(HashMap::new()),
                counter: AtomicU64::new(ctx.counter_base),
                closed: AtomicBool::new(false),
                // Detached: record without re-registering an origin whose
                // metrics entry was already evicted (retired origins).
                scope: if detached_metrics {
                    metrics::detached_scope(ctx.session)
                } else {
                    metrics::scope_for_session(ctx.session)
                },
            }),
        }
    }

    /// This session's unique id (0 = the process default).
    pub fn id(&self) -> u64 {
        self.inner.id
    }

    /// The id this session *attributes* to: its own id, except for
    /// worker-side derived sessions, which attribute to the originating
    /// session (metrics, shipped contexts, purge keying).
    pub fn origin_id(&self) -> u64 {
        self.inner.origin
    }

    /// Has [`Session::close`] been called?
    pub fn is_closed(&self) -> bool {
        self.inner.closed.load(Ordering::SeqCst)
    }

    pub(crate) fn ensure_open(&self) -> Result<(), FutureError> {
        if self.is_closed() {
            Err(FutureError::SessionClosed { session: self.inner.origin })
        } else {
            Ok(())
        }
    }

    /// The supervision metrics sink futures/pools of this session record to.
    pub(crate) fn metrics_scope(&self) -> CounterScope {
        self.inner.scope.clone()
    }

    /// This session's supervision counters (worker deaths / respawns /
    /// retries attributed to it).
    pub fn supervision_counters(&self) -> SupervisionCounters {
        self.inner.scope.counters()
    }

    // ----------------------------------------------------------- limits ----

    /// Install per-session admission limits, enforced by the capacity
    /// ledger: `max_workers` caps this session's concurrent execution-slot
    /// leases across every backend (blocking seat acquisition — quota'd
    /// launches wait, they are never dropped); `max_in_flight` bounds
    /// created-but-unresolved futures at creation time.  Derived
    /// worker-side sessions share the originating session's limits.
    pub fn set_limits(&self, limits: crate::capacity::SessionLimits) {
        crate::capacity::set_session_limits(self.inner.origin, limits);
    }

    /// The admission limits currently installed for this session.
    pub fn limits(&self) -> crate::capacity::SessionLimits {
        crate::capacity::session_limits(self.inner.origin)
    }

    /// A fresh session under `spec` with admission limits installed.
    pub fn with_limits(spec: PlanSpec, limits: crate::capacity::SessionLimits) -> Session {
        let s = Session::with_plan(spec);
        s.set_limits(limits);
        s
    }

    // ------------------------------------------------------------ plan ----

    /// `plan(spec)` for this session: a single backend for all its futures.
    pub fn plan(&self, spec: PlanSpec) {
        self.plan_topology(vec![spec]);
    }

    /// Set a nested topology; shuts down the previous plan's backends.
    pub fn plan_topology(&self, topology: Vec<PlanSpec>) {
        self.plan_topology_with_retry(topology, None);
    }

    /// `plan(spec)` plus a plan-wide [`RetryPolicy`] default.
    pub fn plan_with_retry(&self, spec: PlanSpec, retry: RetryPolicy) {
        self.plan_topology_with_retry(vec![spec], Some(retry));
    }

    /// [`Session::plan_topology`] with an optional plan-wide retry default.
    pub fn plan_topology_with_retry(
        &self,
        topology: Vec<PlanSpec>,
        retry: Option<RetryPolicy>,
    ) {
        {
            let mut core = self.inner.core.write().unwrap();
            core.topology = topology;
            core.retry = retry;
        }
        self.shutdown_backends();
        // Nested backends built for this session's previous plan live in
        // derived context sessions — retire those too (keyed by origin, so
        // deeper nesting levels are caught as well).  NOT marked closed:
        // the origin is still open, so an in-flight task of the old plan
        // must see recoverable launch errors (torn-down pool), never a
        // misleading terminal SessionClosed.
        purge_contexts_for(self.inner.origin, false);
    }

    /// The current topology (defaults to `[sequential]`).
    pub fn topology(&self) -> Vec<PlanSpec> {
        let core = self.inner.core.read().unwrap();
        if core.topology.is_empty() {
            vec![PlanSpec::Sequential]
        } else {
            core.topology.clone()
        }
    }

    /// The plan-wide retry default, if any.
    pub fn retry(&self) -> Option<RetryPolicy> {
        self.inner.core.read().unwrap().retry.clone()
    }

    /// Set (or clear) the session-wide deadline default: every future
    /// created under this session afterwards times out — latching
    /// [`crate::api::error::FutureError::TimedOut`] and cancelling the
    /// in-flight attempt — after this long, unless its own
    /// [`crate::api::future::FutureOpts::deadline`] overrides it.
    pub fn set_default_deadline(&self, deadline: Option<std::time::Duration>) {
        self.inner.core.write().unwrap().default_deadline = deadline;
    }

    /// The session-wide deadline default, if any.
    pub fn default_deadline(&self) -> Option<std::time::Duration> {
        self.inner.core.read().unwrap().default_deadline
    }

    // --------------------------------------------------------- analysis ----

    /// Replace this session's plan-time static-analysis policy: per-code
    /// severities, export budget, chaos arming (see
    /// [`crate::analysis::AnalysisConfig`]).  Applies to every future
    /// created under this session afterwards.
    pub fn set_analysis_config(&self, config: crate::analysis::AnalysisConfig) {
        self.inner.core.write().unwrap().analysis = config;
    }

    /// This session's static-analysis policy (a snapshot).
    pub fn analysis_config(&self) -> crate::analysis::AnalysisConfig {
        self.inner.core.read().unwrap().analysis.clone()
    }

    // ------------------------------------------------------ result cache ----

    /// Replace this session's result-cache policy: master switch, memory
    /// budget, disk root (see [`crate::cache::CacheConfig`]).  Applies to
    /// every `cached` future created under this session afterwards; the
    /// cache stays opt-in per future via
    /// [`crate::api::future::FutureOpts::cached`] /
    /// [`crate::mapreduce::LapplyOpts::cached`].
    pub fn set_cache_config(&self, config: crate::cache::CacheConfig) {
        self.inner.core.write().unwrap().cache = config;
    }

    /// This session's result-cache policy (a snapshot).
    pub fn cache_config(&self) -> crate::cache::CacheConfig {
        self.inner.core.read().unwrap().cache.clone()
    }

    // ---------------------------------------------------------- liveness ----

    /// Set this session's liveness policy: worker heartbeat cadence and the
    /// stall deadline after which a silent busy seat is declared hung (see
    /// [`crate::liveness::LivenessConfig`]).  Shipped inside the
    /// [`SessionContext`] of every future created afterwards, so it reaches
    /// workers and the transport reactor without process-global state; pass
    /// `None` to fall back to the process-global
    /// [`crate::liveness::set_liveness_config`] default.
    pub fn set_liveness_config(&self, config: Option<crate::liveness::LivenessConfig>) {
        self.inner.core.write().unwrap().liveness = config;
    }

    /// This session's *effective* liveness policy: the per-session setting
    /// if one was given, else the process-global fallback.
    pub fn liveness_config(&self) -> crate::liveness::LivenessConfig {
        self.inner
            .core
            .read()
            .unwrap()
            .liveness
            .clone()
            .unwrap_or_else(crate::liveness::liveness_config)
    }

    /// The session-side facts the analyzer's plan cross-check pass needs,
    /// assembled without instantiating any backend.
    pub(crate) fn analysis_facts(&self, depth: u32) -> crate::analysis::SessionFacts {
        crate::analysis::SessionFacts {
            derived: self.inner.id != self.inner.origin,
            depth,
            topology_levels: self.inner.core.read().unwrap().topology.len(),
            max_workers: self.limits().max_workers,
            default_deadline: self.default_deadline(),
        }
    }

    /// Run the full static analyzer over `(expr, env, opts)` under this
    /// session's plan and policy WITHOUT creating a future: no capacity
    /// lease, no metrics, no relayed conditions — just the diagnostics,
    /// including `Allow`-severity findings that enforcement would skip.
    ///
    /// Globals are identified best-effort: a
    /// [`crate::api::globals::GlobalsSpec`]-level failure
    /// (missing explicit name) simply yields an empty capture here, since
    /// the capture-typo pass reports the underlying problem as a
    /// diagnostic anyway.
    pub fn lint(
        &self,
        expr: &Expr,
        env: &crate::api::env::Env,
        opts: &crate::api::future::FutureOpts,
    ) -> Vec<crate::analysis::Diagnostic> {
        let globals = crate::api::globals::identify_globals(expr, env, &opts.globals)
            .unwrap_or_else(|_| crate::api::env::Env::new());
        let facts = self.analysis_facts(crate::api::plan::current_depth());
        crate::analysis::lint(expr, &globals, &opts.globals, opts, &facts, &self.analysis_config())
    }

    // --------------------------------------------------------- counters ----

    /// Next future-creation ordinal (deterministic RNG stream assignment).
    pub(crate) fn next_ordinal(&self) -> u64 {
        self.inner.counter.fetch_add(1, Ordering::SeqCst)
    }

    /// Restart the creation counter (new "session run"; benches/tests).
    pub fn reset_counter(&self) {
        self.inner.counter.store(0, Ordering::SeqCst);
    }

    /// Session-prefixed future id: unique across sessions by construction.
    pub(crate) fn next_future_id(&self) -> String {
        format!("s{}-{}", self.inner.id, uuid_v4())
    }

    // ---------------------------------------------------------- backends ----

    /// Resolve the backend for nesting depth `depth`, instantiating it
    /// lazily under this session's metrics scope.  Depths beyond the
    /// configured topology get the implicit `plan(sequential)` — the
    /// nested-parallelism protection.
    pub fn backend_for_depth(&self, depth: u32) -> Result<Arc<dyn Backend>, FutureError> {
        // Hold the cache lock across the closed-check, spec read, build,
        // and insert.  `plan_topology_with_retry` and `close()` drain this
        // map only under the same lock (and only AFTER releasing the core
        // write lock — no hold-and-wait cycle), so a backend built against
        // a spec that was concurrently re-planned cannot outlive the
        // re-plan: the drain that follows it shuts it down (its handles
        // then error at launch), and a closed session cannot resurrect a
        // pool (the flag is checked under the lock close() must take).
        let mut backends = self.inner.backends.lock().unwrap();
        self.ensure_open()?;
        if let Some(b) = backends.get(&depth) {
            return Ok(Arc::clone(b));
        }
        let spec = {
            let core = self.inner.core.read().unwrap();
            core.topology.get(depth as usize).cloned().unwrap_or(PlanSpec::Sequential)
        };
        // Pools constructed here capture this session's counter scope, so
        // their monitor/reader threads attribute worker deaths and
        // respawns to the right session.
        let _ambient = metrics::push_ambient_scope(self.inner.scope.clone());
        let b = make_backend(&spec)?;
        backends.insert(depth, Arc::clone(&b));
        Ok(b)
    }

    /// The topology tail nested futures of a depth-`depth` future consult
    /// (cheaper than [`Session::context_for_depth`] when only the tail is
    /// needed — no retry clone).
    pub fn nested_plan_for_depth(&self, depth: u32) -> Vec<PlanSpec> {
        let core = self.inner.core.read().unwrap();
        core.topology.get(depth as usize + 1..).map(|s| s.to_vec()).unwrap_or_default()
    }

    /// The serialized context a task created at `depth` ships to its
    /// worker: topology tail, retry default, counter base.
    pub fn context_for_depth(&self, depth: u32) -> SessionContext {
        let core = self.inner.core.read().unwrap();
        // Resolve the effective liveness policy NOW (per-session override,
        // else the process-global fallback) so workers and the transport
        // reactor never consult global state themselves.
        let liveness =
            core.liveness.clone().unwrap_or_else(crate::liveness::liveness_config);
        SessionContext {
            // The ORIGIN id, not the local one: a derived session's nested
            // context must keep attributing (and purge-keying) to the real
            // owning session at every nesting level.
            session: self.inner.origin,
            nested_plan: core
                .topology
                .get(depth as usize + 1..)
                .map(|s| s.to_vec())
                .unwrap_or_default(),
            retry: core.retry.clone(),
            // Reserved: always 0 in protocol v4.  The field pins the
            // worker-side counter start so a future protocol revision can
            // make nested *unseeded* stream assignment reproducible
            // without another wire change; derived sessions already honor
            // a non-zero base.
            counter_base: 0,
            heartbeat_ms: liveness.heartbeat_interval.as_millis().max(1) as u64,
            stall_after_ms: liveness
                .stall_after
                .map(|d| d.as_millis().max(1) as u64)
                .unwrap_or(0),
        }
    }

    fn shutdown_backends(&self) {
        let backends = std::mem::take(&mut *self.inner.backends.lock().unwrap());
        for (_, b) in backends {
            b.shutdown();
        }
    }

    /// Close the session: tear down its backends (and any worker-side
    /// derived state) and latch every future of this session that can no
    /// longer complete into a terminal [`FutureError::SessionClosed`] —
    /// results a worker finished before the close are promoted and
    /// survive collection.  Idempotent.
    pub fn close(&self) {
        self.inner.closed.store(true, Ordering::SeqCst);
        self.shutdown_backends();
        purge_contexts_for(self.inner.origin, true);
        // Lift the session's admission limits: launchers blocked on its
        // quotas wake (their pools are torn down, so they surface launch
        // errors rather than waiting on a quota nobody will ever drain).
        crate::capacity::clear_session_limits(self.inner.origin);
        // Evict the metrics registry entry (never the shared default's):
        // per-session counters of a closed session stop being enumerable,
        // but the handle's own scope Arc — and the process-wide totals —
        // remain readable.  Keeps long-lived multi-tenant processes from
        // accumulating one registry entry per session ever created.
        if self.inner.origin != 0 {
            metrics::drop_session_scope(self.inner.origin);
        }
    }

    // ------------------------------------------------------------ scope ----

    /// Run `f` with this session as the *current* session on this thread:
    /// every free-function call inside (`future`, `future_lapply`, `plan`,
    /// ...) operates on it.  Nests; panic-safe.
    pub fn scope<R>(&self, f: impl FnOnce(&Session) -> R) -> R {
        let _guard = push_current(self.clone());
        f(self)
    }

    // ----------------------------------------------------- conveniences ----

    /// [`crate::api::future::future`] under this session.
    pub fn future(
        &self,
        expr: Expr,
        env: &Env,
    ) -> Result<crate::api::future::Future, FutureError> {
        self.scope(|_| crate::api::future::future(expr, env))
    }

    /// [`crate::api::future::future_with`] under this session.
    pub fn future_with(
        &self,
        expr: Expr,
        env: &Env,
        opts: crate::api::future::FutureOpts,
    ) -> Result<crate::api::future::Future, FutureError> {
        self.scope(|_| crate::api::future::future_with(expr, env, opts))
    }

    /// [`crate::api::future::future_pipelined`] under this session.
    pub fn future_pipelined(
        &self,
        expr: Expr,
        env: &Env,
        opts: crate::api::future::FutureOpts,
        deps: Vec<crate::api::future::Future>,
    ) -> Result<crate::api::future::Future, FutureError> {
        self.scope(|_| crate::api::future::future_pipelined(expr, env, opts, deps))
    }

    /// [`crate::mapreduce::future_lapply`] under this session.
    pub fn lapply(
        &self,
        xs: &[Value],
        param: &str,
        body: &Expr,
        env: &Env,
        opts: &crate::mapreduce::LapplyOpts,
    ) -> Result<Vec<Value>, FutureError> {
        self.scope(|_| crate::mapreduce::future_lapply(xs, param, body, env, opts))
    }

    /// Evaluate `expr` via a transient future under this session.
    pub fn value_of(&self, expr: Expr, env: &Env) -> Result<Value, FutureError> {
        self.scope(|_| crate::api::future::value_of(expr, env))
    }
}

impl Default for Session {
    fn default() -> Self {
        Session::new()
    }
}

impl Drop for Inner {
    fn drop(&mut self) {
        // An ordinary session dropped without close() (early return, RAII
        // habits) must not leak its metrics-registry entry or its cached
        // derived context sessions forever.  Derived sessions
        // (origin != id) attribute to their origin and must not evict it;
        // the default session (id 0) is never dropped.  Backends shut
        // down via their own Drop impls as the map drops.
        // NOTE: only the SCOPES, capacity-ledger, and PENDING_RETIRE locks
        // are taken here — never REGISTRY or CONTEXT_SESSIONS, either of
        // which may be held by the caller releasing the last handle (see
        // `origin_lookup`).
        if self.origin == self.id && self.id != 0 {
            crate::metrics::drop_session_scope(self.id);
            crate::capacity::clear_session_limits(self.id);
            PENDING_RETIRE.lock().unwrap().push(self.id);
        }
    }
}

// ------------------------------------------------- derived task sessions ----

/// Cache of worker-side derived sessions, keyed by (origin session id,
/// rendered context), valued with a last-use stamp for LRU eviction.
/// Reuse keeps nested backends alive across the tasks of one map instead
/// of rebuilding pools per task; isolation holds because the origin
/// session id is part of the key.  **Bounded**: at most
/// [`context_cache_cap`] entries (default 64, `RUSTURES_CONTEXT_CACHE_CAP`
/// overrides) — a worker serving many origin-session × topology-tail
/// pairs evicts the least-recently-used derived session (its nested
/// backends shut down; the same context later re-derives a fresh one)
/// instead of growing for the worker's lifetime.
static CONTEXT_SESSIONS: Mutex<Option<HashMap<(u64, String), (Session, u64)>>> = Mutex::new(None);

/// Monotonic use-stamp source for the cache's LRU order.
static CONTEXT_CLOCK: AtomicU64 = AtomicU64::new(1);

/// Cached cap (0 = not yet read from the environment).
static CONTEXT_CACHE_CAP: AtomicU64 = AtomicU64::new(0);

const DEFAULT_CONTEXT_CACHE_CAP: u64 = 64;

fn context_cache_cap() -> usize {
    let v = CONTEXT_CACHE_CAP.load(Ordering::Relaxed);
    if v != 0 {
        return v as usize;
    }
    let cap = std::env::var("RUSTURES_CONTEXT_CACHE_CAP")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .filter(|n| *n > 0)
        .unwrap_or(DEFAULT_CONTEXT_CACHE_CAP);
    CONTEXT_CACHE_CAP.store(cap, Ordering::Relaxed);
    cap as usize
}

#[cfg(test)]
pub(crate) fn set_context_cache_cap_for_tests(n: u64) {
    CONTEXT_CACHE_CAP.store(n, Ordering::Relaxed);
}

/// Number of cached derived sessions (tests assert the LRU bound holds).
#[cfg(test)]
pub(crate) fn context_cache_len() -> usize {
    CONTEXT_SESSIONS.lock().unwrap().as_ref().map(|m| m.len()).unwrap_or(0)
}

fn context_key(ctx: &SessionContext) -> (u64, String) {
    // Fast path: the overwhelmingly common leaf context (no nested plan,
    // no retry) renders to the empty string — no formatting cost.
    let rendered = if ctx.nested_plan.is_empty() && ctx.retry.is_none() && ctx.counter_base == 0
    {
        String::new()
    } else {
        format!("{:?}|{:?}|{}", ctx.nested_plan, ctx.retry, ctx.counter_base)
    };
    (ctx.session, rendered)
}

/// Bumped by every [`purge_contexts_for`]: invalidates the per-thread
/// context memos so a purged derived session is never served from one.
static CONTEXT_GEN: AtomicU64 = AtomicU64::new(0);

thread_local! {
    /// §Perf: per-thread memo of the last (generation, context) → derived
    /// session.  Worker threads overwhelmingly evaluate runs of tasks
    /// with the same context, so the hot path is ONE atomic load + a
    /// SessionContext equality — no global mutex, no allocation —
    /// matching the one-atomic hot-path budget the metrics layer keeps.
    static CONTEXT_MEMO: RefCell<Option<(u64, SessionContext, Session)>> =
        const { RefCell::new(None) };
}

/// Origin sessions whose last handle was RAII-dropped without `close()`:
/// `Inner::drop` cannot purge the context cache itself (the drop may run
/// while the cache lock is held — see `origin_lookup`'s upgrade), so it
/// queues the id here and the next slow-path lookup or `Session::new`
/// retires the dropped origin's derived sessions.
static PENDING_RETIRE: Mutex<Vec<u64>> = Mutex::new(Vec::new());

fn drain_pending_retirements() {
    let ids: Vec<u64> = std::mem::take(&mut *PENDING_RETIRE.lock().unwrap());
    for id in ids {
        purge_contexts_for(id, true);
    }
}

fn context_session(ctx: &SessionContext) -> Session {
    let generation = CONTEXT_GEN.load(Ordering::SeqCst);
    let hit = CONTEXT_MEMO.with(|m| {
        m.borrow().as_ref().and_then(|(g, c, s)| {
            if *g == generation && c == ctx {
                Some(s.clone())
            } else {
                None
            }
        })
    });
    if let Some(session) = hit {
        return session;
    }
    // Slow path only: retire contexts of RAII-dropped origins, render the
    // shared-map key (the memo compares the SessionContext itself, so the
    // hot path never allocates).
    drain_pending_retirements();
    let key = context_key(ctx);
    let (session, memoizable) = context_session_slow(ctx, &key);
    if memoizable {
        CONTEXT_MEMO.with(|m| {
            *m.borrow_mut() = Some((generation, ctx.clone(), session.clone()));
        });
    }
    session
}

/// The shared-cache path behind the per-thread memo.  Returns the session
/// plus whether it came from (or went into) the cache — ephemeral sessions
/// are never memoized, so they self-clean when their task finishes.
fn context_session_slow(ctx: &SessionContext, key: &(u64, String)) -> (Session, bool) {
    // A RETIRED origin (closed / dropped in this process) must not get a
    // cached derived session: purge_contexts_for already ran for it, so an
    // entry inserted now would never be retired again — leaking any nested
    // backends it builds.  In-flight tasks racing a close() instead get an
    // EPHEMERAL derived session that self-cleans when the task finishes
    // (backend `Drop` impls shut their pools down), with a detached
    // metrics scope so the evicted registry entry is not resurrected.
    let mut guard = CONTEXT_SESSIONS.lock().unwrap();
    // Checked UNDER the cache lock: close()/re-plan set their state before
    // purging under this lock, so either we observe it here (and go
    // ephemeral) or our cached insert happens first and the purge that is
    // about to run drains it — no interleaving leaks an entry.
    let cacheable = match origin_lookup(ctx.session) {
        Origin::Retired => false,
        Origin::Unknown => true,
        // A live local origin: only cache contexts from its CURRENT plan.
        // A task of the old plan still evaluating after a re-plan would
        // otherwise re-insert a stale derived session (with stale nested
        // pools) that the just-run purge can never have seen.
        Origin::Live(origin) => {
            ctx.nested_plan.is_empty() || {
                let topo = origin.topology();
                (1..=topo.len()).any(|d| topo.get(d..) == Some(&ctx.nested_plan[..]))
            }
        }
    };
    if !cacheable {
        return (Session::for_context(ctx, true), false);
    }
    let map = guard.get_or_insert_with(HashMap::new);
    let stamp = CONTEXT_CLOCK.fetch_add(1, Ordering::SeqCst);
    if let Some((session, last_use)) = map.get_mut(key) {
        *last_use = stamp;
        return (session.clone(), true);
    }
    // Miss: make room first (LRU — evict the least-recently-used derived
    // sessions until the insert fits the cap), then insert.
    let cap = context_cache_cap().max(1);
    let mut evicted: Vec<Session> = Vec::new();
    while map.len() >= cap {
        let Some(oldest) = map.iter().min_by_key(|(_, v)| v.1).map(|(k, _)| k.clone())
        else {
            break;
        };
        if let Some((s, _)) = map.remove(&oldest) {
            evicted.push(s);
        }
    }
    let session = Session::for_context(ctx, false);
    map.insert(key.clone(), (session.clone(), stamp));
    drop(guard);
    if !evicted.is_empty() {
        // Invalidate every thread's memo BEFORE tearing the evicted
        // sessions down (same discipline as purge_contexts_for): their
        // nested backends shut down NOT marked closed, so an in-flight
        // task of an evicted context sees recoverable launch errors and
        // the same context later re-derives cleanly.  (Eviction under
        // pressure CAN fail a still-running task's nested futures — the
        // same trade a re-plan makes; size the cap above the number of
        // concurrently live tenants to avoid it.)
        CONTEXT_GEN.fetch_add(1, Ordering::SeqCst);
        for s in evicted {
            s.shutdown_backends();
        }
    }
    (session, true)
}

/// Retire the derived sessions of origin session `id`: their nested
/// backends shut down with the plan that spawned them.  `mark_closed` is
/// true only for [`Session::close`] — a re-plan leaves the drained
/// sessions open so in-flight tasks of the old plan see recoverable
/// launch errors (torn-down pool) instead of a false terminal
/// `SessionClosed`.
fn purge_contexts_for(id: u64, mark_closed: bool) {
    let drained: Vec<Session> = {
        let mut guard = CONTEXT_SESSIONS.lock().unwrap();
        match guard.as_mut() {
            Some(map) => {
                let keys: Vec<(u64, String)> =
                    map.keys().filter(|(sid, _)| *sid == id).cloned().collect();
                keys.into_iter().filter_map(|k| map.remove(&k).map(|(s, _)| s)).collect()
            }
            None => Vec::new(),
        }
    };
    // Invalidate every thread's memo before tearing anything down.
    CONTEXT_GEN.fetch_add(1, Ordering::SeqCst);
    for s in drained {
        if mark_closed {
            s.inner.closed.store(true, Ordering::SeqCst);
        }
        s.shutdown_backends();
    }
}

/// Evaluate `f` under the derived session for a task's shipped
/// [`SessionContext`] — THE worker-side context install, shared by remote
/// worker processes ([`crate::worker::run_worker`]/`run_batch_job`) and the
/// in-process backends (sequential, thread pool).  Inside, the context's
/// topology tail is the full topology and nesting depth restarts at 0, so
/// nested futures created during evaluation pick `tail[0]`, inherit the
/// origin session's retry default, and ship `tail[1..]` onward.
pub fn scope_task_context<R>(ctx: &SessionContext, f: impl FnOnce() -> R) -> R {
    let session = context_session(ctx);
    let _guard = push_current(session);
    at_depth(0, f)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::future::FutureOpts;
    use crate::api::plan::{current_plan_retry, current_topology};

    #[test]
    fn sessions_get_unique_ids_and_default_is_zero() {
        let a = Session::new();
        let b = Session::new();
        assert_ne!(a.id(), b.id());
        assert_ne!(a.id(), 0);
        assert_eq!(default_session().id(), 0);
    }

    #[test]
    fn scope_installs_and_restores_current() {
        let s = Session::with_plan(PlanSpec::multicore(2));
        let outer = current().id();
        s.scope(|inner| {
            assert_eq!(current().id(), inner.id());
            assert_eq!(current_topology(), vec![PlanSpec::multicore(2)]);
        });
        assert_eq!(current().id(), outer, "scope must restore the previous session");
        s.close();
    }

    #[test]
    fn scopes_nest() {
        let a = Session::with_plan(PlanSpec::multicore(1));
        let b = Session::with_plan(PlanSpec::multicore(2));
        a.scope(|_| {
            b.scope(|_| assert_eq!(current().id(), b.id()));
            assert_eq!(current().id(), a.id());
        });
        a.close();
        b.close();
    }

    #[test]
    fn session_future_roundtrip_and_id_prefix() {
        let s = Session::with_plan(PlanSpec::sequential());
        let env = Env::new();
        let f = s.future(Expr::add(Expr::lit(40i64), Expr::lit(2i64)), &env).unwrap();
        assert!(
            f.id().starts_with(&format!("s{}-", s.id())),
            "future id {} must carry the session prefix",
            f.id()
        );
        assert_eq!(f.value().unwrap(), Value::I64(42));
        s.close();
    }

    #[test]
    fn closed_session_rejects_new_futures() {
        let s = Session::with_plan(PlanSpec::sequential());
        s.close();
        let env = Env::new();
        match s.future(Expr::lit(1i64), &env) {
            Err(FutureError::SessionClosed { session }) => assert_eq!(session, s.id()),
            other => panic!("expected SessionClosed, got {other:?}"),
        }
    }

    #[test]
    fn context_carries_tail_retry_and_session() {
        let s = Session::new();
        let retry = RetryPolicy::idempotent(3);
        s.plan_topology_with_retry(
            vec![PlanSpec::multicore(2), PlanSpec::multicore(3), PlanSpec::Sequential],
            Some(retry.clone()),
        );
        let ctx = s.context_for_depth(0);
        assert_eq!(ctx.session, s.id());
        assert_eq!(ctx.nested_plan, vec![PlanSpec::multicore(3), PlanSpec::Sequential]);
        assert_eq!(ctx.retry, Some(retry.clone()));
        let ctx1 = s.context_for_depth(1);
        assert_eq!(ctx1.nested_plan, vec![PlanSpec::Sequential]);
        let ctx9 = s.context_for_depth(9);
        assert!(ctx9.nested_plan.is_empty());
        assert_eq!(ctx9.retry, Some(retry));
        s.close();
    }

    #[test]
    fn scope_task_context_installs_topology_and_retry() {
        let retry = RetryPolicy::idempotent(4);
        let ctx = SessionContext {
            session: 12345,
            nested_plan: vec![PlanSpec::multicore(3), PlanSpec::Sequential],
            retry: Some(retry.clone()),
            ..SessionContext::default()
        };
        scope_task_context(&ctx, || {
            // The worker-side view: the tail IS the topology, retry is the
            // plan default — exactly what nested future creation consults.
            assert_eq!(
                current_topology(),
                vec![PlanSpec::multicore(3), PlanSpec::Sequential]
            );
            assert_eq!(current_plan_retry(), Some(retry.clone()));
        });
    }

    #[test]
    fn nested_contexts_keep_the_origin_session_at_every_depth() {
        // Two nesting levels: the derived session created for S's depth-0
        // task must ship S's id (not its own worker-local id) in the next
        // context, so purge/close and metrics still find the deepest
        // backends (review regression: phantom-session leak at depth 2).
        let s = Session::with_topology(vec![
            PlanSpec::multicore(1),
            PlanSpec::Sequential,
            PlanSpec::Sequential,
        ]);
        let ctx0 = s.context_for_depth(0);
        assert_eq!(ctx0.session, s.id());
        let ctx1 = scope_task_context(&ctx0, || {
            let inner = current();
            assert_ne!(inner.id(), s.id(), "derived sessions get their own id");
            assert_eq!(inner.origin_id(), s.id(), "…but attribute to the origin");
            inner.context_for_depth(0)
        });
        assert_eq!(
            ctx1.session,
            s.id(),
            "a depth-1 context must still name the originating session"
        );
        assert_eq!(ctx1.nested_plan, vec![PlanSpec::Sequential]);
        s.close();
    }

    #[test]
    fn derived_sessions_are_cached_per_context() {
        let ctx = SessionContext {
            session: 54321,
            nested_plan: vec![PlanSpec::Sequential],
            ..SessionContext::default()
        };
        let a = scope_task_context(&ctx, || current().id());
        let b = scope_task_context(&ctx, || current().id());
        assert_eq!(a, b, "same context must reuse the derived session");
        let other = SessionContext { session: 54322, ..ctx.clone() };
        let c = scope_task_context(&other, || current().id());
        assert_ne!(a, c, "different origin sessions must stay isolated");
    }

    #[test]
    fn per_session_counters_are_independent() {
        let a = Session::with_plan(PlanSpec::sequential());
        let b = Session::with_plan(PlanSpec::sequential());
        let env = Env::new();
        // Session A creates three futures; B's counter must be untouched,
        // so B's first seeded future draws stream 0 regardless.
        for _ in 0..3 {
            a.future(Expr::lit(0i64), &env).unwrap().value().unwrap();
        }
        let vb = b
            .future_with(Expr::rnorm(2), &env, FutureOpts::new().seed(42))
            .unwrap()
            .value()
            .unwrap();
        let fresh = Session::with_plan(PlanSpec::sequential());
        let vf = fresh
            .future_with(Expr::rnorm(2), &env, FutureOpts::new().seed(42))
            .unwrap()
            .value()
            .unwrap();
        assert_eq!(vb, vf, "stream assignment must be per-session deterministic");
        a.close();
        b.close();
        fresh.close();
    }

    #[test]
    fn raii_dropped_session_contexts_are_retired() {
        // A session dropped WITHOUT close() must still have its cached
        // derived sessions reclaimed (deferred via PENDING_RETIRE): later
        // lookups for its contexts go ephemeral instead of re-caching.
        let ctx = {
            let s = Session::with_topology(vec![PlanSpec::multicore(1), PlanSpec::Sequential]);
            let ctx = s.context_for_depth(0);
            let first = scope_task_context(&ctx, || current().id());
            let second = scope_task_context(&ctx, || current().id());
            assert_eq!(first, second, "live origin: derived session is cached");
            ctx
        }; // s dropped here, close() never called
        let _keep = Session::new(); // drains pending retirements
        let a = scope_task_context(&ctx, || current().id());
        let b = scope_task_context(&ctx, || current().id());
        assert_ne!(a, b, "retired origin: derived sessions are ephemeral, never re-cached");
    }

    #[test]
    fn session_limits_install_and_clear_on_close() {
        let s = Session::with_limits(
            PlanSpec::sequential(),
            crate::capacity::SessionLimits::new().max_workers(2).max_in_flight(8),
        );
        assert_eq!(s.limits().max_workers, Some(2));
        assert_eq!(s.limits().max_in_flight, Some(8));
        s.close();
        assert_eq!(s.limits(), crate::capacity::SessionLimits::default());
    }

    /// Restores the context-cache cap even if the test body panics, so a
    /// failing assertion cannot leave the global cache tiny for the rest
    /// of the (parallel) test run.
    struct CapGuard(u64);
    impl Drop for CapGuard {
        fn drop(&mut self) {
            set_context_cache_cap_for_tests(self.0);
        }
    }

    #[test]
    fn context_cache_evicts_lru_and_rederives() {
        // Only assertions robust to CONCURRENT cache users (other tests'
        // worker evaluations insert leaf contexts too): the cap bound, the
        // guaranteed eviction of the oldest un-touched entry, and that a
        // re-derived context caches again.  (Survivors after any insert
        // are exactly the cap newest stamps, so the oldest of 4 distinct
        // inserts under cap 2 cannot remain.)
        let _restore = CapGuard(context_cache_cap() as u64);
        set_context_cache_cap_for_tests(2);
        let mk = |sid: u64| SessionContext {
            session: sid, // unknown (non-local) origins: cacheable
            nested_plan: vec![PlanSpec::Sequential],
            ..SessionContext::default()
        };
        let contexts: Vec<SessionContext> = (0..4).map(|i| mk(9_200_001 + i)).collect();
        let first_id = scope_task_context(&contexts[0], || current().id());
        for c in &contexts[1..] {
            scope_task_context(c, || current().id());
        }
        assert!(context_cache_len() <= 2, "cache must stay within its cap");
        let rederived = scope_task_context(&contexts[0], || current().id());
        assert_ne!(
            first_id, rederived,
            "the oldest context must have been evicted and re-derive a fresh session"
        );
        let again = scope_task_context(&contexts[0], || current().id());
        assert_eq!(rederived, again, "a re-derived context is cached again");
    }

    #[test]
    fn plan_change_purges_derived_contexts() {
        let s = Session::with_topology(vec![PlanSpec::multicore(1), PlanSpec::Sequential]);
        let ctx = s.context_for_depth(0);
        let first = scope_task_context(&ctx, || current().id());
        s.plan(PlanSpec::sequential());
        // The old derived session was retired with the plan change; the
        // same context now yields a fresh one.
        let second = scope_task_context(&ctx, || current().id());
        assert_ne!(first, second);
        s.close();
    }
}
