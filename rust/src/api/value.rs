//! [`Value`] — the data model that crosses process boundaries.
//!
//! Everything a future consumes (globals) or produces (its value) is a
//! `Value`.  The set is deliberately small — scalars, strings, f32 tensors
//! (the PJRT interchange type), and lists — and every variant serializes
//! through [`crate::ipc::wire`] so any backend (in-process, pipe, TCP,
//! batch-file) transports the same representation.
//!
//! §Perf — zero-copy clones: [`Tensor`] payloads live in an `Arc<[f32]>`,
//! so every clone on the future hot path — globals capture at creation,
//! element literals in map-reduce chunks, the in-process hand-off to
//! threadpool workers, `restart()` spec retention — is a reference-count
//! bump, O(1) in payload bytes.  Mutation goes through the copy-on-write
//! [`Tensor::data_mut`], which detaches a private buffer only when the
//! payload is actually shared.

use std::fmt;
use std::sync::Arc;

/// A dense row-major f32 tensor — the PJRT buffer interchange type.
///
/// Cloning shares the payload buffer (see module docs); `==` compares
/// contents, not identity.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    /// Shared payload.  Reads deref straight to `[f32]`; writers use
    /// [`Tensor::data_mut`] for copy-on-write semantics.
    pub data: Arc<[f32]>,
}

impl Tensor {
    /// Build a tensor, validating that `data` fills `shape` exactly.
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Self, String> {
        Self::from_shared(shape, data.into())
    }

    /// Build from an already-shared buffer (wire decode, slicing) without
    /// copying; validates the element count like [`Tensor::new`].
    pub fn from_shared(shape: Vec<usize>, data: Arc<[f32]>) -> Result<Self, String> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(format!(
                "tensor shape {:?} wants {} elements, got {}",
                shape,
                n,
                data.len()
            ));
        }
        Ok(Tensor { shape, data })
    }

    /// Internal constructor for freshly computed buffers whose length is
    /// correct by construction (evaluator arithmetic, RNG fills — these
    /// collect straight into the shared allocation, no intermediate Vec).
    pub(crate) fn from_parts(shape: Vec<usize>, data: Arc<[f32]>) -> Self {
        debug_assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape, data }
    }

    /// A scalar (rank-0) tensor.
    pub fn scalar(v: f32) -> Self {
        Tensor { shape: vec![], data: std::iter::once(v).collect() }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: std::iter::repeat(0.0).take(n).collect() }
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Copy-on-write mutable access: detaches a private copy of the buffer
    /// iff it is currently shared, then hands out `&mut [f32]`.
    pub fn data_mut(&mut self) -> &mut [f32] {
        if Arc::get_mut(&mut self.data).is_none() {
            let copied: Arc<[f32]> = Arc::from(&self.data[..]);
            self.data = copied;
        }
        Arc::get_mut(&mut self.data).expect("uniquely owned after copy-on-write detach")
    }

    /// Do two tensors share one payload allocation?  (Diagnostics/tests for
    /// the zero-copy invariant; not part of value equality.)
    pub fn shares_data(&self, other: &Tensor) -> bool {
        Arc::ptr_eq(&self.data, &other.data)
    }
}

/// The value domain of the future framework.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// R's `NULL` / invisible result.
    Unit,
    Bool(bool),
    I64(i64),
    F64(f64),
    Str(String),
    Tensor(Tensor),
    List(Vec<Value>),
}

impl Value {
    /// Human-readable type name for error messages.
    pub fn type_name(&self) -> &'static str {
        match self {
            Value::Unit => "unit",
            Value::Bool(_) => "bool",
            Value::I64(_) => "i64",
            Value::F64(_) => "f64",
            Value::Str(_) => "str",
            Value::Tensor(_) => "tensor",
            Value::List(_) => "list",
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::F64(v) => Some(*v),
            Value::I64(v) => Some(*v as f64),
            Value::Tensor(t) if t.rank() == 0 => Some(t.data[0] as f64),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::I64(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_tensor(&self) -> Option<&Tensor> {
        match self {
            Value::Tensor(t) => Some(t),
            _ => None,
        }
    }

    pub fn as_list(&self) -> Option<&[Value]> {
        match self {
            Value::List(v) => Some(v),
            _ => None,
        }
    }

    /// Approximate in-memory payload size in bytes (used by metrics, the
    /// cluster backend's transfer accounting, and the wire encoder's
    /// buffer-size hints).
    pub fn byte_size(&self) -> usize {
        match self {
            Value::Unit => 1,
            Value::Bool(_) => 1,
            Value::I64(_) | Value::F64(_) => 8,
            Value::Str(s) => s.len(),
            Value::Tensor(t) => t.data.len() * 4 + t.shape.len() * 8,
            Value::List(v) => v.iter().map(Value::byte_size).sum::<usize>() + 8,
        }
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Unit => write!(f, "()"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::I64(v) => write!(f, "{v}"),
            Value::F64(v) => write!(f, "{v}"),
            Value::Str(s) => write!(f, "{s:?}"),
            Value::Tensor(t) => {
                write!(f, "tensor{:?}", t.shape)?;
                if t.len() <= 4 {
                    write!(f, "{:?}", &t.data[..])?;
                }
                Ok(())
            }
            Value::List(v) => {
                write!(f, "[")?;
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{item}")?;
                }
                write!(f, "]")
            }
        }
    }
}

impl From<f64> for Value {
    fn from(v: f64) -> Self {
        Value::F64(v)
    }
}

impl From<i64> for Value {
    fn from(v: i64) -> Self {
        Value::I64(v)
    }
}

impl From<bool> for Value {
    fn from(v: bool) -> Self {
        Value::Bool(v)
    }
}

impl From<&str> for Value {
    fn from(v: &str) -> Self {
        Value::Str(v.to_string())
    }
}

impl From<String> for Value {
    fn from(v: String) -> Self {
        Value::Str(v)
    }
}

impl From<Tensor> for Value {
    fn from(t: Tensor) -> Self {
        Value::Tensor(t)
    }
}

impl From<Vec<Value>> for Value {
    fn from(v: Vec<Value>) -> Self {
        Value::List(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_shape_validation() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
        assert!(Tensor::from_shared(vec![2], vec![0.0; 3].into()).is_err());
        assert_eq!(Tensor::scalar(2.5).rank(), 0);
        assert_eq!(Tensor::zeros(&[4, 4]).len(), 16);
    }

    #[test]
    fn value_coercions() {
        assert_eq!(Value::from(2.0).as_f64(), Some(2.0));
        assert_eq!(Value::from(2i64).as_f64(), Some(2.0));
        assert_eq!(Value::Tensor(Tensor::scalar(1.5)).as_f64(), Some(1.5));
        assert_eq!(Value::from("x").as_str(), Some("x"));
        assert_eq!(Value::from(true).as_bool(), Some(true));
        assert_eq!(Value::Unit.as_f64(), None);
    }

    #[test]
    fn byte_size_accounts_tensor_payload() {
        let t = Value::Tensor(Tensor::zeros(&[10, 10]));
        assert!(t.byte_size() >= 400);
    }

    #[test]
    fn display_is_stable() {
        let v = Value::List(vec![Value::from(1i64), Value::from("a")]);
        assert_eq!(format!("{v}"), "[1, \"a\"]");
    }

    #[test]
    fn clone_shares_payload_buffer() {
        // The zero-copy invariant: cloning a tensor (directly or inside a
        // Value/List) must not copy the f32 buffer.
        let t = Tensor::zeros(&[256]);
        let c = t.clone();
        assert!(t.shares_data(&c));

        let v = Value::List(vec![Value::Tensor(t.clone()), Value::I64(1)]);
        let v2 = v.clone();
        match (&v, &v2) {
            (Value::List(a), Value::List(b)) => {
                let (ta, tb) = (a[0].as_tensor().unwrap(), b[0].as_tensor().unwrap());
                assert!(ta.shares_data(tb));
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn data_mut_is_copy_on_write() {
        let base = Tensor::zeros(&[4]);
        let mut shared = base.clone();
        shared.data_mut()[0] = 5.0;
        // The write detached shared's buffer; base is untouched.
        assert_eq!(base.data[0], 0.0);
        assert_eq!(shared.data[0], 5.0);
        assert!(!base.shares_data(&shared));
        // Uniquely owned: further writes do NOT re-copy.
        let before = Arc::as_ptr(&shared.data);
        shared.data_mut()[1] = 6.0;
        assert_eq!(Arc::as_ptr(&shared.data), before);
    }
}
