//! `plan(future.batchtools::batchtools_slurm)` analog — futures as
//! scheduler jobs.
//!
//! Each future is spooled to a task file and submitted to the simulated
//! [`crate::scheduler`]; the handle polls job state and reads the result
//! file on completion.  High per-future latency (submission + polling), but
//! capacity scales with the scheduler's nodes — the paper's
//! "better suited for large-throughput requirements" backend.  No live
//! channel exists, so `immediateCondition`s arrive only with the result
//! (exactly the non-supporting-backend behaviour the paper describes).
//!
//! Blocking semantic: `launch()` blocks while `workers` jobs are pending or
//! running — capacity frees when a job *completes*, not when its result is
//! collected (matching the other backends).  Node-slot **admission** is the
//! scheduler daemon's per-job [`crate::capacity::CapacityLedger`] lease
//! (per-session quotas apply there); a daemon that dies surfaces structured
//! `FutureError`s to every waiting handle instead of a frozen `Pending`.

use std::sync::Arc;
use std::time::Duration;

use crate::api::error::FutureError;
use crate::backend::dispatch::CompletionWaker;
use crate::backend::{Backend, TaskHandle};
use crate::ipc::wire::{decode_message, encode_message};
use crate::ipc::{Message, TaskResult, TaskSpec};
use crate::scheduler::{JobId, JobState, SchedConfig, Scheduler};

pub struct BatchBackend {
    scheduler: Arc<Scheduler>,
    poll_interval: Duration,
    workers: usize,
}

impl BatchBackend {
    /// Spool the task file and submit (fire-and-forget, like sbatch).
    fn submit(&self, task: TaskSpec) -> Result<Box<dyn TaskHandle>, FutureError> {
        if !self.scheduler.daemon_alive() {
            return Err(FutureError::Launch("batch scheduler daemon died".into()));
        }
        // The originating session rides along: the scheduler daemon's
        // ledger admission charges the job's node-slot lease to it, so
        // per-session quotas hold on the batch backend too.
        let session = task.opts.context.session;
        // The attempt epoch names the spool file: a resubmitted task never
        // overwrites the file a still-running previous attempt may be
        // reading, and the handle can fence a result frame whose echoed
        // epoch is not its own.
        let expected_attempt = task.opts.attempt;
        let task_file = self
            .scheduler
            .spool()
            .join(format!("task-{}-a{}.task", task.id, expected_attempt));
        let bytes = encode_message(&Message::Task(task));
        std::fs::write(&task_file, &bytes)
            .map_err(|e| FutureError::Launch(format!("spool task: {e}")))?;
        let job = self.scheduler.submit_attempt(task_file, session, expected_attempt);
        Ok(Box::new(BatchHandle {
            scheduler: Arc::clone(&self.scheduler),
            job,
            poll_interval: self.poll_interval,
            done: None,
            expected_attempt,
            scope: crate::metrics::scope_for_session(session),
        }))
    }

    pub fn new(
        workers: usize,
        submit_latency_ms: u64,
        poll_interval_ms: u64,
    ) -> Result<Self, FutureError> {
        let workers = workers.max(1);
        let scheduler = Scheduler::start(SchedConfig {
            submit_latency: Duration::from_millis(submit_latency_ms),
            tick: Duration::from_millis(poll_interval_ms.clamp(1, 50)),
            ..SchedConfig::local(workers)
        })?;
        Ok(BatchBackend {
            scheduler,
            poll_interval: Duration::from_millis(poll_interval_ms.max(1)),
            workers,
        })
    }
}

impl Backend for BatchBackend {
    fn name(&self) -> &'static str {
        "batchtools"
    }

    fn workers(&self) -> usize {
        self.workers
    }

    fn supports_immediate(&self) -> bool {
        false // file-staged: no live channel
    }

    fn launch(&self, task: TaskSpec) -> Result<Box<dyn TaskHandle>, FutureError> {
        // Block while the scheduler is saturated (capacity frees on job
        // completion, matching the paper's blocking semantic).  This is
        // client-side backpressure only — the authoritative seat admission
        // is the daemon's per-job ledger lease.
        loop {
            if !self.scheduler.daemon_alive() {
                return Err(FutureError::Launch("batch scheduler daemon died".into()));
            }
            let (pending, running, _) = self.scheduler.load();
            if pending + running < self.workers {
                break;
            }
            std::thread::sleep(self.poll_interval);
        }
        self.submit(task)
    }

    fn launch_queued(&self, task: TaskSpec) -> Result<Box<dyn TaskHandle>, FutureError> {
        // `sbatch` is already fire-and-forget and the scheduler's FIFO
        // queue already IS a backlog — queued dispatch simply skips the
        // client-side saturation throttle above.
        self.submit(task)
    }

    fn shutdown(&self) {
        self.scheduler.shutdown();
    }
}

impl Drop for BatchBackend {
    fn drop(&mut self) {
        self.scheduler.shutdown();
    }
}

pub struct BatchHandle {
    scheduler: Arc<Scheduler>,
    job: JobId,
    poll_interval: Duration,
    done: Option<TaskResult>,
    /// Attempt epoch this handle launched; result frames echoing any other
    /// epoch are stale writes and get fenced, never surfaced.
    expected_attempt: u32,
    scope: crate::metrics::CounterScope,
}

impl BatchHandle {
    fn try_harvest(&mut self) -> Result<Option<TaskResult>, FutureError> {
        if let Some(r) = &self.done {
            return Ok(Some(r.clone()));
        }
        match self.scheduler.poll(self.job) {
            Some(JobState::Completed) => {
                let path = self
                    .scheduler
                    .result_file(self.job)
                    .ok_or_else(|| FutureError::Channel("result path lost".into()))?;
                let bytes = std::fs::read(&path)
                    .map_err(|e| FutureError::Channel(format!("read result: {e}")))?;
                match decode_message(&bytes)
                    .map_err(|e| FutureError::Channel(format!("bad result file: {e}")))?
                {
                    Message::Result(r) => {
                        if r.attempt != self.expected_attempt {
                            // A write from a different attempt epoch landed in
                            // this job's result slot (e.g. a revived worker from
                            // a previous attempt flushing late).  Fence it:
                            // discard the frame and fail this attempt as a
                            // worker death so the supervisor relaunches —
                            // surfacing the stale payload could hand the caller
                            // a value computed from superseded inputs.
                            self.scope.fenced();
                            let _ = std::fs::remove_file(&path);
                            return Err(FutureError::WorkerDied {
                                detail: format!(
                                    "fenced stale batch result (attempt {}, expected {})",
                                    r.attempt, self.expected_attempt
                                ),
                            });
                        }
                        self.done = Some(r.clone());
                        Ok(Some(r))
                    }
                    other => Err(FutureError::Channel(format!("result file held {other:?}"))),
                }
            }
            Some(JobState::Failed(detail)) => {
                Err(FutureError::WorkerDied { detail: format!("batch job failed: {detail}") })
            }
            Some(JobState::Cancelled) => Err(FutureError::Cancelled),
            Some(JobState::Pending) | Some(JobState::Running { .. }) => {
                if self.scheduler.daemon_alive() {
                    Ok(None)
                } else {
                    // A dead daemon can never admit or harvest this job:
                    // surface the structured failure instead of polling a
                    // frozen state forever.
                    Err(FutureError::WorkerDied {
                        detail: format!(
                            "batch scheduler daemon died; job {} cannot complete",
                            self.job
                        ),
                    })
                }
            }
            None => Err(FutureError::Channel("job vanished from scheduler".into())),
        }
    }
}

impl TaskHandle for BatchHandle {
    fn is_resolved(&mut self) -> bool {
        if self.done.is_some() {
            return true;
        }
        match self.scheduler.poll(self.job) {
            Some(JobState::Pending) | Some(JobState::Running { .. }) => {
                // Resolved-to-an-error when the daemon died under the job.
                !self.scheduler.daemon_alive()
            }
            _ => true,
        }
    }

    fn wait(&mut self) -> Result<TaskResult, FutureError> {
        loop {
            match self.try_harvest()? {
                Some(r) => return Ok(r),
                None => std::thread::sleep(self.poll_interval),
            }
        }
    }

    fn cancel(&mut self) -> bool {
        let cancelled = self.scheduler.cancel(self.job);
        if cancelled {
            self.scope.cancel();
        }
        cancelled
    }

    fn subscribe(&mut self, waker: &Arc<CompletionWaker>, token: u64) -> bool {
        if self.done.is_some() {
            waker.notify(token);
        } else {
            // The scheduler daemon notifies on the job's terminal
            // transition — resolve() over batch futures stops polling.
            self.scheduler.subscribe(self.job, waker, token);
        }
        true
    }
}

impl Drop for BatchHandle {
    fn drop(&mut self) {
        if self.done.is_none() {
            // Abandoned before completion: cancel so the slot frees.
            match self.scheduler.poll(self.job) {
                Some(JobState::Pending) | Some(JobState::Running { .. }) => {
                    self.scheduler.cancel(self.job);
                }
                _ => {}
            }
        }
    }
}
