//! `plan(cluster, workers = c("n1.remote.org", ...))` analog — TCP workers.
//!
//! The paper's cluster backend talks to R workers on remote machines over
//! sockets (`makeClusterPSOCK` with reverse SSH tunneling).  This image has
//! no remote hosts, so each named host is **simulated** by launching the
//! worker process locally and having it *connect back* to the coordinator's
//! listener — the same reverse-connection topology
//! `parallelly::makeClusterPSOCK` uses, over a real TCP socket, exercising
//! the identical code path a remote worker would (serialize → socket →
//! execute → socket → deserialize).

use std::net::{TcpListener, TcpStream};
use std::process::{Child, Command, Stdio};
use std::sync::{Arc, Mutex};

use crate::api::error::FutureError;
use crate::backend::procpool::{Connection, ProcPool, Spawner};
use crate::backend::{Backend, TaskHandle};
use crate::ipc::TaskSpec;
use crate::util::exe::worker_exe;

pub struct ClusterBackend {
    pool: Arc<ProcPool>,
    hosts: Vec<String>,
}

fn launch_host_worker(listener: &TcpListener, host: &str) -> Result<Connection, FutureError> {
    let addr = listener
        .local_addr()
        .map_err(|e| FutureError::Launch(format!("listener addr: {e}")))?;
    let exe = worker_exe()?;
    // "ssh $host rustures worker --connect <coordinator>" — simulated by a
    // local process tagged with the host label.
    let child: Child = Command::new(&exe)
        .args(["worker", "--connect", &addr.to_string()])
        .env("TF_CPP_MIN_LOG_LEVEL", "1")
        .env("RUSTURES_HOST_LABEL", host)
        .stdin(Stdio::null())
        .stdout(Stdio::null())
        .stderr(Stdio::inherit())
        .spawn()
        .map_err(|e| FutureError::Launch(format!("spawn cluster worker for {host}: {e}")))?;

    let (stream, _peer) = listener
        .accept()
        .map_err(|e| FutureError::Launch(format!("accept from {host}: {e}")))?;
    stream.set_nodelay(true).ok();
    let reader: TcpStream = stream
        .try_clone()
        .map_err(|e| FutureError::Launch(format!("clone socket: {e}")))?;
    Ok(Connection { reader: Box::new(reader), writer: Box::new(stream), child: Some(child) })
}

impl ClusterBackend {
    pub fn new(hosts: &[String]) -> Result<Self, FutureError> {
        if hosts.is_empty() {
            return Err(FutureError::InvalidPlan("cluster: no hosts given".into()));
        }
        let listener = TcpListener::bind("127.0.0.1:0")
            .map_err(|e| FutureError::Launch(format!("bind coordinator listener: {e}")))?;
        listener
            .set_nonblocking(false)
            .map_err(|e| FutureError::Launch(format!("listener mode: {e}")))?;

        // Respawns round-robin over the host list.
        let hosts_owned: Vec<String> = hosts.to_vec();
        let next = Mutex::new(0usize);
        let listener = Arc::new(listener);
        let spawner_hosts = hosts_owned.clone();
        let spawner_listener = Arc::clone(&listener);
        let spawner: Spawner = Box::new(move || {
            let mut idx = next.lock().unwrap();
            let host = &spawner_hosts[*idx % spawner_hosts.len()];
            *idx += 1;
            launch_host_worker(&spawner_listener, host)
        });
        let pool = ProcPool::new(hosts_owned.len(), spawner)?;
        Ok(ClusterBackend { pool, hosts: hosts_owned })
    }

    pub fn hosts(&self) -> &[String] {
        &self.hosts
    }
}

impl Backend for ClusterBackend {
    fn name(&self) -> &'static str {
        "cluster"
    }

    fn workers(&self) -> usize {
        self.pool.workers()
    }

    fn supports_immediate(&self) -> bool {
        true // live socket back to the coordinator
    }

    fn launch(&self, task: TaskSpec) -> Result<Box<dyn TaskHandle>, FutureError> {
        self.pool.launch(task)
    }

    fn launch_queued(&self, task: TaskSpec) -> Result<Box<dyn TaskHandle>, FutureError> {
        self.pool.launch_queued(task)
    }

    fn shutdown(&self) {
        self.pool.shutdown();
    }
}

impl Drop for ClusterBackend {
    fn drop(&mut self) {
        self.pool.shutdown();
    }
}
