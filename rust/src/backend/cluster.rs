//! `plan(cluster, workers = c("n1.remote.org", ...))` analog — TCP workers.
//!
//! The paper's cluster backend talks to R workers on remote machines over
//! sockets (`makeClusterPSOCK` with reverse SSH tunneling).  This image has
//! no remote hosts, so each named host is **simulated** by launching the
//! worker process locally and having it *connect back* to the coordinator's
//! listener — the same reverse-connection topology
//! `parallelly::makeClusterPSOCK` uses, over a real TCP socket, exercising
//! the identical code path a remote worker would (serialize → socket →
//! execute → socket → deserialize).

use std::net::{TcpListener, TcpStream};
use std::process::{Child, Command, Stdio};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::api::error::FutureError;
use crate::backend::procpool::{Connection, ProcPool, Spawner};
use crate::backend::supervisor::supervisor_config;
use crate::backend::{Backend, TaskHandle};
use crate::ipc::TaskSpec;
use crate::util::exe::worker_exe;

pub struct ClusterBackend {
    pool: Arc<ProcPool>,
    hosts: Vec<String>,
}

/// How long a spawned worker gets to connect back before plan creation
/// gives up on it.  Overridable via `RUSTURES_CLUSTER_ACCEPT_TIMEOUT_MS`.
fn accept_timeout_from_env() -> Duration {
    std::env::var("RUSTURES_CLUSTER_ACCEPT_TIMEOUT_MS")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .map(Duration::from_millis)
        .unwrap_or(Duration::from_secs(10))
}

fn launch_host_worker(
    listener: &TcpListener,
    host: &str,
    accept_timeout: Duration,
) -> Result<Connection, FutureError> {
    let addr = listener
        .local_addr()
        .map_err(|e| FutureError::Launch(format!("listener addr: {e}")))?;
    let exe = worker_exe()?;
    // "ssh $host rustures worker --connect <coordinator>" — simulated by a
    // local process tagged with the host label.  Host labels suffixed
    // "!noconnect" spawn a worker that never phones home (chaos hook for
    // the accept-timeout tests).
    let (host_label, no_connect) = match host.strip_suffix("!noconnect") {
        Some(h) => (h, true),
        None => (host, false),
    };
    let mut cmd = Command::new(&exe);
    cmd.args(["worker", "--connect", &addr.to_string()])
        .env("TF_CPP_MIN_LOG_LEVEL", "1")
        .env("RUSTURES_HOST_LABEL", host_label)
        .stdin(Stdio::null())
        .stdout(Stdio::null())
        .stderr(Stdio::inherit());
    if no_connect {
        cmd.env("RUSTURES_CHAOS_NO_CONNECT", "1");
    }
    if let Some(marker) = crate::backend::supervisor::chaos_midwrite_marker() {
        // Kill-during-serialization chaos (see supervisor::MIDWRITE_ENV).
        cmd.env(crate::backend::supervisor::MIDWRITE_ENV, marker);
    }
    let mut child: Child = cmd
        .spawn()
        .map_err(|e| FutureError::Launch(format!("spawn cluster worker for {host}: {e}")))?;

    // Accept with a deadline — a worker that spawns but never connects
    // back must not hang plan creation forever.  The listener is
    // nonblocking (set once at backend creation); poll it until the child
    // connects, exits, or the deadline passes (then kill the child).
    let deadline = Instant::now() + accept_timeout;
    let stream = loop {
        match listener.accept() {
            Ok((s, _peer)) => break s,
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::Interrupted =>
            {
                if let Ok(Some(status)) = child.try_wait() {
                    return Err(FutureError::Launch(format!(
                        "cluster worker for {host} exited ({status}) before connecting back"
                    )));
                }
                if Instant::now() >= deadline {
                    let _ = child.kill();
                    let _ = child.wait();
                    return Err(FutureError::Launch(format!(
                        "cluster worker for {host} did not connect back within {accept_timeout:?}"
                    )));
                }
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(e) => {
                let _ = child.kill();
                let _ = child.wait();
                return Err(FutureError::Launch(format!("accept from {host}: {e}")));
            }
        }
    };
    // The accepted socket must be blocking regardless of what it inherited
    // from the nonblocking listener.
    stream
        .set_nonblocking(false)
        .map_err(|e| FutureError::Launch(format!("socket mode: {e}")))?;
    stream.set_nodelay(true).ok();
    let reader: TcpStream = stream
        .try_clone()
        .map_err(|e| FutureError::Launch(format!("clone socket: {e}")))?;
    // Hand the raw socket descriptors to the transport reactor: the
    // connection becomes poll-driven (no per-seat thread).  Reader and
    // writer are distinct fds (try_clone dups), each owned by its box.
    #[cfg(unix)]
    let (read_fd, write_fd) = {
        use std::os::unix::io::AsRawFd;
        (Some(reader.as_raw_fd()), Some(stream.as_raw_fd()))
    };
    #[cfg(not(unix))]
    let (read_fd, write_fd) = (None, None);
    Ok(Connection {
        reader: Box::new(reader),
        writer: Box::new(stream),
        child: Some(child),
        read_fd,
        write_fd,
    })
}

impl ClusterBackend {
    pub fn new(hosts: &[String]) -> Result<Self, FutureError> {
        Self::new_with_accept_timeout(hosts, accept_timeout_from_env())
    }

    /// [`ClusterBackend::new`] with an explicit connect-back deadline per
    /// spawned worker (tests inject short deadlines here).
    pub fn new_with_accept_timeout(
        hosts: &[String],
        accept_timeout: Duration,
    ) -> Result<Self, FutureError> {
        if hosts.is_empty() {
            return Err(FutureError::InvalidPlan("cluster: no hosts given".into()));
        }
        let listener = TcpListener::bind("127.0.0.1:0")
            .map_err(|e| FutureError::Launch(format!("bind coordinator listener: {e}")))?;
        // Nonblocking so launch_host_worker can poll accept with a deadline.
        listener
            .set_nonblocking(true)
            .map_err(|e| FutureError::Launch(format!("listener mode: {e}")))?;

        // Seats are keyed by host in the capacity ledger: the ledger picks
        // the host for every launch and revive (per-host respawn budgets,
        // per-host circuit breakers — a dying host stops receiving
        // resubmissions while healthy hosts absorb the load), and the
        // spawner brings a worker up on exactly the host it is asked for.
        // A host named twice in the plan contributes two seats.
        let hosts_owned: Vec<String> = hosts.to_vec();
        let mut seats: Vec<(String, usize)> = Vec::new();
        for host in &hosts_owned {
            match seats.iter_mut().find(|(h, _)| h == host) {
                Some((_, n)) => *n += 1,
                None => seats.push((host.clone(), 1)),
            }
        }
        let listener = Arc::new(listener);
        let spawner_listener = Arc::clone(&listener);
        let spawner: Spawner = Box::new(move |host| {
            launch_host_worker(&spawner_listener, host, accept_timeout)
        });
        let pool =
            ProcPool::new_with_hosts("cluster", &seats, spawner, &supervisor_config())?;
        Ok(ClusterBackend { pool, hosts: hosts_owned })
    }

    pub fn hosts(&self) -> &[String] {
        &self.hosts
    }
}

impl Backend for ClusterBackend {
    fn name(&self) -> &'static str {
        "cluster"
    }

    fn workers(&self) -> usize {
        self.pool.workers()
    }

    fn supports_immediate(&self) -> bool {
        true // live socket back to the coordinator
    }

    fn launch(&self, task: TaskSpec) -> Result<Box<dyn TaskHandle>, FutureError> {
        self.pool.launch(task)
    }

    fn launch_queued(&self, task: TaskSpec) -> Result<Box<dyn TaskHandle>, FutureError> {
        self.pool.launch_queued(task)
    }

    fn supports_pipelining(&self) -> bool {
        true // live socket to every worker: Forward frames deliver
    }

    fn pipeline_forward(
        &self,
        consumer_task_id: &str,
        dep_future_id: &str,
        outcome: &crate::ipc::TaskOutcome,
    ) -> bool {
        self.pool.pipeline_forward(consumer_task_id, dep_future_id, outcome)
    }

    fn shutdown(&self) {
        self.pool.shutdown();
    }
}

impl Drop for ClusterBackend {
    fn drop(&mut self) {
        self.pool.shutdown();
    }
}
