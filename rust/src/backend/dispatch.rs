//! The dispatcher subsystem — queued (non-blocking) dispatch and
//! as-completed resolution plumbing.
//!
//! Two cooperating pieces live here:
//!
//! * **[`CompletionWaker`]** — the shared completion channel behind
//!   `resolve()`/`resolve_any()`: one mutex + condvar that *every* watched
//!   future notifies with its token when it resolves, so waiting on N
//!   futures costs one blocked thread and zero polling.  Backends deliver
//!   notifications through [`crate::backend::TaskHandle::subscribe`].
//! * **[`Dispatcher`]** — a bounded backlog + one dispatcher thread in
//!   front of a backend's *blocking* `launch`.  `Future::new` with
//!   [`crate::api::future::FutureOpts::queued`] enqueues here and returns
//!   immediately (a [`QueuedHandle`]); the dispatcher thread acquires the
//!   seat on the caller's behalf.  The backlog is bounded: when it is full,
//!   enqueueing blocks — backpressure, not an unbounded queue.  The paper's
//!   block-on-create default is untouched; queued dispatch is opt-in.
//!
//! [`CompletionSignal`] is a per-task helper for backends whose completion
//! event happens on a worker thread (the threadpool): the worker calls
//! `complete()`, the handle calls `subscribe()`, and the signal resolves
//! the inherent race between the two under one lock.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::api::error::FutureError;
use crate::backend::TaskHandle;
use crate::ipc::{TaskResult, TaskSpec};

/// Default backlog bound for a pool's dispatcher: enough to keep every
/// worker fed plus a small constant, never unbounded.
pub fn default_backlog(workers: usize) -> usize {
    workers.saturating_mul(4).max(16)
}

// ---------------------------------------------------------------- waker ----

/// A shared completion channel: futures push their token when they resolve,
/// waiters pop.  One condvar wakes however many futures are being watched —
/// `resolve_any` over N futures never polls N handles.
pub struct CompletionWaker {
    ready: Mutex<VecDeque<u64>>,
    cv: Condvar,
}

impl CompletionWaker {
    pub fn new() -> Arc<Self> {
        Arc::new(CompletionWaker { ready: Mutex::new(VecDeque::new()), cv: Condvar::new() })
    }

    /// Deliver a completion token (called by backends; never blocks on
    /// anything but this waker's own short-lived lock).
    pub fn notify(&self, token: u64) {
        let mut q = self.ready.lock().unwrap();
        q.push_back(token);
        drop(q);
        self.cv.notify_all();
    }

    /// Non-blocking pop of the next delivered token.
    pub fn try_next(&self) -> Option<u64> {
        self.ready.lock().unwrap().pop_front()
    }

    /// Block until a token arrives; `None` only on timeout (when one is
    /// given).
    pub fn wait_next(&self, timeout: Option<Duration>) -> Option<u64> {
        let mut q = self.ready.lock().unwrap();
        loop {
            if let Some(t) = q.pop_front() {
                return Some(t);
            }
            match timeout {
                None => q = self.cv.wait(q).unwrap(),
                Some(d) => {
                    let (guard, res) = self.cv.wait_timeout(q, d).unwrap();
                    q = guard;
                    if res.timed_out() {
                        return q.pop_front();
                    }
                }
            }
        }
    }
}

// --------------------------------------------------------------- signal ----

/// Per-task completion latch: `complete()` (worker side) and `subscribe()`
/// (waiter side) may race in either order; exactly one notification is
/// delivered either way.
#[derive(Default)]
pub struct CompletionSignal {
    state: Mutex<SignalState>,
}

#[derive(Default)]
struct SignalState {
    done: bool,
    waiter: Option<(Arc<CompletionWaker>, u64)>,
}

impl CompletionSignal {
    pub fn new() -> Arc<Self> {
        Arc::new(CompletionSignal::default())
    }

    /// Mark the task complete and notify a registered waiter, if any.
    pub fn complete(&self) {
        let waiter = {
            let mut s = self.state.lock().unwrap();
            s.done = true;
            s.waiter.take()
        };
        if let Some((w, t)) = waiter {
            w.notify(t);
        }
    }

    /// Register a waiter; notifies immediately if already complete.
    pub fn subscribe(&self, waker: &Arc<CompletionWaker>, token: u64) {
        let notify_now = {
            let mut s = self.state.lock().unwrap();
            if s.done {
                true
            } else {
                s.waiter = Some((Arc::clone(waker), token));
                false
            }
        };
        if notify_now {
            waker.notify(token);
        }
    }
}

// ----------------------------------------------------------- dispatcher ----

/// The blocking-launch half the dispatcher drives (a pool's `launch`).
pub type LaunchFn =
    Box<dyn Fn(TaskSpec) -> Result<Box<dyn TaskHandle>, FutureError> + Send + Sync>;

enum CellState {
    /// In the backlog, seat not yet acquired.
    Queued { waiter: Option<(Arc<CompletionWaker>, u64)>, cancelled: bool },
    /// Seat acquired; the live handle parks here until its [`QueuedHandle`]
    /// claims it (Option so it can be moved out exactly once).
    Launched(Option<Box<dyn TaskHandle>>),
    /// Launch failed (or was cancelled/shut down while queued).  Queued
    /// futures surface launch errors at collection time, not creation —
    /// the price of not blocking on create.
    Failed(FutureError),
}

/// Shared slot a queued task's handle and the dispatcher thread meet at.
pub struct DispatchCell {
    state: Mutex<CellState>,
    cv: Condvar,
}

impl DispatchCell {
    fn new() -> Self {
        DispatchCell {
            state: Mutex::new(CellState::Queued { waiter: None, cancelled: false }),
            cv: Condvar::new(),
        }
    }

    fn cancelled(&self) -> bool {
        matches!(&*self.state.lock().unwrap(), CellState::Queued { cancelled: true, .. })
    }

    /// Dispatcher side: record the launch outcome, forward any resolution
    /// subscription into the live handle, wake blocked waiters.
    fn fulfill(&self, outcome: Result<Box<dyn TaskHandle>, FutureError>) {
        let mut notify_waiter = None;
        {
            let mut state = self.state.lock().unwrap();
            let (waiter, was_cancelled) = match &mut *state {
                CellState::Queued { waiter, cancelled } => (waiter.take(), *cancelled),
                // Already fulfilled (double shutdown): keep the first outcome.
                _ => return,
            };
            match outcome {
                // cancel() raced the dispatcher: it flagged the cell AFTER
                // the pre-launch cancelled() check but the launch went
                // through anyway.  Honor the cancel — best-effort stop the
                // live task and latch Cancelled, so cancel()'s `true` and a
                // later wait() agree.
                Ok(mut handle) if was_cancelled => {
                    handle.cancel();
                    notify_waiter = waiter;
                    *state = CellState::Failed(FutureError::Cancelled);
                }
                Ok(mut handle) => {
                    if let Some((w, t)) = waiter {
                        // Forward the pending subscription into the live
                        // handle.  A backend without push notification gets
                        // an immediate (spurious) wake instead, which
                        // downgrades that future to the poll fallback in
                        // FutureSet — never a lost wakeup.
                        if !handle.subscribe(&w, t) {
                            notify_waiter = Some((w, t));
                        }
                    }
                    *state = CellState::Launched(Some(handle));
                }
                Err(e) => {
                    notify_waiter = waiter;
                    *state = CellState::Failed(e);
                }
            }
        }
        self.cv.notify_all();
        if let Some((w, t)) = notify_waiter {
            w.notify(t);
        }
    }
}

struct Backlog {
    tasks: VecDeque<(TaskSpec, Arc<DispatchCell>)>,
    shutting_down: bool,
}

struct DispatchShared {
    queue: Mutex<Backlog>,
    /// Dispatcher thread waits here for work.
    work_cv: Condvar,
    /// Producers wait here when the bounded backlog is full.
    space_cv: Condvar,
    capacity: usize,
}

/// A bounded backlog + one thread that performs blocking seat acquisition
/// on behalf of non-blocking `launch_queued` callers.
pub struct Dispatcher {
    shared: Arc<DispatchShared>,
    thread: Mutex<Option<JoinHandle<()>>>,
}

impl Dispatcher {
    /// Start a dispatcher over `launch` with a backlog bound of `capacity`
    /// tasks (clamped to ≥ 1).
    pub fn new(capacity: usize, launch: LaunchFn) -> Self {
        let shared = Arc::new(DispatchShared {
            queue: Mutex::new(Backlog { tasks: VecDeque::new(), shutting_down: false }),
            work_cv: Condvar::new(),
            space_cv: Condvar::new(),
            capacity: capacity.max(1),
        });
        let thread_shared = Arc::clone(&shared);
        let thread = std::thread::Builder::new()
            .name("rustures-dispatch".into())
            .spawn(move || dispatcher_loop(thread_shared, launch))
            .expect("spawn dispatcher thread");
        Dispatcher { shared, thread: Mutex::new(Some(thread)) }
    }

    /// Enqueue without waiting for a seat.  Blocks only when the bounded
    /// backlog is full (backpressure) or errors when shutting down.
    pub fn launch(&self, task: TaskSpec) -> Result<Box<dyn TaskHandle>, FutureError> {
        let cell = Arc::new(DispatchCell::new());
        {
            let mut q = self.shared.queue.lock().unwrap();
            loop {
                if q.shutting_down {
                    return Err(FutureError::Launch("dispatcher is shutting down".into()));
                }
                if q.tasks.len() < self.shared.capacity {
                    break;
                }
                q = self.shared.space_cv.wait(q).unwrap();
            }
            q.tasks.push_back((task, Arc::clone(&cell)));
        }
        self.shared.work_cv.notify_one();
        Ok(Box::new(QueuedHandle { cell, inner: None, failed: None }))
    }

    /// Stop the dispatcher: fail every task still in the backlog (their
    /// handles resolve to a launch error) and join the thread.  Idempotent.
    ///
    /// The owning pool must unblock any in-flight blocking `launch` (set its
    /// own shutting-down flag and notify its seat condvar) *before* calling
    /// this, or the join would deadlock.
    pub fn shutdown(&self) {
        let drained = {
            let mut q = self.shared.queue.lock().unwrap();
            q.shutting_down = true;
            std::mem::take(&mut q.tasks)
        };
        self.shared.work_cv.notify_all();
        self.shared.space_cv.notify_all();
        for (_, cell) in drained {
            cell.fulfill(Err(FutureError::Launch("pool shut down before launch".into())));
        }
        if let Some(t) = self.thread.lock().unwrap().take() {
            let _ = t.join();
        }
    }
}

fn dispatcher_loop(shared: Arc<DispatchShared>, launch: LaunchFn) {
    loop {
        let (task, cell) = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(item) = q.tasks.pop_front() {
                    break item;
                }
                if q.shutting_down {
                    return;
                }
                q = shared.work_cv.wait(q).unwrap();
            }
        };
        shared.space_cv.notify_one();
        if cell.cancelled() {
            cell.fulfill(Err(FutureError::Cancelled));
            continue;
        }
        cell.fulfill(launch(task));
    }
}

// --------------------------------------------------------- queued handle ----

/// Handle to a task sitting in (or launched from) a dispatcher backlog.
/// Transparent once launched: every call delegates to the inner handle.
pub struct QueuedHandle {
    cell: Arc<DispatchCell>,
    inner: Option<Box<dyn TaskHandle>>,
    failed: Option<FutureError>,
}

impl QueuedHandle {
    /// Non-blocking: claim the inner handle / terminal failure if the
    /// dispatcher has fulfilled the cell.
    fn poll_cell(&mut self) {
        if self.inner.is_some() || self.failed.is_some() {
            return;
        }
        let mut state = self.cell.state.lock().unwrap();
        match &mut *state {
            CellState::Launched(h) => self.inner = h.take(),
            CellState::Failed(e) => self.failed = Some(e.clone()),
            CellState::Queued { .. } => {}
        }
    }

    /// Blocking: wait for the dispatcher to fulfill the cell.
    fn wait_cell(&mut self) {
        if self.inner.is_some() || self.failed.is_some() {
            return;
        }
        let mut state = self.cell.state.lock().unwrap();
        loop {
            match &mut *state {
                CellState::Launched(h) => {
                    self.inner = h.take();
                    return;
                }
                CellState::Failed(e) => {
                    self.failed = Some(e.clone());
                    return;
                }
                CellState::Queued { .. } => state = self.cell.cv.wait(state).unwrap(),
            }
        }
    }
}

impl TaskHandle for QueuedHandle {
    fn is_resolved(&mut self) -> bool {
        self.poll_cell();
        if self.failed.is_some() {
            return true;
        }
        match &mut self.inner {
            Some(h) => h.is_resolved(),
            None => false, // still waiting for a seat
        }
    }

    fn wait(&mut self) -> Result<TaskResult, FutureError> {
        self.wait_cell();
        if let Some(e) = &self.failed {
            return Err(e.clone());
        }
        self.inner.as_mut().expect("launched handle").wait()
    }

    fn cancel(&mut self) -> bool {
        self.poll_cell();
        if let Some(h) = &mut self.inner {
            return h.cancel();
        }
        if self.failed.is_some() {
            return false;
        }
        let mut state = self.cell.state.lock().unwrap();
        match &mut *state {
            CellState::Queued { cancelled, .. } => {
                // The dispatcher skips the launch and fails the cell.
                *cancelled = true;
                true
            }
            CellState::Launched(h) => match h.as_mut() {
                Some(handle) => handle.cancel(),
                None => false,
            },
            CellState::Failed(_) => false,
        }
    }

    fn subscribe(&mut self, waker: &Arc<CompletionWaker>, token: u64) -> bool {
        self.poll_cell();
        if let Some(h) = &mut self.inner {
            return h.subscribe(waker, token);
        }
        if self.failed.is_some() {
            waker.notify(token);
            return true;
        }
        let mut state = self.cell.state.lock().unwrap();
        match &mut *state {
            CellState::Queued { waiter, .. } => {
                *waiter = Some((Arc::clone(waker), token));
                true
            }
            // Raced with the dispatcher's fulfill: act on the live state.
            CellState::Launched(h) => match h.as_mut() {
                Some(handle) => handle.subscribe(waker, token),
                None => {
                    waker.notify(token);
                    true
                }
            },
            CellState::Failed(_) => {
                waker.notify(token);
                true
            }
        }
    }
}

impl Drop for QueuedHandle {
    fn drop(&mut self) {
        // Abandoned before launch: cancel the queued task so the dispatcher
        // never spends a seat on work nobody can collect.
        if self.inner.is_none() && self.failed.is_none() {
            let mut state = self.cell.state.lock().unwrap();
            if let CellState::Queued { cancelled, .. } = &mut *state {
                *cancelled = true;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::env::Env;
    use crate::api::expr::Expr;
    use crate::ipc::TaskOpts;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::time::Instant;

    fn task(expr: Expr) -> TaskSpec {
        TaskSpec {
            id: crate::util::uuid_v4(),
            expr,
            globals: Env::new(),
            opts: TaskOpts::default(),
        }
    }

    /// Launch function that resolves instantly via the sequential backend.
    fn instant_launch() -> LaunchFn {
        use crate::backend::{sequential::SequentialBackend, Backend};
        let b = SequentialBackend::new();
        Box::new(move |t| b.launch(t))
    }

    #[test]
    fn waker_delivers_tokens_in_order() {
        let w = CompletionWaker::new();
        w.notify(3);
        w.notify(7);
        assert_eq!(w.try_next(), Some(3));
        assert_eq!(w.wait_next(Some(Duration::from_millis(10))), Some(7));
        assert_eq!(w.wait_next(Some(Duration::from_millis(10))), None);
    }

    #[test]
    fn signal_resolves_subscribe_complete_race_both_orders() {
        // subscribe then complete
        let s = CompletionSignal::new();
        let w = CompletionWaker::new();
        s.subscribe(&w, 1);
        assert_eq!(w.try_next(), None);
        s.complete();
        assert_eq!(w.try_next(), Some(1));
        // complete then subscribe
        let s = CompletionSignal::new();
        s.complete();
        s.subscribe(&w, 2);
        assert_eq!(w.try_next(), Some(2));
    }

    #[test]
    fn queued_launch_resolves_through_dispatcher() {
        let d = Dispatcher::new(4, instant_launch());
        let mut h = d.launch(task(Expr::add(Expr::lit(1i64), Expr::lit(2i64)))).unwrap();
        let r = h.wait().unwrap();
        assert_eq!(r.outcome, crate::ipc::TaskOutcome::Ok(crate::api::value::Value::I64(3)));
        d.shutdown();
    }

    #[test]
    fn enqueue_does_not_block_while_launch_is_slow() {
        // A launch function that stalls: enqueueing N ≤ capacity tasks must
        // return immediately anyway.
        let slow: LaunchFn = Box::new(|t| {
            std::thread::sleep(Duration::from_millis(80));
            use crate::backend::{sequential::SequentialBackend, Backend};
            SequentialBackend::new().launch(t)
        });
        let d = Dispatcher::new(8, slow);
        let t0 = Instant::now();
        let mut handles: Vec<_> =
            (0..4).map(|i| d.launch(task(Expr::lit(i as i64))).unwrap()).collect();
        assert!(
            t0.elapsed() < Duration::from_millis(60),
            "enqueue blocked: {:?}",
            t0.elapsed()
        );
        for (i, h) in handles.iter_mut().enumerate() {
            let r = h.wait().unwrap();
            assert_eq!(
                r.outcome,
                crate::ipc::TaskOutcome::Ok(crate::api::value::Value::I64(i as i64))
            );
        }
        d.shutdown();
    }

    #[test]
    fn backlog_is_bounded() {
        // Capacity 2 with a launch that blocks until released: the third
        // enqueue must block until the dispatcher drains one.
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        let g = Arc::clone(&gate);
        let gated: LaunchFn = Box::new(move |t| {
            let (lock, cv) = &*g;
            let mut open = lock.lock().unwrap();
            while !*open {
                open = cv.wait(open).unwrap();
            }
            use crate::backend::{sequential::SequentialBackend, Backend};
            SequentialBackend::new().launch(t)
        });
        let d = Arc::new(Dispatcher::new(2, gated));
        // One task occupies the dispatcher thread, two fill the backlog.
        let _h0 = d.launch(task(Expr::lit(0i64))).unwrap();
        let _h1 = d.launch(task(Expr::lit(1i64))).unwrap();
        let _h2 = d.launch(task(Expr::lit(2i64))).unwrap();
        let d2 = Arc::clone(&d);
        let (tx, rx) = std::sync::mpsc::channel();
        std::thread::spawn(move || {
            let h = d2.launch(task(Expr::lit(3i64)));
            let _ = tx.send(h.is_ok());
        });
        assert!(
            rx.recv_timeout(Duration::from_millis(60)).is_err(),
            "enqueue past the bound should have blocked"
        );
        // Open the gate: the dispatcher drains, space frees, enqueue lands.
        {
            let (lock, cv) = &*gate;
            *lock.lock().unwrap() = true;
            cv.notify_all();
        }
        assert_eq!(rx.recv_timeout(Duration::from_secs(5)), Ok(true));
        d.shutdown();
    }

    #[test]
    fn shutdown_fails_queued_tasks_instead_of_hanging() {
        let stalls = Arc::new(AtomicUsize::new(0));
        let s = Arc::clone(&stalls);
        let never: LaunchFn = Box::new(move |t| {
            // First launch sleeps long enough for shutdown to arrive.
            s.fetch_add(1, Ordering::SeqCst);
            std::thread::sleep(Duration::from_millis(50));
            use crate::backend::{sequential::SequentialBackend, Backend};
            SequentialBackend::new().launch(t)
        });
        let d = Dispatcher::new(4, never);
        let _in_flight = d.launch(task(Expr::lit(0i64))).unwrap();
        let mut queued = d.launch(task(Expr::lit(1i64))).unwrap();
        std::thread::sleep(Duration::from_millis(10));
        d.shutdown();
        match queued.wait() {
            Err(FutureError::Launch(_)) => {}
            other => panic!("queued task should fail on shutdown, got {other:?}"),
        }
    }

    #[test]
    fn cancel_after_dispatcher_claims_task_still_cancels() {
        // The race the pre-launch cancelled() check cannot catch: the
        // dispatcher has already POPPED the task and is inside launch()
        // when cancel() flags the cell.  fulfill() must honor the flag —
        // cancel the live handle and latch Cancelled — so cancel()'s
        // `true` and a later wait() agree.
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        let g = Arc::clone(&gate);
        let gated: LaunchFn = Box::new(move |t| {
            let (lock, cv) = &*g;
            let mut open = lock.lock().unwrap();
            while !*open {
                open = cv.wait(open).unwrap();
            }
            use crate::backend::{sequential::SequentialBackend, Backend};
            SequentialBackend::new().launch(t)
        });
        let d = Dispatcher::new(4, gated);
        let mut h = d.launch(task(Expr::lit(1i64))).unwrap();
        // Give the dispatcher time to pop the task and block in launch().
        std::thread::sleep(Duration::from_millis(30));
        assert!(h.cancel(), "cancel of a claimed-but-unlaunched task should succeed");
        {
            let (lock, cv) = &*gate;
            *lock.lock().unwrap() = true;
            cv.notify_all();
        }
        match h.wait() {
            Err(FutureError::Cancelled) => {}
            other => panic!("expected Cancelled, got {other:?}"),
        }
        d.shutdown();
    }

    #[test]
    fn cancel_while_queued_prevents_launch() {
        let launches = Arc::new(AtomicUsize::new(0));
        let l = Arc::clone(&launches);
        let counting: LaunchFn = Box::new(move |t| {
            l.fetch_add(1, Ordering::SeqCst);
            std::thread::sleep(Duration::from_millis(40));
            use crate::backend::{sequential::SequentialBackend, Backend};
            SequentialBackend::new().launch(t)
        });
        let d = Dispatcher::new(4, counting);
        let _busy = d.launch(task(Expr::lit(0i64))).unwrap();
        let mut h = d.launch(task(Expr::lit(1i64))).unwrap();
        assert!(h.cancel(), "cancel of a queued task should succeed");
        match h.wait() {
            Err(FutureError::Cancelled) => {}
            other => panic!("expected Cancelled, got {other:?}"),
        }
        d.shutdown();
        assert_eq!(launches.load(Ordering::SeqCst), 1, "cancelled task must not launch");
    }
}
