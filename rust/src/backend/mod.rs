//! Future backends — the pluggable "how/where" of the framework.
//!
//! [`Backend`] is the *Future API backend specification* the paper describes:
//! any implementation that passes the [`crate::conformance`] suite can be
//! selected by the end-user via `plan()` without changing a line of user
//! code.  Built-ins mirror the paper's set:
//!
//! | paper            | here                                   |
//! |------------------|----------------------------------------|
//! | `sequential`     | [`sequential::SequentialBackend`]      |
//! | `multicore`      | [`threadpool::ThreadPoolBackend`]      |
//! | `multisession`   | [`multiprocess::MultiprocessBackend`]  |
//! | `cluster`        | [`cluster::ClusterBackend`]            |
//! | `batchtools_*`   | [`batch::BatchBackend`]                |
//!
//! Third-party backends register a factory via
//! [`crate::api::plan::register_backend`] and are selected with
//! `PlanSpec::Custom` — the paper's "third-party contributions meeting the
//! specifications are automatically supported".

pub mod batch;
pub mod cluster;
pub mod dispatch;
pub mod multiprocess;
pub mod procpool;
pub mod sequential;
pub mod supervisor;
pub mod threadpool;

use std::sync::Arc;

use crate::api::error::FutureError;
use crate::api::plan::{lookup_backend_factory, PlanSpec};
use crate::backend::dispatch::CompletionWaker;
use crate::ipc::{TaskOutcome, TaskResult, TaskSpec};

/// Handle to one launched (possibly still running) task.
pub trait TaskHandle: Send {
    /// Non-blocking: has the task finished (successfully or not)?
    fn is_resolved(&mut self) -> bool;

    /// Block until the task finishes and take its result.  At-most-once;
    /// infrastructure failures surface as [`FutureError`]s.
    fn wait(&mut self) -> Result<TaskResult, FutureError>;

    /// Best-effort cancellation (extension; `suspend()` is "Future work" in
    /// the paper).  Returns true if the task was prevented from completing.
    fn cancel(&mut self) -> bool {
        false
    }

    /// How many launches this handle has made (1 = the original submission;
    /// >1 means the supervisor resubmitted after infrastructure loss).
    /// Feeds [`FutureError::TimedOut::attempts`] so a deadline expiry
    /// reports how much work was actually tried.
    fn attempts(&self) -> u32 {
        1
    }

    /// Register a completion subscription: when this task resolves, the
    /// backend calls `waker.notify(token)` exactly once.  Returns `true`
    /// when the backend delivers push notifications (every built-in does);
    /// `false` means unsupported and the caller must poll this handle —
    /// [`crate::api::future::FutureSet`] downgrades such futures to a
    /// timed poll fallback.  Subscribing to an already-resolved task
    /// notifies immediately.  At most one subscription per handle is kept
    /// (last one wins).
    fn subscribe(&mut self, waker: &Arc<CompletionWaker>, token: u64) -> bool {
        let _ = (waker, token);
        false
    }
}

/// The backend specification: launch tasks, report capacity.
///
/// **Launch blocks when all workers are busy** — the paper's core blocking
/// semantic ("this causes `future()` to block until one of the workers is
/// available").
pub trait Backend: Send + Sync {
    /// Paper-style name ("sequential", "multicore", ...).
    fn name(&self) -> &'static str;

    /// Number of parallel workers.
    fn workers(&self) -> usize;

    /// Whether `immediateCondition`s relay live (before `value()`).
    /// Backends with a live channel relay them through
    /// [`crate::api::conditions::relay_immediate`] as they arrive; the rest
    /// deliver them with the result.
    fn supports_immediate(&self) -> bool {
        false
    }

    /// Launch a task, blocking while no worker is free.
    fn launch(&self, task: TaskSpec) -> Result<Box<dyn TaskHandle>, FutureError>;

    /// Enqueue a task *without* blocking on seat availability — the queued
    /// dispatch path behind [`crate::api::future::FutureOpts::queued`].
    /// Backends with a [`dispatch::Dispatcher`] return immediately with a
    /// backlog-backed handle (bounded: a full backlog blocks — that is the
    /// backpressure, not failure); launch errors then surface at
    /// `value()`/`wait()` instead of creation.  The default falls back to
    /// the blocking [`Backend::launch`], preserving the paper's
    /// block-on-create semantics for backends without a dispatcher.
    fn launch_queued(&self, task: TaskSpec) -> Result<Box<dyn TaskHandle>, FutureError> {
        self.launch(task)
    }

    /// Whether this backend can deliver a resolved dependency's outcome
    /// directly to the seat evaluating a consumer task (wire-v7 `Forward`
    /// frames) — promise pipelining.  Backends answering `false` force
    /// [`crate::api::future::future_pipelined`] to resolve dependencies
    /// coordinator-side before launch (prebinding), which is always
    /// correct, just a round trip slower.
    fn supports_pipelining(&self) -> bool {
        false
    }

    /// Forward `outcome` (the resolved value of dependency future
    /// `dep_future_id`) to whichever worker is evaluating
    /// `consumer_task_id`.  Outcomes must survive the consumer's
    /// supervised retries — a relaunched attempt's fresh seat receives
    /// every forward again.  Returns `false` when the backend cannot
    /// deliver (shutting down, or pipelining unsupported); the caller
    /// then has no fallback, which is why creation probes
    /// [`Backend::supports_pipelining`] first.
    fn pipeline_forward(
        &self,
        consumer_task_id: &str,
        dep_future_id: &str,
        outcome: &TaskOutcome,
    ) -> bool {
        let _ = (consumer_task_id, dep_future_id, outcome);
        false
    }

    /// Tear down workers (called on `plan()` change and process exit).
    fn shutdown(&self) {}
}

/// Instantiate the backend for a plan spec.
pub fn make_backend(spec: &PlanSpec) -> Result<Arc<dyn Backend>, FutureError> {
    Ok(match spec {
        PlanSpec::Sequential => Arc::new(sequential::SequentialBackend::new()),
        PlanSpec::ThreadPool { .. } => {
            Arc::new(threadpool::ThreadPoolBackend::new(spec.effective_workers()))
        }
        PlanSpec::Multiprocess { .. } => {
            Arc::new(multiprocess::MultiprocessBackend::new(spec.effective_workers())?)
        }
        PlanSpec::Cluster { hosts } => Arc::new(cluster::ClusterBackend::new(hosts)?),
        PlanSpec::Batch { submit_latency_ms, poll_interval_ms, .. } => {
            Arc::new(batch::BatchBackend::new(
                spec.effective_workers(),
                *submit_latency_ms,
                *poll_interval_ms,
            )?)
        }
        PlanSpec::Custom { name, workers } => match lookup_backend_factory(name) {
            Some(factory) => factory(*workers),
            None => {
                return Err(FutureError::InvalidPlan(format!(
                    "no registered backend named '{name}'"
                )))
            }
        },
    })
}
