//! `plan(multisession)` analog — background worker OS processes.
//!
//! The paper's multisession backend runs a SOCK cluster of R processes on
//! the local machine; tasks and globals are *serialized* to the workers and
//! results travel back over the channel.  Here each worker is a re-exec of
//! the `rustures` binary (`rustures worker --stdio`) speaking the framed
//! wire protocol over its stdin/stdout pipes.  Everything a task needs
//! crosses the process boundary explicitly — exactly the property that makes
//! the conformance suite's globals tests meaningful.

use std::process::{Command, Stdio};
use std::sync::Arc;

use crate::api::error::FutureError;
use crate::backend::procpool::{Connection, ProcPool, Spawner};
use crate::backend::{Backend, TaskHandle};
use crate::ipc::TaskSpec;
use crate::util::exe::worker_exe;

pub struct MultiprocessBackend {
    pool: Arc<ProcPool>,
}

fn spawn_stdio_worker() -> Result<Connection, FutureError> {
    let exe = worker_exe()?;
    let mut cmd = Command::new(&exe);
    cmd.args(["worker", "--stdio"])
        .env("TF_CPP_MIN_LOG_LEVEL", "1")
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::inherit());
    if let Some(marker) = crate::backend::supervisor::chaos_midwrite_marker() {
        // Kill-during-serialization chaos: the child dies halfway through
        // writing its first result frame (marker file = exactly once).
        cmd.env(crate::backend::supervisor::MIDWRITE_ENV, marker);
    }
    let mut child = cmd
        .spawn()
        .map_err(|e| FutureError::Launch(format!("spawn {}: {e}", exe.display())))?;
    let stdin = child.stdin.take().expect("piped stdin");
    let stdout = child.stdout.take().expect("piped stdout");
    // Name the raw pipe descriptors so the transport reactor owns this
    // connection poll-driven (no pump thread).  The boxes still own the
    // handles; the reactor keeps them alive and closes them with the
    // channel.
    #[cfg(unix)]
    let (read_fd, write_fd) = {
        use std::os::unix::io::AsRawFd;
        (Some(stdout.as_raw_fd()), Some(stdin.as_raw_fd()))
    };
    #[cfg(not(unix))]
    let (read_fd, write_fd) = (None, None);
    Ok(Connection {
        reader: Box::new(stdout),
        writer: Box::new(stdin),
        child: Some(child),
        read_fd,
        write_fd,
    })
}

impl MultiprocessBackend {
    pub fn new(workers: usize) -> Result<Self, FutureError> {
        // One simulated host ("local"): the ledger key every seat,
        // budget, and breaker of this pool lives under.
        let spawner: Spawner = Box::new(|_host| spawn_stdio_worker());
        Ok(MultiprocessBackend { pool: ProcPool::new(workers, spawner)? })
    }
}

impl Backend for MultiprocessBackend {
    fn name(&self) -> &'static str {
        "multisession"
    }

    fn workers(&self) -> usize {
        self.pool.workers()
    }

    fn supports_immediate(&self) -> bool {
        // Live pipe back to the coordinator: immediates relay as they occur.
        true
    }

    fn launch(&self, task: TaskSpec) -> Result<Box<dyn TaskHandle>, FutureError> {
        self.pool.launch(task)
    }

    fn launch_queued(&self, task: TaskSpec) -> Result<Box<dyn TaskHandle>, FutureError> {
        self.pool.launch_queued(task)
    }

    fn supports_pipelining(&self) -> bool {
        true // live channel to every worker: Forward frames deliver
    }

    fn pipeline_forward(
        &self,
        consumer_task_id: &str,
        dep_future_id: &str,
        outcome: &crate::ipc::TaskOutcome,
    ) -> bool {
        self.pool.pipeline_forward(consumer_task_id, dep_future_id, outcome)
    }

    fn shutdown(&self) {
        self.pool.shutdown();
    }
}

impl Drop for MultiprocessBackend {
    fn drop(&mut self) {
        self.pool.shutdown();
    }
}
