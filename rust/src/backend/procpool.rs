//! Shared machinery for backends whose workers live behind a byte channel:
//! multiprocess (child pipes) and cluster (TCP sockets).
//!
//! Central semantic (paper, "blocking" example): a worker becomes free the
//! moment it **resolves** its future — not when the result is collected.
//! Creating three futures on two workers must unblock as soon as either of
//! the first two finishes, even if no one has called `value()` yet.  The
//! per-worker reader thread therefore returns the worker to the idle set as
//! soon as the `Result` frame arrives, parking the result in a shared map
//! until the handle asks for it.
//!
//! `immediateCondition`s are relayed **live** from the reader threads — the
//! paper's "relayed as soon as possible ... depending on the backend used".

use std::collections::{HashMap, HashSet};
use std::io::{Read, Write};
use std::process::Child;
use std::sync::{Arc, Condvar, Mutex, OnceLock, Weak};

use crate::api::conditions::relay_immediate;
use crate::api::error::FutureError;
use crate::backend::dispatch::{default_backlog, CompletionWaker, Dispatcher};
use crate::backend::supervisor::{supervisor_config, RespawnBudget, SupervisorConfig};
use crate::backend::TaskHandle;
use crate::ipc::frame::{read_message, write_message};
use crate::ipc::{Message, TaskResult, TaskSpec};

/// A connected worker's coordinator-side seat: the write half + lifecycle.
pub struct Seat {
    pub id: u64,
    writer: Box<dyn Write + Send>,
    child: Option<Child>,
}

impl Seat {
    fn send_task(&mut self, task: &TaskSpec) -> Result<(), FutureError> {
        // Encode from the reference — no clone of (possibly large) globals.
        let payload = crate::ipc::wire::encode_task_message(task);
        let len = payload.len() as u32;
        self.writer
            .write_all(&len.to_le_bytes())
            .and_then(|_| self.writer.write_all(&payload))
            .and_then(|_| self.writer.flush())
            .map_err(|e| FutureError::Channel(format!("write failed: {e}")))
    }

    fn kill(&mut self) {
        if let Some(child) = &mut self.child {
            let _ = child.kill();
            let _ = child.wait();
        }
    }

    fn graceful_shutdown(mut self) {
        let _ = write_message(&mut self.writer, &Message::Shutdown);
        if let Some(child) = &mut self.child {
            let deadline = std::time::Instant::now() + std::time::Duration::from_millis(500);
            loop {
                match child.try_wait() {
                    Ok(Some(_)) => break,
                    Ok(None) if std::time::Instant::now() < deadline => {
                        std::thread::sleep(std::time::Duration::from_millis(5));
                    }
                    _ => {
                        let _ = child.kill();
                        let _ = child.wait();
                        break;
                    }
                }
            }
        }
    }
}

/// What a finished task leaves in the results map.  Failures park the
/// *structured* error: a reader that died at a frame boundary parks
/// `WorkerDied`, a reader that errored mid-frame (truncated/corrupt bytes —
/// e.g. a worker killed during serialization) parks `Channel`, so callers
/// can tell a clean crash from a torn write.
type Parked = Result<TaskResult, FutureError>;

struct Inner {
    /// Workers ready for a task.
    idle: Vec<Seat>,
    /// worker id → (seat, task id) while a task is in flight.
    busy: HashMap<u64, (Seat, String)>,
    /// worker id → task id reserved *before* the task frame is written.
    /// Fast tasks can complete before `launch` re-acquires the lock; the
    /// reader parks such results against this reservation instead of
    /// dropping them (the send/insert race).
    pending: HashMap<u64, String>,
    /// task id → parked outcome, until the handle collects it.
    results: HashMap<String, Parked>,
    /// task id → resolution subscription: notified (once) the moment the
    /// task's result parks or the task is lost — the push half of
    /// `resolve()`/`resolve_any()` (no per-handle polling).
    waiters: HashMap<String, (Arc<CompletionWaker>, u64)>,
    /// Task ids whose handles were dropped: discard their results.
    abandoned: HashSet<String>,
    /// Live workers (idle + busy + being spawned).
    alive: usize,
    shutting_down: bool,
    next_worker_id: u64,
}

struct Shared {
    inner: Mutex<Inner>,
    /// Session-attributed supervision metrics sink, captured from the
    /// constructing session (see `metrics::ambient_scope`).
    scope: crate::metrics::CounterScope,
    /// A worker became idle (or capacity changed).
    slot_cv: Condvar,
    /// A result was parked.
    result_cv: Condvar,
    /// A worker died (or the pool is shutting down) — wakes the health
    /// monitor.  Deliberately separate from `slot_cv`: the monitor must
    /// never consume a `notify_one` meant for a parked launcher.
    death_cv: Condvar,
}

/// Transport halves for one fresh worker connection.
pub struct Connection {
    pub reader: Box<dyn Read + Send>,
    pub writer: Box<dyn Write + Send>,
    pub child: Option<Child>,
}

/// Spawner contract: produce a fresh connected worker transport.
pub type Spawner = Box<dyn Fn() -> Result<Connection, FutureError> + Send + Sync>;

/// A pool of remote workers with resolution-frees-the-worker semantics.
pub struct ProcPool {
    shared: Arc<Shared>,
    spawner: Spawner,
    workers: usize,
    /// Lifetime respawn allowance shared by the health monitor and the
    /// launch path's on-demand respawn — ONE cap on replacement workers,
    /// however they come up (`None` = supervision disabled: the historical
    /// unbudgeted on-demand respawn).
    budget: Option<Arc<RespawnBudget>>,
    /// Lazily-started queued-dispatch front (see [`crate::backend::dispatch`]).
    dispatcher: OnceLock<Dispatcher>,
}

/// Notify (and clear) the resolution subscription for `task_id`, if any.
/// Called with the pool lock held; the waker's own lock nests strictly
/// inside it, never the other way around.
fn notify_task_waiter(inner: &mut Inner, task_id: &str) {
    if let Some((waker, token)) = inner.waiters.remove(task_id) {
        waker.notify(token);
    }
}

impl ProcPool {
    /// Spawn all `workers` eagerly (PSOCK-style: cluster set up once),
    /// supervised per the process-wide [`supervisor_config`].
    pub fn new(workers: usize, spawner: Spawner) -> Result<Arc<Self>, FutureError> {
        Self::new_configured(workers, spawner, &supervisor_config())
    }

    /// [`ProcPool::new`] with an explicit supervision config (tests inject
    /// disabled respawn / tiny budgets here without touching the global).
    pub fn new_configured(
        workers: usize,
        spawner: Spawner,
        cfg: &SupervisorConfig,
    ) -> Result<Arc<Self>, FutureError> {
        let workers = workers.max(1);
        let shared = Arc::new(Shared {
            scope: crate::metrics::ambient_scope(),
            inner: Mutex::new(Inner {
                idle: Vec::with_capacity(workers),
                busy: HashMap::new(),
                pending: HashMap::new(),
                results: HashMap::new(),
                waiters: HashMap::new(),
                abandoned: HashSet::new(),
                alive: 0,
                shutting_down: false,
                next_worker_id: 0,
            }),
            slot_cv: Condvar::new(),
            result_cv: Condvar::new(),
            death_cv: Condvar::new(),
        });
        let budget = if cfg.respawn { Some(RespawnBudget::new(cfg.max_respawns)) } else { None };
        let pool = Arc::new(ProcPool {
            shared,
            spawner,
            workers,
            budget: budget.clone(),
            dispatcher: OnceLock::new(),
        });
        for _ in 0..workers {
            let seat = pool.spawn_seat()?;
            let mut inner = pool.shared.inner.lock().unwrap();
            inner.alive += 1;
            inner.idle.push(seat);
        }
        if let Some(budget) = budget {
            let weak = Arc::downgrade(&pool);
            let poll = cfg.poll;
            // Detached on purpose: the monitor holds only a Weak and exits
            // on shutdown (death_cv wake) or when the pool is dropped.
            // A failed monitor spawn is tolerable here: the launch path's
            // on-demand respawn still revives capacity (same budget).
            let _ = std::thread::Builder::new()
                .name("rustures-procpool-monitor".into())
                .spawn(move || monitor_loop(weak, budget, poll));
        }
        Ok(pool)
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Create a seat + its reader thread.
    fn spawn_seat(&self) -> Result<Seat, FutureError> {
        let conn = (self.spawner)()?;
        let id = {
            let mut inner = self.shared.inner.lock().unwrap();
            inner.next_worker_id += 1;
            inner.next_worker_id
        };
        let shared = Arc::clone(&self.shared);
        std::thread::Builder::new()
            .name(format!("rustures-reader-{id}"))
            .spawn(move || reader_loop(id, conn.reader, shared))
            .map_err(|e| FutureError::Launch(format!("spawn reader: {e}")))?;
        Ok(Seat { id, writer: conn.writer, child: conn.child })
    }

    /// Launch a task, blocking while every worker is busy (a worker frees
    /// on *resolution* of its task).
    pub fn launch(self: &Arc<Self>, task: TaskSpec) -> Result<Box<dyn TaskHandle>, FutureError> {
        let task_id = task.id.clone();
        let mut seat = {
            let mut inner = self.shared.inner.lock().unwrap();
            loop {
                if inner.shutting_down {
                    return Err(FutureError::Launch("pool is shutting down".into()));
                }
                if let Some(seat) = inner.idle.pop() {
                    // Reserve before sending: a fast worker may finish the
                    // task before we re-acquire the lock below.
                    inner.pending.insert(seat.id, task_id.clone());
                    break seat;
                }
                if inner.alive < self.workers {
                    // A worker died earlier: restore capacity — charged to
                    // the SAME respawn budget the monitor uses, so a
                    // crash-looping workload cannot fork-bomb the host
                    // through the launch path either.  (`budget: None` =
                    // supervision disabled: historical unbudgeted respawn.)
                    let allowed = self.budget.as_ref().map(|b| b.try_take()).unwrap_or(true);
                    if !allowed {
                        if inner.alive == 0 {
                            // Nothing alive and nothing may be revived:
                            // error out instead of parking forever.
                            return Err(FutureError::Launch(
                                "all pool workers died and the respawn budget is exhausted"
                                    .into(),
                            ));
                        }
                        // Live workers remain: wait for one to free.
                    } else {
                        inner.alive += 1;
                        drop(inner);
                        match self.spawn_seat() {
                            Ok(seat) => {
                                self.shared.scope.respawn();
                                let mut inner = self.shared.inner.lock().unwrap();
                                inner.pending.insert(seat.id, task_id.clone());
                                break seat;
                            }
                            Err(e) => {
                                self.shared.inner.lock().unwrap().alive -= 1;
                                // The reservation is released: wake launchers
                                // parked in this same wait loop so they observe
                                // alive < workers and retry the spawn themselves
                                // (without this they could sleep forever after a
                                // failed respawn).
                                self.shared.slot_cv.notify_all();
                                return Err(e);
                            }
                        }
                    }
                }
                inner = self.shared.slot_cv.wait(inner).unwrap();
            }
        };

        // Send outside the lock: serializing large globals must not stall
        // other launches or reader threads.
        if let Err(first_err) = seat.send_task(&task) {
            seat.kill();
            {
                // Dead worker's slot is immediately re-reserved for the
                // retry spawn, so `alive` is unchanged net.
                let mut inner = self.shared.inner.lock().unwrap();
                inner.pending.remove(&seat.id);
            }
            // One retry on a fresh worker.
            seat = match self.spawn_seat() {
                Ok(s) => s,
                Err(e) => {
                    self.shared.inner.lock().unwrap().alive -= 1;
                    // Capacity freed: wake parked launchers (same hang as
                    // the spawn-retry path above).
                    self.shared.slot_cv.notify_all();
                    return Err(e);
                }
            };
            {
                let mut inner = self.shared.inner.lock().unwrap();
                inner.pending.insert(seat.id, task_id.clone());
            }
            if let Err(e2) = seat.send_task(&task) {
                let mut inner = self.shared.inner.lock().unwrap();
                inner.pending.remove(&seat.id);
                inner.alive -= 1;
                drop(inner);
                seat.kill();
                self.shared.slot_cv.notify_all();
                return Err(FutureError::Channel(format!(
                    "send to fresh worker failed after '{first_err}': {e2}"
                )));
            }
        }

        {
            let mut inner = self.shared.inner.lock().unwrap();
            inner.pending.remove(&seat.id);
            match inner.results.get(&task_id) {
                // Fast path raced us: the result is already parked.
                Some(Ok(_)) => {
                    inner.idle.push(seat);
                    drop(inner);
                    self.shared.slot_cv.notify_one();
                }
                // Worker died right after (or while) resolving.
                Some(Err(_)) => {
                    inner.alive = inner.alive.saturating_sub(1);
                    drop(inner);
                    seat.kill();
                }
                None => {
                    inner.busy.insert(seat.id, (seat, task_id.clone()));
                }
            }
        }

        Ok(Box::new(ProcHandle { pool: Arc::clone(self), task_id, collected: false }))
    }

    /// Enqueue a task without blocking on a free seat: the pool's
    /// dispatcher thread performs the blocking [`ProcPool::launch`] when
    /// the bounded backlog's turn comes (see [`crate::backend::dispatch`]).
    pub fn launch_queued(
        self: &Arc<Self>,
        task: TaskSpec,
    ) -> Result<Box<dyn TaskHandle>, FutureError> {
        let dispatcher = self.dispatcher.get_or_init(|| {
            // Weak: the dispatcher is owned by the pool — a strong Arc here
            // would keep the pool alive forever (reference cycle).
            let pool: Weak<ProcPool> = Arc::downgrade(self);
            Dispatcher::new(
                default_backlog(self.workers),
                Box::new(move |t| match pool.upgrade() {
                    Some(pool) => pool.launch(t),
                    None => Err(FutureError::Launch("pool was dropped".into())),
                }),
            )
        });
        dispatcher.launch(task)
    }

    pub fn shutdown(&self) {
        let (idle, busy, waiters) = {
            let mut inner = self.shared.inner.lock().unwrap();
            inner.shutting_down = true;
            (
                std::mem::take(&mut inner.idle),
                std::mem::take(&mut inner.busy),
                std::mem::take(&mut inner.waiters),
            )
        };
        self.shared.slot_cv.notify_all();
        self.shared.result_cv.notify_all();
        // The health monitor exits on the shutting_down flag.
        self.shared.death_cv.notify_all();
        // Unblock the dispatcher thread (its in-flight blocking launch now
        // errors), then drain + join it.
        if let Some(d) = self.dispatcher.get() {
            d.shutdown();
        }
        // Tasks die with their seats below: wake their subscribers so a
        // FutureSet never waits on a torn-down pool.
        for (_, (waker, token)) in waiters {
            waker.notify(token);
        }
        for seat in idle {
            seat.graceful_shutdown();
        }
        for (_, (mut seat, _)) in busy {
            seat.kill();
        }
    }
}

fn reader_loop(worker_id: u64, mut reader: Box<dyn Read + Send>, shared: Arc<Shared>) {
    loop {
        let msg = read_message(&mut reader);
        match msg {
            Ok(Some(Message::Hello { .. })) | Ok(Some(Message::Pong)) => continue,
            Ok(Some(Message::Immediate { condition, .. })) => {
                relay_immediate(&condition);
            }
            Ok(Some(Message::Result(result))) => {
                let result_id = result.id.clone();
                let mut inner = shared.inner.lock().unwrap();
                // The worker is free *now* — before anyone collects.
                if let Some((seat, task_id)) = inner.busy.remove(&worker_id) {
                    debug_assert_eq!(task_id, result_id);
                    if inner.abandoned.remove(&result_id) {
                        // Nobody wants this result.
                    } else {
                        inner.results.insert(result_id.clone(), Ok(result));
                    }
                    notify_task_waiter(&mut inner, &result_id);
                    if inner.shutting_down {
                        drop(inner);
                        seat.graceful_shutdown();
                    } else {
                        inner.idle.push(seat);
                        drop(inner);
                        shared.slot_cv.notify_one();
                    }
                    shared.result_cv.notify_all();
                } else if inner.pending.get(&worker_id) == Some(&result_id) {
                    // Fast completion before launch() re-registered the
                    // seat: park the result; launch() returns the seat.
                    if !inner.abandoned.remove(&result_id) {
                        inner.results.insert(result_id.clone(), Ok(result));
                    }
                    notify_task_waiter(&mut inner, &result_id);
                    drop(inner);
                    shared.result_cv.notify_all();
                } else {
                    // cancel() raced us; drop the result.
                }
            }
            Ok(Some(other)) => {
                close_worker(
                    worker_id,
                    &shared,
                    FutureError::Channel(format!("unexpected message {other:?}")),
                );
                return;
            }
            Ok(None) => {
                // Clean EOF at a frame boundary: the worker died (or was
                // killed) between frames.
                close_worker(
                    worker_id,
                    &shared,
                    FutureError::WorkerDied { detail: "worker closed the channel".into() },
                );
                return;
            }
            Err(e) => {
                // Frame-level failure — typically a worker killed MID-WRITE
                // (truncated length prefix or body, corrupt bytes).  `e` is
                // already a structured `Channel` error; park it as such.
                close_worker(worker_id, &shared, e);
                return;
            }
        }
    }
}

/// Health monitor: proactively respawn dead workers (the elastic half of
/// the supervision subsystem).  Launch-path on-demand respawn still exists;
/// the monitor restores capacity *before* the next launch needs it, so
/// queued dispatch and parked launchers — including the PR 2 dispatcher
/// thread blocked inside `launch` — wake into a healthy seat.  Budgeted:
/// a crash-looping workload stops being revived once `budget` is spent.
fn monitor_loop(pool: Weak<ProcPool>, budget: Arc<RespawnBudget>, poll: std::time::Duration) {
    loop {
        let Some(pool) = pool.upgrade() else { return };
        // Reserve capacity under the lock (same protocol as launch()'s
        // on-demand respawn), spawn outside it.
        let deficit = {
            let inner = pool.shared.inner.lock().unwrap();
            if inner.shutting_down {
                return;
            }
            pool.workers.saturating_sub(inner.alive)
        };
        if deficit > 0 && budget.try_take() {
            {
                let mut inner = pool.shared.inner.lock().unwrap();
                if inner.shutting_down {
                    return;
                }
                if inner.alive >= pool.workers {
                    // A launcher respawned on demand first.
                    budget.refund();
                    continue;
                }
                inner.alive += 1;
            }
            match pool.spawn_seat() {
                Ok(seat) => {
                    let mut inner = pool.shared.inner.lock().unwrap();
                    if inner.shutting_down {
                        inner.alive -= 1;
                        drop(inner);
                        seat.graceful_shutdown();
                        return;
                    }
                    inner.idle.push(seat);
                    drop(inner);
                    pool.shared.scope.respawn();
                    pool.shared.slot_cv.notify_all();
                    continue; // more deficit?  re-check immediately
                }
                Err(_) => {
                    pool.shared.inner.lock().unwrap().alive -= 1;
                    // Wake parked launchers so they can try (and surface
                    // the spawn error to a caller instead of hanging).
                    pool.shared.slot_cv.notify_all();
                    // Spawner is failing: the budget charge stands (no
                    // refund — a broken spawner must not spin forever) and
                    // we back off one poll interval.
                    drop(pool);
                    std::thread::sleep(poll);
                    continue;
                }
            }
        }
        // Nothing to do: sleep until a death (death_cv) or the poll tick.
        let shared = Arc::clone(&pool.shared);
        drop(pool);
        let guard = shared.inner.lock().unwrap();
        if guard.shutting_down {
            return;
        }
        let _ = shared.death_cv.wait_timeout(guard, poll);
    }
}

fn close_worker(worker_id: u64, shared: &Shared, err: FutureError) {
    let mut inner = shared.inner.lock().unwrap();
    if !inner.shutting_down {
        // An orderly shutdown EOF is not a death worth counting.
        shared.scope.worker_death();
    }
    if let Some((mut seat, task_id)) = inner.busy.remove(&worker_id) {
        seat.kill();
        inner.alive = inner.alive.saturating_sub(1);
        if !inner.abandoned.remove(&task_id) {
            inner.results.insert(task_id.clone(), Err(err.clone()));
        }
        notify_task_waiter(&mut inner, &task_id);
    } else if let Some(task_id) = inner.pending.remove(&worker_id) {
        // Died while launch() still owns the seat: park the failure;
        // launch()'s post-send bookkeeping reclaims the seat.
        if !inner.abandoned.remove(&task_id) {
            inner.results.insert(task_id.clone(), Err(err.clone()));
        }
        notify_task_waiter(&mut inner, &task_id);
    } else {
        // Idle worker died (e.g. graceful shutdown EOF): if still seated,
        // remove it so launch() respawns capacity on demand.
        if let Some(pos) = inner.idle.iter().position(|s| s.id == worker_id) {
            let mut seat = inner.idle.remove(pos);
            seat.kill();
            inner.alive = inner.alive.saturating_sub(1);
        }
    }
    drop(inner);
    shared.slot_cv.notify_all();
    shared.result_cv.notify_all();
    // Wake the health monitor: capacity just dropped.
    shared.death_cv.notify_all();
}

/// Handle to a task launched on the pool.
pub struct ProcHandle {
    pool: Arc<ProcPool>,
    task_id: String,
    collected: bool,
}

impl ProcHandle {
    /// Is the task still in flight (unresolved, un-parked)?
    fn in_flight(inner: &Inner, task_id: &str) -> bool {
        inner.busy.values().any(|(_, t)| t == task_id)
            || inner.pending.values().any(|t| t == task_id)
    }
}

impl TaskHandle for ProcHandle {
    fn is_resolved(&mut self) -> bool {
        if self.collected {
            return true;
        }
        let inner = self.pool.shared.inner.lock().unwrap();
        inner.results.contains_key(&self.task_id) || !Self::in_flight(&inner, &self.task_id)
    }

    fn wait(&mut self) -> Result<TaskResult, FutureError> {
        if self.collected {
            return Err(FutureError::Launch("result already taken".into()));
        }
        let shared = Arc::clone(&self.pool.shared);
        let mut inner = shared.inner.lock().unwrap();
        loop {
            if let Some(parked) = inner.results.remove(&self.task_id) {
                self.collected = true;
                return parked;
            }
            if !Self::in_flight(&inner, &self.task_id) {
                self.collected = true;
                return Err(FutureError::WorkerDied {
                    detail: format!("task {} lost (worker gone)", self.task_id),
                });
            }
            inner = shared.result_cv.wait(inner).unwrap();
        }
    }

    fn cancel(&mut self) -> bool {
        if self.collected {
            return false;
        }
        let mut inner = self.pool.shared.inner.lock().unwrap();
        if inner.results.remove(&self.task_id).is_some() {
            // Already resolved: nothing to cancel, result discarded.
            self.collected = true;
            return false;
        }
        let worker_id = inner
            .busy
            .iter()
            .find(|(_, (_, t))| *t == self.task_id)
            .map(|(w, _)| *w);
        match worker_id {
            Some(w) => {
                let (mut seat, _) = inner.busy.remove(&w).unwrap();
                seat.kill();
                inner.alive = inner.alive.saturating_sub(1);
                self.collected = true;
                // Cancellation resolves the future (to an error): wake any
                // resolve()-subscriber.
                notify_task_waiter(&mut inner, &self.task_id);
                drop(inner);
                // launch() respawns capacity on demand.
                self.pool.shared.slot_cv.notify_all();
                true
            }
            None => false,
        }
    }

    fn subscribe(&mut self, waker: &Arc<CompletionWaker>, token: u64) -> bool {
        if self.collected {
            waker.notify(token);
            return true;
        }
        let mut inner = self.pool.shared.inner.lock().unwrap();
        if inner.results.contains_key(&self.task_id)
            || !Self::in_flight(&inner, &self.task_id)
        {
            // Already parked (or lost): resolved either way.
            drop(inner);
            waker.notify(token);
        } else {
            inner.waiters.insert(self.task_id.clone(), (Arc::clone(waker), token));
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::env::Env;
    use crate::api::expr::Expr;
    use crate::ipc::TaskOpts;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::time::Duration;

    fn task(expr: Expr) -> TaskSpec {
        TaskSpec {
            id: crate::util::uuid_v4(),
            expr,
            globals: Env::new(),
            opts: TaskOpts::default(),
        }
    }

    /// A reader that stays silent for a beat, then signals clean EOF — a
    /// worker that connects successfully and dies shortly after, once the
    /// pool has registered its seat.
    struct DelayedEof(Duration);

    impl std::io::Read for DelayedEof {
        fn read(&mut self, _buf: &mut [u8]) -> std::io::Result<usize> {
            std::thread::sleep(self.0);
            Ok(0)
        }
    }

    #[test]
    fn failed_respawn_wakes_parked_launchers() {
        // Spawner: the first call hands out a worker that dies shortly
        // after connecting; every later call stalls briefly and fails.
        // One launcher's failed respawn must wake a second launcher parked
        // on the slot_cv (regression: the launch error paths returned
        // without notify_all, leaving concurrent launchers asleep forever).
        let calls = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&calls);
        let spawner: Spawner = Box::new(move || {
            if c.fetch_add(1, Ordering::SeqCst) == 0 {
                Ok(Connection {
                    reader: Box::new(DelayedEof(Duration::from_millis(40))),
                    writer: Box::new(std::io::sink()),
                    child: None,
                })
            } else {
                std::thread::sleep(Duration::from_millis(120));
                Err(FutureError::Launch("no spare workers".into()))
            }
        });
        // Respawn monitor off: this regression test is about the *launch
        // path's* wakeup discipline, so the monitor must not race it.
        let cfg = SupervisorConfig { respawn: false, ..Default::default() };
        let pool = ProcPool::new_configured(1, spawner, &cfg).unwrap();
        // Let the delayed EOF retire the idle seat: alive drops to 0.
        std::thread::sleep(Duration::from_millis(120));

        let (tx, rx) = std::sync::mpsc::channel();
        for _ in 0..2 {
            let pool = Arc::clone(&pool);
            let tx = tx.clone();
            std::thread::spawn(move || {
                let outcome = pool.launch(task(Expr::lit(1i64))).map(|_| ());
                let _ = tx.send(outcome);
            });
        }
        // Both launchers must COMPLETE (with errors) — neither may hang.
        for _ in 0..2 {
            let outcome = rx
                .recv_timeout(Duration::from_secs(5))
                .expect("a launcher hung after a failed respawn");
            assert!(outcome.is_err(), "launch cannot succeed with a dead spawner");
        }
        pool.shutdown();
    }

    #[test]
    fn exhausted_budget_dead_pool_launch_errors_not_hangs() {
        // Supervision on but zero budget: once the only worker dies,
        // launch must surface a structured error — the historical
        // unbudgeted on-demand respawn is reserved for supervision OFF.
        let spawner: Spawner = Box::new(|| {
            Ok(Connection {
                reader: Box::new(DelayedEof(Duration::from_millis(5))),
                writer: Box::new(std::io::sink()),
                child: None,
            })
        });
        let cfg = SupervisorConfig {
            respawn: true,
            max_respawns: 0,
            poll: Duration::from_millis(5),
        };
        let pool = ProcPool::new_configured(1, spawner, &cfg).unwrap();
        std::thread::sleep(Duration::from_millis(60)); // the worker dies
        match pool.launch(task(Expr::lit(1i64))) {
            Err(FutureError::Launch(msg)) => assert!(msg.contains("respawn budget"), "{msg}"),
            Err(other) => panic!("expected the budget error, got {other}"),
            Ok(_) => panic!("launch on a dead, unbudgeted pool must fail"),
        }
        pool.shutdown();
    }

    #[test]
    fn monitor_respawns_dead_workers_up_to_budget() {
        // Every spawned worker "dies" ~10ms after connecting; the health
        // monitor must revive exactly `max_respawns` replacements and then
        // stop (the crash-loop backstop).
        let calls = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&calls);
        let spawner: Spawner = Box::new(move || {
            c.fetch_add(1, Ordering::SeqCst);
            Ok(Connection {
                reader: Box::new(DelayedEof(Duration::from_millis(10))),
                writer: Box::new(std::io::sink()),
                child: None,
            })
        });
        let cfg = SupervisorConfig {
            respawn: true,
            max_respawns: 3,
            poll: Duration::from_millis(5),
        };
        let pool = ProcPool::new_configured(1, spawner, &cfg).unwrap();
        std::thread::sleep(Duration::from_millis(500));
        let n = calls.load(Ordering::SeqCst);
        assert_eq!(n, 4, "1 initial spawn + 3 budgeted respawns, got {n}");
        pool.shutdown();
    }
}

impl Drop for ProcHandle {
    fn drop(&mut self) {
        if self.collected {
            return;
        }
        let mut inner = self.pool.shared.inner.lock().unwrap();
        // A dropped handle's subscription is dead weight: remove it so the
        // reader never notifies a token nobody is waiting on.
        inner.waiters.remove(&self.task_id);
        if inner.results.remove(&self.task_id).is_none() && Self::in_flight(&inner, &self.task_id)
        {
            // Still running: mark abandoned so the reader discards the
            // result but the worker itself returns to the pool.
            inner.abandoned.insert(self.task_id.clone());
        }
    }
}
