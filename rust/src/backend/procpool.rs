//! Shared machinery for backends whose workers live behind a byte channel:
//! multiprocess (child pipes) and cluster (TCP sockets).
//!
//! Central semantic (paper, "blocking" example): a worker becomes free the
//! moment it **resolves** its future — not when the result is collected.
//! Creating three futures on two workers must unblock as soon as either of
//! the first two finishes, even if no one has called `value()` yet.  Since
//! PR 10 the pool owns **no per-seat reader threads**: every worker channel
//! is registered with the process-wide [`crate::transport`] reactor, whose
//! single poll thread demultiplexes inbound frames and invokes this pool's
//! event handler — which returns the worker to the idle set (and releases
//! its [`SlotLease`]) as soon as the `Result` frame arrives, parking the
//! result in a shared map until the handle asks for it.
//!
//! Seat **admission** lives in the [`crate::capacity::CapacityLedger`]:
//! every launch acquires a lease through the ledger's single waiter queue
//! (per-session quotas and the dead-pool guard apply there), keyed by the
//! worker's **host** — so a heterogeneous cluster gets per-host respawn
//! budgets and per-host circuit breakers for free.  The pool keeps only
//! the seat *objects* (channel handles, children); it holds no private
//! slot counters or admission condvars.
//!
//! Liveness is the reactor's too: each launched task arms its seat's stall
//! deadline (from the task's [`crate::ipc::SessionContext`], so per-session
//! [`crate::liveness::LivenessConfig`]s apply) as a timer entry on the poll
//! loop — the historical per-pool `stall_loop` scan thread is gone.  The
//! [`ChannelEvent::Stalled`] callback kills the hung worker exactly the way
//! the old detector did.
//!
//! Promise pipelining (wire v7): a task may launch with unresolved
//! dependency ids in `TaskOpts::pending`.  When a dependency resolves, the
//! coordinator forwards its outcome straight to the consumer's seat as a
//! `Forward` frame ([`ProcPool::pipeline_forward`]) — one hop instead of a
//! worker→coordinator→worker round trip.  Forwarded outcomes survive the
//! consumer's retries: each relaunch retransmits them to the fresh seat
//! under the new attempt epoch.
//!
//! `immediateCondition`s are relayed **live** from the reactor handler —
//! the paper's "relayed as soon as possible ... depending on the backend
//! used".

use std::collections::{HashMap, HashSet};
use std::io::{Read, Write};
use std::process::Child;
use std::sync::{Arc, Condvar, Mutex, OnceLock, Weak};
use std::time::{Duration, Instant};

use crate::api::conditions::relay_immediate;
use crate::api::error::FutureError;
use crate::backend::dispatch::{default_backlog, CompletionWaker, Dispatcher};
use crate::backend::supervisor::{supervisor_config, SupervisorConfig};
use crate::backend::TaskHandle;
use crate::capacity::{Acquired, PoolRegistration, RevivePolicy, SlotLease};
use crate::ipc::intern::{self, SeatLedger};
use crate::ipc::{wire, Message, TaskOutcome, TaskResult, TaskSpec};
use crate::transport::{self, ChannelEvent, ChannelHandle, Endpoint, Handler};

/// A connected worker's coordinator-side seat: the outbound channel handle
/// + process lifecycle.  The inbound half lives on the transport reactor.
pub struct Seat {
    pub id: u64,
    /// The (possibly simulated) host this worker runs on — the ledger key
    /// for its seat, budget, and breaker.
    host: String,
    /// The transport channel to this worker (reactor-owned or pump-backed).
    channel: ChannelHandle,
    child: Option<Child>,
    /// Mirror of the worker's intern cache (protocol v6): which blob
    /// digests this seat has already been sent.  A fresh seat starts
    /// empty, so a respawned worker is never assumed to hold anything.
    intern: SeatLedger,
}

impl Seat {
    fn send_task(&mut self, task: &TaskSpec) -> Result<(), FutureError> {
        // Encode from the reference — no clone of (possibly large) globals.
        // v6+ frames are self-delimiting (varint body length in the header).
        let frame = if intern::session_interning(task.opts.context.session) {
            wire::encode_task_message_interned(task, &mut self.intern)
        } else {
            wire::encode_task_message(task)
        };
        self.channel.send_bytes(&frame)
    }

    fn kill(&mut self) {
        if let Some(child) = &mut self.child {
            let _ = child.kill();
            let _ = child.wait();
        }
    }

    fn graceful_shutdown(mut self) {
        let _ = self.channel.send_bytes(&wire::encode_message(&Message::Shutdown));
        // Give the reactor a beat to flush the Shutdown frame before the
        // channel (and with it the descriptors) is retired.
        let _ = self.channel.wait_outbox_below(0, Duration::from_millis(250));
        if let Some(child) = &mut self.child {
            let deadline = Instant::now() + Duration::from_millis(500);
            loop {
                match child.try_wait() {
                    Ok(Some(_)) => break,
                    Ok(None) if Instant::now() < deadline => {
                        std::thread::sleep(Duration::from_millis(5));
                    }
                    _ => {
                        let _ = child.kill();
                        let _ = child.wait();
                        break;
                    }
                }
            }
        }
        self.channel.close();
    }
}

/// What a finished task leaves in the results map.  Failures park the
/// *structured* error: a channel that died at a frame boundary parks
/// `WorkerDied`, one that errored mid-frame (truncated/corrupt bytes —
/// e.g. a worker killed during serialization) parks `Channel`, so callers
/// can tell a clean crash from a torn write.
type Parked = Result<TaskResult, FutureError>;

struct Inner {
    /// Workers ready for a task.
    idle: Vec<Seat>,
    /// worker id → (seat, task id, seat lease) while a task is in flight.
    /// The lease releases (seat frees) when the handler parks the result,
    /// or is forfeited (seat dies) when the worker goes down.
    busy: HashMap<u64, (Seat, String, SlotLease)>,
    /// worker id → task id reserved *before* the task frame is written.
    /// Fast tasks can complete before `launch` re-acquires the lock; the
    /// handler parks such results against this reservation instead of
    /// dropping them (the send/insert race).  `launch` still owns the seat
    /// and its lease for these workers.
    pending: HashMap<u64, String>,
    /// task id → parked outcome, until the handle collects it.
    results: HashMap<String, Parked>,
    /// task id → resolution subscription: notified (once) the moment the
    /// task's result parks or the task is lost — the push half of
    /// `resolve()`/`resolve_any()` (no per-handle polling).
    waiters: HashMap<String, (Arc<CompletionWaker>, u64)>,
    /// Task ids whose handles were dropped: discard their results.
    abandoned: HashSet<String>,
    /// worker id → when a frame (result, immediate, heartbeat, ...) last
    /// arrived from it.  Set when a task goes in flight, refreshed by the
    /// event handler on every frame; the stall recheck reads it.
    activity: HashMap<u64, Instant>,
    /// Workers killed by the stall handler: their channel's imminent
    /// EOF/error must not double-count the death ([`close_worker`] guard).
    stalled: HashSet<u64>,
    /// task id → the attempt epoch of its *current* launch.  A result
    /// frame carrying any other epoch is stale (a presumed-dead attempt
    /// spoke up late) and is dropped — the stale-result fence.
    expected_attempt: HashMap<String, u32>,
    /// worker id → transport channel, for every live seat regardless of
    /// which set currently owns it (idle, busy, or the pending window
    /// where `launch` holds the seat object) — the NeedBlob answer path
    /// and the Forward flusher look channels up here.
    channels: HashMap<u64, ChannelHandle>,
    /// worker id → the in-flight task's stall span (from its
    /// `SessionContext`); absent when liveness is disabled for the task.
    stall_spans: HashMap<u64, Duration>,
    /// consumer task id → forwarded dependency outcomes, in arrival order.
    /// Survives worker death: a retried launch retransmits the whole list
    /// to the fresh seat (see `pipe_sent`).
    pipe_parked: HashMap<String, Vec<(String, TaskOutcome)>>,
    /// consumer task id → (attempt the forwards were sent under, how many
    /// of `pipe_parked` have been sent).  An attempt mismatch resets the
    /// cursor so the new seat receives everything again.
    pipe_sent: HashMap<String, (u32, usize)>,
    /// consumer task id → how many dependency outcomes the task declared
    /// in `TaskOpts::pending`.  The stall deadline arms only once all of
    /// them have been forwarded (a worker waiting on a dependency is not
    /// hung).
    pipe_expected: HashMap<String, usize>,
    shutting_down: bool,
    next_worker_id: u64,
}

struct Shared {
    inner: Mutex<Inner>,
    /// This pool's seats in the capacity ledger — the ONLY admission path.
    reg: Arc<PoolRegistration>,
    /// Session-attributed supervision metrics sink, captured from the
    /// constructing session (see `metrics::ambient_scope`).
    scope: crate::metrics::CounterScope,
    /// A result was parked.
    result_cv: Condvar,
    /// A worker died (or the pool is shutting down) — wakes the health
    /// monitor (seat admission itself is the ledger's waiter queue).
    death_cv: Condvar,
}

/// Transport halves for one fresh worker connection.  Spawners that can
/// name raw descriptors (child pipes, sockets) should fill `read_fd` /
/// `write_fd`: the reactor then owns the connection without any thread.
/// In-memory transports leave them `None` and get a pump-thread fallback.
pub struct Connection {
    pub reader: Box<dyn Read + Send>,
    pub writer: Box<dyn Write + Send>,
    pub child: Option<Child>,
    /// Raw fd behind `reader`, when one exists (`AsRawFd`).
    pub read_fd: Option<i32>,
    /// Raw fd behind `writer`, when one exists (`AsRawFd`).
    pub write_fd: Option<i32>,
}

/// Spawner contract: produce a fresh connected worker transport **on the
/// given host** (the ledger picks the host; multiprocess pools only ever
/// see `"local"`).
pub type Spawner = Box<dyn Fn(&str) -> Result<Connection, FutureError> + Send + Sync>;

/// A pool of remote workers with resolution-frees-the-worker semantics.
pub struct ProcPool {
    shared: Arc<Shared>,
    spawner: Spawner,
    workers: usize,
    /// Lazily-started queued-dispatch front (see [`crate::backend::dispatch`]).
    dispatcher: OnceLock<Dispatcher>,
}

/// Notify (and clear) the resolution subscription for `task_id`, if any.
/// Called with the pool lock held; the waker's own lock nests strictly
/// inside it, never the other way around.
fn notify_task_waiter(inner: &mut Inner, task_id: &str) {
    if let Some((waker, token)) = inner.waiters.remove(task_id) {
        waker.notify(token);
    }
}

impl ProcPool {
    /// Spawn all `workers` eagerly on one simulated host (PSOCK-style:
    /// cluster set up once), supervised per the process-wide
    /// [`supervisor_config`].
    pub fn new(workers: usize, spawner: Spawner) -> Result<Arc<Self>, FutureError> {
        Self::new_configured(workers, spawner, &supervisor_config())
    }

    /// [`ProcPool::new`] with an explicit supervision config (tests inject
    /// disabled respawn / tiny budgets here without touching the global).
    pub fn new_configured(
        workers: usize,
        spawner: Spawner,
        cfg: &SupervisorConfig,
    ) -> Result<Arc<Self>, FutureError> {
        let workers = workers.max(1);
        Self::new_with_hosts("multisession", &[("local".to_string(), workers)], spawner, cfg)
    }

    /// A pool whose seats are spread over named hosts (`host` × seat
    /// count) — the cluster shape.  Each host gets its own respawn budget
    /// and circuit breaker in the ledger.
    pub fn new_with_hosts(
        backend_name: &'static str,
        hosts: &[(String, usize)],
        spawner: Spawner,
        cfg: &SupervisorConfig,
    ) -> Result<Arc<Self>, FutureError> {
        let workers: usize = hosts.iter().map(|(_, n)| n).sum::<usize>().max(1);
        // Supervision ON: per-host budgeted revives (monitor + on-demand
        // launch path share each host's allowance).  OFF: the historical
        // unbudgeted on-demand respawn.
        let policy = if cfg.respawn {
            RevivePolicy::Budgeted(cfg.max_respawns)
        } else {
            RevivePolicy::Unbudgeted
        };
        let reg = Arc::new(PoolRegistration::register(backend_name, hosts, policy, cfg.breaker));
        let shared = Arc::new(Shared {
            inner: Mutex::new(Inner {
                idle: Vec::with_capacity(workers),
                busy: HashMap::new(),
                pending: HashMap::new(),
                results: HashMap::new(),
                waiters: HashMap::new(),
                abandoned: HashSet::new(),
                activity: HashMap::new(),
                stalled: HashSet::new(),
                expected_attempt: HashMap::new(),
                channels: HashMap::new(),
                stall_spans: HashMap::new(),
                pipe_parked: HashMap::new(),
                pipe_sent: HashMap::new(),
                pipe_expected: HashMap::new(),
                shutting_down: false,
                next_worker_id: 0,
            }),
            reg,
            scope: crate::metrics::ambient_scope(),
            result_cv: Condvar::new(),
            death_cv: Condvar::new(),
        });
        let pool = Arc::new(ProcPool {
            shared,
            spawner,
            workers,
            dispatcher: OnceLock::new(),
        });
        for (host, seats) in hosts {
            for _ in 0..*seats {
                let seat = pool.spawn_seat(host)?;
                let mut inner = pool.shared.inner.lock().unwrap();
                inner.idle.push(seat);
                drop(inner);
                // Activate AFTER the seat is in the idle set: a lease is
                // never granted for a seat that is not there yet.
                pool.shared.reg.activate(host);
            }
        }
        if cfg.respawn {
            let weak = Arc::downgrade(&pool);
            let poll = cfg.poll;
            // Detached on purpose: the monitor holds only a Weak and exits
            // on shutdown (death_cv wake) or when the pool is dropped.
            // A failed monitor spawn is tolerable here: the launch path's
            // on-demand revive still restores capacity (same budget).
            let _ = std::thread::Builder::new()
                .name("rustures-procpool-monitor".into())
                .spawn(move || monitor_loop(weak, poll));
        }
        // No stall thread: hang detection is the transport reactor's timer
        // scan.  Each launch arms its seat's deadline from the task's own
        // SessionContext, so per-session liveness configs apply and a pool
        // with liveness disabled costs nothing.
        Ok(pool)
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// This pool's capacity-ledger registration (tests/diagnostics).
    pub fn registration(&self) -> &Arc<PoolRegistration> {
        &self.shared.reg
    }

    /// Create a seat on `host` and register its connection with the
    /// transport reactor (fd-backed when the spawner named descriptors,
    /// pump-thread fallback otherwise).  The handler holds only a `Weak`
    /// to the pool state: a dropped pool silently drains late events.
    fn spawn_seat(&self, host: &str) -> Result<Seat, FutureError> {
        let conn = (self.spawner)(host)?;
        let id = {
            let mut inner = self.shared.inner.lock().unwrap();
            inner.next_worker_id += 1;
            inner.next_worker_id
        };
        let weak = Arc::downgrade(&self.shared);
        let handler: Handler = Arc::new(move |ev| {
            if let Some(shared) = weak.upgrade() {
                handle_event(id, &shared, ev);
            }
        });
        let endpoint = match (conn.read_fd, conn.write_fd) {
            (Some(rfd), Some(wfd)) => Endpoint::with_fds(conn.reader, conn.writer, rfd, wfd),
            _ => Endpoint::stream(conn.reader, conn.writer),
        };
        let channel = transport::register(&format!("procpool-{id}"), endpoint, handler);
        self.shared.inner.lock().unwrap().channels.insert(id, channel.clone());
        Ok(Seat {
            id,
            host: host.to_string(),
            channel,
            child: conn.child,
            intern: SeatLedger::new(),
        })
    }

    /// Acquire a seat through the ledger and match it to an idle worker.
    /// The ledger may instead hand back a revive ticket (a dead seat whose
    /// host's budget and breaker admit an on-demand respawn) — then we
    /// spawn the replacement ourselves and lease it directly.
    fn claim_seat(
        self: &Arc<Self>,
        task: &TaskSpec,
    ) -> Result<(Seat, SlotLease), FutureError> {
        loop {
            match self.shared.reg.acquire_or_revive(task.opts.context.session)? {
                Acquired::Seat(lease) => {
                    let mut inner = self.shared.inner.lock().unwrap();
                    if inner.shutting_down {
                        return Err(FutureError::Launch("pool is shutting down".into()));
                    }
                    match inner.idle.iter().position(|s| s.host == lease.host()) {
                        Some(pos) => {
                            let seat = inner.idle.remove(pos);
                            inner.pending.insert(seat.id, task.id.clone());
                            // Register the launch's attempt epoch: frames
                            // from any OTHER epoch of this task are stale.
                            inner.expected_attempt.insert(task.id.clone(), task.opts.attempt);
                            return Ok((seat, lease));
                        }
                        None => {
                            // The leased seat died between grant and pop
                            // (idle-death race): forfeit restores the
                            // ledger's truth (the seat is dead) and we
                            // re-enter admission — the revive machinery
                            // brings real capacity back.
                            drop(inner);
                            lease.forfeit();
                            continue;
                        }
                    }
                }
                Acquired::Revive(ticket) => {
                    match self.spawn_seat(ticket.host()) {
                        Ok(mut seat) => {
                            self.shared.scope.respawn();
                            let lease = ticket.commit_lease();
                            let mut inner = self.shared.inner.lock().unwrap();
                            if inner.shutting_down {
                                inner.channels.remove(&seat.id);
                                drop(inner);
                                seat.kill();
                                seat.channel.close();
                                return Err(FutureError::Launch(
                                    "pool is shutting down".into(),
                                ));
                            }
                            inner.pending.insert(seat.id, task.id.clone());
                            inner.expected_attempt.insert(task.id.clone(), task.opts.attempt);
                            return Ok((seat, lease));
                        }
                        // Dropping the ticket aborts the revive (the seat
                        // returns to dead; the budget charge stands) and
                        // wakes other parked launchers to try themselves.
                        Err(e) => return Err(e),
                    }
                }
            }
        }
    }

    /// Launch a task, blocking while every worker is busy (a worker frees
    /// on *resolution* of its task; admission — including per-session
    /// quotas and the dead-pool guard — is the capacity ledger's).
    pub fn launch(self: &Arc<Self>, task: TaskSpec) -> Result<Box<dyn TaskHandle>, FutureError> {
        if self.shared.inner.lock().unwrap().shutting_down {
            return Err(FutureError::Launch("pool is shutting down".into()));
        }
        let task_id = task.id.clone();
        let (mut seat, lease) = self.claim_seat(&task)?;
        let host = seat.host.clone();

        // Send outside the lock: serializing large globals must not stall
        // other launches or the reactor.  A reactor channel only errors
        // here when the transport has already observed the worker dead —
        // then retry once on a fresh worker of the SAME host, reusing the
        // lease (net seat accounting is unchanged).
        if let Err(first_err) = seat.send_task(&task) {
            seat.kill();
            self.shared.reg.record_death(&host);
            {
                let mut inner = self.shared.inner.lock().unwrap();
                inner.pending.remove(&seat.id);
                inner.channels.remove(&seat.id);
            }
            seat.channel.close();
            seat = match self.spawn_seat(&host) {
                Ok(s) => s,
                Err(e) => {
                    // Could not replace it: the seat is genuinely dead.
                    lease.forfeit();
                    return Err(e);
                }
            };
            {
                let mut inner = self.shared.inner.lock().unwrap();
                inner.pending.insert(seat.id, task_id.clone());
            }
            if let Err(e2) = seat.send_task(&task) {
                {
                    let mut inner = self.shared.inner.lock().unwrap();
                    inner.pending.remove(&seat.id);
                    inner.channels.remove(&seat.id);
                }
                seat.kill();
                seat.channel.close();
                self.shared.reg.record_death(&host);
                lease.forfeit();
                return Err(FutureError::Channel(format!(
                    "send to fresh worker failed after '{first_err}': {e2}"
                )));
            }
        }

        // Backpressure target, taken only when the task actually goes in
        // flight (waiting must happen outside the pool lock).
        let mut backpressure: Option<ChannelHandle> = None;
        {
            let mut inner = self.shared.inner.lock().unwrap();
            inner.pending.remove(&seat.id);
            match inner.results.get(&task_id) {
                // Fast path raced us: the result is already parked.
                Some(Ok(_)) => {
                    inner.idle.push(seat);
                    drop(inner);
                    // Release AFTER the seat is back in the idle set.
                    drop(lease);
                }
                // Worker died right after (or while) resolving.
                Some(Err(_)) => {
                    inner.channels.remove(&seat.id);
                    drop(inner);
                    seat.kill();
                    seat.channel.close();
                    self.shared.reg.record_death(&host);
                    lease.forfeit();
                }
                None => {
                    // The liveness clock starts now: the send completed, so
                    // silence from here on is the worker's own.
                    inner.activity.insert(seat.id, Instant::now());
                    let span_ms = task.opts.context.stall_after_ms;
                    if span_ms > 0 {
                        inner.stall_spans.insert(seat.id, Duration::from_millis(span_ms));
                    }
                    let channel = seat.channel.clone();
                    let worker_id = seat.id;
                    inner.busy.insert(worker_id, (seat, task_id.clone(), lease));
                    if task.opts.pending.is_empty() {
                        // No pipelined dependencies: the deadline arms now.
                        if span_ms > 0 {
                            channel.arm_stall(Some(Duration::from_millis(span_ms)));
                        }
                    } else {
                        // The deadline arms only once every declared
                        // dependency outcome has been forwarded — a worker
                        // blocked on its inputs is waiting, not hung.
                        inner
                            .pipe_expected
                            .insert(task_id.clone(), task.opts.pending.len());
                        flush_forwards(&mut inner, &task_id);
                    }
                    backpressure = Some(channel);
                }
            }
        }
        if let Some(channel) = backpressure {
            // Bounded outbox: a launch storm against a slow worker parks
            // here instead of growing the reactor's buffers without limit.
            // Timeout is advisory — a genuinely wedged worker is the stall
            // detector's to kill, not ours.
            let _ = channel.wait_outbox_below(8 << 20, Duration::from_secs(30));
        }

        Ok(Box::new(ProcHandle { pool: Arc::clone(self), task_id, collected: false }))
    }

    /// Enqueue a task without blocking on a free seat: the pool's
    /// dispatcher thread performs the blocking [`ProcPool::launch`] when
    /// the bounded backlog's turn comes (see [`crate::backend::dispatch`]).
    pub fn launch_queued(
        self: &Arc<Self>,
        task: TaskSpec,
    ) -> Result<Box<dyn TaskHandle>, FutureError> {
        let dispatcher = self.dispatcher.get_or_init(|| {
            // Weak: the dispatcher is owned by the pool — a strong Arc here
            // would keep the pool alive forever (reference cycle).
            let pool: Weak<ProcPool> = Arc::downgrade(self);
            Dispatcher::new(
                default_backlog(self.workers),
                Box::new(move |t| match pool.upgrade() {
                    Some(pool) => pool.launch(t),
                    None => Err(FutureError::Launch("pool was dropped".into())),
                }),
            )
        });
        dispatcher.launch(task)
    }

    /// Forward a resolved dependency's outcome to the seat evaluating
    /// `consumer_task_id` as a wire-v7 `Forward` frame — the coordinator
    /// half of promise pipelining.  The outcome is parked first, so a
    /// consumer between attempts (or still in its launch window) receives
    /// it on the next flush; parked outcomes are retransmitted to fresh
    /// seats under bumped attempt epochs.  Returns `false` only when the
    /// pool is shutting down.
    pub fn pipeline_forward(
        &self,
        consumer_task_id: &str,
        dep_future_id: &str,
        outcome: &TaskOutcome,
    ) -> bool {
        let mut inner = self.shared.inner.lock().unwrap();
        if inner.shutting_down {
            return false;
        }
        inner
            .pipe_parked
            .entry(consumer_task_id.to_string())
            .or_default()
            .push((dep_future_id.to_string(), outcome.clone()));
        flush_forwards(&mut inner, consumer_task_id);
        true
    }

    pub fn shutdown(&self) {
        let (idle, busy, waiters, channels) = {
            let mut inner = self.shared.inner.lock().unwrap();
            inner.shutting_down = true;
            inner.pipe_parked.clear();
            inner.pipe_sent.clear();
            inner.pipe_expected.clear();
            (
                std::mem::take(&mut inner.idle),
                std::mem::take(&mut inner.busy),
                std::mem::take(&mut inner.waiters),
                std::mem::take(&mut inner.channels),
            )
        };
        // Wake launchers parked in the ledger's waiter queue (they error),
        // the result waiters, and the health monitor.
        self.shared.reg.shutdown();
        self.shared.result_cv.notify_all();
        self.shared.death_cv.notify_all();
        // Unblock the dispatcher thread (its in-flight blocking launch now
        // errors), then drain + join it.
        if let Some(d) = self.dispatcher.get() {
            d.shutdown();
        }
        // Tasks die with their seats below: wake their subscribers so a
        // FutureSet never waits on a torn-down pool.
        for (waker, token) in waiters.into_values() {
            waker.notify(token);
        }
        for seat in idle {
            seat.graceful_shutdown();
        }
        for (mut seat, _, lease) in busy.into_values() {
            seat.kill();
            seat.channel.close();
            drop(lease);
        }
        // Channels for seats in neither set (a launch's pending window)
        // are retired too; close() is idempotent for the ones above.
        for ch in channels.into_values() {
            ch.close();
        }
    }
}

/// The transport handler for one worker channel — the replacement for the
/// historical per-seat `reader_loop` thread.  Runs on the reactor (or a
/// pump thread for non-fd endpoints); events for one channel arrive in
/// order.  Takes the pool lock per event; never blocks.
fn handle_event(worker_id: u64, shared: &Shared, ev: ChannelEvent) {
    match ev {
        ChannelEvent::Message(msg) => {
            {
                // ANY frame is proof of life — heartbeats exist for the
                // silent stretches, but immediates and results reset the
                // clock too.  (The transport's own activity clock, which
                // slides the stall deadline, was already touched.)
                let mut inner = shared.inner.lock().unwrap();
                if inner.activity.contains_key(&worker_id) {
                    inner.activity.insert(worker_id, Instant::now());
                }
            }
            match msg {
                Message::Hello { .. } | Message::Pong | Message::Heartbeat { .. } => {}
                Message::NeedBlob { digests } => {
                    // The worker's intern cache is missing blobs our seat
                    // ledger thought it held (eviction skew, a mid-decode
                    // respawn): answer from the process-global store.
                    intern::note_need_blob();
                    if !serve_need_blob(worker_id, shared, &digests) {
                        close_worker(
                            worker_id,
                            shared,
                            FutureError::Channel("failed to answer NeedBlob".into()),
                        );
                    }
                }
                Message::Immediate { condition, .. } => {
                    relay_immediate(&condition);
                }
                Message::Result(result) => {
                    handle_result(worker_id, shared, result);
                }
                other => {
                    close_worker(
                        worker_id,
                        shared,
                        FutureError::Channel(format!("unexpected message {other:?}")),
                    );
                }
            }
        }
        // Clean EOF at a frame boundary: the worker died (or was killed)
        // between frames.
        ChannelEvent::Closed => close_worker(
            worker_id,
            shared,
            FutureError::WorkerDied { detail: "worker closed the channel".into() },
        ),
        // Frame-level failure — typically a worker killed MID-WRITE
        // (truncated frame header or body, corrupt bytes).  Already a
        // structured `Channel` error; park it as such.
        ChannelEvent::Error(e) => close_worker(worker_id, shared, e),
        ChannelEvent::Stalled { silent_for } => stall_worker(worker_id, shared, silent_for),
    }
}

fn handle_result(worker_id: u64, shared: &Shared, result: TaskResult) {
    let result_id = result.id.clone();
    let mut inner = shared.inner.lock().unwrap();
    // The worker is free *now* — before anyone collects.
    if let Some((seat, task_id, lease)) = inner.busy.remove(&worker_id) {
        debug_assert_eq!(task_id, result_id);
        seat.channel.disarm_stall();
        inner.activity.remove(&worker_id);
        inner.stall_spans.remove(&worker_id);
        if !inner.abandoned.remove(&result_id) {
            inner.results.insert(result_id.clone(), Ok(result));
        }
        notify_task_waiter(&mut inner, &result_id);
        if inner.shutting_down {
            inner.channels.remove(&worker_id);
            drop(inner);
            drop(lease);
            seat.graceful_shutdown();
        } else {
            inner.idle.push(seat);
            drop(inner);
            // Release AFTER the seat is back in the idle set: a woken
            // launcher must always find it there.
            drop(lease);
        }
        shared.result_cv.notify_all();
    } else if inner.pending.get(&worker_id) == Some(&result_id) {
        // Fast completion before launch() re-registered the seat: park
        // the result; launch() returns the seat.
        if !inner.abandoned.remove(&result_id) {
            inner.results.insert(result_id.clone(), Ok(result));
        }
        notify_task_waiter(&mut inner, &result_id);
        drop(inner);
        shared.result_cv.notify_all();
    } else {
        // This worker no longer owns the task: either cancel() raced us,
        // or this is a late frame from a presumed-dead attempt (the worker
        // was declared hung, its task relaunched under a bumped epoch).
        // Either way the frame is dropped; when the attempt epoch proves
        // it stale, count it through the fence.
        let stale = inner
            .expected_attempt
            .get(&result_id)
            .is_some_and(|want| *want != result.attempt);
        if stale {
            shared.scope.fenced();
        }
    }
}

/// Answer a worker's `NeedBlob`: look each digest up in the process-global
/// intern store and queue a `Blob` frame on the seat's channel.  `bytes:
/// None` (blob evicted from the store) still gets a frame — the worker
/// fails its decode closed and the supervisor retries on a fresh seat.
/// The channels map covers every live seat including the launch pending
/// window, so no retry loop is needed.  Returns false if the seat is gone
/// or the channel is closed.
fn serve_need_blob(worker_id: u64, shared: &Shared, digests: &[intern::Digest]) -> bool {
    let channel = shared.inner.lock().unwrap().channels.get(&worker_id).cloned();
    let Some(channel) = channel else { return false };
    for d in digests {
        let bytes = intern::store_get(d).map(|a| (*a).clone());
        let frame = wire::encode_message(&Message::Blob { digest: *d, bytes });
        if channel.send_bytes(&frame).is_err() {
            return false;
        }
    }
    true
}

/// Send every not-yet-delivered forwarded dependency outcome for
/// `task_id` to whichever seat currently evaluates it (busy, or still in
/// the launch pending window).  Retransmits from the start after a retry
/// (attempt-epoch mismatch); arms the seat's stall deadline once the last
/// declared dependency is on the wire.  No-op when the consumer has no
/// seat right now — the next launch flushes again.
fn flush_forwards(inner: &mut Inner, task_id: &str) {
    let Inner {
        busy,
        pending,
        channels,
        expected_attempt,
        stall_spans,
        pipe_parked,
        pipe_sent,
        pipe_expected,
        ..
    } = inner;
    let Some(parked) = pipe_parked.get(task_id) else { return };
    let worker_id = busy
        .iter()
        .find(|(_, (_, t, _))| t == task_id)
        .map(|(w, _)| *w)
        .or_else(|| pending.iter().find(|(_, t)| *t == task_id).map(|(w, _)| *w));
    let Some(worker_id) = worker_id else { return };
    let Some(channel) = channels.get(&worker_id) else { return };
    let attempt = expected_attempt.get(task_id).copied().unwrap_or(0);
    let cursor = pipe_sent.entry(task_id.to_string()).or_insert((attempt, 0));
    if cursor.0 != attempt {
        // A fresh attempt evaluates on a fresh seat: start over.
        *cursor = (attempt, 0);
    }
    while cursor.1 < parked.len() {
        let (dep_id, outcome) = &parked[cursor.1];
        let frame = wire::encode_message(&Message::Forward {
            future_id: dep_id.clone(),
            outcome: outcome.clone(),
        });
        // A closed channel means the worker is already dying; the retry
        // path retransmits everything to its replacement.
        let _ = channel.send_bytes(&frame);
        transport::note_forward();
        cursor.1 += 1;
    }
    if busy.contains_key(&worker_id) {
        if let Some(&expected) = pipe_expected.get(task_id) {
            if cursor.1 >= expected {
                if let Some(span) = stall_spans.get(&worker_id) {
                    channel.arm_stall(Some(*span));
                }
            }
        }
    }
}

/// Health monitor: proactively revive dead seats through the ledger
/// ([`PoolRegistration::try_revive`] charges the per-host budget and is
/// gated by each host's circuit breaker).  Launch-path on-demand revival
/// still exists; the monitor restores capacity *before* the next launch
/// needs it, so queued dispatch and parked launchers — including the PR 2
/// dispatcher thread blocked inside `launch` — wake into a healthy seat.
fn monitor_loop(pool: Weak<ProcPool>, poll: Duration) {
    loop {
        let Some(pool) = pool.upgrade() else { return };
        {
            let inner = pool.shared.inner.lock().unwrap();
            if inner.shutting_down {
                return;
            }
        }
        if let Some(ticket) = pool.shared.reg.try_revive() {
            match pool.spawn_seat(ticket.host()) {
                Ok(seat) => {
                    let mut inner = pool.shared.inner.lock().unwrap();
                    if inner.shutting_down {
                        inner.channels.remove(&seat.id);
                        drop(inner);
                        seat.graceful_shutdown();
                        // Ticket drop aborts the revive; nobody will need
                        // the seat again.
                        return;
                    }
                    inner.idle.push(seat);
                    drop(inner);
                    pool.shared.scope.respawn();
                    // Commit AFTER the push: a woken launcher finds the
                    // seat in the idle set.
                    ticket.commit_idle();
                    continue; // more deficit?  re-check immediately
                }
                Err(_) => {
                    // Spawner is failing: dropping the ticket aborts the
                    // revive (the budget charge stands — a broken spawner
                    // must not spin forever); back off one poll interval.
                    drop(ticket);
                    drop(pool);
                    std::thread::sleep(poll);
                    continue;
                }
            }
        }
        // Nothing to do: sleep until a death (death_cv) or the poll tick.
        let shared = Arc::clone(&pool.shared);
        drop(pool);
        let guard = shared.inner.lock().unwrap();
        if guard.shutting_down {
            return;
        }
        let _ = shared.death_cv.wait_timeout(guard, poll);
    }
}

/// The reactor declared this seat's task hung (its armed stall deadline
/// expired with no inbound frame): kill the worker — breaker-counted
/// death, lease forfeited (the seat returns to the ledger through the
/// revive machinery) — and park a retryable `WorkerDied` for the handle;
/// the supervised-retry path takes it from there, exactly as for a crash.
fn stall_worker(worker_id: u64, shared: &Shared, silent_for: Duration) {
    let mut inner = shared.inner.lock().unwrap();
    if inner.shutting_down {
        return;
    }
    let Some((mut seat, task_id, lease)) = inner.busy.remove(&worker_id) else {
        return; // resolved (or died) while the event was in flight
    };
    let span = inner.stall_spans.get(&worker_id).copied();
    // Defensive recheck under the pool lock: a pump-thread frame may have
    // refreshed the activity clock after the reactor's timer fired.
    if let Some(span) = span {
        if inner
            .activity
            .get(&worker_id)
            .is_some_and(|t| t.elapsed() <= span)
        {
            // Not actually silent: re-arm (the reactor disarmed on fire)
            // and put the seat back.
            seat.channel.arm_stall(Some(span));
            inner.busy.insert(worker_id, (seat, task_id, lease));
            return;
        }
    }
    inner.activity.remove(&worker_id);
    inner.stall_spans.remove(&worker_id);
    // The channel's imminent EOF must not count this death again.
    inner.stalled.insert(worker_id);
    shared.scope.stall();
    shared.scope.worker_death();
    seat.kill();
    shared.reg.record_death(&seat.host);
    lease.forfeit();
    let silent = span.unwrap_or(silent_for);
    if !inner.abandoned.remove(&task_id) {
        inner.results.insert(
            task_id.clone(),
            Err(FutureError::WorkerDied {
                detail: format!(
                    "worker hung (no liveness signal for {}ms)",
                    silent.as_millis()
                ),
            }),
        );
    }
    notify_task_waiter(&mut inner, &task_id);
    drop(inner);
    shared.result_cv.notify_all();
    // Capacity just dropped: wake the health monitor to revive the seat.
    shared.death_cv.notify_all();
}

fn close_worker(worker_id: u64, shared: &Shared, err: FutureError) {
    let mut inner = shared.inner.lock().unwrap();
    let channel = inner.channels.remove(&worker_id);
    if inner.stalled.remove(&worker_id) {
        // The stall handler already did everything (kill, death count,
        // breaker, lease forfeit, parked error); this is just its channel
        // reporting the EOF.
        drop(inner);
        if let Some(ch) = channel {
            ch.close();
        }
        return;
    }
    let during_shutdown = inner.shutting_down;
    if !during_shutdown {
        // An orderly shutdown EOF is not a death worth counting.
        shared.scope.worker_death();
    }
    if let Some((mut seat, task_id, lease)) = inner.busy.remove(&worker_id) {
        inner.activity.remove(&worker_id);
        inner.stall_spans.remove(&worker_id);
        seat.kill();
        // Ledger first (breaker fed, seat forfeited), THEN park the error:
        // a collector woken by the parked failure must find the breaker
        // already up to date.  Ledger locks nest inside the pool lock.
        if !during_shutdown {
            shared.reg.record_death(&seat.host);
        }
        lease.forfeit();
        if !inner.abandoned.remove(&task_id) {
            inner.results.insert(task_id.clone(), Err(err.clone()));
        }
        notify_task_waiter(&mut inner, &task_id);
    } else if let Some(task_id) = inner.pending.remove(&worker_id) {
        // Died while launch() still owns the seat and its lease: park the
        // failure; launch()'s post-send bookkeeping kills the seat,
        // records the death, and forfeits the lease.
        if !inner.abandoned.remove(&task_id) {
            inner.results.insert(task_id.clone(), Err(err.clone()));
        }
        notify_task_waiter(&mut inner, &task_id);
    } else {
        // Idle worker died (e.g. crashed between tasks): retire the seat
        // so the revive machinery restores capacity.
        if let Some(pos) = inner.idle.iter().position(|s| s.id == worker_id) {
            let mut seat = inner.idle.remove(pos);
            seat.kill();
            if !during_shutdown {
                shared.reg.seat_died_idle(&seat.host);
                shared.reg.record_death(&seat.host);
            }
        }
    }
    drop(inner);
    if let Some(ch) = channel {
        ch.close();
    }
    shared.result_cv.notify_all();
    // Wake the health monitor: capacity just dropped.
    shared.death_cv.notify_all();
}

/// Handle to a task launched on the pool.
pub struct ProcHandle {
    pool: Arc<ProcPool>,
    task_id: String,
    collected: bool,
}

impl ProcHandle {
    /// Is the task still in flight (unresolved, un-parked)?
    fn in_flight(inner: &Inner, task_id: &str) -> bool {
        inner.busy.values().any(|(_, t, _)| t == task_id)
            || inner.pending.values().any(|t| t == task_id)
    }

    /// Drop the pipelining state for a task that will never launch again
    /// (collected, cancelled, or its handle dropped).
    fn clear_pipeline(inner: &mut Inner, task_id: &str) {
        inner.pipe_parked.remove(task_id);
        inner.pipe_sent.remove(task_id);
        inner.pipe_expected.remove(task_id);
    }
}

impl TaskHandle for ProcHandle {
    fn is_resolved(&mut self) -> bool {
        if self.collected {
            return true;
        }
        let inner = self.pool.shared.inner.lock().unwrap();
        inner.results.contains_key(&self.task_id) || !Self::in_flight(&inner, &self.task_id)
    }

    fn wait(&mut self) -> Result<TaskResult, FutureError> {
        if self.collected {
            return Err(FutureError::Launch("result already taken".into()));
        }
        let shared = Arc::clone(&self.pool.shared);
        let mut inner = shared.inner.lock().unwrap();
        loop {
            if let Some(parked) = inner.results.remove(&self.task_id) {
                self.collected = true;
                inner.expected_attempt.remove(&self.task_id);
                // Forwards are retransmitted per ATTEMPT, not per result:
                // a supervised retry reuses the task id, so the state must
                // survive until the caller actually takes an outcome.
                if parked.is_ok() {
                    Self::clear_pipeline(&mut inner, &self.task_id);
                }
                return parked;
            }
            if !Self::in_flight(&inner, &self.task_id) {
                self.collected = true;
                inner.expected_attempt.remove(&self.task_id);
                return Err(FutureError::WorkerDied {
                    detail: format!("task {} lost (worker gone)", self.task_id),
                });
            }
            inner = shared.result_cv.wait(inner).unwrap();
        }
    }

    fn cancel(&mut self) -> bool {
        if self.collected {
            return false;
        }
        let mut inner = self.pool.shared.inner.lock().unwrap();
        if inner.results.remove(&self.task_id).is_some() {
            // Already resolved: nothing to cancel, result discarded.
            self.collected = true;
            inner.expected_attempt.remove(&self.task_id);
            Self::clear_pipeline(&mut inner, &self.task_id);
            return false;
        }
        let worker_id = inner
            .busy
            .iter()
            .find(|(_, (_, t, _))| *t == self.task_id)
            .map(|(w, _)| *w);
        match worker_id {
            Some(w) => {
                let (mut seat, _, lease) = inner.busy.remove(&w).unwrap();
                inner.activity.remove(&w);
                inner.stall_spans.remove(&w);
                inner.expected_attempt.remove(&self.task_id);
                Self::clear_pipeline(&mut inner, &self.task_id);
                seat.channel.disarm_stall();
                // Best-effort courtesy frame: a worker that happens to be
                // between tasks drops the id cleanly; one mid-evaluation
                // never reads it — the kill below is the enforcement.
                let _ = seat.channel.send_bytes(&wire::encode_message(&Message::Cancel {
                    task_id: self.task_id.clone(),
                }));
                seat.kill();
                // User intent, not a host failure: the seat is forfeited
                // (revive restores it, charged to the host budget) but the
                // breaker window is NOT fed.
                lease.forfeit();
                self.collected = true;
                self.pool.shared.scope.cancel();
                // Cancellation resolves the future (to an error): wake any
                // resolve()-subscriber.
                notify_task_waiter(&mut inner, &self.task_id);
                true
            }
            None => false,
        }
    }

    fn subscribe(&mut self, waker: &Arc<CompletionWaker>, token: u64) -> bool {
        if self.collected {
            waker.notify(token);
            return true;
        }
        let mut inner = self.pool.shared.inner.lock().unwrap();
        if inner.results.contains_key(&self.task_id)
            || !Self::in_flight(&inner, &self.task_id)
        {
            // Already parked (or lost): resolved either way.
            drop(inner);
            waker.notify(token);
        } else {
            inner.waiters.insert(self.task_id.clone(), (Arc::clone(waker), token));
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::env::Env;
    use crate::api::expr::Expr;
    use crate::ipc::TaskOpts;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::time::Duration;

    fn task(expr: Expr) -> TaskSpec {
        TaskSpec {
            id: crate::util::uuid_v4(),
            expr,
            globals: Env::new(),
            opts: TaskOpts::default(),
        }
    }

    /// A reader that stays silent for a beat, then signals clean EOF — a
    /// worker that connects successfully and dies shortly after, once the
    /// pool has registered its seat.  No raw fds: the transport falls back
    /// to a pump thread, same handler path.
    struct DelayedEof(Duration);

    impl std::io::Read for DelayedEof {
        fn read(&mut self, _buf: &mut [u8]) -> std::io::Result<usize> {
            std::thread::sleep(self.0);
            Ok(0)
        }
    }

    #[test]
    fn failed_respawn_wakes_parked_launchers() {
        // Spawner: the first call hands out a worker that dies shortly
        // after connecting; every later call stalls briefly and fails.
        // One launcher's failed on-demand revive must wake a second
        // launcher parked in the ledger's waiter queue (the ticket-drop
        // abort notifies), so neither sleeps forever.
        let calls = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&calls);
        let spawner: Spawner = Box::new(move |_host| {
            if c.fetch_add(1, Ordering::SeqCst) == 0 {
                Ok(Connection {
                    reader: Box::new(DelayedEof(Duration::from_millis(40))),
                    writer: Box::new(std::io::sink()),
                    child: None,
                    read_fd: None,
                    write_fd: None,
                })
            } else {
                std::thread::sleep(Duration::from_millis(120));
                Err(FutureError::Launch("no spare workers".into()))
            }
        });
        // Respawn monitor off: this regression test is about the *launch
        // path's* wakeup discipline, so the monitor must not race it.
        let cfg = SupervisorConfig { respawn: false, ..Default::default() };
        let pool = ProcPool::new_configured(1, spawner, &cfg).unwrap();
        // Let the delayed EOF retire the idle seat.
        std::thread::sleep(Duration::from_millis(120));

        let (tx, rx) = std::sync::mpsc::channel();
        for _ in 0..2 {
            let pool = Arc::clone(&pool);
            let tx = tx.clone();
            std::thread::spawn(move || {
                let outcome = pool.launch(task(Expr::lit(1i64))).map(|_| ());
                let _ = tx.send(outcome);
            });
        }
        // Both launchers must COMPLETE (with errors) — neither may hang.
        for _ in 0..2 {
            let outcome = rx
                .recv_timeout(Duration::from_secs(5))
                .expect("a launcher hung after a failed revive");
            assert!(outcome.is_err(), "launch cannot succeed with a dead spawner");
        }
        pool.shutdown();
    }

    #[test]
    fn exhausted_budget_dead_pool_launch_errors_not_hangs() {
        // Supervision on but zero budget: once the only worker dies,
        // launch must surface a structured error — the historical
        // unbudgeted on-demand respawn is reserved for supervision OFF.
        let spawner: Spawner = Box::new(|_host| {
            Ok(Connection {
                reader: Box::new(DelayedEof(Duration::from_millis(5))),
                writer: Box::new(std::io::sink()),
                child: None,
                read_fd: None,
                write_fd: None,
            })
        });
        let cfg = SupervisorConfig {
            respawn: true,
            max_respawns: 0,
            poll: Duration::from_millis(5),
            ..Default::default()
        };
        let pool = ProcPool::new_configured(1, spawner, &cfg).unwrap();
        std::thread::sleep(Duration::from_millis(60)); // the worker dies
        match pool.launch(task(Expr::lit(1i64))) {
            Err(FutureError::Launch(msg)) => assert!(msg.contains("respawn budget"), "{msg}"),
            Err(other) => panic!("expected the budget error, got {other}"),
            Ok(_) => panic!("launch on a dead, unbudgeted pool must fail"),
        }
        pool.shutdown();
    }

    #[test]
    fn monitor_respawns_dead_workers_up_to_budget() {
        // Every spawned worker "dies" ~10ms after connecting; the health
        // monitor must revive exactly `max_respawns` replacements and then
        // stop (the crash-loop backstop).
        let calls = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&calls);
        let spawner: Spawner = Box::new(move |_host| {
            c.fetch_add(1, Ordering::SeqCst);
            Ok(Connection {
                reader: Box::new(DelayedEof(Duration::from_millis(10))),
                writer: Box::new(std::io::sink()),
                child: None,
                read_fd: None,
                write_fd: None,
            })
        });
        let cfg = SupervisorConfig {
            respawn: true,
            max_respawns: 3,
            poll: Duration::from_millis(5),
            ..Default::default()
        };
        let pool = ProcPool::new_configured(1, spawner, &cfg).unwrap();
        std::thread::sleep(Duration::from_millis(500));
        let n = calls.load(Ordering::SeqCst);
        assert_eq!(n, 4, "1 initial spawn + 3 budgeted respawns, got {n}");
        pool.shutdown();
    }

    #[test]
    fn breaker_routes_launches_away_from_a_dying_host() {
        // Two hosts: "bad" workers die instantly, "good" ones live.  After
        // `threshold` deaths the bad host's breaker opens — revives (and
        // therefore task placements) stop landing there while the good
        // host keeps serving; the half-open probe later re-tests it.
        let spawner: Spawner = Box::new(move |host| {
            if host == "bad" {
                Ok(Connection {
                    reader: Box::new(DelayedEof(Duration::from_millis(5))),
                    writer: Box::new(std::io::sink()),
                    child: None,
                    read_fd: None,
                    write_fd: None,
                })
            } else {
                // A "good" worker that simply never speaks (idle forever).
                Ok(Connection {
                    reader: Box::new(DelayedEof(Duration::from_secs(3600))),
                    writer: Box::new(std::io::sink()),
                    child: None,
                    read_fd: None,
                    write_fd: None,
                })
            }
        });
        let cfg = SupervisorConfig {
            respawn: true,
            max_respawns: 64,
            poll: Duration::from_millis(2),
            breaker: crate::capacity::BreakerConfig {
                threshold: 2,
                window: Duration::from_secs(10),
                cooldown: Duration::from_secs(3600), // stays open for the test
            },
        };
        let pool = ProcPool::new_with_hosts(
            "cluster",
            &[("good".to_string(), 1), ("bad".to_string(), 1)],
            spawner,
            &cfg,
        )
        .unwrap();
        let reg = Arc::clone(pool.registration());
        // The bad worker dies repeatedly; the monitor revives it until the
        // second death trips the breaker.
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        while reg.breaker_state("bad") != crate::capacity::BreakerState::Open {
            assert!(std::time::Instant::now() < deadline, "breaker never opened");
            std::thread::sleep(Duration::from_millis(5));
        }
        let respawns = reg.host_respawns("bad");
        std::thread::sleep(Duration::from_millis(50));
        assert_eq!(
            reg.host_respawns("bad"),
            respawns,
            "an open breaker must stop revives to the dying host"
        );
        assert_eq!(reg.dead_seats(), 1, "the bad seat stays down");
        assert_eq!(reg.alive_seats(), 1, "the good host keeps its capacity");
        pool.shutdown();
    }
}

impl Drop for ProcHandle {
    fn drop(&mut self) {
        if self.collected {
            return;
        }
        let mut inner = self.pool.shared.inner.lock().unwrap();
        // A dropped handle's subscription is dead weight: remove it so the
        // handler never notifies a token nobody is waiting on.
        inner.waiters.remove(&self.task_id);
        inner.expected_attempt.remove(&self.task_id);
        inner.pipe_expected.remove(&self.task_id);
        if inner.results.remove(&self.task_id).is_none() && Self::in_flight(&inner, &self.task_id)
        {
            // Still running: mark abandoned so the handler discards the
            // result but the worker itself returns to the pool.  Parked
            // forwards stay until then — the worker may still need them
            // to finish and free its seat.
            inner.abandoned.insert(self.task_id.clone());
        } else {
            Self::clear_pipeline(&mut inner, &self.task_id);
        }
    }
}
