//! `plan(sequential)` — the default backend.
//!
//! Futures are resolved synchronously *at creation*: "each `future()` blocks
//! until the previously created future has been resolved" — trivially true
//! when creation itself evaluates.  Globals are still captured and the
//! expression still evaluates against them (not the live environment), so
//! results are identical to every parallel backend.

use crate::api::conditions::relay_immediate;
use crate::api::error::FutureError;
use crate::backend::{Backend, TaskHandle};
use crate::capacity::{BreakerConfig, PoolRegistration, RevivePolicy};
use crate::ipc::{TaskResult, TaskSpec};

pub struct SequentialBackend {
    /// Even the inline backend owns a (one-seat) ledger registration, so
    /// `metrics::capacity_json()` sees every execution slot in the process
    /// and the blocking semantic is uniform.  The seat is acquired
    /// *uncounted* (no session `max_workers` charge): sequential is the
    /// implicit nested fallback and must never deadlock against its own
    /// outer future's lease.
    reg: PoolRegistration,
}

impl SequentialBackend {
    pub fn new() -> Self {
        let reg = PoolRegistration::register(
            "sequential",
            &[("local".to_string(), 1)],
            RevivePolicy::Never,
            BreakerConfig::default(),
        );
        reg.activate("local");
        SequentialBackend { reg }
    }
}

impl Default for SequentialBackend {
    fn default() -> Self {
        SequentialBackend::new()
    }
}

/// A handle that is born resolved.
pub struct ResolvedHandle {
    result: Option<TaskResult>,
}

impl ResolvedHandle {
    pub fn new(result: TaskResult) -> Self {
        ResolvedHandle { result: Some(result) }
    }
}

impl TaskHandle for ResolvedHandle {
    fn is_resolved(&mut self) -> bool {
        true
    }

    fn wait(&mut self) -> Result<TaskResult, FutureError> {
        self.result
            .take()
            .ok_or_else(|| FutureError::Launch("result already taken".into()))
    }

    fn subscribe(
        &mut self,
        waker: &std::sync::Arc<crate::backend::dispatch::CompletionWaker>,
        token: u64,
    ) -> bool {
        // Born resolved: notify immediately.
        waker.notify(token);
        true
    }
}

impl Backend for SequentialBackend {
    fn name(&self) -> &'static str {
        "sequential"
    }

    fn workers(&self) -> usize {
        1
    }

    fn supports_immediate(&self) -> bool {
        // Same process: progress conditions surface as they are signaled.
        true
    }

    fn launch(&self, task: TaskSpec) -> Result<Box<dyn TaskHandle>, FutureError> {
        // The one seat, held for the inline evaluation: concurrent callers
        // of the same sequential backend serialize here — exactly the
        // paper's "each future() blocks until the previously created
        // future has been resolved".
        let _lease = self.reg.acquire_uncounted()?;
        // Kernel runtime resolves lazily inside the evaluator on first Call.
        let kernels = None;
        // Evaluation runs under the task's shipped session context: nested
        // futures created during it see the topology *tail* at depth 0 —
        // the implicit-sequential protection applies beneath us too, and
        // the originating session's retry default carries over.
        let result = crate::api::session::scope_task_context(&task.opts.context, || {
            let mut hook = |c: &crate::api::conditions::Condition| relay_immediate(c);
            crate::worker::execute_task(&task, kernels, Some(&mut hook))
        });
        Ok(Box::new(ResolvedHandle::new(result)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::env::Env;
    use crate::api::expr::Expr;
    use crate::ipc::{TaskOpts, TaskOutcome};
    use crate::api::value::Value;

    fn task(expr: Expr) -> TaskSpec {
        TaskSpec {
            id: crate::util::uuid_v4(),
            expr,
            globals: Env::new(),
            opts: TaskOpts::default(),
        }
    }

    #[test]
    fn launch_resolves_immediately() {
        let b = SequentialBackend::new();
        let mut h = b.launch(task(Expr::add(Expr::lit(1i64), Expr::lit(1i64)))).unwrap();
        assert!(h.is_resolved());
        let r = h.wait().unwrap();
        assert_eq!(r.outcome, TaskOutcome::Ok(Value::I64(2)));
    }

    #[test]
    fn wait_is_at_most_once() {
        let b = SequentialBackend::new();
        let mut h = b.launch(task(Expr::lit(1i64))).unwrap();
        h.wait().unwrap();
        assert!(h.wait().is_err());
    }

    #[test]
    fn globals_travel_with_task() {
        let b = SequentialBackend::new();
        let mut globals = Env::new();
        globals.insert("x", 20i64);
        let t = TaskSpec {
            id: "g".into(),
            expr: Expr::add(Expr::var("x"), Expr::lit(2i64)),
            globals,
            opts: TaskOpts::default(),
        };
        let r = b.launch(t).unwrap().wait().unwrap();
        assert_eq!(r.outcome, TaskOutcome::Ok(Value::I64(22)));
    }
}
