//! The supervision subsystem — worker respawn and transparent task retry.
//!
//! PR 1 made worker death *visible* (a dead worker latches
//! [`FutureError::WorkerDied`] and `is_resolved()`/`wait()` agree forever
//! after); this module makes the framework *survive* it, in two
//! cooperating layers:
//!
//! * **Respawn** — every multi-worker backend runs a health monitor that
//!   detects dead workers (ProcPool reader EOF, thread-pool worker death,
//!   cluster socket drop) and respawns replacements up to a configurable
//!   **per-host** budget ([`SupervisorConfig::max_respawns`], tracked by
//!   the [`crate::capacity::CapacityLedger`] and gated by each host's
//!   circuit breaker).  A fresh seat re-enters the pool's idle set and the
//!   ledger wakes its waiter queue, so blocked launchers — and the PR 2
//!   dispatcher thread parked inside the pool's blocking `launch` —
//!   acquire it with no extra re-registration step.
//! * **Retry** — [`RetryPolicy`] (per-future via
//!   [`crate::api::future::FutureOpts::retry`], or plan-wide via
//!   [`crate::api::plan::plan_with_retry`]) resubmits a task whose
//!   *infrastructure* failed (worker died, channel broke, launch lost) to
//!   a healthy seat, transparently, behind [`SupervisedHandle`].
//!
//! ## Determinism
//!
//! A resubmitted task re-runs the *same* [`TaskSpec`]: same RNG stream
//! index, and for map chunks the same `base_index` — so element `i` of a
//! retried chunk draws from substream `base_index + i` exactly like the
//! first attempt did.  A seeded `future_lapply` that loses a worker
//! mid-map therefore returns **bit-identical** values to a no-failure run
//! (the conformance suite's `kill-respawn` check).  The cost is that
//! elements evaluated before the crash run twice — hence the
//! **`idempotent` opt-in gate**: retry is armed only when the caller
//! asserts re-running side effects is safe ([`RetryPolicy::idempotent`]).
//! Without the gate the framework keeps the paper's at-most-once
//! submission and surfaces the structured `WorkerDied` error.
//!
//! Evaluation errors (the user's own `stop()`) are **never** retried —
//! they are deterministic and would fail again; the paper's taxonomy
//! split (eval vs infrastructure) is exactly what makes this safe.
//! Cancellation is user intent and is likewise never retried.
//!
//! ## Chaos probes
//!
//! [`crate::api::expr::Expr::ChaosKill`] kills the executing worker
//! mid-task (process exit in worker processes, worker-thread death on the
//! thread pool, degrade-to-eval-error under `plan(sequential)`); the
//! marker-file form fires exactly once, so kill-then-recover paths are
//! testable deterministically.  See the `chaos` CI job.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, Weak};
use std::time::Duration;

use crate::api::error::FutureError;
use crate::backend::dispatch::CompletionWaker;
use crate::backend::{Backend, TaskHandle};
use crate::ipc::{TaskResult, TaskSpec};
use crate::metrics::CounterScope;

// ------------------------------------------------------------ chaos kill ----

/// Sentinel evaluation-error message produced by `Expr::ChaosKill` when the
/// evaluation happens in-process.  The thread pool's worker loop recognizes
/// it and dies *without replying* — indistinguishable from a crashed
/// worker thread; everywhere else it surfaces as a plain eval error.
pub const WORKER_KILL_ERROR: &str = "__rustures_chaos_worker_kill__";

/// True in disposable worker *processes* (`rustures worker ...`): there,
/// `Expr::ChaosKill` exits the process (like a real crash) instead of
/// returning the sentinel error.
static KILL_EXITS_PROCESS: AtomicBool = AtomicBool::new(false);

/// Mark this process as a disposable worker (set by the `worker` CLI
/// entrypoints before serving tasks).
pub fn set_kill_exits_process(on: bool) {
    KILL_EXITS_PROCESS.store(on, Ordering::SeqCst);
}

pub fn kill_exits_process() -> bool {
    KILL_EXITS_PROCESS.load(Ordering::SeqCst)
}

/// Env var carrying the mid-write chaos marker path into worker processes.
/// When set, the worker process kills itself **halfway through writing its
/// first result frame** (marker file = fail-exactly-once, like
/// `Expr::ChaosKill`'s marker) — the coordinator's reader then observes a
/// truncated frame, the kill-during-serialization failure mode.
pub const MIDWRITE_ENV: &str = "RUSTURES_CHAOS_MIDWRITE";

/// Coordinator-side knob: when set, process-backend spawners pass the
/// marker path to their children via [`MIDWRITE_ENV`].  Tests arm it
/// before creating the plan; `None` disarms.
static MIDWRITE_MARKER: Mutex<Option<String>> = Mutex::new(None);

/// Arm (or disarm, with `None`) the kill-mid-serialization chaos probe for
/// worker processes spawned afterwards.
pub fn set_chaos_midwrite_marker(path: Option<&str>) {
    *MIDWRITE_MARKER.lock().unwrap() = path.map(str::to_string);
}

/// The armed mid-write marker path, if any (read by process spawners).
pub fn chaos_midwrite_marker() -> Option<String> {
    MIDWRITE_MARKER.lock().unwrap().clone()
}

// ---------------------------------------------------------- retry policy ----

/// When and how a supervised future is resubmitted after an
/// *infrastructure* failure.  See the module docs for the determinism and
/// idempotence contract.
#[derive(Debug, Clone, PartialEq)]
pub struct RetryPolicy {
    /// Total attempts including the first; `1` means no resubmission.
    pub max_attempts: u32,
    /// Delay before the first resubmission.
    pub backoff: Duration,
    /// Multiplier applied to the delay for each further resubmission.
    pub factor: f64,
    /// The opt-in gate: resubmission re-runs the task's side effects, so
    /// the caller must assert the task is idempotent.  `false` keeps the
    /// paper's at-most-once submission (no retries ever fire).
    pub idempotent: bool,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_attempts: 1,
            backoff: Duration::from_millis(5),
            factor: 2.0,
            idempotent: false,
        }
    }
}

impl RetryPolicy {
    /// The usual way to build a policy: assert idempotence and allow up to
    /// `max_attempts` total attempts.
    pub fn idempotent(max_attempts: u32) -> Self {
        RetryPolicy {
            max_attempts: max_attempts.max(1),
            idempotent: true,
            ..RetryPolicy::default()
        }
    }

    pub fn with_backoff(mut self, backoff: Duration, factor: f64) -> Self {
        self.backoff = backoff;
        self.factor = if factor.is_finite() && factor >= 1.0 { factor } else { 1.0 };
        self
    }

    /// Will this policy ever resubmit?
    pub fn armed(&self) -> bool {
        self.idempotent && self.max_attempts > 1
    }

    /// Failures a resubmission could plausibly outrun: infrastructure loss
    /// only.  Eval errors are deterministic; cancellation is user intent;
    /// invalid plans / missing globals cannot improve on a fresh seat.
    pub fn retryable(e: &FutureError) -> bool {
        matches!(
            e,
            FutureError::WorkerDied { .. } | FutureError::Channel(_) | FutureError::Launch(_)
        )
    }

    /// Backoff before resubmission number `retry_no` (1-based), capped at
    /// 2 s so an exhausted budget is reached in bounded time.
    pub fn backoff_before(&self, retry_no: u32) -> Duration {
        let mult = self.factor.powi(retry_no.saturating_sub(1).min(16) as i32);
        let ns = (self.backoff.as_nanos() as f64 * mult).min(2e9);
        Duration::from_nanos(ns as u64)
    }
}

// ----------------------------------------------------- supervisor config ----

/// Process-wide respawn configuration, read by pools at construction.
#[derive(Debug, Clone, PartialEq)]
pub struct SupervisorConfig {
    /// Run a health monitor that proactively respawns dead workers.
    pub respawn: bool,
    /// Lifetime respawn budget **per host** (tracked by the
    /// [`crate::capacity::CapacityLedger`]) — a crash-looping workload
    /// cannot fork-bomb the machine, and in a heterogeneous cluster one
    /// flaky host exhausts only its own allowance.
    pub max_respawns: u32,
    /// Monitor poll fallback (deaths also wake it via condvar).
    pub poll: Duration,
    /// Per-host circuit breaker: after [`crate::capacity::BreakerConfig::threshold`]
    /// worker deaths within the window, the host stops receiving revives
    /// (and therefore resubmissions) until a half-open probe succeeds.
    pub breaker: crate::capacity::BreakerConfig,
}

impl Default for SupervisorConfig {
    fn default() -> Self {
        SupervisorConfig {
            respawn: true,
            max_respawns: 1024,
            poll: Duration::from_millis(25),
            breaker: crate::capacity::BreakerConfig::default(),
        }
    }
}

static CONFIG: Mutex<Option<SupervisorConfig>> = Mutex::new(None);

/// The config new pools will be built with.
pub fn supervisor_config() -> SupervisorConfig {
    CONFIG.lock().unwrap().clone().unwrap_or_default()
}

/// Override the process-wide default (affects pools built afterwards).
pub fn set_supervisor_config(cfg: SupervisorConfig) {
    *CONFIG.lock().unwrap() = Some(cfg);
}

/// Back to the built-in default.
pub fn reset_supervisor_config() {
    *CONFIG.lock().unwrap() = None;
}

// ------------------------------------------------------ supervised handle ----

/// Launch `task` under `policy`: the returned handle transparently
/// resubmits the retained spec to the backend on retryable infrastructure
/// failures, up to the policy's budget.  The spec is retained by clone —
/// O(1) in payload bytes since tensors/bodies are `Arc`-shared.
pub fn supervise(
    backend: &Arc<dyn Backend>,
    task: TaskSpec,
    policy: RetryPolicy,
    queued: bool,
    scope: CounterScope,
) -> Result<Box<dyn TaskHandle>, FutureError> {
    let spec = task.clone();
    let inner = if queued { backend.launch_queued(task)? } else { backend.launch(task)? };
    Ok(Box::new(SupervisedHandle {
        backend: Arc::downgrade(backend),
        spec,
        policy,
        inner,
        attempts: 1,
        buffered: None,
        pending_retry: None,
        waiter: None,
        cancelled: false,
        scope,
    }))
}

/// A [`TaskHandle`] that owns the retry loop.  Delegates to the live
/// attempt's handle; on a retryable failure it resubmits and re-forwards
/// any `resolve()` subscription to the fresh handle.
pub struct SupervisedHandle {
    /// Weak: a handle must not keep a torn-down backend alive.
    backend: Weak<dyn Backend>,
    spec: TaskSpec,
    policy: RetryPolicy,
    inner: Box<dyn TaskHandle>,
    /// Attempts made so far (1 = the original submission).
    attempts: u32,
    /// Terminal outcome captured by `is_resolved()` for `wait()` to take.
    buffered: Option<Result<TaskResult, FutureError>>,
    /// A retryable failure waiting out its backoff window: the next
    /// resubmission fires no earlier than the instant.  `wait()` sleeps
    /// the window out; `is_resolved()` reports "not resolved yet" until it
    /// passes — so the policy's backoff holds on BOTH paths without the
    /// non-blocking probe ever sleeping.
    pending_retry: Option<(FutureError, std::time::Instant)>,
    /// Last subscription, re-forwarded into each fresh attempt.
    waiter: Option<(Arc<CompletionWaker>, u64)>,
    cancelled: bool,
    /// Session-attributed metrics sink for retry events.
    scope: CounterScope,
}

impl SupervisedHandle {
    /// Total attempts made (diagnostics/tests).
    pub fn attempts(&self) -> u32 {
        self.attempts
    }

    /// Wrap the final failure with retry provenance when resubmissions
    /// actually happened.
    fn finalize(&self, last: FutureError) -> FutureError {
        if self.attempts > 1 {
            FutureError::Retried { attempts: self.attempts, last: Box::new(last) }
        } else {
            last
        }
    }

    /// Classify an attempt failure: schedule a backoff-gated resubmission
    /// (`pending_retry`) or latch the final (possibly wrapped) error.
    fn fail(&mut self, err: FutureError) {
        if self.cancelled
            || !self.policy.armed()
            || !RetryPolicy::retryable(&err)
            || self.attempts >= self.policy.max_attempts
        {
            self.buffered = Some(Err(self.finalize(err)));
        } else {
            // attempts == resubmissions made + 1, so this is the (1-based)
            // number of the resubmission about to happen.
            let due = std::time::Instant::now() + self.policy.backoff_before(self.attempts);
            self.pending_retry = Some((err, due));
        }
    }

    /// Perform the resubmission whose backoff window has passed.  A fresh
    /// attempt lands in `self.inner`; failures re-enter [`Self::fail`].
    fn relaunch(&mut self, err: FutureError) {
        if self.cancelled {
            self.buffered = Some(Err(self.finalize(err)));
            return;
        }
        let backend = match self.backend.upgrade() {
            Some(b) => b,
            None => {
                self.buffered = Some(Err(self.finalize(err)));
                return;
            }
        };
        self.attempts += 1;
        self.scope.retry();
        // Bump the attempt epoch (0-based: first launch = 0) BEFORE the
        // clone so the resubmission's frames carry it — readers fence any
        // late result from the presumed-dead previous attempt.
        self.spec.opts.attempt = self.attempts - 1;
        // Resubmissions always go through queued dispatch: the backlog
        // hands back a handle immediately, so a retry fired from the
        // non-blocking `is_resolved()` probe never parks on seat
        // acquisition (launch failures surface at wait()).
        match backend.launch_queued(self.spec.clone()) {
            Ok(mut handle) => {
                if let Some((w, t)) = &self.waiter {
                    // Re-forward the resolve() subscription; a handle
                    // without push support gets a spurious wake, which
                    // FutureSet downgrades to its poll fallback.
                    if !handle.subscribe(w, *t) {
                        w.notify(*t);
                    }
                }
                self.inner = handle;
            }
            // The relaunch itself failed: charge it as this attempt's
            // failure and decide again against the remaining budget.
            Err(e2) => self.fail(e2),
        }
    }
}

impl TaskHandle for SupervisedHandle {
    fn is_resolved(&mut self) -> bool {
        loop {
            if self.buffered.is_some() {
                return true;
            }
            if let Some((_, due)) = &self.pending_retry {
                // A resubmission is waiting out its backoff window: not
                // resolved, and the probe must not sleep.
                if std::time::Instant::now() < *due {
                    return false;
                }
                let (err, _) = self.pending_retry.take().expect("checked above");
                self.relaunch(err);
                continue;
            }
            if !self.inner.is_resolved() {
                return false;
            }
            // Resolved: peek the outcome so a failure can trigger a retry
            // *now* instead of reporting a resolution wait() would undo.
            match self.inner.wait() {
                Ok(r) => {
                    self.buffered = Some(Ok(r));
                    return true;
                }
                Err(e) => {
                    self.fail(e);
                    continue;
                }
            }
        }
    }

    fn wait(&mut self) -> Result<TaskResult, FutureError> {
        loop {
            if let Some(out) = self.buffered.take() {
                return out;
            }
            if let Some((err, due)) = self.pending_retry.take() {
                let now = std::time::Instant::now();
                if due > now {
                    std::thread::sleep(due - now);
                }
                self.relaunch(err);
                continue;
            }
            match self.inner.wait() {
                Ok(r) => return Ok(r),
                Err(e) => {
                    self.fail(e);
                    continue;
                }
            }
        }
    }

    fn cancel(&mut self) -> bool {
        // Cancellation is user intent: disarm the retry loop so the
        // resulting worker loss is not "recovered" behind the user's back.
        self.cancelled = true;
        self.inner.cancel()
    }

    fn attempts(&self) -> u32 {
        self.attempts
    }

    fn subscribe(&mut self, waker: &Arc<CompletionWaker>, token: u64) -> bool {
        if self.buffered.is_some() {
            waker.notify(token);
            return true;
        }
        self.waiter = Some((Arc::clone(waker), token));
        if !self.inner.subscribe(waker, token) {
            waker.notify(token);
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::env::Env;
    use crate::api::expr::Expr;
    use crate::ipc::{TaskOpts, TaskOutcome};
    use std::sync::atomic::AtomicUsize;

    fn task(expr: Expr) -> TaskSpec {
        TaskSpec {
            id: crate::util::uuid_v4(),
            expr,
            globals: Env::new(),
            opts: TaskOpts::default(),
        }
    }

    /// A backend whose first `fail_times` launches return handles that die.
    struct FlakyBackend {
        fail_times: usize,
        launches: AtomicUsize,
    }

    struct DeadHandle;

    impl TaskHandle for DeadHandle {
        fn is_resolved(&mut self) -> bool {
            true
        }
        fn wait(&mut self) -> Result<TaskResult, FutureError> {
            Err(FutureError::WorkerDied { detail: "injected".into() })
        }
    }

    impl Backend for FlakyBackend {
        fn name(&self) -> &'static str {
            "flaky"
        }
        fn workers(&self) -> usize {
            1
        }
        fn launch(&self, t: TaskSpec) -> Result<Box<dyn TaskHandle>, FutureError> {
            let n = self.launches.fetch_add(1, Ordering::SeqCst);
            if n < self.fail_times {
                Ok(Box::new(DeadHandle))
            } else {
                crate::backend::sequential::SequentialBackend::new().launch(t)
            }
        }
    }

    #[test]
    fn retry_recovers_from_worker_death() {
        let b: Arc<dyn Backend> =
            Arc::new(FlakyBackend { fail_times: 2, launches: AtomicUsize::new(0) });
        let policy = RetryPolicy::idempotent(3).with_backoff(Duration::from_millis(1), 1.0);
        let mut h = supervise(&b, task(Expr::lit(42i64)), policy, false, crate::metrics::default_scope()).unwrap();
        let r = h.wait().unwrap();
        assert_eq!(r.outcome, TaskOutcome::Ok(crate::api::value::Value::I64(42)));
    }

    #[test]
    fn retry_exhaustion_wraps_with_provenance() {
        let b: Arc<dyn Backend> =
            Arc::new(FlakyBackend { fail_times: usize::MAX, launches: AtomicUsize::new(0) });
        let policy = RetryPolicy::idempotent(3).with_backoff(Duration::from_millis(1), 1.0);
        let mut h = supervise(&b, task(Expr::lit(1i64)), policy, false, crate::metrics::default_scope()).unwrap();
        match h.wait() {
            Err(FutureError::Retried { attempts, last }) => {
                assert_eq!(attempts, 3);
                assert!(matches!(*last, FutureError::WorkerDied { .. }));
            }
            other => panic!("expected Retried, got {other:?}"),
        }
    }

    #[test]
    fn unarmed_policy_never_resubmits() {
        let b: Arc<dyn Backend> =
            Arc::new(FlakyBackend { fail_times: usize::MAX, launches: AtomicUsize::new(0) });
        // Attempts allowed but idempotence NOT asserted: the gate holds.
        let policy = RetryPolicy { max_attempts: 5, idempotent: false, ..Default::default() };
        let mut h = supervise(&b, task(Expr::lit(1i64)), policy, false, crate::metrics::default_scope()).unwrap();
        match h.wait() {
            Err(FutureError::WorkerDied { .. }) => {}
            other => panic!("expected bare WorkerDied, got {other:?}"),
        }
    }

    #[test]
    fn is_resolved_retries_without_blocking_collect() {
        let b: Arc<dyn Backend> =
            Arc::new(FlakyBackend { fail_times: 1, launches: AtomicUsize::new(0) });
        let policy = RetryPolicy::idempotent(2).with_backoff(Duration::from_millis(1), 1.0);
        let mut h = supervise(&b, task(Expr::lit(7i64)), policy, false, crate::metrics::default_scope()).unwrap();
        // The probe discovers the dead attempt, defers through the backoff
        // window (reporting unresolved — never sleeping), then relaunches
        // onto the sequential fallback; poll like a FutureSet would.
        let t0 = std::time::Instant::now();
        while !h.is_resolved() {
            assert!(t0.elapsed() < Duration::from_secs(5), "retry never resolved");
            std::thread::sleep(Duration::from_millis(1));
        }
        let r = h.wait().unwrap();
        assert_eq!(r.outcome, TaskOutcome::Ok(crate::api::value::Value::I64(7)));
    }

    #[test]
    fn backoff_window_gates_the_probe_path() {
        let b: Arc<dyn Backend> =
            Arc::new(FlakyBackend { fail_times: 1, launches: AtomicUsize::new(0) });
        let policy = RetryPolicy::idempotent(2).with_backoff(Duration::from_millis(60), 1.0);
        let mut h = supervise(&b, task(Expr::lit(7i64)), policy, false, crate::metrics::default_scope()).unwrap();
        // Within the 60ms window the probe must report "not resolved"
        // without relaunching (and must return quickly — no sleeping).
        let t0 = std::time::Instant::now();
        assert!(!h.is_resolved(), "probe inside the backoff window");
        assert!(t0.elapsed() < Duration::from_millis(40), "probe must not sleep");
        // wait() honors the same window, then recovers.
        let r = h.wait().unwrap();
        assert_eq!(r.outcome, TaskOutcome::Ok(crate::api::value::Value::I64(7)));
    }

    #[test]
    fn eval_errors_are_not_retried() {
        let seq: Arc<dyn Backend> = Arc::new(crate::backend::sequential::SequentialBackend::new());
        let policy = RetryPolicy::idempotent(5);
        let mut h = supervise(&seq, task(Expr::stop(Expr::lit("boom"))), policy, false, crate::metrics::default_scope()).unwrap();
        // Eval errors ride inside a successful TaskResult — no retry path
        // even fires; the outcome carries the error.
        let r = h.wait().unwrap();
        assert!(matches!(r.outcome, TaskOutcome::Err(_)));
    }

    #[test]
    fn backoff_grows_and_caps() {
        let p = RetryPolicy::idempotent(10).with_backoff(Duration::from_millis(10), 2.0);
        assert_eq!(p.backoff_before(1), Duration::from_millis(10));
        assert_eq!(p.backoff_before(2), Duration::from_millis(20));
        assert!(p.backoff_before(30) <= Duration::from_secs(2));
    }

    #[test]
    fn retryable_excludes_eval_and_cancel() {
        assert!(RetryPolicy::retryable(&FutureError::WorkerDied { detail: String::new() }));
        assert!(RetryPolicy::retryable(&FutureError::Channel("x".into())));
        assert!(RetryPolicy::retryable(&FutureError::Launch("x".into())));
        assert!(!RetryPolicy::retryable(&FutureError::Cancelled));
        assert!(!RetryPolicy::retryable(&FutureError::Eval(
            crate::api::error::EvalError::new("boom")
        )));
        assert!(!RetryPolicy::retryable(&FutureError::InvalidPlan("x".into())));
    }
}
