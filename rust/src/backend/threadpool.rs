//! `plan(multicore)` analog — shared-memory worker threads.
//!
//! The paper's `multicore` backend forks the R process: workers inherit the
//! session state for free and latency is the lowest of all backends.  The
//! Rust equivalent with the same observable properties is a thread pool,
//! and the hand-off really is **zero-copy in payload bytes**: the
//! [`TaskSpec`] (expression + captured globals) is *moved* into the job
//! queue, and every tensor inside it shares its `Arc<[f32]>` buffer with
//! the caller's environment — capturing a 1 MiB global and shipping it to a
//! worker thread bumps a reference count, it never copies the megabyte
//! (`api::value` §Perf).  Map-reduce chunks arrive as first-class
//! [`crate::api::expr::Expr::MapChunk`] tasks: one `Arc`-shared body plus
//! packed element values, so a 1000-element chunk costs the same expression
//! handling as a 1-element one.  No serialization happens anywhere on this
//! path; `immediateCondition`s relay live.
//!
//! `launch()` **blocks while all workers are busy** — seat admission goes
//! through the [`crate::capacity::CapacityLedger`]: a [`SlotLease`] rides
//! inside each queued job and frees the seat when the worker finishes, so
//! the ledger's waiter queue is exactly the paper's "future() blocks until
//! one of the workers is available" (and is where per-session quotas and
//! the dead-pool guard live — no pool-private slot counting remains).
//!
//! Failure contract (shared by all backends): a handle whose worker died is
//! *resolved* — `is_resolved()` reports `true` and every `wait()` returns
//! the same [`FutureError::WorkerDied`], so probing and collecting can
//! never disagree about the future's fate.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{Receiver, Sender, TryRecvError};
use std::sync::{mpsc, Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

use crate::api::conditions::relay_immediate;
use crate::api::error::{EvalError, FutureError};
use crate::backend::dispatch::{default_backlog, CompletionSignal, CompletionWaker, Dispatcher};
use crate::backend::supervisor::{supervisor_config, SupervisorConfig, WORKER_KILL_ERROR};
use crate::backend::{Backend, TaskHandle};
use crate::capacity::{PoolRegistration, RevivePolicy, SlotLease};
use crate::ipc::{TaskOutcome, TaskResult, TaskSpec};

/// The thread pool's single (simulated) host: threads share the machine.
const HOST: &str = "local";

struct Job {
    task: TaskSpec,
    reply: Sender<TaskResult>,
    /// Completion latch for `resolve()`-style subscribers: the worker
    /// completes it right after sending the result.
    signal: Arc<CompletionSignal>,
    /// The seat this job occupies; released (worker finished) or forfeited
    /// (worker died) by the worker thread.
    lease: SlotLease,
    /// Per-task progress cell: the evaluator ticks it between `MapChunk`
    /// elements and honors its cancel flag (a thread cannot be killed, so
    /// in-process cancellation is strictly cooperative).
    liveness: Arc<crate::liveness::TaskLiveness>,
}

struct Shared {
    /// Pending jobs; workers pop from the front.
    queue: Mutex<VecDeque<Job>>,
    /// Signals a job is available (workers park here — job *dispatch*;
    /// seat *admission* is the ledger's waiter queue).
    job_cv: Condvar,
    /// A worker thread died — wakes the health monitor.
    death_cv: Condvar,
    /// This pool's seats in the capacity ledger.
    reg: Arc<PoolRegistration>,
    /// Session-attributed supervision metrics sink, captured from the
    /// constructing session (see `metrics::ambient_scope`).
    scope: crate::metrics::CounterScope,
    shutting_down: AtomicBool,
}

pub struct ThreadPoolBackend {
    shared: Arc<Shared>,
    threads: Arc<Mutex<Vec<JoinHandle<()>>>>,
    monitor: Mutex<Option<JoinHandle<()>>>,
    workers: usize,
    /// Lazily-started queued-dispatch front (see [`crate::backend::dispatch`]).
    dispatcher: OnceLock<Dispatcher>,
}

impl ThreadPoolBackend {
    /// A pool supervised per the process-wide [`supervisor_config`].
    pub fn new(workers: usize) -> Self {
        Self::new_configured(workers, &supervisor_config())
    }

    /// [`ThreadPoolBackend::new`] with an explicit supervision config
    /// (tests inject disabled respawn / tiny budgets here).
    pub fn new_configured(workers: usize, cfg: &SupervisorConfig) -> Self {
        let workers = workers.max(1);
        // Seats live in the ledger: respawn ON gives each host (one here) a
        // budgeted revive allowance the monitor draws from; OFF means dead
        // threads stay dead and a fully dead pool errors at acquire.
        let policy = if cfg.respawn {
            RevivePolicy::Budgeted(cfg.max_respawns)
        } else {
            RevivePolicy::Never
        };
        let reg = Arc::new(PoolRegistration::register(
            "multicore",
            &[(HOST.to_string(), workers)],
            policy,
            cfg.breaker,
        ));
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            job_cv: Condvar::new(),
            death_cv: Condvar::new(),
            reg,
            scope: crate::metrics::ambient_scope(),
            shutting_down: AtomicBool::new(false),
        });
        let threads = Arc::new(Mutex::new(Vec::with_capacity(workers)));
        for i in 0..workers {
            let shared = Arc::clone(&shared);
            let handle = std::thread::Builder::new()
                .name(format!("rustures-pool-{i}"))
                .spawn(move || worker_loop(shared))
                .expect("spawn pool worker");
            threads.lock().unwrap().push(handle);
            shared.reg.activate(HOST);
        }
        let monitor = if cfg.respawn {
            let m_shared = Arc::clone(&shared);
            let m_threads = Arc::clone(&threads);
            let poll = cfg.poll;
            match std::thread::Builder::new()
                .name("rustures-pool-monitor".into())
                .spawn(move || monitor_loop(m_shared, m_threads, poll))
            {
                Ok(handle) => Some(handle),
                Err(_) => {
                    // No monitor will ever respawn anything: zero the
                    // budgets so the ledger's dead-pool guard stops
                    // promising a rescue that cannot come (it would park
                    // forever).
                    shared.reg.drain_budgets();
                    None
                }
            }
        } else {
            None
        };
        ThreadPoolBackend {
            shared,
            threads,
            monitor: Mutex::new(monitor),
            workers,
            dispatcher: OnceLock::new(),
        }
    }
}

/// Health monitor: revive chaos-killed worker threads through the ledger
/// ([`PoolRegistration::try_revive`] charges the per-host budget and is
/// breaker-gated), restoring the seat the dead worker took down with it.
/// Parked launchers (including the dispatcher thread) wake via the
/// ledger's waiter queue when the revive commits — no re-registration.
fn monitor_loop(
    shared: Arc<Shared>,
    threads: Arc<Mutex<Vec<JoinHandle<()>>>>,
    poll: std::time::Duration,
) {
    loop {
        if shared.shutting_down.load(Ordering::SeqCst) {
            return;
        }
        if let Some(ticket) = shared.reg.try_revive() {
            let w_shared = Arc::clone(&shared);
            match std::thread::Builder::new()
                .name("rustures-pool-respawn".into())
                .spawn(move || worker_loop(w_shared))
            {
                Ok(handle) => {
                    threads.lock().unwrap().push(handle);
                    shared.scope.respawn();
                    // Commit AFTER the thread exists: a woken launcher's
                    // seat always has a live worker behind it.
                    ticket.commit_idle();
                }
                Err(_) => {
                    // Dropping the ticket aborts the revive (seat returns
                    // to dead; the budget charge stands — a broken host
                    // must not spin the monitor forever).  Back off.
                    drop(ticket);
                    std::thread::sleep(poll);
                }
            }
            continue;
        }
        // Nothing to revive: sleep until a death (death_cv) or poll tick.
        let q = shared.queue.lock().unwrap();
        if shared.shutting_down.load(Ordering::SeqCst) {
            return;
        }
        let (guard, _) = shared.death_cv.wait_timeout(q, poll).unwrap();
        drop(guard);
    }
}

/// The blocking launch, as a free function so the dispatcher thread can
/// drive it through a captured `Arc<Shared>` (no backend self-reference).
fn blocking_launch(
    shared: &Arc<Shared>,
    task: TaskSpec,
) -> Result<Box<dyn TaskHandle>, FutureError> {
    if shared.shutting_down.load(Ordering::SeqCst) {
        return Err(FutureError::Launch("pool is shutting down".into()));
    }
    // The paper's blocking semantic, via the ledger's single waiter queue:
    // blocks while every seat is leased (or the task's session is at its
    // max_workers quota); errors — never parks — on a dead, unrevivable
    // pool or a shutdown.
    let lease = shared.reg.acquire_for(&task)?;

    let label = task.id.clone();
    // Registry entry so the task is cancellable by id; the handle keeps
    // its own Arc, so a cancel-before-start still lands on the cell the
    // worker will read (register() returns the same cell on re-register).
    let liveness = crate::liveness::register(&task.id);
    let (tx, rx) = mpsc::channel();
    let signal = CompletionSignal::new();
    let mut q = shared.queue.lock().unwrap();
    if shared.shutting_down.load(Ordering::SeqCst) {
        return Err(FutureError::Launch("pool is shutting down".into()));
    }
    q.push_back(Job {
        task,
        reply: tx,
        signal: Arc::clone(&signal),
        lease,
        liveness: Arc::clone(&liveness),
    });
    drop(q);
    shared.job_cv.notify_one();

    Ok(Box::new(PoolHandle {
        rx,
        done: None,
        died: false,
        label,
        signal,
        liveness,
        scope: shared.scope.clone(),
    }))
}

fn worker_loop(shared: Arc<Shared>) {
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(job) = q.pop_front() {
                    break job;
                }
                if shared.shutting_down.load(Ordering::SeqCst) {
                    return;
                }
                q = shared.job_cv.wait(q).unwrap();
            }
        };

        // Kernel runtime resolves lazily inside the evaluator on first Call.
        let kernels = None;
        let Job { task, reply, signal, lease, liveness } = job;
        // Panic isolation: a panicking task must not take the worker down.
        // Evaluation runs under the task's shipped session context, so
        // nested futures created on this worker thread inherit the
        // originating session's topology tail and retry default (depth
        // restarts at 0 against the tail — see api::session).
        //
        // Cancelled while still queued: skip evaluation entirely — the
        // sentinel result frees the seat and the handle reports Cancelled.
        let result = if liveness.is_cancelled() {
            TaskResult {
                id: task.id.clone(),
                outcome: TaskOutcome::Err(EvalError::new(crate::liveness::WORKER_CANCEL_ERROR)),
                captured: Default::default(),
                metrics: Default::default(),
                attempt: task.opts.attempt,
            }
        } else {
            catch_unwind(AssertUnwindSafe(|| {
                crate::api::session::scope_task_context(&task.opts.context, || {
                    let mut hook = |c: &crate::api::conditions::Condition| relay_immediate(c);
                    crate::worker::execute_task_live(
                        &task,
                        kernels,
                        Some(&mut hook),
                        Some(Arc::clone(&liveness)),
                        None,
                    )
                })
            }))
            .unwrap_or_else(|_| TaskResult {
                id: task.id.clone(),
                outcome: TaskOutcome::Err(EvalError::new("worker thread panicked")),
                captured: Default::default(),
                metrics: Default::default(),
                attempt: task.opts.attempt,
            })
        };
        crate::liveness::deregister(&task.id);

        // Chaos kill: die like a crashed worker thread — no reply (the
        // handle sees a disconnected channel → WorkerDied), the seat goes
        // down with us (forfeited, not released), the death feeds the
        // host's breaker window, and the monitor wakes to revive.
        if matches!(&result.outcome, TaskOutcome::Err(e) if e.message == WORKER_KILL_ERROR) {
            // Ledger first (death feeds the breaker window, the seat goes
            // down forfeited), THEN make the failure observable: a handle
            // that sees the disconnect must find the breaker already fed.
            shared.reg.record_death(HOST);
            lease.forfeit();
            shared.scope.worker_death();
            drop(reply);
            // Wake resolve()-subscribers; their handles report WorkerDied.
            signal.complete();
            shared.death_cv.notify_all();
            return;
        }

        // The worker frees the moment it RESOLVES (paper semantics):
        // release the seat before the reply becomes observable, so a
        // collector that saw the result also sees the freed capacity.
        drop(lease);
        // Receiver may be gone (abandoned future) — that's fine.
        let _ = reply.send(result);
        // Wake resolve()-style subscribers AFTER the result is available.
        signal.complete();
    }
}

/// Handle over the reply channel.
pub struct PoolHandle {
    rx: Receiver<TaskResult>,
    done: Option<TaskResult>,
    /// Latched on reply-channel disconnect so `is_resolved()` and `wait()`
    /// agree forever after: resolved-to-an-error, reported as `WorkerDied`
    /// by every call (the resolved-but-errored consistency contract).
    died: bool,
    label: String,
    signal: Arc<CompletionSignal>,
    /// The task's progress/cancel cell (shared with the queued job).
    liveness: Arc<crate::liveness::TaskLiveness>,
    /// Metrics sink for cancel events, captured from the pool.
    scope: crate::metrics::CounterScope,
}

impl PoolHandle {
    fn died_err(&self) -> FutureError {
        FutureError::WorkerDied {
            detail: format!("pool worker dropped reply for {}", self.label),
        }
    }

    /// Map the cooperative-cancel sentinel to the structured error: a
    /// cancelled task did not *fail evaluation*, it was stopped — callers
    /// must see [`FutureError::Cancelled`], never a fake eval error.
    fn screen(r: TaskResult) -> Result<TaskResult, FutureError> {
        match &r.outcome {
            TaskOutcome::Err(e) if e.message == crate::liveness::WORKER_CANCEL_ERROR => {
                Err(FutureError::Cancelled)
            }
            _ => Ok(r),
        }
    }
}

impl TaskHandle for PoolHandle {
    fn is_resolved(&mut self) -> bool {
        if self.done.is_some() || self.died {
            return true;
        }
        match self.rx.try_recv() {
            Ok(r) => {
                self.done = Some(r);
                true
            }
            Err(TryRecvError::Empty) => false,
            // Worker died without replying: resolved (to an error).
            Err(TryRecvError::Disconnected) => {
                self.died = true;
                true
            }
        }
    }

    fn wait(&mut self) -> Result<TaskResult, FutureError> {
        if let Some(r) = self.done.take() {
            return Self::screen(r);
        }
        if self.died {
            return Err(self.died_err());
        }
        match self.rx.recv() {
            Ok(r) => Self::screen(r),
            Err(_) => {
                self.died = true;
                Err(self.died_err())
            }
        }
    }

    fn cancel(&mut self) -> bool {
        // Already resolved (result buffered, or worker dead): nothing left
        // to prevent — a cancel-after-resolve is a strict no-op.
        if self.is_resolved() {
            return false;
        }
        // Cooperative: the evaluator sees the flag at its next yield point
        // (between MapChunk elements / inside ChaosHang slices).  The seat
        // is freed by the worker's normal reply path — a cancel is NOT a
        // death and must not feed the breaker.
        self.liveness.cancel();
        self.scope.cancel();
        true
    }

    fn subscribe(&mut self, waker: &Arc<CompletionWaker>, token: u64) -> bool {
        if self.done.is_some() || self.died {
            waker.notify(token);
        } else {
            self.signal.subscribe(waker, token);
        }
        true
    }
}

impl Backend for ThreadPoolBackend {
    fn name(&self) -> &'static str {
        "multicore"
    }

    fn workers(&self) -> usize {
        self.workers
    }

    fn supports_immediate(&self) -> bool {
        true
    }

    fn launch(&self, task: TaskSpec) -> Result<Box<dyn TaskHandle>, FutureError> {
        blocking_launch(&self.shared, task)
    }

    fn launch_queued(&self, task: TaskSpec) -> Result<Box<dyn TaskHandle>, FutureError> {
        let dispatcher = self.dispatcher.get_or_init(|| {
            let shared = Arc::clone(&self.shared);
            Dispatcher::new(
                default_backlog(self.workers),
                Box::new(move |t| blocking_launch(&shared, t)),
            )
        });
        dispatcher.launch(task)
    }

    fn shutdown(&self) {
        // Order matters: raise the flag and wake everyone FIRST so a
        // dispatcher thread parked inside blocking_launch (on the ledger's
        // waiter queue) errors out, then the dispatcher can drain + join,
        // then the monitor (so no new workers appear), then the workers.
        self.shared.shutting_down.store(true, Ordering::SeqCst);
        self.shared.reg.shutdown();
        self.shared.job_cv.notify_all();
        self.shared.death_cv.notify_all();
        if let Some(d) = self.dispatcher.get() {
            d.shutdown();
        }
        if let Some(m) = self.monitor.lock().unwrap().take() {
            let _ = m.join();
        }
        let mut threads = self.threads.lock().unwrap();
        for t in threads.drain(..) {
            let _ = t.join();
        }
        // Jobs the workers never picked up: complete their signals so
        // subscribed FutureSets wake (their handles then report WorkerDied);
        // dropping the jobs releases their leases.
        let mut q = self.shared.queue.lock().unwrap();
        for job in q.drain(..) {
            job.signal.complete();
        }
    }
}

impl Drop for ThreadPoolBackend {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::env::Env;
    use crate::api::expr::Expr;
    use crate::api::value::Value;
    use crate::ipc::TaskOpts;
    use std::time::{Duration, Instant};

    fn task(expr: Expr) -> TaskSpec {
        TaskSpec {
            id: crate::util::uuid_v4(),
            expr,
            globals: Env::new(),
            opts: TaskOpts::default(),
        }
    }

    #[test]
    fn resolves_tasks_on_worker_threads() {
        let pool = ThreadPoolBackend::new(2);
        let mut handles: Vec<_> = (0..6)
            .map(|i| pool.launch(task(Expr::mul(Expr::lit(i as i64), Expr::lit(10i64)))).unwrap())
            .collect();
        for (i, h) in handles.iter_mut().enumerate() {
            let r = h.wait().unwrap();
            assert_eq!(r.outcome, TaskOutcome::Ok(Value::I64(i as i64 * 10)));
        }
        pool.shutdown();
    }

    #[test]
    fn launch_blocks_when_all_workers_busy() {
        let pool = ThreadPoolBackend::new(2);
        // Two long tasks occupy both workers.
        let _h1 = pool.launch(task(Expr::Spin { millis: 120 })).unwrap();
        let _h2 = pool.launch(task(Expr::Spin { millis: 120 })).unwrap();
        // The third launch must block until a worker frees up.
        let t0 = Instant::now();
        let mut h3 = pool.launch(task(Expr::lit(3i64))).unwrap();
        let elapsed = t0.elapsed();
        assert!(
            elapsed >= Duration::from_millis(60),
            "third launch should have blocked, took {elapsed:?}"
        );
        h3.wait().unwrap();
        pool.shutdown();
    }

    #[test]
    fn is_resolved_is_nonblocking() {
        let pool = ThreadPoolBackend::new(1);
        let mut h = pool.launch(task(Expr::Spin { millis: 80 })).unwrap();
        assert!(!h.is_resolved());
        let r = h.wait().unwrap();
        assert!(matches!(r.outcome, TaskOutcome::Ok(_)));
        pool.shutdown();
    }

    #[test]
    fn panic_in_task_becomes_error_result_and_pool_survives() {
        let pool = ThreadPoolBackend::new(1);
        // Force a panic via tensor index far out of range after unwrap-style
        // error... the evaluator doesn't panic, so simulate by a task whose
        // expression is fine but check pool keeps working after errors.
        let mut h = pool.launch(task(Expr::stop(Expr::lit("x")))).unwrap();
        let r = h.wait().unwrap();
        assert!(matches!(r.outcome, TaskOutcome::Err(_)));
        // Pool still functional.
        let mut h2 = pool.launch(task(Expr::lit(1i64))).unwrap();
        assert_eq!(h2.wait().unwrap().outcome, TaskOutcome::Ok(Value::I64(1)));
        pool.shutdown();
    }

    #[test]
    fn disconnected_reply_is_resolved_and_wait_errors_consistently() {
        // Regression: a dropped reply channel (dead worker) must look the
        // same from both probes — is_resolved() says resolved, and EVERY
        // wait() returns WorkerDied (never a success, never a hang, never a
        // different error kind on repeat calls).
        let (tx, rx) = mpsc::channel::<TaskResult>();
        drop(tx);
        let mut h = PoolHandle {
            rx,
            done: None,
            died: false,
            label: "t-dead".into(),
            signal: CompletionSignal::new(),
            liveness: crate::liveness::TaskLiveness::new(),
            scope: crate::metrics::default_scope(),
        };
        assert!(h.is_resolved(), "disconnected handle must report resolved");
        for _ in 0..2 {
            match h.wait() {
                Err(FutureError::WorkerDied { detail }) => {
                    assert!(detail.contains("t-dead"));
                }
                other => panic!("expected WorkerDied, got {other:?}"),
            }
            assert!(h.is_resolved(), "still resolved after the error");
        }
    }

    #[test]
    fn task_hand_off_shares_tensor_buffers() {
        // The multicore zero-copy promise, observed END TO END: the task
        // returns its tensor global, and the tensor that comes back from
        // the worker thread must still share the caller's allocation —
        // proving the queue hand-off, the worker's scope lookup, and the
        // result path never deep-copied the payload.
        use crate::api::value::Tensor;
        let pool = ThreadPoolBackend::new(1);
        let t = Tensor::zeros(&[1024]);
        let mut globals = Env::new();
        globals.insert("t", Value::Tensor(t.clone()));
        let spec = TaskSpec {
            id: crate::util::uuid_v4(),
            expr: Expr::var("t"),
            globals,
            opts: crate::ipc::TaskOpts::default(),
        };
        let mut h = pool.launch(spec).unwrap();
        let r = h.wait().unwrap();
        match r.outcome {
            TaskOutcome::Ok(Value::Tensor(got)) => {
                assert!(
                    got.shares_data(&t),
                    "tensor returned through the pool must share the caller's buffer"
                );
            }
            other => panic!("expected the tensor back, got {other:?}"),
        }
        pool.shutdown();
    }

    #[test]
    fn launch_queued_returns_while_all_workers_busy() {
        let pool = ThreadPoolBackend::new(1);
        let _busy = pool.launch(task(Expr::Spin { millis: 150 })).unwrap();
        let t0 = Instant::now();
        let mut h = pool.launch_queued(task(Expr::lit(9i64))).unwrap();
        assert!(
            t0.elapsed() < Duration::from_millis(100),
            "queued launch blocked for {:?}",
            t0.elapsed()
        );
        assert!(!h.is_resolved(), "still waiting for the busy worker");
        let r = h.wait().unwrap();
        assert_eq!(r.outcome, TaskOutcome::Ok(Value::I64(9)));
        pool.shutdown();
    }

    #[test]
    fn subscribe_notifies_on_resolution_without_polling() {
        use crate::backend::dispatch::CompletionWaker;
        let pool = ThreadPoolBackend::new(1);
        let mut h = pool.launch(task(Expr::Spin { millis: 30 })).unwrap();
        let waker = CompletionWaker::new();
        assert!(h.subscribe(&waker, 42));
        let tok = waker.wait_next(Some(Duration::from_secs(5)));
        assert_eq!(tok, Some(42));
        assert!(h.is_resolved(), "notified handle must be resolved");
        h.wait().unwrap();
        pool.shutdown();
    }

    #[test]
    fn subscribe_after_resolution_notifies_immediately() {
        use crate::backend::dispatch::CompletionWaker;
        let pool = ThreadPoolBackend::new(1);
        let mut h = pool.launch(task(Expr::lit(1i64))).unwrap();
        let r = h.wait().unwrap();
        assert_eq!(r.outcome, TaskOutcome::Ok(Value::I64(1)));
        let waker = CompletionWaker::new();
        assert!(h.subscribe(&waker, 7));
        assert_eq!(waker.try_next(), Some(7));
        pool.shutdown();
    }

    #[test]
    fn chaos_kill_reports_worker_died_and_monitor_respawns() {
        // Default supervision: the kill surfaces as WorkerDied (a real
        // crash, not an eval error) and the monitor revives the capacity.
        let pool = ThreadPoolBackend::new(1);
        let mut h = pool.launch(task(Expr::chaos_kill())).unwrap();
        match h.wait() {
            Err(FutureError::WorkerDied { .. }) => {}
            other => panic!("expected WorkerDied, got {other:?}"),
        }
        let mut h2 = pool.launch(task(Expr::lit(5i64))).unwrap();
        assert_eq!(h2.wait().unwrap().outcome, TaskOutcome::Ok(Value::I64(5)));
        pool.shutdown();
    }

    #[test]
    fn dead_pool_without_budget_errors_instead_of_hanging() {
        let cfg = SupervisorConfig { respawn: false, ..Default::default() };
        let pool = ThreadPoolBackend::new_configured(1, &cfg);
        let mut h = pool.launch(task(Expr::chaos_kill())).unwrap();
        assert!(matches!(h.wait(), Err(FutureError::WorkerDied { .. })));
        // Every worker is dead and nothing can revive one: launch must
        // surface a structured error, never park forever.
        match pool.launch(task(Expr::lit(1i64))) {
            Err(FutureError::Launch(msg)) => {
                assert!(msg.contains("respawn budget"), "{msg}");
            }
            other => panic!("expected Launch error, got {other:?}"),
        }
        pool.shutdown();
    }

    #[test]
    fn respawn_budget_bounds_thread_revivals() {
        let cfg = SupervisorConfig {
            respawn: true,
            max_respawns: 2,
            poll: Duration::from_millis(5),
            ..Default::default()
        };
        let pool = ThreadPoolBackend::new_configured(1, &cfg);
        // Two kills are revived...
        for _ in 0..2 {
            let mut h = pool.launch(task(Expr::chaos_kill())).unwrap();
            assert!(matches!(h.wait(), Err(FutureError::WorkerDied { .. })));
            let mut ok = pool.launch(task(Expr::lit(1i64))).unwrap();
            assert!(matches!(ok.wait().unwrap().outcome, TaskOutcome::Ok(_)));
        }
        // ...the third kill exhausts the budget: the pool is dead and says so.
        let mut h = pool.launch(task(Expr::chaos_kill())).unwrap();
        assert!(matches!(h.wait(), Err(FutureError::WorkerDied { .. })));
        assert!(matches!(
            pool.launch(task(Expr::lit(1i64))),
            Err(FutureError::Launch(_))
        ));
        pool.shutdown();
    }

    #[test]
    fn tripped_breaker_blocks_revival_until_probe() {
        // Per-host circuit breaker on the thread pool's one host: two
        // quick kills trip it; the monitor may not revive until the
        // cooldown passes, then a half-open probe restores service and a
        // clean task closes the breaker.
        let cfg = SupervisorConfig {
            respawn: true,
            max_respawns: 64,
            poll: Duration::from_millis(2),
            breaker: crate::capacity::BreakerConfig {
                threshold: 2,
                window: Duration::from_secs(10),
                cooldown: Duration::from_millis(120),
            },
        };
        let pool = ThreadPoolBackend::new_configured(1, &cfg);
        for _ in 0..2 {
            let mut h = pool.launch(task(Expr::chaos_kill())).unwrap();
            assert!(matches!(h.wait(), Err(FutureError::WorkerDied { .. })));
        }
        assert_eq!(
            pool.shared.reg.breaker_state(HOST),
            crate::capacity::BreakerState::Open,
            "two deaths within the window must trip the breaker"
        );
        let respawns = pool.shared.reg.host_respawns(HOST);
        std::thread::sleep(Duration::from_millis(40));
        assert_eq!(
            pool.shared.reg.host_respawns(HOST),
            respawns,
            "an open breaker must stop the monitor's revives"
        );
        // Cooldown passes: the probe revives the worker; a healthy task
        // closes the breaker and the pool serves again.
        let mut ok = pool.launch(task(Expr::lit(7i64))).unwrap();
        assert_eq!(ok.wait().unwrap().outcome, TaskOutcome::Ok(Value::I64(7)));
        assert_eq!(
            pool.shared.reg.breaker_state(HOST),
            crate::capacity::BreakerState::Closed,
            "a clean completion on the probed host must close the breaker"
        );
        pool.shutdown();
    }

    #[test]
    fn cooperative_cancel_interrupts_map_chunk_and_frees_seat() {
        let pool = ThreadPoolBackend::new(1);
        // 100 × 20 ms elements: without cancellation this runs ~2 s.
        let body = Arc::new(Expr::Spin { millis: 20 });
        let elements: Vec<Value> = (0..100).map(Value::I64).collect();
        let mut h = pool
            .launch(task(Expr::map_chunk("x", body, elements, 0)))
            .unwrap();
        std::thread::sleep(Duration::from_millis(50));
        let t0 = Instant::now();
        assert!(h.cancel(), "unresolved task must report cancellable");
        match h.wait() {
            Err(FutureError::Cancelled) => {}
            other => panic!("expected Cancelled, got {other:?}"),
        }
        assert!(
            t0.elapsed() < Duration::from_millis(500),
            "cancel must interrupt the chunk, waited {:?}",
            t0.elapsed()
        );
        // The seat came back clean (no death, no respawn needed): the next
        // launch runs on the same worker.
        let mut h2 = pool.launch(task(Expr::lit(11i64))).unwrap();
        assert_eq!(h2.wait().unwrap().outcome, TaskOutcome::Ok(Value::I64(11)));
        pool.shutdown();
    }

    #[test]
    fn cancel_after_resolve_is_noop() {
        let pool = ThreadPoolBackend::new(1);
        let mut h = pool.launch(task(Expr::lit(3i64))).unwrap();
        while !h.is_resolved() {
            std::thread::sleep(Duration::from_millis(2));
        }
        assert!(!h.cancel(), "cancel after resolution must be a no-op");
        assert_eq!(h.wait().unwrap().outcome, TaskOutcome::Ok(Value::I64(3)));
        pool.shutdown();
    }

    #[test]
    fn cancel_while_queued_skips_evaluation() {
        let pool = ThreadPoolBackend::new(1);
        let _busy = pool.launch(task(Expr::Spin { millis: 120 })).unwrap();
        // Queued behind the busy worker: never starts evaluating.
        let mut h = pool.launch_queued(task(Expr::Spin { millis: 5000 })).unwrap();
        assert!(h.cancel());
        let t0 = Instant::now();
        match h.wait() {
            Err(FutureError::Cancelled) => {}
            other => panic!("expected Cancelled, got {other:?}"),
        }
        assert!(
            t0.elapsed() < Duration::from_secs(2),
            "queued cancel must not evaluate the 5 s body ({:?})",
            t0.elapsed()
        );
        pool.shutdown();
    }

    #[test]
    fn abandoned_handle_does_not_wedge_pool() {
        let pool = ThreadPoolBackend::new(1);
        {
            let _abandoned = pool.launch(task(Expr::Spin { millis: 10 })).unwrap();
            // dropped without wait()
        }
        let mut h = pool.launch(task(Expr::lit(7i64))).unwrap();
        assert_eq!(h.wait().unwrap().outcome, TaskOutcome::Ok(Value::I64(7)));
        pool.shutdown();
    }
}
