//! Content-addressed result cache — memoized futures (E17).
//!
//! The paper's central promise — "the same code works on all backends" —
//! makes a future's result a *pure function* of its expression, captured
//! globals, seed/stream, and wire protocol version.  Pure functions are
//! memoizable, and at the ROADMAP's millions-of-users scale the dominant
//! waste is duplicate evaluation of identical map-reduce stages.  This
//! module turns PR 8's content [`Digest`] into a result cache:
//!
//! * **Keying.**  [`cache_key`] digests the canonical task identity —
//!   `PROTOCOL_VERSION ‖ canonical expr bytes ‖ resolved globals ‖ seed ‖
//!   RNG stream` — reusing the exact [`crate::ipc::wire`] encoders that
//!   produce the task frame, under a dedicated hash domain
//!   ([`crate::ipc::intern::digest_cache_key`]).  The RNG stream index
//!   participates only when the expression actually draws from the RNG, so
//!   deterministic expressions hit regardless of creation order.
//!   `MapChunk` tasks are keyed **per element** ([`chunk_element_keys`],
//!   substream `base_index + i` — the PR 1 chunking-invariance rule), so a
//!   warm `future_lapply` hits under *any* chunking policy.
//!
//! * **Tiers.**  A bounded per-session in-memory tier (LRU by bytes,
//!   [`CacheConfig::memory_bytes`]) in front of an optional spill-to-disk
//!   [`CacheStore`] (content-named object files, scratch-dir write +
//!   atomic `rename` publish, startup sweep of orphaned scratch entries)
//!   so results survive process restarts.  Entries in both tiers are
//!   encoded [`Message::Result`] frames — the wire decoder doubles as the
//!   corruption check: a torn or bit-rotted entry fails to decode and is
//!   treated as a miss (and deleted), never surfaced.
//!
//! * **Admission-free hits.**  `future_with` consults the cache *before*
//!   capacity admission: a hit constructs a born-resolved future with no
//!   in-flight permit, no slot lease, and no backend instantiation — the
//!   session never appears in `capacity_json()` (asserted by conformance
//!   `cached-bit-identical` and `tests/cache.rs`).
//!
//! * **Determinism contract** (DESIGN.md §Result Cache is normative):
//!   only clean `TaskOutcome::Ok` resolutions publish.  Eval errors,
//!   `TimedOut`, `Cancelled`, and infrastructure failures never do;
//!   chaos-marked and unseeded-RNG expressions are not even keyed
//!   ([`plan_for_task`] returns `None`), and the `cache-nondeterministic`
//!   lint warns (denies under `AnalysisConfig::hardened`) when a cached
//!   future could freeze one nondeterministic sample.
//!
//! Observability: per-session per-tier hit/miss/publish/eviction/byte
//! counters, surfaced as [`cache_json`] (schema `rustures.cache.v1`,
//! re-exported as `metrics::cache_json()`).

use std::collections::HashMap;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex, OnceLock};

use crate::api::conditions::Captured;
use crate::api::env::Env;
use crate::api::expr::Expr;
use crate::api::value::Value;
use crate::ipc::intern::{digest_cache_key, Digest};
use crate::ipc::wire::{decode_message, enc_env, enc_expr, enc_value, encode_message, Encoder};
use crate::ipc::{Message, TaskMetrics, TaskOutcome, TaskResult, PROTOCOL_VERSION};
use crate::util::uuid_v4;

/// Default in-memory tier budget per session (bytes).
pub const DEFAULT_MEMORY_BYTES: usize = 64 << 20;

// ---------------------------------------------------------------- config --

/// Per-session result-cache policy (see [`crate::api::session::Session::set_cache_config`]).
///
/// The cache is additionally opt-in **per future** via
/// `FutureOpts::cached` / `LapplyOpts::cached`: this config gates and
/// shapes what those opted-in futures may use.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CacheConfig {
    /// Master switch: `false` makes every `cached` future evaluate
    /// normally (and publish nothing) — the A/B baseline.
    pub enabled: bool,
    /// In-memory tier budget in bytes (LRU by bytes; an entry larger than
    /// the whole budget is simply not admitted).
    pub memory_bytes: usize,
    /// Root directory of the disk tier ([`CacheStore`]); `None` keeps the
    /// cache memory-only.  The store is content-addressed and safely
    /// shared across sessions and processes.
    pub disk: Option<PathBuf>,
}

impl Default for CacheConfig {
    fn default() -> Self {
        CacheConfig { enabled: true, memory_bytes: DEFAULT_MEMORY_BYTES, disk: None }
    }
}

impl CacheConfig {
    /// The default policy: enabled, memory-only, 64 MiB budget.
    pub fn new() -> Self {
        Self::default()
    }

    /// A disabled cache: `cached` futures evaluate normally.
    pub fn disabled() -> Self {
        CacheConfig { enabled: false, ..Self::default() }
    }

    /// Set the in-memory tier budget (bytes).
    pub fn memory_bytes(mut self, bytes: usize) -> Self {
        self.memory_bytes = bytes;
        self
    }

    /// Attach a disk tier rooted at `path` (created on first use).
    pub fn disk(mut self, path: impl Into<PathBuf>) -> Self {
        self.disk = Some(path.into());
        self
    }
}

// ------------------------------------------------------------------ keys --

/// Canonical key bytes shared by both key forms.  Domain layout:
/// `varint(PROTOCOL_VERSION)` then a form byte (0 = whole future, 1 = map
/// element), then the form's fields — all through the same `ipc::wire`
/// encoders that build task frames, so the key is exactly as canonical as
/// the wire format (and `Env`'s `BTreeMap` keeps globals ordered).
fn whole_key_frame(expr: &Expr, globals: &Env, seed: Option<u64>, stream_index: u64) -> Vec<u8> {
    let mut e = Encoder::new();
    e.varint(u64::from(PROTOCOL_VERSION));
    e.u8(0);
    enc_expr(&mut e, expr);
    enc_env(&mut e, globals);
    match seed {
        Some(s) => {
            e.u8(1);
            e.u64(s);
        }
        None => e.u8(0),
    }
    // The stream index participates only when the expression draws from
    // the RNG: a deterministic expression must hit regardless of the
    // creation ordinal the session happened to assign it.
    if expr.uses_rng() {
        e.varint(stream_index);
    }
    e.into_bytes()
}

/// The content-addressed identity of one (non-chunk) future:
/// `digest(PROTOCOL_VERSION ‖ expr ‖ resolved globals ‖ seed ‖ stream)`,
/// hashed under the cache-key domain.  Backend-independent by
/// construction — no backend, topology, or session field participates.
pub fn cache_key(expr: &Expr, globals: &Env, seed: Option<u64>, stream_index: u64) -> Digest {
    digest_cache_key(&whole_key_frame(expr, globals, seed, stream_index))
}

/// Per-element keys for a `MapChunk` task: element `i` (global index
/// `base_index + i`) is keyed by `digest(version ‖ param ‖ body ‖ element
/// ‖ globals ‖ seed ‖ global index)` — the same substream-selection rule
/// that makes seeded maps chunking-invariant, so a chunk built under ANY
/// chunking policy addresses the same entries.  For non-RNG bodies the
/// index is excluded, so identical elements dedup across the whole map.
pub fn chunk_element_keys(
    param: &str,
    body: &Expr,
    elements: &[Value],
    base_index: u64,
    seed: Option<u64>,
    globals: &Env,
) -> Vec<Digest> {
    let rng = body.uses_rng();
    elements
        .iter()
        .enumerate()
        .map(|(i, v)| {
            let mut e = Encoder::new();
            e.varint(u64::from(PROTOCOL_VERSION));
            e.u8(1);
            e.str(param);
            enc_expr(&mut e, body);
            enc_value(&mut e, v);
            enc_env(&mut e, globals);
            match seed {
                Some(s) => {
                    e.u8(1);
                    e.u64(s);
                }
                None => e.u8(0),
            }
            if rng {
                e.varint(base_index + i as u64);
            }
            digest_cache_key(&e.into_bytes())
        })
        .collect()
}

/// Does the expression carry a chaos marker anywhere?  Chaos-marked
/// expressions are never cached: their whole point is to *not* be a pure
/// function of their inputs.
fn has_chaos(expr: &Expr) -> bool {
    match expr {
        // `Await` rides along: a pipelined dependency's value arrives
        // out-of-band, so the expression is not a pure function of its
        // encoded bytes either — never cache it.
        Expr::ChaosKill { .. } | Expr::ChaosHang { .. } | Expr::Await { .. } => true,
        Expr::Let { value, body, .. } => has_chaos(value) || has_chaos(body),
        Expr::Seq(items) | Expr::List(items) => items.iter().any(has_chaos),
        Expr::Index { list, index } => has_chaos(list) || has_chaos(index),
        Expr::Call { args, .. } | Expr::Prim { args, .. } => items_any(args),
        Expr::If { cond, then, otherwise } => {
            has_chaos(cond) || has_chaos(then) || has_chaos(otherwise)
        }
        Expr::DynLookup(inner) | Expr::Stop(inner) => has_chaos(inner),
        Expr::Emit { message, .. } => has_chaos(message),
        Expr::WithRngStream { body, .. } => has_chaos(body),
        Expr::MapChunk { body, .. } => has_chaos(body),
        Expr::Lit(_)
        | Expr::Var(_)
        | Expr::Rng { .. }
        | Expr::Spin { .. }
        | Expr::Sleep { .. }
        | Expr::Work { .. } => false,
    }
}

fn items_any(items: &[Expr]) -> bool {
    items.iter().any(has_chaos)
}

// ------------------------------------------------------------------ plan --

/// How one future addresses the cache.
#[derive(Debug, Clone)]
pub(crate) enum KeyPlan {
    /// One entry for the whole result.
    Whole(Digest),
    /// One entry per map element (chunking-invariant `future_lapply`).
    Chunk { elements: Vec<Digest> },
}

/// Everything a `cached` future needs to consult and later publish the
/// cache — snapshotted at creation so resolution never reads session
/// state (the session may be closed by then; promoted results of a closed
/// session deliberately do NOT publish — see `latch_if_session_closed`).
#[derive(Debug, Clone)]
pub(crate) struct CachePlan {
    pub(crate) session: u64,
    pub(crate) keys: KeyPlan,
    pub(crate) memory_bytes: usize,
    pub(crate) disk: Option<PathBuf>,
}

/// Build the cache plan for one opted-in task, or `None` when the task is
/// not cacheable: config disabled, a chaos marker anywhere in the
/// expression, or unseeded RNG use (caching a nondeterministic future
/// would silently freeze one sample — the `cache-nondeterministic` lint's
/// territory).
pub(crate) fn plan_for_task(
    session: u64,
    expr: &Expr,
    globals: &Env,
    seed: Option<u64>,
    stream_index: u64,
    config: &CacheConfig,
) -> Option<CachePlan> {
    if !config.enabled || has_chaos(expr) || (seed.is_none() && expr.uses_rng()) {
        return None;
    }
    let keys = match expr {
        Expr::MapChunk { param, body, elements, base_index } => KeyPlan::Chunk {
            elements: chunk_element_keys(param, body, elements, *base_index, seed, globals),
        },
        _ => KeyPlan::Whole(cache_key(expr, globals, seed, stream_index)),
    };
    Some(CachePlan {
        session,
        keys,
        memory_bytes: config.memory_bytes,
        disk: config.disk.clone(),
    })
}

// -------------------------------------------------------------- counters --

/// Hit/miss/publish/eviction/byte counters for one tier.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TierCounters {
    /// Lookups served by this tier.
    pub hits: u64,
    /// Lookups this tier could not serve.
    pub misses: u64,
    /// Entries written to this tier (disk-to-memory promotions count as
    /// memory publishes).
    pub publishes: u64,
    /// Entries evicted (memory LRU; the disk tier never evicts in v1).
    pub evictions: u64,
    /// Memory: live resident bytes.  Disk: cumulative bytes written.
    pub bytes: u64,
}

impl TierCounters {
    fn add(&mut self, other: &TierCounters) {
        self.hits += other.hits;
        self.misses += other.misses;
        self.publishes += other.publishes;
        self.evictions += other.evictions;
        self.bytes += other.bytes;
    }
}

/// Per-session cache counters, one [`TierCounters`] per tier.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheCounters {
    /// The in-memory tier.
    pub memory: TierCounters,
    /// The spill-to-disk tier.
    pub disk: TierCounters,
}

impl CacheCounters {
    fn add(&mut self, other: &CacheCounters) {
        self.memory.add(&other.memory);
        self.disk.add(&other.disk);
    }
}

// ----------------------------------------------------------- memory tier --

struct MemEntry {
    frame: Arc<Vec<u8>>,
    tick: u64,
}

#[derive(Default)]
struct SessionCache {
    counters: CacheCounters,
    entries: HashMap<Digest, MemEntry>,
    bytes: usize,
    clock: u64,
}

static SESSIONS: OnceLock<Mutex<HashMap<u64, SessionCache>>> = OnceLock::new();

/// Counters of sessions already cleared — keeps the process totals in
/// `cache_json()` monotonic, matching the supervision plane's convention.
static RETIRED: Mutex<CacheCounters> = Mutex::new(CacheCounters {
    memory: TierCounters { hits: 0, misses: 0, publishes: 0, evictions: 0, bytes: 0 },
    disk: TierCounters { hits: 0, misses: 0, publishes: 0, evictions: 0, bytes: 0 },
});

fn sessions() -> &'static Mutex<HashMap<u64, SessionCache>> {
    SESSIONS.get_or_init(|| Mutex::new(HashMap::new()))
}

fn with_session<R>(session: u64, f: impl FnOnce(&mut SessionCache) -> R) -> R {
    let mut map = sessions().lock().unwrap();
    f(map.entry(session).or_default())
}

fn memory_get(session: u64, key: &Digest) -> Option<Arc<Vec<u8>>> {
    with_session(session, |e| {
        e.clock += 1;
        let clock = e.clock;
        match e.entries.get_mut(key) {
            Some(m) => {
                m.tick = clock;
                e.counters.memory.hits += 1;
                Some(Arc::clone(&m.frame))
            }
            None => {
                e.counters.memory.misses += 1;
                None
            }
        }
    })
}

fn memory_remove(session: u64, key: &Digest) {
    with_session(session, |e| {
        if let Some(m) = e.entries.remove(key) {
            e.bytes -= m.frame.len();
            e.counters.memory.bytes = e.bytes as u64;
        }
    });
}

fn memory_insert(session: u64, cap: usize, key: Digest, frame: Arc<Vec<u8>>) {
    let len = frame.len();
    if len > cap {
        // An entry larger than the whole tier budget is never admitted
        // (it would evict everything and then be evicted itself).
        return;
    }
    with_session(session, |e| {
        e.clock += 1;
        let tick = e.clock;
        match e.entries.insert(key, MemEntry { frame, tick }) {
            Some(old) => e.bytes = e.bytes - old.frame.len() + len,
            None => {
                e.bytes += len;
                e.counters.memory.publishes += 1;
            }
        }
        // LRU by last-use tick; the linear min-scan per eviction is O(n)
        // but runs only while over budget, off the lookup hot path.
        while e.bytes > cap {
            let Some(oldest) = e.entries.iter().min_by_key(|(_, m)| m.tick).map(|(k, _)| *k)
            else {
                break;
            };
            if let Some(m) = e.entries.remove(&oldest) {
                e.bytes -= m.frame.len();
                e.counters.memory.evictions += 1;
            }
        }
        e.counters.memory.bytes = e.bytes as u64;
    });
}

// ------------------------------------------------------------- disk tier --

/// The spill-to-disk tier: a content-addressed object store.
///
/// Layout under the root: `objects/<32-hex-digest>` holds one encoded
/// `Message::Result` frame per key; `scratch/` stages in-progress writes.
/// **Publishing is atomic**: the frame is fully written to a unique
/// scratch file (`<pid>-<uuid>`), then `rename`d into `objects/` — readers
/// can never observe a torn object, and a crashed publisher leaves only a
/// scratch orphan, which [`CacheStore::open`] sweeps.  Should a torn or
/// bit-rotted object appear anyway (hostile disk), the wire decode fails
/// and the lookup path deletes it and reports a miss.  The disk tier has
/// no eviction in v1 — it is an explicit operator-owned directory.
#[derive(Debug)]
pub struct CacheStore {
    root: PathBuf,
}

impl CacheStore {
    /// Open (creating if needed) the store rooted at `root`, sweeping any
    /// orphaned scratch entries left by a crashed publisher.
    pub fn open(root: &Path) -> io::Result<CacheStore> {
        fs::create_dir_all(root.join("objects"))?;
        fs::create_dir_all(root.join("scratch"))?;
        // Startup sweep: every scratch file is a torn write that never
        // reached its atomic rename — dead by definition, never publishable.
        for entry in fs::read_dir(root.join("scratch"))?.flatten() {
            let _ = fs::remove_file(entry.path());
        }
        Ok(CacheStore { root: root.to_path_buf() })
    }

    /// The object file path for `key` (content-named: the hex digest).
    pub fn object_path(&self, key: &Digest) -> PathBuf {
        self.root.join("objects").join(key.to_string())
    }

    /// Read the raw frame for `key`, if present.  Decoding (and deleting
    /// undecodable objects) is the caller's job.
    pub fn load(&self, key: &Digest) -> Option<Vec<u8>> {
        fs::read(self.object_path(key)).ok()
    }

    /// Atomically publish `frame` under `key`: scratch write, then rename.
    /// Returns `Ok(false)` if the object already existed (content-named
    /// entries are immutable — first write wins, rewrites are pointless).
    pub fn publish(&self, key: &Digest, frame: &[u8]) -> io::Result<bool> {
        let object = self.object_path(key);
        if object.exists() {
            return Ok(false);
        }
        let scratch =
            self.root.join("scratch").join(format!("{}-{}", std::process::id(), uuid_v4()));
        fs::write(&scratch, frame)?;
        fs::rename(&scratch, &object)?;
        Ok(true)
    }

    /// Delete the object for `key` (corrupt-entry quarantine).
    pub fn remove(&self, key: &Digest) {
        let _ = fs::remove_file(self.object_path(key));
    }
}

/// One [`CacheStore`] per root path per process — the orphan sweep runs
/// once, and every session sharing a root shares the handle.
static STORES: OnceLock<Mutex<HashMap<PathBuf, Arc<CacheStore>>>> = OnceLock::new();

fn store_for(root: &Path) -> Option<Arc<CacheStore>> {
    let mut map = STORES.get_or_init(|| Mutex::new(HashMap::new())).lock().unwrap();
    if let Some(store) = map.get(root) {
        return Some(Arc::clone(store));
    }
    match CacheStore::open(root) {
        Ok(store) => {
            let store = Arc::new(store);
            map.insert(root.to_path_buf(), Arc::clone(&store));
            Some(store)
        }
        // An unusable disk tier degrades to memory-only, never to an error
        // on the future path.
        Err(_) => None,
    }
}

// --------------------------------------------------------- lookup/publish --

fn decode_frame(frame: &[u8]) -> Option<TaskResult> {
    match decode_message(frame) {
        Ok(Message::Result(result)) => Some(result),
        _ => None,
    }
}

fn lookup_result(plan: &CachePlan, key: &Digest) -> Option<TaskResult> {
    if let Some(frame) = memory_get(plan.session, key) {
        match decode_frame(&frame) {
            Some(result) => return Some(result),
            // A memory entry can only corrupt through a bug, but the
            // decode gate is already there — drop it and fall through.
            None => memory_remove(plan.session, key),
        }
    }
    let root = plan.disk.as_deref()?;
    let store = store_for(root)?;
    match store.load(key).map(|frame| (decode_frame(&frame), frame)) {
        Some((Some(result), frame)) => {
            with_session(plan.session, |e| e.counters.disk.hits += 1);
            // Promote to the memory tier so the next hit skips the read.
            memory_insert(plan.session, plan.memory_bytes, *key, Arc::new(frame));
            Some(result)
        }
        Some((None, _)) => {
            // Undecodable object: quarantine it so it cannot keep failing.
            store.remove(key);
            with_session(plan.session, |e| e.counters.disk.misses += 1);
            None
        }
        None => {
            with_session(plan.session, |e| e.counters.disk.misses += 1);
            None
        }
    }
}

/// Resolve a cache hit for `plan`, or `None` on any miss.  Chunk plans are
/// all-or-nothing: the first missing element aborts (the chunk then
/// evaluates normally and re-publishes every element).  The returned
/// result carries an empty id — the creation path stamps the new future's.
pub(crate) fn lookup(plan: &CachePlan) -> Option<TaskResult> {
    match &plan.keys {
        KeyPlan::Whole(key) => lookup_result(plan, key),
        KeyPlan::Chunk { elements } => {
            let mut values = Vec::with_capacity(elements.len());
            let mut rng_used = false;
            for key in elements {
                let result = lookup_result(plan, key)?;
                rng_used |= result.captured.rng_used;
                match result.outcome {
                    TaskOutcome::Ok(v) => values.push(v),
                    // Errors are never published; treat a rogue entry as a miss.
                    TaskOutcome::Err(_) => return None,
                }
            }
            Some(TaskResult {
                id: String::new(),
                outcome: TaskOutcome::Ok(Value::List(values)),
                captured: Captured {
                    stdout: String::new(),
                    conditions: Vec::new(),
                    rng_used,
                },
                metrics: TaskMetrics { started_ns: 0, finished_ns: 0 },
                attempt: 0,
            })
        }
    }
}

fn publish_frame(plan: &CachePlan, key: &Digest, frame: Vec<u8>) {
    let len = frame.len();
    let frame = Arc::new(frame);
    memory_insert(plan.session, plan.memory_bytes, *key, Arc::clone(&frame));
    if let Some(root) = &plan.disk {
        if let Some(store) = store_for(root) {
            // Best-effort: a full or read-only disk never fails the future.
            if let Ok(true) = store.publish(key, &frame) {
                with_session(plan.session, |e| {
                    e.counters.disk.publishes += 1;
                    e.counters.disk.bytes += len as u64;
                });
            }
        }
    }
}

/// Publish a cleanly-resolved result under `plan`.  Anything that is not
/// `TaskOutcome::Ok` is silently skipped — **eval errors are never
/// cached** (and `TimedOut`/`Cancelled`/infra failures never reach here:
/// they latch `State::Failed`, which has no result to publish).
pub(crate) fn publish(plan: &CachePlan, result: &TaskResult) {
    if !matches!(result.outcome, TaskOutcome::Ok(_)) {
        return;
    }
    match &plan.keys {
        KeyPlan::Whole(key) => {
            // Canonical stored identity: id/attempt/timings are
            // per-creation facts, not content — zero them so the same
            // computation stores byte-identical frames from any session.
            let canonical = TaskResult {
                id: String::new(),
                metrics: TaskMetrics { started_ns: 0, finished_ns: 0 },
                attempt: 0,
                ..result.clone()
            };
            publish_frame(plan, key, encode_message(&Message::Result(canonical)));
        }
        KeyPlan::Chunk { elements } => {
            // Chunk results split into per-element entries (chunking
            // invariance).  Chunk-level captured output cannot be
            // attributed back to elements, so such chunks don't publish.
            if !result.captured.stdout.is_empty() || !result.captured.conditions.is_empty() {
                return;
            }
            let TaskOutcome::Ok(Value::List(values)) = &result.outcome else {
                return;
            };
            if values.len() != elements.len() {
                return;
            }
            for (key, value) in elements.iter().zip(values) {
                let element = TaskResult {
                    id: String::new(),
                    outcome: TaskOutcome::Ok(value.clone()),
                    captured: Captured {
                        stdout: String::new(),
                        conditions: Vec::new(),
                        rng_used: result.captured.rng_used,
                    },
                    metrics: TaskMetrics { started_ns: 0, finished_ns: 0 },
                    attempt: 0,
                };
                publish_frame(plan, key, encode_message(&Message::Result(element)));
            }
        }
    }
}

// ---------------------------------------------------------- observability --

/// Snapshot one session's cache counters.
pub fn session_counters(session: u64) -> CacheCounters {
    sessions().lock().unwrap().get(&session).map(|e| e.counters).unwrap_or_default()
}

/// Drop a session's in-memory tier and counters (its counters fold into
/// the process totals first, so `cache_json()` stays monotonic).  Disk
/// objects persist by design — they are content-addressed and shared
/// across sessions and process restarts.
pub fn clear_session(session: u64) {
    if let Some(entry) = sessions().lock().unwrap().remove(&session) {
        let mut retired = RETIRED.lock().unwrap();
        retired.add(&entry.counters);
        // Resident bytes are not a monotonic counter: the freed tier no
        // longer holds them.
        retired.memory.bytes -= entry.counters.memory.bytes;
    }
}

fn tier_json(t: &TierCounters) -> String {
    format!(
        "{{\"hits\":{},\"misses\":{},\"publishes\":{},\"evictions\":{},\"bytes\":{}}}",
        t.hits, t.misses, t.publishes, t.evictions, t.bytes
    )
}

/// Result-cache utilization as JSON, schema **`rustures.cache.v1`**:
///
/// ```json
/// {"schema":"rustures.cache.v1",
///  "total":{"memory":{"hits":1,"misses":1,"publishes":1,"evictions":0,"bytes":64},
///           "disk":{...}},
///  "sessions":[{"session":3,"memory":{...},"disk":{...}}]}
/// ```
///
/// `total` includes cleared sessions (monotonic, except `memory.bytes`,
/// which is resident); `sessions` lists live per-session counters.
pub fn cache_json() -> String {
    let map = sessions().lock().unwrap();
    let mut rows: Vec<(u64, CacheCounters)> = map.iter().map(|(s, e)| (*s, e.counters)).collect();
    drop(map);
    rows.sort_by_key(|(s, _)| *s);
    let mut total = *RETIRED.lock().unwrap();
    for (_, c) in &rows {
        total.add(c);
    }
    let sessions_json: Vec<String> = rows
        .iter()
        .map(|(s, c)| {
            format!(
                "{{\"session\":{s},\"memory\":{},\"disk\":{}}}",
                tier_json(&c.memory),
                tier_json(&c.disk)
            )
        })
        .collect();
    format!(
        "{{\"schema\":\"rustures.cache.v1\",\"total\":{{\"memory\":{},\"disk\":{}}},\"sessions\":[{}]}}",
        tier_json(&total.memory),
        tier_json(&total.disk),
        sessions_json.join(",")
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::error::EvalError;
    use std::sync::Arc as StdArc;

    fn tmp_root(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!("rustures-cache-{tag}-{}", uuid_v4()))
    }

    fn ok_result(v: Value) -> TaskResult {
        TaskResult {
            id: "t".into(),
            outcome: TaskOutcome::Ok(v),
            captured: Captured {
                stdout: String::new(),
                conditions: Vec::new(),
                rng_used: false,
            },
            metrics: TaskMetrics { started_ns: 1, finished_ns: 2 },
            attempt: 0,
        }
    }

    fn whole_plan(session: u64, key: Digest, disk: Option<PathBuf>) -> CachePlan {
        CachePlan { session, keys: KeyPlan::Whole(key), memory_bytes: 1 << 20, disk }
    }

    #[test]
    fn cache_key_is_deterministic_and_input_sensitive() {
        let env = {
            let mut e = Env::new();
            e.insert("x", 7i64);
            e
        };
        let expr = Expr::add(Expr::var("x"), Expr::lit(1i64));
        let k1 = cache_key(&expr, &env, Some(42), 0);
        let k2 = cache_key(&expr, &env, Some(42), 0);
        assert_eq!(k1, k2, "same identity, same key");
        assert_ne!(k1, cache_key(&expr, &env, Some(43), 0), "seed participates");
        let mut env2 = env.clone();
        env2.insert("x", 8i64);
        assert_ne!(k1, cache_key(&expr, &env2, Some(42), 0), "globals participate");
        assert_ne!(
            k1,
            cache_key(&Expr::add(Expr::var("x"), Expr::lit(2i64)), &env, Some(42), 0),
            "expression participates"
        );
    }

    #[test]
    fn stream_index_participates_only_under_rng() {
        let env = Env::new();
        let pure = Expr::lit(1i64);
        assert_eq!(
            cache_key(&pure, &env, Some(1), 0),
            cache_key(&pure, &env, Some(1), 99),
            "deterministic exprs must hit regardless of creation ordinal"
        );
        let rng = Expr::runif(2);
        assert_ne!(
            cache_key(&rng, &env, Some(1), 0),
            cache_key(&rng, &env, Some(1), 1),
            "RNG exprs draw from their stream: the index is identity"
        );
    }

    #[test]
    fn chunk_element_keys_are_chunking_invariant() {
        let body = Expr::add(Expr::var("x"), Expr::runif(1));
        let env = Env::new();
        let elements: Vec<Value> = (0..8i64).map(Value::I64).collect();
        let whole = chunk_element_keys("x", &body, &elements, 0, Some(9), &env);
        // Split 3 | 5: per-element keys must line up with the whole map's.
        let mut split = chunk_element_keys("x", &body, &elements[..3], 0, Some(9), &env);
        split.extend(chunk_element_keys("x", &body, &elements[3..], 3, Some(9), &env));
        assert_eq!(whole, split, "keys depend on global index, not chunk shape");
    }

    #[test]
    fn plan_refuses_uncacheable_tasks() {
        let env = Env::new();
        let config = CacheConfig::new();
        assert!(
            plan_for_task(1, &Expr::chaos_kill(), &env, Some(1), 0, &config).is_none(),
            "chaos-marked expressions are never keyed"
        );
        assert!(
            plan_for_task(1, &Expr::runif(1), &env, None, 0, &config).is_none(),
            "unseeded RNG is never keyed"
        );
        assert!(
            plan_for_task(1, &Expr::lit(1i64), &env, None, 0, &CacheConfig::disabled())
                .is_none(),
            "disabled config keys nothing"
        );
        assert!(plan_for_task(1, &Expr::lit(1i64), &env, None, 0, &config).is_some());
    }

    #[test]
    fn memory_roundtrip_and_counters() {
        let session = 0xCAC4E_001;
        let plan = whole_plan(session, cache_key(&Expr::lit(5i64), &Env::new(), None, 0), None);
        assert!(lookup(&plan).is_none(), "cold lookup misses");
        publish(&plan, &ok_result(Value::I64(5)));
        let got = lookup(&plan).expect("warm lookup hits");
        assert_eq!(got.outcome, TaskOutcome::Ok(Value::I64(5)));
        assert_eq!(got.id, "", "stored identity is canonical (id zeroed)");
        let c = session_counters(session);
        assert_eq!(c.memory.hits, 1);
        assert_eq!(c.memory.misses, 1);
        assert_eq!(c.memory.publishes, 1);
        assert!(c.memory.bytes > 0);
        clear_session(session);
        assert_eq!(session_counters(session), CacheCounters::default());
    }

    #[test]
    fn eval_errors_are_never_published() {
        let session = 0xCAC4E_002;
        let plan = whole_plan(session, Digest([3; 16]), None);
        let mut r = ok_result(Value::I64(1));
        r.outcome = TaskOutcome::Err(EvalError { message: "boom".into(), call: None });
        publish(&plan, &r);
        assert_eq!(session_counters(session).memory.publishes, 0);
        assert!(lookup(&plan).is_none());
        clear_session(session);
    }

    #[test]
    fn chunk_with_captured_output_is_not_split_published() {
        let session = 0xCAC4E_003;
        let keys = vec![Digest([7; 16]), Digest([8; 16])];
        let plan = CachePlan {
            session,
            keys: KeyPlan::Chunk { elements: keys },
            memory_bytes: 1 << 20,
            disk: None,
        };
        let mut r = ok_result(Value::List(vec![Value::I64(1), Value::I64(2)]));
        r.captured.stdout = "printed".into();
        publish(&plan, &r);
        assert_eq!(
            session_counters(session).memory.publishes,
            0,
            "chunk-level output cannot be attributed to elements"
        );
        clear_session(session);
    }

    #[test]
    fn lru_eviction_is_by_bytes_and_counted() {
        let session = 0xCAC4E_004;
        let frame = encode_message(&Message::Result(ok_result(Value::I64(1))));
        let cap = frame.len() * 2 + 1; // room for two entries, not three
        for i in 0..3u8 {
            memory_insert(session, cap, Digest([i; 16]), StdArc::new(frame.clone()));
        }
        let c = session_counters(session);
        assert_eq!(c.memory.publishes, 3);
        assert_eq!(c.memory.evictions, 1, "third insert evicts the LRU entry");
        assert!(c.memory.bytes as usize <= cap);
        assert!(memory_get(session, &Digest([0; 16])).is_none(), "oldest entry evicted");
        assert!(memory_get(session, &Digest([2; 16])).is_some());
        clear_session(session);
    }

    #[test]
    fn disk_store_publishes_atomically_and_survives_sessions() {
        let root = tmp_root("disk");
        let session = 0xCAC4E_005;
        let key = cache_key(&Expr::lit(11i64), &Env::new(), None, 0);
        let plan = whole_plan(session, key, Some(root.clone()));
        publish(&plan, &ok_result(Value::I64(11)));
        let c = session_counters(session);
        assert_eq!(c.disk.publishes, 1);
        assert!(c.disk.bytes > 0);
        clear_session(session);
        // A different session (fresh memory tier) hits from disk.
        let other = whole_plan(0xCAC4E_006, key, Some(root.clone()));
        let got = lookup(&other).expect("disk tier survives the session");
        assert_eq!(got.outcome, TaskOutcome::Ok(Value::I64(11)));
        let c2 = session_counters(0xCAC4E_006);
        assert_eq!(c2.disk.hits, 1);
        assert_eq!(c2.memory.publishes, 1, "disk hits promote into memory");
        clear_session(0xCAC4E_006);
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn torn_scratch_files_are_swept_never_published() {
        let root = tmp_root("torn");
        fs::create_dir_all(root.join("scratch")).unwrap();
        fs::create_dir_all(root.join("objects")).unwrap();
        // A torn write: a publisher crashed mid-frame, before its rename.
        let orphan = root.join("scratch").join("4242-deadbeef");
        fs::write(&orphan, b"torn-half-frame").unwrap();
        let store = CacheStore::open(&root).unwrap();
        assert!(!orphan.exists(), "open() must sweep orphaned scratch entries");
        assert_eq!(
            fs::read_dir(root.join("objects")).unwrap().count(),
            0,
            "a torn scratch file must never reach objects/"
        );
        // And a clean publish through the same store works.
        let key = Digest([0xAB; 16]);
        assert!(store.publish(&key, b"frame").unwrap());
        assert!(!store.publish(&key, b"frame").unwrap(), "content-named: first write wins");
        assert_eq!(store.load(&key).as_deref(), Some(b"frame".as_slice()));
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn corrupt_disk_objects_are_quarantined_as_misses() {
        let root = tmp_root("corrupt");
        let key = Digest([0xCC; 16]);
        let store = CacheStore::open(&root).unwrap();
        // Bit-rotted object: present on disk but not a decodable frame.
        store.publish(&key, b"not a wire frame").unwrap();
        let plan = whole_plan(0xCAC4E_007, key, Some(root.clone()));
        assert!(lookup(&plan).is_none(), "undecodable object must read as a miss");
        assert!(!store.object_path(&key).exists(), "corrupt object must be quarantined");
        assert_eq!(session_counters(0xCAC4E_007).disk.misses, 1);
        clear_session(0xCAC4E_007);
        let _ = fs::remove_dir_all(&root);
    }

    #[test]
    fn cache_json_has_schema_totals_and_sessions() {
        let session = 0xCAC4E_008;
        let plan = whole_plan(session, Digest([0x44; 16]), None);
        publish(&plan, &ok_result(Value::I64(4)));
        let _ = lookup(&plan);
        let json = cache_json();
        assert!(json.starts_with("{\"schema\":\"rustures.cache.v1\""), "{json}");
        assert!(json.contains(&format!("\"session\":{session}")), "{json}");
        assert!(json.contains("\"memory\":{\"hits\":"), "{json}");
        assert!(json.contains("\"disk\":{\"hits\":"), "{json}");
        clear_session(session);
        assert!(!cache_json().contains(&format!("\"session\":{session}")));
    }
}
