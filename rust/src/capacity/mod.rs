//! The capacity subsystem — ONE ledger for every execution slot.
//!
//! Before this layer, each backend re-implemented its own seat accounting
//! (ProcPool `slot_cv`/`alive`, ThreadPool `free_slots`, the batch
//! scheduler's `free_slots` node list), so cross-cutting admission policies
//! — per-session quotas, per-host respawn budgets, circuit-breaking — would
//! have needed five divergent copies.  The [`CapacityLedger`] centralizes
//! the shared-state bookkeeping (the `rush` design: one authoritative view
//! of worker capacity) behind an RAII [`SlotLease`]:
//!
//! * **Pools register seats** ([`PoolRegistration`]), keyed by backend ×
//!   host.  Seats move through four states — `dead` (not spawned/crashed)
//!   → `reviving` (spawn in flight) → `free` → `in_use` — and every
//!   transition happens under the ledger's single lock.
//! * **Launch paths acquire leases** through the ledger's single waiter
//!   queue (one mutex + condvar): `acquire` blocks while no seat is free —
//!   the paper's "future() blocks until one of the workers is available" —
//!   and errors (never parks forever) when the pool is dead and nothing can
//!   revive it.  Dropping the lease frees the seat and wakes one waiter.
//! * **Session quotas** ([`SessionLimits`]): `max_workers` caps a session's
//!   concurrent leases across *all* pools (blocking admission, never a
//!   silent drop); `max_in_flight` bounds created-but-unresolved futures
//!   via [`InFlightPermit`]s taken at future creation.
//! * **Per-host respawn budgets** ([`RevivePolicy`]): each host gets its
//!   own lifetime revive allowance, so one crash-looping host in a
//!   heterogeneous cluster exhausts only its own budget.
//! * **Circuit breaker** per host: `Closed` → `Open` after
//!   [`BreakerConfig::threshold`] worker deaths within
//!   [`BreakerConfig::window`] → (after [`BreakerConfig::cooldown`])
//!   `HalfOpen`, which admits exactly ONE probe revive; a clean lease
//!   release on the host closes the breaker, another death re-opens it.
//!   The breaker gates *revives* (resubmission capacity): an open host's
//!   dead seats stay down, so it receives no further work while healthy
//!   hosts absorb the load.
//!
//! Utilization is rendered by [`capacity_json`] (schema
//! `rustures.capacity.v1`), surfaced as `metrics::capacity_json()`.
//!
//! ## Lock discipline
//!
//! The ledger lock is a leaf: ledger methods never call back into pools,
//! so pools may call the ledger while holding their own locks (pool lock →
//! ledger lock), never the reverse.  Waiters park on the ledger condvar
//! only — no pool lock is held while waiting for a seat.
//!
//! ## Quotas and nesting
//!
//! `max_workers` counts *parallel* leases (sequential evaluation acquires
//! its pool seat without charging the session — the implicit nested
//! `plan(sequential)` fallback must never deadlock against its own outer
//! future).  A nested *parallel* topology can hold leases at two depths at
//! once; size `max_workers` accordingly (see DESIGN.md §Capacity).
//! `max_in_flight` gates future **creation** against futures not yet
//! resolved-or-dropped: a caller that creates more than `max_in_flight`
//! futures before collecting any will block — that is the backpressure
//! contract, the same shape as the dispatcher's bounded backlog.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex, OnceLock};
use std::time::{Duration, Instant};

use crate::api::error::FutureError;
use crate::ipc::TaskSpec;
use crate::util::json::{self, Json};

// ------------------------------------------------------------- configs ----

/// Per-session admission limits (the multi-tenant quota surface).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct SessionLimits {
    /// Maximum concurrent execution-slot leases attributed to the session,
    /// across every pool.  `None` = unlimited.
    pub max_workers: Option<usize>,
    /// Maximum futures created by the session and not yet resolved (or
    /// dropped).  `None` = unlimited.
    ///
    /// **Semantics warning**: the permit frees when the *creating side*
    /// observes the future's terminal state (or drops it) — backend
    /// resolution alone does not release it.  Code that creates more than
    /// `max_in_flight` futures before collecting ANY of them (including
    /// `future_lapply` with more chunks than the cap, whose chunk futures
    /// are all created up front) will therefore block at creation and
    /// never unblock itself.  Use `max_workers` to bound a map's real
    /// concurrency; use `max_in_flight` for create/collect-interleaved
    /// workloads where it acts as a backpressure window, like the
    /// dispatcher's bounded backlog.
    pub max_in_flight: Option<usize>,
}

impl SessionLimits {
    pub fn new() -> Self {
        SessionLimits::default()
    }

    pub fn max_workers(mut self, n: usize) -> Self {
        self.max_workers = Some(n.max(1));
        self
    }

    pub fn max_in_flight(mut self, n: usize) -> Self {
        self.max_in_flight = Some(n.max(1));
        self
    }

    fn is_unlimited(&self) -> bool {
        self.max_workers.is_none() && self.max_in_flight.is_none()
    }
}

/// Circuit-breaker tuning for one pool's hosts.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BreakerConfig {
    /// Worker deaths within [`BreakerConfig::window`] that trip the host's
    /// breaker open.  `0` disables the breaker.
    pub threshold: u32,
    /// Sliding window the deaths are counted over.
    pub window: Duration,
    /// How long an open breaker blocks revives before allowing the
    /// half-open probe.
    pub cooldown: Duration,
}

impl Default for BreakerConfig {
    fn default() -> Self {
        BreakerConfig {
            threshold: 16,
            window: Duration::from_secs(10),
            cooldown: Duration::from_millis(250),
        }
    }
}

/// Observable breaker state of one host.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BreakerState {
    /// Healthy: revives flow freely.
    Closed,
    /// Tripped: no revives until the cooldown passes.
    Open,
    /// Cooled down: exactly one probe revive is in flight; a clean lease
    /// release closes the breaker, a death re-opens it.
    HalfOpen,
}

impl BreakerState {
    fn as_str(&self) -> &'static str {
        match self {
            BreakerState::Closed => "closed",
            BreakerState::Open => "open",
            BreakerState::HalfOpen => "half-open",
        }
    }
}

/// How (and whether) a pool's dead seats come back.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RevivePolicy {
    /// Seats are never revived (thread pools without a monitor; batch node
    /// slots, which never die).  A fully dead pool errors at acquire.
    Never,
    /// Each host gets this lifetime revive budget (the supervision
    /// default) — shared by monitor and on-demand revives.
    Budgeted(u32),
    /// Unbudgeted on-demand revival (the historical supervision-disabled
    /// ProcPool behaviour).
    Unbudgeted,
}

// ------------------------------------------------------------- internals ----

#[derive(Debug, Clone, Copy, PartialEq)]
enum Phase {
    Closed,
    Open { until: Instant },
    HalfOpen,
}

struct HostState {
    name: String,
    free: usize,
    in_use: usize,
    reviving: usize,
    dead: usize,
    /// Remaining revive budget (`None` for `Never`/`Unbudgeted` policies).
    budget: Option<u32>,
    /// Revives committed on this host (diagnostics; the conformance
    /// breaker check asserts this stops growing once the breaker opens).
    respawns: u64,
    deaths: VecDeque<Instant>,
    phase: Phase,
}

impl HostState {
    fn total(&self) -> usize {
        self.free + self.in_use + self.reviving + self.dead
    }

    fn breaker_state(&self, now: Instant) -> BreakerState {
        match self.phase {
            Phase::Closed => BreakerState::Closed,
            // An expired cooldown *reads* as HalfOpen even before a probe
            // transitions the phase — observers see the recoverable state.
            Phase::Open { until } if now >= until => BreakerState::HalfOpen,
            Phase::Open { .. } => BreakerState::Open,
            Phase::HalfOpen => BreakerState::HalfOpen,
        }
    }
}

struct PoolState {
    backend: &'static str,
    /// Session that built the backend (metrics attribution only).
    owner_session: u64,
    policy: RevivePolicy,
    breaker: BreakerConfig,
    shutting_down: bool,
    hosts: Vec<HostState>,
}

impl PoolState {
    fn host_mut(&mut self, host: &str) -> Option<&mut HostState> {
        self.hosts.iter_mut().find(|h| h.name == host)
    }

    fn alive(&self) -> usize {
        self.hosts.iter().map(|h| h.free + h.in_use + h.reviving).sum()
    }

    /// Can ANY mechanism ever bring a dead seat back?  (Breaker state is
    /// deliberately ignored — an open breaker is temporary; only budget
    /// exhaustion / a `Never` policy are terminal.)
    fn revivable_eventually(&self) -> bool {
        match self.policy {
            RevivePolicy::Never => false,
            RevivePolicy::Unbudgeted => self.hosts.iter().any(|h| h.dead > 0),
            RevivePolicy::Budgeted(_) => self
                .hosts
                .iter()
                .any(|h| h.dead > 0 && h.budget.unwrap_or(0) > 0),
        }
    }
}

#[derive(Default)]
struct SessionUsage {
    in_use: usize,
    peak_in_use: usize,
    in_flight: usize,
    peak_in_flight: usize,
    limits: SessionLimits,
}

impl SessionUsage {
    fn is_idle(&self) -> bool {
        self.in_use == 0 && self.in_flight == 0 && self.limits.is_unlimited()
    }
}

#[derive(Default)]
struct LedgerState {
    next_pool: u64,
    pools: HashMap<u64, PoolState>,
    sessions: HashMap<u64, SessionUsage>,
}

/// The process-wide capacity ledger.  All seat state lives behind ONE
/// mutex; all waiting happens on ONE condvar (the single waiter queue).
pub struct CapacityLedger {
    state: Mutex<LedgerState>,
    cv: Condvar,
}

static LEDGER: OnceLock<CapacityLedger> = OnceLock::new();

/// The process-wide ledger instance.
pub fn ledger() -> &'static CapacityLedger {
    LEDGER.get_or_init(|| CapacityLedger {
        state: Mutex::new(LedgerState::default()),
        cv: Condvar::new(),
    })
}

// ------------------------------------------------------------ leases ----

/// RAII handle to one acquired execution slot.  Dropping it releases the
/// seat (clean completion: frees capacity, closes a half-open breaker);
/// [`SlotLease::forfeit`] consumes it as a *death* instead (the seat goes
/// down with its worker and only a revive brings it back).
pub struct SlotLease {
    pool: u64,
    host: String,
    /// Session the lease is charged to (None = uncounted, e.g. the
    /// sequential fallback seat).
    session: Option<u64>,
    done: bool,
}

impl SlotLease {
    /// Which host this lease's seat lives on.
    pub fn host(&self) -> &str {
        &self.host
    }

    /// Consume the lease as a worker death: the seat becomes `dead`
    /// (revive-only) instead of returning to the free set.  The session
    /// charge is returned either way.  Does NOT record a breaker death —
    /// call [`PoolRegistration::record_death`] for that (cancellation
    /// forfeits without feeding the breaker).
    pub fn forfeit(mut self) {
        self.done = true;
        let led = ledger();
        let mut st = led.state.lock().unwrap();
        release_session(&mut st, self.session);
        if let Some(pool) = st.pools.get_mut(&self.pool) {
            if let Some(h) = pool.host_mut(&self.host) {
                h.in_use = h.in_use.saturating_sub(1);
                h.dead += 1;
            }
        }
        drop(st);
        led.cv.notify_all();
    }
}

impl Drop for SlotLease {
    fn drop(&mut self) {
        if self.done {
            return;
        }
        let led = ledger();
        let mut st = led.state.lock().unwrap();
        release_session(&mut st, self.session);
        if let Some(pool) = st.pools.get_mut(&self.pool) {
            if let Some(h) = pool.host_mut(&self.host) {
                h.in_use = h.in_use.saturating_sub(1);
                h.free += 1;
                // A clean completion on a probing host proves it healthy.
                if h.phase == Phase::HalfOpen {
                    h.phase = Phase::Closed;
                    h.deaths.clear();
                }
            }
        }
        drop(st);
        led.cv.notify_all();
    }
}

fn release_session(st: &mut LedgerState, session: Option<u64>) {
    if let Some(sid) = session {
        if let Some(u) = st.sessions.get_mut(&sid) {
            u.in_use = u.in_use.saturating_sub(1);
            if u.is_idle() {
                st.sessions.remove(&sid);
            }
        }
    }
}

/// Permission to revive one dead seat on `host` (budget already charged,
/// breaker already consulted).  The holder spawns the worker, then either
/// [`ReviveTicket::commit_idle`]s (monitor path: seat returns to the free
/// set) or [`ReviveTicket::commit_lease`]s (launch path: the fresh seat is
/// immediately leased for the waiting task).  Dropping the ticket aborts:
/// the seat returns to `dead` (the budget charge stands — a failing
/// spawner must not spin) and a half-open probe re-opens the breaker.
pub struct ReviveTicket {
    pool: u64,
    host: String,
    session: Option<u64>,
    probe: bool,
    done: bool,
}

impl ReviveTicket {
    pub fn host(&self) -> &str {
        &self.host
    }

    /// Spawn succeeded; the seat joins the free set (monitor path).  Call
    /// only AFTER the seat is visible to the pool's own structures (e.g.
    /// pushed to the idle list), so a woken waiter always finds it.
    pub fn commit_idle(mut self) {
        self.done = true;
        let led = ledger();
        let mut st = led.state.lock().unwrap();
        // Monitor revives carry no session charge; return it if present.
        release_session(&mut st, self.session.take());
        if let Some(pool) = st.pools.get_mut(&self.pool) {
            if let Some(h) = pool.host_mut(&self.host) {
                h.reviving = h.reviving.saturating_sub(1);
                h.free += 1;
                h.respawns += 1;
            }
        }
        drop(st);
        led.cv.notify_all();
    }

    /// Spawn succeeded; convert directly into a lease for the task that
    /// triggered the on-demand revive (the session charge carries over).
    pub fn commit_lease(mut self) -> SlotLease {
        self.done = true;
        let led = ledger();
        let mut st = led.state.lock().unwrap();
        if let Some(pool) = st.pools.get_mut(&self.pool) {
            if let Some(h) = pool.host_mut(&self.host) {
                h.reviving = h.reviving.saturating_sub(1);
                h.in_use += 1;
                h.respawns += 1;
            }
        }
        drop(st);
        SlotLease {
            pool: self.pool,
            host: self.host.clone(),
            session: self.session.take(),
            done: false,
        }
    }
}

impl Drop for ReviveTicket {
    fn drop(&mut self) {
        if self.done {
            return;
        }
        let led = ledger();
        let mut st = led.state.lock().unwrap();
        release_session(&mut st, self.session.take());
        if let Some(pool) = st.pools.get_mut(&self.pool) {
            if let Some(h) = pool.host_mut(&self.host) {
                h.reviving = h.reviving.saturating_sub(1);
                h.dead += 1;
                if self.probe {
                    // The probe could not even come up: back to Open.
                    h.phase = Phase::Open { until: Instant::now() + pool.breaker.cooldown };
                }
            }
        }
        drop(st);
        led.cv.notify_all();
    }
}

/// Outcome of [`PoolRegistration::acquire_or_revive`].
pub enum Acquired {
    /// A free seat was leased.
    Seat(SlotLease),
    /// No seat was free, but a dead one may be revived: spawn a worker on
    /// the ticket's host, then commit.
    Revive(ReviveTicket),
}

// ------------------------------------------------------- registration ----

/// A pool's handle into the ledger.  Dropping it deregisters the pool
/// (outstanding leases then release as no-ops; blocked acquirers error).
pub struct PoolRegistration {
    pool: u64,
}

impl PoolRegistration {
    /// Register `hosts` (name × seat count) for a backend.  Seats start
    /// `dead`; the pool calls [`PoolRegistration::activate`] as each
    /// initial worker comes up, so a seat is never acquirable before its
    /// worker exists.
    pub fn register(
        backend: &'static str,
        hosts: &[(String, usize)],
        policy: RevivePolicy,
        breaker: BreakerConfig,
    ) -> PoolRegistration {
        let budget = match policy {
            RevivePolicy::Budgeted(n) => Some(n),
            _ => None,
        };
        let host_states = hosts
            .iter()
            .map(|(name, seats)| HostState {
                name: name.clone(),
                free: 0,
                in_use: 0,
                reviving: 0,
                dead: *seats,
                budget,
                respawns: 0,
                deaths: VecDeque::new(),
                phase: Phase::Closed,
            })
            .collect();
        // Resolved before taking the ledger lock: the ledger is a leaf
        // lock and must never nest another lock inside it.
        let owner_session = crate::metrics::ambient_scope().session();
        let led = ledger();
        let mut st = led.state.lock().unwrap();
        st.next_pool += 1;
        let id = st.next_pool;
        st.pools.insert(
            id,
            PoolState {
                backend,
                owner_session,
                policy,
                breaker,
                shutting_down: false,
                hosts: host_states,
            },
        );
        PoolRegistration { pool: id }
    }

    /// Ledger-internal pool id (stable for this registration's lifetime).
    pub fn pool_id(&self) -> u64 {
        self.pool
    }

    /// An initial worker on `host` came up: its seat joins the free set.
    /// Call AFTER the seat is visible to the pool's own structures.
    pub fn activate(&self, host: &str) {
        let led = ledger();
        let mut st = led.state.lock().unwrap();
        if let Some(pool) = st.pools.get_mut(&self.pool) {
            if let Some(h) = pool.host_mut(host) {
                h.dead = h.dead.saturating_sub(1);
                h.free += 1;
            }
        }
        drop(st);
        led.cv.notify_all();
    }

    /// [`PoolRegistration::acquire`] charged to the task's originating
    /// session (shipped in its [`crate::ipc::SessionContext`]).
    pub fn acquire_for(&self, task: &TaskSpec) -> Result<SlotLease, FutureError> {
        self.acquire(task.opts.context.session)
    }

    /// Block until a seat is free (the paper's blocking launch), charging
    /// the lease to `session`'s `max_workers` quota.  Errors — instead of
    /// parking forever — when the pool is shutting down, was deregistered,
    /// or is fully dead with no possible revival.
    pub fn acquire(&self, session: u64) -> Result<SlotLease, FutureError> {
        match self.acquire_inner(Some(session), false)? {
            Acquired::Seat(lease) => Ok(lease),
            Acquired::Revive(_) => unreachable!("revive disabled on this path"),
        }
    }

    /// [`PoolRegistration::acquire`] without charging any session quota —
    /// the sequential fallback seat (an inline evaluation must never
    /// deadlock against its own outer future's lease).
    pub fn acquire_uncounted(&self) -> Result<SlotLease, FutureError> {
        match self.acquire_inner(None, false)? {
            Acquired::Seat(lease) => Ok(lease),
            Acquired::Revive(_) => unreachable!("revive disabled on this path"),
        }
    }

    /// Blocking acquire that may hand back a [`ReviveTicket`] instead of a
    /// lease when every seat is busy but a dead one can be revived *now*
    /// (budget available, breaker admits) — the ProcPool launch path's
    /// on-demand respawn, budgeted and breaker-gated like the monitor's.
    pub fn acquire_or_revive(&self, session: u64) -> Result<Acquired, FutureError> {
        self.acquire_inner(Some(session), true)
    }

    fn acquire_inner(
        &self,
        session: Option<u64>,
        on_demand_revive: bool,
    ) -> Result<Acquired, FutureError> {
        let led = ledger();
        let mut st = led.state.lock().unwrap();
        loop {
            let Some(pool) = st.pools.get(&self.pool) else {
                return Err(FutureError::Launch("pool is shutting down".into()));
            };
            if pool.shutting_down {
                return Err(FutureError::Launch("pool is shutting down".into()));
            }
            // Session quota gate (max_workers) — blocking, never a drop.
            let quota_blocked = session.is_some_and(|sid| {
                let u = st.sessions.entry(sid).or_default();
                u.limits.max_workers.is_some_and(|m| u.in_use >= m)
            });
            if !quota_blocked {
                let pool = st.pools.get_mut(&self.pool).expect("checked above");
                if let Some(idx) = best_free_host(pool) {
                    let h = &mut pool.hosts[idx];
                    h.free -= 1;
                    h.in_use += 1;
                    let host = h.name.clone();
                    charge_session(&mut st, session);
                    return Ok(Acquired::Seat(SlotLease {
                        pool: self.pool,
                        host,
                        session,
                        done: false,
                    }));
                }
                if on_demand_revive {
                    if let Some((host, probe)) = take_revive(pool) {
                        charge_session(&mut st, session);
                        return Ok(Acquired::Revive(ReviveTicket {
                            pool: self.pool,
                            host,
                            session,
                            probe,
                            done: false,
                        }));
                    }
                }
                // Dead pool, nothing can ever revive: error, don't park.
                let pool = st.pools.get(&self.pool).expect("checked above");
                if pool.alive() == 0 && !pool.revivable_eventually() {
                    return Err(FutureError::Launch(
                        "all pool workers died and the respawn budget is exhausted".into(),
                    ));
                }
            }
            // An Open breaker whose cooldown ends soon may be the only
            // revival path: wake periodically so the half-open probe fires
            // without needing a fresh external event.
            let (guard, _) = led.cv.wait_timeout(st, Duration::from_millis(50)).unwrap();
            st = guard;
        }
    }

    /// Non-blocking acquire (the batch scheduler daemon's admission step):
    /// `None` when no seat is free, the session quota is at its cap, or
    /// the pool is shutting down — the job simply stays queued.
    pub fn try_acquire(&self, session: u64) -> Option<SlotLease> {
        let led = ledger();
        let mut st = led.state.lock().unwrap();
        let pool = st.pools.get(&self.pool)?;
        if pool.shutting_down {
            return None;
        }
        let quota_blocked = {
            let u = st.sessions.entry(session).or_default();
            u.limits.max_workers.is_some_and(|m| u.in_use >= m)
        };
        if quota_blocked {
            return None;
        }
        let pool = st.pools.get_mut(&self.pool)?;
        let idx = best_free_host(pool)?;
        let h = &mut pool.hosts[idx];
        h.free -= 1;
        h.in_use += 1;
        let host = h.name.clone();
        charge_session(&mut st, Some(session));
        Some(SlotLease { pool: self.pool, host, session: Some(session), done: false })
    }

    /// Monitor path: claim permission to revive one dead seat (budget
    /// charged, breaker consulted), without blocking.  `None` when nothing
    /// is dead, the budget is spent, or every dead host's breaker is open.
    pub fn try_revive(&self) -> Option<ReviveTicket> {
        let led = ledger();
        let mut st = led.state.lock().unwrap();
        let pool = st.pools.get_mut(&self.pool)?;
        if pool.shutting_down {
            return None;
        }
        let (host, probe) = take_revive(pool)?;
        Some(ReviveTicket { pool: self.pool, host, session: None, probe, done: false })
    }

    /// A worker on `host` died outside an orderly shutdown: feed the
    /// host's breaker window (possibly tripping it open).  Seat-state
    /// transitions are separate ([`SlotLease::forfeit`] /
    /// [`PoolRegistration::seat_died_idle`]).
    pub fn record_death(&self, host: &str) {
        let led = ledger();
        let mut st = led.state.lock().unwrap();
        if let Some(pool) = st.pools.get_mut(&self.pool) {
            let cfg = pool.breaker;
            if let Some(h) = pool.host_mut(host) {
                let now = Instant::now();
                h.deaths.push_back(now);
                while h.deaths.front().is_some_and(|t| now.duration_since(*t) > cfg.window) {
                    h.deaths.pop_front();
                }
                let tripped = cfg.threshold > 0 && h.deaths.len() >= cfg.threshold as usize;
                match h.phase {
                    // A death during the probe re-opens immediately.
                    Phase::HalfOpen => h.phase = Phase::Open { until: now + cfg.cooldown },
                    Phase::Closed if tripped => {
                        h.phase = Phase::Open { until: now + cfg.cooldown }
                    }
                    _ => {}
                }
            }
        }
        drop(st);
        led.cv.notify_all();
    }

    /// An *idle* worker died (no lease outstanding): its seat leaves the
    /// free set for the dead set.  If `free` is already 0, the dying seat
    /// was concurrently CLAIMED (a lease was granted but the pool-side pop
    /// has not happened yet): the transition is deliberately skipped here
    /// — the claim holder finds the seat missing and `forfeit()`s, which
    /// performs the in_use → dead transition exactly once.  (Doing both
    /// would double-count the death and mint phantom capacity.)
    pub fn seat_died_idle(&self, host: &str) {
        let led = ledger();
        let mut st = led.state.lock().unwrap();
        if let Some(pool) = st.pools.get_mut(&self.pool) {
            if let Some(h) = pool.host_mut(host) {
                if h.free > 0 {
                    h.free -= 1;
                    h.dead += 1;
                }
            }
        }
        drop(st);
        led.cv.notify_all();
    }

    /// Current breaker state of `host` (tests/diagnostics).
    pub fn breaker_state(&self, host: &str) -> BreakerState {
        let st = ledger().state.lock().unwrap();
        st.pools
            .get(&self.pool)
            .and_then(|p| p.hosts.iter().find(|h| h.name == host))
            .map(|h| h.breaker_state(Instant::now()))
            .unwrap_or(BreakerState::Closed)
    }

    /// Committed revives on `host` (the conformance breaker check asserts
    /// this stops growing once the breaker opens).
    pub fn host_respawns(&self, host: &str) -> u64 {
        let st = ledger().state.lock().unwrap();
        st.pools
            .get(&self.pool)
            .and_then(|p| p.hosts.iter().find(|h| h.name == host))
            .map(|h| h.respawns)
            .unwrap_or(0)
    }

    /// Dead seats across all hosts (the monitor's deficit probe).
    pub fn dead_seats(&self) -> usize {
        let st = ledger().state.lock().unwrap();
        st.pools
            .get(&self.pool)
            .map(|p| p.hosts.iter().map(|h| h.dead).sum())
            .unwrap_or(0)
    }

    /// Live seats (free + leased + reviving) across all hosts.
    pub fn alive_seats(&self) -> usize {
        let st = ledger().state.lock().unwrap();
        st.pools.get(&self.pool).map(|p| p.alive()).unwrap_or(0)
    }

    /// Could any dead seat still be revived some day?  (Budget left under a
    /// budgeted policy; always for unbudgeted; never for `Never`.)
    pub fn revivable_eventually(&self) -> bool {
        let st = ledger().state.lock().unwrap();
        st.pools.get(&self.pool).map(|p| p.revivable_eventually()).unwrap_or(false)
    }

    /// Zero every host's revive budget: no rescue will ever come (used
    /// when the monitor that would perform revives could not start).
    pub fn drain_budgets(&self) {
        let led = ledger();
        let mut st = led.state.lock().unwrap();
        if let Some(pool) = st.pools.get_mut(&self.pool) {
            for h in &mut pool.hosts {
                if h.budget.is_some() {
                    h.budget = Some(0);
                }
            }
        }
        drop(st);
        led.cv.notify_all();
    }

    /// Flag the pool as shutting down: blocked and future acquires error.
    pub fn shutdown(&self) {
        let led = ledger();
        let mut st = led.state.lock().unwrap();
        if let Some(pool) = st.pools.get_mut(&self.pool) {
            pool.shutting_down = true;
        }
        drop(st);
        led.cv.notify_all();
    }
}

impl Drop for PoolRegistration {
    fn drop(&mut self) {
        let led = ledger();
        let mut st = led.state.lock().unwrap();
        st.pools.remove(&self.pool);
        drop(st);
        // Outstanding leases release as no-ops; blocked acquirers error.
        led.cv.notify_all();
    }
}

fn charge_session(st: &mut LedgerState, session: Option<u64>) {
    if let Some(sid) = session {
        let u = st.sessions.entry(sid).or_default();
        u.in_use += 1;
        u.peak_in_use = u.peak_in_use.max(u.in_use);
    }
}

/// The host to lease from.  Breaker health ranks first — a `Closed`
/// breaker beats `HalfOpen` (still probing after deaths) beats `Open`
/// (cooling down): placing new work on a host that just burned through its
/// death threshold risks losing that work too, so healthy hosts absorb
/// load while a shaky one proves itself.  Within a health tier, most free
/// seats wins (spreads load); ties go to registration order
/// (deterministic).
fn best_free_host(pool: &PoolState) -> Option<usize> {
    let now = Instant::now();
    // Lower is healthier; becomes the major sort key.
    let rank = |h: &HostState| match h.breaker_state(now) {
        BreakerState::Closed => 0u8,
        BreakerState::HalfOpen => 1,
        BreakerState::Open => 2,
    };
    let mut best: Option<(usize, u8, usize)> = None;
    for (i, h) in pool.hosts.iter().enumerate() {
        if h.free == 0 {
            continue;
        }
        let r = rank(h);
        if best
            .map(|(_, br, bf)| r < br || (r == br && h.free > bf))
            .unwrap_or(true)
        {
            best = Some((i, r, h.free));
        }
    }
    best.map(|(i, _, _)| i)
}

/// Claim a revive on the first host whose breaker and budget admit one.
/// Marks the seat `reviving`, charges the budget, and transitions an
/// expired-cooldown breaker to its half-open probe.
fn take_revive(pool: &mut PoolState) -> Option<(String, bool)> {
    let policy = pool.policy;
    let now = Instant::now();
    for h in &mut pool.hosts {
        if h.dead == 0 {
            continue;
        }
        let probe = match h.phase {
            Phase::Closed => false,
            Phase::Open { until } if now >= until => true,
            Phase::Open { .. } | Phase::HalfOpen => continue,
        };
        let budget_ok = match policy {
            RevivePolicy::Never => false,
            RevivePolicy::Unbudgeted => true,
            RevivePolicy::Budgeted(_) => match h.budget {
                Some(n) if n > 0 => {
                    h.budget = Some(n - 1);
                    true
                }
                _ => false,
            },
        };
        if !budget_ok {
            continue;
        }
        if probe {
            h.phase = Phase::HalfOpen;
        }
        h.dead -= 1;
        h.reviving += 1;
        return Some((h.name.clone(), probe));
    }
    None
}

// ------------------------------------------------------------ sessions ----

/// Number of sessions with a `max_in_flight` limit installed — the fast
/// path for [`admit_in_flight`]: while zero (the overwhelmingly common
/// case), future creation skips the ledger lock entirely.
static IN_FLIGHT_LIMITED_SESSIONS: AtomicU64 = AtomicU64::new(0);

/// Maintain [`IN_FLIGHT_LIMITED_SESSIONS`] across a limits change.
/// Called with the ledger lock held.
fn track_in_flight_limit(old: &SessionLimits, new: &SessionLimits) {
    match (old.max_in_flight.is_some(), new.max_in_flight.is_some()) {
        (false, true) => {
            IN_FLIGHT_LIMITED_SESSIONS.fetch_add(1, Ordering::SeqCst);
        }
        (true, false) => {
            IN_FLIGHT_LIMITED_SESSIONS.fetch_sub(1, Ordering::SeqCst);
        }
        _ => {}
    }
}

/// Install (or replace) `session`'s admission limits.
pub fn set_session_limits(session: u64, limits: SessionLimits) {
    let led = ledger();
    let mut st = led.state.lock().unwrap();
    let u = st.sessions.entry(session).or_default();
    track_in_flight_limit(&u.limits, &limits);
    u.limits = limits;
    // Installing default limits must not strand a forever-idle entry.
    if u.is_idle() {
        st.sessions.remove(&session);
    }
    drop(st);
    led.cv.notify_all();
}

/// Remove `session`'s limits (called on `Session::close`): blocked
/// admissions wake and proceed unlimited; usage counters drain naturally.
pub fn clear_session_limits(session: u64) {
    set_session_limits(session, SessionLimits::default());
}

/// The limits currently installed for `session`.
pub fn session_limits(session: u64) -> SessionLimits {
    let st = ledger().state.lock().unwrap();
    st.sessions.get(&session).map(|u| u.limits).unwrap_or_default()
}

/// Concurrent leases currently charged to `session`.
pub fn session_in_use(session: u64) -> usize {
    let st = ledger().state.lock().unwrap();
    st.sessions.get(&session).map(|u| u.in_use).unwrap_or(0)
}

/// High-water mark of concurrent leases ever charged to `session` — the
/// quota regression tests assert this never exceeds `max_workers`.
pub fn session_peak_in_use(session: u64) -> usize {
    let st = ledger().state.lock().unwrap();
    st.sessions.get(&session).map(|u| u.peak_in_use).unwrap_or(0)
}

/// RAII permit counting one created-but-unresolved future against its
/// session's `max_in_flight` quota.
pub struct InFlightPermit {
    session: u64,
    /// False for fast-path permits minted while NO session had an
    /// in-flight limit — those never touched the ledger and release for
    /// free.  (A limit installed while such permits are outstanding
    /// applies to futures created afterwards; the window under-counts by
    /// the futures already in flight, which is the price of keeping the
    /// zero-limit hot path at one atomic load.)
    counted: bool,
}

impl Drop for InFlightPermit {
    fn drop(&mut self) {
        if !self.counted {
            return;
        }
        let led = ledger();
        let mut st = led.state.lock().unwrap();
        if let Some(u) = st.sessions.get_mut(&self.session) {
            u.in_flight = u.in_flight.saturating_sub(1);
            if u.is_idle() {
                st.sessions.remove(&self.session);
            }
        }
        drop(st);
        led.cv.notify_all();
    }
}

/// Admit one future creation for `session`, blocking while the session is
/// at its `max_in_flight` cap (never a silent drop).  The limit is re-read
/// each wake, so `clear_session_limits` (session close) unblocks waiters.
/// §Perf: while no session anywhere has a `max_in_flight` limit, this is
/// ONE atomic load — future creation does not take the ledger lock.
///
/// Result-cache hits never reach this function: a `cached` future whose
/// key is already published resolves before admission, taking no in-flight
/// permit, no backend lease, and leaving no trace in [`capacity_json`] —
/// the cache is strictly upstream of the capacity plane ([`crate::cache`]).
pub fn admit_in_flight(session: u64) -> InFlightPermit {
    if IN_FLIGHT_LIMITED_SESSIONS.load(Ordering::Acquire) == 0 {
        return InFlightPermit { session, counted: false };
    }
    let led = ledger();
    let mut st = led.state.lock().unwrap();
    loop {
        let u = st.sessions.entry(session).or_default();
        if !u.limits.max_in_flight.is_some_and(|m| u.in_flight >= m) {
            u.in_flight += 1;
            u.peak_in_flight = u.peak_in_flight.max(u.in_flight);
            return InFlightPermit { session, counted: true };
        }
        st = led.cv.wait(st).unwrap();
    }
}

// ---------------------------------------------------------------- json ----

/// Per-session and per-host utilization, schema `rustures.capacity.v1`:
///
/// ```json
/// {"schema":"rustures.capacity.v1",
///  "pools":[{"pool":1,"backend":"multicore","session":0,
///    "hosts":[{"host":"local","total":2,"free":1,"in_use":1,"reviving":0,
///              "dead":0,"breaker":"closed","recent_deaths":0,"respawns":0,
///              "budget_remaining":1024}]}],
///  "sessions":[{"session":3,"in_use":1,"peak_in_use":2,"in_flight":4,
///               "peak_in_flight":8,"max_workers":2,"max_in_flight":null}]}
/// ```
pub fn capacity_json() -> String {
    let st = ledger().state.lock().unwrap();
    let now = Instant::now();
    let mut pool_ids: Vec<u64> = st.pools.keys().copied().collect();
    pool_ids.sort_unstable();
    let pools: Vec<Json> = pool_ids
        .iter()
        .map(|id| {
            let p = &st.pools[id];
            let hosts: Vec<Json> = p
                .hosts
                .iter()
                .map(|h| {
                    obj(&[
                        ("host", Json::Str(h.name.clone())),
                        ("total", Json::Int(h.total() as i64)),
                        ("free", Json::Int(h.free as i64)),
                        ("in_use", Json::Int(h.in_use as i64)),
                        ("reviving", Json::Int(h.reviving as i64)),
                        ("dead", Json::Int(h.dead as i64)),
                        ("breaker", Json::Str(h.breaker_state(now).as_str().into())),
                        ("recent_deaths", Json::Int(h.deaths.len() as i64)),
                        ("respawns", Json::Int(h.respawns as i64)),
                        (
                            "budget_remaining",
                            h.budget.map(|b| Json::Int(b as i64)).unwrap_or(Json::Null),
                        ),
                    ])
                })
                .collect();
            obj(&[
                ("pool", Json::Int(*id as i64)),
                ("backend", Json::Str(p.backend.into())),
                ("session", Json::Int(p.owner_session as i64)),
                ("hosts", Json::Arr(hosts)),
            ])
        })
        .collect();
    let mut session_ids: Vec<u64> = st.sessions.keys().copied().collect();
    session_ids.sort_unstable();
    let sessions: Vec<Json> = session_ids
        .iter()
        .map(|id| {
            let u = &st.sessions[id];
            obj(&[
                ("session", Json::Int(*id as i64)),
                ("in_use", Json::Int(u.in_use as i64)),
                ("peak_in_use", Json::Int(u.peak_in_use as i64)),
                ("in_flight", Json::Int(u.in_flight as i64)),
                ("peak_in_flight", Json::Int(u.peak_in_flight as i64)),
                (
                    "max_workers",
                    u.limits.max_workers.map(|m| Json::Int(m as i64)).unwrap_or(Json::Null),
                ),
                (
                    "max_in_flight",
                    u.limits.max_in_flight.map(|m| Json::Int(m as i64)).unwrap_or(Json::Null),
                ),
            ])
        })
        .collect();
    json::to_string(&obj(&[
        ("schema", Json::Str("rustures.capacity.v1".into())),
        ("pools", Json::Arr(pools)),
        ("sessions", Json::Arr(sessions)),
    ]))
}

fn obj(fields: &[(&str, Json)]) -> Json {
    Json::Obj(fields.iter().map(|(k, v)| (k.to_string(), v.clone())).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    fn one_host_pool(seats: usize, policy: RevivePolicy) -> PoolRegistration {
        let reg = PoolRegistration::register(
            "test",
            &[("local".to_string(), seats)],
            policy,
            BreakerConfig::default(),
        );
        for _ in 0..seats {
            reg.activate("local");
        }
        reg
    }

    #[test]
    fn acquire_blocks_until_release_and_lease_drop_frees() {
        let reg = Arc::new(one_host_pool(1, RevivePolicy::Never));
        let lease = reg.acquire(0).unwrap();
        let r2 = Arc::clone(&reg);
        let (tx, rx) = std::sync::mpsc::channel();
        std::thread::spawn(move || {
            let l = r2.acquire(0).unwrap();
            let _ = tx.send(());
            drop(l);
        });
        assert!(
            rx.recv_timeout(Duration::from_millis(60)).is_err(),
            "second acquire must block while the seat is leased"
        );
        drop(lease);
        rx.recv_timeout(Duration::from_secs(5))
            .expect("released seat must wake the waiter");
    }

    #[test]
    fn dead_pool_without_revival_errors_instead_of_parking() {
        let reg = one_host_pool(1, RevivePolicy::Never);
        let lease = reg.acquire(0).unwrap();
        lease.forfeit();
        match reg.acquire(0) {
            Err(FutureError::Launch(msg)) => assert!(msg.contains("respawn budget"), "{msg}"),
            other => panic!("expected the dead-pool error, got {other:?}"),
        }
    }

    #[test]
    fn shutdown_wakes_blocked_acquirers_with_error() {
        let reg = Arc::new(one_host_pool(1, RevivePolicy::Never));
        let _lease = reg.acquire(0).unwrap();
        let r2 = Arc::clone(&reg);
        let (tx, rx) = std::sync::mpsc::channel();
        std::thread::spawn(move || {
            let _ = tx.send(r2.acquire(0).map(|_| ()));
        });
        std::thread::sleep(Duration::from_millis(20));
        reg.shutdown();
        match rx.recv_timeout(Duration::from_secs(5)) {
            Ok(Err(FutureError::Launch(msg))) => assert!(msg.contains("shutting down"), "{msg}"),
            other => panic!("expected shutdown error, got {other:?}"),
        }
    }

    #[test]
    fn on_demand_revive_charges_budget_and_commits_to_lease() {
        let reg = one_host_pool(1, RevivePolicy::Budgeted(1));
        reg.acquire(0).unwrap().forfeit();
        match reg.acquire_or_revive(0).unwrap() {
            Acquired::Revive(ticket) => {
                assert_eq!(ticket.host(), "local");
                let lease = ticket.commit_lease();
                assert_eq!(reg.host_respawns("local"), 1);
                lease.forfeit();
            }
            Acquired::Seat(_) => panic!("no free seat existed"),
        }
        // Budget spent: the pool is now terminally dead.
        assert!(matches!(reg.acquire_or_revive(0), Err(FutureError::Launch(_))));
    }

    #[test]
    fn aborted_revive_keeps_the_budget_charge() {
        let reg = one_host_pool(1, RevivePolicy::Budgeted(2));
        reg.acquire(0).unwrap().forfeit();
        let ticket = reg.try_revive().expect("budget allows a revive");
        drop(ticket); // spawn failed
        assert_eq!(reg.dead_seats(), 1, "aborted revive returns the seat to dead");
        assert!(reg.try_revive().is_some(), "second budget charge still available");
    }

    #[test]
    fn max_workers_quota_blocks_and_peak_is_tracked() {
        let reg = Arc::new(one_host_pool(4, RevivePolicy::Never));
        let session = 9_100_001;
        set_session_limits(session, SessionLimits::new().max_workers(2));
        let l1 = reg.acquire(session).unwrap();
        let _l2 = reg.acquire(session).unwrap();
        let r2 = Arc::clone(&reg);
        let (tx, rx) = std::sync::mpsc::channel();
        std::thread::spawn(move || {
            let l = r2.acquire(session).unwrap();
            let _ = tx.send(());
            drop(l);
        });
        assert!(
            rx.recv_timeout(Duration::from_millis(60)).is_err(),
            "third lease must block at max_workers = 2 despite free seats"
        );
        drop(l1);
        rx.recv_timeout(Duration::from_secs(5)).expect("freed quota must admit the waiter");
        assert!(session_peak_in_use(session) <= 2, "quota must bound the high-water mark");
        clear_session_limits(session);
    }

    #[test]
    fn uncounted_acquire_ignores_quota() {
        let reg = one_host_pool(2, RevivePolicy::Never);
        let session = 9_100_002;
        set_session_limits(session, SessionLimits::new().max_workers(1));
        let _l1 = reg.acquire(session).unwrap();
        // The sequential-fallback path must not deadlock against the quota.
        let _l2 = reg.acquire_uncounted().unwrap();
        assert_eq!(session_in_use(session), 1);
        clear_session_limits(session);
    }

    #[test]
    fn in_flight_permits_block_at_cap_and_release_on_drop() {
        let session = 9_100_003;
        set_session_limits(session, SessionLimits::new().max_in_flight(2));
        let p1 = admit_in_flight(session);
        let _p2 = admit_in_flight(session);
        let (tx, rx) = std::sync::mpsc::channel();
        std::thread::spawn(move || {
            let p = admit_in_flight(session);
            let _ = tx.send(());
            drop(p);
        });
        assert!(rx.recv_timeout(Duration::from_millis(60)).is_err(), "cap must block");
        drop(p1);
        rx.recv_timeout(Duration::from_secs(5)).expect("freed permit must admit the waiter");
        clear_session_limits(session);
    }

    #[test]
    fn breaker_opens_after_threshold_blocks_revives_then_probes_and_closes() {
        let reg = PoolRegistration::register(
            "test",
            &[("a".to_string(), 1), ("b".to_string(), 1)],
            RevivePolicy::Budgeted(16),
            BreakerConfig {
                threshold: 2,
                window: Duration::from_secs(10),
                cooldown: Duration::from_millis(40),
            },
        );
        reg.activate("a");
        reg.activate("b");

        // Two deaths on host a within the window trip its breaker.
        let respawns_before;
        {
            let l = reg.acquire(0).unwrap();
            assert_eq!(l.host(), "a", "deterministic selection: registration order");
            l.forfeit();
            reg.record_death("a");
            let t = reg.try_revive().expect("first death: breaker still closed");
            assert_eq!(t.host(), "a");
            t.commit_idle();
            let l = reg.acquire(0).unwrap();
            assert_eq!(l.host(), "a");
            l.forfeit();
            reg.record_death("a");
            respawns_before = reg.host_respawns("a");
        }
        assert_eq!(reg.breaker_state("a"), BreakerState::Open);
        // No resubmission capacity flows to the open host...
        assert!(reg.try_revive().is_none(), "open breaker must deny revives");
        assert_eq!(reg.host_respawns("a"), respawns_before, "no further respawns on a");
        // ...while the healthy host keeps serving.
        let lb = reg.acquire(0).unwrap();
        assert_eq!(lb.host(), "b");
        drop(lb);

        // Cooldown passes: exactly one half-open probe is admitted.
        std::thread::sleep(Duration::from_millis(60));
        let probe = reg.try_revive().expect("cooled-down breaker must admit the probe");
        assert_eq!(probe.host(), "a");
        assert_eq!(reg.breaker_state("a"), BreakerState::HalfOpen);
        probe.commit_idle();
        // Breaker-aware placement sends new work to the healthy host first;
        // take b's seat so the next lease lands on the half-open probe host.
        let lb = reg.acquire(0).unwrap();
        assert_eq!(lb.host(), "b", "closed breaker outranks half-open");
        // A clean lease release on the probed host closes the breaker.
        let la = reg.acquire(0).unwrap();
        assert_eq!(la.host(), "a");
        drop(la);
        assert_eq!(reg.breaker_state("a"), BreakerState::Closed);
    }

    #[test]
    fn death_during_half_open_probe_reopens() {
        let reg = PoolRegistration::register(
            "test",
            &[("a".to_string(), 1)],
            RevivePolicy::Budgeted(16),
            BreakerConfig {
                threshold: 1,
                window: Duration::from_secs(10),
                cooldown: Duration::from_millis(20),
            },
        );
        reg.activate("a");
        reg.acquire(0).unwrap().forfeit();
        reg.record_death("a");
        assert_eq!(reg.breaker_state("a"), BreakerState::Open);
        std::thread::sleep(Duration::from_millis(30));
        let probe = reg.try_revive().expect("probe after cooldown");
        probe.commit_idle();
        let l = reg.acquire(0).unwrap();
        l.forfeit();
        reg.record_death("a");
        assert_eq!(reg.breaker_state("a"), BreakerState::Open, "probe death must re-open");
    }

    #[test]
    fn blocked_acquirer_rides_out_an_open_breaker_via_on_demand_probe() {
        // A launcher parked in acquire_or_revive while the only host's
        // breaker is open must pick up the half-open probe once the
        // cooldown passes — the timed re-check inside acquire_inner.
        let reg = Arc::new(PoolRegistration::register(
            "test",
            &[("a".to_string(), 1)],
            RevivePolicy::Budgeted(16),
            BreakerConfig {
                threshold: 1,
                window: Duration::from_secs(10),
                cooldown: Duration::from_millis(80),
            },
        ));
        reg.activate("a");
        reg.acquire(0).unwrap().forfeit();
        reg.record_death("a");
        let r2 = Arc::clone(&reg);
        let (tx, rx) = std::sync::mpsc::channel();
        std::thread::spawn(move || {
            let got = r2.acquire_or_revive(0);
            let _ = tx.send(matches!(got, Ok(Acquired::Revive(_))));
        });
        assert!(
            rx.recv_timeout(Duration::from_millis(40)).is_err(),
            "open breaker must defer the revive"
        );
        assert_eq!(
            rx.recv_timeout(Duration::from_secs(5)),
            Ok(true),
            "cooldown expiry must hand the parked launcher the probe ticket"
        );
    }

    #[test]
    fn capacity_json_has_schema_pools_and_sessions() {
        let reg = one_host_pool(2, RevivePolicy::Budgeted(4));
        let session = 9_100_004;
        set_session_limits(session, SessionLimits::new().max_workers(3));
        let _l = reg.acquire(session).unwrap();
        let doc = crate::util::json::parse(&capacity_json()).expect("valid JSON");
        assert_eq!(
            doc.get("schema").and_then(|s| s.as_str()),
            Some("rustures.capacity.v1")
        );
        let pools = doc.get("pools").unwrap().as_arr().unwrap();
        let pool = pools
            .iter()
            .find(|p| p.get("pool").and_then(|v| v.as_i64()) == Some(reg.pool_id() as i64))
            .expect("registered pool present");
        let host = &pool.get("hosts").unwrap().as_arr().unwrap()[0];
        assert_eq!(host.get("host").unwrap().as_str(), Some("local"));
        assert_eq!(host.get("in_use").unwrap().as_i64(), Some(1));
        assert_eq!(host.get("breaker").unwrap().as_str(), Some("closed"));
        let sessions = doc.get("sessions").unwrap().as_arr().unwrap();
        let entry = sessions
            .iter()
            .find(|e| e.get("session").and_then(|v| v.as_i64()) == Some(session as i64))
            .expect("session entry present");
        assert_eq!(entry.get("max_workers").unwrap().as_i64(), Some(3));
        clear_session_limits(session);
    }

    #[test]
    fn deregistered_pool_leases_release_as_noops() {
        let reg = one_host_pool(1, RevivePolicy::Never);
        let session = 9_100_005;
        let lease = reg.acquire(session).unwrap();
        drop(reg);
        assert_eq!(session_in_use(session), 1);
        drop(lease); // must not panic; session charge still returns
        assert_eq!(session_in_use(session), 0);
    }

    #[test]
    fn placement_deprioritizes_open_adjacent_host() {
        // Host a trips its breaker (Open), cools down into the observable
        // HalfOpen state, and gets a seat back via the probe.  Even though
        // it then has MORE free seats than the healthy host, new leases
        // must prefer the Closed-breaker host until a's probe proves out.
        let reg = PoolRegistration::register(
            "test",
            &[("a".to_string(), 2), ("b".to_string(), 1)],
            RevivePolicy::Budgeted(16),
            BreakerConfig {
                threshold: 1,
                window: Duration::from_secs(10),
                cooldown: Duration::from_millis(20),
            },
        );
        for h in ["a", "a", "b"] {
            reg.activate(h);
        }
        // One death on a trips the threshold-1 breaker.
        let l = reg.acquire(0).unwrap();
        assert_eq!(l.host(), "a", "all-closed tie: most free seats wins");
        l.forfeit();
        reg.record_death("a");
        assert_eq!(reg.breaker_state("a"), BreakerState::Open);
        // Cooldown expires (reads as HalfOpen); the probe restores a's seat.
        std::thread::sleep(Duration::from_millis(30));
        let probe = reg.try_revive().expect("cooled-down breaker admits probe");
        probe.commit_idle();
        assert_eq!(reg.breaker_state("a"), BreakerState::HalfOpen);
        // a: 2 free, HalfOpen.  b: 1 free, Closed.  Health outranks free.
        let l1 = reg.acquire(0).unwrap();
        assert_eq!(l1.host(), "b", "half-open host must be deprioritized");
        // Only once the healthy host is saturated does a get new work.
        let l2 = reg.acquire(0).unwrap();
        assert_eq!(l2.host(), "a");
    }

    #[test]
    fn leases_spread_across_hosts_by_free_count() {
        let reg = PoolRegistration::register(
            "test",
            &[("a".to_string(), 2), ("b".to_string(), 2)],
            RevivePolicy::Never,
            BreakerConfig::default(),
        );
        for h in ["a", "a", "b", "b"] {
            reg.activate(h);
        }
        let l1 = reg.acquire(0).unwrap();
        let l2 = reg.acquire(0).unwrap();
        assert_ne!(l1.host(), l2.host(), "equal-free tie then max-free must alternate");
    }

    #[test]
    fn concurrent_acquire_release_is_balanced() {
        let reg = Arc::new(one_host_pool(3, RevivePolicy::Never));
        let peak = Arc::new(AtomicUsize::new(0));
        let cur = Arc::new(AtomicUsize::new(0));
        let mut joins = Vec::new();
        for _ in 0..8 {
            let reg = Arc::clone(&reg);
            let peak = Arc::clone(&peak);
            let cur = Arc::clone(&cur);
            joins.push(std::thread::spawn(move || {
                for _ in 0..20 {
                    let lease = reg.acquire(0).unwrap();
                    let now = cur.fetch_add(1, Ordering::SeqCst) + 1;
                    peak.fetch_max(now, Ordering::SeqCst);
                    std::thread::sleep(Duration::from_micros(200));
                    cur.fetch_sub(1, Ordering::SeqCst);
                    drop(lease);
                }
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
        assert!(
            peak.load(Ordering::SeqCst) <= 3,
            "ledger must never over-admit: peak {} > 3 seats",
            peak.load(Ordering::SeqCst)
        );
    }
}
