//! The Future API conformance suite — the `future.tests` package.
//!
//! "Any new future backend developed must pass these tests on complying
//! with the Future API.  By conforming to this API, the end-user can trust
//! that the backend will produce the same correct and reproducible results
//! as any other backend."  [`run_conformance`] executes every check under
//! the given plan and reports pass/fail per check; the integration suite
//! runs it for all built-in backends.

use std::time::{Duration, Instant};

use crate::api::conditions::{set_sink, ConditionKind, RecordingSink};
use crate::api::env::Env;
use crate::api::error::FutureError;
use crate::api::expr::{Expr, PrimOp};
use crate::api::future::{
    future, future_with, reset_session_counter, resolve, resolve_any, FutureOpts, FutureSet,
};
use crate::api::globals::GlobalsSpec;
use crate::api::plan::{current_topology, with_plan_topology, PlanSpec};
use crate::api::session::Session;
use crate::api::value::{Tensor, Value};
use crate::backend::supervisor::RetryPolicy;
use crate::mapreduce::{future_lapply, Chunking, LapplyOpts};

/// One conformance check.
pub struct Check {
    pub name: &'static str,
    pub what: &'static str,
    run: fn() -> Result<(), String>,
}

/// Result of one check.
#[derive(Debug, Clone, PartialEq)]
pub struct CheckResult {
    pub name: &'static str,
    pub passed: bool,
    pub detail: String,
    pub elapsed: Duration,
}

/// Full suite report for one backend.
#[derive(Debug)]
pub struct Report {
    pub plan: PlanSpec,
    pub results: Vec<CheckResult>,
}

impl Report {
    pub fn passed(&self) -> bool {
        self.results.iter().all(|r| r.passed)
    }

    pub fn summary(&self) -> String {
        let ok = self.results.iter().filter(|r| r.passed).count();
        format!("{}: {ok}/{} checks passed", self.plan.name(), self.results.len())
    }
}

fn err(msg: impl Into<String>) -> Result<(), String> {
    Err(msg.into())
}

fn expect_eq<T: PartialEq + std::fmt::Debug>(got: T, want: T, what: &str) -> Result<(), String> {
    if got == want {
        Ok(())
    } else {
        err(format!("{what}: got {got:?}, want {want:?}"))
    }
}

// ------------------------------------------------------------- checks ----

fn check_basic_value() -> Result<(), String> {
    let mut env = Env::new();
    env.insert("x", 20i64);
    let f = future(Expr::add(Expr::var("x"), Expr::lit(22i64)), &env)
        .map_err(|e| e.to_string())?;
    expect_eq(f.value().map_err(|e| e.to_string())?, Value::I64(42), "value")
}

fn check_creation_time_capture() -> Result<(), String> {
    let mut env = Env::new();
    env.insert("x", 1i64);
    let f = future(Expr::var("x"), &env).map_err(|e| e.to_string())?;
    env.insert("x", 2i64);
    expect_eq(f.value().map_err(|e| e.to_string())?, Value::I64(1), "captured global")
}

fn check_missing_global_errors_at_creation() -> Result<(), String> {
    let env = Env::new();
    match future(Expr::var("ghost"), &env) {
        Err(FutureError::MissingGlobal { name }) if name == "ghost" => Ok(()),
        Err(other) => err(format!("expected MissingGlobal, got {other}")),
        Ok(_) => err("expected MissingGlobal, future was created"),
    }
}

fn check_dyn_lookup_trap_and_fixes() -> Result<(), String> {
    let mut env = Env::new();
    env.insert("k", 42i64);
    // Trap: get("k") alone fails at evaluation with R's message.
    let f = future(Expr::dyn_lookup(Expr::lit("k")), &env).map_err(|e| e.to_string())?;
    match f.value() {
        Err(FutureError::Eval(e)) if e.message == "object 'k' not found" => {}
        other => return err(format!("trap: expected eval error, got {other:?}")),
    }
    // Fix 1: mention the variable.
    let f = future(
        Expr::seq(vec![Expr::var("k"), Expr::dyn_lookup(Expr::lit("k"))]),
        &env,
    )
    .map_err(|e| e.to_string())?;
    expect_eq(f.value().map_err(|e| e.to_string())?, Value::I64(42), "fix: mention")?;
    // Fix 2: globals = "k".
    let f = future_with(
        Expr::dyn_lookup(Expr::lit("k")),
        &env,
        FutureOpts::new().globals(GlobalsSpec::Explicit(vec!["k".into()])),
    )
    .map_err(|e| e.to_string())?;
    expect_eq(f.value().map_err(|e| e.to_string())?, Value::I64(42), "fix: explicit")
}

fn check_eval_error_relayed_as_is() -> Result<(), String> {
    let env = Env::new();
    let f = future(Expr::stop(Expr::lit("non-numeric argument")), &env)
        .map_err(|e| e.to_string())?;
    match f.value() {
        Err(FutureError::Eval(e)) => expect_eq(
            e.message.as_str(),
            "non-numeric argument",
            "relayed error message",
        ),
        other => err(format!("expected eval error, got {other:?}")),
    }
}

fn check_stdout_and_condition_relay_order() -> Result<(), String> {
    let env = Env::new();
    let f = future(
        Expr::seq(vec![
            Expr::cat(Expr::lit("Hello world\n")),
            Expr::message(Expr::lit("The sum of 'x' is 55")),
            Expr::warning(Expr::lit("Missing values were omitted")),
            Expr::cat(Expr::lit("Bye bye\n")),
            Expr::lit(55i64),
        ]),
        &env,
    )
    .map_err(|e| e.to_string())?;

    let rec = RecordingSink::new();
    set_sink(Some(Box::new(rec.clone())));
    let v = f.value();
    set_sink(None);

    expect_eq(v.map_err(|e| e.to_string())?, Value::I64(55), "value")?;
    expect_eq(rec.stdout_text().as_str(), "Hello world\nBye bye\n", "stdout relay")?;
    let conds = rec.conditions();
    if conds.len() != 2 {
        return err(format!("expected 2 conditions, got {}: {conds:?}", conds.len()));
    }
    expect_eq(conds[0].kind, ConditionKind::Message, "first condition kind")?;
    expect_eq(conds[1].kind, ConditionKind::Warning, "second condition kind")
}

fn check_rng_reproducible_across_runs() -> Result<(), String> {
    let env = Env::new();
    let run = || -> Result<Vec<Value>, String> {
        reset_session_counter();
        let fs: Vec<_> = (0..4)
            .map(|_| future_with(Expr::rnorm(3), &env, FutureOpts::new().seed(42)))
            .collect::<Result<_, _>>()
            .map_err(|e| e.to_string())?;
        fs.iter().map(|f| f.value().map_err(|e| e.to_string())).collect()
    };
    let a = run()?;
    let b = run()?;
    expect_eq(a.clone(), b, "reproducible draws")?;
    // Streams must differ between futures.
    if a[0] == a[1] {
        return err("futures shared an RNG stream");
    }
    Ok(())
}

fn check_unseeded_rng_warns() -> Result<(), String> {
    let env = Env::new();
    let f = future(Expr::runif(2), &env).map_err(|e| e.to_string())?;
    let rec = RecordingSink::new();
    set_sink(Some(Box::new(rec.clone())));
    let _ = f.value();
    set_sink(None);
    if rec
        .conditions()
        .iter()
        .any(|c| c.kind == ConditionKind::Warning && c.message.contains("UnexpectedRandomNumbers"))
    {
        Ok(())
    } else {
        err("missing UnexpectedRandomNumbers warning")
    }
}

fn check_lazy_semantics() -> Result<(), String> {
    let mut env = Env::new();
    env.insert("x", 1i64);
    let f = future_with(Expr::var("x"), &env, FutureOpts::new().lazy())
        .map_err(|e| e.to_string())?;
    // Globals captured at creation even for lazy futures (paper footnote 16).
    env.insert("x", 99i64);
    expect_eq(f.value().map_err(|e| e.to_string())?, Value::I64(1), "lazy capture")
}

fn check_resolved_is_nonblocking() -> Result<(), String> {
    let env = Env::new();
    let f = future(Expr::Spin { millis: 150 }, &env).map_err(|e| e.to_string())?;
    let t0 = Instant::now();
    let _ = f.resolved();
    let probe = t0.elapsed();
    let _ = f.value();
    // Sequential backends resolve at creation, so the probe is trivially
    // fast; parallel backends must not block for the full task.
    if probe > Duration::from_millis(100) {
        return err(format!("resolved() blocked for {probe:?}"));
    }
    Ok(())
}

fn check_values_collect_in_any_order() -> Result<(), String> {
    let env = Env::new();
    let fs: Vec<_> = (0..4)
        .map(|i| future(Expr::lit(i as i64), &env))
        .collect::<Result<_, _>>()
        .map_err(|e| e.to_string())?;
    // Collect in reverse order — values must still match creation index.
    for (i, f) in fs.iter().enumerate().rev() {
        expect_eq(f.value().map_err(|e| e.to_string())?, Value::I64(i as i64), "reverse collect")?;
    }
    Ok(())
}

fn check_large_payload_roundtrip() -> Result<(), String> {
    let mut env = Env::new();
    let n = 128 * 128;
    let data: Vec<f32> = (0..n).map(|i| i as f32 * 0.5).collect();
    env.insert("t", Tensor::new(vec![128, 128], data.clone()).unwrap());
    let f = future(
        Expr::prim(PrimOp::Sum, vec![Expr::mul(Expr::var("t"), Expr::lit(2.0))]),
        &env,
    )
    .map_err(|e| e.to_string())?;
    let want: f64 = data.iter().map(|x| *x as f64 * 2.0).sum();
    let got = f.value().map_err(|e| e.to_string())?.as_f64().unwrap();
    if (got - want).abs() > want.abs() * 1e-6 {
        return err(format!("tensor payload: got {got}, want {want}"));
    }
    Ok(())
}

fn check_lapply_chunking_invariance() -> Result<(), String> {
    let env = Env::new();
    let xs: Vec<Value> = (0..6i64).map(Value::I64).collect();
    let body = Expr::add(Expr::var("x"), Expr::runif(1));
    let go = |chunking| {
        future_lapply(&xs, "x", &body, &env, &LapplyOpts::new().seed(7).chunking(chunking))
            .map_err(|e| e.to_string())
    };
    let a = go(Chunking::PerElement)?;
    let b = go(Chunking::PerWorker)?;
    expect_eq(a, b, "chunking invariance")
}

fn check_resolve_all_without_collection() -> Result<(), String> {
    // The paper's resolve(): wait until all are resolved, collect later.
    let env = Env::new();
    let fs: Vec<_> = (0..4)
        .map(|i| {
            future(Expr::seq(vec![Expr::Spin { millis: 5 }, Expr::lit(i as i64)]), &env)
        })
        .collect::<Result<_, _>>()
        .map_err(|e| e.to_string())?;
    resolve(&fs);
    for (i, f) in fs.iter().enumerate() {
        if !f.resolved() {
            return err(format!("future {i} unresolved after resolve()"));
        }
    }
    // Collection still works, in any order, after resolution.
    for (i, f) in fs.iter().enumerate().rev() {
        expect_eq(f.value().map_err(|e| e.to_string())?, Value::I64(i as i64), "post-resolve")?;
    }
    Ok(())
}

fn check_resolve_any_returns_a_resolved_future() -> Result<(), String> {
    let env = Env::new();
    let fs: Vec<_> = (0..3)
        .map(|i| future(Expr::lit(i as i64), &env))
        .collect::<Result<_, _>>()
        .map_err(|e| e.to_string())?;
    match resolve_any(&fs) {
        Some(i) if i < fs.len() => {
            if !fs[i].resolved() {
                return err(format!("resolve_any returned unresolved index {i}"));
            }
            expect_eq(
                fs[i].value().map_err(|e| e.to_string())?,
                Value::I64(i as i64),
                "resolve_any winner",
            )
        }
        Some(i) => err(format!("resolve_any index {i} out of range")),
        None => err("resolve_any returned None for a non-empty set"),
    }
}

fn check_future_set_reports_every_index_once() -> Result<(), String> {
    let env = Env::new();
    let fs: Vec<_> = (0..5)
        .map(|i| future(Expr::lit(i as i64), &env))
        .collect::<Result<_, _>>()
        .map_err(|e| e.to_string())?;
    let mut set = FutureSet::new(&fs);
    let mut seen = Vec::new();
    while let Some(i) = set.wait_any() {
        seen.push(i);
    }
    seen.sort_unstable();
    expect_eq(seen, (0..5).collect::<Vec<_>>(), "reported indices")
}

fn check_streaming_collect_matches_in_order() -> Result<(), String> {
    // As-completed harvesting must be bit-identical (values + seeded RNG)
    // to the strictly-in-order reference under this backend.
    let env = Env::new();
    let xs: Vec<Value> = (0..6i64).map(Value::I64).collect();
    let body = Expr::add(Expr::var("x"), Expr::runif(1));
    let streamed = future_lapply(
        &xs,
        "x",
        &body,
        &env,
        &LapplyOpts::new().seed(31).chunking(Chunking::ChunkSize(2)),
    )
    .map_err(|e| e.to_string())?;
    let ordered = future_lapply(
        &xs,
        "x",
        &body,
        &env,
        &LapplyOpts::new().seed(31).chunking(Chunking::ChunkSize(2)).in_order(),
    )
    .map_err(|e| e.to_string())?;
    expect_eq(streamed, ordered, "streaming vs in-order")
}

fn check_queued_dispatch_resolves_correctly() -> Result<(), String> {
    // Semantics only (timing is backend-specific): a queued future must
    // deliver the same value/ordering guarantees as a blocking-create one.
    let env = Env::new();
    let fs: Vec<_> = (0..3)
        .map(|i| {
            future_with(
                Expr::mul(Expr::lit(i as i64), Expr::lit(10i64)),
                &env,
                FutureOpts::new().queued(),
            )
        })
        .collect::<Result<_, _>>()
        .map_err(|e| e.to_string())?;
    for (i, f) in fs.iter().enumerate() {
        expect_eq(
            f.value().map_err(|e| e.to_string())?,
            Value::I64(i as i64 * 10),
            "queued value",
        )?;
    }
    Ok(())
}

// ------------------------------------------------- supervision checks ----

/// The plan these checks run under (set by [`run_conformance`]).
fn ambient_plan() -> PlanSpec {
    current_topology().first().cloned().unwrap_or(PlanSpec::Sequential)
}

/// Does this plan have workers a chaos kill can actually take down?
/// Everything except `sequential` does: thread-pool threads, multisession
/// pipes, cluster sockets, batch job processes, and custom backends (the
/// registered ones wrap the thread pool).  Under `sequential` the probe
/// degrades to an evaluation error.
fn disposable_workers(spec: &PlanSpec) -> bool {
    !matches!(spec, PlanSpec::Sequential)
}

/// Fresh, unique marker path for a fail-exactly-once chaos probe.
fn chaos_marker(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("rustures-chaos-{tag}-{}", crate::util::uuid_v4()))
        .to_string_lossy()
        .into_owned()
}

/// Map body: element `kill_at` kills its worker once (marker-gated), then
/// every element computes `x + runif(1)` — one seeded draw per element, so
/// bit-identity against a clean run is meaningful.
fn kill_body(kill_at: i64, marker: &str) -> Expr {
    Expr::seq(vec![
        Expr::if_else(
            Expr::prim(PrimOp::Eq, vec![Expr::var("x"), Expr::lit(kill_at)]),
            Expr::chaos_kill_once(marker),
            Expr::lit(0i64),
        ),
        Expr::add(Expr::var("x"), Expr::runif(1)),
    ])
}

fn check_kill_respawn_bit_identical() -> Result<(), String> {
    let spec = ambient_plan();
    let env = Env::new();
    let xs: Vec<Value> = (0..6i64).map(Value::I64).collect();
    // Reference: the same seeded map, no chaos.
    let clean_body = Expr::seq(vec![Expr::lit(0i64), Expr::add(Expr::var("x"), Expr::runif(1))]);
    let want = future_lapply(
        &xs,
        "x",
        &clean_body,
        &env,
        &LapplyOpts::new().seed(13).chunking(Chunking::ChunkSize(2)),
    )
    .map_err(|e| e.to_string())?;

    let marker = chaos_marker("respawn");
    let body = kill_body(2, &marker);
    let opts = LapplyOpts::new()
        .seed(13)
        .chunking(Chunking::ChunkSize(2))
        .retry(RetryPolicy::idempotent(4).with_backoff(Duration::from_millis(1), 2.0));
    let got = future_lapply(&xs, "x", &body, &env, &opts);
    let _ = std::fs::remove_file(&marker);

    if disposable_workers(&spec) {
        // The kill took a worker down mid-map; the supervisor respawned
        // capacity and the retry resubmitted the lost chunk — values must
        // be bit-identical to the no-failure run.
        expect_eq(got.map_err(|e| e.to_string())?, want, "kill+retry vs clean run")
    } else {
        // No disposable worker: the probe degrades to an eval error, and
        // retry must NOT mask it (eval errors are never resubmitted).
        match got {
            Err(e) if e.is_eval() => Ok(()),
            other => err(format!("sequential: expected un-retried eval error, got {other:?}")),
        }
    }
}

fn check_retry_exhausted_surfaces_structured_error() -> Result<(), String> {
    let spec = ambient_plan();
    let env = Env::new();
    let opts = FutureOpts::new()
        .retry(RetryPolicy::idempotent(2).with_backoff(Duration::from_millis(1), 1.0));
    // Unconditional kill: every attempt murders its worker.
    let f = future_with(Expr::chaos_kill(), &env, opts).map_err(|e| e.to_string())?;
    match f.value() {
        Err(FutureError::Retried { attempts, last }) if disposable_workers(&spec) => {
            if attempts != 2 {
                return err(format!("expected 2 attempts, got {attempts}"));
            }
            if (*last).is_eval() {
                return err(format!("last failure must be infrastructure, got {last}"));
            }
            Ok(())
        }
        Err(e) if !disposable_workers(&spec) && e.is_eval() => Ok(()),
        other => err(format!("expected Retried provenance, got {other:?}")),
    }
}

fn check_kill_without_retry_is_structured_not_hang() -> Result<(), String> {
    let spec = ambient_plan();
    let env = Env::new();
    let xs: Vec<Value> = (0..6i64).map(Value::I64).collect();
    let marker = chaos_marker("noretry");
    let body = kill_body(2, &marker);
    // No retry policy: the map must COMPLETE with a structured error for
    // the killed chunk (never a hang), and the pool must still serve.
    let got = future_lapply(
        &xs,
        "x",
        &body,
        &env,
        &LapplyOpts::new().seed(13).chunking(Chunking::ChunkSize(2)),
    );
    let _ = std::fs::remove_file(&marker);
    match got {
        Err(e) if disposable_workers(&spec) => {
            if e.is_eval() {
                return err(format!("worker loss must not masquerade as eval error: {e}"));
            }
            if !e.is_recoverable() {
                return err(format!("worker loss must be recoverable: {e}"));
            }
        }
        Err(e) if e.is_eval() => {} // sequential: degraded probe
        other => return err(format!("expected a structured failure, got {other:?}")),
    }
    // Capacity recovered (respawn): a follow-up future still works.
    let f = future(Expr::lit(7i64), &env).map_err(|e| e.to_string())?;
    expect_eq(f.value().map_err(|e| e.to_string())?, Value::I64(7), "post-kill future")
}

// -------------------------------------------------- capacity checks ----

/// Per-session `max_workers` quota, end to end on the ambient backend: a
/// quota-capped 64-element lapply completes (blocking admission, never a
/// drop), the seeded result is bit-identical to an unlimited run, and the
/// ledger's high-water mark proves concurrency never exceeded the cap.
fn check_capacity_quota_bounds_concurrency() -> Result<(), String> {
    use crate::api::session::Session;
    use crate::capacity::{self, SessionLimits};

    let spec = ambient_plan();
    let env = Env::new();
    let xs: Vec<Value> = (0..64i64).map(Value::I64).collect();
    let body = Expr::add(Expr::var("x"), Expr::runif(1));
    let opts = || LapplyOpts::new().seed(23).chunking(Chunking::ChunkSize(8));

    // Unlimited reference run on its own session (seeded per-element
    // substreams: the values are invariant to concurrency by design).
    let unlimited = Session::with_plan(spec.clone());
    let want = unlimited.lapply(&xs, "x", &body, &env, &opts()).map_err(|e| e.to_string())?;
    unlimited.close();

    // Quota-capped: at most 2 concurrent execution-slot leases.
    let s = Session::with_limits(spec, SessionLimits::new().max_workers(2));
    let got = s.lapply(&xs, "x", &body, &env, &opts()).map_err(|e| e.to_string())?;
    let peak = capacity::session_peak_in_use(s.id());
    s.close();
    expect_eq(got, want, "quota-capped lapply vs unlimited run")?;
    if peak > 2 {
        return err(format!(
            "session max_workers = 2 but peak concurrent leases was {peak}"
        ));
    }
    Ok(())
}

/// The three-state circuit breaker at the ledger layer (plan-independent
/// semantics, exercised under every suite): K deaths within the window
/// open a host's breaker — zero further revives (resubmission capacity)
/// flow to it while a healthy host keeps serving — and after the cooldown
/// exactly one half-open probe runs; a clean completion closes the
/// breaker.
fn check_circuit_breaker_isolates_dying_host() -> Result<(), String> {
    use crate::capacity::{BreakerConfig, BreakerState, PoolRegistration, RevivePolicy};

    let reg = PoolRegistration::register(
        "conformance-probe",
        &[("a".to_string(), 1), ("b".to_string(), 1)],
        RevivePolicy::Budgeted(16),
        BreakerConfig {
            threshold: 2,
            window: Duration::from_secs(10),
            cooldown: Duration::from_millis(30),
        },
    );
    reg.activate("a");
    reg.activate("b");

    // First death on host a: breaker stays closed, the revive flows.
    let l = reg.acquire(0).map_err(|e| e.to_string())?;
    expect_eq(l.host().to_string(), "a".to_string(), "deterministic first host")?;
    l.forfeit();
    reg.record_death("a");
    let t = reg.try_revive().ok_or("first revive denied while the breaker is closed")?;
    expect_eq(t.host().to_string(), "a".to_string(), "revive targets the dead host")?;
    t.commit_idle();

    // Second death within the window: the breaker opens.
    let l = reg.acquire(0).map_err(|e| e.to_string())?;
    l.forfeit();
    reg.record_death("a");
    expect_eq(reg.breaker_state("a"), BreakerState::Open, "breaker after K deaths")?;
    let respawns = reg.host_respawns("a");
    if reg.try_revive().is_some() {
        return err("open breaker must deny revives (no resubmissions to host a)");
    }
    expect_eq(reg.host_respawns("a"), respawns, "zero further respawns on the open host")?;

    // The healthy host keeps absorbing the load.
    let lb = reg.acquire(0).map_err(|e| e.to_string())?;
    expect_eq(lb.host().to_string(), "b".to_string(), "healthy host serves meanwhile")?;
    drop(lb);

    // Cooldown passes: exactly one half-open probe is admitted, and a
    // clean lease release on the probed host closes the breaker.
    std::thread::sleep(Duration::from_millis(45));
    let probe = reg.try_revive().ok_or("half-open probe denied after the cooldown")?;
    expect_eq(probe.host().to_string(), "a".to_string(), "probe targets the tripped host")?;
    expect_eq(reg.breaker_state("a"), BreakerState::HalfOpen, "probe state")?;
    probe.commit_idle();
    let la = reg.acquire(0).map_err(|e| e.to_string())?;
    expect_eq(la.host().to_string(), "a".to_string(), "probe seat serves")?;
    drop(la);
    expect_eq(reg.breaker_state("a"), BreakerState::Closed, "clean release closes the breaker")
}

// --------------------------------------------------- session checks ----

/// Two concurrent first-class sessions on *different* backends in one
/// process: seeded results bit-identical per session (independent stream
/// counters), supervision counters isolated, future ids session-prefixed,
/// and no cross-session dispatcher interference.  Runs regardless of the
/// ambient plan — the sessions bring their own.
fn check_two_sessions_isolated() -> Result<(), String> {
    use crate::api::session::Session;

    let env = Env::new();
    let xs: Vec<Value> = (0..6i64).map(Value::I64).collect();
    let body = Expr::add(Expr::var("x"), Expr::runif(1));
    let opts = || LapplyOpts::new().seed(17).chunking(Chunking::ChunkSize(2));

    // Reference: a fresh sequential session (bit-identical target — seeded
    // lapply is backend-invariant by construction).
    let reference = Session::with_plan(PlanSpec::sequential());
    let want = reference
        .lapply(&xs, "x", &body, &env, &opts())
        .map_err(|e| e.to_string())?;
    reference.close();

    let a = Session::with_plan(PlanSpec::multicore(2));
    let b = Session::with_plan(PlanSpec::multiprocess(2));

    // Run both sessions concurrently from two threads.
    let env_a = Env::new();
    let env_b = Env::new();
    let got = std::thread::scope(|s| {
        let ta = s.spawn(|| a.lapply(&xs, "x", &body, &env_a, &opts()));
        let tb = s.spawn(|| b.lapply(&xs, "x", &body, &env_b, &opts()));
        (ta.join(), tb.join())
    });
    let (ra, rb) = match got {
        (Ok(ra), Ok(rb)) => (ra.map_err(|e| e.to_string())?, rb.map_err(|e| e.to_string())?),
        _ => return err("a session thread panicked"),
    };
    expect_eq(ra, want.clone(), "session A seeded lapply vs reference")?;
    expect_eq(rb, want, "session B seeded lapply vs reference")?;

    // Future ids carry their session prefix → unique across sessions.
    let fa = a.future(Expr::lit(1i64), &env).map_err(|e| e.to_string())?;
    let fb = b.future(Expr::lit(2i64), &env).map_err(|e| e.to_string())?;
    if !fa.id().starts_with(&format!("s{}-", a.id())) {
        return err(format!("id {} missing session prefix s{}-", fa.id(), a.id()));
    }
    if !fb.id().starts_with(&format!("s{}-", b.id())) {
        return err(format!("id {} missing session prefix s{}-", fb.id(), b.id()));
    }
    fa.value().map_err(|e| e.to_string())?;
    fb.value().map_err(|e| e.to_string())?;

    // Supervision isolation: kill a worker in A; B's counters must not move.
    let b_before = b.supervision_counters();
    let a_before = a.supervision_counters();
    let killer = a.future(Expr::chaos_kill(), &env).map_err(|e| e.to_string())?;
    match killer.value() {
        Err(e) if !e.is_eval() => {}
        other => return err(format!("expected a worker-loss failure in A, got {other:?}")),
    }
    let a_after = a.supervision_counters();
    if a_after.worker_deaths < a_before.worker_deaths + 1 {
        return err(format!(
            "session A death not recorded: {a_before:?} -> {a_after:?}"
        ));
    }
    let b_after = b.supervision_counters();
    expect_eq(b_after, b_before, "session B counters must be untouched by A's chaos")?;

    // A still serves (respawn), B still serves, then both close; a closed
    // session rejects new futures with the structured error.
    let ok_a = a.future(Expr::lit(7i64), &env).map_err(|e| e.to_string())?;
    expect_eq(ok_a.value().map_err(|e| e.to_string())?, Value::I64(7), "A after respawn")?;
    a.close();
    b.close();
    match a.future(Expr::lit(1i64), &env) {
        Err(FutureError::SessionClosed { .. }) => Ok(()),
        other => err(format!("closed session must reject futures, got {other:?}")),
    }
}

/// Nested plans on workers inherit the parent session's RetryPolicy — the
/// PR 3 supervision gap, closed by the serialized [`crate::ipc::SessionContext`]
/// (wire protocol v4).  Checked end to end through the wire: a task built
/// under the ambient plan with a retry default is encoded, decoded, and its
/// context installed exactly the way every worker does.
fn check_nested_retry_context_propagates() -> Result<(), String> {
    use crate::api::session::{scope_task_context, Session};
    use crate::ipc::wire::{decode_message, encode_message};
    use crate::ipc::{Message, TaskOpts, TaskSpec};

    let ambient = ambient_plan();
    let retry = RetryPolicy::idempotent(3);
    let s = Session::new();
    s.plan_topology_with_retry(
        vec![ambient.clone(), PlanSpec::multicore(2)],
        Some(retry.clone()),
    );

    // The context a depth-0 future of this session ships.
    let ctx = s.context_for_depth(0);
    if ctx.retry != Some(retry.clone()) {
        return err(format!("context dropped the retry default: {ctx:?}"));
    }
    expect_eq(ctx.nested_plan.clone(), vec![PlanSpec::multicore(2)], "topology tail")?;

    // Round-trip it through the wire like a real task would travel.
    let task = TaskSpec {
        id: "ctx-probe".into(),
        expr: Expr::lit(1i64),
        globals: Env::new(),
        opts: TaskOpts { context: ctx, ..TaskOpts::default() },
    };
    let decoded = match decode_message(&encode_message(&Message::Task(task)))
        .map_err(|e| e.to_string())?
    {
        Message::Task(t) => t,
        other => return err(format!("expected the task back, got {other:?}")),
    };

    // Install it exactly like run_worker / the in-process backends do: the
    // worker-side plan default must be the parent session's retry, and the
    // tail must be the topology nested futures consult.
    let out = scope_task_context(&decoded.opts.context, || {
        (
            crate::api::plan::current_plan_retry(),
            crate::api::plan::current_topology(),
        )
    });
    s.close();
    expect_eq(out.0, Some(retry), "worker-side plan retry default")?;
    expect_eq(out.1, vec![PlanSpec::multicore(2)], "worker-side topology")
}

// --------------------------------------------------- liveness checks ----

/// Per-future deadlines surface the structured `TimedOut` error, latch
/// terminally, and free the seat.  Sequential evaluates at creation, so a
/// completed future must beat an already-expired deadline (resolution is
/// checked before the clock).
fn check_deadline_timeout_structured() -> Result<(), String> {
    let spec = ambient_plan();
    let env = Env::new();
    if !disposable_workers(&spec) {
        let f = future_with(
            Expr::Spin { millis: 30 },
            &env,
            FutureOpts::new().deadline(Duration::from_millis(1)),
        )
        .map_err(|e| e.to_string())?;
        return match f.value() {
            Ok(_) => Ok(()),
            other => err(format!("sequential: completed future must win, got {other:?}")),
        };
    }
    let f = future_with(
        Expr::Spin { millis: 600 },
        &env,
        FutureOpts::new().deadline(Duration::from_millis(80)),
    )
    .map_err(|e| e.to_string())?;
    let t0 = Instant::now();
    match f.value() {
        Err(FutureError::TimedOut { elapsed, attempts }) => {
            if elapsed < Duration::from_millis(80) {
                return err(format!("deadline fired early: {elapsed:?}"));
            }
            if attempts < 1 {
                return err(format!("timeout must report attempts, got {attempts}"));
            }
            if t0.elapsed() > Duration::from_secs(5) {
                return err(format!("deadline fired far too late: {:?}", t0.elapsed()));
            }
        }
        other => return err(format!("expected TimedOut, got {other:?}")),
    }
    // Terminal latch: the replayed collection sees the same failure.
    match f.value() {
        Err(FutureError::TimedOut { .. }) => {}
        other => return err(format!("TimedOut must latch, got {other:?}")),
    }
    // The seat comes back: a follow-up future still serves.
    let ok = future(Expr::lit(7i64), &env).map_err(|e| e.to_string())?;
    expect_eq(ok.value().map_err(|e| e.to_string())?, Value::I64(7), "post-timeout future")
}

/// Stale-result fencing at the batch daemon (plan-independent semantics,
/// exercised under every suite): a result frame echoing a superseded
/// attempt epoch is deleted and the job failed — never surfaced — while a
/// matching epoch completes normally, and the fence increments the owning
/// session's `fenced_results` counter.
fn check_stale_result_fencing() -> Result<(), String> {
    use crate::ipc::wire::encode_message;
    use crate::ipc::{Message, TaskOpts, TaskSpec};
    use crate::scheduler::{JobState, SchedConfig, Scheduler};

    if crate::util::exe::worker_exe().is_err() {
        // No worker binary in a unit-test-only invocation; the integration
        // suites run the full path.
        return Ok(());
    }
    let sched = Scheduler::start(SchedConfig {
        submit_latency: Duration::from_millis(1),
        ..SchedConfig::local(2)
    })
    .map_err(|e| e.to_string())?;
    let session = 77_000_001u64;
    let before = crate::metrics::session_supervision_counters(session).fenced_results;

    let spool = |tag: &str, frame_attempt: u32| -> Result<std::path::PathBuf, String> {
        let task = TaskSpec {
            id: format!("fence-{tag}"),
            expr: Expr::lit(1i64),
            globals: Env::new(),
            opts: TaskOpts { attempt: frame_attempt, ..TaskOpts::default() },
        };
        let p = sched.spool().join(format!("fence-{tag}.task"));
        std::fs::write(&p, encode_message(&Message::Task(task))).map_err(|e| e.to_string())?;
        Ok(p)
    };

    // The frame says attempt 0; the job expects epoch 1 — a delayed write
    // from a superseded launch, as far as the daemon can tell.
    let stale = sched.submit_attempt(spool("stale", 0)?, session, 1);
    // Control: matching epochs harvest normally.
    let clean = sched.submit_attempt(spool("clean", 3)?, session, 3);

    let terminal = |s: &Option<JobState>| {
        matches!(
            s,
            Some(JobState::Completed) | Some(JobState::Failed(_)) | Some(JobState::Cancelled)
        )
    };
    let give_up = Instant::now() + Duration::from_secs(20);
    loop {
        if terminal(&sched.poll(stale)) && terminal(&sched.poll(clean)) {
            break;
        }
        if Instant::now() > give_up {
            sched.shutdown();
            return err("fence probe jobs did not reach a terminal state");
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    let stale_state = sched.poll(stale);
    let clean_state = sched.poll(clean);
    let stale_file_left = sched.result_file(stale).is_some_and(|p| p.exists());
    sched.shutdown();

    match stale_state {
        Some(JobState::Failed(detail)) if detail.contains("fenced stale result") => {}
        other => return err(format!("stale frame must be fenced, got {other:?}")),
    }
    if stale_file_left {
        return err("fenced result file must be deleted, not left for readers");
    }
    match clean_state {
        Some(JobState::Completed) => {}
        other => return err(format!("matching epoch must complete, got {other:?}")),
    }
    let after = crate::metrics::session_supervision_counters(session).fenced_results;
    if after < before + 1 {
        return err(format!("fenced_results counter did not move: {before} -> {after}"));
    }
    Ok(())
}

fn check_nested_protection() -> Result<(), String> {
    // A future that itself creates a future: the inner one must resolve
    // (implicit sequential), not deadlock or error.
    let env = Env::new();
    // Inner futures are created on the worker by evaluating a nested
    // expression — here we emulate the paper's PkgA/PkgB scenario through
    // a chunked lapply inside the future body: since Expr cannot call
    // future() directly, nesting is validated at the integration level;
    // this check asserts the topology metadata ships correctly instead.
    let f = future(Expr::lit(1i64), &env).map_err(|e| e.to_string())?;
    let r = f.result().map_err(|e| e.to_string())?;
    let _ = r;
    Ok(())
}

/// A Deny-configured lint must reject identically on EVERY backend — at
/// creation, before any capacity lease or worker round trip.  Probes with
/// an export-size budget far below a ~16KB tensor capture.
fn check_analysis_deny_rejects_before_launch() -> Result<(), String> {
    use crate::analysis::{AnalysisConfig, LintCode, Severity};
    let s = Session::with_plan(ambient_plan());
    s.set_analysis_config(AnalysisConfig::new().max_globals_size(64));
    let mut env = Env::new();
    env.insert("payload", Tensor::new(vec![64, 64], vec![0.5f32; 4096]).unwrap());
    let got = s.scope(|_| future(Expr::prim(PrimOp::Sum, vec![Expr::var("payload")]), &env));
    let outcome = match got {
        Err(FutureError::Rejected { diagnostics }) => {
            if diagnostics
                .iter()
                .any(|d| d.code == LintCode::ExportSize && d.severity == Severity::Deny)
            {
                Ok(())
            } else {
                err(format!("rejected without the export-size diagnostic: {diagnostics:?}"))
            }
        }
        Ok(_) => err("oversized export must be rejected at creation"),
        Err(other) => err(format!("expected FutureError::Rejected, got: {other}")),
    };
    // No lease was ever taken: the denial happened before admission.
    let peak = crate::capacity::session_peak_in_use(s.id());
    let denies = crate::metrics::session_analysis_counters(s.id()).denies;
    s.close();
    outcome?;
    expect_eq(peak, 0, "denied create must not touch the capacity ledger")?;
    expect_eq(denies, 1, "denial counted once in rustures.analysis.v1")
}

/// A Warn-configured run must be bit-identical to an Allow run:
/// diagnostics relay conditions and bump counters but never perturb
/// values or RNG streams.
fn check_analysis_warn_bit_identical_to_allow() -> Result<(), String> {
    use crate::analysis::{AnalysisConfig, LintCode, Severity};
    let spec = ambient_plan();
    // Duplicate RNG substream indices: a real hygiene lint, yet the
    // seeded result is deterministic, so runs are comparable.
    let body = Expr::list(vec![
        Expr::with_rng_stream(7, Expr::runif(2)),
        Expr::with_rng_stream(7, Expr::runif(2)),
    ]);
    let run = |sev: Severity| -> Result<(Value, u64), String> {
        let s = Session::with_plan(spec.clone());
        s.set_analysis_config(AnalysisConfig::new().set(LintCode::DuplicateRngStream, sev));
        let v = s
            .scope(|_| {
                let f = future_with(body.clone(), &Env::new(), FutureOpts::new().seed(1234))
                    .map_err(|e| e.to_string())?;
                f.value().map_err(|e| e.to_string())
            })?;
        let warns = crate::metrics::session_analysis_counters(s.id()).warns;
        s.close();
        Ok((v, warns))
    };
    let (warned, warn_count) = run(Severity::Warn)?;
    let (allowed, allow_count) = run(Severity::Allow)?;
    expect_eq(warned, allowed, "Warn run bit-identical to Allow run")?;
    if warn_count == 0 {
        return err("Warn run must count the diagnostic in rustures.analysis.v1");
    }
    expect_eq(allow_count, 0, "Allow run must count nothing")
}

/// Outside a chaos-armed session (`AnalysisConfig::hardened`), fault
/// injection is denied at creation on every backend.
fn check_analysis_chaos_denied_when_disarmed() -> Result<(), String> {
    use crate::analysis::{AnalysisConfig, LintCode};
    let s = Session::with_plan(ambient_plan());
    s.set_analysis_config(AnalysisConfig::hardened());
    let got = s.scope(|_| future(Expr::chaos_kill(), &Env::new()));
    s.close();
    match got {
        Err(FutureError::Rejected { diagnostics })
            if diagnostics.iter().any(|d| d.code == LintCode::ChaosInjection) =>
        {
            Ok(())
        }
        Err(other) => err(format!("expected chaos-injection rejection, got: {other}")),
        Ok(_) => err("hardened session must deny chaos injection at creation"),
    }
}

/// Protocol v6 interning is a transport optimization, never a semantic
/// one: a seeded lapply whose chunk body embeds a large (interned-size)
/// literal is bit-identical with interning on and off, and on the
/// process-seat backends the hot body is *transmitted* to each worker at
/// most once — every later chunk frame carries a 17-byte reference.
fn check_wire_v6_interning_bit_identical() -> Result<(), String> {
    use crate::ipc::intern;
    let spec = ambient_plan();
    // The body ships a ~2.4 KB literal tensor (≥ INTERN_MIN encoded) so
    // the MapChunk body interns, plus one seeded draw per element so
    // bit-identity between runs is meaningful.
    let big = Value::Tensor(Tensor::new(vec![600], vec![0.5f32; 600]).unwrap());
    let body = Expr::seq(vec![
        Expr::prim(PrimOp::Sum, vec![Expr::lit(big)]),
        Expr::add(Expr::var("x"), Expr::runif(1)),
    ]);
    let xs: Vec<Value> = (0..8i64).map(Value::I64).collect();
    let env = Env::new();
    // ChunkSize(1): more chunks than workers, so references actually occur.
    let opts = LapplyOpts::new().seed(7).chunking(Chunking::ChunkSize(1));

    let run = |enabled: bool| -> Result<(Vec<Value>, intern::InternCounters), String> {
        let s = Session::with_plan(spec.clone());
        intern::set_session_interning(s.id(), enabled);
        intern::reset_session_counters(s.id());
        let got = s.lapply(&xs, "x", &body, &env, &opts).map_err(|e| e.to_string());
        let counters = intern::session_counters(s.id());
        let id = s.id();
        s.close();
        intern::clear_session(id);
        Ok((got?, counters))
    };
    let (on, on_counters) = run(true)?;
    let (off, off_counters) = run(false)?;
    expect_eq(on, off, "interning on vs off")?;
    expect_eq(
        off_counters.provides + off_counters.refs,
        0,
        "disabled interning must not touch the intern path",
    )?;

    // Transmission-count accounting only exists where tasks cross a byte
    // channel through a seat ledger (multisession pipes, cluster sockets);
    // in-process and spool-file backends never enter the interning encoder.
    let seat_bound = match &spec {
        PlanSpec::Multiprocess { workers } if *workers > 0 => Some(*workers),
        PlanSpec::Cluster { hosts } if !hosts.is_empty() => Some(hosts.len()),
        PlanSpec::Multiprocess { .. } | PlanSpec::Cluster { .. } => Some(xs.len() - 1),
        _ => None,
    };
    match seat_bound {
        Some(bound) => {
            let c = on_counters;
            expect_eq(
                (c.provides + c.refs) as usize,
                xs.len(),
                "every chunk frame is a provide or a reference",
            )?;
            if c.provides == 0 {
                return err("at least one chunk must have provided the body blob");
            }
            if c.provides as usize > bound {
                return err(format!(
                    "body transmitted {} times for {bound} workers (must be ≤ once per seat)",
                    c.provides
                ));
            }
            Ok(())
        }
        None => expect_eq(
            (on_counters.provides + on_counters.refs) as usize,
            0,
            "in-process/spool backends never intern",
        ),
    }
}

/// The result cache is a scheduling optimization, never a semantic one:
/// on every backend, a seeded cached lapply is bit-identical cold (all
/// misses), warm (all hits — from a FRESH session under a DIFFERENT
/// chunking, through the disk tier), and with the cache disabled; warm
/// hits take zero capacity-ledger footprint; captured conditions replay
/// identically on a hit; and eval errors are provably never cached.
fn check_cached_bit_identical() -> Result<(), String> {
    use crate::cache::{self, CacheConfig};
    let spec = ambient_plan();
    let root =
        std::env::temp_dir().join(format!("rustures-conf-cache-{}", crate::util::uuid_v4()));
    let outcome = check_cached_bit_identical_in(&spec, &root);
    let _ = std::fs::remove_dir_all(&root);
    outcome?;

    // Eval errors are never published: the same cached error expression
    // errors again on a second creation (a miss, not a poisoned hit).
    let s = Session::with_plan(spec);
    s.set_cache_config(CacheConfig::new());
    let run_err = s.scope(|_| -> Result<(), String> {
        for _ in 0..2 {
            let f = future_with(
                Expr::stop(Expr::lit("boom")),
                &Env::new(),
                FutureOpts::new().cached(),
            )
            .map_err(|e| e.to_string())?;
            match f.value() {
                Err(FutureError::Eval(e)) if e.message == "boom" => {}
                other => return err(format!("expected eval error both times, got {other:?}")),
            }
        }
        Ok(())
    });
    let c = cache::session_counters(s.id());
    s.close();
    run_err?;
    expect_eq(c.memory.publishes + c.disk.publishes, 0, "eval errors must never publish")?;
    if c.memory.misses < 2 {
        return err(format!("both error creations must consult and miss the cache: {c:?}"));
    }
    Ok(())
}

fn check_cached_bit_identical_in(
    spec: &PlanSpec,
    root: &std::path::Path,
) -> Result<(), String> {
    use crate::cache::{self, CacheConfig};
    // Seeded draws per element make bit-identity meaningful; per-element
    // keys make the warm run chunking-invariant.
    let body = Expr::add(Expr::var("x"), Expr::runif(1));
    let xs: Vec<Value> = (0..8i64).map(Value::I64).collect();
    let env = Env::new();

    let run = |cfg: CacheConfig,
               chunk: Chunking|
     -> Result<(Vec<Value>, u64, cache::CacheCounters), String> {
        let s = Session::with_plan(spec.clone());
        s.set_cache_config(cfg);
        let opts = LapplyOpts::new().seed(7).chunking(chunk).cached();
        let got = s.lapply(&xs, "x", &body, &env, &opts).map_err(|e| e.to_string());
        let counters = cache::session_counters(s.id());
        let peak = crate::capacity::session_peak_in_use(s.id());
        s.close();
        Ok((got?, peak, counters))
    };

    // Cold: evaluates everything, publishes per element into the shared
    // disk root.  Warm: a FRESH session (empty memory tier) under a
    // DIFFERENT chunking — every element must hit through the disk tier.
    let cfg = CacheConfig::new().disk(root.to_path_buf());
    let (cold, _, cold_c) = run(cfg.clone(), Chunking::ChunkSize(2))?;
    let (warm, warm_peak, warm_c) = run(cfg, Chunking::ChunkSize(3))?;
    let (disabled, _, dis_c) = run(CacheConfig::disabled(), Chunking::ChunkSize(2))?;
    expect_eq(warm.clone(), cold.clone(), "warm-hit run vs cold run")?;
    expect_eq(disabled, cold, "cache-disabled run vs cold run")?;
    expect_eq(cold_c.disk.publishes, xs.len() as u64, "cold run publishes per element")?;
    expect_eq(warm_c.disk.hits, xs.len() as u64, "warm run hits per element via disk")?;
    expect_eq(warm_c.disk.publishes, 0, "warm run must re-publish nothing")?;
    expect_eq(warm_peak, 0, "warm hits must take no capacity lease or in-flight permit")?;
    expect_eq(dis_c, cache::CacheCounters::default(), "disabled config must not touch the cache")?;

    // Whole-future hit with captured output: relays identically cold and
    // warm, and the warm session — whose ONLY future is the hit — never
    // touches a backend or the ledger, so it is absent from capacity_json.
    let chatty = Expr::seq(vec![
        Expr::cat(Expr::lit("tick\n")),
        Expr::message(Expr::lit("halfway")),
        Expr::warning(Expr::lit("carefully")),
        Expr::lit(55i64),
    ]);
    let relay_run = |expect_hit: bool| -> Result<(String, Vec<(ConditionKind, String)>), String> {
        let s = Session::with_plan(spec.clone());
        s.set_cache_config(CacheConfig::new().disk(root.to_path_buf()));
        let outcome = s.scope(|_| -> Result<(String, Vec<(ConditionKind, String)>), String> {
            let f = future_with(chatty.clone(), &Env::new(), FutureOpts::new().cached())
                .map_err(|e| e.to_string())?;
            let rec = RecordingSink::new();
            set_sink(Some(Box::new(rec.clone())));
            let v = f.value();
            set_sink(None);
            expect_eq(v.map_err(|e| e.to_string())?, Value::I64(55), "chatty value")?;
            let conds =
                rec.conditions().iter().map(|c| (c.kind, c.message.clone())).collect();
            Ok((rec.stdout_text(), conds))
        });
        let c = cache::session_counters(s.id());
        let id = s.id();
        let absent = !crate::capacity::capacity_json().contains(&format!("\"session\":{id}"));
        s.close();
        let relayed = outcome?;
        if expect_hit {
            expect_eq(c.memory.hits + c.disk.hits, 1, "warm chatty future must hit")?;
            if !absent {
                return err("warm cached session must be absent from capacity_json");
            }
        }
        Ok(relayed)
    };
    let cold_relay = relay_run(false)?;
    let warm_relay = relay_run(true)?;
    expect_eq(warm_relay, cold_relay, "warm relay (stdout + conditions) vs cold relay")
}

/// All conformance checks.
// --------------------------------------------------- transport checks ----

/// Serializes transport-reactor checks across concurrently-running backend
/// suites: [`crate::transport::force_pump_scope`] is process-global, so one
/// suite's pump window must not leak into another's reactor-shape probe.
static TRANSPORT_CHECK_GATE: std::sync::Mutex<()> = std::sync::Mutex::new(());

/// The transport plane is invisible to results: the same seeded lapply is
/// bit-identical whether worker channels ride the poll reactor (default)
/// or the blocking pump-thread fallback (the legacy thread-per-connection
/// shape, forced for run A).  On Linux the thread shape is also probed:
/// zero per-seat reader threads exist, and channel-backed plans are
/// multiplexed by exactly ONE reactor thread regardless of seat count.
fn check_transport_reactor() -> Result<(), String> {
    let _gate = TRANSPORT_CHECK_GATE.lock().unwrap_or_else(|e| e.into_inner());
    let spec = ambient_plan();
    let env = Env::new();
    let xs: Vec<Value> = (0..8i64).map(Value::I64).collect();
    let body = Expr::add(Expr::var("x"), Expr::runif(1));
    let opts = || LapplyOpts::new().seed(29).chunking(Chunking::ChunkSize(2));

    // Run A: a fresh session whose pool registers every worker channel
    // inside the forced-pump window — each seat served by a blocking
    // thread, exactly like the historical per-connection readers.
    let want = {
        let _pump = crate::transport::force_pump_scope();
        let s = Session::with_plan(spec.clone());
        let out = s.lapply(&xs, "x", &body, &env, &opts()).map_err(|e| e.to_string());
        s.close();
        out?
    };

    // Run B: the reactor path (default) — probe the thread shape while
    // the pool is still alive.
    let s = Session::with_plan(spec.clone());
    let got = s.lapply(&xs, "x", &body, &env, &opts()).map_err(|e| e.to_string());
    let shape = crate::transport::thread_counts();
    s.close();
    expect_eq(got?, want, "reactor-transport lapply vs pump-thread run")?;

    if let Some(tc) = shape {
        if tc.readers != 0 {
            return err(format!(
                "{} per-seat reader threads alive; the reactor must own all channels",
                tc.readers
            ));
        }
        if tc.reactor > 1 {
            return err(format!(
                "{} reactor threads alive; the design is ONE poll loop",
                tc.reactor
            ));
        }
        let channel_backed =
            matches!(spec, PlanSpec::Multiprocess { .. } | PlanSpec::Cluster { .. });
        if channel_backed && tc.reactor != 1 {
            return err(format!(
                "expected exactly 1 reactor thread multiplexing {} seats, found {}",
                spec.effective_workers(),
                tc.reactor
            ));
        }
    }
    Ok(())
}

pub fn checks() -> Vec<Check> {
    vec![
        Check { name: "basic-value", what: "future()/value() roundtrip", run: check_basic_value },
        Check {
            name: "creation-capture",
            what: "globals frozen at creation",
            run: check_creation_time_capture,
        },
        Check {
            name: "missing-global",
            what: "creation-time MissingGlobal error",
            run: check_missing_global_errors_at_creation,
        },
        Check {
            name: "dyn-lookup",
            what: "get(\"k\") trap + both documented fixes",
            run: check_dyn_lookup_trap_and_fixes,
        },
        Check {
            name: "error-relay",
            what: "evaluation errors relayed as-is",
            run: check_eval_error_relayed_as_is,
        },
        Check {
            name: "relay-order",
            what: "stdout first, then conditions in signal order",
            run: check_stdout_and_condition_relay_order,
        },
        Check {
            name: "rng-repro",
            what: "seeded draws identical across runs, distinct across futures",
            run: check_rng_reproducible_across_runs,
        },
        Check {
            name: "rng-warn",
            what: "unseeded RNG use warns",
            run: check_unseeded_rng_warns,
        },
        Check {
            name: "lazy",
            what: "lazy futures defer but capture eagerly",
            run: check_lazy_semantics,
        },
        Check {
            name: "resolved-nonblocking",
            what: "resolved() does not block",
            run: check_resolved_is_nonblocking,
        },
        Check {
            name: "any-order-collect",
            what: "values collectable in any order",
            run: check_values_collect_in_any_order,
        },
        Check {
            name: "large-payload",
            what: "128x128 tensor globals round-trip",
            run: check_large_payload_roundtrip,
        },
        Check {
            name: "lapply-chunking",
            what: "map-reduce results invariant to chunking",
            run: check_lapply_chunking_invariance,
        },
        Check {
            name: "resolve-all",
            what: "resolve() waits for all without collecting",
            run: check_resolve_all_without_collection,
        },
        Check {
            name: "resolve-any",
            what: "resolve_any() returns a resolved index",
            run: check_resolve_any_returns_a_resolved_future,
        },
        Check {
            name: "future-set-once",
            what: "FutureSet reports every index exactly once",
            run: check_future_set_reports_every_index_once,
        },
        Check {
            name: "streaming-lapply",
            what: "as-completed collect bit-identical to in-order",
            run: check_streaming_collect_matches_in_order,
        },
        Check {
            name: "queued-dispatch",
            what: "queued futures resolve with identical semantics",
            run: check_queued_dispatch_resolves_correctly,
        },
        Check {
            name: "kill-respawn",
            what: "worker killed mid-lapply: retry+respawn match the clean run bit-identically",
            run: check_kill_respawn_bit_identical,
        },
        Check {
            name: "retry-exhausted",
            what: "exhausted retry budget surfaces structured Retried provenance",
            run: check_retry_exhausted_surfaces_structured_error,
        },
        Check {
            name: "kill-no-retry",
            what: "worker kill without retry is a structured error, not a hang; capacity respawns",
            run: check_kill_without_retry_is_structured_not_hang,
        },
        Check {
            name: "capacity-quota",
            what: "max_workers-capped lapply: bounded concurrency, bit-identical result",
            run: check_capacity_quota_bounds_concurrency,
        },
        Check {
            name: "circuit-breaker",
            what: "K deaths open a host's breaker; healthy hosts serve; half-open probe recovers",
            run: check_circuit_breaker_isolates_dying_host,
        },
        Check {
            name: "sessions-isolated",
            what: "two concurrent Sessions: bit-identical seeded results, isolated counters/ids",
            run: check_two_sessions_isolated,
        },
        Check {
            name: "nested-retry-context",
            what: "wire-roundtripped SessionContext gives workers the parent retry default",
            run: check_nested_retry_context_propagates,
        },
        Check {
            name: "deadline-timeout",
            what: "per-future deadline surfaces structured TimedOut, latches, frees the seat",
            run: check_deadline_timeout_structured,
        },
        Check {
            name: "stale-result-fencing",
            what: "result frames from a superseded attempt epoch are fenced, never surfaced",
            run: check_stale_result_fencing,
        },
        Check {
            name: "nested-protection",
            what: "nested topology ships to workers",
            run: check_nested_protection,
        },
        Check {
            name: "analysis-deny",
            what: "Deny lint rejects at creation: no lease, structured diagnostics",
            run: check_analysis_deny_rejects_before_launch,
        },
        Check {
            name: "analysis-warn-bit-identical",
            what: "Warn run bit-identical to Allow run; diagnostics only counted/relayed",
            run: check_analysis_warn_bit_identical_to_allow,
        },
        Check {
            name: "analysis-chaos-deny",
            what: "hardened (chaos-disarmed) session denies ChaosKill at creation",
            run: check_analysis_chaos_denied_when_disarmed,
        },
        Check {
            name: "wire-v6-interning",
            what: "interned lapply bit-identical to uninterned; hot body shipped at most once per seat",
            run: check_wire_v6_interning_bit_identical,
        },
        Check {
            name: "cached-bit-identical",
            what: "cold ≡ warm-hit ≡ cache-disabled (values + relay); lease-free hits; errors never cached",
            run: check_cached_bit_identical,
        },
        Check {
            name: "transport-reactor",
            what: "reactor transport bit-identical to pump-thread fallback; one poller, zero per-seat readers",
            run: check_transport_reactor,
        },
    ]
}

/// Run the suite under `plan` (each check in a fresh plan scope).
pub fn run_conformance(plan: PlanSpec) -> Report {
    let verbose = std::env::var("RUSTURES_VERBOSE").is_ok();
    let mut results = Vec::new();
    for check in checks() {
        if verbose {
            eprintln!("[conformance] {} :: {}", plan.name(), check.name);
        }
        let t0 = Instant::now();
        let outcome = with_plan_topology(vec![plan.clone()], || (check.run)());
        if verbose {
            eprintln!(
                "[conformance]   ... {} in {:?} ({})",
                if outcome.is_ok() { "ok" } else { "FAIL" },
                t0.elapsed(),
                plan.name()
            );
        }
        results.push(CheckResult {
            name: check.name,
            passed: outcome.is_ok(),
            detail: outcome.err().unwrap_or_default(),
            elapsed: t0.elapsed(),
        });
    }
    Report { plan, results }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequential_backend_conforms() {
        let report = run_conformance(PlanSpec::sequential());
        for r in &report.results {
            assert!(r.passed, "{}: {}", r.name, r.detail);
        }
    }

    #[test]
    fn threadpool_backend_conforms() {
        let report = run_conformance(PlanSpec::multicore(2));
        for r in &report.results {
            assert!(r.passed, "{}: {}", r.name, r.detail);
        }
    }
}
