//! Per-frame compression codec (wire protocol v6).
//!
//! Stdlib-only, deterministic, and *stateless per frame*: every frame is
//! compressed independently, so a concatenation of frames compresses to the
//! concatenation of the per-frame outputs — the "linear under
//! concatenation" property borrowed from the tagger `.tags.zst` design
//! (WIRE.md §Codec is the normative description of this format).
//!
//! The transform is a lag-4 byte delta followed by run-length encoding.
//! f32 tensor payloads are 4-byte-periodic, so constant (or slowly varying)
//! tensors delta to long zero runs that RLE collapses; incompressible
//! payloads fall back to the raw codec via [`maybe_compress`], which only
//! selects compression when it is a strict byte win.

/// Codec byte for an uncompressed frame body.
pub const CODEC_RAW: u8 = 0;

/// Codec byte for a lag-4 delta + RLE compressed frame body.
pub const CODEC_DELTA_RLE: u8 = 1;

/// Frame bodies below this size are never compressed (the codec framing
/// overhead would dominate, and small frames are latency-sensitive).
pub const COMPRESS_MIN: usize = 1024;

/// The delta lag: f32 payloads repeat on a 4-byte period, so differencing
/// against the byte 4 positions back turns constant tensors into zeros.
const LAG: usize = 4;

/// Minimum run length worth a Run op (a run op costs >= 3 bytes).
const MIN_RUN: usize = 4;

fn push_varint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let b = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(b);
            break;
        }
        out.push(b | 0x80);
    }
}

fn read_varint(bytes: &[u8], pos: &mut usize) -> Result<u64, &'static str> {
    let mut out = 0u64;
    let mut shift = 0u32;
    loop {
        if *pos >= bytes.len() {
            return Err("truncated varint");
        }
        let b = bytes[*pos];
        *pos += 1;
        if shift >= 64 || (shift == 63 && (b & 0x7f) > 1) {
            return Err("varint overflow");
        }
        out |= u64::from(b & 0x7f) << shift;
        if b & 0x80 == 0 {
            return Ok(out);
        }
        shift += 7;
    }
}

/// Compress `raw` with the lag-4 delta + RLE codec.
///
/// Output layout: `varint raw_len` followed by ops until the deltas sum to
/// exactly `raw_len` bytes. Op 0 = `Run { varint len, byte }`, op 1 =
/// `Literal { varint len, bytes }`. Always succeeds; the output may be
/// larger than the input for incompressible data (see [`maybe_compress`]).
pub fn compress(raw: &[u8]) -> Vec<u8> {
    let mut delta = Vec::with_capacity(raw.len());
    for (i, &b) in raw.iter().enumerate() {
        let prev = if i >= LAG { raw[i - LAG] } else { 0 };
        delta.push(b.wrapping_sub(prev));
    }
    let mut out = Vec::with_capacity(raw.len() / 4 + 16);
    push_varint(&mut out, raw.len() as u64);
    let mut i = 0;
    let mut lit_start = 0;
    let flush_literal = |out: &mut Vec<u8>, lit: &[u8]| {
        if !lit.is_empty() {
            out.push(1);
            push_varint(out, lit.len() as u64);
            out.extend_from_slice(lit);
        }
    };
    while i < delta.len() {
        let b = delta[i];
        let mut j = i + 1;
        while j < delta.len() && delta[j] == b {
            j += 1;
        }
        let run = j - i;
        if run >= MIN_RUN {
            flush_literal(&mut out, &delta[lit_start..i]);
            out.push(0);
            push_varint(&mut out, run as u64);
            out.push(b);
            lit_start = j;
        }
        i = j;
    }
    flush_literal(&mut out, &delta[lit_start..]);
    out
}

/// Decompress a [`compress`] stream, rejecting malformed input.
///
/// `max_len` bounds the claimed raw length (callers pass the frame-size
/// cap) so a tiny corrupt frame cannot demand an unbounded allocation.
/// Every failure mode — truncated varints, unknown ops, ops that overrun
/// or undershoot the declared length — is a clean `Err`, never a panic.
pub fn decompress(bytes: &[u8], max_len: usize) -> Result<Vec<u8>, &'static str> {
    let mut pos = 0;
    let raw_len = read_varint(bytes, &mut pos)?;
    if raw_len > max_len as u64 {
        return Err("declared length exceeds frame cap");
    }
    let raw_len = raw_len as usize;
    // Grow as ops arrive instead of trusting raw_len for the allocation.
    let mut delta = Vec::with_capacity(raw_len.min(bytes.len().saturating_mul(8)));
    while pos < bytes.len() {
        let op = bytes[pos];
        pos += 1;
        let n = read_varint(bytes, &mut pos)? as usize;
        if delta.len() + n > raw_len {
            return Err("ops overrun declared length");
        }
        match op {
            0 => {
                if pos >= bytes.len() {
                    return Err("truncated run byte");
                }
                let b = bytes[pos];
                pos += 1;
                delta.resize(delta.len() + n, b);
            }
            1 => {
                if pos + n > bytes.len() {
                    return Err("truncated literal");
                }
                delta.extend_from_slice(&bytes[pos..pos + n]);
                pos += n;
            }
            _ => return Err("unknown codec op"),
        }
    }
    if delta.len() != raw_len {
        return Err("ops undershoot declared length");
    }
    // Undo the lag-4 delta in place: positions < LAG are stored raw.
    let mut raw = delta;
    for i in LAG..raw.len() {
        raw[i] = raw[i].wrapping_add(raw[i - LAG]);
    }
    Ok(raw)
}

/// Pick the codec for a frame body: compress when the body is at least
/// [`COMPRESS_MIN`] bytes *and* compression is a strict byte win, else ship
/// raw. Deterministic, so encode → decode → encode is bit-stable.
pub fn maybe_compress(body: Vec<u8>) -> (u8, Vec<u8>) {
    if body.len() >= COMPRESS_MIN {
        let c = compress(&body);
        if c.len() < body.len() {
            return (CODEC_DELTA_RLE, c);
        }
    }
    (CODEC_RAW, body)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::uuid::splitmix64;

    fn roundtrip(raw: &[u8]) {
        let c = compress(raw);
        let back = decompress(&c, raw.len().max(1)).unwrap();
        assert_eq!(back, raw);
    }

    #[test]
    fn roundtrips_edge_cases() {
        roundtrip(&[]);
        roundtrip(&[7]);
        roundtrip(&[1, 2, 3]);
        roundtrip(&[0; 4]);
        roundtrip(b"hello world, hello world, hello world");
    }

    #[test]
    fn zeros_compress_to_under_one_percent() {
        let raw = vec![0u8; 1 << 20];
        let c = compress(&raw);
        assert!(c.len() < raw.len() / 100, "{} bytes for 1MiB of zeros", c.len());
        assert_eq!(decompress(&c, raw.len()).unwrap(), raw);
    }

    #[test]
    fn constant_f32_pattern_compresses() {
        // A constant non-zero tensor: every 4-byte group identical, so the
        // lag-4 delta is zero everywhere past the first word.
        let word = 1.5f32.to_le_bytes();
        let raw: Vec<u8> = word.iter().copied().cycle().take(1 << 16).collect();
        let c = compress(&raw);
        assert!(c.len() < raw.len() / 50, "{} bytes", c.len());
        assert_eq!(decompress(&c, raw.len()).unwrap(), raw);
    }

    #[test]
    fn pseudorandom_bytes_roundtrip_and_fall_back_raw() {
        let raw: Vec<u8> = (0..4096u64).map(|i| splitmix64(i) as u8).collect();
        roundtrip(&raw);
        let (codec, body) = maybe_compress(raw.clone());
        assert_eq!(codec, CODEC_RAW, "incompressible data must ship raw");
        assert_eq!(body, raw);
    }

    #[test]
    fn maybe_compress_thresholds() {
        let small = vec![0u8; COMPRESS_MIN - 1];
        assert_eq!(maybe_compress(small.clone()), (CODEC_RAW, small));
        let (codec, body) = maybe_compress(vec![0u8; COMPRESS_MIN]);
        assert_eq!(codec, CODEC_DELTA_RLE);
        assert!(body.len() < COMPRESS_MIN);
    }

    #[test]
    fn linear_under_concatenation() {
        // Compressing two frames independently and concatenating the
        // outputs decodes to the concatenation of the inputs: no codec
        // state leaks across frames.
        let a = vec![3u8; 2048];
        let b: Vec<u8> = (0..2048u64).map(|i| splitmix64(i ^ 9) as u8).collect();
        let ca = compress(&a);
        let cb = compress(&b);
        let da = decompress(&ca, a.len()).unwrap();
        let db = decompress(&cb, b.len()).unwrap();
        let mut joined = da;
        joined.extend_from_slice(&db);
        let mut want = a;
        want.extend_from_slice(&b);
        assert_eq!(joined, want);
    }

    #[test]
    fn malformed_streams_rejected() {
        // Truncated varint.
        assert!(decompress(&[0x80], 1024).is_err());
        // Claimed length over the cap.
        let mut big = Vec::new();
        push_varint(&mut big, 1 << 40);
        assert!(decompress(&big, 1024).is_err());
        // Unknown op.
        assert!(decompress(&[4, 9, 1, 0], 1024).is_err());
        // Run overruns declared length.
        assert!(decompress(&[2, 0, 200, 0], 1024).is_err());
        // Truncated literal.
        assert!(decompress(&[8, 1, 8, 1, 2], 1024).is_err());
        // Ops undershoot declared length.
        assert!(decompress(&[8, 1, 2, 1, 2], 1024).is_err());
        // Truncated run byte.
        assert!(decompress(&[8, 0, 8], 1024).is_err());
    }
}
