//! v6 frame I/O over any byte transport.
//!
//! Frame = `magic "RF" + version + kind + codec + varint body length +
//! body` ([`wire`]-encoded [`Message`]; WIRE.md §Framing is normative).
//! Used identically over child-process pipes (multisession), TCP sockets
//! (cluster), batch spool files, and in tests over in-memory buffers.

use std::io::{Read, Write};

use crate::api::error::FutureError;
use crate::ipc::wire::{self, encode_message};
use crate::ipc::{Message, PROTOCOL_VERSION};

/// Maximum accepted frame body (guards against corrupt length prefixes).
pub const MAX_FRAME: u32 = 1 << 30; // 1 GiB

/// One frame as read off a stream, header parsed but body not yet decoded.
/// Stream readers that need the kind byte before decoding (the worker's
/// `NeedBlob` recovery loop) consume this; everyone else uses
/// [`read_message`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RawFrame {
    /// Frame kind byte ([`wire::FRAME_KIND_TABLE`]).
    pub kind: u8,
    /// Codec byte ([`wire::CODEC_TABLE`]).
    pub codec: u8,
    /// The (possibly compressed) frame body bytes.
    pub body: Vec<u8>,
}

/// Write one message as a complete v6 frame and flush.
pub fn write_message<W: Write>(w: &mut W, msg: &Message) -> Result<(), FutureError> {
    let frame = encode_message(msg);
    w.write_all(&frame)
        .and_then(|_| w.flush())
        .map_err(|e| FutureError::Channel(format!("write failed: {e}")))
}

/// Read one frame header + body, blocking. `Ok(None)` = clean EOF at a
/// frame boundary; EOF mid-frame, bad magic, a version mismatch, or a body
/// length over [`MAX_FRAME`] are channel errors.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Option<RawFrame>, FutureError> {
    // EOF before any header byte is a clean close; mid-header EOF is not.
    let mut first = [0u8; 1];
    match r.read(&mut first) {
        Ok(0) => return Ok(None),
        Ok(_) => {}
        Err(e) => return Err(FutureError::Channel(format!("read failed: {e}"))),
    }
    let mut rest = [0u8; 4];
    r.read_exact(&mut rest)
        .map_err(|e| FutureError::Channel(format!("truncated frame header: {e}")))?;
    if [first[0], rest[0]] != wire::MAGIC {
        return Err(FutureError::Channel(format!(
            "bad frame magic {:02x}{:02x}",
            first[0], rest[0]
        )));
    }
    let version = rest[1];
    if version != PROTOCOL_VERSION as u8 {
        return Err(FutureError::Channel(format!(
            "protocol version {version} (this build speaks {PROTOCOL_VERSION})"
        )));
    }
    let kind = rest[2];
    let codec = rest[3];
    // Byte-at-a-time varint body length with a 64-bit overflow guard.
    let mut len: u64 = 0;
    let mut shift: u32 = 0;
    loop {
        let mut b = [0u8; 1];
        r.read_exact(&mut b)
            .map_err(|e| FutureError::Channel(format!("truncated frame length: {e}")))?;
        let b = b[0];
        if shift >= 64 || (shift == 63 && (b & 0x7f) > 1) {
            return Err(FutureError::Channel("frame length varint overflow".into()));
        }
        len |= u64::from(b & 0x7f) << shift;
        if b & 0x80 == 0 {
            break;
        }
        shift += 7;
    }
    if len > u64::from(MAX_FRAME) {
        return Err(FutureError::Channel(format!("frame too large: {len} bytes")));
    }
    let mut body = vec![0u8; len as usize];
    r.read_exact(&mut body)
        .map_err(|e| FutureError::Channel(format!("truncated frame body: {e}")))?;
    Ok(Some(RawFrame { kind, codec, body }))
}

/// Read one frame and decode its message (no intern cache — interned
/// references from prior frames will fail; workers that participate in
/// interning use [`read_frame`] + [`wire::decode_frame_body`] with their
/// cache). `Ok(None)` = clean EOF at a frame boundary.
pub fn read_message<R: Read>(r: &mut R) -> Result<Option<Message>, FutureError> {
    match read_frame(r)? {
        None => Ok(None),
        Some(f) => wire::decode_frame_body(f.kind, f.codec, &f.body, None)
            .map(Some)
            .map_err(|e| FutureError::Channel(format!("bad frame: {e}"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn roundtrip_over_buffer() {
        let mut buf = Vec::new();
        write_message(&mut buf, &Message::Ping).unwrap();
        write_message(&mut buf, &Message::Shutdown).unwrap();
        let mut cur = Cursor::new(buf);
        assert_eq!(read_message(&mut cur).unwrap(), Some(Message::Ping));
        assert_eq!(read_message(&mut cur).unwrap(), Some(Message::Shutdown));
        assert_eq!(read_message(&mut cur).unwrap(), None); // clean EOF
    }

    #[test]
    fn truncated_body_is_channel_error() {
        let mut buf = Vec::new();
        write_message(&mut buf, &Message::Hello { worker_id: "w".into(), version: 1 }).unwrap();
        buf.truncate(buf.len() - 2);
        let mut cur = Cursor::new(buf);
        assert!(matches!(read_message(&mut cur), Err(FutureError::Channel(_))));
    }

    #[test]
    fn oversized_length_rejected() {
        // Hand-built v6 header claiming a body one byte over the cap.
        let mut buf = Vec::from(wire::MAGIC);
        buf.push(PROTOCOL_VERSION as u8);
        buf.push(5); // Ping kind
        buf.push(0); // raw codec
        let mut len = u64::from(MAX_FRAME) + 1;
        loop {
            let b = (len & 0x7f) as u8;
            len >>= 7;
            if len == 0 {
                buf.push(b);
                break;
            }
            buf.push(b | 0x80);
        }
        let mut cur = Cursor::new(buf);
        assert!(matches!(read_message(&mut cur), Err(FutureError::Channel(_))));
    }

    #[test]
    fn wrong_version_is_channel_error() {
        let mut buf = Vec::new();
        write_message(&mut buf, &Message::Ping).unwrap();
        buf[2] = 5; // a v5 peer
        let mut cur = Cursor::new(buf);
        assert!(matches!(read_message(&mut cur), Err(FutureError::Channel(_))));
    }
}
