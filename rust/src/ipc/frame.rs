//! v6 frame I/O over any byte transport.
//!
//! Frame = `magic "RF" + version + kind + codec + varint body length +
//! body` ([`wire`]-encoded [`Message`]; WIRE.md §Framing is normative).
//! Used identically over child-process pipes (multisession), TCP sockets
//! (cluster), batch spool files, and in tests over in-memory buffers.

use std::io::{Read, Write};

use crate::api::error::FutureError;
use crate::ipc::wire::{self, encode_message};
use crate::ipc::{Message, PROTOCOL_VERSION};

/// Maximum accepted frame body (guards against corrupt length prefixes).
pub const MAX_FRAME: u32 = 1 << 30; // 1 GiB

/// One frame as read off a stream, header parsed but body not yet decoded.
/// Stream readers that need the kind byte before decoding (the worker's
/// `NeedBlob` recovery loop) consume this; everyone else uses
/// [`read_message`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RawFrame {
    /// Frame kind byte ([`wire::FRAME_KIND_TABLE`]).
    pub kind: u8,
    /// Codec byte ([`wire::CODEC_TABLE`]).
    pub codec: u8,
    /// The (possibly compressed) frame body bytes.
    pub body: Vec<u8>,
}

/// Write one message as a complete v6 frame and flush.
pub fn write_message<W: Write>(w: &mut W, msg: &Message) -> Result<(), FutureError> {
    let frame = encode_message(msg);
    w.write_all(&frame)
        .and_then(|_| w.flush())
        .map_err(|e| FutureError::Channel(format!("write failed: {e}")))
}

/// Read one frame header + body, blocking. `Ok(None)` = clean EOF at a
/// frame boundary; EOF mid-frame, bad magic, a version mismatch, or a body
/// length over [`MAX_FRAME`] are channel errors.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Option<RawFrame>, FutureError> {
    // EOF before any header byte is a clean close; mid-header EOF is not.
    let mut first = [0u8; 1];
    match r.read(&mut first) {
        Ok(0) => return Ok(None),
        Ok(_) => {}
        Err(e) => return Err(FutureError::Channel(format!("read failed: {e}"))),
    }
    let mut rest = [0u8; 4];
    r.read_exact(&mut rest)
        .map_err(|e| FutureError::Channel(format!("truncated frame header: {e}")))?;
    if [first[0], rest[0]] != wire::MAGIC {
        return Err(FutureError::Channel(format!(
            "bad frame magic {:02x}{:02x}",
            first[0], rest[0]
        )));
    }
    let version = rest[1];
    if version != PROTOCOL_VERSION as u8 {
        return Err(FutureError::Channel(format!(
            "protocol version {version} (this build speaks {PROTOCOL_VERSION})"
        )));
    }
    let kind = rest[2];
    let codec = rest[3];
    // Byte-at-a-time varint body length with a 64-bit overflow guard.
    let mut len: u64 = 0;
    let mut shift: u32 = 0;
    loop {
        let mut b = [0u8; 1];
        r.read_exact(&mut b)
            .map_err(|e| FutureError::Channel(format!("truncated frame length: {e}")))?;
        let b = b[0];
        if shift >= 64 || (shift == 63 && (b & 0x7f) > 1) {
            return Err(FutureError::Channel("frame length varint overflow".into()));
        }
        len |= u64::from(b & 0x7f) << shift;
        if b & 0x80 == 0 {
            break;
        }
        shift += 7;
    }
    if len > u64::from(MAX_FRAME) {
        return Err(FutureError::Channel(format!("frame too large: {len} bytes")));
    }
    let mut body = vec![0u8; len as usize];
    r.read_exact(&mut body)
        .map_err(|e| FutureError::Channel(format!("truncated frame body: {e}")))?;
    Ok(Some(RawFrame { kind, codec, body }))
}

/// Try to split one complete frame off the front of `buf` without
/// blocking — the incremental twin of [`read_frame`] for nonblocking
/// transports (the [`crate::transport`] reactor accumulates socket/pipe
/// bytes in a per-channel buffer and calls this until it returns
/// `Ok(None)`).
///
/// Returns `Ok(Some((frame, consumed)))` when `buf[..consumed]` held a
/// complete frame, `Ok(None)` when more bytes are needed, and an error on
/// bad magic / version mismatch / oversized length — the same validation
/// (and error text) as the blocking reader, so both paths classify
/// corruption identically.
pub fn try_split_frame(buf: &[u8]) -> Result<Option<(RawFrame, usize)>, FutureError> {
    if buf.len() < 2 {
        return Ok(None);
    }
    if buf[..2] != wire::MAGIC {
        return Err(FutureError::Channel(format!(
            "bad frame magic {:02x}{:02x}",
            buf[0], buf[1]
        )));
    }
    if buf.len() < 5 {
        return Ok(None);
    }
    let version = buf[2];
    if version != PROTOCOL_VERSION as u8 {
        return Err(FutureError::Channel(format!(
            "protocol version {version} (this build speaks {PROTOCOL_VERSION})"
        )));
    }
    let kind = buf[3];
    let codec = buf[4];
    // Varint body length with the same 64-bit overflow guard as read_frame.
    let mut len: u64 = 0;
    let mut shift: u32 = 0;
    let mut at = 5usize;
    loop {
        let Some(&b) = buf.get(at) else { return Ok(None) };
        at += 1;
        if shift >= 64 || (shift == 63 && (b & 0x7f) > 1) {
            return Err(FutureError::Channel("frame length varint overflow".into()));
        }
        len |= u64::from(b & 0x7f) << shift;
        if b & 0x80 == 0 {
            break;
        }
        shift += 7;
    }
    if len > u64::from(MAX_FRAME) {
        return Err(FutureError::Channel(format!("frame too large: {len} bytes")));
    }
    let len = len as usize;
    if buf.len() < at + len {
        return Ok(None);
    }
    let body = buf[at..at + len].to_vec();
    Ok(Some((RawFrame { kind, codec, body }, at + len)))
}

/// Read one frame and decode its message (no intern cache — interned
/// references from prior frames will fail; workers that participate in
/// interning use [`read_frame`] + [`wire::decode_frame_body`] with their
/// cache). `Ok(None)` = clean EOF at a frame boundary.
pub fn read_message<R: Read>(r: &mut R) -> Result<Option<Message>, FutureError> {
    match read_frame(r)? {
        None => Ok(None),
        Some(f) => wire::decode_frame_body(f.kind, f.codec, &f.body, None)
            .map(Some)
            .map_err(|e| FutureError::Channel(format!("bad frame: {e}"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn roundtrip_over_buffer() {
        let mut buf = Vec::new();
        write_message(&mut buf, &Message::Ping).unwrap();
        write_message(&mut buf, &Message::Shutdown).unwrap();
        let mut cur = Cursor::new(buf);
        assert_eq!(read_message(&mut cur).unwrap(), Some(Message::Ping));
        assert_eq!(read_message(&mut cur).unwrap(), Some(Message::Shutdown));
        assert_eq!(read_message(&mut cur).unwrap(), None); // clean EOF
    }

    #[test]
    fn truncated_body_is_channel_error() {
        let mut buf = Vec::new();
        write_message(&mut buf, &Message::Hello { worker_id: "w".into(), version: 1 }).unwrap();
        buf.truncate(buf.len() - 2);
        let mut cur = Cursor::new(buf);
        assert!(matches!(read_message(&mut cur), Err(FutureError::Channel(_))));
    }

    #[test]
    fn oversized_length_rejected() {
        // Hand-built v6 header claiming a body one byte over the cap.
        let mut buf = Vec::from(wire::MAGIC);
        buf.push(PROTOCOL_VERSION as u8);
        buf.push(5); // Ping kind
        buf.push(0); // raw codec
        let mut len = u64::from(MAX_FRAME) + 1;
        loop {
            let b = (len & 0x7f) as u8;
            len >>= 7;
            if len == 0 {
                buf.push(b);
                break;
            }
            buf.push(b | 0x80);
        }
        let mut cur = Cursor::new(buf);
        assert!(matches!(read_message(&mut cur), Err(FutureError::Channel(_))));
    }

    #[test]
    fn try_split_frame_matches_blocking_reader() {
        let mut buf = Vec::new();
        write_message(&mut buf, &Message::Ping).unwrap();
        write_message(&mut buf, &Message::Shutdown).unwrap();
        let (f1, n1) = try_split_frame(&buf).unwrap().unwrap();
        // Every strict prefix of the first frame is "need more bytes".
        for cut in 0..n1 {
            assert_eq!(try_split_frame(&buf[..cut]).unwrap(), None, "prefix {cut}");
        }
        let m1 = wire::decode_frame_body(f1.kind, f1.codec, &f1.body, None).unwrap();
        assert_eq!(m1, Message::Ping);
        let (f2, n2) = try_split_frame(&buf[n1..]).unwrap().unwrap();
        let m2 = wire::decode_frame_body(f2.kind, f2.codec, &f2.body, None).unwrap();
        assert_eq!(m2, Message::Shutdown);
        assert_eq!(n1 + n2, buf.len());
        assert_eq!(try_split_frame(&[]).unwrap(), None);
    }

    #[test]
    fn try_split_frame_rejects_bad_magic_and_version() {
        let mut buf = Vec::new();
        write_message(&mut buf, &Message::Ping).unwrap();
        let mut bad = buf.clone();
        bad[0] = b'X';
        assert!(matches!(try_split_frame(&bad), Err(FutureError::Channel(_))));
        let mut old = buf;
        old[2] = 5; // a v5 peer
        assert!(matches!(try_split_frame(&old), Err(FutureError::Channel(_))));
    }

    #[test]
    fn wrong_version_is_channel_error() {
        let mut buf = Vec::new();
        write_message(&mut buf, &Message::Ping).unwrap();
        buf[2] = 5; // a v5 peer
        let mut cur = Cursor::new(buf);
        assert!(matches!(read_message(&mut cur), Err(FutureError::Channel(_))));
    }
}
