//! Length-prefixed message framing over any byte transport.
//!
//! Frame = `u32 LE length` + payload ([`wire`]-encoded [`Message`]).
//! Used identically over child-process pipes (multisession), TCP sockets
//! (cluster), and in tests over in-memory buffers.

use std::io::{Read, Write};

use crate::api::error::FutureError;
use crate::ipc::wire::{decode_message, encode_message};
use crate::ipc::Message;

/// Maximum accepted frame (guards against corrupt length prefixes).
pub const MAX_FRAME: u32 = 1 << 30; // 1 GiB

/// Write one message as a frame and flush.
pub fn write_message<W: Write>(w: &mut W, msg: &Message) -> Result<(), FutureError> {
    let payload = encode_message(msg);
    let len = payload.len() as u32;
    w.write_all(&len.to_le_bytes())
        .and_then(|_| w.write_all(&payload))
        .and_then(|_| w.flush())
        .map_err(|e| FutureError::Channel(format!("write failed: {e}")))
}

/// Read one frame, blocking.  `Ok(None)` = clean EOF at a frame boundary.
pub fn read_message<R: Read>(r: &mut R) -> Result<Option<Message>, FutureError> {
    let mut len_buf = [0u8; 4];
    // EOF before any length byte is a clean close; mid-prefix EOF is not.
    match r.read(&mut len_buf) {
        Ok(0) => return Ok(None),
        Ok(n) if n < 4 => {
            r.read_exact(&mut len_buf[n..])
                .map_err(|e| FutureError::Channel(format!("truncated frame length: {e}")))?;
        }
        Ok(_) => {}
        Err(e) => return Err(FutureError::Channel(format!("read failed: {e}"))),
    }
    let len = u32::from_le_bytes(len_buf);
    if len > MAX_FRAME {
        return Err(FutureError::Channel(format!("frame too large: {len} bytes")));
    }
    let mut payload = vec![0u8; len as usize];
    r.read_exact(&mut payload)
        .map_err(|e| FutureError::Channel(format!("truncated frame body: {e}")))?;
    let msg = decode_message(&payload)
        .map_err(|e| FutureError::Channel(format!("bad frame: {e}")))?;
    Ok(Some(msg))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn roundtrip_over_buffer() {
        let mut buf = Vec::new();
        write_message(&mut buf, &Message::Ping).unwrap();
        write_message(&mut buf, &Message::Shutdown).unwrap();
        let mut cur = Cursor::new(buf);
        assert_eq!(read_message(&mut cur).unwrap(), Some(Message::Ping));
        assert_eq!(read_message(&mut cur).unwrap(), Some(Message::Shutdown));
        assert_eq!(read_message(&mut cur).unwrap(), None); // clean EOF
    }

    #[test]
    fn truncated_body_is_channel_error() {
        let mut buf = Vec::new();
        write_message(&mut buf, &Message::Hello { worker_id: "w".into(), version: 1 }).unwrap();
        buf.truncate(buf.len() - 2);
        let mut cur = Cursor::new(buf);
        assert!(matches!(read_message(&mut cur), Err(FutureError::Channel(_))));
    }

    #[test]
    fn oversized_length_rejected() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&(MAX_FRAME + 1).to_le_bytes());
        let mut cur = Cursor::new(buf);
        assert!(matches!(read_message(&mut cur), Err(FutureError::Channel(_))));
    }
}
