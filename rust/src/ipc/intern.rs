//! Content-hashed global interning (wire protocol v6).
//!
//! The dominant per-future serialization cost the paper attributes to
//! `serialize()` round trips is *re-sending the same captured globals with
//! every task*. v6 fixes that: large captured globals and hot `MapChunk`
//! bodies are addressed by a 128-bit content [`Digest`]; a task frame
//! carries the full blob bytes only the first time a given worker sees a
//! digest, and a 17-byte reference afterwards.
//!
//! Three cooperating structures (WIRE.md §Interning is normative):
//!
//! * [`SeatLedger`] — coordinator-side, one per worker seat: a bounded
//!   FIFO set of digests this seat has been *provided*. Decides
//!   provide-vs-reference at encode time.
//! * [`InternCache`] — worker-side mirror: digest → decoded blob, same
//!   capacity and FIFO policy, populated by the provide entries in task
//!   frames (and by `NeedBlob`/`Blob` recovery on a miss).
//! * The process-global *intern store* — digest → encoded blob bytes, so
//!   the coordinator can answer a worker's `NeedBlob` without re-encoding.
//!
//! The ledger and cache stay in lockstep because provides are inserted in
//! identical encounter order on both sides with the same capacity; the
//! mirror is *approximate*, not load-bearing — any drift (a frame that was
//! encoded but never delivered, an eviction skew after a `NeedBlob`
//! install) degrades to an extra `NeedBlob` round trip, never to a wrong
//! result.
//!
//! Interning is per-session togglable ([`set_session_interning`], default
//! on) and observable via [`session_counters`]; results are bit-identical
//! either way, which the `wire-v6-interning` conformance check enforces on
//! every backend.

use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::api::expr::Expr;
use crate::api::value::Value;

/// Minimum *encoded* size (bytes) for a captured global or chunk body to
/// be interned. Below this, inline encoding is cheaper than the digest +
/// cache bookkeeping.
pub const INTERN_MIN: usize = 1024;

/// Default capacity of each [`SeatLedger`] / [`InternCache`] pair
/// (overridable with `RUSTURES_INTERN_CAP`).
pub const DEFAULT_INTERN_CAP: usize = 64;

/// Default capacity of the process-global intern store (overridable with
/// `RUSTURES_INTERN_STORE_CAP`).
const DEFAULT_STORE_CAP: usize = 256;

fn env_cap(var: &str, default: usize) -> usize {
    std::env::var(var)
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok())
        .unwrap_or(default)
        .max(1)
}

/// Effective per-seat intern capacity (`RUSTURES_INTERN_CAP`, min 1).
pub fn intern_cap() -> usize {
    env_cap("RUSTURES_INTERN_CAP", DEFAULT_INTERN_CAP)
}

// ---------------------------------------------------------------- digest --

/// 128-bit content digest of an interned blob: two independent FNV-1a-64
/// passes over the canonical content stream (WIRE.md §Digest). Not
/// cryptographic — it keys an in-process cache, where 128 bits of a good
/// mixing hash make accidental collision negligible.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Digest(pub [u8; 16]);

impl std::fmt::Display for Digest {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        for b in self.0 {
            write!(f, "{b:02x}")?;
        }
        Ok(())
    }
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Two-lane FNV-1a streaming hasher; lane B perturbs each input byte so
/// the lanes decorrelate without a second pass over the data.
struct Fnv2 {
    a: u64,
    b: u64,
}

impl Fnv2 {
    fn new() -> Self {
        Fnv2 { a: FNV_OFFSET, b: FNV_OFFSET ^ 0x9e37_79b9_7f4a_7c15 }
    }

    fn update(&mut self, bytes: &[u8]) {
        for &x in bytes {
            self.a = (self.a ^ u64::from(x)).wrapping_mul(FNV_PRIME);
            self.b = (self.b ^ u64::from(x ^ 0xa5)).wrapping_mul(FNV_PRIME);
        }
    }

    fn finish(self) -> Digest {
        let mut out = [0u8; 16];
        out[..8].copy_from_slice(&self.a.to_le_bytes());
        out[8..].copy_from_slice(&self.b.to_le_bytes());
        Digest(out)
    }
}

/// Digest of arbitrary bytes (used for expression blobs, which are hashed
/// over their encoded form).
pub fn digest_bytes(bytes: &[u8]) -> Digest {
    let mut h = Fnv2::new();
    h.update(bytes);
    h.finish()
}

/// Structural digest of a [`Value`] — streams the content (tags, lengths,
/// payload bytes) through the hasher without materializing an encoding, so
/// reference-only sends of a 1MB tensor cost a hash pass, not an encode.
/// Domain-separated from [`digest_bytes`] expression blobs by the leading
/// kind byte (0 = value; expression blob bytes start with 1).
pub fn digest_value(v: &Value) -> Digest {
    let mut h = Fnv2::new();
    h.update(&[0]);
    hash_value(&mut h, v);
    h.finish()
}

/// Digest of a result-cache key frame (see [`crate::cache`]): the canonical
/// task-identity bytes hashed under a dedicated domain — the leading kind
/// byte 2 keeps cache keys disjoint from [`digest_value`] content digests
/// (0) and expression blobs (1), so a cache object name can never collide
/// with an interned blob digest.
pub fn digest_cache_key(bytes: &[u8]) -> Digest {
    let mut h = Fnv2::new();
    h.update(&[2]);
    h.update(bytes);
    h.finish()
}

fn hash_value(h: &mut Fnv2, v: &Value) {
    match v {
        Value::Unit => h.update(&[0]),
        Value::Bool(b) => h.update(&[1, u8::from(*b)]),
        Value::I64(x) => {
            h.update(&[2]);
            h.update(&x.to_le_bytes());
        }
        Value::F64(x) => {
            h.update(&[3]);
            h.update(&x.to_bits().to_le_bytes());
        }
        Value::Str(s) => {
            h.update(&[4]);
            h.update(&(s.len() as u64).to_le_bytes());
            h.update(s.as_bytes());
        }
        Value::Tensor(t) => {
            h.update(&[5]);
            h.update(&(t.shape.len() as u64).to_le_bytes());
            for d in &t.shape {
                h.update(&(*d as u64).to_le_bytes());
            }
            h.update(&(t.data.len() as u64).to_le_bytes());
            #[cfg(target_endian = "little")]
            {
                // Same justification as the wire encoder's bulk tensor
                // path: on LE targets the in-memory f32 layout is the
                // canonical byte stream.
                let bytes = unsafe {
                    std::slice::from_raw_parts(t.data.as_ptr() as *const u8, t.data.len() * 4)
                };
                h.update(bytes);
            }
            #[cfg(not(target_endian = "little"))]
            {
                for f in t.data.iter() {
                    h.update(&f.to_bits().to_le_bytes());
                }
            }
        }
        Value::List(items) => {
            h.update(&[6]);
            h.update(&(items.len() as u64).to_le_bytes());
            for item in items {
                hash_value(h, item);
            }
        }
    }
}

// ----------------------------------------------------------- seat ledger --

/// Coordinator-side record of which digests one worker seat has been
/// provided: a bounded FIFO set. `admit` answers "can I send a reference?"
/// and books the provide when the answer is no.
#[derive(Debug)]
pub struct SeatLedger {
    known: HashSet<Digest>,
    fifo: VecDeque<Digest>,
    cap: usize,
}

impl Default for SeatLedger {
    fn default() -> Self {
        Self::new()
    }
}

impl SeatLedger {
    /// Ledger with the process-default capacity ([`intern_cap`]).
    pub fn new() -> Self {
        Self::with_cap(intern_cap())
    }

    /// Ledger with an explicit capacity (minimum 1).
    pub fn with_cap(cap: usize) -> Self {
        SeatLedger { known: HashSet::new(), fifo: VecDeque::new(), cap: cap.max(1) }
    }

    /// Returns `true` if the seat already holds `d` (encode a reference);
    /// otherwise records it — evicting the oldest entry at capacity, the
    /// same FIFO policy as the worker's [`InternCache`] — and returns
    /// `false` (encode a provide).
    pub fn admit(&mut self, d: Digest) -> bool {
        if self.known.contains(&d) {
            return true;
        }
        self.known.insert(d);
        self.fifo.push_back(d);
        if self.fifo.len() > self.cap {
            if let Some(old) = self.fifo.pop_front() {
                self.known.remove(&old);
            }
        }
        false
    }

    /// Number of digests currently tracked.
    pub fn len(&self) -> usize {
        self.fifo.len()
    }

    /// True when no digest has been provided to this seat yet.
    pub fn is_empty(&self) -> bool {
        self.fifo.is_empty()
    }
}

// ---------------------------------------------------------- worker cache --

/// A decoded interned blob: either a captured global [`Value`] or a shared
/// `MapChunk` body expression.
#[derive(Debug, Clone)]
pub enum InternedBlob {
    /// A captured global (values keep `Arc` tensor payloads, so cache hits
    /// are O(1) clones).
    Value(Value),
    /// A shared chunk body, held behind `Arc` so every task referencing it
    /// reuses one allocation.
    Expr(Arc<Expr>),
}

/// Worker-side intern cache: digest → decoded blob, bounded FIFO with the
/// same capacity as the coordinator's [`SeatLedger`]. Interior-mutable so
/// the wire decoder can install provides through a shared reference.
#[derive(Debug)]
pub struct InternCache {
    inner: Mutex<CacheInner>,
}

#[derive(Debug)]
struct CacheInner {
    map: HashMap<Digest, InternedBlob>,
    fifo: VecDeque<Digest>,
    cap: usize,
}

impl Default for InternCache {
    fn default() -> Self {
        Self::new()
    }
}

impl InternCache {
    /// Cache with the process-default capacity ([`intern_cap`]).
    pub fn new() -> Self {
        Self::with_cap(intern_cap())
    }

    /// Cache with an explicit capacity (minimum 1).
    pub fn with_cap(cap: usize) -> Self {
        InternCache {
            inner: Mutex::new(CacheInner {
                map: HashMap::new(),
                fifo: VecDeque::new(),
                cap: cap.max(1),
            }),
        }
    }

    /// Install a blob. Re-inserting an existing digest replaces the blob
    /// without perturbing FIFO order (provides replayed during a decode
    /// retry stay idempotent).
    pub fn insert(&self, d: Digest, blob: InternedBlob) {
        let mut inner = self.inner.lock().unwrap();
        if inner.map.insert(d, blob).is_none() {
            inner.fifo.push_back(d);
            if inner.fifo.len() > inner.cap {
                if let Some(old) = inner.fifo.pop_front() {
                    inner.map.remove(&old);
                }
            }
        }
    }

    /// Look up a value blob (None on miss *or* kind mismatch — both are
    /// recovered through the `NeedBlob` path).
    pub fn value(&self, d: &Digest) -> Option<Value> {
        match self.inner.lock().unwrap().map.get(d) {
            Some(InternedBlob::Value(v)) => Some(v.clone()),
            _ => None,
        }
    }

    /// Look up an expression blob.
    pub fn expr(&self, d: &Digest) -> Option<Arc<Expr>> {
        match self.inner.lock().unwrap().map.get(d) {
            Some(InternedBlob::Expr(e)) => Some(Arc::clone(e)),
            _ => None,
        }
    }

    /// Number of cached blobs.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().fifo.len()
    }

    /// True when the cache is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

// ----------------------------------------------------------- blob store --

#[derive(Default)]
struct StoreInner {
    map: HashMap<Digest, Arc<Vec<u8>>>,
    fifo: VecDeque<Digest>,
}

static STORE: OnceLock<Mutex<StoreInner>> = OnceLock::new();

fn store() -> &'static Mutex<StoreInner> {
    STORE.get_or_init(|| Mutex::new(StoreInner::default()))
}

/// Ensure the process-global intern store holds the encoded blob bytes for
/// `d`, building them with `make` only on absence. Returns the shared
/// bytes. The store is what answers a worker's `NeedBlob`; it is bounded
/// (`RUSTURES_INTERN_STORE_CAP`, FIFO) — an evicted digest makes the
/// worker's recovery fail closed into a seat respawn, never a wrong value.
pub fn store_ensure(d: Digest, make: impl FnOnce() -> Vec<u8>) -> Arc<Vec<u8>> {
    let mut inner = store().lock().unwrap();
    if let Some(bytes) = inner.map.get(&d) {
        return Arc::clone(bytes);
    }
    let bytes = Arc::new(make());
    inner.map.insert(d, Arc::clone(&bytes));
    inner.fifo.push_back(d);
    let cap = env_cap("RUSTURES_INTERN_STORE_CAP", DEFAULT_STORE_CAP);
    while inner.fifo.len() > cap {
        if let Some(old) = inner.fifo.pop_front() {
            inner.map.remove(&old);
        }
    }
    bytes
}

/// Fetch encoded blob bytes for `d` from the process-global store.
pub fn store_get(d: &Digest) -> Option<Arc<Vec<u8>>> {
    store().lock().unwrap().map.get(d).map(Arc::clone)
}

// ------------------------------------------------- per-session registry --

/// Per-session interning observability counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct InternCounters {
    /// Interned slots shipped with full blob bytes (first send to a seat).
    pub provides: u64,
    /// Interned slots shipped as a 16-byte digest reference.
    pub refs: u64,
}

#[derive(Debug, Clone, Copy)]
struct SessionEntry {
    enabled: bool,
    counters: InternCounters,
}

static SESSIONS: OnceLock<Mutex<HashMap<u64, SessionEntry>>> = OnceLock::new();

fn sessions() -> &'static Mutex<HashMap<u64, SessionEntry>> {
    SESSIONS.get_or_init(|| Mutex::new(HashMap::new()))
}

fn with_entry<R>(session: u64, f: impl FnOnce(&mut SessionEntry) -> R) -> R {
    let mut map = sessions().lock().unwrap();
    let entry = map
        .entry(session)
        .or_insert(SessionEntry { enabled: true, counters: InternCounters::default() });
    f(entry)
}

/// Is interning enabled for `session`? Defaults to true.
pub fn session_interning(session: u64) -> bool {
    sessions().lock().unwrap().get(&session).map(|e| e.enabled).unwrap_or(true)
}

/// Enable or disable interning for one session. Results are bit-identical
/// either way; off trades bytes-on-wire for zero cache state (useful for
/// debugging and for the conformance cross-check).
pub fn set_session_interning(session: u64, enabled: bool) {
    with_entry(session, |e| e.enabled = enabled);
}

/// Snapshot the interning counters for one session.
pub fn session_counters(session: u64) -> InternCounters {
    sessions().lock().unwrap().get(&session).map(|e| e.counters).unwrap_or_default()
}

/// Zero the interning counters for one session (the toggle is preserved).
pub fn reset_session_counters(session: u64) {
    with_entry(session, |e| e.counters = InternCounters::default());
}

/// Drop a session's interning state entirely (toggle and counters).
pub fn clear_session(session: u64) {
    sessions().lock().unwrap().remove(&session);
}

pub(crate) fn note_provide(session: u64) {
    with_entry(session, |e| e.counters.provides += 1);
}

pub(crate) fn note_ref(session: u64) {
    with_entry(session, |e| e.counters.refs += 1);
}

static NEED_BLOBS: AtomicU64 = AtomicU64::new(0);

/// Record one `NeedBlob` recovery round trip (process-global: the frame
/// carries no session id, by design — its body was undecodable).
pub fn note_need_blob() {
    NEED_BLOBS.fetch_add(1, Ordering::Relaxed);
}

/// Total `NeedBlob` recovery round trips served by this process.
pub fn need_blob_count() -> u64 {
    NEED_BLOBS.load(Ordering::Relaxed)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::value::Tensor;

    #[test]
    fn digest_is_content_addressed() {
        let a = Value::Tensor(Tensor::new(vec![4], vec![1.0, 2.0, 3.0, 4.0]).unwrap());
        let b = Value::Tensor(Tensor::new(vec![4], vec![1.0, 2.0, 3.0, 4.0]).unwrap());
        assert_eq!(digest_value(&a), digest_value(&b));
        let c = Value::Tensor(Tensor::new(vec![4], vec![1.0, 2.0, 3.0, 5.0]).unwrap());
        assert_ne!(digest_value(&a), digest_value(&c));
        // Shape participates: [4] vs [2,2] with identical data differ.
        let d = Value::Tensor(Tensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap());
        assert_ne!(digest_value(&a), digest_value(&d));
    }

    #[test]
    fn digest_separates_structurally_ambiguous_values() {
        assert_ne!(digest_value(&Value::Str("ab".into())), digest_value(&Value::Str("a".into())));
        assert_ne!(
            digest_value(&Value::List(vec![Value::I64(1)])),
            digest_value(&Value::I64(1))
        );
        assert_ne!(digest_bytes(b"x"), digest_bytes(b"y"));
    }

    #[test]
    fn ledger_and_cache_mirror_fifo_eviction() {
        let mut ledger = SeatLedger::with_cap(2);
        let cache = InternCache::with_cap(2);
        let d = |i: u8| Digest([i; 16]);
        for i in 0..3u8 {
            assert!(!ledger.admit(d(i)), "first admit of {i} must be a provide");
            cache.insert(d(i), InternedBlob::Value(Value::I64(i64::from(i))));
        }
        // Oldest (0) evicted on both sides; 1 and 2 retained.
        assert!(!ledger.admit(d(0)), "evicted digest re-provides");
        assert!(ledger.admit(d(2)));
        assert!(cache.value(&d(1)).is_some());
        assert_eq!(cache.len(), 2);
    }

    #[test]
    fn cache_reinsert_is_idempotent() {
        let cache = InternCache::with_cap(4);
        let d = Digest([9; 16]);
        cache.insert(d, InternedBlob::Value(Value::I64(1)));
        cache.insert(d, InternedBlob::Value(Value::I64(1)));
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.value(&d), Some(Value::I64(1)));
        // Kind mismatch is a miss, not a panic.
        assert!(cache.expr(&d).is_none());
    }

    #[test]
    fn store_roundtrip_and_dedup() {
        let d = Digest([0xCD; 16]);
        let first = store_ensure(d, || vec![1, 2, 3]);
        let again = store_ensure(d, || panic!("must not rebuild an existing blob"));
        assert_eq!(*first, vec![1, 2, 3]);
        assert!(Arc::ptr_eq(&first, &again));
        assert_eq!(store_get(&d).as_deref(), Some(&vec![1, 2, 3]));
    }

    #[test]
    fn session_toggle_and_counters() {
        let sid = 0x5eed_0001;
        assert!(session_interning(sid), "interning defaults on");
        set_session_interning(sid, false);
        assert!(!session_interning(sid));
        note_provide(sid);
        note_ref(sid);
        note_ref(sid);
        assert_eq!(session_counters(sid), InternCounters { provides: 1, refs: 2 });
        reset_session_counters(sid);
        assert_eq!(session_counters(sid), InternCounters::default());
        assert!(!session_interning(sid), "reset keeps the toggle");
        clear_session(sid);
        assert!(session_interning(sid));
    }
}
