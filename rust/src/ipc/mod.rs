//! Inter-process plumbing: the task/result message model, a from-scratch
//! binary wire format ([`wire`] — serde is unavailable offline), v6
//! self-describing framing over any `Read`/`Write` transport ([`frame`]),
//! per-frame compression ([`codec`]), and content-hashed global interning
//! ([`intern`]).
//!
//! Every backend speaks the same protocol: the in-process backends shortcut
//! the bytes but share the *types*; the multiprocess, cluster, and batch
//! backends move [`Message`]s over pipes, TCP sockets, and spool files
//! respectively. **WIRE.md at the repository root is the normative
//! specification of the byte format**; the `wire_spec` integration test
//! keeps it and this module in lockstep.
//!
//! ```
//! use rustures::ipc::{frame, Message};
//!
//! let mut buf = Vec::new();
//! frame::write_message(&mut buf, &Message::Ping).unwrap();
//! let mut cur = std::io::Cursor::new(buf);
//! assert_eq!(frame::read_message(&mut cur).unwrap(), Some(Message::Ping));
//! ```
#![deny(missing_docs)]

pub mod codec;
pub mod frame;
pub mod intern;
pub mod wire;

use crate::api::conditions::{Captured, Condition};
use crate::api::env::Env;
use crate::api::error::EvalError;
use crate::api::expr::Expr;
use crate::api::plan::PlanSpec;
use crate::api::value::Value;
use crate::backend::supervisor::RetryPolicy;

/// The serialized execution context a task carries to its worker — the
/// session-first API's answer to "what should *nested* futures on the
/// worker inherit?".  One compact wire record (protocol v4) instead of a
/// bare topology tail, so plan-level retry defaults no longer silently
/// drop on nested workers (the PR 3 gap).
#[derive(Debug, Clone, PartialEq)]
pub struct SessionContext {
    /// Originating [`crate::api::session::Session`] id (0 = the default
    /// session).  Worker-side derived sessions attribute supervision
    /// metrics to this id.
    pub session: u64,
    /// Remaining plan topology for *nested* futures resolved on the worker
    /// — the paper's nested-parallelism protection: empty means implicit
    /// `plan(sequential)`.
    pub nested_plan: Vec<PlanSpec>,
    /// The originating session's plan-wide retry default: nested futures
    /// created on the worker are supervised with this policy unless their
    /// own options override it.
    pub retry: Option<RetryPolicy>,
    /// Starting value for the worker-side session's future-creation
    /// counter (RNG stream index assignment for nested futures).
    pub counter_base: u64,
    /// Heartbeat interval in milliseconds the worker should use while
    /// evaluating this task (protocol v7 — [`crate::liveness::LivenessConfig`]
    /// became per-session, carried here instead of read from process-global
    /// state on the worker).
    pub heartbeat_ms: u64,
    /// Stall deadline in milliseconds: a seat silent for longer than this
    /// while busy is declared hung by the transport reactor's timer and
    /// recycled into the retry path.  `0` = stall detection disabled.
    pub stall_after_ms: u64,
}

impl Default for SessionContext {
    fn default() -> Self {
        SessionContext {
            session: 0,
            nested_plan: Vec::new(),
            retry: None,
            counter_base: 0,
            heartbeat_ms: crate::liveness::DEFAULT_HEARTBEAT_MS,
            stall_after_ms: 0,
        }
    }
}

/// Per-task options shipped with the expression (the `future(...)` args).
#[derive(Debug, Clone, PartialEq)]
pub struct TaskOpts {
    /// `seed = TRUE` analog: base seed for parallel RNG streams.
    /// `None` = seed not set; RNG use then triggers the misuse warning.
    pub seed: Option<u64>,
    /// Which RNG stream this future uses (assigned by creation order, so
    /// results are reproducible regardless of backend and worker count).
    pub stream_index: u64,
    /// Capture standard output on the worker (`stdout = TRUE`).
    pub capture_stdout: bool,
    /// Capture conditions on the worker (`conditions = "all"` vs none).
    pub capture_conditions: bool,
    /// Human label for traces and error messages.
    pub label: Option<String>,
    /// Nesting depth of this future (0 = created in the top-level session).
    pub depth: u32,
    /// Serialized session context for nested futures on the worker.
    pub context: SessionContext,
    /// Attempt epoch (protocol v5): 0 for the first launch, bumped by the
    /// supervisor on every retry.  Workers echo it in [`TaskResult`], and
    /// readers/the batch daemon *fence* result frames whose epoch does not
    /// match the handle's current attempt — a slow-but-alive worker from a
    /// presumed-dead attempt can never corrupt a retried future.
    pub attempt: u32,
    /// Pipelined-dependency ids (protocol v7): futures whose results this
    /// task consumes via [`crate::api::expr::Expr::Await`] but which were
    /// *unresolved* at launch.  The worker must collect one
    /// [`Message::Forward`] frame per listed id (in any order) before
    /// evaluating — the coordinator forwards each dependency's outcome
    /// directly to this task's seat, saving the resolve-and-resubmit round
    /// trip through the caller.
    pub pending: Vec<String>,
}

impl Default for TaskOpts {
    fn default() -> Self {
        TaskOpts {
            seed: None,
            stream_index: 0,
            capture_stdout: true,
            capture_conditions: true,
            label: None,
            depth: 0,
            context: SessionContext::default(),
            attempt: 0,
            pending: Vec::new(),
        }
    }
}

/// A fully self-contained unit of work: expression + captured globals +
/// options.  This is what "a future" is on the wire.
#[derive(Debug, Clone, PartialEq)]
pub struct TaskSpec {
    /// Globally unique future id (`f-<session>-<counter>` scheme).
    pub id: String,
    /// The expression to evaluate on the worker.
    pub expr: Expr,
    /// Captured globals the expression closes over.
    pub globals: Env,
    /// Evaluation options (seed, capture flags, session context, attempt).
    pub opts: TaskOpts,
}

/// Worker-side evaluation outcome (wire-encodable `Result`).
#[derive(Debug, Clone, PartialEq)]
pub enum TaskOutcome {
    /// Evaluation produced a value.
    Ok(Value),
    /// Evaluation raised an error (R's `stop()` analog).
    Err(EvalError),
}

/// Worker-side timing of one task (drives metrics and Figure-1 traces).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct TaskMetrics {
    /// Worker wall-clock when evaluation started (ns since UNIX epoch).
    pub started_ns: u64,
    /// Worker wall-clock when evaluation finished.
    pub finished_ns: u64,
}

impl TaskMetrics {
    /// Wall-clock evaluation time in nanoseconds (saturating).
    pub fn eval_nanos(&self) -> u64 {
        self.finished_ns.saturating_sub(self.started_ns)
    }
}

/// Everything a resolved future sends home.
#[derive(Debug, Clone, PartialEq)]
pub struct TaskResult {
    /// The task id this result answers.
    pub id: String,
    /// Evaluation outcome (value or structured error).
    pub outcome: TaskOutcome,
    /// Captured stdout and conditions from the worker.
    pub captured: Captured,
    /// Worker-side timing of the evaluation.
    pub metrics: TaskMetrics,
    /// Echo of the launching [`TaskOpts::attempt`] — the stale-result fence.
    pub attempt: u32,
}

/// The worker protocol.  Each variant maps 1:1 to a frame kind byte
/// ([`wire::FRAME_KIND_TABLE`], WIRE.md §Frame kinds).
#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    /// Worker → coordinator on connect: identity + protocol version.
    Hello {
        /// Worker's self-reported id (seat name).
        worker_id: String,
        /// Protocol version the worker speaks.
        version: u32,
    },
    /// Coordinator → worker: run this task.
    Task(TaskSpec),
    /// Worker → coordinator: a live `immediateCondition` (progress).
    Immediate {
        /// The task that emitted the condition.
        task_id: String,
        /// The condition itself.
        condition: Condition,
    },
    /// Worker → coordinator: task finished.
    Result(TaskResult),
    /// Coordinator → worker: exit the event loop.
    Shutdown,
    /// Liveness probe (either direction).
    Ping,
    /// Liveness probe response.
    Pong,
    /// Worker → coordinator: still alive and making progress on `task_id`.
    /// Emitted from the evaluator's tick hook (between `MapChunk` elements
    /// and other yield points) over the same writer the immediates use —
    /// no per-worker heartbeat thread exists.
    Heartbeat {
        /// The task being heartbeat.
        task_id: String,
    },
    /// Coordinator → worker: abandon `task_id` if it is still queued.  A
    /// single-threaded worker mid-evaluation only reads this after the
    /// task completes (then drops it as a no-op); the coordinator's seat
    /// kill remains the enforcement path for a running task.
    Cancel {
        /// The task to abandon.
        task_id: String,
    },
    /// Worker → coordinator (protocol v6): the worker's intern cache is
    /// missing these digests — resend the blobs. The recovery path when
    /// the coordinator's [`intern::SeatLedger`] and the worker's
    /// [`intern::InternCache`] drift (eviction skew, a respawned worker).
    NeedBlob {
        /// The digests to resend.
        digests: Vec<intern::Digest>,
    },
    /// Coordinator → worker (protocol v6): one intern blob, answering a
    /// `NeedBlob`. `bytes: None` means the blob is unknown (evicted from
    /// the process-global store) — the worker fails the task's decode
    /// closed and the supervisor retries through a fresh seat.
    Blob {
        /// Which digest this answers.
        digest: intern::Digest,
        /// Encoded blob bytes ([`wire::decode_blob`]), or `None` if gone.
        bytes: Option<Vec<u8>>,
    },
    /// Coordinator → worker (protocol v7): the outcome of a pipelined
    /// dependency, forwarded directly to the seat running a consumer task
    /// that listed `future_id` in [`TaskOpts::pending`].  The worker binds
    /// it for [`crate::api::expr::Expr::Await`] and only starts evaluating
    /// once every pending id has arrived.  Forwards ride the same
    /// attempt-fenced launch path as the task itself: a consumer relaunch
    /// resends the task *and* its forwards, so retry semantics are
    /// unchanged.
    Forward {
        /// The pipelined dependency this outcome resolves.
        future_id: String,
        /// The dependency's outcome (value, or the error `Await` re-raises).
        outcome: TaskOutcome,
    },
}

/// Reserved environment key a pipelined dependency's *successful* value is
/// bound under in the consumer task's globals (creation-time prebind) or
/// worker-side environment (Forward collection).  The `__pipe:` prefix
/// cannot collide with user globals: [`crate::api::expr::Expr::Var`] names
/// come from user code and the analyzer flags unknown captures long before
/// a name like this could be typed by accident.
pub fn pipeline_ok_key(future_id: &str) -> String {
    format!("__pipe:{future_id}")
}

/// Reserved environment key a pipelined dependency's *error message* is
/// bound under (as a [`Value::Str`]) — [`crate::api::expr::Expr::Await`]
/// re-raises it as an evaluation error.
pub fn pipeline_err_key(future_id: &str) -> String {
    format!("__pipe_err:{future_id}")
}

/// Protocol version — bump on any wire-format change.
/// v2: `Expr::MapChunk` (tag 17) — body-once + packed-elements chunk tasks.
/// v3: `Expr::ChaosKill` (tag 18) — supervised-recovery chaos probe.
/// v4: [`SessionContext`] record in `TaskOpts` — session id + topology tail
///     + plan-wide retry default + counter base, so nested plans on workers
///     inherit the originating session's execution context.
/// v5: liveness plane — `Heartbeat` (tag 7) / `Cancel` (tag 8) frames,
///     attempt epochs on `TaskOpts`/`TaskResult` (stale-result fencing),
///     and `Expr::ChaosHang` (tag 19).
/// v6: self-describing frames (magic + version + kind + codec header,
///     varint lengths), per-frame delta+RLE compression, and content-hashed
///     global interning (`ValueRef`/`ExprRef` tags, `NeedBlob`/`Blob`
///     frames).  WIRE.md is the normative spec.
/// v7: async transport + promise pipelining — `Forward` (tag 11) frames
///     carry a pipelined dependency's outcome straight to the consumer's
///     seat, `Expr::Await` (tag 21) consumes it, `TaskOpts::pending` lists
///     the forwards a task must collect before evaluating, and
///     [`SessionContext`] carries per-session liveness settings
///     (`heartbeat_ms`, `stall_after_ms`) now that stall deadlines live in
///     the transport reactor's timer instead of per-pool scan threads.
pub const PROTOCOL_VERSION: u32 = 7;
