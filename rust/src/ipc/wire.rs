//! Binary wire format — the serialization substrate (R's `serialize()`
//! analog; serde is unavailable in this offline image, so this is a
//! from-scratch, versioned, tagged little-endian encoding).
//!
//! Every type that crosses a process boundary round-trips through
//! [`Encoder`]/[`Decoder`]: values, expressions, captured globals,
//! conditions, task specs and results, plan topologies, and the
//! [`Message`] envelope.  Tags are one byte; lengths are u32 LE; integers
//! u64/i64 LE; floats IEEE-754 bits.

use crate::api::conditions::{Captured, Condition, ConditionKind};
use crate::api::env::Env;
use crate::api::error::EvalError;
use crate::api::expr::{EmitKind, Expr, PrimOp, RngDist};
use crate::api::plan::PlanSpec;
use crate::api::value::{Tensor, Value};
use crate::backend::supervisor::RetryPolicy;
use crate::ipc::{
    Message, SessionContext, TaskMetrics, TaskOpts, TaskOutcome, TaskResult, TaskSpec,
};

/// Decode failure: offset + description (possibly a truncated/corrupt frame).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WireError {
    pub offset: usize,
    pub message: String,
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "wire decode error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for WireError {}

pub struct Encoder {
    buf: Vec<u8>,
}

impl Default for Encoder {
    fn default() -> Self {
        Self::new()
    }
}

impl Encoder {
    pub fn new() -> Self {
        Encoder { buf: Vec::with_capacity(256) }
    }

    /// §Perf: size-hinted construction — callers that know the payload size
    /// (task encoders sum their tensor buffers) allocate once instead of
    /// doubling through megabytes.
    pub fn with_capacity(bytes: usize) -> Self {
        Encoder { buf: Vec::with_capacity(bytes.max(64)) }
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    #[inline]
    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    #[inline]
    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    #[inline]
    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    #[inline]
    pub fn i64(&mut self, v: i64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    #[inline]
    pub fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_bits().to_le_bytes());
    }

    #[inline]
    pub fn bool(&mut self, v: bool) {
        self.buf.push(v as u8);
    }

    pub fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    pub fn f32_slice(&mut self, data: &[f32]) {
        self.u32(data.len() as u32);
        #[cfg(target_endian = "little")]
        {
            // §Perf: on LE targets the in-memory f32 layout *is* the wire
            // layout — one memcpy instead of per-element conversion.
            let bytes = unsafe {
                std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
            };
            self.buf.extend_from_slice(bytes);
        }
        #[cfg(not(target_endian = "little"))]
        {
            self.buf.reserve(data.len() * 4);
            for v in data {
                self.buf.extend_from_slice(&v.to_bits().to_le_bytes());
            }
        }
    }

    fn opt_str(&mut self, s: &Option<String>) {
        match s {
            Some(s) => {
                self.bool(true);
                self.str(s);
            }
            None => self.bool(false),
        }
    }

    fn opt_u64(&mut self, v: &Option<u64>) {
        match v {
            Some(v) => {
                self.bool(true);
                self.u64(*v);
            }
            None => self.bool(false),
        }
    }
}

pub struct Decoder<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Decoder<'a> {
    pub fn new(bytes: &'a [u8]) -> Self {
        Decoder { bytes, pos: 0 }
    }

    pub fn finished(&self) -> bool {
        self.pos == self.bytes.len()
    }

    fn err(&self, msg: &str) -> WireError {
        WireError { offset: self.pos, message: msg.to_string() }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if self.pos + n > self.bytes.len() {
            return Err(self.err(&format!("truncated: need {n} bytes")));
        }
        let s = &self.bytes[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn i64(&mut self) -> Result<i64, WireError> {
        Ok(i64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn f64(&mut self) -> Result<f64, WireError> {
        Ok(f64::from_bits(self.u64()?))
    }

    pub fn bool(&mut self) -> Result<bool, WireError> {
        Ok(self.u8()? != 0)
    }

    pub fn str(&mut self) -> Result<String, WireError> {
        let n = self.u32()? as usize;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| self.err("invalid UTF-8"))
    }

    /// Decode a length-prefixed f32 buffer into the **shared** allocation
    /// [`Tensor`] stores.  §Perf: `from_le_bytes` is a no-op on LE targets,
    /// so the loop compiles to a bulk copy; collecting from a `chunks_exact`
    /// iterator lets the standard library write the `Arc` allocation
    /// directly when it can (and costs at most one intermediate buffer
    /// otherwise — safely, with no unsafe reinterpret).
    pub fn f32_arc(&mut self) -> Result<std::sync::Arc<[f32]>, WireError> {
        let n = self.u32()? as usize;
        let raw = self.take(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn opt_str(&mut self) -> Result<Option<String>, WireError> {
        Ok(if self.bool()? { Some(self.str()?) } else { None })
    }

    fn opt_u64(&mut self) -> Result<Option<u64>, WireError> {
        Ok(if self.bool()? { Some(self.u64()?) } else { None })
    }
}

// ---------------------------------------------------------------- Value --

pub fn enc_value(e: &mut Encoder, v: &Value) {
    match v {
        Value::Unit => e.u8(0),
        Value::Bool(b) => {
            e.u8(1);
            e.bool(*b);
        }
        Value::I64(v) => {
            e.u8(2);
            e.i64(*v);
        }
        Value::F64(v) => {
            e.u8(3);
            e.f64(*v);
        }
        Value::Str(s) => {
            e.u8(4);
            e.str(s);
        }
        Value::Tensor(t) => {
            e.u8(5);
            e.u32(t.shape.len() as u32);
            for d in &t.shape {
                e.u64(*d as u64);
            }
            e.f32_slice(&t.data);
        }
        Value::List(items) => {
            e.u8(6);
            e.u32(items.len() as u32);
            for item in items {
                enc_value(e, item);
            }
        }
    }
}

pub fn dec_value(d: &mut Decoder) -> Result<Value, WireError> {
    Ok(match d.u8()? {
        0 => Value::Unit,
        1 => Value::Bool(d.bool()?),
        2 => Value::I64(d.i64()?),
        3 => Value::F64(d.f64()?),
        4 => Value::Str(d.str()?),
        5 => {
            let rank = d.u32()? as usize;
            let mut shape = Vec::with_capacity(rank);
            for _ in 0..rank {
                shape.push(d.u64()? as usize);
            }
            let data = d.f32_arc()?;
            Value::Tensor(Tensor::from_shared(shape, data).map_err(|m| d.err(&m))?)
        }
        6 => {
            let n = d.u32()? as usize;
            let mut items = Vec::with_capacity(n);
            for _ in 0..n {
                items.push(dec_value(d)?);
            }
            Value::List(items)
        }
        t => return Err(d.err(&format!("bad Value tag {t}"))),
    })
}

// ----------------------------------------------------------------- Expr --

fn prim_tag(op: PrimOp) -> u8 {
    match op {
        PrimOp::Add => 0,
        PrimOp::Sub => 1,
        PrimOp::Mul => 2,
        PrimOp::Div => 3,
        PrimOp::Neg => 4,
        PrimOp::Lt => 5,
        PrimOp::Le => 6,
        PrimOp::Eq => 7,
        PrimOp::Not => 8,
        PrimOp::Len => 9,
        PrimOp::Sum => 10,
        PrimOp::Mean => 11,
        PrimOp::Sqrt => 12,
        PrimOp::Concat => 13,
    }
}

fn prim_from(tag: u8, d: &Decoder) -> Result<PrimOp, WireError> {
    Ok(match tag {
        0 => PrimOp::Add,
        1 => PrimOp::Sub,
        2 => PrimOp::Mul,
        3 => PrimOp::Div,
        4 => PrimOp::Neg,
        5 => PrimOp::Lt,
        6 => PrimOp::Le,
        7 => PrimOp::Eq,
        8 => PrimOp::Not,
        9 => PrimOp::Len,
        10 => PrimOp::Sum,
        11 => PrimOp::Mean,
        12 => PrimOp::Sqrt,
        13 => PrimOp::Concat,
        t => return Err(d.err(&format!("bad PrimOp tag {t}"))),
    })
}

fn emit_tag(k: EmitKind) -> u8 {
    match k {
        EmitKind::Stdout => 0,
        EmitKind::Message => 1,
        EmitKind::Warning => 2,
        EmitKind::Progress => 3,
    }
}

fn emit_from(tag: u8, d: &Decoder) -> Result<EmitKind, WireError> {
    Ok(match tag {
        0 => EmitKind::Stdout,
        1 => EmitKind::Message,
        2 => EmitKind::Warning,
        3 => EmitKind::Progress,
        t => return Err(d.err(&format!("bad EmitKind tag {t}"))),
    })
}

fn enc_exprs(e: &mut Encoder, items: &[Expr]) {
    e.u32(items.len() as u32);
    for item in items {
        enc_expr(e, item);
    }
}

fn dec_exprs(d: &mut Decoder) -> Result<Vec<Expr>, WireError> {
    let n = d.u32()? as usize;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(dec_expr(d)?);
    }
    Ok(out)
}

pub fn enc_expr(e: &mut Encoder, expr: &Expr) {
    match expr {
        Expr::Lit(v) => {
            e.u8(0);
            enc_value(e, v);
        }
        Expr::Var(name) => {
            e.u8(1);
            e.str(name);
        }
        Expr::Let { name, value, body } => {
            e.u8(2);
            e.str(name);
            enc_expr(e, value);
            enc_expr(e, body);
        }
        Expr::Seq(items) => {
            e.u8(3);
            enc_exprs(e, items);
        }
        Expr::List(items) => {
            e.u8(4);
            enc_exprs(e, items);
        }
        Expr::Index { list, index } => {
            e.u8(5);
            enc_expr(e, list);
            enc_expr(e, index);
        }
        Expr::Call { kernel, args } => {
            e.u8(6);
            e.str(kernel);
            enc_exprs(e, args);
        }
        Expr::Prim { op, args } => {
            e.u8(7);
            e.u8(prim_tag(*op));
            enc_exprs(e, args);
        }
        Expr::If { cond, then, otherwise } => {
            e.u8(8);
            enc_expr(e, cond);
            enc_expr(e, then);
            enc_expr(e, otherwise);
        }
        Expr::DynLookup(inner) => {
            e.u8(9);
            enc_expr(e, inner);
        }
        Expr::Emit { kind, message } => {
            e.u8(10);
            e.u8(emit_tag(*kind));
            enc_expr(e, message);
        }
        Expr::Stop(inner) => {
            e.u8(11);
            enc_expr(e, inner);
        }
        Expr::Rng { dist, shape } => {
            e.u8(12);
            e.u8(match dist {
                RngDist::Unif => 0,
                RngDist::Norm => 1,
            });
            e.u32(shape.len() as u32);
            for d in shape {
                e.u64(*d as u64);
            }
        }
        Expr::WithRngStream { index, body } => {
            e.u8(13);
            e.u64(*index);
            enc_expr(e, body);
        }
        Expr::Spin { millis } => {
            e.u8(14);
            e.u64(*millis);
        }
        Expr::Sleep { millis } => {
            e.u8(15);
            e.u64(*millis);
        }
        Expr::Work { iters } => {
            e.u8(16);
            e.u64(*iters);
        }
        Expr::MapChunk { param, body, elements, base_index } => {
            // §Perf: the body is encoded ONCE per chunk, followed by the
            // packed element values — serializing backends pay O(|body| +
            // Σ|elements|) instead of O(n·|body|).
            e.u8(17);
            e.str(param);
            e.u64(*base_index);
            enc_expr(e, body);
            e.u32(elements.len() as u32);
            for v in elements {
                enc_value(e, v);
            }
        }
        Expr::ChaosKill { marker } => {
            e.u8(18);
            match marker {
                Some(m) => {
                    e.u8(1);
                    e.str(m);
                }
                None => e.u8(0),
            }
        }
        Expr::ChaosHang { millis, marker } => {
            e.u8(19);
            e.u64(*millis);
            match marker {
                Some(m) => {
                    e.u8(1);
                    e.str(m);
                }
                None => e.u8(0),
            }
        }
    }
}

pub fn dec_expr(d: &mut Decoder) -> Result<Expr, WireError> {
    Ok(match d.u8()? {
        0 => Expr::Lit(dec_value(d)?),
        1 => Expr::Var(d.str()?),
        2 => {
            let name = d.str()?;
            let value = Box::new(dec_expr(d)?);
            let body = Box::new(dec_expr(d)?);
            Expr::Let { name, value, body }
        }
        3 => Expr::Seq(dec_exprs(d)?),
        4 => Expr::List(dec_exprs(d)?),
        5 => {
            let list = Box::new(dec_expr(d)?);
            let index = Box::new(dec_expr(d)?);
            Expr::Index { list, index }
        }
        6 => {
            let kernel = d.str()?;
            let args = dec_exprs(d)?;
            Expr::Call { kernel, args }
        }
        7 => {
            let tag = d.u8()?;
            let op = prim_from(tag, d)?;
            let args = dec_exprs(d)?;
            Expr::Prim { op, args }
        }
        8 => {
            let cond = Box::new(dec_expr(d)?);
            let then = Box::new(dec_expr(d)?);
            let otherwise = Box::new(dec_expr(d)?);
            Expr::If { cond, then, otherwise }
        }
        9 => Expr::DynLookup(Box::new(dec_expr(d)?)),
        10 => {
            let tag = d.u8()?;
            let kind = emit_from(tag, d)?;
            Expr::Emit { kind, message: Box::new(dec_expr(d)?) }
        }
        11 => Expr::Stop(Box::new(dec_expr(d)?)),
        12 => {
            let dist = match d.u8()? {
                0 => RngDist::Unif,
                1 => RngDist::Norm,
                t => return Err(d.err(&format!("bad RngDist tag {t}"))),
            };
            let rank = d.u32()? as usize;
            let mut shape = Vec::with_capacity(rank);
            for _ in 0..rank {
                shape.push(d.u64()? as usize);
            }
            Expr::Rng { dist, shape }
        }
        13 => {
            let index = d.u64()?;
            Expr::WithRngStream { index, body: Box::new(dec_expr(d)?) }
        }
        14 => Expr::Spin { millis: d.u64()? },
        15 => Expr::Sleep { millis: d.u64()? },
        16 => Expr::Work { iters: d.u64()? },
        17 => {
            let param = d.str()?;
            let base_index = d.u64()?;
            let body = std::sync::Arc::new(dec_expr(d)?);
            let n = d.u32()? as usize;
            let mut elements = Vec::with_capacity(n);
            for _ in 0..n {
                elements.push(dec_value(d)?);
            }
            Expr::MapChunk { param, body, elements, base_index }
        }
        18 => {
            let marker = match d.u8()? {
                0 => None,
                1 => Some(d.str()?),
                t => return Err(d.err(&format!("bad ChaosKill marker flag {t}"))),
            };
            Expr::ChaosKill { marker }
        }
        19 => {
            let millis = d.u64()?;
            let marker = match d.u8()? {
                0 => None,
                1 => Some(d.str()?),
                t => return Err(d.err(&format!("bad ChaosHang marker flag {t}"))),
            };
            Expr::ChaosHang { millis, marker }
        }
        t => return Err(d.err(&format!("bad Expr tag {t}"))),
    })
}

// ------------------------------------------------------------------ Env --

pub fn enc_env(e: &mut Encoder, env: &Env) {
    let n = env.len();
    e.u32(n as u32);
    for (k, v) in env.iter() {
        e.str(k);
        enc_value(e, v);
    }
}

pub fn dec_env(d: &mut Decoder) -> Result<Env, WireError> {
    let n = d.u32()? as usize;
    let mut env = Env::new();
    for _ in 0..n {
        let k = d.str()?;
        let v = dec_value(d)?;
        env.insert(&k, v);
    }
    Ok(env)
}

// ----------------------------------------------------------- Conditions --

fn cond_kind_tag(k: ConditionKind) -> u8 {
    match k {
        ConditionKind::Message => 0,
        ConditionKind::Warning => 1,
        ConditionKind::Immediate => 2,
    }
}

fn cond_kind_from(tag: u8, d: &Decoder) -> Result<ConditionKind, WireError> {
    Ok(match tag {
        0 => ConditionKind::Message,
        1 => ConditionKind::Warning,
        2 => ConditionKind::Immediate,
        t => return Err(d.err(&format!("bad ConditionKind tag {t}"))),
    })
}

pub fn enc_condition(e: &mut Encoder, c: &Condition) {
    e.u8(cond_kind_tag(c.kind));
    e.str(&c.message);
    e.u64(c.seq);
}

pub fn dec_condition(d: &mut Decoder) -> Result<Condition, WireError> {
    let tag = d.u8()?;
    let kind = cond_kind_from(tag, d)?;
    Ok(Condition { kind, message: d.str()?, seq: d.u64()? })
}

pub fn enc_captured(e: &mut Encoder, c: &Captured) {
    e.str(&c.stdout);
    e.u32(c.conditions.len() as u32);
    for cond in &c.conditions {
        enc_condition(e, cond);
    }
    e.bool(c.rng_used);
}

pub fn dec_captured(d: &mut Decoder) -> Result<Captured, WireError> {
    let stdout = d.str()?;
    let n = d.u32()? as usize;
    let mut conditions = Vec::with_capacity(n);
    for _ in 0..n {
        conditions.push(dec_condition(d)?);
    }
    Ok(Captured { stdout, conditions, rng_used: d.bool()? })
}

// ----------------------------------------------------------- PlanSpec ----

pub fn enc_plan(e: &mut Encoder, p: &PlanSpec) {
    match p {
        PlanSpec::Sequential => e.u8(0),
        PlanSpec::ThreadPool { workers } => {
            e.u8(1);
            e.u64(*workers as u64);
        }
        PlanSpec::Multiprocess { workers } => {
            e.u8(2);
            e.u64(*workers as u64);
        }
        PlanSpec::Cluster { hosts } => {
            e.u8(3);
            e.u32(hosts.len() as u32);
            for h in hosts {
                e.str(h);
            }
        }
        PlanSpec::Batch { workers, submit_latency_ms, poll_interval_ms } => {
            e.u8(4);
            e.u64(*workers as u64);
            e.u64(*submit_latency_ms);
            e.u64(*poll_interval_ms);
        }
        PlanSpec::Custom { name, workers } => {
            e.u8(5);
            e.str(name);
            e.u64(*workers as u64);
        }
    }
}

pub fn dec_plan(d: &mut Decoder) -> Result<PlanSpec, WireError> {
    Ok(match d.u8()? {
        0 => PlanSpec::Sequential,
        1 => PlanSpec::ThreadPool { workers: d.u64()? as usize },
        2 => PlanSpec::Multiprocess { workers: d.u64()? as usize },
        3 => {
            let n = d.u32()? as usize;
            let mut hosts = Vec::with_capacity(n);
            for _ in 0..n {
                hosts.push(d.str()?);
            }
            PlanSpec::Cluster { hosts }
        }
        4 => PlanSpec::Batch {
            workers: d.u64()? as usize,
            submit_latency_ms: d.u64()?,
            poll_interval_ms: d.u64()?,
        },
        5 => PlanSpec::Custom { name: d.str()?, workers: d.u64()? as usize },
        t => return Err(d.err(&format!("bad PlanSpec tag {t}"))),
    })
}

// ----------------------------------------------------------- Task types --

fn enc_retry(e: &mut Encoder, r: &Option<RetryPolicy>) {
    match r {
        Some(p) => {
            e.bool(true);
            e.u32(p.max_attempts);
            e.u64(p.backoff.as_nanos() as u64);
            e.f64(p.factor);
            e.bool(p.idempotent);
        }
        None => e.bool(false),
    }
}

fn dec_retry(d: &mut Decoder) -> Result<Option<RetryPolicy>, WireError> {
    if !d.bool()? {
        return Ok(None);
    }
    let max_attempts = d.u32()?;
    let backoff = std::time::Duration::from_nanos(d.u64()?);
    let factor = d.f64()?;
    let idempotent = d.bool()?;
    Ok(Some(RetryPolicy { max_attempts, backoff, factor, idempotent }))
}

/// Protocol-v4 session context record: origin session id, topology tail,
/// plan-wide retry default, and the nested counter base.
pub fn enc_session_context(e: &mut Encoder, c: &SessionContext) {
    e.u64(c.session);
    e.u32(c.nested_plan.len() as u32);
    for p in &c.nested_plan {
        enc_plan(e, p);
    }
    enc_retry(e, &c.retry);
    e.u64(c.counter_base);
}

pub fn dec_session_context(d: &mut Decoder) -> Result<SessionContext, WireError> {
    let session = d.u64()?;
    let n = d.u32()? as usize;
    let mut nested_plan = Vec::with_capacity(n);
    for _ in 0..n {
        nested_plan.push(dec_plan(d)?);
    }
    let retry = dec_retry(d)?;
    let counter_base = d.u64()?;
    Ok(SessionContext { session, nested_plan, retry, counter_base })
}

pub fn enc_task_opts(e: &mut Encoder, o: &TaskOpts) {
    e.opt_u64(&o.seed);
    e.u64(o.stream_index);
    e.bool(o.capture_stdout);
    e.bool(o.capture_conditions);
    e.opt_str(&o.label);
    e.u32(o.depth);
    enc_session_context(e, &o.context);
    e.u32(o.attempt);
}

pub fn dec_task_opts(d: &mut Decoder) -> Result<TaskOpts, WireError> {
    let seed = d.opt_u64()?;
    let stream_index = d.u64()?;
    let capture_stdout = d.bool()?;
    let capture_conditions = d.bool()?;
    let label = d.opt_str()?;
    let depth = d.u32()?;
    let context = dec_session_context(d)?;
    let attempt = d.u32()?;
    Ok(TaskOpts {
        seed,
        stream_index,
        capture_stdout,
        capture_conditions,
        label,
        depth,
        context,
        attempt,
    })
}

pub fn enc_task(e: &mut Encoder, t: &TaskSpec) {
    e.str(&t.id);
    enc_expr(e, &t.expr);
    enc_env(e, &t.globals);
    enc_task_opts(e, &t.opts);
}

/// Approximate encoded size of a task (§Perf: drives
/// [`Encoder::with_capacity`] so tensor-heavy tasks — large captured
/// globals, packed `MapChunk` elements — serialize into one allocation).
pub fn task_size_hint(t: &TaskSpec) -> usize {
    let mut hint = 128 + t.id.len() + t.globals.byte_size();
    t.expr.walk(&mut |e| {
        hint += 8;
        match e {
            Expr::Lit(v) => hint += v.byte_size(),
            Expr::MapChunk { elements, .. } => {
                hint += elements.iter().map(crate::api::value::Value::byte_size).sum::<usize>()
            }
            _ => {}
        }
    });
    hint
}

pub fn dec_task(d: &mut Decoder) -> Result<TaskSpec, WireError> {
    Ok(TaskSpec {
        id: d.str()?,
        expr: dec_expr(d)?,
        globals: dec_env(d)?,
        opts: dec_task_opts(d)?,
    })
}

pub fn enc_result(e: &mut Encoder, r: &TaskResult) {
    e.str(&r.id);
    match &r.outcome {
        TaskOutcome::Ok(v) => {
            e.u8(0);
            enc_value(e, v);
        }
        TaskOutcome::Err(err) => {
            e.u8(1);
            e.str(&err.message);
            e.opt_str(&err.call);
        }
    }
    enc_captured(e, &r.captured);
    e.u64(r.metrics.started_ns);
    e.u64(r.metrics.finished_ns);
    e.u32(r.attempt);
}

pub fn dec_result(d: &mut Decoder) -> Result<TaskResult, WireError> {
    let id = d.str()?;
    let outcome = match d.u8()? {
        0 => TaskOutcome::Ok(dec_value(d)?),
        1 => {
            let message = d.str()?;
            let call = d.opt_str()?;
            TaskOutcome::Err(EvalError { message, call })
        }
        t => return Err(d.err(&format!("bad TaskOutcome tag {t}"))),
    };
    let captured = dec_captured(d)?;
    let metrics = TaskMetrics { started_ns: d.u64()?, finished_ns: d.u64()? };
    let attempt = d.u32()?;
    Ok(TaskResult { id, outcome, captured, metrics, attempt })
}

// ------------------------------------------------------------- Message --

pub fn encode_message(m: &Message) -> Vec<u8> {
    let mut e = match m {
        // §Perf: size-hinted buffer for the payload-bearing messages.
        Message::Task(t) => Encoder::with_capacity(task_size_hint(t)),
        Message::Result(r) => Encoder::with_capacity(64 + result_size_hint(r)),
        _ => Encoder::new(),
    };
    match m {
        Message::Hello { worker_id, version } => {
            e.u8(0);
            e.str(worker_id);
            e.u32(*version);
        }
        Message::Task(t) => {
            e.u8(1);
            enc_task(&mut e, t);
        }
        Message::Immediate { task_id, condition } => {
            e.u8(2);
            e.str(task_id);
            enc_condition(&mut e, condition);
        }
        Message::Result(r) => {
            e.u8(3);
            enc_result(&mut e, r);
        }
        Message::Shutdown => e.u8(4),
        Message::Ping => e.u8(5),
        Message::Pong => e.u8(6),
        Message::Heartbeat { task_id } => {
            e.u8(7);
            e.str(task_id);
        }
        Message::Cancel { task_id } => {
            e.u8(8);
            e.str(task_id);
        }
    }
    e.into_bytes()
}

/// Encode a `Message::Task` directly from a reference (§Perf: avoids
/// cloning large captured globals just to wrap them in the enum, and
/// pre-sizes the buffer from the task's payload bytes).
pub fn encode_task_message(t: &TaskSpec) -> Vec<u8> {
    let mut e = Encoder::with_capacity(1 + task_size_hint(t));
    e.u8(1); // Message::Task tag — keep in sync with encode_message
    enc_task(&mut e, t);
    e.into_bytes()
}

fn result_size_hint(r: &TaskResult) -> usize {
    let payload = match &r.outcome {
        TaskOutcome::Ok(v) => v.byte_size(),
        TaskOutcome::Err(e) => e.message.len() + 16,
    };
    payload + r.id.len() + r.captured.stdout.len()
}

pub fn decode_message(bytes: &[u8]) -> Result<Message, WireError> {
    let mut d = Decoder::new(bytes);
    let m = match d.u8()? {
        0 => Message::Hello { worker_id: d.str()?, version: d.u32()? },
        1 => Message::Task(dec_task(&mut d)?),
        2 => Message::Immediate { task_id: d.str()?, condition: dec_condition(&mut d)? },
        3 => Message::Result(dec_result(&mut d)?),
        4 => Message::Shutdown,
        5 => Message::Ping,
        6 => Message::Pong,
        7 => Message::Heartbeat { task_id: d.str()? },
        8 => Message::Cancel { task_id: d.str()? },
        t => return Err(d.err(&format!("bad Message tag {t}"))),
    };
    if !d.finished() {
        return Err(d.err("trailing bytes in message"));
    }
    Ok(m)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::expr::Expr;

    fn roundtrip_value(v: Value) {
        let mut e = Encoder::new();
        enc_value(&mut e, &v);
        let bytes = e.into_bytes();
        let mut d = Decoder::new(&bytes);
        assert_eq!(dec_value(&mut d).unwrap(), v);
        assert!(d.finished());
    }

    #[test]
    fn value_roundtrips() {
        roundtrip_value(Value::Unit);
        roundtrip_value(Value::Bool(true));
        roundtrip_value(Value::I64(-42));
        roundtrip_value(Value::F64(std::f64::consts::PI));
        roundtrip_value(Value::Str("héllo\nworld".into()));
        roundtrip_value(Value::Tensor(Tensor::new(vec![2, 3], vec![1.0; 6]).unwrap()));
        roundtrip_value(Value::Tensor(Tensor::scalar(7.5)));
        roundtrip_value(Value::List(vec![
            Value::I64(1),
            Value::List(vec![Value::Str("nested".into())]),
            Value::Unit,
        ]));
    }

    #[test]
    fn expr_roundtrips_every_variant() {
        let expr = Expr::seq(vec![
            Expr::let_in(
                "a",
                Expr::add(Expr::var("x"), Expr::lit(1.0)),
                Expr::if_else(
                    Expr::prim(PrimOp::Lt, vec![Expr::var("a"), Expr::lit(10.0)]),
                    Expr::call("slow_fcn", vec![Expr::var("a")]),
                    Expr::stop(Expr::lit("too big")),
                ),
            ),
            Expr::index(Expr::list(vec![Expr::lit(1i64)]), Expr::lit(0i64)),
            Expr::dyn_lookup(Expr::lit("k")),
            Expr::cat(Expr::lit("out")),
            Expr::message(Expr::lit("msg")),
            Expr::warning(Expr::lit("warn")),
            Expr::progress(Expr::lit("50%")),
            Expr::runif(3),
            Expr::rnorm(2),
            Expr::with_rng_stream(9, Expr::runif(1)),
            Expr::Spin { millis: 5 },
            Expr::chaos_kill(),
            Expr::chaos_kill_once("/tmp/rustures-marker"),
            Expr::chaos_hang(25),
            Expr::chaos_hang_once(25, "/tmp/rustures-hang-marker"),
        ]);
        let mut e = Encoder::new();
        enc_expr(&mut e, &expr);
        let bytes = e.into_bytes();
        let mut d = Decoder::new(&bytes);
        assert_eq!(dec_expr(&mut d).unwrap(), expr);
        assert!(d.finished());
    }

    #[test]
    fn map_chunk_roundtrips_with_tensor_elements() {
        let body = std::sync::Arc::new(Expr::add(Expr::var("x"), Expr::runif(1)));
        let chunk = Expr::map_chunk(
            "x",
            body,
            vec![Value::Tensor(Tensor::zeros(&[8])), Value::I64(3), Value::Unit],
            42,
        );
        let mut e = Encoder::new();
        enc_expr(&mut e, &chunk);
        let bytes = e.into_bytes();
        let mut d = Decoder::new(&bytes);
        assert_eq!(dec_expr(&mut d).unwrap(), chunk);
        assert!(d.finished());
    }

    #[test]
    fn map_chunk_encodes_body_once() {
        // The whole point of the first-class chunk: n elements, one body.
        let body = std::sync::Arc::new(Expr::call(
            "a_rather_long_kernel_name_to_make_body_bytes_visible",
            vec![Expr::var("x")],
        ));
        let encoded_len = |n: usize| {
            let chunk = Expr::map_chunk(
                "x",
                std::sync::Arc::clone(&body),
                (0..n as i64).map(Value::I64).collect(),
                0,
            );
            let mut e = Encoder::new();
            enc_expr(&mut e, &chunk);
            e.into_bytes().len()
        };
        let one = encoded_len(1);
        let hundred = encoded_len(100);
        // Growth is per-element value bytes (9 each for I64), not per-body.
        assert_eq!(hundred - one, 99 * 9, "chunk must grow by elements only");
    }

    #[test]
    fn task_size_hint_covers_tensor_payload() {
        let mut globals = Env::new();
        globals.insert("t", Value::Tensor(Tensor::zeros(&[1 << 14])));
        let task = TaskSpec {
            id: "t-1".into(),
            expr: Expr::prim(PrimOp::Sum, vec![Expr::var("t")]),
            globals,
            opts: TaskOpts::default(),
        };
        let hint = task_size_hint(&task);
        let actual = encode_task_message(&task).len();
        // The hint must cover at least the dominant payload bytes so the
        // encoder allocates once, and stay within 2x of the actual size.
        assert!(hint >= (1 << 14) * 4, "hint {hint} misses the payload");
        assert!(hint <= actual * 2, "hint {hint} vs actual {actual}");
    }

    #[test]
    fn task_roundtrips() {
        let mut globals = Env::new();
        globals.insert("x", Value::Tensor(Tensor::zeros(&[4])));
        let task = TaskSpec {
            id: "t-1".into(),
            expr: Expr::call("slow_fcn", vec![Expr::var("x")]),
            globals,
            opts: TaskOpts {
                seed: Some(42),
                stream_index: 7,
                capture_stdout: false,
                capture_conditions: true,
                label: Some("my future".into()),
                depth: 1,
                context: SessionContext {
                    session: 9,
                    nested_plan: vec![
                        PlanSpec::ThreadPool { workers: 3 },
                        PlanSpec::Sequential,
                    ],
                    retry: Some(
                        RetryPolicy::idempotent(3)
                            .with_backoff(std::time::Duration::from_millis(7), 1.5),
                    ),
                    counter_base: 11,
                },
                attempt: 2,
            },
        };
        let msg = Message::Task(task.clone());
        let decoded = decode_message(&encode_message(&msg)).unwrap();
        assert_eq!(decoded, msg);
    }

    #[test]
    fn session_context_roundtrips_all_fields() {
        for ctx in [
            SessionContext::default(),
            SessionContext {
                session: u64::MAX,
                nested_plan: vec![PlanSpec::Multiprocess { workers: 2 }],
                retry: None,
                counter_base: 0,
            },
            SessionContext {
                session: 3,
                nested_plan: vec![],
                retry: Some(RetryPolicy::idempotent(5)),
                counter_base: 1 << 40,
            },
        ] {
            let mut e = Encoder::new();
            enc_session_context(&mut e, &ctx);
            let bytes = e.into_bytes();
            let mut d = Decoder::new(&bytes);
            assert_eq!(dec_session_context(&mut d).unwrap(), ctx);
            assert!(d.finished());
        }
    }

    #[test]
    fn result_roundtrips_both_outcomes() {
        let ok = TaskResult {
            id: "a".into(),
            outcome: TaskOutcome::Ok(Value::F64(1.5)),
            captured: Captured {
                stdout: "hello\n".into(),
                conditions: vec![Condition {
                    kind: ConditionKind::Warning,
                    message: "careful".into(),
                    seq: 0,
                }],
                rng_used: true,
            },
            metrics: TaskMetrics { started_ns: 10, finished_ns: 30 },
            attempt: 1,
        };
        assert_eq!(
            decode_message(&encode_message(&Message::Result(ok.clone()))).unwrap(),
            Message::Result(ok)
        );

        let err = TaskResult {
            id: "b".into(),
            outcome: TaskOutcome::Err(EvalError::with_call("boom", "log(x)")),
            captured: Captured::default(),
            metrics: TaskMetrics::default(),
            attempt: 0,
        };
        assert_eq!(
            decode_message(&encode_message(&Message::Result(err.clone()))).unwrap(),
            Message::Result(err)
        );
    }

    #[test]
    fn plan_specs_roundtrip() {
        for p in [
            PlanSpec::Sequential,
            PlanSpec::ThreadPool { workers: 2 },
            PlanSpec::Multiprocess { workers: 8 },
            PlanSpec::Cluster { hosts: vec!["n1".into(), "n2".into()] },
            PlanSpec::Batch { workers: 4, submit_latency_ms: 50, poll_interval_ms: 10 },
            PlanSpec::Custom { name: "redis".into(), workers: 3 },
        ] {
            let mut e = Encoder::new();
            enc_plan(&mut e, &p);
            let bytes = e.into_bytes();
            assert_eq!(dec_plan(&mut Decoder::new(&bytes)).unwrap(), p);
        }
    }

    #[test]
    fn control_messages_roundtrip() {
        for m in [
            Message::Hello { worker_id: "w1".into(), version: 1 },
            Message::Shutdown,
            Message::Ping,
            Message::Pong,
            Message::Immediate {
                task_id: "t".into(),
                condition: Condition {
                    kind: ConditionKind::Immediate,
                    message: "10%".into(),
                    seq: 3,
                },
            },
            Message::Heartbeat { task_id: "t-hb".into() },
            Message::Cancel { task_id: "t-cx".into() },
        ] {
            assert_eq!(decode_message(&encode_message(&m)).unwrap(), m);
        }
    }

    #[test]
    fn corrupt_bytes_fail_cleanly() {
        assert!(decode_message(&[]).is_err());
        assert!(decode_message(&[99]).is_err());
        // Truncated task message.
        let msg = Message::Task(TaskSpec {
            id: "x".into(),
            expr: Expr::lit(1.0),
            globals: Env::new(),
            opts: TaskOpts::default(),
        });
        let bytes = encode_message(&msg);
        assert!(decode_message(&bytes[..bytes.len() - 3]).is_err());
        // Trailing garbage.
        let mut extended = bytes.clone();
        extended.push(0);
        assert!(decode_message(&extended).is_err());
    }
}
