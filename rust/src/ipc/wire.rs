//! Binary wire format v6 — the serialization substrate (R's `serialize()`
//! analog; serde is unavailable in this offline image, so this is a
//! from-scratch, versioned, tagged little-endian encoding).
//!
//! **WIRE.md at the repository root is the normative specification** of
//! this format (frame grammar, tag tables, codec, interning protocol,
//! version rules); this module is the reference implementation, and the
//! `wire_spec` integration test asserts the two agree constant-by-constant.
//!
//! Every type that crosses a process boundary round-trips through
//! [`Encoder`]/[`Decoder`]: values, expressions, captured globals,
//! conditions, task specs and results, plan topologies, and the
//! [`Message`] envelope. Tags are one byte; counts and lengths are LEB128
//! varints; semantic integers (seeds, session ids, nanosecond clocks) stay
//! fixed-width u64/i64 LE; floats are IEEE-754 bits.
//!
//! A v6 frame is self-describing: `magic "RF" + version + frame-kind +
//! codec + varint body length + body`, where the body may be compressed
//! ([`crate::ipc::codec`]) and large captured globals / hot `MapChunk`
//! bodies may be replaced by 16-byte content digests
//! ([`crate::ipc::intern`]).
//!
//! Primitive round-trip:
//!
//! ```
//! use rustures::ipc::wire::{Decoder, Encoder};
//!
//! let mut e = Encoder::new();
//! e.varint(300);
//! e.str("hello");
//! let bytes = e.into_bytes();
//!
//! let mut d = Decoder::new(&bytes);
//! assert_eq!(d.varint().unwrap(), 300);
//! assert_eq!(d.str().unwrap(), "hello");
//! assert!(d.finished());
//! ```
//!
//! Whole-frame round-trip:
//!
//! ```
//! use rustures::ipc::{wire, Message};
//!
//! let frame = wire::encode_message(&Message::Ping);
//! assert_eq!(frame[0..2], wire::MAGIC);
//! assert_eq!(wire::decode_message(&frame).unwrap(), Message::Ping);
//! ```

use std::collections::HashMap;
use std::sync::Arc;

use crate::api::conditions::{Captured, Condition, ConditionKind};
use crate::api::env::Env;
use crate::api::error::EvalError;
use crate::api::expr::{EmitKind, Expr, PrimOp, RngDist};
use crate::api::plan::PlanSpec;
use crate::api::value::{Tensor, Value};
use crate::backend::supervisor::RetryPolicy;
use crate::ipc::codec;
use crate::ipc::intern::{self, Digest, InternCache, InternedBlob, SeatLedger};
use crate::ipc::{
    Message, SessionContext, TaskMetrics, TaskOpts, TaskOutcome, TaskResult, TaskSpec,
    PROTOCOL_VERSION,
};

// ------------------------------------------------------------ tag tables --
//
// These tables are the single in-code source of truth for every tag byte;
// WIRE.md documents the same tables and tests/wire_spec.rs asserts the two
// never drift. Keep them sorted by tag.

/// Frame kind byte → name (WIRE.md §Frame kinds).
pub const FRAME_KIND_TABLE: &[(u8, &str)] = &[
    (0, "Hello"),
    (1, "Task"),
    (2, "Immediate"),
    (3, "Result"),
    (4, "Shutdown"),
    (5, "Ping"),
    (6, "Pong"),
    (7, "Heartbeat"),
    (8, "Cancel"),
    (9, "NeedBlob"),
    (10, "Blob"),
    (11, "Forward"),
];

/// Value tag byte → name (WIRE.md §Values).
pub const VALUE_TAG_TABLE: &[(u8, &str)] = &[
    (0, "Unit"),
    (1, "Bool"),
    (2, "I64"),
    (3, "F64"),
    (4, "Str"),
    (5, "Tensor"),
    (6, "List"),
    (7, "ValueRef"),
];

/// Expression tag byte → name (WIRE.md §Expressions).
pub const EXPR_TAG_TABLE: &[(u8, &str)] = &[
    (0, "Lit"),
    (1, "Var"),
    (2, "Let"),
    (3, "Seq"),
    (4, "List"),
    (5, "Index"),
    (6, "Call"),
    (7, "Prim"),
    (8, "If"),
    (9, "DynLookup"),
    (10, "Emit"),
    (11, "Stop"),
    (12, "Rng"),
    (13, "WithRngStream"),
    (14, "Spin"),
    (15, "Sleep"),
    (16, "Work"),
    (17, "MapChunk"),
    (18, "ChaosKill"),
    (19, "ChaosHang"),
    (20, "ExprRef"),
    (21, "Await"),
];

/// Plan tag byte → name (WIRE.md §Plans).
pub const PLAN_TAG_TABLE: &[(u8, &str)] = &[
    (0, "Sequential"),
    (1, "ThreadPool"),
    (2, "Multiprocess"),
    (3, "Cluster"),
    (4, "Batch"),
    (5, "Custom"),
];

/// Primitive-op tag byte → name (WIRE.md §Expressions).
pub const PRIM_TAG_TABLE: &[(u8, &str)] = &[
    (0, "Add"),
    (1, "Sub"),
    (2, "Mul"),
    (3, "Div"),
    (4, "Neg"),
    (5, "Lt"),
    (6, "Le"),
    (7, "Eq"),
    (8, "Not"),
    (9, "Len"),
    (10, "Sum"),
    (11, "Mean"),
    (12, "Sqrt"),
    (13, "Concat"),
];

/// Emit-kind tag byte → name (WIRE.md §Expressions).
pub const EMIT_TAG_TABLE: &[(u8, &str)] =
    &[(0, "Stdout"), (1, "Message"), (2, "Warning"), (3, "Progress")];

/// Condition-kind tag byte → name (WIRE.md §Conditions).
pub const CONDITION_TAG_TABLE: &[(u8, &str)] =
    &[(0, "Message"), (1, "Warning"), (2, "Immediate")];

/// RNG distribution tag byte → name (WIRE.md §Expressions).
pub const RNG_DIST_TABLE: &[(u8, &str)] = &[(0, "Unif"), (1, "Norm")];

/// Codec byte → name (WIRE.md §Codec).
pub const CODEC_TABLE: &[(u8, &str)] = &[(0, "Raw"), (1, "DeltaRle")];

/// Human name for a frame kind byte (used by [`WireError`]'s `Display`).
pub fn frame_kind_name(kind: u8) -> &'static str {
    FRAME_KIND_TABLE.iter().find(|(k, _)| *k == kind).map(|(_, n)| *n).unwrap_or("unknown")
}

// --------------------------------------------------------------- errors --

/// Structured decode failure: byte offset, the frame kind being decoded
/// (when known), and a typed [`WireErrorKind`] that preserves expected vs.
/// found bytes instead of flattening them into free text.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WireError {
    /// Byte offset (within the failing buffer) where decoding stopped.
    pub offset: usize,
    /// Frame kind byte of the enclosing frame, when the header was parsed.
    pub frame: Option<u8>,
    /// What went wrong.
    pub kind: WireErrorKind,
}

/// Typed decode failure cases (WIRE.md §Errors lists the normative set).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WireErrorKind {
    /// The buffer ended before a fixed-width read completed.
    Truncated {
        /// Bytes the read needed.
        needed: usize,
        /// Bytes actually remaining.
        remaining: usize,
    },
    /// The first two frame bytes were not the `"RF"` magic.
    BadMagic {
        /// The two bytes found instead.
        found: [u8; 2],
    },
    /// The frame's version byte differs from this build's protocol version.
    BadVersion {
        /// Version byte on the wire.
        found: u8,
        /// Version this build speaks.
        expected: u8,
    },
    /// The frame-kind byte is outside [`FRAME_KIND_TABLE`].
    BadFrameKind {
        /// The unknown kind byte.
        found: u8,
    },
    /// The codec byte is outside [`CODEC_TABLE`].
    BadCodec {
        /// The unknown codec byte.
        found: u8,
    },
    /// A tag byte did not match any variant of the record being decoded.
    BadTag {
        /// Which tag table was being consulted (e.g. `"Value"`, `"Expr"`).
        what: &'static str,
        /// The tag byte found.
        found: u8,
    },
    /// A length prefix claims more bytes than remain in the buffer — the
    /// decoder rejects *before* allocating.
    LengthOverflow {
        /// Which length field overflowed (e.g. `"string"`, `"frame body"`).
        what: &'static str,
        /// The claimed element count / byte length.
        length: u64,
        /// Bytes actually remaining.
        remaining: usize,
    },
    /// A varint continued past 64 bits.
    VarintOverflow,
    /// A string field held invalid UTF-8.
    BadUtf8,
    /// Bytes remained after the record was fully decoded.
    TrailingBytes {
        /// How many bytes were left over.
        count: usize,
    },
    /// An interned reference named a digest absent from the decode cache
    /// (recovered out-of-band via the `NeedBlob` protocol).
    MissingBlob {
        /// The digest that missed.
        digest: Digest,
    },
    /// Any other semantic violation (shape mismatches, codec stream
    /// corruption) with a free-text description.
    Invalid(String),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "wire decode error at byte {}", self.offset)?;
        if let Some(k) = self.frame {
            write!(f, " in {} frame", frame_kind_name(k))?;
        }
        write!(f, ": ")?;
        match &self.kind {
            WireErrorKind::Truncated { needed, remaining } => {
                write!(f, "truncated: need {needed} bytes, {remaining} remain")
            }
            WireErrorKind::BadMagic { found } => {
                write!(f, "bad magic {:02x}{:02x} (want \"RF\")", found[0], found[1])
            }
            WireErrorKind::BadVersion { found, expected } => {
                write!(f, "protocol version {found} (this build speaks {expected})")
            }
            WireErrorKind::BadFrameKind { found } => write!(f, "unknown frame kind {found}"),
            WireErrorKind::BadCodec { found } => write!(f, "unknown codec {found}"),
            WireErrorKind::BadTag { what, found } => {
                write!(f, "bad {what} tag: found {found}")
            }
            WireErrorKind::LengthOverflow { what, length, remaining } => {
                write!(f, "{what} length {length} exceeds {remaining} remaining bytes")
            }
            WireErrorKind::VarintOverflow => write!(f, "varint exceeds 64 bits"),
            WireErrorKind::BadUtf8 => write!(f, "invalid UTF-8"),
            WireErrorKind::TrailingBytes { count } => write!(f, "{count} trailing bytes"),
            WireErrorKind::MissingBlob { digest } => {
                write!(f, "interned blob {digest} not in cache")
            }
            WireErrorKind::Invalid(m) => write!(f, "{m}"),
        }
    }
}

impl std::error::Error for WireError {}

// -------------------------------------------------------------- encoder --

/// Append-only byte sink for the v6 encoding primitives.
pub struct Encoder {
    buf: Vec<u8>,
}

impl Default for Encoder {
    fn default() -> Self {
        Self::new()
    }
}

impl Encoder {
    /// Encoder with a small default buffer.
    pub fn new() -> Self {
        Encoder { buf: Vec::with_capacity(256) }
    }

    /// §Perf: size-hinted construction — callers that know the payload size
    /// (task encoders sum their tensor buffers) allocate once instead of
    /// doubling through megabytes.
    pub fn with_capacity(bytes: usize) -> Self {
        Encoder { buf: Vec::with_capacity(bytes.max(64)) }
    }

    /// Consume the encoder, returning the encoded bytes.
    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    /// Append one raw byte (tag bytes, flags).
    #[inline]
    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    /// Append a fixed-width u32 LE (legacy fixed-width records only; new
    /// counts use [`Encoder::varint`]).
    #[inline]
    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a fixed-width u64 LE (semantic integers: ids, seeds, clocks).
    #[inline]
    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append a fixed-width i64 LE.
    #[inline]
    pub fn i64(&mut self, v: i64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Append an f64 as IEEE-754 bits, LE.
    #[inline]
    pub fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_bits().to_le_bytes());
    }

    /// Append a bool as one byte (0 or 1).
    #[inline]
    pub fn bool(&mut self, v: bool) {
        self.buf.push(v as u8);
    }

    /// Append a LEB128 varint (WIRE.md §Varints): 7 value bits per byte,
    /// low bits first, high bit = continuation. Counts and lengths use
    /// this; a length under 128 costs one byte instead of four.
    pub fn varint(&mut self, mut v: u64) {
        loop {
            let b = (v & 0x7f) as u8;
            v >>= 7;
            if v == 0 {
                self.buf.push(b);
                break;
            }
            self.buf.push(b | 0x80);
        }
    }

    /// Append raw bytes verbatim (blob payloads).
    pub fn raw(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Append a 16-byte content [`Digest`].
    pub fn digest(&mut self, d: &Digest) {
        self.buf.extend_from_slice(&d.0);
    }

    /// Append a varint-length-prefixed UTF-8 string.
    pub fn str(&mut self, s: &str) {
        self.varint(s.len() as u64);
        self.buf.extend_from_slice(s.as_bytes());
    }

    /// Append a varint-count-prefixed f32 buffer.
    pub fn f32_slice(&mut self, data: &[f32]) {
        self.varint(data.len() as u64);
        #[cfg(target_endian = "little")]
        {
            // §Perf: on LE targets the in-memory f32 layout *is* the wire
            // layout — one memcpy instead of per-element conversion.
            let bytes = unsafe {
                std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
            };
            self.buf.extend_from_slice(bytes);
        }
        #[cfg(not(target_endian = "little"))]
        {
            self.buf.reserve(data.len() * 4);
            for v in data {
                self.buf.extend_from_slice(&v.to_bits().to_le_bytes());
            }
        }
    }

    fn opt_str(&mut self, s: &Option<String>) {
        match s {
            Some(s) => {
                self.bool(true);
                self.str(s);
            }
            None => self.bool(false),
        }
    }

    fn opt_u64(&mut self, v: &Option<u64>) {
        match v {
            Some(v) => {
                self.bool(true);
                self.u64(*v);
            }
            None => self.bool(false),
        }
    }
}

// -------------------------------------------------------------- decoder --

/// Cursor over an encoded buffer. Never panics on malformed input: every
/// read validates against the remaining bytes and returns a structured
/// [`WireError`]. Optionally carries an [`InternCache`] so interned
/// references (`ValueRef`/`ExprRef`) resolve to previously provided blobs.
pub struct Decoder<'a> {
    bytes: &'a [u8],
    pos: usize,
    frame: Option<u8>,
    cache: Option<&'a InternCache>,
    local: Option<InternCache>,
}

impl<'a> Decoder<'a> {
    /// Decoder without an intern cache: provides carried *in* the buffer
    /// still resolve (a lazily created frame-local cache holds them), but
    /// references to blobs from earlier frames miss with
    /// [`WireErrorKind::MissingBlob`].
    pub fn new(bytes: &'a [u8]) -> Self {
        Decoder { bytes, pos: 0, frame: None, cache: None, local: None }
    }

    /// Decoder backed by a long-lived worker [`InternCache`]: provides are
    /// installed into it and references resolve across frames.
    pub fn with_cache(bytes: &'a [u8], cache: &'a InternCache) -> Self {
        Decoder { bytes, pos: 0, frame: None, cache: Some(cache), local: None }
    }

    /// True when every byte has been consumed.
    pub fn finished(&self) -> bool {
        self.pos == self.bytes.len()
    }

    fn err_kind(&self, kind: WireErrorKind) -> WireError {
        WireError { offset: self.pos, frame: self.frame, kind }
    }

    fn err(&self, msg: &str) -> WireError {
        self.err_kind(WireErrorKind::Invalid(msg.to_string()))
    }

    fn bad_tag(&self, what: &'static str, found: u8) -> WireError {
        self.err_kind(WireErrorKind::BadTag { what, found })
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        let remaining = self.bytes.len() - self.pos;
        if n > remaining {
            return Err(self.err_kind(WireErrorKind::Truncated { needed: n, remaining }));
        }
        let s = &self.bytes[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    /// Read one byte.
    pub fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    /// Read a fixed-width u32 LE.
    pub fn u32(&mut self) -> Result<u32, WireError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    /// Read a fixed-width u64 LE.
    pub fn u64(&mut self) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// Read a fixed-width i64 LE.
    pub fn i64(&mut self) -> Result<i64, WireError> {
        Ok(i64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// Read an f64 from IEEE-754 bits.
    pub fn f64(&mut self) -> Result<f64, WireError> {
        Ok(f64::from_bits(self.u64()?))
    }

    /// Read a one-byte bool (any nonzero byte is `true`).
    pub fn bool(&mut self) -> Result<bool, WireError> {
        Ok(self.u8()? != 0)
    }

    /// Read a LEB128 varint, rejecting encodings past 64 bits.
    pub fn varint(&mut self) -> Result<u64, WireError> {
        let mut out = 0u64;
        let mut shift = 0u32;
        loop {
            let b = self.u8()?;
            if shift >= 64 || (shift == 63 && (b & 0x7f) > 1) {
                return Err(self.err_kind(WireErrorKind::VarintOverflow));
            }
            out |= u64::from(b & 0x7f) << shift;
            if b & 0x80 == 0 {
                return Ok(out);
            }
            shift += 7;
        }
    }

    /// Read a varint element count whose elements each occupy at least
    /// `elem_min` bytes, rejecting counts the remaining buffer cannot
    /// possibly satisfy — *before* any allocation sized by the count.
    fn len_varint(&mut self, elem_min: usize, what: &'static str) -> Result<usize, WireError> {
        let n = self.varint()?;
        let remaining = self.bytes.len() - self.pos;
        if n.checked_mul(elem_min as u64).map_or(true, |need| need > remaining as u64) {
            return Err(self.err_kind(WireErrorKind::LengthOverflow {
                what,
                length: n,
                remaining,
            }));
        }
        Ok(n as usize)
    }

    /// Read a 16-byte content [`Digest`].
    pub fn digest(&mut self) -> Result<Digest, WireError> {
        let raw = self.take(16)?;
        let mut out = [0u8; 16];
        out.copy_from_slice(raw);
        Ok(Digest(out))
    }

    /// Read a varint-length-prefixed UTF-8 string.
    pub fn str(&mut self) -> Result<String, WireError> {
        let n = self.len_varint(1, "string")?;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| self.err_kind(WireErrorKind::BadUtf8))
    }

    /// Decode a varint-count-prefixed f32 buffer into the **shared**
    /// allocation [`Tensor`] stores. §Perf: `from_le_bytes` is a no-op on
    /// LE targets, so the loop compiles to a bulk copy; collecting from a
    /// `chunks_exact` iterator lets the standard library write the `Arc`
    /// allocation directly when it can (and costs at most one intermediate
    /// buffer otherwise — safely, with no unsafe reinterpret).
    pub fn f32_arc(&mut self) -> Result<std::sync::Arc<[f32]>, WireError> {
        let n = self.len_varint(4, "tensor data")?;
        let raw = self.take(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn opt_str(&mut self) -> Result<Option<String>, WireError> {
        Ok(if self.bool()? { Some(self.str()?) } else { None })
    }

    fn opt_u64(&mut self) -> Result<Option<u64>, WireError> {
        Ok(if self.bool()? { Some(self.u64()?) } else { None })
    }

    /// Install a provided blob into the active cache (the shared worker
    /// cache when present, else a lazily created frame-local one).
    fn install_blob(&mut self, d: Digest, blob: InternedBlob) {
        match self.cache {
            Some(c) => c.insert(d, blob),
            None => self.local.get_or_insert_with(InternCache::new).insert(d, blob),
        }
    }

    fn value_blob(&self, dg: &Digest) -> Result<Value, WireError> {
        let hit = match self.cache {
            Some(c) => c.value(dg),
            None => self.local.as_ref().and_then(|c| c.value(dg)),
        };
        hit.ok_or_else(|| self.err_kind(WireErrorKind::MissingBlob { digest: *dg }))
    }

    fn expr_blob(&self, dg: &Digest) -> Result<Arc<Expr>, WireError> {
        let hit = match self.cache {
            Some(c) => c.expr(dg),
            None => self.local.as_ref().and_then(|c| c.expr(dg)),
        };
        hit.ok_or_else(|| self.err_kind(WireErrorKind::MissingBlob { digest: *dg }))
    }
}

// ---------------------------------------------------------------- Value --

/// Encode a [`Value`] (tag byte + payload, [`VALUE_TAG_TABLE`]).
pub fn enc_value(e: &mut Encoder, v: &Value) {
    match v {
        Value::Unit => e.u8(0),
        Value::Bool(b) => {
            e.u8(1);
            e.bool(*b);
        }
        Value::I64(v) => {
            e.u8(2);
            e.i64(*v);
        }
        Value::F64(v) => {
            e.u8(3);
            e.f64(*v);
        }
        Value::Str(s) => {
            e.u8(4);
            e.str(s);
        }
        Value::Tensor(t) => {
            e.u8(5);
            e.varint(t.shape.len() as u64);
            for d in &t.shape {
                e.varint(*d as u64);
            }
            e.f32_slice(&t.data);
        }
        Value::List(items) => {
            e.u8(6);
            e.varint(items.len() as u64);
            for item in items {
                enc_value(e, item);
            }
        }
    }
}

/// Decode a [`Value`]. Tag 7 (`ValueRef`) resolves through the decoder's
/// intern cache and fails with [`WireErrorKind::MissingBlob`] on a miss.
pub fn dec_value(d: &mut Decoder) -> Result<Value, WireError> {
    Ok(match d.u8()? {
        0 => Value::Unit,
        1 => Value::Bool(d.bool()?),
        2 => Value::I64(d.i64()?),
        3 => Value::F64(d.f64()?),
        4 => Value::Str(d.str()?),
        5 => {
            let rank = d.len_varint(1, "tensor shape")?;
            let mut shape = Vec::with_capacity(rank);
            for _ in 0..rank {
                shape.push(d.varint()? as usize);
            }
            let data = d.f32_arc()?;
            let mut need: usize = 1;
            for &dim in &shape {
                need = need
                    .checked_mul(dim)
                    .ok_or_else(|| d.err("tensor shape product overflows"))?;
            }
            if need != data.len() {
                return Err(d.err(&format!(
                    "tensor shape wants {need} elements, data has {}",
                    data.len()
                )));
            }
            Value::Tensor(Tensor::from_shared(shape, data).map_err(|m| d.err(&m))?)
        }
        6 => {
            let n = d.len_varint(1, "list items")?;
            let mut items = Vec::with_capacity(n);
            for _ in 0..n {
                items.push(dec_value(d)?);
            }
            Value::List(items)
        }
        7 => {
            let dg = d.digest()?;
            d.value_blob(&dg)?
        }
        t => return Err(d.bad_tag("Value", t)),
    })
}

// ----------------------------------------------------------------- Expr --

fn prim_tag(op: PrimOp) -> u8 {
    match op {
        PrimOp::Add => 0,
        PrimOp::Sub => 1,
        PrimOp::Mul => 2,
        PrimOp::Div => 3,
        PrimOp::Neg => 4,
        PrimOp::Lt => 5,
        PrimOp::Le => 6,
        PrimOp::Eq => 7,
        PrimOp::Not => 8,
        PrimOp::Len => 9,
        PrimOp::Sum => 10,
        PrimOp::Mean => 11,
        PrimOp::Sqrt => 12,
        PrimOp::Concat => 13,
    }
}

fn prim_from(tag: u8, d: &Decoder) -> Result<PrimOp, WireError> {
    Ok(match tag {
        0 => PrimOp::Add,
        1 => PrimOp::Sub,
        2 => PrimOp::Mul,
        3 => PrimOp::Div,
        4 => PrimOp::Neg,
        5 => PrimOp::Lt,
        6 => PrimOp::Le,
        7 => PrimOp::Eq,
        8 => PrimOp::Not,
        9 => PrimOp::Len,
        10 => PrimOp::Sum,
        11 => PrimOp::Mean,
        12 => PrimOp::Sqrt,
        13 => PrimOp::Concat,
        t => return Err(d.bad_tag("PrimOp", t)),
    })
}

fn emit_tag(k: EmitKind) -> u8 {
    match k {
        EmitKind::Stdout => 0,
        EmitKind::Message => 1,
        EmitKind::Warning => 2,
        EmitKind::Progress => 3,
    }
}

fn emit_from(tag: u8, d: &Decoder) -> Result<EmitKind, WireError> {
    Ok(match tag {
        0 => EmitKind::Stdout,
        1 => EmitKind::Message,
        2 => EmitKind::Warning,
        3 => EmitKind::Progress,
        t => return Err(d.bad_tag("EmitKind", t)),
    })
}

fn enc_exprs(e: &mut Encoder, items: &[Expr]) {
    e.varint(items.len() as u64);
    for item in items {
        enc_expr(e, item);
    }
}

fn dec_exprs(d: &mut Decoder) -> Result<Vec<Expr>, WireError> {
    let n = d.len_varint(1, "expression list")?;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(dec_expr(d)?);
    }
    Ok(out)
}

/// Encode an [`Expr`] (tag byte + payload, [`EXPR_TAG_TABLE`]).
pub fn enc_expr(e: &mut Encoder, expr: &Expr) {
    match expr {
        Expr::Lit(v) => {
            e.u8(0);
            enc_value(e, v);
        }
        Expr::Var(name) => {
            e.u8(1);
            e.str(name);
        }
        Expr::Let { name, value, body } => {
            e.u8(2);
            e.str(name);
            enc_expr(e, value);
            enc_expr(e, body);
        }
        Expr::Seq(items) => {
            e.u8(3);
            enc_exprs(e, items);
        }
        Expr::List(items) => {
            e.u8(4);
            enc_exprs(e, items);
        }
        Expr::Index { list, index } => {
            e.u8(5);
            enc_expr(e, list);
            enc_expr(e, index);
        }
        Expr::Call { kernel, args } => {
            e.u8(6);
            e.str(kernel);
            enc_exprs(e, args);
        }
        Expr::Prim { op, args } => {
            e.u8(7);
            e.u8(prim_tag(*op));
            enc_exprs(e, args);
        }
        Expr::If { cond, then, otherwise } => {
            e.u8(8);
            enc_expr(e, cond);
            enc_expr(e, then);
            enc_expr(e, otherwise);
        }
        Expr::DynLookup(inner) => {
            e.u8(9);
            enc_expr(e, inner);
        }
        Expr::Emit { kind, message } => {
            e.u8(10);
            e.u8(emit_tag(*kind));
            enc_expr(e, message);
        }
        Expr::Stop(inner) => {
            e.u8(11);
            enc_expr(e, inner);
        }
        Expr::Rng { dist, shape } => {
            e.u8(12);
            e.u8(match dist {
                RngDist::Unif => 0,
                RngDist::Norm => 1,
            });
            e.varint(shape.len() as u64);
            for d in shape {
                e.varint(*d as u64);
            }
        }
        Expr::WithRngStream { index, body } => {
            e.u8(13);
            e.u64(*index);
            enc_expr(e, body);
        }
        Expr::Spin { millis } => {
            e.u8(14);
            e.u64(*millis);
        }
        Expr::Sleep { millis } => {
            e.u8(15);
            e.u64(*millis);
        }
        Expr::Work { iters } => {
            e.u8(16);
            e.u64(*iters);
        }
        Expr::MapChunk { param, body, elements, base_index } => {
            // §Perf: the body is encoded ONCE per chunk, followed by the
            // packed element values — serializing backends pay O(|body| +
            // Σ|elements|) instead of O(n·|body|).
            e.u8(17);
            e.str(param);
            e.u64(*base_index);
            enc_expr(e, body);
            e.varint(elements.len() as u64);
            for v in elements {
                enc_value(e, v);
            }
        }
        Expr::ChaosKill { marker } => {
            e.u8(18);
            match marker {
                Some(m) => {
                    e.u8(1);
                    e.str(m);
                }
                None => e.u8(0),
            }
        }
        Expr::ChaosHang { millis, marker } => {
            e.u8(19);
            e.u64(*millis);
            match marker {
                Some(m) => {
                    e.u8(1);
                    e.str(m);
                }
                None => e.u8(0),
            }
        }
        Expr::Await { future_id } => {
            e.u8(21);
            e.str(future_id);
        }
    }
}

/// Decode an [`Expr`]. Tag 20 (`ExprRef`) resolves through the decoder's
/// intern cache; inside a `MapChunk` (tag 17) the body slot may itself be
/// an `ExprRef`, which shares the cached `Arc` directly.
pub fn dec_expr(d: &mut Decoder) -> Result<Expr, WireError> {
    let tag = d.u8()?;
    dec_expr_tagged(d, tag)
}

fn dec_expr_tagged(d: &mut Decoder, tag: u8) -> Result<Expr, WireError> {
    Ok(match tag {
        0 => Expr::Lit(dec_value(d)?),
        1 => Expr::Var(d.str()?),
        2 => {
            let name = d.str()?;
            let value = Box::new(dec_expr(d)?);
            let body = Box::new(dec_expr(d)?);
            Expr::Let { name, value, body }
        }
        3 => Expr::Seq(dec_exprs(d)?),
        4 => Expr::List(dec_exprs(d)?),
        5 => {
            let list = Box::new(dec_expr(d)?);
            let index = Box::new(dec_expr(d)?);
            Expr::Index { list, index }
        }
        6 => {
            let kernel = d.str()?;
            let args = dec_exprs(d)?;
            Expr::Call { kernel, args }
        }
        7 => {
            let tag = d.u8()?;
            let op = prim_from(tag, d)?;
            let args = dec_exprs(d)?;
            Expr::Prim { op, args }
        }
        8 => {
            let cond = Box::new(dec_expr(d)?);
            let then = Box::new(dec_expr(d)?);
            let otherwise = Box::new(dec_expr(d)?);
            Expr::If { cond, then, otherwise }
        }
        9 => Expr::DynLookup(Box::new(dec_expr(d)?)),
        10 => {
            let tag = d.u8()?;
            let kind = emit_from(tag, d)?;
            Expr::Emit { kind, message: Box::new(dec_expr(d)?) }
        }
        11 => Expr::Stop(Box::new(dec_expr(d)?)),
        12 => {
            let dist = match d.u8()? {
                0 => RngDist::Unif,
                1 => RngDist::Norm,
                t => return Err(d.bad_tag("RngDist", t)),
            };
            let rank = d.len_varint(1, "rng shape")?;
            let mut shape = Vec::with_capacity(rank);
            for _ in 0..rank {
                shape.push(d.varint()? as usize);
            }
            Expr::Rng { dist, shape }
        }
        13 => {
            let index = d.u64()?;
            Expr::WithRngStream { index, body: Box::new(dec_expr(d)?) }
        }
        14 => Expr::Spin { millis: d.u64()? },
        15 => Expr::Sleep { millis: d.u64()? },
        16 => Expr::Work { iters: d.u64()? },
        17 => {
            let param = d.str()?;
            let base_index = d.u64()?;
            let btag = d.u8()?;
            let body = if btag == 20 {
                let dg = d.digest()?;
                d.expr_blob(&dg)?
            } else {
                Arc::new(dec_expr_tagged(d, btag)?)
            };
            let n = d.len_varint(1, "chunk elements")?;
            let mut elements = Vec::with_capacity(n);
            for _ in 0..n {
                elements.push(dec_value(d)?);
            }
            Expr::MapChunk { param, body, elements, base_index }
        }
        18 => {
            let marker = match d.u8()? {
                0 => None,
                1 => Some(d.str()?),
                t => return Err(d.bad_tag("ChaosKill marker flag", t)),
            };
            Expr::ChaosKill { marker }
        }
        19 => {
            let millis = d.u64()?;
            let marker = match d.u8()? {
                0 => None,
                1 => Some(d.str()?),
                t => return Err(d.bad_tag("ChaosHang marker flag", t)),
            };
            Expr::ChaosHang { millis, marker }
        }
        20 => {
            let dg = d.digest()?;
            let arc = d.expr_blob(&dg)?;
            (*arc).clone()
        }
        21 => Expr::Await { future_id: d.str()? },
        t => return Err(d.bad_tag("Expr", t)),
    })
}

// ------------------------------------------------------------------ Env --

/// Encode an [`Env`] of captured globals (count + name/value pairs).
pub fn enc_env(e: &mut Encoder, env: &Env) {
    e.varint(env.len() as u64);
    for (k, v) in env.iter() {
        e.str(k);
        enc_value(e, v);
    }
}

/// Decode an [`Env`] of captured globals.
pub fn dec_env(d: &mut Decoder) -> Result<Env, WireError> {
    let n = d.len_varint(2, "env entries")?;
    let mut env = Env::new();
    for _ in 0..n {
        let k = d.str()?;
        let v = dec_value(d)?;
        env.insert(&k, v);
    }
    Ok(env)
}

// ----------------------------------------------------------- Conditions --

fn cond_kind_tag(k: ConditionKind) -> u8 {
    match k {
        ConditionKind::Message => 0,
        ConditionKind::Warning => 1,
        ConditionKind::Immediate => 2,
    }
}

fn cond_kind_from(tag: u8, d: &Decoder) -> Result<ConditionKind, WireError> {
    Ok(match tag {
        0 => ConditionKind::Message,
        1 => ConditionKind::Warning,
        2 => ConditionKind::Immediate,
        t => return Err(d.bad_tag("ConditionKind", t)),
    })
}

/// Encode a relayed [`Condition`] ([`CONDITION_TAG_TABLE`]).
pub fn enc_condition(e: &mut Encoder, c: &Condition) {
    e.u8(cond_kind_tag(c.kind));
    e.str(&c.message);
    e.u64(c.seq);
}

/// Decode a relayed [`Condition`].
pub fn dec_condition(d: &mut Decoder) -> Result<Condition, WireError> {
    let tag = d.u8()?;
    let kind = cond_kind_from(tag, d)?;
    Ok(Condition { kind, message: d.str()?, seq: d.u64()? })
}

/// Encode a [`Captured`] record (stdout + conditions + RNG-used flag).
pub fn enc_captured(e: &mut Encoder, c: &Captured) {
    e.str(&c.stdout);
    e.varint(c.conditions.len() as u64);
    for cond in &c.conditions {
        enc_condition(e, cond);
    }
    e.bool(c.rng_used);
}

/// Decode a [`Captured`] record.
pub fn dec_captured(d: &mut Decoder) -> Result<Captured, WireError> {
    let stdout = d.str()?;
    let n = d.len_varint(10, "conditions")?;
    let mut conditions = Vec::with_capacity(n);
    for _ in 0..n {
        conditions.push(dec_condition(d)?);
    }
    Ok(Captured { stdout, conditions, rng_used: d.bool()? })
}

// ----------------------------------------------------------- PlanSpec ----

/// Encode a [`PlanSpec`] topology entry ([`PLAN_TAG_TABLE`]).
pub fn enc_plan(e: &mut Encoder, p: &PlanSpec) {
    match p {
        PlanSpec::Sequential => e.u8(0),
        PlanSpec::ThreadPool { workers } => {
            e.u8(1);
            e.varint(*workers as u64);
        }
        PlanSpec::Multiprocess { workers } => {
            e.u8(2);
            e.varint(*workers as u64);
        }
        PlanSpec::Cluster { hosts } => {
            e.u8(3);
            e.varint(hosts.len() as u64);
            for h in hosts {
                e.str(h);
            }
        }
        PlanSpec::Batch { workers, submit_latency_ms, poll_interval_ms } => {
            e.u8(4);
            e.varint(*workers as u64);
            e.u64(*submit_latency_ms);
            e.u64(*poll_interval_ms);
        }
        PlanSpec::Custom { name, workers } => {
            e.u8(5);
            e.str(name);
            e.varint(*workers as u64);
        }
    }
}

/// Decode a [`PlanSpec`] topology entry.
pub fn dec_plan(d: &mut Decoder) -> Result<PlanSpec, WireError> {
    Ok(match d.u8()? {
        0 => PlanSpec::Sequential,
        1 => PlanSpec::ThreadPool { workers: d.varint()? as usize },
        2 => PlanSpec::Multiprocess { workers: d.varint()? as usize },
        3 => {
            let n = d.len_varint(1, "hosts")?;
            let mut hosts = Vec::with_capacity(n);
            for _ in 0..n {
                hosts.push(d.str()?);
            }
            PlanSpec::Cluster { hosts }
        }
        4 => PlanSpec::Batch {
            workers: d.varint()? as usize,
            submit_latency_ms: d.u64()?,
            poll_interval_ms: d.u64()?,
        },
        5 => PlanSpec::Custom { name: d.str()?, workers: d.varint()? as usize },
        t => return Err(d.bad_tag("PlanSpec", t)),
    })
}

// ----------------------------------------------------------- Task types --

fn enc_retry(e: &mut Encoder, r: &Option<RetryPolicy>) {
    match r {
        Some(p) => {
            e.bool(true);
            e.varint(u64::from(p.max_attempts));
            e.u64(p.backoff.as_nanos() as u64);
            e.f64(p.factor);
            e.bool(p.idempotent);
        }
        None => e.bool(false),
    }
}

fn dec_retry(d: &mut Decoder) -> Result<Option<RetryPolicy>, WireError> {
    if !d.bool()? {
        return Ok(None);
    }
    let max_attempts = d.varint()? as u32;
    let backoff = std::time::Duration::from_nanos(d.u64()?);
    let factor = d.f64()?;
    let idempotent = d.bool()?;
    Ok(Some(RetryPolicy { max_attempts, backoff, factor, idempotent }))
}

/// Encode the session-context record: origin session id, topology tail,
/// plan-wide retry default, and the nested counter base.
pub fn enc_session_context(e: &mut Encoder, c: &SessionContext) {
    e.u64(c.session);
    e.varint(c.nested_plan.len() as u64);
    for p in &c.nested_plan {
        enc_plan(e, p);
    }
    enc_retry(e, &c.retry);
    e.u64(c.counter_base);
    e.varint(c.heartbeat_ms);
    e.varint(c.stall_after_ms);
}

/// Decode the session-context record.
pub fn dec_session_context(d: &mut Decoder) -> Result<SessionContext, WireError> {
    let session = d.u64()?;
    let n = d.len_varint(1, "nested plans")?;
    let mut nested_plan = Vec::with_capacity(n);
    for _ in 0..n {
        nested_plan.push(dec_plan(d)?);
    }
    let retry = dec_retry(d)?;
    let counter_base = d.u64()?;
    let heartbeat_ms = d.varint()?;
    let stall_after_ms = d.varint()?;
    Ok(SessionContext {
        session,
        nested_plan,
        retry,
        counter_base,
        heartbeat_ms,
        stall_after_ms,
    })
}

/// Encode per-task options (seed, streams, capture flags, context).
pub fn enc_task_opts(e: &mut Encoder, o: &TaskOpts) {
    e.opt_u64(&o.seed);
    e.u64(o.stream_index);
    e.bool(o.capture_stdout);
    e.bool(o.capture_conditions);
    e.opt_str(&o.label);
    e.varint(u64::from(o.depth));
    enc_session_context(e, &o.context);
    e.varint(u64::from(o.attempt));
    e.varint(o.pending.len() as u64);
    for id in &o.pending {
        e.str(id);
    }
}

/// Decode per-task options.
pub fn dec_task_opts(d: &mut Decoder) -> Result<TaskOpts, WireError> {
    let seed = d.opt_u64()?;
    let stream_index = d.u64()?;
    let capture_stdout = d.bool()?;
    let capture_conditions = d.bool()?;
    let label = d.opt_str()?;
    let depth = d.varint()? as u32;
    let context = dec_session_context(d)?;
    let attempt = d.varint()? as u32;
    let n = d.len_varint(1, "pending ids")?;
    let mut pending = Vec::with_capacity(n);
    for _ in 0..n {
        pending.push(d.str()?);
    }
    Ok(TaskOpts {
        seed,
        stream_index,
        capture_stdout,
        capture_conditions,
        label,
        depth,
        context,
        attempt,
        pending,
    })
}

// ------------------------------------------------------------ interning --

/// Encoded blob bytes for a value: kind byte 0 + the value encoding.
/// These bytes are what the intern store holds and what `Blob` frames and
/// task-frame provides carry.
pub fn value_blob_bytes(v: &Value) -> Vec<u8> {
    let mut e = Encoder::with_capacity(v.byte_size() + 16);
    e.u8(0);
    enc_value(&mut e, v);
    e.into_bytes()
}

/// Encoded blob bytes for an expression: kind byte 1 + the expression
/// encoding. Digested with [`intern::digest_bytes`] over exactly these
/// bytes, so the digest is trivially content-addressed.
pub fn expr_blob_bytes(x: &Expr) -> Vec<u8> {
    let mut e = Encoder::new();
    e.u8(1);
    enc_expr(&mut e, x);
    e.into_bytes()
}

/// Decode intern blob bytes (as produced by [`value_blob_bytes`] /
/// [`expr_blob_bytes`]) into an [`InternedBlob`].
pub fn decode_blob(bytes: &[u8]) -> Result<InternedBlob, WireError> {
    let mut d = Decoder::new(bytes);
    let blob = match d.u8()? {
        0 => InternedBlob::Value(dec_value(&mut d)?),
        1 => InternedBlob::Expr(Arc::new(dec_expr(&mut d)?)),
        t => return Err(d.bad_tag("blob kind", t)),
    };
    if !d.finished() {
        let count = d.bytes.len() - d.pos;
        return Err(d.err_kind(WireErrorKind::TrailingBytes { count }));
    }
    Ok(blob)
}

/// Which task slots encode as digest references instead of inline payloads.
struct RefPlan {
    globals: HashMap<String, Digest>,
    body: Option<Digest>,
}

/// Encode a task frame with content-hashed interning against one worker
/// seat's [`SeatLedger`]: captured globals and `MapChunk` bodies whose
/// encoded size reaches [`intern::INTERN_MIN`] are digested; blobs the seat
/// has not been provided yet ride in the frame's provide section, and
/// everything else is a 17-byte reference. Blob bytes are pinned in the
/// process-global intern store so a worker cache miss can be answered via
/// the `NeedBlob` protocol.
pub fn encode_task_message_interned(t: &TaskSpec, ledger: &mut SeatLedger) -> Vec<u8> {
    let session = t.opts.context.session;
    let mut provides: Vec<(Digest, Arc<Vec<u8>>)> = Vec::new();
    let mut plan = RefPlan { globals: HashMap::new(), body: None };
    for (name, value) in t.globals.iter() {
        if value.byte_size() < intern::INTERN_MIN {
            continue;
        }
        let dg = intern::digest_value(value);
        let bytes = intern::store_ensure(dg, || value_blob_bytes(value));
        if ledger.admit(dg) {
            intern::note_ref(session);
        } else {
            intern::note_provide(session);
            provides.push((dg, bytes));
        }
        plan.globals.insert(name.to_string(), dg);
    }
    if let Expr::MapChunk { body, .. } = &t.expr {
        let bytes = expr_blob_bytes(body);
        if bytes.len() - 1 >= intern::INTERN_MIN {
            let dg = intern::digest_bytes(&bytes);
            let shared = intern::store_ensure(dg, move || bytes);
            if ledger.admit(dg) {
                intern::note_ref(session);
            } else {
                intern::note_provide(session);
                provides.push((dg, shared));
            }
            plan.body = Some(dg);
        }
    }
    let mut e = Encoder::with_capacity(task_size_hint(t));
    e.varint(provides.len() as u64);
    for (dg, bytes) in &provides {
        e.digest(dg);
        e.varint(bytes.len() as u64);
        e.raw(bytes);
    }
    enc_task_record(&mut e, t, Some(&plan));
    finish_frame(1, e.into_bytes(), true)
}

/// Encode a [`TaskSpec`] body with no interning: an empty provide section
/// followed by the plain task record.
pub fn enc_task(e: &mut Encoder, t: &TaskSpec) {
    e.varint(0);
    enc_task_record(e, t, None);
}

fn enc_task_record(e: &mut Encoder, t: &TaskSpec, plan: Option<&RefPlan>) {
    e.str(&t.id);
    match (plan.and_then(|p| p.body), &t.expr) {
        (Some(dg), Expr::MapChunk { param, elements, base_index, .. }) => {
            e.u8(17);
            e.str(param);
            e.u64(*base_index);
            e.u8(20);
            e.digest(&dg);
            e.varint(elements.len() as u64);
            for v in elements {
                enc_value(e, v);
            }
        }
        _ => enc_expr(e, &t.expr),
    }
    let interned = plan.map(|p| &p.globals);
    e.varint(t.globals.len() as u64);
    for (k, v) in t.globals.iter() {
        e.str(k);
        match interned.and_then(|m| m.get(k)) {
            Some(dg) => {
                e.u8(7);
                e.digest(dg);
            }
            None => enc_value(e, v),
        }
    }
    enc_task_opts(e, &t.opts);
}

/// Decode a task body: install the provide section into the decoder's
/// intern cache, then decode the task record (whose `ValueRef`/`ExprRef`
/// slots resolve through that cache).
pub fn dec_task(d: &mut Decoder) -> Result<TaskSpec, WireError> {
    let n = d.len_varint(17, "intern provides")?;
    for _ in 0..n {
        let dg = d.digest()?;
        let len = d.len_varint(1, "intern blob")?;
        let bytes = d.take(len)?;
        let blob = decode_blob(bytes).map_err(|mut e| {
            e.frame = d.frame;
            e
        })?;
        d.install_blob(dg, blob);
    }
    Ok(TaskSpec {
        id: d.str()?,
        expr: dec_expr(d)?,
        globals: dec_env(d)?,
        opts: dec_task_opts(d)?,
    })
}

/// Approximate encoded size of a task (§Perf: drives
/// [`Encoder::with_capacity`] so tensor-heavy tasks — large captured
/// globals, packed `MapChunk` elements — serialize into one allocation).
/// Always an over-estimate of the *uncompressed* v6 encoding, which is
/// what lets `analysis::estimate_export_size` stay a sound upper bound.
pub fn task_size_hint(t: &TaskSpec) -> usize {
    let mut hint = 128 + t.id.len() + t.globals.byte_size();
    t.expr.walk(&mut |e| {
        hint += 8;
        match e {
            Expr::Lit(v) => hint += v.byte_size(),
            Expr::MapChunk { elements, .. } => {
                hint += elements.iter().map(crate::api::value::Value::byte_size).sum::<usize>()
            }
            _ => {}
        }
    });
    hint
}

/// Encode a task result (outcome, captured output, metrics, attempt).
pub fn enc_result(e: &mut Encoder, r: &TaskResult) {
    e.str(&r.id);
    enc_outcome(e, &r.outcome);
    enc_captured(e, &r.captured);
    e.u64(r.metrics.started_ns);
    e.u64(r.metrics.finished_ns);
    e.varint(u64::from(r.attempt));
}

/// Decode a task result.
pub fn dec_result(d: &mut Decoder) -> Result<TaskResult, WireError> {
    let id = d.str()?;
    let outcome = dec_outcome(d)?;
    let captured = dec_captured(d)?;
    let metrics = TaskMetrics { started_ns: d.u64()?, finished_ns: d.u64()? };
    let attempt = d.varint()? as u32;
    Ok(TaskResult { id, outcome, captured, metrics, attempt })
}

/// Encode a bare [`TaskOutcome`] (tag byte 0 = Ok + value, 1 = Err +
/// message + optional call) — shared by `Result` and `Forward` frames.
pub fn enc_outcome(e: &mut Encoder, outcome: &TaskOutcome) {
    match outcome {
        TaskOutcome::Ok(v) => {
            e.u8(0);
            enc_value(e, v);
        }
        TaskOutcome::Err(err) => {
            e.u8(1);
            e.str(&err.message);
            e.opt_str(&err.call);
        }
    }
}

/// Decode a bare [`TaskOutcome`].
pub fn dec_outcome(d: &mut Decoder) -> Result<TaskOutcome, WireError> {
    Ok(match d.u8()? {
        0 => TaskOutcome::Ok(dec_value(d)?),
        1 => {
            let message = d.str()?;
            let call = d.opt_str()?;
            TaskOutcome::Err(EvalError { message, call })
        }
        t => return Err(d.bad_tag("TaskOutcome", t)),
    })
}

// ------------------------------------------------------------- framing --

/// The two magic bytes opening every v6 frame.
pub const MAGIC: [u8; 2] = *b"RF";

/// Frame kind byte for a [`Message`] ([`FRAME_KIND_TABLE`]).
pub fn frame_kind(m: &Message) -> u8 {
    match m {
        Message::Hello { .. } => 0,
        Message::Task(_) => 1,
        Message::Immediate { .. } => 2,
        Message::Result(_) => 3,
        Message::Shutdown => 4,
        Message::Ping => 5,
        Message::Pong => 6,
        Message::Heartbeat { .. } => 7,
        Message::Cancel { .. } => 8,
        Message::NeedBlob { .. } => 9,
        Message::Blob { .. } => 10,
        Message::Forward { .. } => 11,
    }
}

/// Wrap an encoded body in the v6 frame header: magic + version + kind +
/// codec + varint body length. When `compress` is set the body goes
/// through [`codec::maybe_compress`] (which only picks the compressed
/// codec on a strict byte win).
fn finish_frame(kind: u8, body: Vec<u8>, compress: bool) -> Vec<u8> {
    let (codec_id, body) =
        if compress { codec::maybe_compress(body) } else { (codec::CODEC_RAW, body) };
    let mut out = Vec::with_capacity(body.len() + 16);
    out.extend_from_slice(&MAGIC);
    out.push(PROTOCOL_VERSION as u8);
    out.push(kind);
    out.push(codec_id);
    let mut len = body.len() as u64;
    loop {
        let b = (len & 0x7f) as u8;
        len >>= 7;
        if len == 0 {
            out.push(b);
            break;
        }
        out.push(b | 0x80);
    }
    out.extend_from_slice(&body);
    out
}

/// Encode a [`Message`] as a complete v6 frame (header + body), with
/// payload-bearing frames (`Task`/`Result`/`Blob`) eligible for
/// compression.
pub fn encode_message(m: &Message) -> Vec<u8> {
    encode_message_opts(m, true)
}

/// [`encode_message`] with explicit control over compression. Passing
/// `compress = false` yields the raw (still framed) encoding — the
/// baseline the benches and the export-size estimator compare against.
pub fn encode_message_opts(m: &Message, compress: bool) -> Vec<u8> {
    let mut e = match m {
        // §Perf: size-hinted buffer for the payload-bearing messages.
        Message::Task(t) => Encoder::with_capacity(task_size_hint(t)),
        Message::Result(r) => Encoder::with_capacity(64 + result_size_hint(r)),
        Message::Forward { future_id, outcome } => {
            Encoder::with_capacity(32 + future_id.len() + outcome_size_hint(outcome))
        }
        _ => Encoder::new(),
    };
    match m {
        Message::Hello { worker_id, version } => {
            e.str(worker_id);
            e.varint(u64::from(*version));
        }
        Message::Task(t) => enc_task(&mut e, t),
        Message::Immediate { task_id, condition } => {
            e.str(task_id);
            enc_condition(&mut e, condition);
        }
        Message::Result(r) => enc_result(&mut e, r),
        Message::Shutdown | Message::Ping | Message::Pong => {}
        Message::Heartbeat { task_id } => e.str(task_id),
        Message::Cancel { task_id } => e.str(task_id),
        Message::NeedBlob { digests } => {
            e.varint(digests.len() as u64);
            for dg in digests {
                e.digest(dg);
            }
        }
        Message::Blob { digest, bytes } => {
            e.digest(digest);
            match bytes {
                Some(b) => {
                    e.bool(true);
                    e.varint(b.len() as u64);
                    e.raw(b);
                }
                None => e.bool(false),
            }
        }
        Message::Forward { future_id, outcome } => {
            e.str(future_id);
            enc_outcome(e, outcome);
        }
    }
    let do_compress = compress
        && matches!(
            m,
            Message::Task(_) | Message::Result(_) | Message::Blob { .. } | Message::Forward { .. }
        );
    finish_frame(frame_kind(m), e.into_bytes(), do_compress)
}

/// Encode a `Message::Task` frame directly from a reference (§Perf: avoids
/// cloning large captured globals just to wrap them in the enum, and
/// pre-sizes the buffer from the task's payload bytes). No interning; see
/// [`encode_task_message_interned`] for the seat-aware path.
pub fn encode_task_message(t: &TaskSpec) -> Vec<u8> {
    let mut e = Encoder::with_capacity(task_size_hint(t));
    enc_task(&mut e, t);
    finish_frame(1, e.into_bytes(), true)
}

fn outcome_size_hint(o: &TaskOutcome) -> usize {
    match o {
        TaskOutcome::Ok(v) => v.byte_size(),
        TaskOutcome::Err(e) => e.message.len() + 16,
    }
}

fn result_size_hint(r: &TaskResult) -> usize {
    outcome_size_hint(&r.outcome) + r.id.len() + r.captured.stdout.len()
}

/// Decode a complete v6 frame (header + body) without an intern cache.
pub fn decode_message(bytes: &[u8]) -> Result<Message, WireError> {
    decode_message_cached(bytes, None)
}

/// Decode a complete v6 frame, resolving interned references through
/// `cache` when provided. Validates magic, version, frame kind, codec,
/// and that the declared body length matches the bytes present.
pub fn decode_message_cached(
    bytes: &[u8],
    cache: Option<&InternCache>,
) -> Result<Message, WireError> {
    let mut d = Decoder::new(bytes);
    let magic = d.take(2)?;
    if magic != MAGIC {
        let found = [magic[0], magic[1]];
        return Err(d.err_kind(WireErrorKind::BadMagic { found }));
    }
    let version = d.u8()?;
    if version != PROTOCOL_VERSION as u8 {
        return Err(d.err_kind(WireErrorKind::BadVersion {
            found: version,
            expected: PROTOCOL_VERSION as u8,
        }));
    }
    let kind = d.u8()?;
    let codec_id = d.u8()?;
    let len = d.varint()?;
    let remaining = bytes.len() - d.pos;
    if len != remaining as u64 {
        let mut e = d.err_kind(WireErrorKind::LengthOverflow {
            what: "frame body",
            length: len,
            remaining,
        });
        e.frame = Some(kind);
        return Err(e);
    }
    decode_frame_body(kind, codec_id, &bytes[d.pos..], cache)
}

/// Decode a frame *body* whose header (`kind`, `codec_id`) was already
/// parsed — the entry point stream readers use after
/// [`crate::ipc::frame::read_frame`].
pub fn decode_frame_body(
    kind: u8,
    codec_id: u8,
    body: &[u8],
    cache: Option<&InternCache>,
) -> Result<Message, WireError> {
    let decompressed;
    let body: &[u8] = match codec_id {
        codec::CODEC_RAW => body,
        codec::CODEC_DELTA_RLE => {
            decompressed = codec::decompress(body, crate::ipc::frame::MAX_FRAME as usize)
                .map_err(|m| WireError {
                    offset: 0,
                    frame: Some(kind),
                    kind: WireErrorKind::Invalid(format!("codec: {m}")),
                })?;
            &decompressed
        }
        other => {
            return Err(WireError {
                offset: 0,
                frame: Some(kind),
                kind: WireErrorKind::BadCodec { found: other },
            })
        }
    };
    let mut d = match cache {
        Some(c) => Decoder::with_cache(body, c),
        None => Decoder::new(body),
    };
    d.frame = Some(kind);
    let m = match kind {
        0 => Message::Hello { worker_id: d.str()?, version: d.varint()? as u32 },
        1 => Message::Task(dec_task(&mut d)?),
        2 => Message::Immediate { task_id: d.str()?, condition: dec_condition(&mut d)? },
        3 => Message::Result(dec_result(&mut d)?),
        4 => Message::Shutdown,
        5 => Message::Ping,
        6 => Message::Pong,
        7 => Message::Heartbeat { task_id: d.str()? },
        8 => Message::Cancel { task_id: d.str()? },
        9 => {
            let n = d.len_varint(16, "digest list")?;
            let mut digests = Vec::with_capacity(n);
            for _ in 0..n {
                digests.push(d.digest()?);
            }
            Message::NeedBlob { digests }
        }
        10 => {
            let digest = d.digest()?;
            let bytes = if d.bool()? {
                let n = d.len_varint(1, "blob")?;
                Some(d.take(n)?.to_vec())
            } else {
                None
            };
            Message::Blob { digest, bytes }
        }
        11 => {
            let future_id = d.str()?;
            let outcome = dec_outcome(&mut d)?;
            Message::Forward { future_id, outcome }
        }
        other => return Err(d.err_kind(WireErrorKind::BadFrameKind { found: other })),
    };
    if !d.finished() {
        let count = d.bytes.len() - d.pos;
        return Err(d.err_kind(WireErrorKind::TrailingBytes { count }));
    }
    Ok(m)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::expr::Expr;

    fn roundtrip_value(v: Value) {
        let mut e = Encoder::new();
        enc_value(&mut e, &v);
        let bytes = e.into_bytes();
        let mut d = Decoder::new(&bytes);
        assert_eq!(dec_value(&mut d).unwrap(), v);
        assert!(d.finished());
    }

    #[test]
    fn value_roundtrips() {
        roundtrip_value(Value::Unit);
        roundtrip_value(Value::Bool(true));
        roundtrip_value(Value::I64(-42));
        roundtrip_value(Value::F64(std::f64::consts::PI));
        roundtrip_value(Value::Str("héllo\nworld".into()));
        roundtrip_value(Value::Tensor(Tensor::new(vec![2, 3], vec![1.0; 6]).unwrap()));
        roundtrip_value(Value::Tensor(Tensor::scalar(7.5)));
        roundtrip_value(Value::List(vec![
            Value::I64(1),
            Value::List(vec![Value::Str("nested".into())]),
            Value::Unit,
        ]));
    }

    #[test]
    fn expr_roundtrips_every_variant() {
        let expr = Expr::seq(vec![
            Expr::let_in(
                "a",
                Expr::add(Expr::var("x"), Expr::lit(1.0)),
                Expr::if_else(
                    Expr::prim(PrimOp::Lt, vec![Expr::var("a"), Expr::lit(10.0)]),
                    Expr::call("slow_fcn", vec![Expr::var("a")]),
                    Expr::stop(Expr::lit("too big")),
                ),
            ),
            Expr::index(Expr::list(vec![Expr::lit(1i64)]), Expr::lit(0i64)),
            Expr::dyn_lookup(Expr::lit("k")),
            Expr::cat(Expr::lit("out")),
            Expr::message(Expr::lit("msg")),
            Expr::warning(Expr::lit("warn")),
            Expr::progress(Expr::lit("50%")),
            Expr::runif(3),
            Expr::rnorm(2),
            Expr::with_rng_stream(9, Expr::runif(1)),
            Expr::Spin { millis: 5 },
            Expr::chaos_kill(),
            Expr::chaos_kill_once("/tmp/rustures-marker"),
            Expr::chaos_hang(25),
            Expr::chaos_hang_once(25, "/tmp/rustures-hang-marker"),
        ]);
        let mut e = Encoder::new();
        enc_expr(&mut e, &expr);
        let bytes = e.into_bytes();
        let mut d = Decoder::new(&bytes);
        assert_eq!(dec_expr(&mut d).unwrap(), expr);
        assert!(d.finished());
    }

    #[test]
    fn map_chunk_roundtrips_with_tensor_elements() {
        let body = Arc::new(Expr::add(Expr::var("x"), Expr::runif(1)));
        let chunk = Expr::map_chunk(
            "x",
            body,
            vec![Value::Tensor(Tensor::zeros(&[8])), Value::I64(3), Value::Unit],
            42,
        );
        let mut e = Encoder::new();
        enc_expr(&mut e, &chunk);
        let bytes = e.into_bytes();
        let mut d = Decoder::new(&bytes);
        assert_eq!(dec_expr(&mut d).unwrap(), chunk);
        assert!(d.finished());
    }

    #[test]
    fn map_chunk_encodes_body_once() {
        // The whole point of the first-class chunk: n elements, one body.
        let body = Arc::new(Expr::call(
            "a_rather_long_kernel_name_to_make_body_bytes_visible",
            vec![Expr::var("x")],
        ));
        let encoded_len = |n: usize| {
            let chunk = Expr::map_chunk(
                "x",
                Arc::clone(&body),
                (0..n as i64).map(Value::I64).collect(),
                0,
            );
            let mut e = Encoder::new();
            enc_expr(&mut e, &chunk);
            e.into_bytes().len()
        };
        let one = encoded_len(1);
        let hundred = encoded_len(100);
        // Growth is per-element value bytes (9 each for I64), not per-body.
        assert_eq!(hundred - one, 99 * 9, "chunk must grow by elements only");
    }

    #[test]
    fn varint_roundtrip_boundaries() {
        for v in [0u64, 1, 127, 128, 16383, 16384, u64::from(u32::MAX), u64::MAX] {
            let mut e = Encoder::new();
            e.varint(v);
            let bytes = e.into_bytes();
            let mut d = Decoder::new(&bytes);
            assert_eq!(d.varint().unwrap(), v, "varint {v}");
            assert!(d.finished());
        }
        // A 10-byte varint claiming a 65th bit must be rejected.
        let overlong = [0xff, 0xff, 0xff, 0xff, 0xff, 0xff, 0xff, 0xff, 0xff, 0x02];
        let err = Decoder::new(&overlong).varint().unwrap_err();
        assert_eq!(err.kind, WireErrorKind::VarintOverflow);
    }

    #[test]
    fn length_claims_beyond_buffer_rejected() {
        // A Value::Str claiming 1 GiB with 3 bytes remaining: the decoder
        // must reject before allocating.
        let mut e = Encoder::new();
        e.u8(4); // Str tag
        e.varint(1 << 30);
        e.raw(b"abc");
        let bytes = e.into_bytes();
        let err = dec_value(&mut Decoder::new(&bytes)).unwrap_err();
        assert!(
            matches!(err.kind, WireErrorKind::LengthOverflow { what: "string", .. }),
            "{err}"
        );
        // Same for tensor data: the claimed f32 count must fit in bytes.
        let mut e = Encoder::new();
        e.u8(5); // Tensor tag
        e.varint(1); // rank
        e.varint(1 << 40); // dim
        e.varint(1 << 40); // claimed f32 count
        let bytes = e.into_bytes();
        assert!(dec_value(&mut Decoder::new(&bytes)).is_err());
    }

    #[test]
    fn structured_error_reports_tag_and_frame() {
        // Unknown frame kind in a hand-built v6 header.
        let mut frame = Vec::from(MAGIC);
        frame.push(PROTOCOL_VERSION as u8);
        frame.push(99); // kind
        frame.push(codec::CODEC_RAW);
        frame.push(0); // body length varint
        let err = decode_message(&frame).unwrap_err();
        assert_eq!(err.kind, WireErrorKind::BadFrameKind { found: 99 });
        assert_eq!(err.frame, Some(99));
        assert!(format!("{err}").contains("unknown frame kind 99"), "{err}");
        // A bad tag inside a payload reports which table and which byte.
        let err = dec_value(&mut Decoder::new(&[42])).unwrap_err();
        assert_eq!(err.kind, WireErrorKind::BadTag { what: "Value", found: 42 });
    }

    #[test]
    fn bad_magic_and_version_rejected() {
        let mut frame = encode_message(&Message::Ping);
        let mut wrong_magic = frame.clone();
        wrong_magic[0] = b'X';
        let err = decode_message(&wrong_magic).unwrap_err();
        assert!(matches!(err.kind, WireErrorKind::BadMagic { .. }), "{err}");
        // A v5 frame arriving at a v6 decoder is a structured version error.
        frame[2] = 5;
        let err = decode_message(&frame).unwrap_err();
        assert_eq!(
            err.kind,
            WireErrorKind::BadVersion { found: 5, expected: PROTOCOL_VERSION as u8 }
        );
    }

    #[test]
    fn tags_match_tables() {
        let samples: Vec<Message> = vec![
            Message::Hello { worker_id: "w".into(), version: PROTOCOL_VERSION },
            Message::Task(TaskSpec {
                id: "t".into(),
                expr: Expr::lit(1.0),
                globals: Env::new(),
                opts: TaskOpts::default(),
            }),
            Message::Immediate {
                task_id: "t".into(),
                condition: Condition {
                    kind: ConditionKind::Message,
                    message: "m".into(),
                    seq: 0,
                },
            },
            Message::Result(TaskResult {
                id: "t".into(),
                outcome: TaskOutcome::Ok(Value::Unit),
                captured: Captured::default(),
                metrics: TaskMetrics::default(),
                attempt: 0,
            }),
            Message::Shutdown,
            Message::Ping,
            Message::Pong,
            Message::Heartbeat { task_id: "t".into() },
            Message::Cancel { task_id: "t".into() },
            Message::NeedBlob { digests: vec![Digest([0; 16])] },
            Message::Blob { digest: Digest([0; 16]), bytes: None },
            Message::Forward { future_id: "f".into(), outcome: TaskOutcome::Ok(Value::Unit) },
        ];
        assert_eq!(samples.len(), FRAME_KIND_TABLE.len());
        for (i, m) in samples.iter().enumerate() {
            assert_eq!(frame_kind(m), FRAME_KIND_TABLE[i].0, "{}", FRAME_KIND_TABLE[i].1);
            let frame = encode_message(m);
            assert_eq!(frame[3], FRAME_KIND_TABLE[i].0, "header {}", FRAME_KIND_TABLE[i].1);
        }
        // Spot-check the value/expr tag bytes against the tables.
        let mut e = Encoder::new();
        enc_value(&mut e, &Value::Tensor(Tensor::scalar(1.0)));
        assert_eq!(e.into_bytes()[0], 5, "Tensor tag");
        let mut e = Encoder::new();
        enc_expr(&mut e, &Expr::var("x"));
        assert_eq!(e.into_bytes()[0], 1, "Var tag");
        assert_eq!(VALUE_TAG_TABLE.len(), 8);
        assert_eq!(EXPR_TAG_TABLE.len(), 22);
    }

    #[test]
    fn task_size_hint_covers_tensor_payload() {
        let mut globals = Env::new();
        globals.insert("t", Value::Tensor(Tensor::zeros(&[1 << 14])));
        let task = TaskSpec {
            id: "t-1".into(),
            expr: Expr::prim(PrimOp::Sum, vec![Expr::var("t")]),
            globals,
            opts: TaskOpts::default(),
        };
        let hint = task_size_hint(&task);
        // Compare against the *uncompressed* frame: the hint sizes the
        // encode buffer, which is filled before any compression runs.
        let actual = encode_message_opts(&Message::Task(task.clone()), false).len();
        assert!(hint >= (1 << 14) * 4, "hint {hint} misses the payload");
        assert!(hint <= actual * 2, "hint {hint} vs actual {actual}");
    }

    #[test]
    fn task_roundtrips() {
        let mut globals = Env::new();
        globals.insert("x", Value::Tensor(Tensor::zeros(&[4])));
        let task = TaskSpec {
            id: "t-1".into(),
            expr: Expr::call("slow_fcn", vec![Expr::var("x")]),
            globals,
            opts: TaskOpts {
                seed: Some(42),
                stream_index: 7,
                capture_stdout: false,
                capture_conditions: true,
                label: Some("my future".into()),
                depth: 1,
                context: SessionContext {
                    session: 9,
                    nested_plan: vec![
                        PlanSpec::ThreadPool { workers: 3 },
                        PlanSpec::Sequential,
                    ],
                    retry: Some(
                        RetryPolicy::idempotent(3)
                            .with_backoff(std::time::Duration::from_millis(7), 1.5),
                    ),
                    counter_base: 11,
                    heartbeat_ms: 10,
                    stall_after_ms: 4000,
                },
                attempt: 2,
                pending: vec!["f-9-1".into(), "f-9-2".into()],
            },
        };
        let msg = Message::Task(task.clone());
        let decoded = decode_message(&encode_message(&msg)).unwrap();
        assert_eq!(decoded, msg);
    }

    #[test]
    fn session_context_roundtrips_all_fields() {
        for ctx in [
            SessionContext::default(),
            SessionContext {
                session: u64::MAX,
                nested_plan: vec![PlanSpec::Multiprocess { workers: 2 }],
                retry: None,
                counter_base: 0,
                heartbeat_ms: 1,
                stall_after_ms: 0,
            },
            SessionContext {
                session: 3,
                nested_plan: vec![],
                retry: Some(RetryPolicy::idempotent(5)),
                counter_base: 1 << 40,
                heartbeat_ms: 25,
                stall_after_ms: 120_000,
            },
        ] {
            let mut e = Encoder::new();
            enc_session_context(&mut e, &ctx);
            let bytes = e.into_bytes();
            let mut d = Decoder::new(&bytes);
            assert_eq!(dec_session_context(&mut d).unwrap(), ctx);
            assert!(d.finished());
        }
    }

    #[test]
    fn result_roundtrips_both_outcomes() {
        let ok = TaskResult {
            id: "a".into(),
            outcome: TaskOutcome::Ok(Value::F64(1.5)),
            captured: Captured {
                stdout: "hello\n".into(),
                conditions: vec![Condition {
                    kind: ConditionKind::Warning,
                    message: "careful".into(),
                    seq: 0,
                }],
                rng_used: true,
            },
            metrics: TaskMetrics { started_ns: 10, finished_ns: 30 },
            attempt: 1,
        };
        assert_eq!(
            decode_message(&encode_message(&Message::Result(ok.clone()))).unwrap(),
            Message::Result(ok)
        );

        let err = TaskResult {
            id: "b".into(),
            outcome: TaskOutcome::Err(EvalError::with_call("boom", "log(x)")),
            captured: Captured::default(),
            metrics: TaskMetrics::default(),
            attempt: 0,
        };
        assert_eq!(
            decode_message(&encode_message(&Message::Result(err.clone()))).unwrap(),
            Message::Result(err)
        );
    }

    #[test]
    fn plan_specs_roundtrip() {
        for p in [
            PlanSpec::Sequential,
            PlanSpec::ThreadPool { workers: 2 },
            PlanSpec::Multiprocess { workers: 8 },
            PlanSpec::Cluster { hosts: vec!["n1".into(), "n2".into()] },
            PlanSpec::Batch { workers: 4, submit_latency_ms: 50, poll_interval_ms: 10 },
            PlanSpec::Custom { name: "redis".into(), workers: 3 },
        ] {
            let mut e = Encoder::new();
            enc_plan(&mut e, &p);
            let bytes = e.into_bytes();
            assert_eq!(dec_plan(&mut Decoder::new(&bytes)).unwrap(), p);
        }
    }

    #[test]
    fn control_messages_roundtrip() {
        for m in [
            Message::Hello { worker_id: "w1".into(), version: 1 },
            Message::Shutdown,
            Message::Ping,
            Message::Pong,
            Message::Immediate {
                task_id: "t".into(),
                condition: Condition {
                    kind: ConditionKind::Immediate,
                    message: "10%".into(),
                    seq: 3,
                },
            },
            Message::Heartbeat { task_id: "t-hb".into() },
            Message::Cancel { task_id: "t-cx".into() },
            Message::NeedBlob { digests: vec![Digest([1; 16]), Digest([2; 16])] },
            Message::Blob { digest: Digest([3; 16]), bytes: Some(vec![9, 8, 7]) },
            Message::Blob { digest: Digest([4; 16]), bytes: None },
            Message::Forward {
                future_id: "f-1-1".into(),
                outcome: TaskOutcome::Ok(Value::F64(2.5)),
            },
            Message::Forward {
                future_id: "f-1-2".into(),
                outcome: TaskOutcome::Err(EvalError::with_call("dep boom", "g(x)")),
            },
        ] {
            assert_eq!(decode_message(&encode_message(&m)).unwrap(), m);
        }
    }

    #[test]
    fn compression_roundtrip_and_wins() {
        let mut globals = Env::new();
        globals.insert("t", Value::Tensor(Tensor::zeros(&[1 << 14]))); // 64 KiB
        let task = TaskSpec {
            id: "c".into(),
            expr: Expr::prim(PrimOp::Sum, vec![Expr::var("t")]),
            globals,
            opts: TaskOpts::default(),
        };
        let msg = Message::Task(task);
        let raw = encode_message_opts(&msg, false);
        let packed = encode_message_opts(&msg, true);
        assert!(packed.len() < raw.len() / 10, "packed {} raw {}", packed.len(), raw.len());
        assert_eq!(decode_message(&raw).unwrap(), msg);
        assert_eq!(decode_message(&packed).unwrap(), msg);
    }

    #[test]
    fn interned_task_roundtrips_and_shrinks() {
        let mut globals = Env::new();
        globals.insert("g", Value::Tensor(Tensor::zeros(&[1024]))); // 4 KiB
        let body = Arc::new(Expr::seq(vec![
            Expr::lit(Value::Tensor(Tensor::zeros(&[600]))), // ~2.4 KiB body
            Expr::var("x"),
        ]));
        let mk = |attempt: u32| TaskSpec {
            id: format!("t-{attempt}"),
            expr: Expr::map_chunk(
                "x",
                Arc::clone(&body),
                vec![Value::I64(1), Value::I64(2)],
                0,
            ),
            globals: globals.clone(),
            opts: TaskOpts { attempt, ..TaskOpts::default() },
        };
        let mut ledger = SeatLedger::with_cap(8);
        let cache = InternCache::with_cap(8);
        let first = encode_task_message_interned(&mk(0), &mut ledger);
        let second = encode_task_message_interned(&mk(1), &mut ledger);
        // The second frame carries only references — it must be a small
        // fraction of the raw (uninterned, uncompressed) resend.
        let resend = encode_message_opts(&Message::Task(mk(1)), false).len();
        assert!(second.len() < resend / 10, "refs {} vs resend {resend}", second.len());
        // Both frames decode bit-identically through the worker cache.
        assert_eq!(
            decode_message_cached(&first, Some(&cache)).unwrap(),
            Message::Task(mk(0))
        );
        assert_eq!(
            decode_message_cached(&second, Some(&cache)).unwrap(),
            Message::Task(mk(1))
        );
        // Without the provides, a reference-only frame is a structured miss
        // (recovered in production via the NeedBlob protocol).
        let err = decode_message(&second).unwrap_err();
        assert!(matches!(err.kind, WireErrorKind::MissingBlob { .. }), "{err}");
    }

    #[test]
    fn corrupt_bytes_fail_cleanly() {
        assert!(decode_message(&[]).is_err());
        assert!(decode_message(&[99]).is_err());
        // Truncated task frame.
        let msg = Message::Task(TaskSpec {
            id: "x".into(),
            expr: Expr::lit(1.0),
            globals: Env::new(),
            opts: TaskOpts::default(),
        });
        let bytes = encode_message(&msg);
        assert!(decode_message(&bytes[..bytes.len() - 3]).is_err());
        // Trailing garbage breaks the declared body length.
        let mut extended = bytes.clone();
        extended.push(0);
        assert!(decode_message(&extended).is_err());
    }
}
