//! # rustures — a unifying framework for parallel and distributed processing using futures
//!
//! A production-grade Rust reproduction of Bengtsson's *future* framework
//! (["A Unifying Framework for Parallel and Distributed Processing in R using
//! Futures"](https://doi.org/10.32614/RJ-2021-048)).  The paper's *Future API*
//! is three atomic constructs:
//!
//! * [`api::future::future`] — evaluate an expression via a future
//!   (non-blocking, if a worker is available),
//! * [`api::future::Future::value`] — the value of the future expression
//!   (blocking until resolved),
//! * [`api::future::Future::resolved`] — non-blocking resolution probe,
//!
//! bridged to pluggable *backends* chosen by the **end-user** via
//! [`api::plan::plan`], while the developer only decides **what** to
//! parallelize.  Cross-cutting services every backend inherits:
//!
//! * automatic identification of globals ([`api::globals`]),
//! * parallel RNG streams — L'Ecuyer-CMRG / MRG32k3a ([`api::rng`]),
//! * capture + ordered relay of stdout and conditions ([`api::conditions`]),
//! * an exception taxonomy separating evaluation errors from
//!   infrastructure [`api::error::FutureError`]s,
//! * nested-parallelism protection via plan topologies ([`api::plan`]),
//! * supervised fault tolerance — worker respawn + transparent,
//!   determinism-preserving retry ([`backend::supervisor`]),
//! * capacity-governed execution — one ledger for every execution slot:
//!   per-session quotas, per-host respawn budgets, circuit breakers
//!   ([`capacity`]),
//! * plan-time static analysis — a multi-pass linter (export-size
//!   budgets, RNG hygiene, opacity traps, plan cross-checks) that rejects
//!   or flags bad futures before they cost anything ([`analysis`]),
//! * a content-addressed result cache — memoized futures with a bounded
//!   in-memory tier and an atomic spill-to-disk store; hits resolve with
//!   no capacity lease and no backend at all ([`cache`]).
//!
//! Compute payloads (the paper's `slow_fcn`) are JAX/Pallas programs
//! AOT-lowered to HLO text and executed through PJRT by [`runtime`] — Python
//! never runs on the request path.
//!
//! ## Quickstart
//!
//! ```no_run
//! use rustures::prelude::*;
//!
//! // End-user decides *how* to parallelize:
//! plan(PlanSpec::multiprocess(4));
//!
//! // Developer decides *what*:
//! let mut env = Env::new();
//! env.insert("x", Value::from(21.0));
//! let f = future(Expr::mul(Expr::var("x"), Expr::lit(2.0)), &env).unwrap();
//! assert_eq!(f.value().unwrap(), Value::from(42.0));
//! ```

pub mod analysis;
pub mod api;
pub mod backend;
pub mod cache;
pub mod capacity;
pub mod conformance;
pub mod ipc;
pub mod liveness;
pub mod mapreduce;
pub mod metrics;
pub mod proptest_lite;
pub mod runtime;
pub mod scheduler;
pub mod transport;
pub mod util;
pub mod worker;

/// One-stop imports for examples and downstream users.
pub mod prelude {
    pub use crate::analysis::{AnalysisConfig, Diagnostic, LintCode, Severity};
    pub use crate::api::conditions::{Condition, ConditionKind};
    pub use crate::api::either::future_either;
    pub use crate::api::env::Env;
    pub use crate::api::error::{EvalError, FutureError};
    pub use crate::api::expr::{Expr, PrimOp};
    pub use crate::api::future::{
        future, future_pipelined, future_with, resolve, resolve_all, resolve_any, Future,
        FutureOpts, FutureSet,
    };
    pub use crate::api::lazy::merge_futures;
    pub use crate::api::plan::{plan, plan_topology, with_plan, PlanSpec};
    pub use crate::api::promise::ListEnv;
    pub use crate::api::plan::plan_with_retry;
    pub use crate::api::rng::RngStream;
    pub use crate::api::session::Session;
    pub use crate::api::value::{Tensor, Value};
    pub use crate::backend::supervisor::{RetryPolicy, SupervisorConfig};
    pub use crate::cache::CacheConfig;
    pub use crate::capacity::{BreakerConfig, BreakerState, SessionLimits};
    pub use crate::liveness::LivenessConfig;
    pub use crate::mapreduce::{
        future_lapply, future_map, future_map_reduce, Chunking, LapplyOpts,
    };
}
