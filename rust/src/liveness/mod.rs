//! The liveness plane — heartbeats, stall detection, per-future deadlines,
//! and cooperative cancellation (protocol v5).
//!
//! PRs 3–5 made the framework survive *crash* faults: a dead worker is
//! visible (reader EOF, nonzero exit) and trips budgets, breakers, and
//! retries.  A *hung* worker is different: it holds its `SlotLease`
//! forever, emits nothing, and — worse — may eventually wake up and send
//! a result for an attempt the supervisor already gave up on.  This
//! module supplies the missing taxonomy:
//!
//! * **Heartbeats** — remote workers emit [`crate::ipc::Message::Heartbeat`]
//!   frames from the evaluator's tick hook (between `MapChunk` elements),
//!   over the same writer the immediates use: no per-worker heartbeat
//!   thread exists.  The ProcPool's monitor declares a busy worker *hung*
//!   after [`LivenessConfig::stall_after`] of silence, kills it, forfeits
//!   its lease (a breaker-counted death), and lets the retry path take
//!   over.
//! * **Progress cells** — in-process backends cannot kill a thread, so
//!   they track an epoch-stamped [`TaskLiveness`] cell instead of frames:
//!   the evaluator bumps the epoch at every tick, and observers read it to
//!   distinguish "slow but progressing" from "stuck".
//! * **Cooperative cancellation** — the same cell carries a cancel flag
//!   the evaluator checks between `MapChunk` elements (and inside
//!   `ChaosHang` sleep slices); a cancelled in-process task returns the
//!   [`WORKER_CANCEL_ERROR`] sentinel and frees its seat instead of
//!   running to completion.  Remote cancellation stays a seat kill (a
//!   single-threaded worker cannot read a `Cancel` frame mid-evaluation);
//!   the frame exists for queued tasks and the future multiplexed
//!   transport.
//! * **Stale-result fencing** — every launch carries an attempt epoch
//!   ([`crate::ipc::TaskOpts::attempt`]), workers echo it, and readers /
//!   the batch daemon drop result frames whose epoch does not match the
//!   handle's current attempt (`metrics` counts them as `fenced_results`).

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Sentinel evaluation-error message produced when the evaluator observes
/// the cooperative cancel flag between elements.  In-process backends
/// recognize it and surface [`crate::api::error::FutureError::Cancelled`]
/// instead of an eval error (mirrors
/// [`crate::backend::supervisor::WORKER_KILL_ERROR`]).
pub const WORKER_CANCEL_ERROR: &str = "__rustures_cooperative_cancel__";

/// Default worker heartbeat cadence in milliseconds
/// ([`LivenessConfig::heartbeat_interval`] and the
/// [`crate::ipc::SessionContext`] default agree through this constant).
pub const DEFAULT_HEARTBEAT_MS: u64 = 25;

/// Liveness tuning (heartbeat cadence + stall deadline).
///
/// Since the transport reactor took over stall deadlines (protocol v7),
/// the *authoritative* copy travels per-session: set it with
/// [`crate::api::session::Session::set_liveness_config`] and it ships to
/// workers inside every task's [`crate::ipc::SessionContext`].  The
/// process-global [`set_liveness_config`] remains as the fallback default
/// for sessions that never set their own.
#[derive(Debug, Clone, PartialEq)]
pub struct LivenessConfig {
    /// Minimum spacing between heartbeat frames a remote worker emits
    /// while evaluating (ticks closer together than this are coalesced).
    pub heartbeat_interval: Duration,
    /// Declare a busy remote worker hung after this much silence (no
    /// result, immediate, or heartbeat frame).  `None` (the default)
    /// disables the stall detector: a coarse-grained task that spends
    /// longer than `stall_after` inside one element would otherwise be
    /// killed as a false positive, so hang detection is strictly opt-in.
    pub stall_after: Option<Duration>,
}

impl Default for LivenessConfig {
    fn default() -> Self {
        LivenessConfig {
            heartbeat_interval: Duration::from_millis(DEFAULT_HEARTBEAT_MS),
            stall_after: None,
        }
    }
}

impl LivenessConfig {
    /// Convenience: a config with the stall detector armed.
    pub fn with_stall_after(stall_after: Duration) -> Self {
        LivenessConfig { stall_after: Some(stall_after), ..Default::default() }
    }
}

static CONFIG: Mutex<Option<LivenessConfig>> = Mutex::new(None);

/// The process-wide *fallback* config — what sessions without a
/// per-session [`crate::api::session::Session::set_liveness_config`]
/// resolve at context-build time.
pub fn liveness_config() -> LivenessConfig {
    CONFIG.lock().unwrap().clone().unwrap_or_default()
}

/// Override the process-wide fallback liveness config.
pub fn set_liveness_config(cfg: LivenessConfig) {
    *CONFIG.lock().unwrap() = Some(cfg);
}

/// Back to the built-in default (stall detector off).
pub fn reset_liveness_config() {
    *CONFIG.lock().unwrap() = None;
}

/// The per-task progress cell used by in-process backends: an
/// epoch-stamped progress counter plus the cooperative cancel flag.
/// Cheap (`Arc` + two atomics) and lock-free on the evaluation path.
#[derive(Debug, Default)]
pub struct TaskLiveness {
    epoch: AtomicU64,
    cancelled: AtomicBool,
}

impl TaskLiveness {
    pub fn new() -> Arc<Self> {
        Arc::new(TaskLiveness::default())
    }

    /// Bumped by the evaluator at every yield point (between `MapChunk`
    /// elements); a stuck task's epoch stops moving.
    pub fn tick(&self) {
        self.epoch.fetch_add(1, Ordering::Relaxed);
    }

    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Relaxed)
    }

    /// Request cooperative cancellation; the evaluator honors it at its
    /// next yield point.
    pub fn cancel(&self) {
        self.cancelled.store(true, Ordering::SeqCst);
    }

    pub fn is_cancelled(&self) -> bool {
        self.cancelled.load(Ordering::SeqCst)
    }
}

/// Registry of live in-process tasks (task id → progress cell), so a
/// handle can cancel a task it only knows by id.  Entries are registered
/// at launch and removed when the task leaves the worker.
static REGISTRY: Mutex<Option<HashMap<String, Arc<TaskLiveness>>>> = Mutex::new(None);

/// Create (or fetch) the progress cell for `task_id`.
pub fn register(task_id: &str) -> Arc<TaskLiveness> {
    let mut reg = REGISTRY.lock().unwrap();
    let map = reg.get_or_insert_with(HashMap::new);
    Arc::clone(map.entry(task_id.to_string()).or_insert_with(TaskLiveness::new))
}

/// The progress cell for `task_id`, if the task is live.
pub fn lookup(task_id: &str) -> Option<Arc<TaskLiveness>> {
    REGISTRY.lock().unwrap().as_ref().and_then(|m| m.get(task_id).cloned())
}

/// Drop the registry entry (the cell itself lives as long as its `Arc`s).
pub fn deregister(task_id: &str) {
    if let Some(map) = REGISTRY.lock().unwrap().as_mut() {
        map.remove(task_id);
    }
}

/// Set the cooperative cancel flag for `task_id`; `true` if it was live.
pub fn cancel_task(task_id: &str) -> bool {
    match lookup(task_id) {
        Some(cell) => {
            cell.cancel();
            true
        }
        None => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_roundtrip_and_reset() {
        reset_liveness_config();
        assert_eq!(liveness_config(), LivenessConfig::default());
        assert!(liveness_config().stall_after.is_none(), "detector must default off");
        set_liveness_config(LivenessConfig::with_stall_after(Duration::from_millis(150)));
        assert_eq!(liveness_config().stall_after, Some(Duration::from_millis(150)));
        reset_liveness_config();
        assert!(liveness_config().stall_after.is_none());
    }

    #[test]
    fn progress_cell_ticks_and_cancels() {
        let cell = TaskLiveness::new();
        assert_eq!(cell.epoch(), 0);
        cell.tick();
        cell.tick();
        assert_eq!(cell.epoch(), 2);
        assert!(!cell.is_cancelled());
        cell.cancel();
        assert!(cell.is_cancelled());
    }

    #[test]
    fn registry_register_cancel_deregister() {
        let id = format!("lv-{}", crate::util::uuid_v4());
        assert!(lookup(&id).is_none());
        assert!(!cancel_task(&id), "cancel of an unknown task is a no-op");
        let cell = register(&id);
        // Re-registration returns the SAME cell (cancel-before-start races
        // land on the flag the evaluator will actually read).
        let again = register(&id);
        assert!(Arc::ptr_eq(&cell, &again));
        assert!(cancel_task(&id));
        assert!(cell.is_cancelled());
        deregister(&id);
        assert!(lookup(&id).is_none());
    }
}
