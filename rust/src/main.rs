//! `rustures` — CLI entrypoint.
//!
//! Subcommands:
//!
//! * `worker --stdio` — multisession worker: framed protocol on stdin/stdout.
//! * `worker --connect ADDR` — cluster worker: connect back to the
//!   coordinator (the simulated-ssh reverse connection).
//! * `worker --batch-job TASK --out RESULT` — batchtools job: read a task
//!   file, write a result file, exit.
//! * `conformance [--backend NAME] [--workers N]` — run the Future API
//!   conformance suite (future.tests analog) against one or all backends.
//! * `kernels` — list AOT artifacts loaded by the PJRT runtime.
//! * `demo` — a tiny end-to-end sanity run on the multisession backend.

use std::io::{stdin, stdout};
use std::net::TcpStream;
use std::process::ExitCode;

use rustures::api::plan::PlanSpec;
use rustures::conformance::run_conformance;
use rustures::prelude::*;
use rustures::worker::{run_batch_job, run_worker};

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let result = match args.first().map(String::as_str) {
        Some("worker") => cmd_worker(&args[1..]),
        Some("conformance") => cmd_conformance(&args[1..]),
        Some("kernels") => cmd_kernels(),
        Some("demo") => cmd_demo(),
        Some("--version") | Some("-V") => {
            println!("rustures {}", env!("CARGO_PKG_VERSION"));
            Ok(())
        }
        _ => {
            eprintln!(
                "usage: rustures <worker|conformance|kernels|demo> [options]\n\
                 \n\
                 worker --stdio                        multisession worker over pipes\n\
                 worker --connect HOST:PORT            cluster worker (reverse connect)\n\
                 worker --batch-job TASK --out RESULT  batch job execution\n\
                 conformance [--backend NAME] [--workers N]\n\
                 kernels                               list loaded PJRT artifacts\n\
                 demo                                  quick multisession sanity run"
            );
            return ExitCode::from(2);
        }
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("rustures: {e}");
            ExitCode::FAILURE
        }
    }
}

fn flag_value<'a>(args: &'a [String], flag: &str) -> Option<&'a str> {
    args.iter().position(|a| a == flag).and_then(|i| args.get(i + 1)).map(String::as_str)
}

fn cmd_worker(args: &[String]) -> Result<(), String> {
    // This process exists to serve tasks and can be killed/respawned at
    // will: chaos probes (Expr::ChaosKill) exit it like a real crash.
    rustures::backend::supervisor::set_kill_exits_process(true);
    // Runtime loads lazily inside the evaluator on first kernel call.
    let kernels = None;
    if args.iter().any(|a| a == "--stdio") {
        run_worker(stdin().lock(), stdout().lock(), kernels).map_err(|e| e.to_string())
    } else if let Some(addr) = flag_value(args, "--connect") {
        if std::env::var("RUSTURES_CHAOS_NO_CONNECT").is_ok_and(|v| v == "1") {
            // Chaos hook for the cluster accept-timeout tests: a worker
            // that launches successfully but never phones home.
            std::thread::sleep(std::time::Duration::from_secs(3600));
            return Ok(());
        }
        let stream = TcpStream::connect(addr)
            .map_err(|e| format!("connect {addr}: {e}"))?;
        stream.set_nodelay(true).ok();
        let reader = stream.try_clone().map_err(|e| e.to_string())?;
        run_worker(reader, stream, kernels).map_err(|e| e.to_string())
    } else if let Some(task) = flag_value(args, "--batch-job") {
        let out = flag_value(args, "--out").ok_or("worker --batch-job requires --out")?;
        run_batch_job(task.as_ref(), out.as_ref(), kernels).map_err(|e| e.to_string())
    } else {
        Err("worker requires --stdio, --connect, or --batch-job".into())
    }
}

fn backend_specs(name: Option<&str>, workers: usize) -> Result<Vec<PlanSpec>, String> {
    let all = vec![
        PlanSpec::sequential(),
        PlanSpec::multicore(workers),
        PlanSpec::multiprocess(workers),
        PlanSpec::Cluster {
            hosts: (1..=workers.max(1)).map(|i| format!("n{i}.local")).collect(),
        },
        PlanSpec::batch(workers),
    ];
    match name {
        None => Ok(all),
        Some(n) => {
            let found: Vec<PlanSpec> =
                all.into_iter().filter(|s| s.name() == n).collect();
            if found.is_empty() {
                Err(format!("unknown backend '{n}' (sequential, multicore, multisession, cluster, batchtools)"))
            } else {
                Ok(found)
            }
        }
    }
}

fn cmd_conformance(args: &[String]) -> Result<(), String> {
    let workers: usize =
        flag_value(args, "--workers").map(|w| w.parse().unwrap_or(2)).unwrap_or(2);
    let specs = backend_specs(flag_value(args, "--backend"), workers)?;
    let mut all_passed = true;
    for spec in specs {
        let report = run_conformance(spec);
        println!("== {}", report.summary());
        for r in &report.results {
            println!(
                "   [{}] {:<22} {:>8.1?}  {}",
                if r.passed { "ok" } else { "FAIL" },
                r.name,
                r.elapsed,
                r.detail
            );
        }
        all_passed &= report.passed();
    }
    if all_passed {
        Ok(())
    } else {
        Err("conformance failures".into())
    }
}

fn cmd_kernels() -> Result<(), String> {
    match rustures::runtime::global() {
        Some(rt) => {
            for name in rt.handle().kernel_names() {
                println!("{name}");
            }
            Ok(())
        }
        None => Err("no PJRT runtime (run `make artifacts` or set RUSTURES_ARTIFACTS)".into()),
    }
}

fn cmd_demo() -> Result<(), String> {
    plan(PlanSpec::multiprocess(2));
    let mut env = Env::new();
    env.insert("x", 21i64);
    let f = future(Expr::mul(Expr::var("x"), Expr::lit(2i64)), &env)
        .map_err(|e| e.to_string())?;
    let v = f.value().map_err(|e| e.to_string())?;
    println!("future(x * 2) on multisession → {v}");
    plan(PlanSpec::sequential());
    Ok(())
}
