//! A `foreach(...) %dopar% { ... }` adaptor — the `doFuture` analog.
//!
//! `foreach` separates the loop construct from the backend; `doFuture`
//! bridges it onto futures so *any* future backend works.  This builder
//! reproduces that surface: iterate a variable over values, evaluate a body
//! per element on the current plan, and `.combine` the results.

use crate::api::env::Env;
use crate::api::error::FutureError;
use crate::api::expr::Expr;
use crate::api::value::Value;
use crate::mapreduce::{future_lapply, LapplyOpts};

/// `.combine=` reduction modes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Combine {
    /// Collect into a list (foreach's default).
    #[default]
    List,
    /// `.combine = c` over numbers: flatten to a numeric vector (list).
    Concat,
    /// `.combine = "+"`.
    Sum,
    /// `.combine = max`.
    Max,
}

/// The `foreach(x = xs)` builder.
pub struct Foreach<'e> {
    env: &'e Env,
    param: String,
    values: Vec<Value>,
    combine: Combine,
    opts: LapplyOpts,
}

/// Entry point: `foreach("x", xs, &env)`.
pub fn foreach<'e>(param: &str, values: Vec<Value>, env: &'e Env) -> Foreach<'e> {
    Foreach {
        env,
        param: param.to_string(),
        values,
        combine: Combine::List,
        opts: LapplyOpts::new(),
    }
}

impl<'e> Foreach<'e> {
    /// `.combine=` argument.
    pub fn combine(mut self, combine: Combine) -> Self {
        self.combine = combine;
        self
    }

    /// `%seed%` / `.options.future(seed=)`.
    pub fn seed(mut self, seed: u64) -> Self {
        self.opts = self.opts.seed(seed);
        self
    }

    /// `%dopar% { body }` — run on the current plan and combine.
    pub fn dopar(self, body: Expr) -> Result<Value, FutureError> {
        let items = future_lapply(&self.values, &self.param, &body, self.env, &self.opts)?;
        Ok(match self.combine {
            Combine::List | Combine::Concat => Value::List(items),
            Combine::Sum => {
                let mut total = 0.0;
                for v in &items {
                    total += v.as_f64().ok_or_else(|| {
                        FutureError::Eval(crate::api::error::EvalError::new(
                            "combine '+': non-numeric result",
                        ))
                    })?;
                }
                Value::F64(total)
            }
            Combine::Max => {
                let mut best = f64::NEG_INFINITY;
                for v in &items {
                    best = best.max(v.as_f64().ok_or_else(|| {
                        FutureError::Eval(crate::api::error::EvalError::new(
                            "combine max: non-numeric result",
                        ))
                    })?);
                }
                Value::F64(best)
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::plan::{with_plan, PlanSpec};

    fn nums(n: i64) -> Vec<Value> {
        (0..n).map(Value::I64).collect()
    }

    #[test]
    fn dopar_list_combine() {
        with_plan(PlanSpec::multicore(2), || {
            let env = Env::new();
            let out = foreach("x", nums(5), &env)
                .dopar(Expr::mul(Expr::var("x"), Expr::lit(2i64)))
                .unwrap();
            assert_eq!(
                out,
                Value::List((0..5).map(|i| Value::I64(i * 2)).collect())
            );
        });
    }

    #[test]
    fn dopar_sum_combine() {
        with_plan(PlanSpec::sequential(), || {
            let env = Env::new();
            let out = foreach("x", nums(5), &env)
                .combine(Combine::Sum)
                .dopar(Expr::var("x"))
                .unwrap();
            assert_eq!(out, Value::F64(10.0));
        });
    }

    #[test]
    fn dopar_max_combine() {
        with_plan(PlanSpec::sequential(), || {
            let env = Env::new();
            let out = foreach("x", nums(7), &env)
                .combine(Combine::Max)
                .dopar(Expr::var("x"))
                .unwrap();
            assert_eq!(out, Value::F64(6.0));
        });
    }

    #[test]
    fn seeded_foreach_is_reproducible() {
        with_plan(PlanSpec::multicore(2), || {
            let env = Env::new();
            let run = || {
                foreach("x", nums(4), &env)
                    .seed(99)
                    .dopar(Expr::runif(1))
                    .unwrap()
            };
            assert_eq!(run(), run());
        });
    }
}
