//! Higher-level map-reduce frontends built on the three atomic constructs —
//! the `future.apply` / `furrr` / `doFuture` layer.
//!
//! "This minimal API provides sufficient constructs for implementing
//! parallel versions of well-established, high-level map-reduce APIs."
//! The key service here is **load balancing**: elements are partitioned into
//! chunks (typically one per worker) so per-future overhead is amortized,
//! while per-element RNG substreams keep results *invariant to chunking*.
//!
//! ## The zero-copy chunk hot path
//!
//! A chunk is shipped as one first-class [`Expr::MapChunk`] task: the map
//! body is cloned **once** per map call and `Arc`-shared into every chunk,
//! and each chunk carries its elements as packed [`Value`]s whose tensor
//! payloads are themselves `Arc`-shared.  Launching a map therefore costs
//! O(chunks) expression handling — not the O(n·|body|) of the historical
//! per-element `let`-desugaring — and O(1) payload bytes per element on
//! shared-memory backends.  On serializing backends the wire format mirrors
//! this: one body encode plus packed elements per chunk
//! ([`crate::ipc::wire`], tag 17).
//!
//! Chunking-invariant RNG is preserved by construction: a chunk records the
//! global index of its first element (`base_index`) and the evaluator runs
//! element `i` under substream `base_index + i` whenever the map is seeded,
//! so every chunking policy, backend, and worker count draws identical
//! numbers (future.apply's per-element streams).
//!
//! ## As-completed collection
//!
//! Harvesting is **streaming**: a [`crate::api::future::FutureSet`] watches
//! every chunk future through the shared completion channel and each chunk
//! is promoted to its terminal state *the moment it resolves* — a slow
//! chunk can no longer head-of-line-block finished results behind it in
//! the backend's parked-result map.  The output is bit-identical to the
//! historical strictly-in-order collect (values, seeded RNG draws, and the
//! relay order of captured output) because values are extracted into their
//! input-order slots after the drain; only the *waiting* is
//! completion-ordered.  [`future_map_reduce`] goes further and folds
//! results in completion order — for commutative reductions only.
//!
//! With [`LapplyOpts::queued`], chunk futures enqueue on the backend's
//! bounded dispatcher backlog instead of blocking creation on seat
//! availability; the backlog bound is the in-flight window.

pub mod foreach;

use std::ops::Range;
use std::sync::Arc;

use crate::api::env::Env;
use crate::api::error::FutureError;
use crate::api::expr::Expr;
use crate::api::future::{future_with, Future, FutureOpts, FutureSet};
use crate::api::plan::current_depth;
use crate::api::session;
use crate::api::value::Value;
use crate::backend::supervisor::RetryPolicy;

/// Chunking policy (future.apply's `scheduling`/`chunk.size` arguments).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Chunking {
    /// One future per element (no load balancing — the naive pattern the
    /// paper's footnote 6 calls suboptimal for cheap elements).
    PerElement,
    /// One chunk per worker (the default; `scheduling = 1.0`).
    PerWorker,
    /// `scheduling = f`: about `f` chunks per worker (f ≥ 1 trades
    /// balance against overhead).
    Scheduling(f64),
    /// Fixed elements per chunk (`chunk.size`).
    ChunkSize(usize),
}

impl Default for Chunking {
    fn default() -> Self {
        Chunking::PerWorker
    }
}

/// Options for [`future_lapply`]/[`future_map`].
#[derive(Debug, Clone, Default)]
pub struct LapplyOpts {
    /// Parallel-RNG base seed (`future.seed = TRUE` analog).  Per-element
    /// substreams make results identical for every chunking and backend.
    pub seed: Option<u64>,
    pub chunking: Chunking,
    /// Capture stdout/conditions on workers (off for throughput benches).
    pub capture: bool,
    pub label: Option<String>,
    /// Enqueue chunk futures on the backend's bounded dispatcher backlog
    /// instead of blocking creation while all seats are busy
    /// ([`crate::api::future::FutureOpts::queued`]).
    pub queued: bool,
    /// Collect strictly in submission order instead of as-completed — the
    /// pre-streaming reference path, kept for A/B tests and benches.  The
    /// output is identical either way; only the waiting differs.
    pub in_order: bool,
    /// Supervised retry for every chunk future: a chunk lost to a worker
    /// crash is transparently resubmitted, so a single dead worker no
    /// longer poisons the whole map.  Retried chunks re-run under the same
    /// `base_index` RNG substreams — seeded results stay **bit-identical**
    /// to a no-failure run.  Requires the policy's `idempotent` gate
    /// (elements finished before the crash run twice).
    pub retry: Option<RetryPolicy>,
    /// Per-chunk deadline ([`crate::api::future::FutureOpts::deadline`]):
    /// each chunk future times out — latching
    /// [`crate::api::error::FutureError::TimedOut`] and cancelling its
    /// in-flight attempt — this long after its creation.  The whole map
    /// then fails with the first chunk's timeout at collection.
    pub deadline: Option<std::time::Duration>,
    /// Opt every chunk future into the content-addressed result cache
    /// ([`crate::api::future::FutureOpts::cached`]).  Entries are keyed
    /// **per element** under the same `base_index` substream rule as the
    /// RNG, so a warm map hits under ANY chunking policy — cached
    /// `future_lapply` is chunking-invariant by construction.
    pub cached: bool,
}

impl LapplyOpts {
    pub fn new() -> Self {
        LapplyOpts { capture: true, ..Default::default() }
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = Some(seed);
        self
    }

    pub fn chunking(mut self, chunking: Chunking) -> Self {
        self.chunking = chunking;
        self
    }

    pub fn no_capture(mut self) -> Self {
        self.capture = false;
        self
    }

    pub fn queued(mut self) -> Self {
        self.queued = true;
        self
    }

    pub fn in_order(mut self) -> Self {
        self.in_order = true;
        self
    }

    pub fn retry(mut self, policy: RetryPolicy) -> Self {
        self.retry = Some(policy);
        self
    }

    pub fn deadline(mut self, deadline: std::time::Duration) -> Self {
        self.deadline = Some(deadline);
        self
    }

    /// Opt every chunk future into the result cache (see
    /// [`LapplyOpts::cached`]).
    pub fn cached(mut self) -> Self {
        self.cached = true;
        self
    }
}

/// Partition `n` elements into `chunks` contiguous ranges whose sizes
/// differ by at most one (cover, disjoint, balanced — property-tested).
pub fn partition(n: usize, chunks: usize) -> Vec<Range<usize>> {
    if n == 0 {
        return Vec::new();
    }
    let chunks = chunks.clamp(1, n);
    let base = n / chunks;
    let extra = n % chunks;
    let mut out = Vec::with_capacity(chunks);
    let mut start = 0;
    for i in 0..chunks {
        let len = base + usize::from(i < extra);
        out.push(start..start + len);
        start += len;
    }
    out
}

/// Number of chunks for a policy given `n` elements and `workers`.
pub fn chunk_count(n: usize, workers: usize, chunking: Chunking) -> usize {
    if n == 0 {
        return 0;
    }
    match chunking {
        Chunking::PerElement => n,
        Chunking::PerWorker => workers.max(1),
        Chunking::Scheduling(f) => {
            // NaN and negative factors fall back to the per-worker default
            // (1.0) rather than silently collapsing to one chunk; zero
            // keeps future.apply's `scheduling = 0` meaning (everything in
            // a single chunk); sub-1.0 gives that fraction of the workers;
            // +inf saturates and is clamped to n below (per-element).
            let f = if f.is_nan() || f < 0.0 { 1.0 } else { f };
            ((workers.max(1) as f64 * f).round() as usize).max(1)
        }
        Chunking::ChunkSize(sz) => n.div_ceil(sz.max(1)),
    }
    .min(n)
}

/// Parallel `lapply()`: evaluate `body` once per element of `xs`, with the
/// element bound to `param`, returning values in input order.
///
/// This is `future.apply::future_lapply()`: chunks are built per the policy,
/// each chunk becomes one future, and with `seed` set each *element* gets
/// RNG substream `i` so the result is identical under any chunking, backend,
/// or worker count.  Chunk results are harvested **as they complete** (see
/// the module docs) unless [`LapplyOpts::in_order`] asks for the historical
/// strictly-ordered collect; the output is bit-identical either way.
///
/// Every chunk future passes through the session's plan-time static
/// analyzer (see [`crate::analysis`]) like any other create: a `Deny`
/// lint — say an oversized global capture — rejects the whole map at the
/// first chunk with [`FutureError::Rejected`], *before* any worker round
/// trip, so misconfiguration surfaces once at plan time instead of N
/// times at eval time.
pub fn future_lapply(
    xs: &[Value],
    param: &str,
    body: &Expr,
    env: &Env,
    opts: &LapplyOpts,
) -> Result<Vec<Value>, FutureError> {
    let futures = lapply_futures(xs, param, body, env, opts)?;
    if opts.in_order {
        collect_in_order(&futures, xs.len())
    } else {
        collect_streaming(&futures, xs.len())
    }
}

/// The pre-streaming reference collect: `value()` per chunk, strictly in
/// submission order — a slow first chunk blocks the harvest of every
/// finished chunk behind it.
fn collect_in_order(futures: &[Future], n_hint: usize) -> Result<Vec<Value>, FutureError> {
    let mut out = Vec::with_capacity(n_hint);
    for f in futures {
        match f.value()? {
            Value::List(items) => out.extend(items),
            other => out.push(other),
        }
    }
    Ok(out)
}

/// As-completed collect: drain resolutions through the shared completion
/// channel (each chunk is promoted to Done — its result leaves the
/// backend's parked map — the moment it finishes), then extract values into
/// their input-order slots.  Extraction after the drain never blocks, and
/// doing the `value()` pass in input order keeps the relay order of
/// captured stdout/conditions identical to [`collect_in_order`].
fn collect_streaming(futures: &[Future], n_hint: usize) -> Result<Vec<Value>, FutureError> {
    let mut set = FutureSet::new(futures);
    while set.wait_any().is_some() {}
    collect_in_order(futures, n_hint)
}

/// The launch half of [`future_lapply`] — returns the chunk futures without
/// collecting (lets callers interleave work or poll with `resolved()`).
pub fn lapply_futures(
    xs: &[Value],
    param: &str,
    body: &Expr,
    env: &Env,
    opts: &LapplyOpts,
) -> Result<Vec<Future>, FutureError> {
    if xs.is_empty() {
        return Ok(Vec::new());
    }
    // Only the worker count is needed here (future_with resolves its own
    // backend + context); asking the session directly avoids building a
    // throwaway SessionContext per map call.
    let workers = session::current().backend_for_depth(current_depth())?.workers();
    let n_chunks = chunk_count(xs.len(), workers, opts.chunking);

    // One body clone for the whole map; every chunk shares it by Arc.
    let shared_body = Arc::new(body.clone());

    let mut futures = Vec::with_capacity(n_chunks);
    for (ci, range) in partition(xs.len(), n_chunks).into_iter().enumerate() {
        // Element values are Arc-cheap clones (tensor payloads shared);
        // base_index pins the chunk's global element offset so seeded runs
        // are chunking-invariant (see module docs).
        let chunk_expr = Expr::map_chunk(
            param,
            Arc::clone(&shared_body),
            xs[range.clone()].to_vec(),
            range.start as u64,
        );
        let mut fopts = FutureOpts::new();
        fopts.seed = opts.seed;
        fopts.stdout = opts.capture;
        fopts.conditions = opts.capture;
        fopts.queued = opts.queued;
        fopts.retry = opts.retry.clone();
        fopts.deadline = opts.deadline;
        fopts.cached = opts.cached;
        fopts.label = Some(match &opts.label {
            Some(l) => format!("{l}[chunk {ci}]"),
            None => format!("lapply[chunk {ci}]"),
        });
        futures.push(future_with(chunk_expr, env, fopts)?);
    }
    Ok(futures)
}

/// Streaming map-reduce: map `body` over `xs` in chunks and fold every
/// element result into `init` with `combine` **in completion order** —
/// finished chunks feed the fold while slower chunks are still running,
/// turning the reduction's wall clock from O(slowest prefix) into
/// O(slowest chunk).
///
/// Because completion order varies run to run, `combine` must be
/// commutative and associative for a deterministic result (sums, products,
/// min/max, set union...).  For order-sensitive reductions, reduce over
/// [`future_lapply`]'s input-ordered output instead.
///
/// Within one chunk, element results fold left-to-right (input order).
pub fn future_map_reduce(
    xs: &[Value],
    param: &str,
    body: &Expr,
    env: &Env,
    opts: &LapplyOpts,
    init: Value,
    mut combine: impl FnMut(Value, Value) -> Result<Value, FutureError>,
) -> Result<Value, FutureError> {
    if xs.is_empty() {
        return Ok(init);
    }
    let futures = lapply_futures(xs, param, body, env, opts)?;
    let mut set = FutureSet::new(&futures);
    let mut acc = init;
    while let Some(i) = set.wait_any() {
        match futures[i].value()? {
            Value::List(items) => {
                for v in items {
                    acc = combine(acc, v)?;
                }
            }
            other => acc = combine(acc, other)?,
        }
    }
    Ok(acc)
}

/// `furrr::future_map()`: build each element's expression with a closure
/// over the element literal.
pub fn future_map(
    xs: &[Value],
    f: impl Fn(Expr) -> Expr,
    env: &Env,
    opts: &LapplyOpts,
) -> Result<Vec<Value>, FutureError> {
    // Desugar to lapply with a reserved parameter name.
    const PARAM: &str = ".x";
    let body = f(Expr::var(PARAM));
    future_lapply(xs, PARAM, &body, env, opts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::plan::{with_plan, PlanSpec};

    fn xs(n: usize) -> Vec<Value> {
        (0..n).map(|i| Value::I64(i as i64)).collect()
    }

    #[test]
    fn partition_covers_disjoint_balanced() {
        for n in [1usize, 2, 7, 10, 100] {
            for c in [1usize, 2, 3, 7, 100] {
                let parts = partition(n, c);
                // cover + disjoint
                let mut all = Vec::new();
                for r in &parts {
                    all.extend(r.clone());
                }
                assert_eq!(all, (0..n).collect::<Vec<_>>(), "n={n} c={c}");
                // balanced
                let sizes: Vec<usize> = parts.iter().map(|r| r.len()).collect();
                let (min, max) =
                    (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
                assert!(max - min <= 1, "n={n} c={c} sizes={sizes:?}");
            }
        }
        assert!(partition(0, 4).is_empty());
    }

    #[test]
    fn chunk_count_policies() {
        assert_eq!(chunk_count(10, 4, Chunking::PerElement), 10);
        assert_eq!(chunk_count(10, 4, Chunking::PerWorker), 4);
        assert_eq!(chunk_count(10, 4, Chunking::Scheduling(2.0)), 8);
        assert_eq!(chunk_count(10, 4, Chunking::ChunkSize(3)), 4);
        assert_eq!(chunk_count(3, 8, Chunking::PerWorker), 3); // never > n
        assert_eq!(chunk_count(0, 4, Chunking::PerWorker), 0);
    }

    #[test]
    fn chunk_count_scheduling_edge_cases() {
        // NaN / negative factors: per-worker fallback, not a silent 1.
        assert_eq!(chunk_count(10, 4, Chunking::Scheduling(f64::NAN)), 4);
        assert_eq!(chunk_count(10, 4, Chunking::Scheduling(-1.0)), 4);
        // Zero: future.apply's scheduling = 0 — one chunk total.
        assert_eq!(chunk_count(10, 4, Chunking::Scheduling(0.0)), 1);
        // Sub-1.0: that fraction of the workers (≥ 1).
        assert_eq!(chunk_count(10, 4, Chunking::Scheduling(0.5)), 2);
        assert_eq!(chunk_count(10, 4, Chunking::Scheduling(0.1)), 1);
        // +inf saturates and clamps to n (per-element).
        assert_eq!(chunk_count(10, 4, Chunking::Scheduling(f64::INFINITY)), 10);
        // Edge policies never exceed [1, n] for n > 0 and stay 0 at n = 0.
        for f in [f64::NAN, -3.0, 0.0, 0.3, f64::INFINITY] {
            assert_eq!(chunk_count(0, 4, Chunking::Scheduling(f)), 0);
            let c = chunk_count(7, 4, Chunking::Scheduling(f));
            assert!((1..=7).contains(&c), "f={f}: {c}");
        }
    }

    #[test]
    fn chunk_size_zero_clamps_to_one_element_chunks() {
        assert_eq!(chunk_count(10, 4, Chunking::ChunkSize(0)), 10);
        assert_eq!(chunk_count(0, 4, Chunking::ChunkSize(0)), 0);
        // And the full map still works.
        with_plan(PlanSpec::sequential(), || {
            let env = Env::new();
            let body = Expr::mul(Expr::var("x"), Expr::lit(2i64));
            let got = future_lapply(
                &xs(4),
                "x",
                &body,
                &env,
                &LapplyOpts::new().chunking(Chunking::ChunkSize(0)),
            )
            .unwrap();
            assert_eq!(got, vec![Value::I64(0), Value::I64(2), Value::I64(4), Value::I64(6)]);
        });
    }

    #[test]
    fn streaming_collect_matches_in_order_collect_bit_identically() {
        // The as-completed path must reproduce the in-order reference
        // exactly, including seeded RNG draws, under every chunking policy.
        with_plan(PlanSpec::multicore(2), || {
            let env = Env::new();
            let body = Expr::add(Expr::var("x"), Expr::runif(2));
            for chunking in [
                Chunking::PerElement,
                Chunking::PerWorker,
                Chunking::Scheduling(2.0),
                Chunking::ChunkSize(3),
            ] {
                let streamed = future_lapply(
                    &xs(8),
                    "x",
                    &body,
                    &env,
                    &LapplyOpts::new().seed(99).chunking(chunking),
                )
                .unwrap();
                let ordered = future_lapply(
                    &xs(8),
                    "x",
                    &body,
                    &env,
                    &LapplyOpts::new().seed(99).chunking(chunking).in_order(),
                )
                .unwrap();
                assert_eq!(streamed, ordered, "{chunking:?}");
            }
        });
    }

    #[test]
    fn lapply_denied_by_analysis_rejects_before_any_launch() {
        use crate::analysis::{AnalysisConfig, LintCode};
        use crate::api::session::Session;
        use crate::api::value::Tensor;
        let s = Session::with_plan(PlanSpec::multicore(2));
        s.set_analysis_config(AnalysisConfig::new().max_globals_size(64));
        let mut env = Env::new();
        env.insert("big", Tensor::new(vec![1024], vec![1.0f32; 1024]).unwrap());
        let body = Expr::add(
            Expr::var("x"),
            Expr::prim(crate::api::expr::PrimOp::Sum, vec![Expr::var("big")]),
        );
        let got = s.scope(|_| {
            let opts = LapplyOpts::new().chunking(Chunking::ChunkSize(2));
            future_lapply(&xs(8), "x", &body, &env, &opts)
        });
        match got {
            Err(FutureError::Rejected { diagnostics }) => {
                assert!(
                    diagnostics.iter().any(|d| d.code == LintCode::ExportSize),
                    "{diagnostics:?}"
                );
            }
            other => panic!("expected Rejected at creation, got {other:?}"),
        }
        // The denial pre-empted admission entirely.
        assert_eq!(crate::capacity::session_peak_in_use(s.id()), 0);
        s.close();
    }

    #[test]
    fn queued_lapply_matches_blocking_lapply() {
        with_plan(PlanSpec::multicore(2), || {
            let env = Env::new();
            let body = Expr::mul(Expr::var("x"), Expr::var("x"));
            let queued = future_lapply(
                &xs(10),
                "x",
                &body,
                &env,
                &LapplyOpts::new().queued().chunking(Chunking::ChunkSize(2)),
            )
            .unwrap();
            let blocking = future_lapply(
                &xs(10),
                "x",
                &body,
                &env,
                &LapplyOpts::new().chunking(Chunking::ChunkSize(2)),
            )
            .unwrap();
            assert_eq!(queued, blocking);
        });
    }

    #[test]
    fn map_reduce_folds_to_the_same_total_as_map_then_reduce() {
        with_plan(PlanSpec::multicore(2), || {
            let env = Env::new();
            let body = Expr::mul(Expr::var("x"), Expr::var("x"));
            let opts = LapplyOpts::new().chunking(Chunking::ChunkSize(3));
            let total = future_map_reduce(
                &xs(10),
                "x",
                &body,
                &env,
                &opts,
                Value::I64(0),
                |acc, v| match (acc, v) {
                    (Value::I64(a), Value::I64(b)) => Ok(Value::I64(a + b)),
                    other => panic!("unexpected fold inputs: {other:?}"),
                },
            )
            .unwrap();
            let want: i64 = (0..10).map(|i| i * i).sum();
            assert_eq!(total, Value::I64(want));
        });
    }

    #[test]
    fn map_reduce_empty_input_returns_init() {
        with_plan(PlanSpec::sequential(), || {
            let env = Env::new();
            let got = future_map_reduce(
                &[],
                "x",
                &Expr::var("x"),
                &env,
                &LapplyOpts::new(),
                Value::I64(7),
                |acc, _| Ok(acc),
            )
            .unwrap();
            assert_eq!(got, Value::I64(7));
        });
    }

    #[test]
    fn map_reduce_propagates_element_errors() {
        with_plan(PlanSpec::sequential(), || {
            let env = Env::new();
            let body = Expr::if_else(
                Expr::prim(crate::api::expr::PrimOp::Eq, vec![Expr::var("x"), Expr::lit(1i64)]),
                Expr::stop(Expr::lit("bad element")),
                Expr::var("x"),
            );
            let err = future_map_reduce(
                &xs(3),
                "x",
                &body,
                &env,
                &LapplyOpts::new(),
                Value::I64(0),
                |acc, _| Ok(acc),
            )
            .unwrap_err();
            assert!(err.is_eval());
        });
    }

    #[test]
    fn lapply_matches_sequential_map() {
        with_plan(PlanSpec::multicore(2), || {
            let env = Env::new();
            let body = Expr::mul(Expr::var("x"), Expr::var("x"));
            let got = future_lapply(&xs(10), "x", &body, &env, &LapplyOpts::new()).unwrap();
            let want: Vec<Value> = (0..10).map(|i| Value::I64(i * i)).collect();
            assert_eq!(got, want);
        });
    }

    #[test]
    fn lapply_uses_outer_globals() {
        with_plan(PlanSpec::sequential(), || {
            let mut env = Env::new();
            env.insert("offset", 100i64);
            let body = Expr::add(Expr::var("x"), Expr::var("offset"));
            let got = future_lapply(&xs(3), "x", &body, &env, &LapplyOpts::new()).unwrap();
            assert_eq!(got, vec![Value::I64(100), Value::I64(101), Value::I64(102)]);
        });
    }

    #[test]
    fn chunking_does_not_change_results_with_seed() {
        with_plan(PlanSpec::multicore(2), || {
            let env = Env::new();
            let body = Expr::runif(2);
            let a = future_lapply(
                &xs(8),
                "x",
                &body,
                &env,
                &LapplyOpts::new().seed(42).chunking(Chunking::PerElement),
            )
            .unwrap();
            let b = future_lapply(
                &xs(8),
                "x",
                &body,
                &env,
                &LapplyOpts::new().seed(42).chunking(Chunking::ChunkSize(4)),
            )
            .unwrap();
            let c = future_lapply(
                &xs(8),
                "x",
                &body,
                &env,
                &LapplyOpts::new().seed(42).chunking(Chunking::PerWorker),
            )
            .unwrap();
            assert_eq!(a, b);
            assert_eq!(b, c);
        });
    }

    #[test]
    fn lapply_launches_one_future_per_chunk() {
        // O(chunks) task structure: 10 elements at chunk size 3 → 4 chunk
        // futures, each resolving to the list of its elements' results.
        with_plan(PlanSpec::multicore(2), || {
            let env = Env::new();
            let body = Expr::mul(Expr::var("x"), Expr::lit(10i64));
            let fs = lapply_futures(
                &xs(10),
                "x",
                &body,
                &env,
                &LapplyOpts::new().chunking(Chunking::ChunkSize(3)),
            )
            .unwrap();
            assert_eq!(fs.len(), 4);
            let mut flat = Vec::new();
            for f in &fs {
                match f.value().unwrap() {
                    Value::List(items) => flat.extend(items),
                    other => flat.push(other),
                }
            }
            assert_eq!(flat, (0..10).map(|i| Value::I64(i * 10)).collect::<Vec<_>>());
        });
    }

    #[test]
    fn future_map_is_lapply_sugar() {
        with_plan(PlanSpec::sequential(), || {
            let env = Env::new();
            let got =
                future_map(&xs(4), |x| Expr::add(x, Expr::lit(1i64)), &env, &LapplyOpts::new())
                    .unwrap();
            assert_eq!(got, vec![Value::I64(1), Value::I64(2), Value::I64(3), Value::I64(4)]);
        });
    }

    #[test]
    fn empty_input_yields_empty_output() {
        with_plan(PlanSpec::sequential(), || {
            let env = Env::new();
            let got =
                future_lapply(&[], "x", &Expr::var("x"), &env, &LapplyOpts::new()).unwrap();
            assert!(got.is_empty());
        });
    }

    #[test]
    fn eval_error_in_element_propagates() {
        with_plan(PlanSpec::sequential(), || {
            let env = Env::new();
            let body = Expr::if_else(
                Expr::prim(crate::api::expr::PrimOp::Eq, vec![Expr::var("x"), Expr::lit(2i64)]),
                Expr::stop(Expr::lit("element 2 failed")),
                Expr::var("x"),
            );
            let err =
                future_lapply(&xs(4), "x", &body, &env, &LapplyOpts::new()).unwrap_err();
            assert!(err.is_eval());
            assert!(err.to_string().contains("element 2 failed"));
        });
    }
}
